//! Lst. 2 reproduction: non-invasively accelerating an "Elemental" GEMM.
//!
//! The paper's integration story (§IV-B): a CPU code keeps its own data
//! structures (Elemental distributed matrices holding MPFR values) and
//! hands the FPGA BLAS interface *indexing functions* instead of copying
//! into a foreign layout.  Here we mimic an Elemental-style column-major
//! local matrix with a leading dimension and accelerate its GEMM call via
//! `apfp::blas::gemm`, comparing against the host ("Elemental") result.
//!
//!     cargo run --release --example elemental_drop_in

use apfp::baseline;
use apfp::blas::{self, BlasTrans};
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::runtime::default_artifact_dir;
use apfp::softfloat::ApFloat;

/// Stand-in for El::Matrix<El::BigFloat>: column-major storage with a
/// leading dimension larger than the row count (as Elemental views have).
struct ElMatrix {
    height: usize,
    width: usize,
    ldim: usize,
    buffer: Vec<ApFloat>,
}

impl ElMatrix {
    fn uniform(height: usize, width: usize, prec: u32, seed: u64) -> Self {
        let ldim = height + 3; // deliberately padded leading dimension
        let src = Matrix::random(height, width, prec, seed, 30);
        let mut buffer = vec![ApFloat::zero(prec); ldim * width];
        for j in 0..width {
            for i in 0..height {
                buffer[j * ldim + i] = src.get(i, j).clone();
            }
        }
        ElMatrix { height, width, ldim, buffer }
    }

    fn to_matrix(&self, prec: u32) -> Matrix {
        Matrix::from_fn(self.height, self.width, prec, |i, j| self.buffer[j * self.ldim + i].clone())
    }
}

fn main() -> anyhow::Result<()> {
    let cfg = ApfpConfig { compute_units: 2, ..Default::default() };
    let prec = cfg.prec();
    let (m, n, k) = (20, 18, 22);

    // "El::DistMatrix<El::BigFloat> distr_a = ...;" — the host's own data
    let local_a = ElMatrix::uniform(m, k, prec, 11);
    let local_b = ElMatrix::uniform(k, n, prec, 12);
    let mut local_c = ElMatrix::uniform(m, n, prec, 13);

    // reference result computed by the "CPU library" (our Elemental stand-in)
    let want = baseline::gemm_threaded(
        &local_a.to_matrix(prec),
        &local_b.to_matrix(prec),
        &local_c.to_matrix(prec),
        4,
    );

    // --- the drop-in acceleration: Lst. 2 lines 17-31 --------------------
    let dev = Device::new(cfg, &default_artifact_dir())?;

    // "CIdxF index_A = [&](unsigned long i) { return ...Buffer()[i]...; }"
    let index_a = |i: usize| local_a.buffer[i].clone();
    let index_b = |i: usize| local_b.buffer[i].clone();
    let index_c = |i: usize| local_c.buffer[i].clone();

    let written = std::cell::RefCell::new(Vec::new());
    let stats = blas::gemm(
        &dev,
        BlasTrans::Normal,
        BlasTrans::Normal,
        m, n, k,
        index_a, local_a.ldim,
        index_b, local_b.ldim,
        index_c,
        |i, v| written.borrow_mut().push((i, v)),
        local_c.ldim,
    )?;
    for (i, v) in written.into_inner() {
        local_c.buffer[i] = v; // results land back in Elemental's storage
    }
    // ----------------------------------------------------------------------

    let got = local_c.to_matrix(prec);
    assert_eq!(got, want, "accelerated GEMM must match the CPU library bit-for-bit");
    println!(
        "accelerated El::Gemm drop-in: {}x{}x{} GEMM, {} tiles, bit-identical to the CPU result",
        m, n, k, stats.tiles
    );
    println!("C[0,0] = {}", got.get(0, 0).to_decimal_string(25));
    Ok(())
}
