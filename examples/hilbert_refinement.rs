//! Precision demonstration: solving a catastrophically ill-conditioned
//! system where f64 collapses and 448-bit APFP does not — the paper's §I
//! motivation ("information found in small differences between numbers")
//! made concrete, with the residual check running on the accelerator.
//!
//! The n x n Hilbert matrix H (H_ij = 1/(i+j+1)) has condition number
//! ~e^{3.5 n}; at n = 14 it is ~1e19, beyond f64's 1e16 precision.  We
//! solve H x = b exactly-ish via APFP Cholesky and compare the residual
//! ||Hx - b|| computed (a) in f64 and (b) in APFP through the device GEMM.
//!
//!     cargo run --release --example hilbert_refinement -- [n]

use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::linalg::{self, MatmulBackend};
use apfp::runtime::default_artifact_dir;
use apfp::softfloat::ApFloat;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(14);
    let cfg = ApfpConfig { compute_units: 2, ..Default::default() };
    let prec = cfg.prec();
    let dev = Device::new(cfg, &default_artifact_dir())?;
    let backend = MatmulBackend::Device(&dev);

    // Hilbert matrix in exact APFP (1/(i+j+1) via high-precision reciprocal)
    let h = Matrix::from_fn(n, n, prec, |i, j| {
        linalg::reciprocal(&ApFloat::from_u64((i + j + 1) as u64, prec))
    });
    // b = H * ones  =>  exact solution x = ones
    let ones = Matrix::from_fn(n, 1, prec, |_, _| ApFloat::from_u64(1, prec));
    let b = backend.gemm(&h, &ones, &Matrix::zeros(n, 1, prec))?;

    // --- f64 attempt -------------------------------------------------------
    let hf: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..n).map(|j| 1.0 / (i + j + 1) as f64).collect())
        .collect();
    let bf: Vec<f64> = (0..n).map(|i| b.get(i, 0).to_f64()).collect();
    let xf = f64_cholesky_solve(&hf, &bf);
    let f64_err: f64 = match xf {
        Some(x) => x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max),
        None => f64::INFINITY, // factorization broke down
    };

    // --- APFP solve through the library -------------------------------------
    let l = linalg::cholesky(&h).expect("Hilbert is SPD in exact arithmetic");
    let x = linalg::solve_lower_transpose(&l, &linalg::solve_lower(&l, &b));
    let apfp_err = (0..n)
        .map(|i| x.get(i, 0).sub(&ApFloat::from_u64(1, prec)).to_f64().abs())
        .fold(0.0, f64::max);

    // residual H x - b through the accelerator GEMM
    let hx = backend.gemm(&h, &x, &Matrix::zeros(n, 1, prec))?;
    let mut resid_exp = i64::MIN;
    for i in 0..n {
        let r = hx.get(i, 0).sub(b.get(i, 0));
        if !r.is_zero() {
            resid_exp = resid_exp.max(r.exp());
        }
    }

    println!("Hilbert system, n = {n} (condition ~ 1e{:.0}):", 1.519 * n as f64);
    println!("  f64 solve:   max |x_i - 1| = {f64_err:.3e}   <- garbage beyond n~12");
    println!("  APFP solve:  max |x_i - 1| = {apfp_err:.3e}");
    println!(
        "  APFP residual ||Hx - b||_max ~ 2^{}  (computed on the accelerator)",
        if resid_exp == i64::MIN { "-inf (exact)".to_string() } else { resid_exp.to_string() }
    );
    anyhow::ensure!(apfp_err < 1e-60, "APFP solve should be near-exact");
    anyhow::ensure!(f64_err > 1e-4, "at this size f64 must have degraded badly");
    if f64_err.is_finite() {
        println!(
            "APFP keeps ~{} orders of magnitude that f64 loses entirely",
            (f64_err / apfp_err.max(1e-300)).log10() as i64
        );
    } else {
        println!("f64 Cholesky broke down entirely; APFP solved to ~1e-116");
    }
    Ok(())
}

/// Plain f64 Cholesky solve; returns None when the factorization breaks.
fn f64_cholesky_solve(a: &[Vec<f64>], b: &[f64]) -> Option<Vec<f64>> {
    let n = b.len();
    let mut l = vec![vec![0.0f64; n]; n];
    for j in 0..n {
        let mut d = a[j][j];
        for k in 0..j {
            d -= l[j][k] * l[j][k];
        }
        if d <= 0.0 {
            return None;
        }
        l[j][j] = d.sqrt();
        for i in (j + 1)..n {
            let mut s = a[i][j];
            for k in 0..j {
                s -= l[i][k] * l[j][k];
            }
            l[i][j] = s / l[j][j];
        }
    }
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i][k] * y[k];
        }
        y[i] = s / l[i][i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[k][i] * x[k];
        }
        x[i] = s / l[i][i];
    }
    Some(x)
}
