//! Mixed-precision iterative refinement on one multi-width device — the
//! per-launch precision knob as a workload, not just an API.
//!
//! The n x n Hilbert matrix H (H_ij = 1/(i+j+1), condition ~e^{3.5 n}) is
//! solved as H x = b with the textbook refinement loop, split across two
//! mantissa widths served by the *same* device:
//!
//! * the **bulk work** — applying an approximate inverse M ~ H^-1 — runs
//!   as 128-bit GEMM launches (`enqueue`s at `gemm_at(128, ...)`), the
//!   cheap width;
//! * the **residual** r = b - H x, where the information lives in small
//!   differences between numbers (§I), runs as 512-bit GEMM launches on
//!   the same device, so the correction direction is computed from a
//!   residual the low width could never represent.
//!
//! Each iteration contracts the error by ~cond(H) * 2^-64 until it
//! bottoms out at the 448-bit residual floor — tens of orders of
//! magnitude below anything a single low-width solve reaches.  The run
//! ends with the device's per-width model ledger: how many tiles,
//! launches, and MACs each width actually executed, and that their sums
//! equal the device totals (the conservation invariant).
//!
//!     cargo run --release --example hilbert_refinement -- [n]

use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::linalg;
use apfp::runtime::{default_artifact_dir, BackendKind};
use apfp::softfloat::ApFloat;

/// Max |x_i - 1| through f64 (the exact solution is all-ones).
fn max_err(x: &Matrix, prec: u32) -> f64 {
    (0..x.rows())
        .map(|i| x.get(i, 0).sub(&ApFloat::from_u64(1, prec)).to_f64().abs())
        .fold(0.0, f64::max)
}

/// Largest residual exponent (base 2), or None when the residual is
/// exactly zero at the working width.
fn max_exp(r: &Matrix) -> Option<i64> {
    let mut e = None;
    for i in 0..r.rows() {
        let v = r.get(i, 0);
        if !v.is_zero() {
            e = Some(e.map_or(v.exp(), |m: i64| m.max(v.exp())));
        }
    }
    e
}

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    // One device, two widths: 128-bit for the bulk correction GEMMs,
    // 512-bit (the default) for the residual.  The sim backend is
    // bit-identical to native and feeds the model ledger the report
    // at the end reads from.
    let cfg = ApfpConfig {
        compute_units: 2,
        backend: BackendKind::Sim,
        widths: vec![128, 512],
        ..Default::default()
    };
    let hi = cfg.prec(); // 448 bits of mantissa
    let lo = 64u32; // the 128-bit packed width
    let dev = Device::new(cfg, &default_artifact_dir())?;

    // Hilbert matrix at the high width (1/(i+j+1) via high-precision
    // reciprocal), and b = H * ones so the exact solution is all-ones.
    let h = Matrix::from_fn(n, n, hi, |i, j| {
        linalg::reciprocal(&ApFloat::from_u64((i + j + 1) as u64, hi))
    });
    let ones = Matrix::from_fn(n, 1, hi, |_, _| ApFloat::from_u64(1, hi));
    let (b, _) = dev.gemm_at(512, &h, &ones, &Matrix::zeros(n, 1, hi))?;

    // The approximate inverse is *computed and applied* entirely at the
    // low width: M ~ H^-1 from a 64-bit-mantissa Cholesky.
    let h_lo = h.to_prec(lo);
    let m_lo = linalg::spd_inverse(&h_lo)
        .expect("Hilbert stays SPD at 64 bits of mantissa for small n");

    // x0 = M b, the one-shot low-width solve the refinement improves on.
    let b_lo = b.to_prec(lo);
    let (x_lo, _) = dev.gemm_at(128, &m_lo, &b_lo, &Matrix::zeros(n, 1, lo))?;
    let mut x = x_lo.to_prec(hi);
    let first_err = max_err(&x, hi);

    println!("Hilbert system, n = {n} (condition ~ 1e{:.0}):", 1.519 * n as f64);
    println!("  one-shot 128-bit solve: max |x_i - 1| = {first_err:.3e}");
    println!("  refining with 128-bit bulk GEMM + 512-bit residual:");

    let mut last_exp = i64::MAX;
    let mut iterations = 0usize;
    for iter in 1..=40 {
        // residual at the HIGH width on the device: r = b - H x
        let (hx, _) = dev.gemm_at(512, &h, &x, &Matrix::zeros(n, 1, hi))?;
        let r = Matrix::from_fn(n, 1, hi, |i, _| b.get(i, 0).sub(hx.get(i, 0)));
        let rexp = max_exp(&r);
        match rexp {
            None => {
                println!("    iter {iter:2}: residual exactly zero at 448 bits — done");
                iterations = iter;
                break;
            }
            Some(e) => {
                println!("    iter {iter:2}: max residual ~ 2^{e}  (~1e{:.0})", e as f64 * 0.30103);
                if e >= last_exp {
                    // bottomed out at the high-width residual floor
                    iterations = iter;
                    break;
                }
                last_exp = e;
            }
        }
        // correction at the LOW width on the same device: d = M r
        let r_lo = r.to_prec(lo);
        let (d_lo, _) = dev.gemm_at(128, &m_lo, &r_lo, &Matrix::zeros(n, 1, lo))?;
        let d = d_lo.to_prec(hi);
        x = Matrix::from_fn(n, 1, hi, |i, _| x.get(i, 0).add(d.get(i, 0)));
        iterations = iter;
    }
    let final_err = max_err(&x, hi);
    println!("  refined solve: max |x_i - 1| = {final_err:.3e} after {iterations} iterations");

    // ---- the per-width model ledger -----------------------------------
    let m = dev.model_metrics();
    anyhow::ensure!(m.is_live(), "the sim backend must feed the model ledger");
    println!("  per-width device ledger:");
    let (mut tiles, mut launches, mut macs) = (0u64, 0u64, 0u64);
    for w in m.width_breakdown() {
        println!(
            "    {:>4} bits: {:>3} launches, {:>3} tiles, {:>6} MACs, {:.3e} pJ",
            w.bits, w.launches, w.tiles, w.macs, w.energy_pj as f64
        );
        tiles += w.tiles;
        launches += w.launches;
        macs += w.macs;
    }
    anyhow::ensure!(
        (tiles, launches, macs) == (m.tiles, m.launches, m.macs),
        "per-width ledger must conserve the device totals"
    );

    // The point of the exercise, asserted: the low width alone is wrong
    // by many orders of magnitude; refinement with a high-width residual
    // recovers (nearly) the full 448-bit accuracy.
    anyhow::ensure!(first_err > 1e-12, "the 64-bit-mantissa solve should be visibly wrong");
    anyhow::ensure!(final_err < 1e-60, "refinement should reach deep sub-f64 accuracy");
    anyhow::ensure!(final_err < first_err * 1e-20, "refinement must improve by >= 20 orders");
    let lo_launches = m.width_breakdown().find(|w| w.bits == 128).map_or(0, |w| w.launches);
    let hi_launches = m.width_breakdown().find(|w| w.bits == 512).map_or(0, |w| w.launches);
    anyhow::ensure!(
        lo_launches >= 2 && hi_launches >= 2,
        "both widths must have done real work on the one device"
    );
    println!(
        "  refinement recovered ~{} orders of magnitude over the one-shot low-width solve",
        (first_err / final_err.max(1e-300)).log10() as i64
    );
    Ok(())
}
