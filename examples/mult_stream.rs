//! The §V-B multiplier microbenchmark, end to end:
//!   * stream operand pairs through the accelerator's multiplier artifacts
//!     (functional path, bit-checked);
//!   * measure this host's softfloat throughput (the MPFR-baseline analog);
//!   * print the modeled U250 Tab. I/II rows for the same configuration.
//!
//!     cargo run --release --example mult_stream -- [bits] [stream_len]

use apfp::baseline;
use apfp::bench_util::fmt_rate;
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::runtime::default_artifact_dir;
use apfp::sim::mult_sim;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let bits: u32 = args.next().map(|s| s.parse()).transpose()?.unwrap_or(512);
    let len: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(512);
    let cfg = ApfpConfig { bits, compute_units: 4, ..Default::default() };
    let prec = cfg.prec();

    // functional path: the linear operand stream of the paper's benchmark
    let dev = Device::new(cfg.clone(), &default_artifact_dir())?;
    let a = Matrix::random(1, len, prec, 1, 200);
    let b = Matrix::random(1, len, prec, 2, 200);
    let t0 = std::time::Instant::now();
    let got = dev.mul_stream(a.values(), b.values())?;
    let functional = len as f64 / t0.elapsed().as_secs_f64();
    for i in 0..len {
        assert_eq!(got[i], a.values()[i].mul(&b.values()[i]), "lane {i}");
    }
    println!("functional stream: {len} multiplications, bit-exact, {} through PJRT-CPU", fmt_rate(functional));

    // measured host baseline (the paper's L1-resident methodology)
    let one_core = baseline::measure_mul_throughput(prec, 100_000);
    println!("softfloat on this host: {} per core", fmt_rate(one_core));

    // modeled hardware rows (Tab. I / Tab. II)
    println!("\nmodeled U250 ({}-bit):", bits);
    for row in mult_sim::table(bits) {
        println!(
            "  {:<28} {:>10} {:>8} {:>8}",
            row.label,
            format!("{:.0} MOp/s", row.throughput_mops),
            format!("{:.1}x", row.speedup_vs_node),
            format!("{:.0} cores", row.equivalent_cores),
        );
    }
    Ok(())
}
