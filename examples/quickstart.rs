//! Quickstart: open the virtual accelerator, run a GEMM through the full
//! three-layer stack (Rust coordinator -> PJRT -> Pallas-lowered HLO), and
//! verify the result bit-for-bit against the software reference.
//!
//!     make artifacts && cargo run --release --example quickstart

use apfp::baseline;
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::runtime::default_artifact_dir;
use apfp::softfloat::ApFloat;

fn main() -> anyhow::Result<()> {
    // 1. Configuration — the paper's CMake knobs at runtime (§IV-A).
    let cfg = ApfpConfig { compute_units: 2, ..Default::default() };
    let prec = cfg.prec(); // 448-bit mantissas inside 512-bit numbers
    println!("opening device: {} CUs, {}-bit APFP", cfg.compute_units, cfg.bits);

    // 2. "Program the bitstream": spawn CU workers, load AOT artifacts.
    let dev = Device::new(cfg, &default_artifact_dir())?;
    for p in dev.placements() {
        println!("  CU[{}] -> DDR bank {} / SLR{}  (Fig. 4 round-robin)", p.cu, p.ddr_bank, p.slr);
    }

    // 3. Build operands (exactly representable decimal values).
    let n = 24;
    let a = Matrix::from_fn(n, n, prec, |i, j| {
        ApFloat::parse_decimal(&format!("{}.{:02}", i + 1, j), prec).unwrap()
    });
    let b = Matrix::from_fn(n, n, prec, |i, j| {
        ApFloat::from_i64((i as i64 - j as i64) * 3 + 1, prec)
    });
    let c = Matrix::zeros(n, n, prec);

    // 4. C += A @ B on the device (the §III tiled dataflow).
    let (got, stats) = dev.gemm(&a, &b, &c)?;
    println!(
        "device GEMM: {} tiles over {} artifact calls in {:.2}s (marshal {:.1}%)",
        stats.tiles, stats.artifact_calls, stats.wall_s, stats.marshal_fraction * 100.0
    );

    // 5. Verify against the MPFR-class software baseline, bit for bit.
    let want = baseline::gemm_serial(&a, &b, &c);
    assert_eq!(got, want, "accelerator output must be bit-identical");
    println!("verified: bit-identical to the softfloat reference");
    println!("C[0][0] = {}", got.get(0, 0).to_decimal_string(30));
    println!("C[{0}][{0}] = {1}", n - 1, got.get(n - 1, n - 1).to_decimal_string(30));
    Ok(())
}
