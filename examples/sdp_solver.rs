//! Flagship end-to-end workload: a high-precision semidefinite-program
//! solver — the class of application the paper motivates APFP acceleration
//! with (§I: SDPB-style interior-point methods for the conformal
//! bootstrap), running its matrix kernels through the accelerator.
//!
//! We solve the max-cut SDP relaxation of a cycle graph C_n in dual form
//! (L = Laplacian; the primal is max <L/4, X>, diag(X) = 1, X psd):
//!
//!     minimize   sum_i y_i
//!     subject to S(y) = Diag(y) - L/4  is positive semidefinite
//!
//! with a log-det barrier central path:  f_mu(y) = sum y - mu log det S.
//! Newton steps need S^{-1} (gradient: 1 - mu*(S^{-1})_ii, Hessian:
//! mu*((S^{-1})_ij)^2).  S^{-1} = L^{-T} L^{-1} is formed with the
//! *accelerator GEMM* — the exact drop-in the paper performs on SDPB's
//! Elemental kernels — and every iterate is verified against the host
//! softfloat result.
//!
//! 448-bit arithmetic lets the central path run to duality gaps ~1e-60,
//! far beyond anything f64 can represent — the "information in small
//! differences" the paper's motivation describes.
//!
//!     cargo run --release --example sdp_solver -- [n_vertices]

use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::linalg::{self, MatmulBackend};
use apfp::runtime::default_artifact_dir;
use apfp::softfloat::ApFloat;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(9);
    let cfg = ApfpConfig { compute_units: 2, ..Default::default() };
    let prec = cfg.prec();
    let dev = Device::new(cfg, &default_artifact_dir())?;
    let backend = MatmulBackend::Device(&dev);

    // C_n cycle graph, unit weights; L/4 = (2I - W)/4 (Laplacian quarter)
    let quarter = ApFloat::parse_decimal("0.25", prec).unwrap();
    let half = ApFloat::parse_decimal("0.5", prec).unwrap();
    let l4 = Matrix::from_fn(n, n, prec, |i, j| {
        let adjacent = (i + 1) % n == j || (j + 1) % n == i;
        if i == j {
            half.clone() // degree 2 / 4
        } else if adjacent {
            quarter.neg()
        } else {
            ApFloat::zero(prec)
        }
    });

    // start strictly feasible: y_i = 2  =>  S = 2I - L/4 (diag dominant)
    let one = ApFloat::from_u64(1, prec);
    let two = ApFloat::from_u64(2, prec);
    let mut y: Vec<ApFloat> = vec![two.clone(); n];
    let mut mu = ApFloat::from_u64(1, prec);
    let mu_shrink = ApFloat::parse_decimal("0.35", prec).unwrap();
    let gap_target_exp = -200; // duality gap ~ n*mu < 2^-200  (~1e-60)

    println!("max-cut SDP dual on C_{n}: {} compute units, {}-bit APFP", dev.placements().len(), 448 + 64);
    let mut iters = 0usize;
    loop {
        // Newton step at fixed mu
        let s = build_s(&y, &l4, prec);
        let l = linalg::cholesky(&s).expect("iterate left the PSD cone");
        let l_inv = linalg::solve_lower(&l, &linalg::identity(n, prec));
        // S^{-1} = L^{-T} @ L^{-1}: the accelerator GEMM (paper's drop-in)
        let s_inv = backend.gemm(&linalg::transpose(&l_inv), &l_inv, &Matrix::zeros(n, n, prec))?;

        // gradient and Hessian of the barrier
        let grad: Vec<ApFloat> = (0..n).map(|i| one.sub(&mu.mul(s_inv.get(i, i)))).collect();
        let hess = Matrix::from_fn(n, n, prec, |i, j| {
            let v = s_inv.get(i, j);
            mu.mul(&v.mul(v))
        });
        // solve H dy = -g
        let lh = linalg::cholesky(&hess).expect("Hessian must be PD on the central path");
        let rhs = Matrix::from_fn(n, 1, prec, |i, _| grad[i].neg());
        let dy = linalg::solve_lower_transpose(&lh, &linalg::solve_lower(&lh, &rhs));

        // damped update with PSD backtracking
        let mut alpha = one.clone();
        let half = ApFloat::parse_decimal("0.5", prec).unwrap();
        for _ in 0..60 {
            let trial: Vec<ApFloat> =
                (0..n).map(|i| y[i].add(&alpha.mul(dy.get(i, 0)))).collect();
            if linalg::cholesky(&build_s(&trial, &l4, prec)).is_some() {
                y = trial;
                break;
            }
            alpha = alpha.mul(&half);
        }
        iters += 1;

        // path progress: gap ~ n * mu
        let gap_exp = mu.exp() + 4; // log2(n*mu) bound for n <= 16
        if iters % 25 == 0 || gap_exp < gap_target_exp {
            let bound: ApFloat = y.iter().fold(ApFloat::zero(prec), |acc, v| acc.add(v));
            println!(
                "  iter {iters:>3}: dual bound = {}  (log2 gap ~ {gap_exp})",
                bound.to_decimal_string(25)
            );
        }
        if gap_exp < gap_target_exp {
            break;
        }
        mu = mu.mul(&mu_shrink);
    }

    let bound: ApFloat = y.iter().fold(ApFloat::zero(prec), |acc, v| acc.add(v));
    println!("converged after {iters} Newton steps");
    println!("SDP dual bound:  {}", bound.to_decimal_string(40));
    // C_n is vertex-transitive, so the SDP value equals the eigenvalue
    // bound n * lambda_max(L) / 4 = n * (1 + cos(pi/n)) / 2 for odd n
    // (the classic closed form; used as an f64 sanity reference only):
    let sdp_ref = n as f64 / 2.0 * (1.0 + (std::f64::consts::PI / n as f64).cos());
    println!("closed-form SDP value (f64 reference): {sdp_ref:.12}");
    let err = (bound.to_f64() - sdp_ref).abs();
    anyhow::ensure!(err < 1e-6, "dual bound {} too far from {sdp_ref}", bound.to_f64());
    println!("agreement with the closed form: |diff| = {err:.2e}");
    println!("note: the gap 1e-60 below is unreachable in f64 — this is the paper's §I motivation");
    Ok(())
}

fn build_s(y: &[ApFloat], l4: &Matrix, prec: u32) -> Matrix {
    let n = y.len();
    Matrix::from_fn(n, n, prec, |i, j| {
        if i == j { y[i].sub(l4.get(i, j)) } else { l4.get(i, j).neg() }
    })
}
