"""APFP compile path: Layer 1 (Pallas kernels) + Layer 2 (JAX model)."""
