"""AOT compile path: lower every artifact variant to HLO *text* + manifest.

This is the only entry point that runs Python in the whole system, invoked
once by ``make artifacts``.  Each configured (operator, precision, shape)
variant is lowered with jax.jit -> StableHLO -> XlaComputation -> HLO text,
which the Rust runtime loads via ``HloModuleProto::from_text_file`` and
compiles on the PJRT CPU client.

HLO **text** (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

The manifest (``artifacts/manifest.txt``) is a whitespace-separated table —
one artifact per line — parsed by rust/src/runtime/manifest.rs:

    name kind bits batch t_n t_m k_tile limbs file

Argument order conventions (fixed; the Rust runtime relies on them):
    mul/add :  sa ea ma sb eb mb          -> (s, e, m)
    mac     :  sc ec mc sa ea ma sb eb mb -> (s, e, m)
    gemm    :  sa ea ma sb eb mb sc ec mc -> (s, e, m)   [C += A @ B]
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import config, model
from .kernels import karatsuba


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the text
    parser on the Rust side; outputs become a tuple via return_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(bits: int, batch_shape):
    """(sign, exp, mant) ShapeDtypeStructs for one ApTensor plane group."""
    import jax.numpy as jnp

    l = config.mant_limbs(bits)
    return (
        jax.ShapeDtypeStruct(batch_shape, jnp.int32),
        jax.ShapeDtypeStruct(batch_shape, jnp.int64),
        jax.ShapeDtypeStruct(batch_shape + (l,), jnp.int32),
    )


def build_variants():
    """Yield (name, kind, bits, batch, t_n, t_m, k_tile, lowered)."""
    b = config.STREAM_BATCH
    for bits in config.ARTIFACT_BITS:
        x = _specs(bits, (b,))
        yield (f"mul_{bits}", "mul", bits, b, 0, 0, 0,
               jax.jit(model.mul_stream_flat).lower(*x, *x))
        yield (f"add_{bits}", "add", bits, b, 0, 0, 0,
               jax.jit(model.add_stream_flat).lower(*x, *x))
        yield (f"mac_{bits}", "mac", bits, b, 0, 0, 0,
               jax.jit(model.mac_stream_flat).lower(*x, *x, *x))
        for suffix, (t_n, t_m, k_tile) in config.TILE_VARIANTS.items():
            if bits == 1024 and suffix != "t8":
                continue  # keep 1024-bit artifact build time bounded (§V-D)
            a = _specs(bits, (t_n, k_tile))
            bm = _specs(bits, (k_tile, t_m))
            c = _specs(bits, (t_n, t_m))
            yield (f"gemm_{bits}_{suffix}", "gemm", bits, 0, t_n, t_m, k_tile,
                   jax.jit(model.gemm_tile_flat).lower(*a, *bm, *c))


def write_tpu_report(out_dir: str) -> None:
    """DESIGN.md §7: static TPU-side estimates (VMEM footprint, MAC counts)
    for the L1 kernel across precisions and bottom-out choices."""
    lines = [
        "# L1 Pallas kernel structure report (interpret=True carries no "
        "hardware timing; these are the quantities the DESIGN.md §7 TPU "
        "estimate is based on)",
        "# bits limbs padded base_limbs depth leaf_convs macs_per_mult "
        "schoolbook_macs mac_ratio vmem_bytes_per_block",
    ]
    for bits in config.ARTIFACT_BITS:
        for base in (4, 8, 16, 32):
            r = karatsuba.vmem_report(bits, base, config.STREAM_BATCH)
            lines.append(
                f"{r['bits']} {r['limbs']} {r['padded_limbs']} "
                f"{r['base_limbs']} {r['depth']} {r['leaf_convs']} "
                f"{r['macs_per_mult']} {r['schoolbook_macs']} "
                f"{r['mac_ratio']:.4f} {r['vmem_bytes_per_block']}"
            )
    with open(os.path.join(out_dir, "tpu_report.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    manifest = []
    for name, kind, bits, batch, t_n, t_m, k_tile, lowered in build_variants():
        fname = f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        limbs = config.mant_limbs(bits)
        manifest.append(
            f"{name} {kind} {bits} {batch} {t_n} {t_m} {k_tile} {limbs} {fname}"
        )
        print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("# name kind bits batch t_n t_m k_tile limbs file\n")
        f.write("\n".join(manifest) + "\n")

    write_tpu_report(out_dir)
    print(f"wrote {len(manifest)} artifacts + manifest to {out_dir}")


if __name__ == "__main__":
    main()
