"""Struct-of-arrays APFP tensor type shared by the L2 model and the tests.

An ``ApTensor`` holds a batch of APFP numbers as three planes:

  sign: (...)    i32, 0 = positive, 1 = negative
  exp:  (...)    i64, the 63-bit signed exponent (ZERO_EXP sentinel for 0)
  mant: (..., L) i32, little-endian 8-bit limbs of the normalized mantissa

This is the unpacked form of the paper's Fig. 1 format; ``pack_words`` /
``unpack_words`` below implement the packed Fig. 1 layout itself (sign bit
in the exponent MSB, mantissa tight-packed into a multiple of 512 bits) so
the Python tests can pin the same byte layout the Rust ``pack`` module uses.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import config
from .kernels import ref


class ApTensor(NamedTuple):
    sign: jnp.ndarray  # (...), i32
    exp: jnp.ndarray  # (...), i64
    mant: jnp.ndarray  # (..., L), i32

    @property
    def limbs(self) -> int:
        return self.mant.shape[-1]

    @property
    def batch_shape(self):
        return self.sign.shape

    def reshape(self, *shape) -> "ApTensor":
        return ApTensor(
            self.sign.reshape(shape),
            self.exp.reshape(shape),
            self.mant.reshape(shape + (self.limbs,)),
        )

    def __getitem__(self, idx) -> "ApTensor":
        return ApTensor(self.sign[idx], self.exp[idx], self.mant[idx])


def zeros(batch_shape, bits: int) -> ApTensor:
    l = config.mant_limbs(bits)
    return ApTensor(
        jnp.zeros(batch_shape, jnp.int32),
        jnp.full(batch_shape, config.ZERO_EXP, jnp.int64),
        jnp.zeros(batch_shape + (l,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Conversions to/from the exact PyApfp oracle
# ---------------------------------------------------------------------------


def from_py(values, bits: int) -> ApTensor:
    """Nested list/array of PyApfp -> ApTensor (shape inferred)."""
    arr = np.asarray(values, dtype=object)
    shape = arr.shape
    l = config.mant_limbs(bits)
    sign = np.zeros(shape, np.int32)
    exp = np.zeros(shape, np.int64)
    mant = np.zeros(shape + (l,), np.int32)
    for idx in np.ndindex(shape):
        v: ref.PyApfp = arr[idx]
        assert v.prec == config.PRECISIONS[bits]
        sign[idx] = v.sign
        exp[idx] = v.exp
        mant[idx] = v.mant_limb_list()
    return ApTensor(jnp.asarray(sign), jnp.asarray(exp), jnp.asarray(mant))


def to_py(t: ApTensor, bits: int):
    """ApTensor -> numpy object array of PyApfp."""
    prec = config.PRECISIONS[bits]
    sign = np.asarray(t.sign)
    exp = np.asarray(t.exp)
    mant = np.asarray(t.mant)
    out = np.empty(sign.shape, dtype=object)
    for idx in np.ndindex(sign.shape):
        out[idx] = ref.PyApfp.from_limb_parts(sign[idx], exp[idx], mant[idx], prec)
    return out


# ---------------------------------------------------------------------------
# Fig. 1 packed layout (numpy, used by tests to pin the Rust pack module)
# ---------------------------------------------------------------------------


def pack_words(v: ref.PyApfp, bits: int) -> list[int]:
    """Pack one APFP number into ``bits``/64 little-endian u64 words.

    Word 0 is the head word: 63-bit two's-complement exponent in bits 0..62
    and the sign in bit 63 (the paper packs the sign into the exponent
    word).  Words 1.. are the mantissa, least-significant limb first.
    """
    n_words = bits // 64
    exp = int(v.exp) & ((1 << 63) - 1)
    head = exp | (int(v.sign) << 63)
    words = [head]
    m = v.mant
    for _ in range(n_words - 1):
        words.append(m & ((1 << 64) - 1))
        m >>= 64
    assert m == 0
    return words


def unpack_words(words, bits: int) -> ref.PyApfp:
    head = int(words[0])
    sign = head >> 63
    exp = head & ((1 << 63) - 1)
    if exp >= 1 << 62:  # sign-extend the 63-bit exponent
        exp -= 1 << 63
    m = 0
    for i, w in enumerate(words[1:]):
        m |= int(w) << (64 * i)
    if m == 0:
        return ref.PyApfp.zero(config.PRECISIONS[bits])
    return ref.PyApfp(sign, exp, m, config.PRECISIONS[bits])
