"""Shared configuration for the APFP compile path (Layer 1 + Layer 2).

This mirrors the paper's CMake-time configuration surface (§IV-A):

  APFP_BITS            -> the entries of ``PRECISIONS`` (total packed bits,
                          including sign+exponent word, per Fig. 1)
  APFP_MULT_BASE_BITS  -> ``base_limbs`` (Karatsuba bottom-out threshold,
                          expressed in 8-bit limbs; 8 limbs = 64 bits, the
                          analog of the paper's Pareto-optimal 72-bit choice)
  APFP_ADD_BASE_BITS   -> ``add_chunk_limbs`` (carry-propagation chunking)

The number representation follows DESIGN.md §5 and the paper's Fig. 1:

  value = (-1)^sign * M * 2^(exp - p)

with ``M`` a p-bit mantissa normalized so that 2^(p-1) <= M < 2^p, ``exp`` a
63-bit signed exponent (an i64 here), and round-to-zero (MPFR_RNDZ)
semantics: results are the exact value truncated toward zero to p bits.

The mantissa is stored little-endian as 8-bit limbs held in i32 lanes
("limb planes").  8-bit limbs leave ~15 bits of headroom in an i32 lane for
the redundant carry-save representation used inside the Karatsuba kernel
(see kernels/karatsuba.py for the bound), which is the TPU-friendly analog
of the paper's explicit carry-save adder trees.
"""

import jax

# The exponent is an i64 (the paper packs sign+exponent into one 64-bit
# machine word); enable x64 before any tracing happens.
jax.config.update("jax_enable_x64", True)

# --- Limb geometry -----------------------------------------------------------

LIMB_BITS = 8
LIMB_BASE = 1 << LIMB_BITS
LIMB_MASK = LIMB_BASE - 1

# --- Supported precisions (paper's APFP_BITS) --------------------------------
#
# Total bits include the 64-bit sign+exponent word, exactly as in Fig. 1:
#   512-bit numbers carry a 448-bit mantissa (56 limbs)
#  1024-bit numbers carry a 960-bit mantissa (120 limbs)

PRECISIONS = {
    512: 448,
    1024: 960,
}


def mant_limbs(total_bits: int) -> int:
    """Number of 8-bit mantissa limbs for a given total (packed) bit width."""
    mant_bits = PRECISIONS[total_bits]
    assert mant_bits % LIMB_BITS == 0
    return mant_bits // LIMB_BITS


# --- Special values -----------------------------------------------------------
#
# Zero is represented as (sign=0, exp=ZERO_EXP, mant=0).  MPFR keeps a special
# zero as well; the sentinel is far below any exponent reachable through
# arithmetic on sane inputs (the paper, like us, does not handle
# overflow/underflow of the 63-bit exponent).

ZERO_EXP = -(1 << 61)

# --- Default kernel tuning (the paper's Pareto point, translated) -------------

DEFAULT_BASE_LIMBS = 8  # 64-bit bottom-out (paper: 72-bit MULT_BASE_BITS)

# Carry-propagation chunking (the ADD_BASE_BITS analog).  None = one
# full-width ripple scan.  perf_probe.py (EXPERIMENTS.md §Perf P4) measured
# the ripple ~8% faster per multiply on the CPU-XLA execution path, so the
# artifacts ship with None; pass an int to model the paper's staged adder.
DEFAULT_ADD_CHUNK_LIMBS = None

# Guard geometry for the floating-point adder workspace (DESIGN.md §5):
# 2 guard limbs (16 bits) below the mantissa + 1 overflow limb above.
GUARD_LIMBS = 2
OVERFLOW_LIMBS = 1
GUARD_BITS = GUARD_LIMBS * LIMB_BITS

# --- AOT artifact variants -----------------------------------------------------
#
# Every (kind, bits, shape) tuple below is lowered by aot.py into one HLO-text
# artifact that the Rust runtime loads through PJRT.  STREAM_BATCH is the
# batch size of the element-wise operator artifacts (Tab. I/II microbenchmark
# path); tile shapes are (T_N, T_M, K_TILE) for the GEMM compute-unit
# datapath (§III: T_N = T_M = 32 in the paper's evaluation; we additionally
# emit a small tile used by the fast test/e2e configurations).

STREAM_BATCH = 64

TILE_VARIANTS = {
    # name suffix -> (T_N, T_M, K_TILE)
    "t8": (8, 8, 8),
    "t16": (16, 16, 16),
}

ARTIFACT_BITS = (512, 1024)
