"""Generate cross-language test vectors pinning Rust softfloat to PyApfp.

Invoked by ``make artifacts`` (after aot.py).  Writes
``artifacts/test_vectors.txt`` with lines

    <op> <bits> <a-words> <b-words> [<c-words>] <result-words>

where each operand is the Fig. 1 packed representation as comma-separated
hex u64 words (apfp_types.pack_words).  rust/tests/vectors.rs replays every
line through the Rust library and requires bit equality — the cross-language
half of the paper's "bit-compatible with MPFR" check.
"""

from __future__ import annotations

import argparse
import os
import random

from . import apfp_types, config
from .kernels import ref


def w(v: ref.PyApfp, bits: int) -> str:
    return ",".join(f"{x:016x}" for x in apfp_types.pack_words(v, bits))


def interesting_values(bits: int, rng: random.Random):
    prec = config.PRECISIONS[bits]
    lo = 1 << (prec - 1)
    hi = (1 << prec) - 1
    vals = [
        ref.PyApfp.zero(prec),
        ref.PyApfp(0, 0, lo, prec),
        ref.PyApfp(0, 0, hi, prec),
        ref.PyApfp(1, 0, lo, prec),
        ref.PyApfp(1, 0, hi, prec),
        ref.PyApfp(0, 1, lo + 1, prec),
        ref.PyApfp(0, -1, hi - 1, prec),
        ref.PyApfp.from_float(1.0, prec),
        ref.PyApfp.from_float(-1.0, prec),
        ref.PyApfp.from_float(3.141592653589793, prec),
        ref.PyApfp(0, 900, lo | 1, prec),
        ref.PyApfp(1, -900, lo | 1, prec),
    ]
    for _ in range(40):
        m = rng.getrandbits(prec) | lo
        vals.append(ref.PyApfp(rng.randint(0, 1), rng.randint(-1200, 1200), m, prec))
    return vals


def emit(out):
    rng = random.Random(0xAB54)
    lines = []
    for bits in config.ARTIFACT_BITS:
        vals = interesting_values(bits, rng)
        # dense pairwise coverage on the corner values, random tail
        pairs = [(a, b) for a in vals[:12] for b in vals[:12]]
        pairs += [(rng.choice(vals), rng.choice(vals)) for _ in range(150)]
        for a, b in pairs:
            lines.append(f"mul {bits} {w(a, bits)} {w(b, bits)} {w(a.mul(b), bits)}")
            lines.append(f"add {bits} {w(a, bits)} {w(b, bits)} {w(a.add(b), bits)}")
            if not b.is_zero():
                lines.append(f"div {bits} {w(a, bits)} {w(b, bits)} {w(a.div(b), bits)}")
        # MAC triples (intermediate rounding semantics)
        for _ in range(80):
            c, a, b = (rng.choice(vals) for _ in range(3))
            lines.append(
                f"mac {bits} {w(c, bits)} {w(a, bits)} {w(b, bits)} "
                f"{w(c.mac(a, b), bits)}"
            )
        # near-cancellation adversarial cases for the adder
        prec = config.PRECISIONS[bits]
        for d in (0, 1, 2, 3, 8, 17, prec - 1, prec, prec + 1, prec + 17, 3000):
            for _ in range(4):
                m1 = rng.getrandbits(prec) | (1 << (prec - 1))
                m2 = rng.getrandbits(prec) | (1 << (prec - 1))
                x = ref.PyApfp(0, 10, m1, prec)
                y = ref.PyApfp(1, 10 - d, m2, prec)
                lines.append(f"add {bits} {w(x, bits)} {w(y, bits)} {w(x.add(y), bits)}")
    out.write("\n".join(lines) + "\n")
    return len(lines)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="../artifacts")
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "test_vectors.txt")
    with open(path, "w") as f:
        n = emit(f)
    print(f"wrote {n} test vectors to {path}")


if __name__ == "__main__":
    main()
