"""Layer 1: Pallas kernels for the APFP compute hot-spots."""
