"""Batched limb-plane primitives for the floating-point adder (§II-B).

Everything here is vectorized over the batch: per-element *dynamic* shifts,
sticky-bit extraction, and leading-zero counting — the operations the paper
implements with barrel shifters and LZC circuits in the adder pipeline.

Limb vectors are little-endian 8-bit limbs in i32 lanes.  Shifts are in
*bits* and may be negative (negative = left shift); out-of-range source
positions read as zero, matching a hardware shifter that fills with zeros.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import config

LB = config.LIMB_BITS
LM = config.LIMB_MASK


def _gather_limb(x, idx):
    """x: (..., N) limbs, idx: (..., N) source limb indices (may be out of
    range).  Returns x[..., idx] with zero fill outside [0, N)."""
    n = x.shape[-1]
    valid = (idx >= 0) & (idx < n)
    safe = jnp.clip(idx, 0, n - 1)
    g = jnp.take_along_axis(x, safe, axis=-1)
    return jnp.where(valid, g, 0)


def shift_right_bits(x, s):
    """Per-element dynamic funnel shift: result bit k = x bit (k + s).

    x: (..., N) canonical limbs; s: (...,) signed bit shift (s < 0 shifts
    left).  Returns (..., N) canonical limbs.  Bits shifted out are dropped;
    bits shifted in are zero.
    """
    x = jnp.asarray(x, jnp.int32)
    s = jnp.asarray(s, jnp.int64)
    n = x.shape[-1]
    q = s >> jnp.int64(3)  # floor division: works for negative shifts
    r = (s & 7).astype(jnp.int32)  # limb-internal shift in [0, 8)
    k = jnp.arange(n, dtype=jnp.int64)
    idx = k + q[..., None]
    lo = _gather_limb(x, idx)
    hi = _gather_limb(x, idx + 1)
    r_ = r[..., None]
    out = (lo >> r_) | jnp.where(r_ == 0, 0, hi << (LB - r_))
    return (out & LM).astype(jnp.int32)


def sticky_below(x, s):
    """True iff any bit of x strictly below bit position s is set.

    This is the sticky signal the RNDZ subtraction correction needs
    (DESIGN.md §5): when the aligned smaller operand loses nonzero bits, the
    computed difference must be decremented by one workspace ulp.
    """
    x = jnp.asarray(x, jnp.int32)
    s = jnp.asarray(s, jnp.int64)
    n = x.shape[-1]
    q = jnp.clip(s >> jnp.int64(3), 0, n)
    r = (jnp.maximum(s, 0) & 7).astype(jnp.int32)
    k = jnp.arange(n, dtype=jnp.int64)
    full = (k < q[..., None]) & (x != 0)
    any_full = jnp.any(full, axis=-1)
    part_idx = jnp.minimum(q, n - 1)
    part = jnp.take_along_axis(x, part_idx[..., None], axis=-1)[..., 0]
    mask = (1 << r) - 1
    part_set = jnp.where(q < n, (part & mask) != 0, False)
    return any_full | part_set


def bit_length(x):
    """Per-element bit length of a canonical limb vector (0 for zero).

    The vectorized leading-zero counter of the adder's renormalization stage.
    x: (..., N) -> (...,) int64 giving the position of the MSB + 1.
    """
    x = jnp.asarray(x, jnp.int32)
    n = x.shape[-1]
    nz = x != 0
    k = jnp.arange(1, n + 1, dtype=jnp.int64)  # 1-based so zero -> 0
    top1 = jnp.max(jnp.where(nz, k, 0), axis=-1)  # 1-based index of top limb
    top_limb = jnp.take_along_axis(
        x, jnp.maximum(top1 - 1, 0)[..., None].astype(jnp.int64), axis=-1
    )[..., 0]
    # bit length of an 8-bit value via comparison ladder
    bl = jnp.zeros(top_limb.shape, jnp.int64)
    for t in range(LB):
        bl = jnp.where(top_limb >= (1 << t), t + 1, bl)
    return jnp.where(top1 == 0, 0, (top1 - 1) * LB + bl)


def compare_mag(ma, mb):
    """Lexicographic magnitude comparison of equal-length canonical limb
    vectors: returns (...,) int32 in {-1, 0, +1} for a<b / a==b / a>b."""
    ma = jnp.asarray(ma, jnp.int32)
    mb = jnp.asarray(mb, jnp.int32)
    n = ma.shape[-1]
    d = jnp.sign(ma - mb)  # per-limb comparison
    k = jnp.arange(1, n + 1, dtype=jnp.int64)
    top = jnp.max(jnp.where(d != 0, k, 0), axis=-1)
    safe = jnp.maximum(top - 1, 0)
    winner = jnp.take_along_axis(d, safe[..., None].astype(jnp.int64), axis=-1)[..., 0]
    return jnp.where(top == 0, 0, winner).astype(jnp.int32)
