"""Carry/borrow propagation over limb planes.

The paper pipelines wide integer additions by splitting them into
``APFP_ADD_BASE_BITS``-bit chunks per pipeline stage (§II-A, Fig. 3's x-axis).
The vectorized analog here is a two-level scheme:

  * within a chunk of ``chunk_limbs`` limbs, carries ripple sequentially
    (combinatorial logic inside one stage);
  * between chunks, a second scan propagates the chunk carry-outs
    (the stage-to-stage pipeline registers).

Because a carry into an all-0xFF chunk can ripple through the whole chunk,
the inter-chunk scan re-ripples inside the chunk; both levels are exact.
``propagate_carries(x, None)`` collapses to a single full-width scan, the
analog of an unpipelined combinatorial adder.

All scans carry int64 accumulators: redundant limbs out of the Karatsuba
kernel are < 2^31 and the running carry is bounded by (2^31 + carry)/256,
so the int64 intermediate never overflows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .. import config


def _scan_carries(x):
    """Full-width exact carry propagation (little-endian, batched).

    x: (..., N) int64 possibly-redundant nonnegative limbs.
    Returns (..., N) canonical 8-bit limbs; any final carry-out is dropped
    (callers size the workspace so it cannot occur).
    """
    x = jnp.asarray(x, jnp.int64)
    xt = jnp.moveaxis(x, -1, 0)  # scan over the limb axis

    def step(carry, v):
        t = v + carry
        return t >> config.LIMB_BITS, t & config.LIMB_MASK

    _, out = jax.lax.scan(step, jnp.zeros(x.shape[:-1], jnp.int64), xt)
    return jnp.moveaxis(out, 0, -1)


@functools.partial(jax.jit, static_argnames=("chunk_limbs",))
def propagate_carries(x, chunk_limbs: int | None = config.DEFAULT_ADD_CHUNK_LIMBS):
    """Canonicalize a redundant limb vector to base-256 limbs.

    ``chunk_limbs`` is the ADD_BASE_BITS analog (limbs per pipeline stage);
    None means one full-width ripple.
    """
    x = jnp.asarray(x, jnp.int64)
    n = x.shape[-1]
    if chunk_limbs is None or chunk_limbs >= n:
        return _scan_carries(x).astype(jnp.int32)

    pad = (-n) % chunk_limbs
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    chunks = xp.reshape(xp.shape[:-1] + (-1, chunk_limbs))

    # Level 1: in-chunk ripple; record each chunk's carry-out.
    chunks_t = jnp.moveaxis(chunks, -1, 0)

    def in_chunk(carry, v):
        t = v + carry
        return t >> config.LIMB_BITS, t & config.LIMB_MASK

    carry_out, canon = jax.lax.scan(
        in_chunk, jnp.zeros(chunks.shape[:-1], jnp.int64), chunks_t
    )
    canon = jnp.moveaxis(canon, 0, -1)  # (..., n_chunks, chunk_limbs)

    # Level 2: propagate chunk carry-outs across chunks.  Adding a carry to a
    # canonical chunk can ripple through it, so the scan re-ripples in-chunk.
    canon_t = jnp.moveaxis(canon, -2, 0)  # (n_chunks, ..., chunk_limbs)
    couts_t = jnp.moveaxis(carry_out, -1, 0)  # (n_chunks, ...)

    def across(carry_in, args):
        chunk, cout = args
        c = carry_in
        outs = []
        for k in range(chunk_limbs):
            t = chunk[..., k] + c
            outs.append(t & config.LIMB_MASK)
            c = t >> config.LIMB_BITS
        return cout + c, jnp.stack(outs, axis=-1)

    _, fixed = jax.lax.scan(
        across, jnp.zeros(carry_out.shape[:-1], jnp.int64), (canon_t, couts_t)
    )
    fixed = jnp.moveaxis(fixed, 0, -2)
    out = fixed.reshape(x.shape[:-1] + (n + pad,))[..., :n]
    return out.astype(jnp.int32)


def propagate_borrows(x):
    """Exact borrow propagation of a signed limb-wise difference.

    x: (..., N) int64 limb-wise differences (each in roughly [-2^31, 2^31)).
    The represented integer must be nonnegative; returns canonical limbs.
    """
    x = jnp.asarray(x, jnp.int64)
    xt = jnp.moveaxis(x, -1, 0)

    def step(borrow, v):
        t = v + borrow  # borrow is <= 0
        limb = t & config.LIMB_MASK  # arithmetic-shift floor keeps this exact
        return (t - limb) >> config.LIMB_BITS, limb

    _, out = jax.lax.scan(step, jnp.zeros(x.shape[:-1], jnp.int64), xt)
    return jnp.moveaxis(out, 0, -1).astype(jnp.int32)
