"""Layer 1: the Karatsuba mantissa multiplier as a Pallas kernel.

This is the TPU re-think of the paper's §II-A multiplier (see DESIGN.md
§Hardware-Adaptation):

* The paper implements the Karatsuba decomposition as a *static C++ template
  recursion* (Lst. 1) that HLS unrolls into one flat, deeply pipelined
  circuit, bottoming out at MULT_BASE_BITS where operands are dispatched to
  hardened DSP48E2 18x18-bit multipliers.

* Here the decomposition is a *static Python recursion* that tracing unrolls
  into one flat HLO pipeline, bottoming out at ``base_limbs`` 8-bit limbs
  where operands are dispatched to a vectorized shift-and-accumulate limb
  convolution (the partial-product array a DSP/naive multiplier computes),
  mapped by XLA onto the VPU lanes.

* The paper keeps all sub-multiplications at n bits by explicitly tracking
  the sign of (a1 - a0)(b1 - b0).  A SIMD lane has no cost for a temporarily
  wide limb, so we use the (a0 + a1)(b0 + b1) Karatsuba variant in a
  *redundant carry-save representation*: limbs are allowed to exceed 8 bits
  during the computation and a single carry-propagation pass (kernels/carry)
  canonicalizes the final product.  This is the vector analog of the
  carry-save adder trees synthesis infers for the FPGA design.

Headroom analysis (int32 lanes, 8-bit canonical input limbs):
  at recursion depth d the inputs to a node are sums of at most 2^d original
  limbs, so every limb is < 256 * 2^d.  A base convolution of length B_L
  therefore produces partial sums < B_L * (256 * 2^D)^2 for maximum depth D,
  and the combination step c1 = m - c0 - c2 at most triples the magnitude.
  With B_L = 8 and D = 5 (i.e. 256 limbs = 2048-bit mantissas):
      3 * 8 * (256 * 32)^2 = 1.6e9 < 2^31.
  ``plan_depth`` asserts this bound for the configuration being built.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import config


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def plan_depth(limbs: int, base_limbs: int) -> int:
    """Recursion depth for a given (padded) limb count, with the int32
    headroom bound of the module docstring asserted."""
    padded = _next_pow2(limbs)
    depth = 0
    size = padded
    while size > base_limbs:
        size //= 2
        depth += 1
    bound = 3 * size * (256 << depth) ** 2
    assert bound < 2**31, (
        f"karatsuba(int32) headroom exceeded: limbs={limbs} base={base_limbs} "
        f"depth={depth} bound={bound}"
    )
    return depth


def base_conv(a, b):
    """Bottom-out primitive: shift-and-accumulate limb convolution.

    The analog of the paper's DSP-based naive multiplication: a full
    partial-product array, accumulated in redundant form.  a, b: (..., L);
    returns (..., 2L - 1).
    """
    l = a.shape[-1]
    out = jnp.zeros(a.shape[:-1] + (2 * l - 1,), a.dtype)
    for i in range(l):
        out = out.at[..., i : i + l].add(a[..., i : i + 1] * b)
    return out


def karatsuba(a, b, base_limbs: int):
    """Static-recursive Karatsuba over little-endian limb vectors.

    a, b: (..., L) with L a power of two.  Returns the redundant convolution
    (..., 2L - 1).  Mirrors the paper's Lst. 1: the recursion is resolved at
    trace time (their SFINAE bottom-out is our ``if`` on a static shape).
    """
    l = a.shape[-1]
    assert l == b.shape[-1] and (l & (l - 1)) == 0, "limb count must be 2^k"
    if l <= base_limbs:
        return base_conv(a, b)  # bottom out on the naive partial-product array
    h = l // 2
    a0, a1 = a[..., :h], a[..., h:]
    b0, b1 = b[..., :h], b[..., h:]
    c0 = karatsuba(a0, b0, base_limbs)  # recurse: low half
    c2 = karatsuba(a1, b1, base_limbs)  # recurse: high half
    m = karatsuba(a0 + a1, b0 + b1, base_limbs)  # recurse: cross (carry-save)
    c1 = m - c0 - c2
    # Recombine with shifts (multiplication by B = 2^(8h) is limb offset h).
    out = jnp.zeros(a.shape[:-1] + (2 * l - 1,), a.dtype)
    out = out.at[..., : 2 * h - 1].add(c0)
    out = out.at[..., h : 3 * h - 1].add(c1)
    out = out.at[..., 2 * h : 4 * h - 1].add(c2)
    return out


def _mult_kernel(a_ref, b_ref, o_ref, *, base_limbs: int, out_limbs: int):
    """Pallas kernel body: one batch-block of mantissa multiplications."""
    a = a_ref[...]
    b = b_ref[...]
    conv = karatsuba(a, b, base_limbs)
    pad = out_limbs - conv.shape[-1]
    o_ref[...] = jnp.pad(conv, ((0, 0), (0, pad)))


@functools.partial(jax.jit, static_argnames=("base_limbs",))
def mult_mantissa(a, b, base_limbs: int = config.DEFAULT_BASE_LIMBS):
    """Multiply batches of mantissas: (B, L) x (B, L) -> redundant (B, 2L).

    Pads L up to a power of two for the recursion (e.g. the 56-limb 448-bit
    mantissa computes in 64 limbs, like the paper's power-of-two-friendly
    decomposition of padded operands), then runs the Pallas kernel.  Output
    is the *redundant* product; canonicalize with kernels.carry.

    ``interpret=True`` everywhere: real-TPU lowering emits Mosaic
    custom-calls the CPU PJRT plugin cannot execute (see DESIGN.md).
    """
    batch, l = a.shape
    assert b.shape == (batch, l)
    padded = _next_pow2(l)
    plan_depth(l, base_limbs)
    a_p = jnp.pad(a.astype(jnp.int32), ((0, 0), (0, padded - l)))
    b_p = jnp.pad(b.astype(jnp.int32), ((0, 0), (0, padded - l)))
    out_limbs = 2 * l
    kernel = functools.partial(
        _mult_kernel, base_limbs=base_limbs, out_limbs=2 * padded
    )
    # One block spans the whole batch: the mantissa planes stream through
    # VMEM exactly once, the BlockSpec analog of the paper's operand streams
    # from DDR into the multiplier pipeline.
    res = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((batch, 2 * padded), jnp.int32),
        in_specs=[
            pl.BlockSpec((batch, padded), lambda: (0, 0)),
            pl.BlockSpec((batch, padded), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((batch, 2 * padded), lambda: (0, 0)),
        interpret=True,
    )(a_p, b_p)
    return res[:, :out_limbs]


def vmem_report(bits: int, base_limbs: int, batch: int) -> dict:
    """Static TPU-side resource estimate for this kernel configuration.

    interpret=True gives no hardware timing, so the DESIGN.md §7 TPU
    estimate is derived from structure: VMEM footprint of the blocks and
    MAC counts of the unrolled recursion tree.
    """
    l = config.mant_limbs(bits)
    padded = _next_pow2(l)
    depth = plan_depth(l, base_limbs)
    leaves = 3**depth
    base = padded >> depth
    macs_per_mult = leaves * base * base
    vmem_bytes = batch * (2 * padded + 2 * padded) * 4  # in + out blocks, i32
    return {
        "bits": bits,
        "limbs": l,
        "padded_limbs": padded,
        "base_limbs": base,
        "depth": depth,
        "leaf_convs": leaves,
        "macs_per_mult": macs_per_mult,
        "schoolbook_macs": padded * padded,
        "mac_ratio": macs_per_mult / (padded * padded),
        "vmem_bytes_per_block": vmem_bytes,
    }
