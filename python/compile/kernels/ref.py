"""Correctness oracles for the APFP kernels.

Two oracles live here:

1. ``conv_ref`` / ``carry_ref`` — pure-jnp/numpy schoolbook references for the
   limb-convolution (the quantity the Pallas Karatsuba kernel must match
   *after* carry canonicalization).

2. ``PyApfp`` — an *exact* arbitrary-precision reference implemented with
   Python integers.  This is the semantic gold standard for the whole
   reproduction: both the JAX model (python/tests) and the Rust softfloat
   library (rust/tests via generated vectors) are pinned bit-for-bit against
   it.  It plays the role MPFR plays in the paper ("our operators maintain
   full bit-compatibility in the mantissa with MPFR"), with round-to-zero
   (MPFR_RNDZ) semantics on normalized numbers.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from .. import config


# ---------------------------------------------------------------------------
# Pure-jnp limb convolution reference (schoolbook partial-product array)
# ---------------------------------------------------------------------------


def conv_ref(a, b):
    """Schoolbook limb convolution: out[..., k] = sum_i a[..., i] * b[..., k-i].

    a, b: (..., L) integer arrays (little-endian limbs, possibly redundant).
    Returns (..., 2L - 1) in the same redundant representation, computed in
    int64 so that any configuration the int32 kernel supports is covered.
    """
    a = jnp.asarray(a, jnp.int64)
    b = jnp.asarray(b, jnp.int64)
    la = a.shape[-1]
    lb = b.shape[-1]
    out = jnp.zeros(a.shape[:-1] + (la + lb - 1,), jnp.int64)
    for i in range(la):
        out = out.at[..., i : i + lb].add(a[..., i : i + 1] * b)
    return out


def carry_ref(x, out_limbs):
    """Exact carry propagation of a redundant limb vector to canonical base-256.

    x: (..., N) nonnegative redundant limbs. Returns (..., out_limbs) int64.
    """
    x = np.asarray(x)
    batch_shape = x.shape[:-1]
    flat = x.reshape(-1, x.shape[-1])
    out = np.zeros((flat.shape[0], out_limbs), dtype=np.int64)
    for r in range(flat.shape[0]):
        v = limbs_to_int(flat[r])
        out[r] = int_to_limbs(v, out_limbs)
    return jnp.asarray(out.reshape(batch_shape + (out_limbs,)))


# ---------------------------------------------------------------------------
# Limb <-> Python int helpers
# ---------------------------------------------------------------------------


def limbs_to_int(limbs) -> int:
    """Little-endian (possibly redundant) limbs -> exact Python integer."""
    v = 0
    for k, limb in enumerate(list(limbs)):
        v += int(limb) << (config.LIMB_BITS * k)
    return v


def int_to_limbs(v: int, n: int):
    """Exact Python integer -> n little-endian canonical 8-bit limbs."""
    assert v >= 0
    out = [(v >> (config.LIMB_BITS * k)) & config.LIMB_MASK for k in range(n)]
    assert v >> (config.LIMB_BITS * n) == 0, "value does not fit in limbs"
    return out


# ---------------------------------------------------------------------------
# Exact APFP reference (Python integers, RNDZ)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PyApfp:
    """Exact-semantics APFP scalar: value = (-1)^sign * mant * 2^(exp - prec).

    ``mant`` is either 0 (the zero value, with exp == config.ZERO_EXP) or a
    normalized ``prec``-bit integer in [2^(prec-1), 2^prec).
    """

    sign: int  # 0 or 1
    exp: int
    mant: int
    prec: int

    def __post_init__(self):
        if self.mant == 0:
            assert self.exp == config.ZERO_EXP and self.sign == 0
        else:
            assert (1 << (self.prec - 1)) <= self.mant < (1 << self.prec)

    # -- constructors -----------------------------------------------------

    @staticmethod
    def zero(prec: int) -> "PyApfp":
        return PyApfp(0, config.ZERO_EXP, 0, prec)

    @staticmethod
    def from_parts(sign: int, exp: int, mant: int, prec: int) -> "PyApfp":
        if mant == 0:
            return PyApfp.zero(prec)
        return PyApfp(sign, exp, mant, prec)

    @staticmethod
    def from_int_scaled(signed_scaled: int, scale_exp: int, prec: int) -> "PyApfp":
        """Exact value = signed_scaled * 2^scale_exp, truncated (RNDZ) to prec."""
        if signed_scaled == 0:
            return PyApfp.zero(prec)
        sign = 1 if signed_scaled < 0 else 0
        m = abs(signed_scaled)
        nbits = m.bit_length()
        # Normalize to exactly prec bits, truncating toward zero.
        if nbits >= prec:
            mant = m >> (nbits - prec)
        else:
            mant = m << (prec - nbits)
        exp = scale_exp + nbits
        return PyApfp(sign, exp, mant, prec)

    @staticmethod
    def from_float(x: float, prec: int) -> "PyApfp":
        if x == 0.0:
            return PyApfp.zero(prec)
        m, e = np.frexp(x)  # x = m * 2^e, 0.5 <= |m| < 1
        scaled = int(m * (1 << 53))  # exact: doubles have 53-bit significands
        return PyApfp.from_int_scaled(scaled, int(e) - 53, prec)

    # -- accessors ----------------------------------------------------------

    def is_zero(self) -> bool:
        return self.mant == 0

    def to_float(self) -> float:
        if self.is_zero():
            return 0.0
        m = self.mant >> (self.prec - 64)  # top 64 bits are plenty for f64
        v = float(m) * 2.0 ** (self.exp - 64)
        return -v if self.sign else v

    def to_exact(self):
        """Signed scaled pair: value = signed_mant * 2^(exp - prec)."""
        s = -self.mant if self.sign else self.mant
        return s, self.exp - self.prec

    # -- arithmetic (RNDZ) --------------------------------------------------

    def mul(self, other: "PyApfp") -> "PyApfp":
        assert self.prec == other.prec
        if self.is_zero() or other.is_zero():
            return PyApfp.zero(self.prec)
        sign = self.sign ^ other.sign
        prod = self.mant * other.mant  # exact, 2*prec (or 2*prec-1) bits
        exp = self.exp + other.exp
        nbits = prod.bit_length()  # 2*prec or 2*prec - 1
        mant = prod >> (nbits - self.prec)  # truncate = RNDZ
        exp = exp + nbits - 2 * self.prec
        return PyApfp(sign, exp, mant, self.prec)

    def add(self, other: "PyApfp") -> "PyApfp":
        """Exact sum, truncated toward zero to prec bits.

        This is computed through exact integers, so it serves as the
        specification that both the guard-limb JAX adder and the Rust
        softfloat adder must reproduce bit-for-bit.
        """
        assert self.prec == other.prec
        if self.is_zero():
            return other
        if other.is_zero():
            return self
        sa, ea = self.to_exact()
        sb, eb = other.to_exact()
        e = min(ea, eb)
        total = (sa << (ea - e)) + (sb << (eb - e))
        if total == 0:
            return PyApfp.zero(self.prec)  # MPFR_RNDZ: exact cancellation -> +0
        return PyApfp.from_int_scaled(total, e, self.prec)

    def sub(self, other: "PyApfp") -> "PyApfp":
        return self.add(other.neg())

    def div(self, other: "PyApfp") -> "PyApfp":
        """RNDZ division (the paper's §I "dependent operation"): the exact
        quotient floor'd at p bits.  q = floor(Ma * 2^(p+1) / Mb) keeps one
        guard bit + one headroom bit, and truncating q to p bits equals
        truncating the exact quotient (floor of floor on a coarser grid)."""
        assert self.prec == other.prec
        assert not other.is_zero(), "division by zero"
        if self.is_zero():
            return self
        sign = self.sign ^ other.sign
        q = (self.mant << (self.prec + 1)) // other.mant
        return PyApfp.from_int_scaled(
            -q if sign else q, self.exp - other.exp - (self.prec + 1), self.prec
        )

    def neg(self) -> "PyApfp":
        if self.is_zero():
            return self
        return PyApfp(1 - self.sign, self.exp, self.mant, self.prec)

    def mac(self, a: "PyApfp", b: "PyApfp") -> "PyApfp":
        """self + a*b with intermediate rounding, matching the hardware
        multiply-add pipeline (the product is truncated to prec before the
        addition, exactly as the paper's fused pipeline normalizes the
        multiplier output before feeding the adder)."""
        return self.add(a.mul(b))

    # -- limb-plane conversion -------------------------------------------

    def mant_limb_list(self):
        return int_to_limbs(self.mant, self.prec // config.LIMB_BITS)

    @staticmethod
    def from_limb_parts(sign, exp, limbs, prec) -> "PyApfp":
        m = limbs_to_int(limbs)
        if m == 0:
            return PyApfp.zero(prec)
        return PyApfp(int(sign), int(exp), m, prec)


def gemm_ref(a, b, c):
    """Reference GEMM over PyApfp matrices (lists of lists): C = A*B + C.

    Accumulation order matches the hardware dataflow (§III): the K loop is
    innermost and sequential, accumulating into the output element with
    intermediate rounding at every multiply-add — the same order the
    gemm_tile artifact and the Rust coordinator use, so results are
    bit-comparable.
    """
    n = len(a)
    k_dim = len(b)
    m = len(b[0])
    out = [[c[i][j] for j in range(m)] for i in range(n)]
    for i in range(n):
        for j in range(m):
            acc = out[i][j]
            for k in range(k_dim):
                acc = acc.mac(a[i][k], b[k][j])
            out[i][j] = acc
    return out
