"""Layer 2: the APFP operators and the GEMM compute-unit datapath in JAX.

The functions here are the JAX expression of the paper's hardware pipelines:

  ``apfp_mul``   — §II-A: Karatsuba mantissa multiply (the Pallas kernel),
                   carry canonicalization, renormalization, RNDZ truncation.
  ``apfp_add``   — §II-B: exponent alignment, guard-limb add/sub with sticky
                   correction, leading-zero renormalization, RNDZ truncation.
  ``apfp_mac``   — the combined multiply-addition pipeline the paper feeds
                   its GEMM with (§II-B last paragraph).
  ``gemm_tile``  — §III: one compute unit's inner dataflow — a T_N x T_M
                   output tile accumulated by a sequential K-scan of outer
                   products, exactly the paper's 2D tiling scheme.
  ``mul_stream`` / ``add_stream`` / ``mac_stream`` — the Tab. I/II
                   microbenchmark operators (linear operand streams).

Everything lowers to one HLO module per artifact via aot.py; the Rust
coordinator executes those artifacts through PJRT and never calls Python.

Semantics are pinned bit-for-bit against kernels.ref.PyApfp (exact Python
integers) by python/tests, and transitively against the Rust softfloat
library — the reproduction's analog of the paper's MPFR bit-compatibility.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import config
from .apfp_types import ApTensor
from .kernels import addsub, carry, karatsuba

LB = config.LIMB_BITS


def _is_zero(t: ApTensor):
    return t.exp == config.ZERO_EXP


def _select(pred, a: ApTensor, b: ApTensor) -> ApTensor:
    """Element-wise ApTensor select: pred ? a : b."""
    return ApTensor(
        jnp.where(pred, a.sign, b.sign),
        jnp.where(pred, a.exp, b.exp),
        jnp.where(pred[..., None], a.mant, b.mant),
    )


def _zero_like(t: ApTensor) -> ApTensor:
    return ApTensor(
        jnp.zeros_like(t.sign),
        jnp.full_like(t.exp, config.ZERO_EXP),
        jnp.zeros_like(t.mant),
    )


# ---------------------------------------------------------------------------
# Multiplication (§II-A)
# ---------------------------------------------------------------------------


def apfp_mul(
    a: ApTensor,
    b: ApTensor,
    *,
    base_limbs: int = config.DEFAULT_BASE_LIMBS,
    add_chunk_limbs: int = config.DEFAULT_ADD_CHUNK_LIMBS,
) -> ApTensor:
    """Batched APFP multiply, RNDZ.  a, b: ApTensor with equal batch shape."""
    l = a.limbs
    p = l * LB
    batch_shape = a.batch_shape
    flat = 1
    for dim in batch_shape:
        flat *= dim

    ma = a.mant.reshape(flat, l)
    mb = b.mant.reshape(flat, l)

    # L1 Pallas kernel: redundant Karatsuba product, then the staged
    # carry-propagation (the ADD_BASE_BITS-chunked adder analog).
    red = karatsuba.mult_mantissa(ma, mb, base_limbs=base_limbs)
    prod = carry.propagate_carries(red, chunk_limbs=add_chunk_limbs)  # (flat, 2L)
    prod = prod.reshape(batch_shape + (2 * l,))

    # Renormalize: the exact product has 2p or 2p-1 bits.  Truncating the
    # low (n - p) bits is exactly MPFR_RNDZ on the magnitude.
    n = addsub.bit_length(prod)  # (...,) 2p or 2p-1 (0 only if an input is 0)
    mant = addsub.shift_right_bits(prod, n - p)[..., :l]
    exp = a.exp + b.exp + (n - 2 * p)
    sign = a.sign ^ b.sign

    out = ApTensor(sign, exp.astype(jnp.int64), mant)
    zero = _is_zero(a) | _is_zero(b)
    return _select(zero, _zero_like(out), out)


# ---------------------------------------------------------------------------
# Addition (§II-B)
# ---------------------------------------------------------------------------


def apfp_add(a: ApTensor, b: ApTensor) -> ApTensor:
    """Batched APFP add/subtract, RNDZ, bit-exact vs the integer oracle.

    Pipeline stages (each maps to a stage of the paper's adder):
      1. magnitude compare + operand swap (big/small)
      2. barrel shift of the small operand by the exponent difference,
         with sticky extraction for the RNDZ subtraction correction
      3. guard-limb wide add or subtract (carry-save then canonicalize)
      4. leading-zero count + renormalization shift
      5. truncation to p bits (RNDZ)
    """
    l = a.limbs
    p = l * LB

    # -- stage 1: ordering by magnitude --------------------------------------
    mant_cmp = addsub.compare_mag(a.mant, b.mant)
    a_bigger = (a.exp > b.exp) | ((a.exp == b.exp) & (mant_cmp >= 0))
    big = _select(a_bigger, a, b)
    small = _select(a_bigger, b, a)
    equal_mag = (a.exp == b.exp) & (mant_cmp == 0)

    # -- stage 2: alignment ---------------------------------------------------
    # Workspace: [2 guard limbs | L mantissa limbs | 1 overflow limb], i.e.
    # the big operand's MSB sits at bit GUARD_BITS + p - 1.
    g, o = config.GUARD_LIMBS, config.OVERFLOW_LIMBS
    pad_cfg = [(0, 0)] * (big.mant.ndim - 1) + [(g, o)]
    ws_big = jnp.pad(big.mant, pad_cfg)
    ws_small_base = jnp.pad(small.mant, pad_cfg)
    d = (big.exp - small.exp).astype(jnp.int64)
    ws_small = addsub.shift_right_bits(ws_small_base, d)
    sticky = addsub.sticky_below(ws_small_base, d)

    # -- stage 3: wide add / subtract ----------------------------------------
    same_sign = big.sign == small.sign
    v_add = carry.propagate_carries(
        ws_big.astype(jnp.int64) + ws_small.astype(jnp.int64),
        chunk_limbs=config.DEFAULT_ADD_CHUNK_LIMBS,
    )
    diff = ws_big.astype(jnp.int64) - ws_small.astype(jnp.int64)
    # RNDZ correction: the truncated small operand under-shoots, so the raw
    # difference over-shoots; when sticky bits were lost, subtract one
    # workspace ulp (DESIGN.md §5 derivation).
    correction = jnp.where(~same_sign & sticky, 1, 0).astype(jnp.int64)
    diff = diff.at[..., 0].add(-correction)
    v_sub = carry.propagate_borrows(diff)
    v = jnp.where(same_sign[..., None], v_add, v_sub)

    # -- stages 4+5: renormalize and truncate ---------------------------------
    n = addsub.bit_length(v)
    mant = addsub.shift_right_bits(v, n - p)[..., :l]
    exp = big.exp + (n - (g * LB + p))
    sign = big.sign

    out = ApTensor(sign, exp.astype(jnp.int64), mant)

    # Exact cancellation -> +0 (MPFR_RNDZ convention).
    cancel = ~same_sign & equal_mag
    out = _select(cancel, _zero_like(out), out)
    # Zero operands pass the other operand through.
    out = _select(_is_zero(a), b, out)
    out = _select(_is_zero(b) & ~_is_zero(a), a, out)
    return out


def apfp_mac(c: ApTensor, a: ApTensor, b: ApTensor, **mul_kw) -> ApTensor:
    """The combined multiply-addition pipeline: c + a*b (product rounded to p
    bits before accumulation, matching the hardware pipeline)."""
    return apfp_add(c, apfp_mul(a, b, **mul_kw))


# ---------------------------------------------------------------------------
# GEMM compute-unit datapath (§III)
# ---------------------------------------------------------------------------


def gemm_tile(a: ApTensor, b: ApTensor, c: ApTensor, **mul_kw) -> ApTensor:
    """One compute unit's tile update: C += A @ B over APFP elements.

    a: (T_N, K), b: (K, T_M), c: (T_N, T_M).  The K loop is a sequential
    scan of T_N x T_M outer products accumulated into the on-chip tile —
    the exact dataflow of the paper's §III (one column of A times one row
    of B per step).
    """
    t_n, _ = a.batch_shape
    _, t_m = b.batch_shape
    l = a.mant.shape[-1]

    a_scan = ApTensor(a.sign.T, a.exp.T, jnp.swapaxes(a.mant, 0, 1))  # (K, T_N)
    b_scan = b  # already (K, T_M) in the leading axis

    def step(c_acc: ApTensor, ab):
        a_k, b_k = ab  # a_k: (T_N,), b_k: (T_M,)
        a_bc = ApTensor(
            jnp.broadcast_to(a_k.sign[:, None], (t_n, t_m)),
            jnp.broadcast_to(a_k.exp[:, None], (t_n, t_m)),
            jnp.broadcast_to(a_k.mant[:, None, :], (t_n, t_m, l)),
        )
        b_bc = ApTensor(
            jnp.broadcast_to(b_k.sign[None, :], (t_n, t_m)),
            jnp.broadcast_to(b_k.exp[None, :], (t_n, t_m)),
            jnp.broadcast_to(b_k.mant[None, :, :], (t_n, t_m, l)),
        )
        return apfp_mac(c_acc, a_bc, b_bc, **mul_kw), None

    out, _ = jax.lax.scan(step, c, (a_scan, b_scan))
    return out


# ---------------------------------------------------------------------------
# Stream operators (Tab. I / Tab. II microbenchmark path)
# ---------------------------------------------------------------------------


def mul_stream(a: ApTensor, b: ApTensor) -> ApTensor:
    """Linear multiplier stream: c[i] = a[i] * b[i]."""
    return apfp_mul(a, b)


def add_stream(a: ApTensor, b: ApTensor) -> ApTensor:
    return apfp_add(a, b)


def mac_stream(c: ApTensor, a: ApTensor, b: ApTensor) -> ApTensor:
    return apfp_mac(c, a, b)


# ---------------------------------------------------------------------------
# Flat-argument wrappers for AOT lowering (PJRT artifacts take/return planes)
# ---------------------------------------------------------------------------


def mul_stream_flat(sa, ea, ma, sb, eb, mb):
    out = mul_stream(ApTensor(sa, ea, ma), ApTensor(sb, eb, mb))
    return out.sign, out.exp, out.mant


def add_stream_flat(sa, ea, ma, sb, eb, mb):
    out = add_stream(ApTensor(sa, ea, ma), ApTensor(sb, eb, mb))
    return out.sign, out.exp, out.mant


def mac_stream_flat(sc, ec, mc, sa, ea, ma, sb, eb, mb):
    out = mac_stream(ApTensor(sc, ec, mc), ApTensor(sa, ea, ma), ApTensor(sb, eb, mb))
    return out.sign, out.exp, out.mant


def gemm_tile_flat(sa, ea, ma, sb, eb, mb, sc, ec, mc):
    out = gemm_tile(ApTensor(sa, ea, ma), ApTensor(sb, eb, mb), ApTensor(sc, ec, mc))
    return out.sign, out.exp, out.mant
