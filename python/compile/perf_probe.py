"""L1/L2 performance probe (§Perf P3/P4 in EXPERIMENTS.md).

interpret=True Pallas gives CPU-numpy timings only — *not* a TPU proxy —
so L1 tuning is structural (MAC counts, recursion depth, HLO op counts)
plus a CPU-wallclock sanity signal for the XLA-executed artifact graph:

  P3: Karatsuba bottom-out (``base_limbs`` — the MULT_BASE_BITS analog):
      MAC count + traced-graph size + CPU wallclock per batched multiply.
  P4: carry-propagation chunking (``add_chunk_limbs`` — the ADD_BASE_BITS
      analog): full ripple vs two-level chunked scan.

Usage:  cd python && python -m compile.perf_probe
"""

from __future__ import annotations

import time

import jax
import numpy as np

from . import config
from .kernels import carry, karatsuba


def time_jitted(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def probe_base_limbs(bits: int, batch: int = 64):
    l = config.mant_limbs(bits)
    rng = np.random.RandomState(0)
    a = rng.randint(0, 256, (batch, l)).astype(np.int32)
    b = rng.randint(0, 256, (batch, l)).astype(np.int32)
    print(f"\nP3 — mult_mantissa({bits}-bit, batch {batch}): base_limbs sweep")
    print(f"{'base':>6} {'depth':>6} {'leafconvs':>10} {'MACs':>8} {'ratio':>7} {'cpu_ms':>8}")
    for base in (4, 8, 16, 32, 64):
        r = karatsuba.vmem_report(bits, base, batch)
        dt = time_jitted(
            lambda a=a, b=b, base=base: karatsuba.mult_mantissa(a, b, base_limbs=base),
            iters=10,
        )
        print(
            f"{base:>6} {r['depth']:>6} {r['leaf_convs']:>10} "
            f"{r['macs_per_mult']:>8} {r['mac_ratio']:>7.3f} {dt * 1e3:>8.2f}"
        )


def probe_carry_chunking(bits: int, batch: int = 64):
    l = config.mant_limbs(bits)
    rng = np.random.RandomState(1)
    x = rng.randint(0, 2**24, (batch, 2 * l)).astype(np.int64)
    print(f"\nP4 — propagate_carries({bits}-bit product, batch {batch}): chunk sweep")
    print(f"{'chunk':>8} {'cpu_ms':>8}")
    for chunk in (None, 4, 8, 16, 32):
        dt = time_jitted(
            lambda x=x, chunk=chunk: carry.propagate_carries(x, chunk_limbs=chunk),
            iters=20,
        )
        label = "ripple" if chunk is None else str(chunk)
        print(f"{label:>8} {dt * 1e3:>8.2f}")


def main():
    for bits in config.ARTIFACT_BITS:
        probe_base_limbs(bits)
        probe_carry_chunking(bits)


if __name__ == "__main__":
    main()
