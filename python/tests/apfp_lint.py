"""apfp-lint: Python mirror of the `cargo xtask lint` static-analysis pass.

This module is the executable specification of the rule engine that lives in
``rust/xtask/src/engine.rs``.  Both implementations are deliberately
regex-free, line-mirrored ports of the same algorithm, and both are pinned by
the shared fixtures under ``rust/xtask/tests/fixtures/`` — the same strategy
PRs 1-5 used to verify kernels in a container without a Rust toolchain.

Three rule families (see docs/INVARIANTS.md for the catalogue):

* ``alloc`` / ``alloc-coverage`` — functions annotated ``// apfp-lint:
  no_alloc`` are transitively checked against an allocation denylist, and
  every annotated function must be exercised (by name) by
  ``tests/alloc_free.rs`` or be reachable from one that is.
* ``panic`` / ``index`` — no ``unwrap``/``expect``/``panic!``-family macros
  and no unguarded slice subscripts in ``runtime/`` (the simulated
  backend's model accounting in ``runtime/sim_backend.rs`` included — the
  ``panic_bad`` fixture pins that path), ``coordinator/`` (where the sim
  ledger ``coordinator/model_metrics.rs`` lives) and ``config.rs``
  outside ``#[cfg(test)]``.
* ``hazard`` — mechanical protocol shape of ``coordinator/stream.rs`` /
  ``worker.rs``: every ``TileResult`` / ``Job::GemmTile`` literal carries
  ``c_buf`` and the retry arm's ``attempt`` counter, reply receives are
  ``recv_timeout``, no unbounded/shared ``Inflight``-style channel
  reappears, and the probe interval stays ``ApfpConfig::reply_timeout``
  (no hardcoded ``REPLY_LIVENESS_INTERVAL``).

Escape hatch, shared grammar with the Rust port::

    // apfp-lint: allow(<rule>, reason="why this site is fine")
    // apfp-lint: allow(<rule>, scope=fn, reason="why this whole fn is fine")
    // apfp-lint: no_alloc

A trailing same-line ``allow`` applies to that line; a standalone comment
line applies to the next line of code; ``scope=fn`` (and ``no_alloc``)
attach to the next ``fn`` item.  A ``scope=fn`` alloc allow also stops the
transitive walk at that function (it is a declared cold path).
"""

from __future__ import annotations

import bisect
import json
import sys
from dataclasses import dataclass, field
from pathlib import Path

RULE_ALLOC = "alloc"
RULE_COVERAGE = "alloc-coverage"
RULE_PANIC = "panic"
RULE_INDEX = "index"
RULE_HAZARD = "hazard"
RULE_ANNOTATION = "annotation"

KNOWN_RULES = (RULE_ALLOC, RULE_COVERAGE, RULE_PANIC, RULE_INDEX, RULE_HAZARD)

# Kernel roots that must carry `// apfp-lint: no_alloc` at every non-test
# definition: the fixed-width GEMM fast path is only sound while its entry
# points stay on the allocation-free discipline, so silently dropping an
# annotation (and with it the transitive denylist walk) is itself an
# `alloc-coverage` finding.
REQUIRED_NO_ALLOC = ("mul_fixed", "gemm_fixed", "exec_gemm_tile_fixed")

# Files subject to the panic / index discipline (relative-path prefixes).
PANIC_SCOPE = ("runtime/", "coordinator/", "config.rs")
# Files subject to the hazard-protocol structure rule.
HAZARD_SCOPE = ("coordinator/stream.rs", "coordinator/worker.rs")

# Allocation denylist: (needle, label).  Needles starting with an identifier
# character additionally require a non-identifier character before the match.
DENY_ALLOC = (
    ("vec!", "vec! macro"),
    ("format!", "format! macro"),
    ("Vec::new", "Vec::new"),
    ("Vec::with_capacity", "Vec::with_capacity"),
    ("Vec::from", "Vec::from"),
    ("Box::new", "Box::new"),
    ("String::new", "String::new"),
    ("String::from", "String::from"),
    ("String::with_capacity", "String::with_capacity"),
    ("sync_channel(", "sync_channel"),
    (".to_vec(", "to_vec"),
    (".to_string(", "to_string"),
    (".to_owned(", "to_owned"),
    (".clone(", "clone"),
    (".collect(", "collect"),
    (".collect::<", "collect"),
    (".with_capacity(", "with_capacity"),
    (".resize(", "resize"),
    (".resize_with(", "resize_with"),
    (".reserve(", "reserve"),
)

# Panic-family denylist for the panic rule.
DENY_PANIC = (
    (".unwrap(", "unwrap"),
    (".expect(", "expect"),
    ("panic!", "panic! macro"),
    ("unreachable!", "unreachable! macro"),
    ("todo!", "todo! macro"),
    ("unimplemented!", "unimplemented! macro"),
)

# A subscript identifier counts as guarded when some earlier line of the same
# fn mentions it together with one of these markers (loop bounds, asserts,
# modulo arithmetic, clamping).
GUARD_MARKS = (
    "for ",
    "while ",
    "if ",
    "assert",
    "ensure!",
    "%",
    ".min(",
    ".max(",
    "match ",
    "clamp(",
    " < ",
    " <= ",
    "..",
)

# Identifiers never treated as unguarded subscript variables.
INDEX_IDENT_SKIP = {
    "self", "as", "usize", "u8", "u16", "u32", "u64", "i8", "i16", "i32",
    "i64", "f32", "f64", "len",
}


def is_ident(ch: str) -> bool:
    return ch.isalnum() or ch == "_"


@dataclass
class Finding:
    rule: str
    file: str
    line: int
    message: str
    allowed: bool = False
    reason: str | None = None

    def key(self):
        return (self.file, self.line, self.rule, self.message)


@dataclass
class Ann:
    kind: str  # "no_alloc" | "allow"
    line: int  # 1-based line the comment sits on
    rule: str | None = None
    reason: str | None = None
    scope_fn: bool = False


@dataclass
class FnRec:
    name: str
    file: str
    sig_line: int
    body_start_line: int
    end_line: int
    body: str  # masked body text including braces
    no_alloc: bool = False
    no_alloc_line: int = 0
    cold: bool = False  # carries a scope=fn alloc allow: walk stops here
    fn_allows: list = field(default_factory=list)  # [(rule, reason)]
    callees: set = field(default_factory=set)


@dataclass
class FileLint:
    rel: str
    src: str
    masked: str
    line_starts: list
    lines: list
    masked_lines: list
    anns: list
    site_allows: dict  # line -> [(rule, reason)]
    fns: list
    test_ranges: list  # [(start_line, end_line)]

    def line_of(self, off: int) -> int:
        return bisect.bisect_right(self.line_starts, off)

    def in_test(self, line: int) -> bool:
        return any(a <= line <= b for a, b in self.test_ranges)

    def enclosing_fns(self, line: int):
        return [f for f in self.fns if f.sig_line <= line <= f.end_line]


def mask_source(src: str) -> str:
    """Blank out comments, string/char literals (newlines preserved)."""
    out = list(src)
    n = len(src)

    def blank(a: int, b: int) -> None:
        for k in range(a, min(b, n)):
            if out[k] != "\n":
                out[k] = " "

    i = 0
    while i < n:
        c = src[i]
        if c == "/" and src.startswith("//", i):
            j = src.find("\n", i)
            j = n if j < 0 else j
            blank(i, j)
            i = j
        elif c == "/" and src.startswith("/*", i):
            depth, j = 1, i + 2
            while j < n and depth > 0:
                if src.startswith("/*", j):
                    depth, j = depth + 1, j + 2
                elif src.startswith("*/", j):
                    depth, j = depth - 1, j + 2
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c == "r" and (i == 0 or not is_ident(src[i - 1])):
            # raw string r"..." / r#"..."#
            j = i + 1
            hashes = 0
            while j < n and src[j] == "#":
                hashes, j = hashes + 1, j + 1
            if j < n and src[j] == '"':
                close = '"' + "#" * hashes
                k = src.find(close, j + 1)
                k = n if k < 0 else k + len(close)
                blank(i, k)
                i = k
            else:
                i += 1
        elif c == '"':
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                elif src[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c == "'":
            if i + 1 < n and src[i + 1] == "\\":
                j = i + 2
                while j < n and src[j] != "'":
                    j += 1
                blank(i, j + 1)
                i = j + 1
            elif i + 2 < n and src[i + 2] == "'":
                blank(i, i + 3)
                i += 3
            else:
                i += 1  # lifetime
        else:
            i += 1
    return "".join(out)


def find_with_boundary(line: str, needle: str) -> list:
    """Offsets of `needle` in `line`; identifier-leading needles require a
    non-identifier character immediately before the match."""
    hits = []
    start = 0
    while True:
        k = line.find(needle, start)
        if k < 0:
            return hits
        ok = True
        if is_ident(needle[0]) and k > 0 and is_ident(line[k - 1]):
            ok = False
        if ok:
            hits.append(k)
        start = k + 1


def ident_mentioned(line: str, ident: str) -> bool:
    """True when `ident` appears in `line` as a whole identifier."""
    start = 0
    while True:
        k = line.find(ident, start)
        if k < 0:
            return False
        before_ok = k == 0 or not is_ident(line[k - 1])
        after = k + len(ident)
        after_ok = after >= len(line) or not is_ident(line[after])
        if before_ok and after_ok:
            return True
        start = k + 1


def parse_annotations(lines: list, masked_lines: list, findings: list, rel: str):
    """Extract `// apfp-lint:` directives from original source lines."""
    anns = []
    for idx, line in enumerate(lines):
        lineno = idx + 1
        slash = line.find("//")
        if slash < 0:
            continue
        mark = line.find("apfp-lint:", slash)
        while mark >= 0:
            nxt = line.find("apfp-lint:", mark + 1)
            end = nxt if nxt >= 0 else len(line)
            parse_directive(line[mark + len("apfp-lint:"):end].strip(),
                            lineno, anns, findings, rel)
            mark = nxt
    return anns


def parse_directive(body: str, lineno: int, anns: list, findings: list, rel: str):
    if body.startswith("no_alloc"):
        anns.append(Ann(kind="no_alloc", line=lineno))
        return
    if not body.startswith("allow("):
        findings.append(Finding(RULE_ANNOTATION, rel, lineno,
                                f"unrecognized apfp-lint directive `{body[:40]}`"))
        return
    close = body.rfind(")")
    if close < 0:
        findings.append(Finding(RULE_ANNOTATION, rel, lineno,
                                "malformed apfp-lint allow: missing `)`"))
        return
    inner = body[len("allow("):close]
    rq = inner.find('reason="')
    reason = None
    head = inner
    if rq >= 0:
        rend = inner.find('"', rq + len('reason="'))
        if rend < 0:
            findings.append(Finding(RULE_ANNOTATION, rel, lineno,
                                    "malformed apfp-lint reason: unterminated string"))
            return
        reason = inner[rq + len('reason="'):rend]
        head = inner[:rq]
    rule = head.split(",")[0].strip()
    scope_fn = "scope=fn" in head
    if rule not in KNOWN_RULES:
        findings.append(Finding(RULE_ANNOTATION, rel, lineno,
                                f"unknown apfp-lint rule `{rule}`"))
        return
    if reason is None or not reason.strip():
        findings.append(Finding(RULE_ANNOTATION, rel, lineno,
                                f"apfp-lint allow({rule}) needs a reason=\"...\""))
        return
    anns.append(Ann(kind="allow", line=lineno, rule=rule,
                    reason=reason, scope_fn=scope_fn))


def parse_fns(fl: FileLint) -> None:
    masked, n = fl.masked, len(fl.masked)
    i = 0
    while True:
        i = masked.find("fn", i)
        if i < 0:
            return
        before = masked[i - 1] if i > 0 else " "
        after = masked[i + 2] if i + 2 < n else " "
        if is_ident(before) or not after.isspace():
            i += 2
            continue
        j = i + 2
        while j < n and masked[j].isspace():
            j += 1
        name_start = j
        while j < n and is_ident(masked[j]):
            j += 1
        name = masked[name_start:j]
        if not name:
            i += 2
            continue
        # find the body-opening brace (skip the parameter list; `;` at
        # paren-depth 0 means a bodyless trait signature)
        par = 0
        k = j
        body_start = -1
        while k < n:
            ch = masked[k]
            if ch == "(":
                par += 1
            elif ch == ")":
                par -= 1
            elif ch == "{" and par == 0:
                body_start = k
                break
            elif ch == ";" and par == 0:
                break
            k += 1
        if body_start < 0:
            i = k if k > i else i + 2
            continue
        depth = 0
        e = body_start
        while e < n:
            if masked[e] == "{":
                depth += 1
            elif masked[e] == "}":
                depth -= 1
                if depth == 0:
                    e += 1
                    break
            e += 1
        fl.fns.append(FnRec(
            name=name,
            file=fl.rel,
            sig_line=fl.line_of(i),
            body_start_line=fl.line_of(body_start),
            end_line=fl.line_of(e - 1),
            body=masked[body_start:e],
        ))
        i = j


def parse_test_ranges(fl: FileLint) -> None:
    masked, n = fl.masked, len(fl.masked)
    i = 0
    while True:
        i = masked.find("#[cfg(test)]", i)
        if i < 0:
            return
        start_line = fl.line_of(i)
        k = masked.find("{", i)
        if k < 0:
            fl.test_ranges.append((start_line, fl.line_of(n - 1)))
            return
        depth = 0
        e = k
        while e < n:
            if masked[e] == "{":
                depth += 1
            elif masked[e] == "}":
                depth -= 1
                if depth == 0:
                    break
            e += 1
        fl.test_ranges.append((start_line, fl.line_of(min(e, n - 1))))
        i = e


def attach_annotations(fl: FileLint, findings: list) -> None:
    """Bind parsed directives to lines / fns; dangling ones are findings."""
    for ann in fl.anns:
        if ann.kind == "allow" and not ann.scope_fn:
            target = ann.line
            code = fl.masked_lines[ann.line - 1].strip() if ann.line - 1 < len(fl.masked_lines) else ""
            if not code:
                # standalone comment: applies to the next line holding code
                target = 0
                for idx in range(ann.line, len(fl.masked_lines)):
                    if fl.masked_lines[idx].strip():
                        target = idx + 1
                        break
                if target == 0:
                    findings.append(Finding(RULE_ANNOTATION, fl.rel, ann.line,
                                            "dangling apfp-lint allow: no code follows"))
                    continue
            fl.site_allows.setdefault(target, []).append((ann.rule, ann.reason))
            continue
        # fn-scoped: nearest fn declared at or after the annotation line
        target_fn = None
        for f in fl.fns:
            if f.sig_line >= ann.line and (target_fn is None or f.sig_line < target_fn.sig_line):
                target_fn = f
        if target_fn is None:
            findings.append(Finding(RULE_ANNOTATION, fl.rel, ann.line,
                                    f"dangling apfp-lint {ann.kind}: no fn follows"))
            continue
        if ann.kind == "no_alloc":
            target_fn.no_alloc = True
            target_fn.no_alloc_line = ann.line
        else:
            target_fn.fn_allows.append((ann.rule, ann.reason))
            if ann.rule == RULE_ALLOC:
                target_fn.cold = True


def parse_callees(f: FnRec) -> None:
    body, n = f.body, len(f.body)
    i = 0
    while i < n:
        if is_ident(body[i]) and not body[i].isdigit() and (i == 0 or not is_ident(body[i - 1])):
            j = i
            while j < n and is_ident(body[j]):
                j += 1
            name = body[i:j]
            k = j
            while k < n and body[k].isspace():
                k += 1
            if k < n and body[k] == "(" and name not in ("if", "while", "for", "match", "return", "fn"):
                f.callees.add(name)
            i = j
        else:
            i += 1


def allow_for(fl: FileLint, line: int, rule: str):
    """(allowed, reason) for a finding at `line` of rule `rule`."""
    for r, reason in fl.site_allows.get(line, []):
        if r == rule:
            return True, reason
    for f in fl.enclosing_fns(line):
        for r, reason in f.fn_allows:
            if r == rule:
                return True, reason
    return False, None


def scan_denylist(fl: FileLint, first: int, last: int, deny, rule: str,
                  findings: list, context: str = "") -> None:
    """Flag denylist needles on lines [first, last] outside tests."""
    seen = set()
    for lineno in range(first, last + 1):
        if lineno - 1 >= len(fl.masked_lines) or fl.in_test(lineno):
            continue
        line = fl.masked_lines[lineno - 1]
        for needle, label in deny:
            if not find_with_boundary(line, needle):
                continue
            if (lineno, label) in seen:
                continue
            seen.add((lineno, label))
            allowed, reason = allow_for(fl, lineno, rule)
            msg = f"`{label}`{context}"
            findings.append(Finding(rule, fl.rel, lineno, msg, allowed, reason))


# ---------------------------------------------------------------------------
# Rule: alloc (+ coverage)
# ---------------------------------------------------------------------------

def resolve_callees(f: FnRec, fn_map: dict) -> list:
    """Resolve `f`'s callee names to function records.

    Name-based resolution is deliberately conservative: a name is followed
    only when it resolves unambiguously -- definitions in the caller's own
    file win; otherwise the name must have exactly one non-test definition
    in the whole tree.  Ambiguous names (trait methods with several
    implementations, ubiquitous names like `new`) are NOT traversed; each
    trait-dispatched kernel carries its own `no_alloc` annotation instead,
    so it is still checked as a root of its own.
    """
    if not f.callees:
        parse_callees(f)
    out = []
    for name in sorted(f.callees):
        cands = fn_map.get(name, [])
        same_file = [c for c in cands if c.file == f.file]
        if same_file:
            out.extend(same_file)
        elif len(cands) == 1:
            out.append(cands[0])
    return out


def run_alloc_rule(files: dict, coverage_text: str | None, findings: list) -> None:
    fn_map: dict[str, list] = {}
    for fl in files.values():
        for f in fl.fns:
            if not fl.in_test(f.sig_line):
                fn_map.setdefault(f.name, []).append(f)

    # required roots: every non-test definition of a fixed-path kernel
    # entry point must be annotated, independent of whether any other
    # root exists — this runs before the `if roots:` coverage gate below
    for name in REQUIRED_NO_ALLOC:
        for f in fn_map.get(name, []):
            if f.no_alloc:
                continue
            allowed, reason = allow_for(files[f.file], f.sig_line, RULE_COVERAGE)
            findings.append(Finding(
                RULE_COVERAGE, f.file, f.sig_line,
                f"`{name}` is a fixed-path kernel root and must carry "
                "`// apfp-lint: no_alloc`", allowed, reason))

    roots = [f for fl in files.values() for f in fl.fns if f.no_alloc]

    # transitive denylist walk from every annotated root
    visited = set()
    queue = [(f, f.name) for f in roots if not f.cold]
    while queue:
        f, root = queue.pop()
        key = (f.file, f.sig_line, f.name)
        if key in visited:
            continue
        visited.add(key)
        fl = files[f.file]
        ctx = f" in `{f.name}` (no_alloc root: `{root}`)"
        scan_denylist(fl, f.body_start_line, f.end_line, DENY_ALLOC,
                      RULE_ALLOC, findings, ctx)
        for cand in resolve_callees(f, fn_map):
            if not cand.cold:
                queue.append((cand, root))

    # coverage: every annotated fn must be named by tests/alloc_free.rs or be
    # reachable from an annotated fn that is
    if roots:
        if coverage_text is None:
            for f in roots:
                findings.append(Finding(
                    RULE_COVERAGE, f.file, f.no_alloc_line or f.sig_line,
                    f"`{f.name}` is marked no_alloc but tests/alloc_free.rs was not found"))
            return
        covered = set()
        queue = []
        for f in roots:
            if ident_mentioned(coverage_text, f.name):
                covered.add((f.file, f.sig_line, f.name))
                queue.append(f)
        seen = set(covered)
        while queue:
            f = queue.pop()
            for cand in resolve_callees(f, fn_map):
                key = (cand.file, cand.sig_line, cand.name)
                if key in seen:
                    continue
                seen.add(key)
                if cand.no_alloc:
                    covered.add(key)
                queue.append(cand)
        for f in roots:
            if (f.file, f.sig_line, f.name) in covered:
                continue
            allowed, reason = allow_for(files[f.file], f.no_alloc_line or f.sig_line,
                                        RULE_COVERAGE)
            findings.append(Finding(
                RULE_COVERAGE, f.file, f.no_alloc_line or f.sig_line,
                f"`{f.name}` is marked no_alloc but is not exercised by tests/alloc_free.rs",
                allowed, reason))


# ---------------------------------------------------------------------------
# Rule: panic
# ---------------------------------------------------------------------------

def in_panic_scope(rel: str) -> bool:
    return any(rel == p or rel.startswith(p) for p in PANIC_SCOPE)


def run_panic_rule(fl: FileLint, findings: list) -> None:
    scan_denylist(fl, 1, len(fl.lines), DENY_PANIC, RULE_PANIC, findings,
                  " in non-test code")


# ---------------------------------------------------------------------------
# Rule: index
# ---------------------------------------------------------------------------

def subscript_sites(fl: FileLint):
    """Yield (line, content) for subscript expressions `expr[...]`."""
    masked, n = fl.masked, len(fl.masked)
    i = 0
    while i < n:
        if masked[i] != "[":
            i += 1
            continue
        k = i - 1
        while k >= 0 and masked[k] in " \t":
            k -= 1
        prev = masked[k] if k >= 0 else " "
        if not (is_ident(prev) or prev in ")]"):
            i += 1
            continue
        if is_ident(prev):
            # a keyword before `[` means a pattern or literal, not a subscript
            w = k
            while w >= 0 and is_ident(masked[w]):
                w -= 1
            if masked[w + 1:k + 1] in ("let", "else", "in", "return", "mut", "ref", "match"):
                i += 1
                continue
        depth = 0
        e = i
        while e < n:
            if masked[e] == "[":
                depth += 1
            elif masked[e] == "]":
                depth -= 1
                if depth == 0:
                    break
            e += 1
        yield fl.line_of(i), masked[i + 1:e]
        i = e + 1


def subscript_idents(content: str):
    """(guardable idents, any_ident): field accesses, constants and numeric
    types are opaque to the guard heuristic and excluded from the first
    list; `any_ident` distinguishes them from pure-literal subscripts."""
    idents = []
    any_ident = False
    n = len(content)
    i = 0
    while i < n:
        if is_ident(content[i]) and not content[i].isdigit() and (i == 0 or not is_ident(content[i - 1])):
            j = i
            while j < n and is_ident(content[j]):
                j += 1
            name = content[i:j]
            k = i - 1
            while k >= 0 and content[k] in " \t":
                k -= 1
            is_field = k >= 0 and content[k] == "."
            # `x.field` as an index is opaque to the guard heuristic: skip
            # both the base and the field (covered by the dynamic tests)
            nk = j
            while nk < n and content[nk] in " \t":
                nk += 1
            is_base = nk < n and content[nk] == "."
            if name != "as":
                any_ident = True
            skip = is_field or is_base or name in INDEX_IDENT_SKIP or name[0].isupper()
            if not skip and name not in idents:
                idents.append(name)
            i = j
        else:
            i += 1
    return idents, any_ident


def run_index_rule(fl: FileLint, findings: list) -> None:
    seen = set()
    for lineno, content in subscript_sites(fl):
        if fl.in_test(lineno):
            continue
        if ".." in content:
            continue  # range slices pair with copy_from_slice length asserts
        idents, any_ident = subscript_idents(content)
        encl = fl.enclosing_fns(lineno)
        if not encl:
            continue
        fn = min(encl, key=lambda f: f.sig_line)
        unguarded = []
        if not idents and not any_ident:
            unguarded.append("<literal>")
        for ident in idents:
            ok = False
            for ln in range(fn.sig_line, lineno + 1):
                if ln - 1 >= len(fl.masked_lines):
                    break
                line = fl.masked_lines[ln - 1]
                if ident_mentioned(line, ident) and any(m in line for m in GUARD_MARKS):
                    ok = True
                    break
            if not ok:
                unguarded.append(ident)
        if not unguarded:
            continue
        key = (lineno, tuple(unguarded))
        if key in seen:
            continue
        seen.add(key)
        allowed, reason = allow_for(fl, lineno, RULE_INDEX)
        what = ", ".join(f"`{u}`" for u in unguarded)
        findings.append(Finding(
            RULE_INDEX, fl.rel, lineno,
            f"subscript without visible guard for {what}", allowed, reason))


# ---------------------------------------------------------------------------
# Rule: hazard
# ---------------------------------------------------------------------------

def in_hazard_scope(rel: str) -> bool:
    return any(rel == p or rel.endswith(p) for p in HAZARD_SCOPE)


def scan_reply_literals(fl: FileLint, token: str, findings: list) -> None:
    """Braced ``token { ... }`` literals must carry ``c_buf`` (the staging
    buffer rides every job and reply arm) and ``attempt`` (the delivery
    counter the retry budget keys on).  Declarations are skipped and
    destructuring patterns eliding fields with ``..`` are exempt from the
    ``attempt`` requirement."""
    masked, n = fl.masked, len(fl.masked)
    i = 0
    while True:
        i = masked.find(token, i)
        if i < 0:
            break
        before = masked[i - 1] if i > 0 else " "
        if is_ident(before):
            i += len(token)
            continue
        head = masked[max(0, i - 16):i]
        j = i + len(token)
        while j < n and masked[j].isspace():
            j += 1
        if j >= n or masked[j] != "{" or any(k in head for k in ("struct", "impl", "enum", "->")):
            i += len(token)
            continue
        depth = 0
        e = j
        while e < n:
            if masked[e] == "{":
                depth += 1
            elif masked[e] == "}":
                depth -= 1
                if depth == 0:
                    break
            e += 1
        lineno = fl.line_of(i)
        body = masked[j:e]
        if not fl.in_test(lineno):
            if "c_buf" not in body:
                allowed, reason = allow_for(fl, lineno, RULE_HAZARD)
                findings.append(Finding(
                    RULE_HAZARD, fl.rel, lineno,
                    f"`{token}` literal without `c_buf`: the staging buffer must "
                    "ride every job and reply arm", allowed, reason))
            elif ".." not in body and "attempt" not in body:
                allowed, reason = allow_for(fl, lineno, RULE_HAZARD)
                findings.append(Finding(
                    RULE_HAZARD, fl.rel, lineno,
                    f"`{token}` literal without `attempt`: the delivery counter "
                    "the retry budget keys on must ride every job and reply",
                    allowed, reason))
        i = e


def scan_width_agreement(fl: FileLint, findings: list) -> None:
    """The mixed-width launch path must validate widths before touching
    any hazard or dispatch state: inside ``fn enqueue_gemm_at``, the typed
    ``WidthMismatch`` rejection has to appear before the first
    hazard-state token (``writes_our_set``, ``retire_n``,
    ``build_b_cache``).  A launch rejected only after the hazard drain
    would have retired other launches — mutated stream state — for a
    launch that never runs."""
    fn_token = "fn enqueue_gemm_at"
    fn_ends = ("\nfn ", "\npub fn ", "\n    fn ", "\n    pub fn ")
    hazard_tokens = ("writes_our_set", "retire_n", "build_b_cache")
    masked = fl.masked
    i = 0
    while True:
        at = masked.find(fn_token, i)
        if at < 0:
            break
        i = at + len(fn_token)
        lineno = fl.line_of(at)
        if fl.in_test(lineno):
            continue
        ends = [e for e in (masked.find(t, i) for t in fn_ends) if e >= 0]
        end = min(ends) if ends else len(masked)
        body = masked[i:end]
        check = body.find("WidthMismatch")
        hazards = [h for h in (body.find(t) for t in hazard_tokens) if h >= 0]
        bad = check < 0 or bool(hazards and min(hazards) < check)
        if bad:
            allowed, reason = allow_for(fl, lineno, RULE_HAZARD)
            findings.append(Finding(
                RULE_HAZARD, fl.rel, lineno,
                "`enqueue_gemm_at` must reject mismatched operand widths "
                "(`WidthMismatch`) before the hazard scan touches stream state",
                allowed, reason))
        i = end


def run_hazard_rule(fl: FileLint, findings: list) -> None:
    # every TileResult reply and Job::GemmTile job must carry the staging
    # buffer and the delivery-attempt counter (ISSUE 7's retry arm)
    scan_reply_literals(fl, "TileResult", findings)
    scan_reply_literals(fl, "GemmTile", findings)
    if not fl.rel.endswith("stream.rs"):
        return
    # mixed-width launches: the width-agreement check precedes the hazard
    # scan (ISSUE 10)
    scan_width_agreement(fl, findings)

    # leader-side receives must be recv_timeout (hang-proof drains)
    for idx, line in enumerate(fl.masked_lines):
        lineno = idx + 1
        if fl.in_test(lineno):
            continue
        if find_with_boundary(line, ".recv()"):
            allowed, reason = allow_for(fl, lineno, RULE_HAZARD)
            findings.append(Finding(
                RULE_HAZARD, fl.rel, lineno,
                "bare `.recv()` on a reply channel: use `recv_timeout` so a "
                "dead worker cannot hang the leader", allowed, reason))
        for k in find_with_boundary(line, "channel("):
            if line[:k].endswith("sync_"):
                continue
            allowed, reason = allow_for(fl, lineno, RULE_HAZARD)
            findings.append(Finding(
                RULE_HAZARD, fl.rel, lineno,
                "unbounded `channel()`: reply channels must be bounded "
                "`sync_channel` sized to the launch", allowed, reason))
        if ident_mentioned(line, "Inflight"):
            allowed, reason = allow_for(fl, lineno, RULE_HAZARD)
            findings.append(Finding(
                RULE_HAZARD, fl.rel, lineno,
                "shared `Inflight` channel type: per-launch reply channels "
                "replaced it (PR 5)", allowed, reason))
        if ident_mentioned(line, "REPLY_LIVENESS_INTERVAL"):
            allowed, reason = allow_for(fl, lineno, RULE_HAZARD)
            findings.append(Finding(
                RULE_HAZARD, fl.rel, lineno,
                "hardcoded `REPLY_LIVENESS_INTERVAL`: the probe interval is "
                "`ApfpConfig::reply_timeout` now (ISSUE 7)", allowed, reason))


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def load_file(root: Path, path: Path, findings: list) -> FileLint:
    rel = path.relative_to(root).as_posix()
    src = path.read_text()
    masked = mask_source(src)
    line_starts = [0]
    for idx, ch in enumerate(src):
        if ch == "\n":
            line_starts.append(idx + 1)
    fl = FileLint(
        rel=rel, src=src, masked=masked, line_starts=line_starts,
        lines=src.split("\n"), masked_lines=masked.split("\n"),
        anns=[], site_allows={}, fns=[], test_ranges=[],
    )
    fl.anns = parse_annotations(fl.lines, fl.masked_lines, findings, rel)
    parse_fns(fl)
    parse_test_ranges(fl)
    attach_annotations(fl, findings)
    return fl


def lint_root(src_root: Path, coverage_path: Path | None = None) -> dict:
    src_root = Path(src_root)
    if coverage_path is None:
        cand = src_root.parent / "tests" / "alloc_free.rs"
        coverage_path = cand if cand.exists() else None
    coverage_text = Path(coverage_path).read_text() if coverage_path else None

    findings: list[Finding] = []
    files: dict[str, FileLint] = {}
    for path in sorted(src_root.rglob("*.rs")):
        fl = load_file(src_root, path, findings)
        files[fl.rel] = fl

    run_alloc_rule(files, coverage_text, findings)
    for fl in files.values():
        if in_panic_scope(fl.rel):
            run_panic_rule(fl, findings)
            run_index_rule(fl, findings)
        if in_hazard_scope(fl.rel):
            run_hazard_rule(fl, findings)

    uniq = {}
    for f in findings:
        uniq.setdefault(f.key(), f)
    ordered = sorted(uniq.values(), key=lambda f: (f.file, f.line, f.rule, f.message))
    denied = sum(1 for f in ordered if not f.allowed)
    return {
        "summary": {
            "files": len(files),
            "findings": len(ordered),
            "denied": denied,
            "allowed": len(ordered) - denied,
        },
        "findings": [
            {
                "rule": f.rule,
                "file": f.file,
                "line": f.line,
                "message": f.message,
                "allowed": f.allowed,
                "reason": f.reason,
            }
            for f in ordered
        ],
    }


def render_json(report: dict) -> str:
    return json.dumps(report, indent=2)


def render_human(report: dict) -> str:
    out = []
    for f in report["findings"]:
        mark = "allow" if f["allowed"] else "DENY "
        out.append(f"{mark} {f['file']}:{f['line']}: [{f['rule']}] {f['message']}")
        if f["allowed"] and f["reason"]:
            out.append(f"      = reason: {f['reason']}")
    s = report["summary"]
    out.append(
        f"{s['findings']} findings across {s['files']} files: "
        f"{s['denied']} denied, {s['allowed']} allowed"
    )
    return "\n".join(out)


def main(argv: list) -> int:
    root = Path(argv[1]) if len(argv) > 1 else Path("../rust/src")
    fmt = argv[2] if len(argv) > 2 else "human"
    report = lint_root(root)
    print(render_json(report) if fmt == "json" else render_human(report))
    return 1 if report["summary"]["denied"] else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
