"""Shared strategies and helpers for the APFP python test-suite.

Hypothesis generates exact ``PyApfp`` values (the integer oracle); tests
push batches of them through the JAX model and require *bit equality* —
the same acceptance criterion the paper uses against MPFR.
"""

from __future__ import annotations

import random

try:
    from hypothesis import strategies as st
except ModuleNotFoundError:  # minimal container: property tests skip below
    st = None

try:
    from compile import config
    from compile.kernels import ref
except ModuleNotFoundError:  # no jax: only the pure-python suite runs
    config = None
    ref = None

# Without hypothesis the property-based modules cannot even import; keep
# the rest of the suite (vector replay, lint engine, pack layout)
# runnable.  Without jax the whole compile layer is out of reach and
# only the self-contained modules (the lint engine, the stream-protocol
# model) remain — that pair is exactly what the CI `analysis` job runs.
collect_ignore = []
if st is None or config is None:
    collect_ignore += [
        "test_addsub_prims.py",
        "test_carry.py",
        "test_karatsuba.py",
        "test_model.py",
        "test_ref_oracle.py",
    ]
if config is None:
    collect_ignore += [
        "test_aot.py",
        "test_gemm.py",
        "test_pack.py",
    ]


def mantissa_strategy(prec: int):
    """Normalized prec-bit mantissas, biased toward the adversarial corners
    (minimum 2^(p-1), maximum 2^p - 1, sparse and dense bit patterns)."""
    lo = 1 << (prec - 1)
    hi = (1 << prec) - 1
    return st.one_of(
        st.just(lo),
        st.just(hi),
        st.just(lo + 1),
        st.just(hi - 1),
        st.integers(min_value=lo, max_value=hi),
        # sparse patterns: MSB plus a few scattered bits
        st.lists(st.integers(0, prec - 2), min_size=0, max_size=4).map(
            lambda bits: lo | sum(1 << b for b in set(bits))
        ),
    )


def apfp_strategy(bits: int, exp_range: int = 600):
    prec = config.PRECISIONS[bits]
    nonzero = st.builds(
        lambda s, e, m: ref.PyApfp(s, e, m, prec),
        st.integers(0, 1),
        st.integers(-exp_range, exp_range),
        mantissa_strategy(prec),
    )
    return st.one_of(nonzero, st.just(ref.PyApfp.zero(prec)))


def random_apfp(rng: random.Random, bits: int, exp_range: int = 300) -> ref.PyApfp:
    prec = config.PRECISIONS[bits]
    m = rng.getrandbits(prec) | (1 << (prec - 1))
    return ref.PyApfp(rng.randint(0, 1), rng.randint(-exp_range, exp_range), m, prec)
