"""Adder-pipeline primitives (§II-B): barrel shift, sticky, LZC, compare."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import addsub, ref

N = 16  # limbs per test vector (128 bits)


def _shift_ref(v: int, s: int, n_limbs: int) -> int:
    """result bit k = source bit k + s, window [0, 8*n_limbs)."""
    if s >= 0:
        v >>= s
    else:
        v <<= -s
    return v % (1 << (8 * n_limbs))


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** (8 * N) - 1), st.integers(-8 * N - 9, 8 * N + 9))
def test_shift_right_bits(v, s):
    x = np.array([ref.int_to_limbs(v, N)], np.int32)
    got = np.asarray(addsub.shift_right_bits(x, np.array([s], np.int64)))[0]
    assert ref.limbs_to_int(got) == _shift_ref(v, s, N)


def test_shift_zero_is_identity():
    rng = np.random.RandomState(5)
    x = rng.randint(0, 256, (3, N)).astype(np.int32)
    got = np.asarray(addsub.shift_right_bits(x, np.zeros(3, np.int64)))
    np.testing.assert_array_equal(got, x)


def test_shift_batched_mixed_signs():
    v = (1 << 100) | 0xABCD
    x = np.array([ref.int_to_limbs(v, N)] * 4, np.int32)
    s = np.array([-8, -1, 1, 37], np.int64)
    got = np.asarray(addsub.shift_right_bits(x, s))
    for i, si in enumerate(s):
        assert ref.limbs_to_int(got[i]) == _shift_ref(v, int(si), N)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** (8 * N) - 1), st.integers(0, 8 * N + 16))
def test_sticky_below(v, s):
    x = np.array([ref.int_to_limbs(v, N)], np.int32)
    got = bool(np.asarray(addsub.sticky_below(x, np.array([s], np.int64)))[0])
    want = (v % (1 << min(s, 8 * N))) != 0 if s > 0 else False
    assert got == want


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** (8 * N) - 1))
def test_bit_length(v):
    x = np.array([ref.int_to_limbs(v, N)], np.int32)
    got = int(np.asarray(addsub.bit_length(x))[0])
    assert got == v.bit_length()


def test_bit_length_edges():
    for v in [0, 1, 255, 256, (1 << (8 * N)) - 1, 1 << (8 * N - 1)]:
        x = np.array([ref.int_to_limbs(v, N)], np.int32)
        assert int(np.asarray(addsub.bit_length(x))[0]) == v.bit_length()


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**64 - 1), st.integers(0, 2**64 - 1))
def test_compare_mag(a, b):
    la = np.array([ref.int_to_limbs(a, N)], np.int32)
    lb = np.array([ref.int_to_limbs(b, N)], np.int32)
    got = int(np.asarray(addsub.compare_mag(la, lb))[0])
    want = (a > b) - (a < b)
    assert got == want


def test_compare_equal():
    x = np.array([ref.int_to_limbs(123456789, N)], np.int32)
    assert int(np.asarray(addsub.compare_mag(x, x))[0]) == 0
