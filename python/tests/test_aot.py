"""AOT path: lowering produces parseable HLO text + a well-formed manifest."""

import jax
import jax.numpy as jnp

from compile import aot, config, model


def test_to_hlo_text_smoke():
    b = 4
    l = config.mant_limbs(512)
    spec = (
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int64),
        jax.ShapeDtypeStruct((b, l), jnp.int32),
    )
    lowered = jax.jit(model.mul_stream_flat).lower(*spec, *spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # outputs must be a tuple of the three planes (runtime convention)
    assert "ROOT" in text


def test_variant_inventory():
    """The manifest must cover every operator/precision the runtime needs."""
    names = set()
    for name, kind, bits, batch, t_n, t_m, k_tile, _lowered in aot.build_variants():
        names.add(name)
        assert kind in ("mul", "add", "mac", "gemm")
        assert bits in config.ARTIFACT_BITS
        if kind == "gemm":
            assert t_n > 0 and t_m > 0 and k_tile > 0
        else:
            assert batch == config.STREAM_BATCH
        break  # lowering everything takes ~10 s; the full set is exercised by `make artifacts`
    assert "mul_512" in names


def test_tpu_report_quantities():
    from compile.kernels import karatsuba

    r = karatsuba.vmem_report(512, 8, config.STREAM_BATCH)
    # VMEM block must fit a real TPU core's ~16 MiB VMEM comfortably
    assert r["vmem_bytes_per_block"] < 16 * 2**20
