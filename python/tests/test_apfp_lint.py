"""Fixture-driven tests for the apfp-lint rule engine.

The fixtures live with the Rust implementation
(``rust/xtask/tests/fixtures``) and are shared verbatim by both engines:
each fixture directory holds a miniature ``src/`` tree (plus an optional
``tests/alloc_free.rs`` coverage witness) and an ``expected.txt`` listing
every finding the engine must produce, one tab-separated
``rule<TAB>path<TAB>line<TAB>status`` row per finding.  A fixture with an
empty ``expected.txt`` must lint clean.  The ``*_bad`` fixtures are the
proof that each rule actually fires; ``clean`` and ``alloc_allow`` prove
the escapes don't over-fire.
"""

import json
from pathlib import Path

import pytest

import apfp_lint

FIXTURES = Path(__file__).resolve().parents[2] / "rust" / "xtask" / "tests" / "fixtures"
RUST_SRC = Path(__file__).resolve().parents[2] / "rust" / "src"

FIXTURE_NAMES = sorted(p.name for p in FIXTURES.iterdir() if p.is_dir())


def findings_as_rows(report):
    return sorted(
        (f["rule"], f["file"], f["line"], "allowed" if f["allowed"] else "denied")
        for f in report["findings"]
    )


def expected_rows(fixture: Path):
    rows = []
    for line in (fixture / "expected.txt").read_text().splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        rule, path, lineno, status = line.split("\t")
        rows.append((rule, path, int(lineno), status))
    return sorted(rows)


@pytest.mark.parametrize("name", FIXTURE_NAMES)
def test_fixture(name):
    fixture = FIXTURES / name
    report = apfp_lint.lint_root(fixture / "src")
    assert findings_as_rows(report) == expected_rows(fixture)


def test_fixture_set_exercises_every_rule():
    # Every rule the engine knows must be proven to fire by some fixture.
    fired = set()
    for name in FIXTURE_NAMES:
        for rule, _, _, status in expected_rows(FIXTURES / name):
            if status == "denied":
                fired.add(rule)
    assert fired == set(apfp_lint.KNOWN_RULES) | {apfp_lint.RULE_ANNOTATION}


def test_rust_src_is_clean():
    # The enforcement test: the real tree must carry zero denied findings.
    # (The Rust xtask runs the same check in CI; this keeps the Python port
    # honest against the live sources.)
    report = apfp_lint.lint_root(RUST_SRC)
    denied = [f for f in report["findings"] if not f["allowed"]]
    assert denied == [], apfp_lint.render_human(report)
    # every allowed finding must carry a non-empty reason
    for f in report["findings"]:
        assert f["reason"] and f["reason"].strip()


def test_json_rendering_round_trips():
    report = apfp_lint.lint_root(FIXTURES / "panic_bad" / "src")
    parsed = json.loads(apfp_lint.render_json(report))
    assert parsed["summary"]["denied"] == 5  # runtime/mod.rs x3 + runtime/sim_backend.rs x2
    assert len(parsed["findings"]) == parsed["summary"]["findings"]


def test_mask_source_blanks_strings_and_comments():
    src = 'let s = "vec![in string]"; // vec![in comment]\nlet v = vec![1];\n'
    masked = apfp_lint.mask_source(src)
    assert masked.count("\n") == src.count("\n")
    assert "vec![in string]" not in masked
    assert "vec![in comment]" not in masked
    assert "vec![1]" in masked


def test_cfg_test_code_is_exempt():
    report = apfp_lint.lint_root(FIXTURES / "panic_bad" / "src")
    assert all(f["line"] < 11 for f in report["findings"])
