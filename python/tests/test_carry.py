"""Carry/borrow canonicalization: chunked pipeline vs full ripple vs exact."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import carry, ref


@pytest.mark.parametrize("chunk", [None, 2, 4, 8, 16, 64])
def test_chunked_equals_exact(chunk):
    rng = np.random.RandomState(chunk or 0)
    x = rng.randint(0, 2**24, (5, 30)).astype(np.int64)
    got = np.asarray(carry.propagate_carries(x, chunk_limbs=chunk))
    # Workspace invariant: the value must fit the limb count after
    # canonicalization; size the reference accordingly and compare prefix.
    want = np.asarray(ref.carry_ref(x, 34))
    for i in range(x.shape[0]):
        v_got = ref.limbs_to_int(got[i])
        v_want = ref.limbs_to_int(want[i])
        assert v_got == v_want % (1 << (8 * 30))


def test_already_canonical_is_identity():
    rng = np.random.RandomState(9)
    x = rng.randint(0, 256, (4, 16)).astype(np.int64)
    got = np.asarray(carry.propagate_carries(x, chunk_limbs=4))
    np.testing.assert_array_equal(got, x.astype(np.int32))


def test_full_ripple_chain():
    """A carry injected below a run of 0xFF limbs must ripple end to end —
    the case that breaks naive fixed-sweep schemes."""
    x = np.full((1, 20), 255, np.int64)
    x[0, 0] = 256  # forces +1 into limb 1, rippling through all the 0xFFs
    x[0, 19] = 0  # leave headroom so the ripple stays inside the workspace
    got = np.asarray(carry.propagate_carries(x, chunk_limbs=4))[0]
    want = ref.int_to_limbs(ref.limbs_to_int(x[0]), 20)
    assert list(got) == want


@settings(max_examples=25, deadline=None)
@given(
    st.lists(st.integers(0, 2**30 - 1), min_size=24, max_size=24),
    st.sampled_from([None, 3, 8]),
)
def test_hypothesis_redundant(limbs, chunk):
    x = np.array([limbs], np.int64)
    got = np.asarray(carry.propagate_carries(x, chunk_limbs=chunk))[0]
    total = ref.limbs_to_int(x[0])
    assert ref.limbs_to_int(got) == total % (1 << (8 * 24))


def test_borrows():
    rng = np.random.RandomState(11)
    for _ in range(10):
        a = rng.randint(0, 2**60)
        b = rng.randint(0, a + 1)
        la = np.array([ref.int_to_limbs(a, 12)], np.int64)
        lb = np.array([ref.int_to_limbs(b, 12)], np.int64)
        got = np.asarray(carry.propagate_borrows(la - lb))[0]
        assert ref.limbs_to_int(got) == a - b


def test_borrow_ripple():
    # 2^64 - 1 as 0x1_0000_0000_0000_0000 - 1: borrows ripple the whole way
    a = np.zeros((1, 10), np.int64)
    a[0, 8] = 1
    b = np.zeros((1, 10), np.int64)
    b[0, 0] = 1
    got = np.asarray(carry.propagate_borrows(a - b))[0]
    assert ref.limbs_to_int(got) == (1 << 64) - 1
