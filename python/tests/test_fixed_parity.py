"""Fixed-width fast-path parity, mirrored from ``rust/tests/fixed_parity.rs``.

Self-contained (stdlib only, always collected): a line-mirror of the Rust
const-generic kernels — the Comba ``(lo, hi)`` split product, the
``mul_into`` renormalization, and the ``Guarded`` ``[1 guard | L | 1
overflow]`` adder pipeline with its ``64 * (L + 2)`` clamp and
sticky-before-shift discipline — replayed over the *same* xorshift64*
operand streams as the Rust suite (same seeds, same draw order) and
checked against an exact-integer RNDZ reference.  The Rust suite pins
fixed == dynamic; this one independently pins fixed == exact math, so the
two cannot drift together.

Covers zeros, deeply negative exponents, and carry-chain boundary
mantissas (all-ones, MSB-only) at the paper's 448-bit (7-limb) and
960-bit (15-limb) widths.
"""

from __future__ import annotations

import dataclasses

MASK64 = (1 << 64) - 1
MASK128 = (1 << 128) - 1
ZERO_EXP = -(1 << 61)

# Compiled crossover mirrored from rust/src/bigint/mod.rs
# KARATSUBA_THRESHOLD; the fixed path splits only for even widths at or
# above it, so both paper widths (7, 15) bottom out in Comba.
KARATSUBA_THRESHOLD = 40


def fixed_uses_karatsuba(limbs: int) -> bool:
    return limbs >= KARATSUBA_THRESHOLD and limbs % 2 == 0


# --------------------------------------------------------------------------
# xorshift64* — exact port of rust/src/testkit/mod.rs
# --------------------------------------------------------------------------


class Rng:
    def __init__(self, seed: int):
        self.state = max((seed * 2685821657736338717) & MASK64, 1)

    def next_u64(self) -> int:
        x = self.state
        x ^= x >> 12
        x = (x ^ (x << 25)) & MASK64
        x ^= x >> 27
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & MASK64

    def below(self, n: int) -> int:
        return (self.next_u64() * n) >> 64

    def range_i64(self, lo: int, hi: int) -> int:
        return lo + self.below(hi - lo + 1)

    def bool(self) -> bool:
        return self.next_u64() & 1 == 1

    def limbs(self, n: int) -> list[int]:
        return [self.next_u64() for _ in range(n)]


def rand_ap(rng: Rng, prec: int, exp_range: int):
    """Mirror of testkit::rand_ap — returns (sign, exp, mant_limbs)."""
    n = prec // 64
    mant = rng.limbs(n)
    mant[n - 1] |= 1 << 63
    sign = rng.bool()
    exp = rng.range_i64(-exp_range, exp_range)
    return sign, exp, mant


# --------------------------------------------------------------------------
# The fixed-width value and kernel mirrors
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Ap:
    """Mirror of ApFloatN<L>: value = (-1)^sign * M * 2^(exp - 64*L)."""

    sign: bool
    exp: int
    mant: list[int]  # L little-endian 64-bit limbs

    def is_zero(self) -> bool:
        return all(m == 0 for m in self.mant)

    def key(self):
        return self.sign, self.exp, tuple(self.mant)


def zero(L: int) -> Ap:
    return Ap(False, ZERO_EXP, [0] * L)


def limbs_to_int(limbs) -> int:
    v = 0
    for i, m in enumerate(limbs):
        v |= m << (64 * i)
    return v


def int_to_limbs(v: int, n: int) -> list[int]:
    return [(v >> (64 * i)) & MASK64 for i in range(n)]


def cmp_mag(x: Ap, y: Ap) -> int:
    if x.exp != y.exp:
        return -1 if x.exp < y.exp else 1
    a, b = limbs_to_int(x.mant), limbs_to_int(y.mant)
    return (a > b) - (a < b)


def widening_mul(a: int, b: int):
    t = a * b
    return t & MASK64, t >> 64


def mul_comba_fixed(a: list[int], b: list[int], L: int):
    """Line-mirror of bigint::fixed::mul_comba_fixed: 128-bit accumulator,
    per-column overflow counter, columns 0..L in lo, L..2L-1 in hi, final
    carry in hi[L-1]."""
    lo, hi = [0] * L, [0] * L
    if L == 0:
        return lo, hi
    acc = 0  # low 128 bits of the running column sum
    over = 0  # count of 2^128 overflows within one column
    for k in range(L):
        for i in range(k + 1):
            plo, phi = widening_mul(a[i], b[k - i])
            t = acc + ((phi << 64) | plo)
            over += t >> 128
            acc = t & MASK128
        lo[k] = acc & MASK64
        acc = (acc >> 64) | (over << 64)
        over = 0
    for k in range(L, 2 * L - 1):
        for i in range(k - (L - 1), L):
            plo, phi = widening_mul(a[i], b[k - i])
            t = acc + ((phi << 64) | plo)
            over += t >> 128
            acc = t & MASK128
        hi[k - L] = acc & MASK64
        acc = (acc >> 64) | (over << 64)
        over = 0
    hi[L - 1] = acc & MASK64
    assert acc >> 64 == 0, "comba column carry must be consumed"
    return lo, hi


def mul_fixed_ap(x: Ap, y: Ap, L: int) -> Ap:
    """Mirror of ApFloatN::mul_into (RNDZ): nbits is 2p or 2p-1, so the
    renormalizing shift is the high half or the high half pulled up one."""
    if x.is_zero() or y.is_zero():
        return zero(L)
    assert not fixed_uses_karatsuba(L), "paper widths bottom out in Comba"
    lo, hi = mul_comba_fixed(x.mant, y.mant, L)
    out = zero(L)
    if hi[L - 1] >> 63:
        out.mant = list(hi)
        out.exp = x.exp + y.exp
    else:
        carry = lo[L - 1] >> 63
        for i in range(L):
            nxt = hi[i] >> 63
            out.mant[i] = ((hi[i] << 1) & MASK64) | carry
            carry = nxt
        out.exp = x.exp + y.exp - 1
    assert out.mant[L - 1] >> 63 == 1, "product renormalizes"
    out.sign = x.sign != y.sign
    return out


def add_core_fixed(x: Ap, y: Ap, flip_y: bool, L: int) -> Ap:
    """Mirror of softfloat::fixed::add_core_fixed on the Guarded
    [1 guard | L | 1 overflow] workspace, expressed on the joined integer
    (bit i of the integer == bit i of the virtual (L+2)-limb vector)."""
    y_sign = y.sign != flip_y
    if y.is_zero():
        return Ap(x.sign, x.exp, list(x.mant))
    if x.is_zero():
        return Ap(y_sign, y.exp, list(y.mant))

    # stage 1: order by magnitude
    swap = cmp_mag(x, y) < 0
    big_sign, big_exp = (y_sign, y.exp) if swap else (x.sign, x.exp)
    small_exp = x.exp if swap else y.exp
    same_sign = x.sign == y_sign

    # stage 2: alignment — big's MSB at bit 64 + p - 1, sticky read before
    # the shift consumes the pre-shift bits, distance clamped to the
    # workspace width 64 * (L + 2)
    p = 64 * L
    big_mant, small_mant = (y.mant, x.mant) if swap else (x.mant, y.mant)
    v = limbs_to_int(big_mant) << 64
    small = limbs_to_int(small_mant) << 64
    d = min(big_exp - small_exp, 64 * (L + 2))
    sticky = small & ((1 << d) - 1) != 0
    small >>= d

    # stage 3: wide add / subtract with the RNDZ sticky correction
    if same_sign:
        v += small
        assert v < 1 << (64 * (L + 2)), "overflow limb absorbs the carry"
    else:
        v -= small
        assert v >= 0, "|big| >= |small| by stage 1"
        if sticky:
            v -= 1
            assert v >= 0

    # stages 4+5: renormalize + truncate
    nbits = v.bit_length()
    if nbits == 0:
        return zero(L)
    if nbits >= p:
        m = (v >> (nbits - p)) & ((1 << p) - 1)
    else:
        m = (v << (p - nbits)) & ((1 << p) - 1)
    return Ap(big_sign, big_exp + (nbits - (64 + p)), int_to_limbs(m, L))


def mac_fixed_ap(acc: Ap, a: Ap, b: Ap, L: int) -> Ap:
    """Mirror of mac_into: product rounded to width, then accumulated."""
    return add_core_fixed(acc, mul_fixed_ap(a, b, L), False, L)


# --------------------------------------------------------------------------
# Exact-integer RNDZ reference (independent of the limb kernels)
# --------------------------------------------------------------------------


def ref_round(num: int, scale: int, p: int) -> Ap:
    """RNDZ-normalize the exact value num * 2^scale to p bits."""
    L = p // 64
    if num == 0:
        return zero(L)
    n = abs(num)
    nbits = n.bit_length()
    m = n >> (nbits - p) if nbits >= p else n << (p - nbits)
    return Ap(num < 0, scale + nbits, int_to_limbs(m, L))


def ref_signed(x: Ap, p: int):
    """Exact (num, scale) with value = num * 2^scale."""
    m = limbs_to_int(x.mant)
    return (-m if x.sign else m), x.exp - p


def ref_mul(x: Ap, y: Ap, p: int) -> Ap:
    nx, sx = ref_signed(x, p)
    ny, sy = ref_signed(y, p)
    return ref_round(nx * ny, sx + sy, p)


def ref_add(x: Ap, y: Ap, p: int, flip_y: bool = False) -> Ap:
    # mirror the adder's zero short-circuits so zero signs stay canonical
    if y.is_zero():
        return Ap(x.sign, x.exp, list(x.mant))
    if x.is_zero():
        return Ap(y.sign != flip_y, y.exp, list(y.mant))
    nx, sx = ref_signed(x, p)
    ny, sy = ref_signed(y, p)
    if flip_y:
        ny = -ny
    s = min(sx, sy)
    return ref_round((nx << (sx - s)) + (ny << (sy - s)), s, p)


def ref_mac(acc: Ap, a: Ap, b: Ap, p: int) -> Ap:
    return ref_add(acc, ref_mul(a, b, p), p)


# --------------------------------------------------------------------------
# Operand stream — mirror of operand() in rust/tests/fixed_parity.rs
# --------------------------------------------------------------------------


def from_ap(v, L: int) -> Ap:
    sign, exp, mant = v
    assert len(mant) == L, "width mismatch: ApFloat prec vs LIMBS"
    return Ap(sign, exp, list(mant))


def operand(rng: Rng, L: int, prec: int) -> Ap:
    sel = rng.below(16)
    if sel == 0:
        return zero(L)
    if sel in (1, 2):
        if rng.bool():
            mant = [MASK64] * L
        else:
            mant = [0] * L
            mant[L - 1] = 1 << 63
        return Ap(rng.bool(), rng.range_i64(-300, 300), mant)
    if sel in (3, 4):
        f = from_ap(rand_ap(rng, prec, 4), L)
        if f.is_zero():
            return f
        return Ap(f.sign, rng.range_i64(-2000, -500), f.mant)
    return from_ap(rand_ap(rng, prec, 300), L)


# --------------------------------------------------------------------------
# The parity properties (same seeds and case counts as the Rust suite)
# --------------------------------------------------------------------------

WIDTHS = [(7, 448), (15, 960)]
SCALAR_SEEDS = {448: 0xF1A8_0448, 960: 0xF1A8_0960}
CHAIN_SEEDS = {448: 0xC4A1_0448, 960: 0xC4A1_0960}


def test_comba_split_product_matches_integer_multiply():
    rng = Rng(0xC0B1A)
    for L, _ in WIDTHS:
        for _ in range(200):
            a, b = rng.limbs(L), rng.limbs(L)
            lo, hi = mul_comba_fixed(a, b, L)
            got = limbs_to_int(lo) | (limbs_to_int(hi) << (64 * L))
            assert got == limbs_to_int(a) * limbs_to_int(b), f"comba at L={L}"


def test_scalar_ops_match_exact_reference():
    for L, prec in WIDTHS:
        rng = Rng(SCALAR_SEEDS[prec])
        for case in range(2000):
            a = operand(rng, L, prec)
            b = operand(rng, L, prec)
            acc = operand(rng, L, prec)
            ctx = f"case {case} at prec {prec}"
            assert mul_fixed_ap(a, b, L).key() == ref_mul(a, b, prec).key(), f"mul {ctx}"
            assert (
                add_core_fixed(a, b, False, L).key() == ref_add(a, b, prec).key()
            ), f"add {ctx}"
            assert (
                add_core_fixed(a, b, True, L).key()
                == ref_add(a, b, prec, flip_y=True).key()
            ), f"sub {ctx}"
            assert (
                mac_fixed_ap(acc, a, b, L).key() == ref_mac(acc, a, b, prec).key()
            ), f"mac {ctx}"


def test_mac_chain_matches_exact_reference():
    for L, prec in WIDTHS:
        rng = Rng(CHAIN_SEEDS[prec])
        accf = zero(L)
        accr = zero(L)
        for step in range(512):
            a = operand(rng, L, prec)
            b = operand(rng, L, prec)
            accf = mac_fixed_ap(accf, a, b, L)
            accr = ref_mac(accr, a, b, prec)
            assert accf.key() == accr.key(), f"mac chain step {step} at prec {prec}"


def test_gemm_inner_loop_order_matches_reference():
    """The gemm_fixed accumulation order (ascending k per output element)
    replayed on the mirror must equal the reference mac chain in the same
    order — rounding is order-sensitive, so this pins the loop shape too."""
    n, k, m = 3, 4, 3
    for L, prec in WIDTHS:
        rng = Rng(0x6E11 ^ prec)
        a = [[operand(rng, L, prec) for _ in range(k)] for _ in range(n)]
        b = [[operand(rng, L, prec) for _ in range(m)] for _ in range(k)]
        c = [[operand(rng, L, prec) for _ in range(m)] for _ in range(n)]
        for i in range(n):
            for j in range(m):
                got = Ap(c[i][j].sign, c[i][j].exp, list(c[i][j].mant))
                want = Ap(c[i][j].sign, c[i][j].exp, list(c[i][j].mant))
                for kk in range(k):
                    got = mac_fixed_ap(got, a[i][kk], b[kk][j], L)
                    want = ref_mac(want, a[i][kk], b[kk][j], prec)
                assert got.key() == want.key(), f"gemm ({i},{j}) at prec {prec}"


def test_carry_chain_boundaries_explicitly():
    """Directed corners: all-ones x all-ones (full carry ripple), MSB-only
    squares, cancellation to exact zero, and the d-clamp path where the
    small operand is entirely sticky."""
    for L, prec in WIDTHS:
        ones = Ap(False, 0, [MASK64] * L)
        msb = Ap(False, 0, [0] * (L - 1) + [1 << 63])
        assert mul_fixed_ap(ones, ones, L).key() == ref_mul(ones, ones, prec).key()
        assert mul_fixed_ap(msb, msb, L).key() == ref_mul(msb, msb, prec).key()
        assert mul_fixed_ap(ones, msb, L).key() == ref_mul(ones, msb, prec).key()
        # exact cancellation -> canonical +0
        neg = Ap(True, ones.exp, list(ones.mant))
        assert add_core_fixed(ones, neg, False, L).key() == zero(L).key()
        assert add_core_fixed(ones, ones, True, L).key() == zero(L).key()
        # far operand: beyond the 64*(L+2) clamp everything is sticky
        far = Ap(True, -(64 * (L + 3)), list(ones.mant))
        assert (
            add_core_fixed(ones, far, False, L).key()
            == ref_add(ones, far, prec).key()
        )
        # zero operands keep canonical zero through every op
        z = zero(L)
        assert mul_fixed_ap(ones, z, L).key() == z.key()
        assert add_core_fixed(z, ones, False, L).key() == ones.key()
        assert mac_fixed_ap(ones, z, msb, L).key() == ones.key()
