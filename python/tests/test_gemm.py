"""gemm_tile: the compute-unit datapath vs the sequential oracle GEMM."""

import random

import pytest

from compile import apfp_types, model
from compile.kernels import ref

from conftest import random_apfp


def rand_mat(rng, rows, cols, bits, exp_range=40):
    return [
        [random_apfp(rng, bits, exp_range) for _ in range(cols)] for _ in range(rows)
    ]


@pytest.mark.parametrize("bits,tn,tm,k", [(512, 4, 4, 4), (512, 3, 5, 7), (1024, 2, 2, 3)])
def test_gemm_tile_bit_exact(bits, tn, tm, k):
    rng = random.Random(1000 + tn * 100 + k + bits)
    a = rand_mat(rng, tn, k, bits)
    b = rand_mat(rng, k, tm, bits)
    c = rand_mat(rng, tn, tm, bits)
    got = apfp_types.to_py(
        model.gemm_tile(
            apfp_types.from_py(a, bits),
            apfp_types.from_py(b, bits),
            apfp_types.from_py(c, bits),
        ),
        bits,
    )
    want = ref.gemm_ref(a, b, c)
    for i in range(tn):
        for j in range(tm):
            assert got[i][j] == want[i][j], (i, j)


def test_gemm_tile_zero_c_and_cancellation():
    bits = 512
    rng = random.Random(77)
    tn = tm = k = 3
    a = rand_mat(rng, tn, k, bits)
    # b column built so some products cancel against C
    b = rand_mat(rng, k, tm, bits)
    zero = ref.PyApfp.zero(a[0][0].prec)
    c = [[zero for _ in range(tm)] for _ in range(tn)]
    got = apfp_types.to_py(
        model.gemm_tile(
            apfp_types.from_py(a, bits),
            apfp_types.from_py(b, bits),
            apfp_types.from_py(c, bits),
        ),
        bits,
    )
    want = ref.gemm_ref(a, b, c)
    for i in range(tn):
        for j in range(tm):
            assert got[i][j] == want[i][j], (i, j)


def test_gemm_accumulation_order_matters_and_matches():
    """APFP addition is not associative under rounding; the artifact and the
    oracle must use the same (sequential-K) order.  Build a case where a
    different order would give a different answer, and check we match the
    specified order."""
    bits = 512
    prec = 448
    big = ref.PyApfp.from_float(1.0, prec)
    tiny = ref.PyApfp(0, big.exp - 600, (1 << (prec - 1)) | 1, prec)
    a = [[big, tiny, big]]
    b = [[big], [big], [big.neg()]]
    c = [[ref.PyApfp.zero(prec)]]
    got = apfp_types.to_py(
        model.gemm_tile(
            apfp_types.from_py(a, bits),
            apfp_types.from_py(b, bits),
            apfp_types.from_py(c, bits),
        ),
        bits,
    )
    want = ref.gemm_ref(a, b, c)
    assert got[0][0] == want[0][0]
