"""L1 kernel correctness: Pallas Karatsuba multiplier vs exact integers.

This is the core correctness signal for the multiplier (§II-A): the kernel's
canonicalized output must equal the exact product of the operand mantissas,
for every precision and every bottom-out threshold configuration.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import config
from compile.kernels import carry, karatsuba, ref


def exact_product_check(a, b, base_limbs):
    red = karatsuba.mult_mantissa(a, b, base_limbs=base_limbs)
    canon = np.asarray(carry.propagate_carries(red))
    for i in range(a.shape[0]):
        got = ref.limbs_to_int(canon[i])
        want = ref.limbs_to_int(a[i]) * ref.limbs_to_int(b[i])
        assert got == want, f"row {i}: got {got:#x}, want {want:#x}"


@pytest.mark.parametrize("bits", [512, 1024])
@pytest.mark.parametrize("base_limbs", [4, 8, 16])
def test_random_mantissas(bits, base_limbs):
    l = config.mant_limbs(bits)
    rng = np.random.RandomState(42 + bits + base_limbs)
    a = rng.randint(0, 256, (8, l)).astype(np.int32)
    b = rng.randint(0, 256, (8, l)).astype(np.int32)
    exact_product_check(a, b, base_limbs)


@pytest.mark.parametrize("bits", [512, 1024])
def test_extreme_mantissas(bits):
    """Worst-case carry-save headroom: all limbs at 255 (the bound in the
    module docstring of kernels/karatsuba.py is tight here)."""
    l = config.mant_limbs(bits)
    ones = np.full((1, l), 255, np.int32)
    zeros = np.zeros((1, l), np.int32)
    one = np.zeros((1, l), np.int32)
    one[0, 0] = 1
    top = np.zeros((1, l), np.int32)
    top[0, -1] = 255
    for a in (ones, zeros, one, top):
        for b in (ones, zeros, one, top):
            exact_product_check(a, b, config.DEFAULT_BASE_LIMBS)


def test_base_conv_matches_ref():
    rng = np.random.RandomState(3)
    a = rng.randint(0, 256, (4, 8)).astype(np.int32)
    b = rng.randint(0, 256, (4, 8)).astype(np.int32)
    got = np.asarray(karatsuba.base_conv(a, b))
    want = np.asarray(ref.conv_ref(a, b))
    np.testing.assert_array_equal(got, want)


def test_karatsuba_equals_schoolbook_conv():
    """The recursion must compute the *same redundant polynomial* as the
    schoolbook partial-product array once carries are resolved."""
    rng = np.random.RandomState(4)
    a = rng.randint(0, 256, (4, 32)).astype(np.int32)
    b = rng.randint(0, 256, (4, 32)).astype(np.int32)
    got = carry.propagate_carries(
        np.pad(np.asarray(karatsuba.karatsuba(a, b, 8), np.int64), ((0, 0), (0, 1)))
    )
    want = carry.propagate_carries(
        np.pad(np.asarray(ref.conv_ref(a, b)), ((0, 0), (0, 1)))
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    data=st.lists(
        st.tuples(st.integers(0, 2**448 - 1), st.integers(0, 2**448 - 1)),
        min_size=4,
        max_size=4,
    )
)
def test_hypothesis_512(data):
    l = config.mant_limbs(512)
    a = np.array([ref.int_to_limbs(x, l) for x, _ in data], np.int32)
    b = np.array([ref.int_to_limbs(y, l) for _, y in data], np.int32)
    exact_product_check(a, b, config.DEFAULT_BASE_LIMBS)


def test_plan_depth_headroom():
    assert karatsuba.plan_depth(56, 8) == 3  # 64 -> 32 -> 16 -> 8
    assert karatsuba.plan_depth(120, 8) == 4  # 128 -> ... -> 8
    assert karatsuba.plan_depth(56, 16) == 2
    with pytest.raises(AssertionError):
        # 2^14 limbs at base 4 would blow the int32 headroom bound
        karatsuba.plan_depth(1 << 14, 4)


def test_vmem_report():
    r = karatsuba.vmem_report(512, 8, 64)
    assert r["depth"] == 3
    assert r["leaf_convs"] == 27
    assert r["macs_per_mult"] == 27 * 8 * 8
    # Karatsuba must beat schoolbook on MAC count at this size
    assert r["mac_ratio"] < 0.5
    r1024 = karatsuba.vmem_report(1024, 8, 64)
    assert r1024["mac_ratio"] < r["mac_ratio"]  # asymptotic advantage grows


@pytest.mark.parametrize("batch", [1, 2, 5, 7])
@pytest.mark.parametrize("bits", [512, 1024])
def test_shape_sweep(batch, bits):
    """The kernel must be exact for any batch size (incl. odd/1) and both
    precisions — the shapes the runtime feeds it under padding."""
    l = config.mant_limbs(bits)
    rng = np.random.RandomState(batch * 1000 + bits)
    a = rng.randint(0, 256, (batch, l)).astype(np.int32)
    b = rng.randint(0, 256, (batch, l)).astype(np.int32)
    exact_product_check(a, b, config.DEFAULT_BASE_LIMBS)


def test_dtype_is_int32_contract():
    """Inputs are widened/validated to i32 lanes (the plane layout the
    Rust runtime marshals); int64 input must still compute exactly."""
    l = config.mant_limbs(512)
    rng = np.random.RandomState(5)
    a64 = rng.randint(0, 256, (2, l)).astype(np.int64)
    b64 = rng.randint(0, 256, (2, l)).astype(np.int64)
    red = karatsuba.mult_mantissa(a64, b64)
    assert red.dtype == jnp.int32
    canon = np.asarray(carry.propagate_carries(red))
    for i in range(2):
        assert ref.limbs_to_int(canon[i]) == ref.limbs_to_int(a64[i]) * ref.limbs_to_int(b64[i])
