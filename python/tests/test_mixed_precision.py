"""Python port of the mixed-precision stream semantics (ISSUE 10).

``rust/src/coordinator/stream.rs`` hosts kernels at several mantissa
widths on one device and lets every launch pick one
(``enqueue_gemm_at``); ``rust/tests/mixed_precision.rs`` drives
randomized schedules of interleaved dependent and independent launches
across those widths.  This module re-states the width layer as an
executable model on top of the stream-protocol model
(``test_stream_protocol.StreamModel``) — same structure, same names
where it matters (``enqueue_at`` / ``convert`` / ``alloc_at``) — and
checks the same theorems on seeded random schedules:

* **per-width bit identity** — a mixed-width schedule, however the
  faults and worker interleavings land, produces exactly the serial
  reference at every width;
* **typed width errors, before state** — a launch whose operand widths
  disagree raises ``WidthMismatch`` and an unloaded width raises
  ``NoArtifact`` (naming the loaded set), in both cases before the
  hazard scan or any dispatch state is touched, and the stream stays
  fully usable;
* **conversion semantics** — ``convert`` drains the writers of its
  source buffer, then re-encodes; narrow -> wide -> narrow is the
  identity on the narrow value;
* **overlap** — independent launches at *different* widths pipeline on
  the one device (``inflight_max >= 2``);
* **per-width ledger conservation** — every retired launch's tiles and
  launches land in exactly one width's ledger row, rows sum to the
  device totals, and failed launches contribute nothing.

The width encoding mirrors the mantissa truncation of
``softfloat::ApFloat::to_prec``: a 128-bit buffer keeps 16 value bits
(both 512- and 1024-bit buffers hold the model's full 32-bit values), so
widening is exact and narrowing is lossy-but-idempotent, exactly the
RNDZ behaviour the Rust unit tests pin.
"""

from __future__ import annotations

import random

import pytest

from test_sim_backend import tile_cost
from test_stream_protocol import (
    TILES,
    NoSurvivors,  # noqa: F401  (re-exported for symmetry with the base model)
    Poisoned,
    StreamModel,
    tile_value,
    writeback_value,
)

DEFAULT_WIDTHS = [128, 512, 1024]  # runtime::manifest::DEFAULT_WIDTHS


def encode(value: int, bits: int) -> int:
    """Re-encode a model value at a packed width: the 128-bit format keeps
    16 of the model's 32 value bits (RNDZ truncation), the wider formats
    keep all of them.  ``encode(encode(v, 128), 128) == encode(v, 128)``
    — narrowing is idempotent, like ``to_prec``."""
    return value & ((1 << (bits // 8)) - 1)


class WidthMismatch(Exception):
    """stream.rs ``StreamError::WidthMismatch``: operand widths vs launch width."""

    def __init__(self, launch: int, bits: int, a: int, b: int, c: int):
        super().__init__(f"launch {launch}: operand widths {a}/{b}/{c} bits "
                         f"do not all match the {bits}-bit launch width")
        self.launch, self.bits = launch, bits
        self.a, self.b, self.c = a, b, c


class NoArtifact(Exception):
    """manifest.rs ``ManifestError::NoArtifact``: an unloaded launch width."""

    def __init__(self, bits: int, loaded: list):
        super().__init__(f"no gemm artifact at {bits} bits; loaded: {loaded}")
        self.bits, self.loaded = bits, loaded


class MixedStreamModel(StreamModel):
    """Width-aware leader state: a width table cut from the loaded set at
    construction (stream.rs ``WidthSlot``), per-buffer widths, typed
    width checks ahead of the hazard scan, and a per-width ledger fed at
    retirement (``ModelMetrics::add_tile_at`` / ``add_launch_at``)."""

    def __init__(self, cus: int, widths=None, faults=None, **kw):
        super().__init__(cus=cus, n_bufs=0, faults=faults or {}, **kw)
        self.widths = list(widths or DEFAULT_WIDTHS)
        self.default_bits = 512 if 512 in self.widths else self.widths[0]
        self.buf_bits = []
        self.launch_info = {}  # launch id -> (bits, c)
        self.ledger = {}  # bits -> {"tiles": n, "launches": n}
        self.total_tiles = 0
        self.total_launches = 0

    # -- buffers ----------------------------------------------------------
    def alloc_at(self, bits: int, value: int = 0) -> int:
        self.bufs.append(encode(value, bits))
        self.buf_bits.append(bits)
        return len(self.bufs) - 1

    def convert(self, src: int, bits: int) -> int:
        """stream.rs ``DeviceStream::convert``: drain through the last
        in-flight writer of the source, then re-encode into a fresh
        buffer at the new width."""
        self.check_live()
        last = None
        for i, l in enumerate(self.inflight):
            if l.c == src:
                last = i
        if last is not None:
            for _ in range(last + 1):
                self.retire_one()
        return self.alloc_at(bits, self.bufs[src])

    # -- launches ---------------------------------------------------------
    def enqueue_at(self, bits: int, a: int, b: int, c: int):
        """stream.rs ``enqueue_gemm_at``: width-table lookup, then the
        width-agreement check, both BEFORE the hazard scan — a rejected
        launch must touch no dispatch state (the apfp-lint width-agreement
        shape rule pins that ordering in the Rust source)."""
        self.check_live()
        if bits not in self.widths:
            raise NoArtifact(bits, list(self.widths))
        wa, wb, wc = (self.buf_bits[i] for i in (a, b, c))
        if not (wa == wb == wc == bits):
            raise WidthMismatch(self.next_launch, bits, wa, wb, wc)
        lid = self.next_launch
        super().enqueue(a, b, c)  # hazard scan + dispatch, unchanged
        self.launch_info[lid] = (bits, c)

    def enqueue(self, a: int, b: int, c: int):
        # the width-oblivious API launches at the device default
        self.enqueue_at(self.default_bits, a, b, c)

    def retire_one(self):
        super().retire_one()
        lid = self.retired_order[-1]
        bits, c = self.launch_info[lid]
        if self.errors and self.errors[-1][:2] == ("LaunchFailed", lid):
            return  # failed launches contribute nothing to any ledger
        # the writeback lands at C's width (the lossy step for 128-bit C)
        self.bufs[c] = encode(self.bufs[c], self.buf_bits[c])
        row = self.ledger.setdefault(bits, {"tiles": 0, "launches": 0})
        row["tiles"] += TILES
        row["launches"] += 1
        self.total_tiles += TILES
        self.total_launches += 1


def serial_mixed_reference(ops: list) -> list:
    """The fault-free serial semantics of a mixed-width op list:
    ``("alloc", bits, value)``, ``("gemm", bits, a, b, c)`` and
    ``("convert", src, bits)`` replayed in order."""
    bufs, bits_of, lid = [], [], 0
    for op in ops:
        if op[0] == "alloc":
            bufs.append(encode(op[2], op[1]))
            bits_of.append(op[1])
        elif op[0] == "convert":
            bufs.append(encode(bufs[op[1]], op[2]))
            bits_of.append(op[2])
        else:
            _, _bits, a, b, c = op
            snap = (bufs[a], bufs[b], bufs[c])
            vals = tuple(tile_value(lid, o, snap) for o in range(TILES))
            bufs[c] = encode(writeback_value(bufs[c], vals), bits_of[c])
            lid += 1
    return bufs


def replay(s: MixedStreamModel, ops: list):
    """Apply an op list to the model (allocs included, so buffer ids line
    up with the serial reference)."""
    for op in ops:
        if op[0] == "alloc":
            s.alloc_at(op[1], op[2])
        elif op[0] == "convert":
            s.convert(op[1], op[2])
        else:
            s.enqueue_at(op[1], op[2], op[3], op[4])


def mixed_schedule(rng: random.Random, widths: list, rounds: int) -> list:
    """The rust/tests/mixed_precision.rs schedule shape: per width a lane
    of A, B and two C buffers; each round two independent launches per
    width (disjoint C — free to pipeline, across widths too) and, half
    the time, a dependent chain step on a random width."""
    ops, lanes = [], []
    for bits in widths:
        ids = []
        for _ in range(4):  # A, B, C1, C2
            ops.append(("alloc", bits, rng.randrange(1 << 32)))
            ids.append(len(ops) - 1)
        lanes.append((bits, ids))
    for _ in range(rounds):
        for bits, (a, b, c1, c2) in lanes:
            ops.append(("gemm", bits, a, b, c1))
            ops.append(("gemm", bits, a, b, c2))
        if rng.random() < 0.5:
            bits, (a, b, c1, _c2) = lanes[rng.randrange(len(lanes))]
            ops.append(("gemm", bits, c1, b, c1))
    return ops


# ---------------------------------------------------------------------------
# the rust/tests/mixed_precision.rs mirrors
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [11, 23, 47])
def test_randomized_mixed_width_schedules_are_bit_identical_per_width(seed):
    rng = random.Random(seed)
    ops = mixed_schedule(rng, DEFAULT_WIDTHS, rounds=4)
    s = MixedStreamModel(cus=2, rng=rng)
    replay(s, ops)
    s.wait()
    assert s.errors == []
    assert s.bufs == serial_mixed_reference(ops), (
        f"seed {seed}: mixed-width run diverged from the serial reference")
    # independent launches at different widths must actually overlap
    assert s.metrics["inflight_max"] >= 2
    assert (s.metrics["retries"], s.metrics["respawns"],
            s.metrics["quarantined_cus"]) == (0, 0, 0)
    s.check_conservation()


def test_transient_faults_heal_inside_mixed_width_schedules():
    rng = random.Random(61)
    ops = mixed_schedule(rng, DEFAULT_WIDTHS, rounds=3)
    # tile 0 exists in every launch, whatever the width: fail its first
    # delivery every time, so the retry rung runs while widths interleave
    n_gemms = sum(1 for op in ops if op[0] == "gemm")
    faults = {(lid, 0): ("fail", 1) for lid in range(n_gemms)}
    s = MixedStreamModel(cus=2, faults=faults, rng=rng)
    replay(s, ops)
    s.wait()
    assert s.errors == [], "budgeted faults must heal silently"
    assert s.bufs == serial_mixed_reference(ops)
    assert s.metrics["retries"] == n_gemms, "every launch retried tile 0 once"
    assert s.metrics["respawns"] == 0, "tile errors never respawn workers"
    s.check_conservation()


def test_width_mismatch_and_unloaded_width_stay_typed_under_load():
    s = MixedStreamModel(cus=1, rng=random.Random(5))
    ha = s.alloc_at(512, 7)
    hb = s.alloc_at(512, 9)
    hc = s.alloc_at(128, 0)
    with pytest.raises(WidthMismatch) as e:
        s.enqueue_at(512, ha, hb, hc)
    assert (e.value.bits, e.value.a, e.value.b, e.value.c) == (512, 512, 512, 128)
    with pytest.raises(NoArtifact) as e:
        s.enqueue_at(2048, ha, hb, hc)
    assert (e.value.bits, e.value.loaded) == (2048, [128, 512, 1024])
    # neither error touched dispatch state or poisoned the stream
    assert not s.poisoned and not s.inflight and s.next_launch == 0
    # the stream stays fully usable: convert the stray C and launch at
    # both the default and the narrow width
    hc_ok = s.convert(hc, 512)
    s.enqueue_at(512, ha, hb, hc_ok)
    la, lb = s.convert(ha, 128), s.convert(hb, 128)
    s.enqueue_at(128, la, lb, hc)
    s.wait()
    assert s.errors == []
    assert sorted(s.ledger) == [128, 512]


def test_convert_round_trips_and_feeds_the_other_width():
    # narrow -> wide -> narrow is the identity on the narrow value, and a
    # converted buffer launches at its new width bit-identically to the
    # serial reference at that width (stream.rs unit-test mirror)
    rng = random.Random(12)
    ops = [("alloc", 512, rng.randrange(1 << 32)),
           ("alloc", 512, rng.randrange(1 << 32)),
           ("convert", 0, 128), ("convert", 1, 128),  # ids 2, 3
           ("convert", 2, 512),                       # id 4: wide again
           ("convert", 4, 128),                       # id 5: narrow again
           ("alloc", 128, 0),                         # id 6: the 128-bit C
           ("gemm", 128, 2, 3, 6)]
    s = MixedStreamModel(cus=2, rng=rng)
    replay(s, ops)
    s.wait()
    want = serial_mixed_reference(ops)
    assert s.bufs == want
    assert s.bufs[5] == s.bufs[2], "narrow -> wide -> narrow is the identity"
    assert s.buf_bits[6] == 128 and s.bufs[6] == want[6]


def test_per_width_ledger_conserves_the_device_totals():
    # tests/sim_backend.rs mirror: every retired launch lands in exactly
    # one width's row, rows sum to the totals, failed launches nothing
    rng = random.Random(800)
    ops = mixed_schedule(rng, DEFAULT_WIDTHS, rounds=2)
    n_gemms = sum(1 for op in ops if op[0] == "gemm")
    faults = {(n_gemms - 1, 0): ("fail", None)}  # the last launch fails
    s = MixedStreamModel(cus=2, faults=faults, retry_limit=1, rng=rng)
    replay(s, ops)
    s.wait()
    assert len(s.errors) == 1 and s.errors[0][0] == "LaunchFailed"
    assert sorted(s.ledger) == DEFAULT_WIDTHS, "every width owns a ledger row"
    assert sum(r["tiles"] for r in s.ledger.values()) == s.total_tiles
    assert sum(r["launches"] for r in s.ledger.values()) == s.total_launches
    assert s.total_launches == n_gemms - 1, "the failed launch accrued nothing"
    # the hardware model behind the rows: same tile geometry, wider words
    # -> more modeled energy and traffic per tile (why the refinement
    # loop mixes widths at all); cycles alone can tie below the II knee
    c128, c512, c1024 = (tile_cost(b, 32, 32, 32) for b in DEFAULT_WIDTHS)
    assert c1024["energy_pj"] > c512["energy_pj"] > c128["energy_pj"]
    assert c1024["dram_bytes"] > c512["dram_bytes"] > c128["dram_bytes"]
    assert c512["cycles"] == c128["cycles"], "below the II knee cycles tie"


def test_poisoned_streams_reject_width_calls_too():
    faults = {(0, o): ("die", None) for o in range(TILES)}
    s = MixedStreamModel(cus=1, faults=faults, respawn_limit=0,
                         rng=random.Random(9))
    ha = s.alloc_at(512, 3)
    with pytest.raises(NoSurvivors):
        s.enqueue_at(512, ha, ha, ha)
        s.wait()
    assert s.poisoned
    with pytest.raises(Poisoned):
        s.enqueue_at(128, ha, ha, ha)
    with pytest.raises(Poisoned):
        s.convert(ha, 128)
