"""L2 operator semantics: apfp_mul / apfp_add / apfp_mac vs the exact oracle.

Bit equality (sign, exponent, every mantissa limb) is required — this is the
reproduction's analog of the paper's MPFR bit-compatibility check.
"""

import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import apfp_types, config, model
from compile.kernels import ref

from .conftest import apfp_strategy, random_apfp


def run_binop(op, pairs, bits):
    a = apfp_types.from_py([p[0] for p in pairs], bits)
    b = apfp_types.from_py([p[1] for p in pairs], bits)
    return apfp_types.to_py(op(a, b), bits)


@pytest.mark.parametrize("bits", [512, 1024])
def test_mul_random(bits):
    rng = random.Random(100 + bits)
    pairs = [(random_apfp(rng, bits), random_apfp(rng, bits)) for _ in range(16)]
    got = run_binop(model.apfp_mul, pairs, bits)
    for i, (x, y) in enumerate(pairs):
        assert got[i] == x.mul(y), i


@pytest.mark.parametrize("bits", [512, 1024])
def test_add_random(bits):
    rng = random.Random(200 + bits)
    pairs = [(random_apfp(rng, bits), random_apfp(rng, bits)) for _ in range(16)]
    got = run_binop(model.apfp_add, pairs, bits)
    for i, (x, y) in enumerate(pairs):
        assert got[i] == x.add(y), i


def test_add_nearby_exponents():
    """d in {0, 1, 2} exercises the catastrophic-cancellation and the
    guard-limb paths of the adder."""
    bits = 512
    prec = config.PRECISIONS[bits]
    rng = random.Random(7)
    pairs = []
    for d in (0, 1, 2, 3, 17):
        for _ in range(4):
            x = random_apfp(rng, bits, exp_range=50)
            m = rng.getrandbits(prec) | (1 << (prec - 1))
            y = ref.PyApfp(1 - x.sign, x.exp - d, m, prec)
            pairs.append((x, y))
    got = run_binop(model.apfp_add, pairs, bits)
    for i, (x, y) in enumerate(pairs):
        assert got[i] == x.add(y), (i, pairs[i])


def test_add_exact_cancellation():
    bits = 512
    rng = random.Random(8)
    x = random_apfp(rng, bits)
    got = run_binop(model.apfp_add, [(x, x.neg())], bits)[0]
    assert got.is_zero()
    assert got.sign == 0  # MPFR_RNDZ: exact cancellation yields +0


def test_add_sticky_rndz_correction():
    """Subtraction where the small operand loses bits below the workspace:
    the computed difference must be corrected downward (DESIGN.md §5)."""
    bits = 512
    prec = config.PRECISIONS[bits]
    one = ref.PyApfp.from_float(1.0, prec)
    pairs = []
    for e in (30, 465, 466, 467, 500, 1000):
        tiny = ref.PyApfp(1, one.exp - e, (1 << (prec - 1)) | 1, prec)
        pairs.append((one, tiny))
    got = run_binop(model.apfp_add, pairs, bits)
    for i, (x, y) in enumerate(pairs):
        assert got[i] == x.add(y), f"exp diff case {i}"


def test_zeros_and_signs():
    bits = 512
    prec = config.PRECISIONS[bits]
    z = ref.PyApfp.zero(prec)
    x = ref.PyApfp.from_float(3.5, prec)
    assert run_binop(model.apfp_add, [(z, x)], bits)[0] == x
    assert run_binop(model.apfp_add, [(x, z)], bits)[0] == x
    assert run_binop(model.apfp_add, [(z, z)], bits)[0].is_zero()
    assert run_binop(model.apfp_mul, [(z, x)], bits)[0].is_zero()
    assert run_binop(model.apfp_mul, [(x, z)], bits)[0].is_zero()
    xn = x.neg()
    assert run_binop(model.apfp_mul, [(xn, x)], bits)[0].sign == 1
    assert run_binop(model.apfp_mul, [(xn, xn)], bits)[0].sign == 0


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(apfp_strategy(512), apfp_strategy(512)), min_size=4, max_size=4))
def test_hypothesis_mul_512(pairs):
    got = run_binop(model.apfp_mul, pairs, 512)
    for i, (x, y) in enumerate(pairs):
        assert got[i] == x.mul(y), i


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(apfp_strategy(512), apfp_strategy(512)), min_size=4, max_size=4))
def test_hypothesis_add_512(pairs):
    got = run_binop(model.apfp_add, pairs, 512)
    for i, (x, y) in enumerate(pairs):
        assert got[i] == x.add(y), i


def test_mac_intermediate_rounding():
    """MAC must round the product before accumulating (pipeline semantics)."""
    bits = 512
    rng = random.Random(9)
    trips = [
        (random_apfp(rng, bits), random_apfp(rng, bits), random_apfp(rng, bits))
        for _ in range(8)
    ]
    c = apfp_types.from_py([t[0] for t in trips], bits)
    a = apfp_types.from_py([t[1] for t in trips], bits)
    b = apfp_types.from_py([t[2] for t in trips], bits)
    got = apfp_types.to_py(model.apfp_mac(c, a, b), bits)
    for i, (cc, aa, bb) in enumerate(trips):
        assert got[i] == cc.mac(aa, bb), i


def test_mul_powers_of_two():
    bits = 512
    prec = config.PRECISIONS[bits]
    two = ref.PyApfp.from_float(2.0, prec)
    half = ref.PyApfp.from_float(0.5, prec)
    x = ref.PyApfp.from_float(1.0, prec)
    assert run_binop(model.apfp_mul, [(two, half)], bits)[0] == x
    got = run_binop(model.apfp_mul, [(two, two)], bits)[0]
    assert got == ref.PyApfp.from_float(4.0, prec)


def test_float_roundtrip_through_model():
    bits = 512
    prec = config.PRECISIONS[bits]
    vals = [3.14159, -2.71828, 1e-30, -1e30, 0.1]
    xs = [ref.PyApfp.from_float(v, prec) for v in vals]
    ys = [ref.PyApfp.from_float(1.0, prec)] * len(vals)
    got = run_binop(model.apfp_mul, list(zip(xs, ys)), bits)
    for g, v in zip(got, vals):
        assert abs(g.to_float() - v) <= abs(v) * 1e-15
