"""Fig. 1 packed format: layout invariants + roundtrip (pins rust/src/pack)."""

import random

import pytest

from compile import apfp_types, config
from compile.kernels import ref

from conftest import random_apfp


@pytest.mark.parametrize("bits", [512, 1024])
def test_roundtrip(bits):
    rng = random.Random(bits)
    for _ in range(20):
        v = random_apfp(rng, bits, exp_range=10**9)
        words = apfp_types.pack_words(v, bits)
        assert len(words) == bits // 64  # multiple of 512 bits (Fig. 1)
        assert apfp_types.unpack_words(words, bits) == v


@pytest.mark.parametrize("bits", [512, 1024])
def test_zero_packs_canonically(bits):
    z = ref.PyApfp.zero(config.PRECISIONS[bits])
    words = apfp_types.pack_words(z, bits)
    assert apfp_types.unpack_words(words, bits).is_zero()
    assert all(w == 0 for w in words[1:])


def test_sign_in_exponent_msb():
    """The sign occupies bit 63 of the head word (the paper packs the sign
    into a single bit of the exponent word)."""
    prec = config.PRECISIONS[512]
    m = (1 << (prec - 1)) | 12345
    pos = ref.PyApfp(0, 42, m, prec)
    neg = ref.PyApfp(1, 42, m, prec)
    wp = apfp_types.pack_words(pos, 512)
    wn = apfp_types.pack_words(neg, 512)
    assert wn[0] == wp[0] | (1 << 63)
    assert wn[1:] == wp[1:]


def test_negative_exponent_two_complement():
    prec = config.PRECISIONS[512]
    m = 1 << (prec - 1)
    v = ref.PyApfp(0, -1, m, prec)
    w = apfp_types.pack_words(v, 512)
    assert w[0] == (1 << 63) - 1  # 63-bit two's complement of -1, sign bit 0
    assert apfp_types.unpack_words(w, 512) == v


def test_mantissa_little_endian_tight_packing():
    prec = config.PRECISIONS[512]
    m = (1 << (prec - 1)) | 0xDEADBEEF
    v = ref.PyApfp(0, 0, m, prec)
    w = apfp_types.pack_words(v, 512)
    assert w[1] & 0xFFFFFFFF == 0xDEADBEEF  # low mantissa word first
    assert w[7] >> 63 == 1  # normalized MSB lands in the top packed bit
