"""Self-consistency of the exact PyApfp oracle (the semantic root of trust).

PyApfp is validated against plain Python integer/fraction arithmetic so the
rest of the stack can safely be pinned against it.
"""

import random

import pytest
from hypothesis import given, settings

from compile import config
from compile.kernels import ref

from .conftest import apfp_strategy, random_apfp

PREC = config.PRECISIONS[512]


def exact_value(v: ref.PyApfp):
    """Return the value as an exact pair (numerator, 2**denominator_exp)."""
    s, e = v.to_exact()
    return s, e


def test_from_float_exact():
    for x in [1.0, -1.0, 0.5, 3.141592653589793, 2**-50, -(2**60)]:
        v = ref.PyApfp.from_float(x, PREC)
        s, e = v.to_exact()
        assert s * 2.0**e == x  # doubles embed exactly into 448-bit APFP


def test_mul_matches_integer_arithmetic():
    rng = random.Random(1)
    for _ in range(50):
        a = random_apfp(rng, 512)
        b = random_apfp(rng, 512)
        got = a.mul(b)
        sa, ea = a.to_exact()
        sb, eb = b.to_exact()
        exact_num = sa * sb  # exact product, scale 2^(ea+eb)
        want = ref.PyApfp.from_int_scaled(exact_num, ea + eb, PREC)
        assert got == want


@settings(max_examples=100, deadline=None)
@given(apfp_strategy(512), apfp_strategy(512))
def test_add_matches_integer_arithmetic(a, b):
    got = a.add(b)
    if a.is_zero() or b.is_zero():
        assert got == (b if a.is_zero() else a)
        return  # the ZERO_EXP sentinel would make the shift below astronomical
    sa, ea = a.to_exact()
    sb, eb = b.to_exact()
    e = min(ea, eb)
    total = (sa << (ea - e)) + (sb << (eb - e))
    want = ref.PyApfp.from_int_scaled(total, e, PREC)
    assert got == want


def test_rndz_truncates_toward_zero():
    """RNDZ: |result| <= |exact| always, and within one ulp."""
    rng = random.Random(2)
    for _ in range(50):
        a = random_apfp(rng, 512)
        b = random_apfp(rng, 512)
        got = a.mul(b)
        sa, ea = a.to_exact()
        sb, eb = b.to_exact()
        gm, ge = got.to_exact()
        # |got|*2^ge <= |exact|*2^(ea+eb) < (|got|+1)*2^ge, compared at a
        # common scale m = min of the two exponents
        exact_mag = abs(sa * sb)
        m = min(ge, ea + eb)
        lhs = abs(gm) << (ge - m)
        rhs = exact_mag << (ea + eb - m)
        assert lhs <= rhs < lhs + (1 << (ge - m))


def test_commutativity():
    rng = random.Random(3)
    for _ in range(25):
        a = random_apfp(rng, 512)
        b = random_apfp(rng, 512)
        assert a.mul(b) == b.mul(a)
        assert a.add(b) == b.add(a)


def test_identity_elements():
    rng = random.Random(4)
    one = ref.PyApfp.from_float(1.0, PREC)
    zero = ref.PyApfp.zero(PREC)
    for _ in range(10):
        a = random_apfp(rng, 512)
        assert a.mul(one) == a
        assert a.add(zero) == a
        assert a.mul(zero).is_zero()


def test_neg_involution():
    rng = random.Random(5)
    a = random_apfp(rng, 512)
    assert a.neg().neg() == a
    assert a.add(a.neg()).is_zero()


def test_limb_roundtrip():
    rng = random.Random(6)
    for _ in range(10):
        a = random_apfp(rng, 512)
        limbs = a.mant_limb_list()
        assert len(limbs) == 56
        back = ref.PyApfp.from_limb_parts(a.sign, a.exp, limbs, PREC)
        assert back == a


def test_gemm_ref_against_naive():
    """gemm_ref (sequential-K MACs) agrees with a naive loop at f64 scale."""
    rng = random.Random(8)
    n = 3
    av = [[rng.uniform(-2, 2) for _ in range(n)] for _ in range(n)]
    bv = [[rng.uniform(-2, 2) for _ in range(n)] for _ in range(n)]
    a = [[ref.PyApfp.from_float(x, PREC) for x in row] for row in av]
    b = [[ref.PyApfp.from_float(x, PREC) for x in row] for row in bv]
    c = [[ref.PyApfp.zero(PREC) for _ in range(n)] for _ in range(n)]
    out = ref.gemm_ref(a, b, c)
    for i in range(n):
        for j in range(n):
            want = sum(av[i][k] * bv[k][j] for k in range(n))
            assert abs(out[i][j].to_float() - want) < 1e-12


def test_div_matches_integer_arithmetic():
    rng = random.Random(21)
    for _ in range(50):
        a = random_apfp(rng, 512)
        b = random_apfp(rng, 512)
        got = a.div(b)
        # exact check: got = trunc_p(a/b) means |got| <= |a/b| < |got|+ulp
        gm, ge = got.to_exact()
        sa, ea = a.to_exact()
        sb, eb = b.to_exact()
        # compare |gm| * 2^ge <= |sa/sb| * 2^(ea-eb)  as integers:
        # |gm| * |sb| * 2^(ge) vs |sa| * 2^(ea-eb); align exponents
        lhs, rhs, sh = abs(gm) * abs(sb), abs(sa), ge - (ea - eb)
        if sh >= 0:
            lhs <<= sh
        else:
            rhs <<= -sh
        assert lhs <= rhs, "RNDZ must not overshoot"
        ulp_side = (abs(gm) + 1) * abs(sb)
        if sh >= 0:
            ulp_side <<= sh
        assert rhs < ulp_side, "must be within one ulp"


def test_div_identities():
    rng = random.Random(22)
    one = ref.PyApfp.from_float(1.0, PREC)
    for _ in range(20):
        a = random_apfp(rng, 512)
        assert a.div(one) == a
        assert a.div(a) == one
        assert ref.PyApfp.zero(PREC).div(a).is_zero()
