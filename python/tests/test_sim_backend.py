"""SimBackend cycle accounting, mirrored from ``rust/src/runtime/sim_backend.rs``.

Self-contained (stdlib only, always collected, no jax): a line-mirror of
the hardware-model chain the simulated backend charges per GEMM K-step —
``hwmodel::dsp`` (Karatsuba DSP counting), ``hwmodel::resources`` (CLB
estimation), ``hwmodel::frequency`` (achievable clock),
``hwmodel::floorplan`` (Fig. 4 bank sharing), ``sim::dram`` (bank
bandwidth derates), ``sim::gemm_sim::simulate/peak`` (the Fig. 5 / Tab.
III dataflow model) and finally ``sim_backend::tile_cost`` itself — then
three layers of checks on top:

1. the same paper calibration pins the Rust unit tests assert (Tab. I-III
   frequencies and peaks, Fig. 3 shape), so the mirror cannot drift from
   the model without failing the same way the Rust suite would;
2. seeded random launch schedules (xorshift64*, same generator as
   ``rust/src/testkit``) replaying the coordinator's retirement
   accounting: per-tile cost = K-steps x ``tile_cost``, ledger totals =
   sum over settled tiles + one fixed launch charge per retired launch,
   with retried/failed attempts contributing nothing;
3. a value-exact cross-check of every pin in ``rust/model_golden.json``
   (the file CI's ``repro modelgold --check`` gate diffs against the Rust
   implementation), which is what ties the two languages together: Rust
   checks that file against its model at 1e-6 relative, this file checks
   it against the mirror at the same tolerance, so Rust and Python agree
   transitively to 2e-6.

Rounding caveat mirrored deliberately: Rust ``f64::round`` is
half-away-from-zero, Python ``round`` is banker's — the mirror uses
``floor(x + 0.5)`` for non-negative model quantities.
"""

from __future__ import annotations

import json
import math
import os

import pytest

# --------------------------------------------------------------------------
# u250 constants — rust/src/hwmodel/mod.rs::u250
# --------------------------------------------------------------------------

DSP_TOTAL = 12_288
CLB_TOTAL = 216_000
SLRS = 4
DDR_BANK_BW = 19.2e9

# rust/src/sim/gemm_sim.rs
CONVERT_S_PER_ELEM = 120e-9
PCIE_BW = 11.0e9
LAUNCH_S = 250e-6
PIPELINE_DEPTH = 400.0

# rust/src/sim/dram.rs
CONTIGUOUS_EFF = 0.93
STRIDED_EFF = 0.78

# rust/src/runtime/sim_backend.rs
DSP_PJ_PER_CYCLE = 22.0
CLB_PJ_PER_CYCLE = 0.55


def rust_round(x: float) -> int:
    """f64::round for non-negative x: half away from zero."""
    assert x >= 0.0
    return math.floor(x + 0.5)


def div_ceil(a: int, b: int) -> int:
    return -(-a // b)


# --------------------------------------------------------------------------
# hwmodel::dsp — Karatsuba DSP counting
# --------------------------------------------------------------------------

DSP_PORT_BITS = 17


def naive_dsps(w: int) -> int:
    tiles = div_ceil(w, DSP_PORT_BITS)
    return tiles * tiles


def karatsuba_leaves(prec: int, mult_base_bits: int) -> tuple[int, int]:
    width, leaves = prec, 1
    while width > mult_base_bits:
        width = div_ceil(width, 2)
        leaves *= 3
    return leaves, width


def multiplier_dsps(prec: int, mult_base_bits: int) -> int:
    leaves, width = karatsuba_leaves(prec, mult_base_bits)
    return leaves * naive_dsps(width)


# --------------------------------------------------------------------------
# hwmodel::resources — CLB estimation
# --------------------------------------------------------------------------

SHELL_CLBS = 21_600
MULTI_CU_CLBS = 12_960
FIXED_CU_CLBS = 1_080


def luts_to_clbs(luts: int) -> int:
    return rust_round((luts / 8.0 + 2.0 * luts / 16.0) / 0.55)


def recombination_luts(prec: int, mult_base_bits: int) -> int:
    total, width, nodes = 0, prec, 1
    while width > mult_base_bits:
        total += nodes * 6 * width
        width = div_ceil(width, 2)
        nodes *= 3
    return total


def leaf_luts(prec: int, mult_base_bits: int) -> int:
    leaves, w = karatsuba_leaves(prec, mult_base_bits)
    tiles = div_ceil(w, DSP_PORT_BITS)
    return leaves * tiles * (w // 2)


def multiplier_luts(prec: int, mult_base_bits: int) -> int:
    return recombination_luts(prec, mult_base_bits) + leaf_luts(prec, mult_base_bits)


# --------------------------------------------------------------------------
# DesignPoint — rust/src/hwmodel/mod.rs (only what tile_cost/simulate need)
# --------------------------------------------------------------------------


class DesignPoint:
    def __init__(self, bits, compute_units, mult_base_bits, add_base_bits, gemm):
        self.bits = bits
        self.compute_units = compute_units
        self.mult_base_bits = mult_base_bits
        self.add_base_bits = add_base_bits
        self.gemm = gemm

    @property
    def prec(self) -> int:
        return self.bits - 64


def gemm_512(cus: int) -> DesignPoint:
    return DesignPoint(512, cus, 72, 64, True)


def gemm_1024(cus: int) -> DesignPoint:
    return DesignPoint(1024, cus, 72, 64, True)


def mult_512(cus: int) -> DesignPoint:
    return DesignPoint(512, cus, 72, 64, False)


def cu_clbs(d: DesignPoint) -> int:
    clbs = FIXED_CU_CLBS + luts_to_clbs(multiplier_luts(d.prec, d.mult_base_bits))
    if d.gemm:
        clbs += 12 * d.prec
    return clbs


# --------------------------------------------------------------------------
# hwmodel::frequency
# --------------------------------------------------------------------------

F_CEILING_MHZ = 500.0
F_FLOOR_MHZ = 293.0
T_CARRY_PER_BIT = 0.004
T_LEAF_PER_BIT = 0.012
T_WIDTH_PER_BIT = 0.001
T_GEMM_PER_BIT = 0.00195
T_BASE = 0.62
CONGESTION = 1.5


def pipeline_mhz(d: DesignPoint) -> float:
    prec = float(d.prec)
    t = (
        T_BASE
        + T_WIDTH_PER_BIT * prec
        + T_CARRY_PER_BIT * d.add_base_bits
        + T_LEAF_PER_BIT * d.mult_base_bits
    )
    if d.gemm:
        t += T_GEMM_PER_BIT * prec
    return min(1000.0 / t, F_CEILING_MHZ)


def achievable_mhz(d: DesignPoint) -> float:
    f_base = pipeline_mhz(d)
    cu_frac = cu_clbs(d) / CLB_TOTAL
    congestion = 1.0 + CONGESTION * (d.compute_units - 1.0) * cu_frac
    f_cong = f_base / congestion
    return max(f_cong, min(F_FLOOR_MHZ, f_base))


# --------------------------------------------------------------------------
# hwmodel::floorplan + sim::dram — bank sharing and stream times
# --------------------------------------------------------------------------

BANK_ORDER = [1, 0, 2, 3]


def cus_per_bank(compute_units: int) -> list[int]:
    counts = [0, 0, 0, 0]
    for cu in range(compute_units):
        counts[BANK_ORDER[cu % 4]] += 1
    return counts


def per_cu_bandwidth(compute_units: int) -> float:
    worst = max(cus_per_bank(compute_units))
    if worst == 0:
        return DDR_BANK_BW
    return DDR_BANK_BW / worst


def stream_time(bytes_, compute_units: int, efficiency: float) -> float:
    return bytes_ / (per_cu_bandwidth(compute_units) * efficiency)


# --------------------------------------------------------------------------
# sim::gemm_sim — the Fig. 5 / Tab. III dataflow model
# --------------------------------------------------------------------------


def simulate(d: DesignPoint, n: int, tile_n: int, tile_m: int) -> dict:
    f = achievable_mhz(d) * 1e6
    p = d.compute_units
    bytes_per_elem = float(d.bits // 8)

    rows_cu = div_ceil(n, p)
    tiles_n = div_ceil(rows_cu, tile_n)
    tiles_m = div_ceil(n, tile_m)
    tiles = float(tiles_n * tiles_m)

    cu_frac = cu_clbs(d) / (CLB_TOTAL / SLRS)
    ii = 1.0 + max(cu_frac - 0.5, 0.0)
    cycles_per_tile = float(n * tile_n * tile_m) * ii + PIPELINE_DEPTH
    compute_s = tiles * cycles_per_tile / f

    tile_read_a = float(tile_n * n) * bytes_per_elem
    tile_read_b = float(tile_m * n) * bytes_per_elem
    tile_write_c = float(tile_n * tile_m) * bytes_per_elem
    mem_s = tiles * (
        stream_time(tile_read_a, p, STRIDED_EFF)
        + stream_time(tile_read_b, p, CONTIGUOUS_EFF)
        + stream_time(tile_write_c, p, CONTIGUOUS_EFF)
    )

    elems = float(n * n)
    convert_s = 3.0 * elems * CONVERT_S_PER_ELEM
    transfer_bytes = (2.0 + min(4.0, float(p))) * elems * bytes_per_elem
    fixed_s = convert_s + transfer_bytes / PCIE_BW + LAUNCH_S * p

    kernel_s = max(compute_s, mem_s)
    total_s = kernel_s + fixed_s
    macs = float(n) ** 3
    mmacs = macs / total_s
    return {
        "n": n,
        "mmacs": mmacs,
        "efficiency": mmacs / (f * p),
        "compute_s": compute_s,
        "mem_s": mem_s,
        "fixed_s": fixed_s,
    }


def peak(d: DesignPoint, tile: int) -> dict:
    best = simulate(d, 256, tile, tile)
    n = 512
    while n <= 16384:
        pt = simulate(d, n, tile, tile)
        if pt["mmacs"] > best["mmacs"]:
            best = pt
        n *= 2
    return best


# --------------------------------------------------------------------------
# runtime::sim_backend::tile_cost — the formula the goldens pin
# --------------------------------------------------------------------------


def tile_cost(bits: int, t_n: int, t_m: int, k_tile: int,
              pipeline_depth: float = PIPELINE_DEPTH) -> dict:
    """Mirror of ``sim_backend::tile_cost`` on ``ArtifactMeta::design_point``
    (1 CU, 72/64 bases, gemm).  ``pipeline_depth`` is a parameter only so
    the falsifiability test can perturb it the way the Rust calibration
    suite does."""
    d = DesignPoint(bits, 1, 72, 64, True)
    f_hz = achievable_mhz(d) * 1e6
    macs = t_n * t_m * k_tile

    cu_frac = cu_clbs(d) / (CLB_TOTAL / SLRS)
    ii = 1.0 + max(cu_frac - 0.5, 0.0)
    cycles_f = float(macs) * ii + pipeline_depth

    bytes_per_elem = float(bits // 8)
    read_a = float(t_n * k_tile) * bytes_per_elem
    read_b = float(k_tile * t_m) * bytes_per_elem
    write_c = float(t_n * t_m) * bytes_per_elem
    mem_s = (
        stream_time(read_a, 1, STRIDED_EFF)
        + stream_time(read_b, 1, CONTIGUOUS_EFF)
        + stream_time(write_c, 1, CONTIGUOUS_EFF)
    )

    dsps = float(multiplier_dsps(d.prec, d.mult_base_bits))
    clbs = float(cu_clbs(d))
    energy_pj = cycles_f * (dsps * DSP_PJ_PER_CYCLE + clbs * CLB_PJ_PER_CYCLE)

    return {
        "cycles": math.ceil(cycles_f),
        "macs": macs,
        "dram_bytes": int(read_a + read_b + write_c),
        "compute_ps": rust_round(cycles_f / f_hz * 1e12),
        "mem_ps": rust_round(mem_s * 1e12),
        "energy_pj": rust_round(energy_pj),
    }


# --------------------------------------------------------------------------
# xorshift64* — exact port of rust/src/testkit/mod.rs (same as the other
# mirrors), used to derive the launch schedules deterministically
# --------------------------------------------------------------------------

MASK64 = (1 << 64) - 1


class Rng:
    def __init__(self, seed: int):
        # avoid the all-zero fixed point (testkit::Rng::from_seed)
        self.state = max((seed * 2685821657736338717) & MASK64, 1)

    def next_u64(self) -> int:
        x = self.state
        x ^= (x >> 12)
        x ^= (x << 25) & MASK64
        x ^= (x >> 27)
        self.state = x
        return (x * 0x2545F4914F6CDD1D) & MASK64

    def below(self, n: int) -> int:
        # multiply-shift, exactly as testkit::Rng::below
        return (self.next_u64() * n) >> 64


# --------------------------------------------------------------------------
# 1. calibration pins — the same paper values the Rust suite asserts
# --------------------------------------------------------------------------


def test_dsp_counts_match_paper_scale():
    assert naive_dsps(56) == 16
    assert karatsuba_leaves(448, 72) == (27, 56)
    assert multiplier_dsps(448, 72) == 432  # Tab. I: "4%" of 12288
    assert multiplier_dsps(960, 72) == 81 * naive_dsps(60)


def test_tab1_tab3_frequency_calibration():
    assert abs(achievable_mhz(mult_512(1)) - 456.0) < 20.0
    assert abs(achievable_mhz(gemm_512(1)) - 327.0) < 15.0
    for cus in (2, 4, 8):
        assert abs(achievable_mhz(gemm_512(cus)) - 285.0) < 25.0
    assert abs(achievable_mhz(gemm_1024(1)) - 212.0) < 20.0


def test_tab3_gemm_peaks():
    for cus, paper in [(1, 322.0), (2, 540.0), (4, 1049.0), (8, 2002.0)]:
        got = peak(gemm_512(cus), 32)["mmacs"] / 1e6
        assert abs(got - paper) / paper < 0.18, f"CUs={cus}: {got:.0f} vs {paper}"
    got = peak(gemm_1024(1), 32)["mmacs"] / 1e6
    assert abs(got - 158.0) / 158.0 < 0.35


def test_compute_bound_at_paper_tile():
    pt = simulate(gemm_512(8), 8192, 32, 32)
    assert pt["compute_s"] > pt["mem_s"]
    pt4 = simulate(gemm_512(8), 8192, 4, 4)
    assert pt4["mem_s"] > pt4["compute_s"]


# --------------------------------------------------------------------------
# 2. tile_cost semantics — mirrors rust sim_backend unit tests
# --------------------------------------------------------------------------


def test_tile_cost_512_paper_tile():
    c = tile_cost(512, 32, 32, 32)
    assert c["macs"] == 32 * 32 * 32
    # below the half-SLR II knee: cycles = macs + pipeline fill
    assert c["cycles"] == 32 * 32 * 32 + int(PIPELINE_DEPTH)
    assert c["dram_bytes"] == 3 * 32 * 32 * 64
    assert c["compute_ps"] > c["mem_ps"] > 0
    assert c["energy_pj"] > 0


def test_tile_cost_1024_pays_ii_and_traffic():
    c512 = tile_cost(512, 32, 32, 32)
    c1024 = tile_cost(1024, 32, 32, 32)
    assert c1024["cycles"] > c512["cycles"], "1024-bit CU crosses the II knee"
    assert c1024["dram_bytes"] == 2 * c512["dram_bytes"]
    assert c1024["compute_ps"] > c512["compute_ps"]
    assert c1024["energy_pj"] > c512["energy_pj"]


def test_pipeline_depth_perturbation_is_visible():
    """Falsifiability: the ±20% PIPELINE_DEPTH perturbation the Rust
    calibration gate injects must move every derived time, or the gate
    could never trip."""
    base = tile_cost(512, 32, 32, 32)
    for scale in (0.8, 1.2):
        bent = tile_cost(512, 32, 32, 32, pipeline_depth=PIPELINE_DEPTH * scale)
        assert bent["cycles"] != base["cycles"]
        assert bent["compute_ps"] != base["compute_ps"]
        assert bent["energy_pj"] != base["energy_pj"]
        rel = abs(bent["compute_ps"] - base["compute_ps"]) / base["compute_ps"]
        assert rel > 1e-3, "a 20% depth bend must exceed the 1e-6 gate tolerance"


# --------------------------------------------------------------------------
# 3. ledger accounting over seeded launch schedules
# --------------------------------------------------------------------------


def ledger_for_schedule(rng: Rng, launches: int) -> dict:
    """Replay the coordinator's retirement accounting: for each launch an
    (n, m, k) problem on a random tile geometry, every output tile settles
    once with k_steps x tile_cost, the device ledger sums settled tiles
    and charges LAUNCH_S once per retired launch."""
    totals = {"cycles": 0, "macs": 0, "dram_bytes": 0, "compute_ps": 0,
              "mem_ps": 0, "energy_pj": 0, "tiles": 0, "launches": 0,
              "fixed_ps": 0}
    for _ in range(launches):
        bits = 512 if rng.below(2) == 0 else 1024
        t = (2, 4, 8, 16)[rng.below(4)]
        n = (t * (1 + rng.below(4)))
        m = (t * (1 + rng.below(4)))
        k = (t * (1 + rng.below(4)))
        per_call = tile_cost(bits, t, t, t)
        k_steps = div_ceil(k, t)
        tiles = div_ceil(n, t) * div_ceil(m, t)
        for _tile in range(tiles):
            # a worker drains k_steps accrued calls into one reply
            for key in ("cycles", "macs", "dram_bytes", "compute_ps",
                        "mem_ps", "energy_pj"):
                totals[key] += k_steps * per_call[key]
            totals["tiles"] += 1
        totals["launches"] += 1
        totals["fixed_ps"] += int(LAUNCH_S * 1e12)
    return totals


def test_schedule_ledger_is_conservation_exact():
    rng = Rng(0x51ABAC)
    totals = ledger_for_schedule(rng, launches=17)
    assert totals["tiles"] > 0 and totals["launches"] == 17
    assert totals["fixed_ps"] == 17 * int(LAUNCH_S * 1e12)
    # MAC conservation: every modeled lane belongs to exactly one settled
    # tile, so totals factor exactly into per-call costs — replaying the
    # same schedule reproduces the ledger bit-for-bit (no double-counting
    # term can hide in a deterministic replay)
    again = ledger_for_schedule(Rng(0x51ABAC), launches=17)
    assert totals == again
    # and retries add nothing: a failed attempt's cost is discarded before
    # the reply, so a schedule with retries has the *same* ledger — model
    # that by charging only settled tiles (what the replay above does) and
    # checking macs factors into whole k_steps x tile lanes
    assert totals["macs"] % 8 == 0  # every tile contributes t^3 >= 8 lanes


def test_ledger_efficiency_bounds():
    rng = Rng(0xFEED5)
    totals = ledger_for_schedule(rng, launches=9)
    eff = totals["macs"] / totals["cycles"]
    assert 0.0 < eff < 1.0, "pipeline fill + II keep efficiency below 1"


# --------------------------------------------------------------------------
# 4. cross-check rust/model_golden.json — the file `repro modelgold
#    --check` diffs against the Rust model
# --------------------------------------------------------------------------

GOLDEN = os.path.join(os.path.dirname(__file__), "..", "..", "rust",
                      "model_golden.json")


def mirror_golden_values() -> dict:
    out = {}
    for bits in (512, 1024):
        c = tile_cost(bits, 32, 32, 32)
        for key in ("cycles", "macs", "dram_bytes", "compute_ps", "mem_ps",
                    "energy_pj"):
            out[f"tile{bits}_{key}"] = float(c[key])
    for bits, cus in [(512, 1), (512, 2), (512, 4), (512, 8), (1024, 1)]:
        d = gemm_512(cus) if bits == 512 else gemm_1024(cus)
        out[f"gemm{bits}_cu{cus}_freq_mhz"] = achievable_mhz(d)
        out[f"gemm{bits}_cu{cus}_peak_mmacs"] = peak(d, 32)["mmacs"] / 1e6
        pt = simulate(d, 4096, 32, 32)
        out[f"gemm{bits}_cu{cus}_n4096_mmacs"] = pt["mmacs"] / 1e6
        out[f"gemm{bits}_cu{cus}_n4096_efficiency"] = pt["efficiency"]
    return out


def test_model_golden_file_matches_mirror():
    with open(GOLDEN) as f:
        pinned = json.load(f)
    mirror = mirror_golden_values()
    assert set(pinned) == set(mirror), (
        "golden keys diverged; regenerate with `repro modelgold --write`"
    )
    for key, want in pinned.items():
        got = mirror[key]
        scale = max(abs(want), abs(got), 1e-30)
        assert abs(got - want) / scale < 1e-6, (
            f"{key}: golden {want!r} vs mirror {got!r}"
        )


def test_golden_spot_values():
    """A few hand-derived anchors so the golden file and the mirror cannot
    be wrong together (see sim_backend.rs tile_cost docs for the 512-bit
    walk-through: 13634 CLBs -> II=1, 33168 cycles, 432 DSPs)."""
    assert cu_clbs(gemm_512(1)) == 13_634
    c = tile_cost(512, 32, 32, 32)
    assert c["cycles"] == 33_168
    assert c["dram_bytes"] == 196_608
    per_cycle_pj = 432 * DSP_PJ_PER_CYCLE + 13_634 * CLB_PJ_PER_CYCLE
    assert c["energy_pj"] == rust_round(33_168.0 * per_cycle_pj)


if __name__ == "__main__":
    # regeneration helper: `python test_sim_backend.py --write-golden`
    # emits rust/model_golden.json in the exact format `repro modelgold
    # --write` uses (sorted keys, 9 decimal places)
    import sys

    if "--write-golden" in sys.argv:
        vals = mirror_golden_values()
        lines = [f'  "{k}": {vals[k]:.9f}' for k in sorted(vals)]
        with open(GOLDEN, "w") as f:
            f.write("{\n" + ",\n".join(lines) + "\n}\n")
        print(f"wrote {len(vals)} goldens to {GOLDEN}")
    else:
        sys.exit(pytest.main([__file__, "-q"]))
