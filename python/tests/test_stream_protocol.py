"""Python port of the device stream's self-healing protocol (ISSUE 7).

``rust/src/coordinator/stream.rs`` pipelines GEMM launches over per-CU
worker threads and heals failures through an escalation ladder:

1. a tile whose reply carries an error is **redispatched** up to
   ``retry_limit`` times, reusing the staging buffer the errored reply
   returned;
2. a worker that dies reply-less is detected through its **incarnation
   stamp** (every dispatch records the worker incarnation it went to; a
   stamp that is no longer live means the dispatch died with the thread)
   and respawned, and the lost dispatches are replayed at ``attempt + 1``;
3. a CU past its respawn budget is **quarantined**: its band re-routes to
   the survivors and later launches schedule around it (degraded mode);
4. with zero survivors the stream reports ``NoSurvivors`` and poisons.

This module re-states that protocol as an executable model — same
structure, same names where it matters (``enqueue`` / ``retire`` /
``probe`` / ``submit_tile``) — and drives it through randomized worker
schedules.  The theorems checked on every schedule:

* **bit identity** — any run whose faults stay inside the budgets
  produces exactly the fault-free serial result, launch for launch;
* **conservation** — every staging buffer token is either returned by a
  reply or provably lost with a dead incarnation, never duplicated and
  never leaked;
* **FIFO retirement** — launches retire in enqueue order regardless of
  retries and replays (a retry never escapes its launch's retirement);
* **bounded redispatch** — error-driven retries per tile never exceed
  ``retry_limit``;
* **typed bottom** — exhausting every budget ends in ``NoSurvivors``
  then ``Poisoned``, never a hang (a probe that finds nothing lost while
  nothing can run is an assertion failure, the model's hang detector).

The Rust integration tests (``rust/tests/stream_faults.rs``) sample real
thread interleavings; this model explores seeded random ones and is the
checkout's executable spec when no Rust toolchain is present.
"""

from __future__ import annotations

import random

import pytest

TILES = 6  # output tiles per launch (origins 0..TILES-1)
MASK = (1 << 32) - 1


def tile_value(launch_id: int, origin: int, snap: tuple) -> int:
    """The 'arithmetic': a deterministic mix of the launch, the tile and
    the operand contents observed at execution time.  Faults must never
    change it — that is the bit-identity theorem."""
    a, b, c = snap
    return (launch_id * 1000003 + origin * 10007 + a * 31 + b * 37 + c * 41) & MASK


def writeback_value(prev: int, values: tuple) -> int:
    out = prev * 69069 + 1
    for v in values:
        out = (out ^ v) * 2654435761 + 97
    return out & MASK


def serial_reference(n_bufs: int, gemms: list) -> list:
    """The fault-free, serial semantics: every launch reads its enqueue
    snapshot and writes back in order."""
    bufs = [0] * n_bufs
    for lid, (a, b, c) in enumerate(gemms):
        snap = (bufs[a], bufs[b], bufs[c])
        vals = tuple(tile_value(lid, o, snap) for o in range(TILES))
        bufs[c] = writeback_value(bufs[c], vals)
    return bufs


class NoSurvivors(Exception):
    pass


class Poisoned(Exception):
    pass


class Worker:
    """One compute unit under supervision (worker.rs: ``Supervisor``)."""

    def __init__(self, cu: int):
        self.cu = cu
        self.alive = True
        self.incarnation = 0  # == respawns, the dispatch stamp
        self.respawns = 0
        self.quarantined = False
        self.last_incident = None
        self.queue = []  # FIFO of jobs

    def submit(self, job) -> bool:
        if not self.alive or self.quarantined:
            return False
        self.queue.append(job)
        return True

    def die(self, stream):
        """Reply-less death: the thread exits, its queue drains nowhere."""
        self.alive = False
        for job in self.queue:
            stream.lost_tokens.add(job["buf"])
        self.queue.clear()

    def respawn(self, incident: str, limit: int, metrics: dict) -> str:
        """worker.rs ``Supervisor::respawn``: fresh incarnation inside the
        budget, quarantine past it.  Idempotent once quarantined."""
        self.last_incident = incident
        if self.quarantined:
            return "quarantined"
        if self.respawns >= limit:
            self.quarantined = True
            metrics["quarantined_cus"] += 1
            return "quarantined"
        self.respawns += 1
        self.incarnation += 1
        self.alive = True
        self.queue = []
        metrics["respawns"] += 1
        return "respawned"


class Launch:
    def __init__(self, lid: int, a: int, b: int, c: int, snap: tuple, slots: list):
        self.id = lid
        self.a, self.b, self.c = a, b, c
        self.snapshot = snap
        self.slots = slots  # slot index -> physical CU (stamped at enqueue)
        self.slot_of = {o: o % len(slots) for o in range(TILES)}
        self.dispatches = {}  # origin -> (phys, incarnation, attempt)
        self.replies = []  # the per-launch bounded reply channel
        self.settled = {}  # origin -> reply (success or retry-exhausted)
        self.error_retries = {}  # origin -> error-driven redispatch count


class StreamModel:
    """Leader-side state of ``DeviceStream``, with the healing ladder."""

    def __init__(self, cus: int, n_bufs: int, faults: dict, retry_limit=2, respawn_limit=1,
                 rng: random.Random | None = None):
        self.workers = [Worker(i) for i in range(cus)]
        self.bufs = [0] * n_bufs
        # faults[(launch, origin)] = ("fail" | "die", attempts): the first
        # `attempts` deliveries fail/kill, later ones succeed (None = all).
        self.faults = faults
        self.retry_limit = retry_limit
        self.respawn_limit = respawn_limit
        self.rng = rng or random.Random(0)
        self.inflight = []
        self.next_launch = 0
        self.poisoned = False
        self.rr = 0
        self.metrics = {"retries": 0, "respawns": 0, "quarantined_cus": 0, "inflight_max": 0}
        self.retired_order = []
        self.errors = []
        # staging-buffer conservation ledger
        self.next_token = 0
        self.outstanding = set()
        self.lost_tokens = set()

    # -- staging pool -----------------------------------------------------
    def mint(self) -> int:
        self.next_token += 1
        self.outstanding.add(self.next_token)
        return self.next_token

    def give_back(self, token: int):
        assert token in self.outstanding, f"token {token} returned twice"
        self.outstanding.remove(token)

    # -- scheduling -------------------------------------------------------
    def live(self) -> list:
        return [w.cu for w in self.workers if not w.quarantined]

    def live_target(self):
        live = self.live()
        if not live:
            return None
        self.rr += 1
        return live[self.rr % len(live)]

    def worker_step(self) -> bool:
        """Run one random runnable worker job — the schedule randomness."""
        runnable = [w for w in self.workers if w.alive and not w.quarantined and w.queue]
        if not runnable:
            return False
        w = self.rng.choice(runnable)
        job = w.queue.pop(0)
        kind, k = self.faults.get((job["launch"], job["origin"]), (None, None))
        if kind == "die" and (k is None or job["attempt"] < k):
            self.lost_tokens.add(job["buf"])
            w.die(self)
            return True
        lid = job["launch"]
        l = next((x for x in self.inflight if x.id == lid), None)
        assert l is not None, "a worker job outlived its launch"
        observed = (self.bufs[l.a], self.bufs[l.b], self.bufs[l.c])
        err = kind == "fail" and (k is None or job["attempt"] < k)
        l.replies.append({
            "launch": lid,
            "origin": job["origin"],
            "attempt": job["attempt"],
            "buf": job["buf"],
            "err": err,
            "observed": observed,
            "value": None if err else tile_value(lid, job["origin"], observed),
        })
        return True

    # -- the ladder -------------------------------------------------------
    def submit_tile(self, l: Launch, origin: int, attempt: int, buf: int):
        """stream.rs ``submit_tile``: home slot, re-route around
        quarantine, respawn on dead send, poison only at zero survivors."""
        while True:
            home = l.slots[l.slot_of[origin]]
            w = self.workers[home]
            if w.quarantined:
                target = self.live_target()
                if target is None:
                    self.give_back(buf)
                    self.poisoned = True
                    raise NoSurvivors(l.id)
                w = self.workers[target]
            job = {"launch": l.id, "origin": origin, "attempt": attempt, "buf": buf}
            if w.submit(job):
                l.dispatches[origin] = (w.cu, w.incarnation, attempt)
                return
            incident = f"launch {l.id} tile {origin} attempt {attempt}: submit failed"
            if (w.respawn(incident, self.respawn_limit, self.metrics) == "quarantined"
                    and not self.live()):
                self.give_back(buf)
                self.poisoned = True
                raise NoSurvivors(l.id)

    def absorb(self, l: Launch) -> bool:
        """Drain the reply channel: dedup, retry-or-settle.  Returns
        whether anything progressed."""
        progressed = False
        while l.replies:
            r = l.replies.pop(0)
            progressed = True
            if r["launch"] != l.id or r["origin"] in l.settled:
                self.give_back(r["buf"])  # duplicate: recycle, drop
                continue
            if r["err"] and r["attempt"] < self.retry_limit:
                self.metrics["retries"] += 1
                n = l.error_retries.get(r["origin"], 0) + 1
                l.error_retries[r["origin"]] = n
                assert n <= self.retry_limit, "error retries must respect the budget"
                # the retry reuses the buffer the errored reply returned
                self.submit_tile(l, r["origin"], r["attempt"] + 1, r["buf"])
                continue
            l.settled[r["origin"]] = r
        return progressed

    def probe(self, l: Launch):
        """stream.rs ``probe_and_replay``: an unsettled origin whose latest
        dispatch stamp is no longer live died with its worker — respawn
        the worker if it is dead on the current stamp, then replay."""
        progressed = False
        for origin in range(TILES):
            if origin in l.settled:
                continue
            phys, inc, attempt = l.dispatches[origin]
            w = self.workers[phys]
            if w.quarantined or w.incarnation != inc:
                lost = True
            elif not w.alive:
                incident = f"launch {l.id} tile {origin} attempt {attempt}: no reply from dead worker"
                w.respawn(incident, self.respawn_limit, self.metrics)
                lost = True
            else:
                lost = False  # alive on the stamped incarnation: still queued
            if lost:
                self.metrics["retries"] += 1
                self.submit_tile(l, origin, attempt + 1, self.mint())
                progressed = True
        # The model's hang detector: a blocked leader must always find
        # either a runnable job or a provably-lost dispatch.
        assert progressed, f"launch {l.id}: probe found nothing lost while nothing can run"

    # -- leader API -------------------------------------------------------
    def check_live(self):
        if self.poisoned:
            raise Poisoned()

    def enqueue(self, a: int, b: int, c: int):
        self.check_live()
        # hazard scan: drain through the last in-flight writer of {a,b,c}
        last = None
        for i, l in enumerate(self.inflight):
            if l.c in (a, b, c):
                last = i
        if last is not None:
            for _ in range(last + 1):
                self.retire_one()
        live = self.live()
        if not live:
            self.poisoned = True
            raise NoSurvivors(self.next_launch)
        lid = self.next_launch
        self.next_launch += 1
        snap = (self.bufs[a], self.bufs[b], self.bufs[c])
        slots = list(live)  # degraded mode: one band slot per live CU
        l = Launch(lid, a, b, c, snap, slots)
        for origin in range(TILES):
            self.submit_tile(l, origin, 0, self.mint())
        self.inflight.append(l)
        self.metrics["inflight_max"] = max(self.metrics["inflight_max"], len(self.inflight))
        # random progress between enqueues: launches overlap in flight
        for _ in range(self.rng.randrange(0, TILES * 2)):
            if not self.worker_step():
                break

    def retire_one(self):
        l = self.inflight[0]
        while len(l.settled) < TILES:
            if self.absorb(l):
                continue
            if self.worker_step():
                continue
            self.probe(l)
        self.inflight.pop(0)
        for r in l.settled.values():
            self.give_back(r["buf"])
        self.retired_order.append(l.id)
        failed = [o for o, r in sorted(l.settled.items()) if r["err"]]
        if failed:
            self.errors.append(("LaunchFailed", l.id, len(failed)))
            return
        # read stability: every settled success observed the snapshot
        for o, r in l.settled.items():
            assert r["observed"] == l.snapshot, (
                f"launch {l.id} tile {o} read {r['observed']}, snapshot {l.snapshot}")
        vals = tuple(l.settled[o]["value"] for o in range(TILES))
        self.bufs[l.c] = writeback_value(self.bufs[l.c], vals)

    def wait(self):
        self.check_live()
        while self.inflight:
            self.retire_one()

    def check_conservation(self):
        assert self.outstanding == self.lost_tokens, (
            f"staging tokens leaked: out={self.outstanding - self.lost_tokens} "
            f"ghost={self.lost_tokens - self.outstanding}")


# ---------------------------------------------------------------------------
# Directed scenarios: one per rung of the ladder
# ---------------------------------------------------------------------------

def test_transient_fail_retries_to_bit_identical_success():
    gemms = [(0, 1, 2), (0, 1, 2)]  # a dependent chain through buffer 2
    faults = {(0, 3): ("fail", 2)}  # two failed deliveries, third succeeds
    s = StreamModel(cus=2, n_bufs=3, faults=faults, retry_limit=2)
    for g in gemms:
        s.enqueue(*g)
    s.wait()
    assert s.errors == []
    assert s.bufs == serial_reference(3, gemms)
    assert s.metrics["retries"] == 2
    assert s.metrics["respawns"] == 0
    s.check_conservation()


def test_exhausted_retry_budget_is_launch_failed_not_poison():
    faults = {(0, 0): ("fail", None)}  # every delivery fails
    s = StreamModel(cus=2, n_bufs=6, faults=faults, retry_limit=2)
    s.enqueue(0, 1, 2)
    s.wait()
    assert s.errors == [("LaunchFailed", 0, 1)]
    assert s.bufs[2] == 0, "a failed launch writes nothing"
    assert s.metrics["retries"] == 2, "redispatches stop at the budget"
    # the stream stays usable
    s.enqueue(3, 4, 5)
    s.wait()
    assert len(s.errors) == 1
    s.check_conservation()


def test_cu_death_respawns_and_completes_bit_identical():
    gemms = [(0, 1, 2), (3, 4, 5)]  # disjoint: both pipeline in flight
    faults = {(0, 1): ("die", 1)}  # first delivery of L0 tile 1 kills its CU
    s = StreamModel(cus=2, n_bufs=6, faults=faults, retry_limit=2, respawn_limit=1,
                    rng=random.Random(7))
    for g in gemms:
        s.enqueue(*g)
    assert s.metrics["inflight_max"] >= 2
    s.wait()
    assert s.errors == []
    assert s.bufs == serial_reference(6, gemms)
    assert s.metrics["respawns"] == 1
    assert s.metrics["quarantined_cus"] == 0
    assert any(w.respawns == 1 for w in s.workers), "the ledger records the respawn"
    s.check_conservation()


def test_exhausted_respawn_budget_quarantines_and_degrades():
    gemms = [(0, 1, 2), (2, 1, 3)]
    faults = {(0, 2): ("die", 1)}
    s = StreamModel(cus=2, n_bufs=4, faults=faults, respawn_limit=0, rng=random.Random(3))
    for g in gemms:
        s.enqueue(*g)
    s.wait()
    assert s.errors == []
    assert s.bufs == serial_reference(4, gemms)
    assert s.metrics["quarantined_cus"] == 1
    assert s.metrics["respawns"] == 0
    dead = [w for w in s.workers if w.quarantined]
    assert len(dead) == 1 and dead[0].last_incident is not None
    # degraded mode: exactly one survivor remains schedulable
    assert len(s.live()) == 1
    assert s.retired_order == [0, 1]
    s.check_conservation()


def test_zero_survivors_is_typed_then_poisoned():
    faults = {(0, o): ("die", None) for o in range(TILES)}  # every tile kills
    s = StreamModel(cus=2, n_bufs=3, faults=faults, respawn_limit=1, rng=random.Random(11))
    s.enqueue(0, 1, 2)
    with pytest.raises(NoSurvivors):
        s.wait()
    assert s.poisoned
    with pytest.raises(Poisoned):
        s.enqueue(0, 1, 2)
    with pytest.raises(Poisoned):
        s.wait()
    assert all(w.quarantined for w in s.workers)
    s.check_conservation()


# ---------------------------------------------------------------------------
# Randomized schedules: the protocol under fuzzed interleavings
# ---------------------------------------------------------------------------

def random_scenario(rng: random.Random):
    """A random op list plus faults guaranteed to stay inside budgets:
    transient fails within retry_limit, each die-fault kills exactly once
    (first delivery), respawn budget sized to the death count."""
    n_bufs = rng.randrange(4, 8)
    n_launches = rng.randrange(2, 6)
    gemms = []
    for _ in range(n_launches):
        a, b = rng.randrange(n_bufs), rng.randrange(n_bufs)
        c = rng.randrange(n_bufs)
        gemms.append((a, b, c))
    retry_limit = rng.randrange(1, 4)
    faults = {}
    deaths = 0
    for lid in range(n_launches):
        for origin in range(TILES):
            roll = rng.random()
            if roll < 0.08:
                faults[(lid, origin)] = ("fail", rng.randrange(1, retry_limit + 1))
            elif roll < 0.12:
                faults[(lid, origin)] = ("die", 1)
                deaths += 1
    return n_bufs, gemms, retry_limit, faults, deaths


@pytest.mark.parametrize("seed", range(40))
def test_randomized_schedules_heal_to_bit_identical(seed):
    rng = random.Random(seed * 7919 + 13)
    n_bufs, gemms, retry_limit, faults, deaths = random_scenario(rng)
    s = StreamModel(cus=rng.randrange(1, 4), n_bufs=n_bufs, faults=faults,
                    retry_limit=retry_limit, respawn_limit=deaths, rng=rng)
    for g in gemms:
        s.enqueue(*g)
    s.wait()
    assert s.errors == [], f"budgeted faults must heal silently: {s.errors}"
    assert s.bufs == serial_reference(n_bufs, gemms), (
        f"seed {seed}: healed run diverged from the serial reference")
    assert s.retired_order == sorted(s.retired_order), "retirement must stay FIFO"
    assert s.metrics["respawns"] + s.metrics["quarantined_cus"] <= deaths
    s.check_conservation()


@pytest.mark.parametrize("seed", range(20))
def test_randomized_quarantine_degrades_but_stays_bit_identical(seed):
    """Zero respawn budget: every death quarantines, yet as long as one CU
    survives, every launch must still complete bit-identically."""
    rng = random.Random(seed * 104729 + 7)
    cus = rng.randrange(2, 5)
    n_bufs, gemms, retry_limit, faults, _ = random_scenario(rng)
    # keep at least one survivor: strictly fewer die-faults than CUs
    dies = [key for key, (kind, _) in faults.items() if kind == "die"]
    for key in dies[max(0, cus - 1):]:
        del faults[key]
    s = StreamModel(cus=cus, n_bufs=n_bufs, faults=faults,
                    retry_limit=retry_limit, respawn_limit=0, rng=rng)
    for g in gemms:
        s.enqueue(*g)
    s.wait()
    assert s.errors == []
    assert s.bufs == serial_reference(n_bufs, gemms)
    assert s.metrics["quarantined_cus"] <= max(0, cus - 1)
    assert s.live(), "at least one CU must survive by construction"
    assert s.retired_order == sorted(s.retired_order)
    s.check_conservation()
