//! Ablation studies over the design choices DESIGN.md calls out.
//!
//!  A1 — tile size: the paper fixes 32x32 "balancing useless work on sizes
//!       that are not a multiple of the tile size with the reduction in
//!       required memory bandwidth" (§V-C).  Sweep T and watch the design
//!       flip from memory-bound to compute-bound as the arithmetic
//!       intensity T^2/2T = T/2 crosses the DDR roofline.
//!  A2 — placement policy: Fig. 4's round-robin across banks vs packing
//!       all CUs onto one bank (bandwidth collapse).
//!  A3 — multiplier algorithm at higher precisions: schoolbook vs
//!       Karatsuba vs Toom-3 (the paper's §II-A lineage), measured.

use apfp::bench_util::{bench, fmt_duration, Table};
use apfp::bigint;
use apfp::hwmodel::DesignPoint;
use apfp::sim::{dram, gemm_sim};
use apfp::testkit::Rng;

fn main() {
    println!("== A1: GEMM tile-size ablation (8 CUs, 512-bit, n = 8192) ==\n");
    let d = DesignPoint::gemm_512(8);
    let mut t = Table::new(&["tile", "arith. intensity", "compute_s", "mem_s", "bound", "MMAC/s"]);
    for tile in [4usize, 8, 16, 32, 64, 128] {
        let pt = gemm_sim::simulate(&d, 8192, tile, tile);
        t.row(&[
            format!("{tile}x{tile}"),
            format!("{:.1}", tile as f64 / 2.0),
            format!("{:.2}", pt.compute_s),
            format!("{:.2}", pt.mem_s),
            if pt.mem_s > pt.compute_s { "memory".into() } else { "compute".to_string() },
            format!("{:.0}", pt.mmacs / 1e6),
        ]);
    }
    println!("{}", t.render());
    let t4 = gemm_sim::simulate(&d, 8192, 4, 4);
    let t32 = gemm_sim::simulate(&d, 8192, 32, 32);
    assert!(t4.mem_s > t4.compute_s, "4x4 must be memory-bound");
    assert!(t32.compute_s > t32.mem_s, "32x32 must be compute-bound (paper's choice)");

    println!("\n== A2: placement policy (8 CUs) ==\n");
    // Fig. 4 round-robin: 2 CUs per bank -> 9.6 GB/s each.
    let rr = dram::per_cu_bandwidth(8);
    // all-on-one-bank straw man: 8 CUs share 19.2 GB/s
    let packed = apfp::hwmodel::u250::DDR_BANK_BW / 8.0;
    println!("  round-robin (Fig. 4): {:.1} GB/s per CU", rr / 1e9);
    println!("  single-bank packing:  {:.1} GB/s per CU ({}x worse)", packed / 1e9, (rr / packed) as u64);
    assert!(rr >= 4.0 * packed);

    println!("\n== A3: multiplier algorithm vs precision (measured, this host) ==\n");
    let mut rng = Rng::from_seed(0xA31A);
    let mut t = Table::new(&["bits", "schoolbook", "karatsuba(8)", "toom-3"]);
    for limbs in [16usize, 32, 64, 128, 256] {
        let a = rng.limbs(limbs);
        let b = rng.limbs(limbs);
        let mut out = vec![0u64; 2 * limbs];
        let rs = bench("s", 50, 400, || {
            bigint::mul_schoolbook(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        let rk = bench("k", 50, 400, || {
            bigint::mul_karatsuba(&a, &b, &mut out, 8);
            std::hint::black_box(&out);
        });
        let rt = bench("t", 50, 400, || {
            bigint::mul_toom3(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        t.row(&[
            (limbs * 64).to_string(),
            fmt_duration(rs.median_s()),
            fmt_duration(rk.median_s()),
            fmt_duration(rt.median_s()),
        ]);
    }
    println!("{}", t.render());
    println!("\n(the paper stops at Karatsuba: at its 448/960-bit operands the");
    println!(" schoolbook/Karatsuba crossover has not been reached, matching GMP)");
}
