//! Fig. 3 regeneration: the (MULT_BASE_BITS x ADD_BASE_BITS) design-space
//! sweep of the 512-bit multiplier — frequency + CLB from the hardware
//! model, with Pareto-efficient configurations marked as in the paper.
//!
//! As the *measured* counterpart of the sweep, the software Karatsuba's
//! bottom-out threshold (the same knob, software edition) is benchmarked
//! on this host across base widths.

use apfp::bench_util::{bench, fmt_rate, Table};
use apfp::bigint;
use apfp::hwmodel::{resources, DesignPoint};
use apfp::testkit::Rng;

fn main() {
    println!("== Fig. 3: 512-bit multiplier design-space sweep (modeled U250) ==\n");
    let mult_bases = [18u32, 36, 72, 144, 288];
    let add_bases = [32u32, 64, 128, 256, 512, 1024];

    // collect all points, then mark the Pareto frontier (max freq, min CLB)
    let mut points = Vec::new();
    for &mb in &mult_bases {
        for &ab in &add_bases {
            let d = DesignPoint { bits: 512, compute_units: 1, mult_base_bits: mb, add_base_bits: ab, gemm: false };
            let s = d.synthesize();
            let clbs = resources::fig3_multiplier_clbs(448, mb, ab);
            points.push((mb, ab, s.frequency_mhz, clbs, s.failure));
        }
    }
    let pareto: Vec<bool> = points
        .iter()
        .map(|p| {
            p.4.is_none()
                && !points.iter().any(|q| {
                    q.4.is_none() && q.2 >= p.2 && q.3 <= p.3 && (q.2 > p.2 || q.3 < p.3)
                })
        })
        .collect();

    let mut t = Table::new(&["mult_base", "add_base", "freq [MHz]", "CLBs", "status"]);
    for (p, is_pareto) in points.iter().zip(&pareto) {
        let status = match (&p.4, is_pareto) {
            (Some(_), _) => "FAILS SYNTHESIS".to_string(),
            (None, true) => "PARETO".to_string(),
            (None, false) => "ok".to_string(),
        };
        t.row(&[p.0.to_string(), p.1.to_string(), format!("{:.0}", p.2), p.3.to_string(), status]);
    }
    println!("{}", t.render());

    // paper's qualitative findings, asserted
    let best = points.iter().zip(&pareto).filter(|(_, &p)| p).map(|(p, _)| p.0).collect::<Vec<_>>();
    assert!(best.contains(&72) || best.contains(&36), "paper: 72/36-bit bottom-out is Pareto");
    assert!(points.iter().filter(|p| p.0 == 288).all(|p| p.4.is_some()), "paper: 288 fails synthesis");

    println!("\n== measured software analog: Karatsuba bottom-out sweep (this host) ==\n");
    let mut rng = Rng::from_seed(0x51EE9);
    let n = 64; // 4096-bit operands: deep enough recursion to matter
    let a = rng.limbs(n);
    let b = rng.limbs(n);
    let mut t = Table::new(&["base [limbs]", "base [bits]", "time/mul", "rate"]);
    for base in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut out = vec![0u64; 2 * n];
        let r = bench(&format!("kara base {base}"), 20, 200, || {
            bigint::mul_karatsuba(&a, &b, &mut out, base);
            std::hint::black_box(&out);
        });
        t.row(&[
            base.to_string(),
            (base * 64).to_string(),
            apfp::bench_util::fmt_duration(r.median_s()),
            fmt_rate(r.throughput()),
        ]);
    }
    println!("{}", t.render());

    // the mul_auto crossover itself: straight Comba vs the recursion at the
    // widths around the threshold, so a host can pick its own override
    println!("\n== Comba vs Karatsuba crossover (mul_auto threshold) ==\n");
    let mut t = Table::new(&["limbs", "comba", "karatsuba", "kara speedup"]);
    for limbs in [16usize, 24, 32, 40, 48, 64] {
        let a = rng.limbs(limbs);
        let b = rng.limbs(limbs);
        let mut out = vec![0u64; 2 * limbs];
        let rc = bench(&format!("comba {limbs}"), 50, 500, || {
            bigint::mul_comba(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        let rk = bench(&format!("kara {limbs}"), 50, 500, || {
            bigint::mul_karatsuba(&a, &b, &mut out, 8);
            std::hint::black_box(&out);
        });
        t.row(&[
            limbs.to_string(),
            apfp::bench_util::fmt_duration(rc.median_s()),
            apfp::bench_util::fmt_duration(rk.median_s()),
            format!("{:.2}x", rk.speedup_vs(&rc)),
        ]);
    }
    println!("{}", t.render());
    println!(
        "\nactive mul_auto threshold: {} limbs (default {}; override with \
         APFP_KARATSUBA_THRESHOLD)",
        bigint::karatsuba_threshold(),
        bigint::KARATSUBA_THRESHOLD,
    );
}
