//! Fig. 5 regeneration: 512-bit GEMM MMAC/s vs matrix size, FPGA compute
//! units (modeled U250) against Elemental/MPFR node counts (paper-reported
//! model), plus a *measured* host GEMM baseline for small sizes.

use apfp::baseline;
use apfp::bench_util::{fmt_rate, Table};
use apfp::coordinator::Matrix;
use apfp::hwmodel::DesignPoint;
use apfp::sim::{cpu_ref, gemm_sim};

fn main() {
    println!("== Fig. 5: C += A*B, 512-bit numbers (448-bit mantissa) ==\n");
    let sizes = [256usize, 512, 1024, 2048, 4096, 8192, 16384];
    let cu_counts = [1usize, 2, 4, 8];

    let mut header: Vec<String> = vec!["n".into()];
    header.extend(cu_counts.iter().map(|c| format!("{c} CU [MMAC/s]")));
    header.extend([1, 2, 4, 8].iter().map(|n| format!("{n} node [MMAC/s]")));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for &n in &sizes {
        let mut row = vec![n.to_string()];
        for &cus in &cu_counts {
            let pt = gemm_sim::simulate(&DesignPoint::gemm_512(cus), n, 32, 32);
            row.push(format!("{:.0}", pt.mmacs / 1e6));
        }
        for nodes in [1usize, 2, 4, 8] {
            row.push(format!("{:.0}", cpu_ref::gemm_mmacs(512, nodes, n) / 1e6));
        }
        t.row(&row);
    }
    println!("{}", t.render());

    // paper's headline claims, asserted on the model output
    let fpga8 = gemm_sim::peak(&DesignPoint::gemm_512(8), 32).mmacs;
    let nodes8 = cpu_ref::gemm_mmacs(512, 8, 16384);
    assert!(fpga8 > nodes8, "8-CU FPGA must outperform the 8-node cluster");
    let cores = fpga8 / (cpu_ref::gemm_mmacs(512, 1, 16384) / 36.0);
    println!("\n8-CU peak = {:.0} MMAC/s  (~{cores:.0}x CPU cores; paper: 2002 MMAC/s, >375x)", fpga8 / 1e6);

    // measured host baseline at a feasible size (the dashed-line analog)
    let n = 48;
    let a = Matrix::random(n, n, 448, 1, 40);
    let b = Matrix::random(n, n, 448, 2, 40);
    let c = Matrix::zeros(n, n, 448);
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let t0 = std::time::Instant::now();
    let out = baseline::gemm_threaded(&a, &b, &c, threads);
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(&out);
    println!(
        "measured host GEMM ({threads} threads, n={n}): {}",
        fmt_rate((n * n * n) as f64 / dt)
    );
}
