//! Fig. 6 regeneration: 1024-bit GEMM (960-bit mantissa), single compute
//! unit (the paper's preliminary monolithic design, downclocked by
//! congestion), against the 36-core Xeon node.

use apfp::bench_util::Table;
use apfp::hwmodel::DesignPoint;
use apfp::sim::{cpu_ref, gemm_sim};

fn main() {
    println!("== Fig. 6: C += A*B, 1024-bit numbers (960-bit mantissa) ==\n");
    let d = DesignPoint::gemm_1024(1);
    let s = d.synthesize();
    println!(
        "design: 1 CU @ {:.0} MHz, {:.1}% CLBs (paper: 212 MHz, 29.8% — congestion-downclocked)\n",
        s.frequency_mhz,
        s.clb_frac * 100.0
    );
    let mut t = Table::new(&["n", "FPGA 1 CU [MMAC/s]", "1 node [MMAC/s]", "2 nodes", "4 nodes"]);
    for n in [256usize, 512, 1024, 2048, 4096, 8192] {
        let pt = gemm_sim::simulate(&d, n, 32, 32);
        t.row(&[
            n.to_string(),
            format!("{:.0}", pt.mmacs / 1e6),
            format!("{:.0}", cpu_ref::gemm_mmacs(1024, 1, n) / 1e6),
            format!("{:.0}", cpu_ref::gemm_mmacs(1024, 2, n) / 1e6),
            format!("{:.0}", cpu_ref::gemm_mmacs(1024, 4, n) / 1e6),
        ]);
    }
    println!("{}", t.render());
    let peak = gemm_sim::peak(&d, 32).mmacs / 1e6;
    let node = cpu_ref::gemm_mmacs(1024, 1, 8192) / 1e6;
    println!("\npeak {peak:.0} MMAC/s vs 36-core node {node:.0} MMAC/s (paper: 158 vs ~70)");
    assert!(peak > node, "paper: the single 1024-bit CU exceeds the Xeon node");
}
