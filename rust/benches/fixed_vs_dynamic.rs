//! Fixed-width fast path vs the dynamic arena reference (ISSUE 8).
//!
//! Two kinds of evidence, deliberately separated:
//!
//! * **Structural counters (hard asserts, always on):** the dynamic
//!   `mac_into` takes arena slices per call (`Scratch::arena_ops`
//!   counts every `take_*`), while the fixed path owns its operands as
//!   `[u64; LIMBS]` stack values and performs **zero** arena ops — at
//!   least one fewer pointer chase per MAC, independent of machine noise.
//! * **Wall clock (gated):** `gate_speedup` warns when the fixed path
//!   falls below the floor and only fails under `APFP_BENCH_STRICT=1`,
//!   so CI boxes with noisy clocks don't flake.

use apfp::baseline::{gemm_fixed, gemm_into, pack_b_fixed, GemmScratch};
use apfp::bench_util::{bench, fmt_duration, fmt_rate, Table};
use apfp::bigint::Scratch;
use apfp::coordinator::Matrix;
use apfp::softfloat::ApFloatN;
use apfp::testkit::{rand_ap, Rng};

fn mac_section<const L: usize>(prec: u32, rng: &mut Rng, t: &mut Table) {
    let a = rand_ap(rng, prec, 40);
    let b = rand_ap(rng, prec, 40);
    let mut acc = rand_ap(rng, prec, 40);
    let af = ApFloatN::<L>::from_ap(&a);
    let bf = ApFloatN::<L>::from_ap(&b);
    let mut accf = ApFloatN::<L>::from_ap(&acc);

    // --- structural: arena ops per MAC, counted not timed ---------------
    let mut scratch = Scratch::new();
    acc.mac_into(&a, &b, &mut scratch); // warm the arena
    scratch.reset_arena_ops();
    let n = 1000u64;
    for _ in 0..n {
        acc.mac_into(&a, &b, &mut scratch);
        if acc.exp() > 1 << 30 {
            acc.assign(&a);
        }
    }
    let dyn_ops_per_mac = scratch.arena_ops() / n;
    scratch.reset_arena_ops();
    for _ in 0..n {
        accf.mac_into(&af, &bf);
        if accf.exp() > 1 << 30 {
            accf = af;
        }
    }
    std::hint::black_box(&accf);
    let fixed_ops_per_mac = scratch.arena_ops() / n; // fixed path never sees the arena
    assert_eq!(
        fixed_ops_per_mac, 0,
        "fixed mac must perform zero arena ops at {prec} bits"
    );
    assert!(
        dyn_ops_per_mac >= fixed_ops_per_mac + 1,
        "dynamic mac must cost at least one more arena op per MAC than fixed \
         at {prec} bits (dynamic {dyn_ops_per_mac}, fixed {fixed_ops_per_mac})"
    );
    t.row(&[
        format!("arena ops/MAC ({prec}b)"),
        format!("dynamic {dyn_ops_per_mac}"),
        format!("fixed {fixed_ops_per_mac}"),
    ]);

    // --- wall clock: warm dynamic mac_into vs fixed mac_into ------------
    let r_dyn = bench(&format!("dynamic mac_into {prec}"), 1000, 20000, || {
        acc.mac_into(&a, &b, &mut scratch);
        if acc.exp() > 1 << 30 {
            acc.assign(&a);
        }
    });
    let r_fixed = bench(&format!("fixed mac_into {prec}"), 1000, 20000, || {
        accf.mac_into(&af, &bf);
        if accf.exp() > 1 << 30 {
            accf = af;
        }
    });
    std::hint::black_box((&acc, &accf));
    t.row(&[
        format!("mac_into dynamic ({prec}b)"),
        fmt_duration(r_dyn.median_s()),
        fmt_rate(r_dyn.throughput()),
    ]);
    t.row(&[
        format!("mac_into fixed ({prec}b)"),
        fmt_duration(r_fixed.median_s()),
        fmt_rate(r_fixed.throughput()),
    ]);
    r_fixed.gate_speedup(&r_dyn, 1.0, &format!("fixed vs dynamic mac at {prec} bits"));
}

fn gemm_section<const L: usize>(prec: u32, rng: &mut Rng, t: &mut Table) {
    let (n, k, m) = (12usize, 12, 12);
    let seed = rng.next_u64();
    let a = Matrix::random(n, k, prec, seed, 20);
    let b = Matrix::random(k, m, prec, seed ^ 1, 20);
    let c = Matrix::random(n, m, prec, seed ^ 2, 20);

    let mut ws = GemmScratch::new();
    let mut out = c.clone();
    gemm_into(&a, &b, &mut out, &mut ws); // warm panel + arena
    let r_dyn = bench(&format!("gemm_into {prec}"), 3, 40, || {
        gemm_into(&a, &b, &mut out, &mut ws);
    });
    std::hint::black_box(&out);

    let mut af: Vec<ApFloatN<L>> = Vec::new();
    for i in 0..n {
        for kk in 0..k {
            af.push(ApFloatN::from_ap(a.get(i, kk)));
        }
    }
    let mut bt = Vec::new();
    pack_b_fixed::<L>(&b, &mut bt);
    let mut cf: Vec<ApFloatN<L>> = Vec::new();
    for i in 0..n {
        for j in 0..m {
            cf.push(ApFloatN::from_ap(c.get(i, j)));
        }
    }
    let r_fixed = bench(&format!("gemm_fixed {prec}"), 3, 40, || {
        gemm_fixed(&af, &bt, &mut cf, n, k, m);
    });
    std::hint::black_box(&cf);

    let macs = (n * k * m) as f64;
    t.row(&[
        format!("gemm dynamic {n}x{k}x{m} ({prec}b)"),
        fmt_duration(r_dyn.median_s()),
        fmt_rate(r_dyn.throughput() * macs),
    ]);
    t.row(&[
        format!("gemm fixed {n}x{k}x{m} ({prec}b)"),
        fmt_duration(r_fixed.median_s()),
        fmt_rate(r_fixed.throughput() * macs),
    ]);
    r_fixed.gate_speedup(&r_dyn, 1.0, &format!("fixed vs dynamic gemm tile at {prec} bits"));
}

fn main() {
    let mut rng = Rng::from_seed(0xF1BD);
    let mut t = Table::new(&["kernel", "median", "rate"]);
    mac_section::<7>(448, &mut rng, &mut t);
    mac_section::<15>(960, &mut rng, &mut t);
    gemm_section::<7>(448, &mut rng, &mut t);
    gemm_section::<15>(960, &mut rng, &mut t);
    println!("== fixed-width fast path vs dynamic arena ==\n\n{}", t.render());
}
