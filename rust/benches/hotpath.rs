//! Hot-path microbenchmarks — the profiling targets of the §Perf pass
//! (EXPERIMENTS.md).  Everything the GEMM datapath touches per tile is
//! timed in isolation: softfloat ops (baseline arithmetic), bigint
//! multiply kernels, plane packing, tile extraction.

use apfp::bench_util::{bench, fmt_rate, Table};
use apfp::bigint;
use apfp::coordinator::Matrix;
use apfp::pack::PlaneBatch;
use apfp::softfloat::ApFloat;
use apfp::testkit::{rand_ap, Rng};

fn main() {
    let mut rng = Rng::from_seed(7);
    let mut t = Table::new(&["op", "median", "rate"]);

    for prec in [448u32, 960] {
        let a = rand_ap(&mut rng, prec, 40);
        let b = rand_ap(&mut rng, prec, 40);
        let mut acc = rand_ap(&mut rng, prec, 40);
        let r = bench(&format!("softfloat mul {prec}"), 1000, 20000, || {
            std::hint::black_box(a.mul(&b));
        });
        t.row(&[format!("softfloat mul ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        // the allocation-free arena path (ISSUE 1 tentpole)
        let mut scratch = apfp::bigint::Scratch::new();
        let mut sink = a.mul(&b);
        let r = bench(&format!("softfloat mul_into {prec}"), 1000, 20000, || {
            a.mul_into(&b, &mut sink, &mut scratch);
        });
        std::hint::black_box(&sink);
        t.row(&[format!("softfloat mul_into ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        let r = bench(&format!("softfloat add {prec}"), 1000, 20000, || {
            std::hint::black_box(a.add(&b));
        });
        t.row(&[format!("softfloat add ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        // the allocation-free arena adder (ISSUE 2 tentpole)
        let r = bench(&format!("softfloat add_into {prec}"), 1000, 20000, || {
            a.add_into(&b, &mut sink, &mut scratch);
        });
        std::hint::black_box(&sink);
        t.row(&[format!("softfloat add_into ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        // `acc = acc.mac(..)` is the clone+alloc accumulation shape the old
        // GEMM inner loop ran: each iteration drops the previous value and
        // allocates a fresh result.
        let r_mac = bench(&format!("softfloat mac {prec}"), 1000, 20000, || {
            acc = acc.mac(&a, &b);
            if acc.exp() > 1 << 30 {
                acc = a.clone();
            }
        });
        t.row(&[format!("softfloat mac ({prec}b)"), apfp::bench_util::fmt_duration(r_mac.median_s()), fmt_rate(r_mac.throughput())]);
        // mac_into: the zero-alloc accumulator the GEMM paths now run
        // (ISSUE 2 acceptance: must not be slower than the alloc path)
        let r_mac_into = bench(&format!("softfloat mac_into {prec}"), 1000, 20000, || {
            acc.mac_into(&a, &b, &mut scratch);
            if acc.exp() > 1 << 30 {
                acc.assign(&a);
            }
        });
        std::hint::black_box(&acc);
        t.row(&[format!("softfloat mac_into ({prec}b)"), apfp::bench_util::fmt_duration(r_mac_into.median_s()), fmt_rate(r_mac_into.throughput())]);
        r_mac_into.gate_speedup(&r_mac, 1.0, &format!("mac_into vs alloc mac at {prec} bits"));
    }

    // bigint multiply kernels at the two paper widths
    for limbs in [7usize, 15, 32, 64] {
        let a = rng.limbs(limbs);
        let b = rng.limbs(limbs);
        let mut out = vec![0u64; 2 * limbs];
        let r = bench(&format!("schoolbook {limbs}"), 500, 5000, || {
            bigint::mul_schoolbook(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        t.row(&[format!("schoolbook mul ({} bits)", limbs * 64), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        if limbs >= 16 {
            let r = bench(&format!("karatsuba {limbs}"), 500, 5000, || {
                bigint::mul_karatsuba(&a, &b, &mut out, 8);
                std::hint::black_box(&out);
            });
            t.row(&[format!("karatsuba mul ({} bits)", limbs * 64), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        }
    }

    // Comba columnwise kernel vs row-wise schoolbook at the paper widths —
    // the bottom-out kernel swap must not regress (ISSUE 1 acceptance).
    for limbs in [7usize, 15] {
        let a = rng.limbs(limbs);
        let b = rng.limbs(limbs);
        let mut out = vec![0u64; 2 * limbs];
        let rs = bench(&format!("row schoolbook {limbs}"), 2000, 20000, || {
            bigint::mul_schoolbook(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        let rc = bench(&format!("comba {limbs}"), 2000, 20000, || {
            bigint::mul_comba(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        t.row(&[format!("comba mul ({} bits)", limbs * 64), apfp::bench_util::fmt_duration(rc.median_s()), fmt_rate(rc.throughput())]);
        rc.gate_speedup(&rs, 0.8, &format!("comba vs schoolbook at {} bits", limbs * 64));
    }

    // marshaling: plane pack/unpack and tile extraction
    let vals: Vec<ApFloat> = (0..256).map(|_| rand_ap(&mut rng, 448, 40)).collect();
    let r = bench("plane pack 256", 50, 2000, || {
        std::hint::black_box(PlaneBatch::from_slice(&vals, 448));
    });
    t.row(&["plane pack (256 values)".into(), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput() * 256.0)]);
    let planes = PlaneBatch::from_slice(&vals, 448);
    let r = bench("plane unpack 256", 50, 2000, || {
        std::hint::black_box(planes.to_vec());
    });
    t.row(&["plane unpack (256 values)".into(), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput() * 256.0)]);

    let m = Matrix::random(64, 64, 448, 3, 40);
    let r = bench("tile extract 16x16", 50, 2000, || {
        std::hint::black_box(m.extract_tile(8, 8, 16, 16));
    });
    t.row(&["tile extract (16x16)".into(), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);

    println!("== hot-path microbenchmarks ==\n\n{}", t.render());
}
