//! Hot-path microbenchmarks — the profiling targets of the §Perf pass
//! (EXPERIMENTS.md).  Everything the GEMM datapath touches per tile is
//! timed in isolation: softfloat ops (baseline arithmetic), bigint
//! multiply kernels, plane packing, tile extraction.

use apfp::bench_util::{bench, fmt_rate, Table};
use apfp::bigint;
use apfp::coordinator::Matrix;
use apfp::pack::PlaneBatch;
use apfp::softfloat::ApFloat;
use apfp::testkit::Rng;

fn rand_ap(rng: &mut Rng, prec: u32) -> ApFloat {
    let n = (prec / 64) as usize;
    let mut mant = rng.limbs(n);
    mant[n - 1] |= 1 << 63;
    ApFloat::from_parts(rng.bool(), rng.range_i64(-40, 40), mant, prec)
}

fn main() {
    let mut rng = Rng::from_seed(7);
    let mut t = Table::new(&["op", "median", "rate"]);

    for prec in [448u32, 960] {
        let a = rand_ap(&mut rng, prec);
        let b = rand_ap(&mut rng, prec);
        let mut acc = rand_ap(&mut rng, prec);
        let r = bench(&format!("softfloat mul {prec}"), 1000, 20000, || {
            std::hint::black_box(a.mul(&b));
        });
        t.row(&[format!("softfloat mul ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        let r = bench(&format!("softfloat add {prec}"), 1000, 20000, || {
            std::hint::black_box(a.add(&b));
        });
        t.row(&[format!("softfloat add ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        let r = bench(&format!("softfloat mac {prec}"), 1000, 20000, || {
            acc = acc.mac(&a, &b);
            if acc.exp() > 1 << 30 {
                acc = a.clone();
            }
        });
        t.row(&[format!("softfloat mac ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
    }

    // bigint multiply kernels at the two paper widths
    for limbs in [7usize, 15, 32, 64] {
        let a = rng.limbs(limbs);
        let b = rng.limbs(limbs);
        let mut out = vec![0u64; 2 * limbs];
        let r = bench(&format!("schoolbook {limbs}"), 500, 5000, || {
            bigint::mul_schoolbook(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        t.row(&[format!("schoolbook mul ({} bits)", limbs * 64), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        if limbs >= 16 {
            let r = bench(&format!("karatsuba {limbs}"), 500, 5000, || {
                bigint::mul_karatsuba(&a, &b, &mut out, 8);
                std::hint::black_box(&out);
            });
            t.row(&[format!("karatsuba mul ({} bits)", limbs * 64), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        }
    }

    // marshaling: plane pack/unpack and tile extraction
    let vals: Vec<ApFloat> = (0..256).map(|_| rand_ap(&mut rng, 448)).collect();
    let r = bench("plane pack 256", 50, 2000, || {
        std::hint::black_box(PlaneBatch::from_slice(&vals, 448));
    });
    t.row(&["plane pack (256 values)".into(), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput() * 256.0)]);
    let planes = PlaneBatch::from_slice(&vals, 448);
    let r = bench("plane unpack 256", 50, 2000, || {
        std::hint::black_box(planes.to_vec());
    });
    t.row(&["plane unpack (256 values)".into(), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput() * 256.0)]);

    let m = Matrix::random(64, 64, 448, 3, 40);
    let r = bench("tile extract 16x16", 50, 2000, || {
        std::hint::black_box(m.extract_tile(8, 8, 16, 16));
    });
    t.row(&["tile extract (16x16)".into(), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);

    println!("== hot-path microbenchmarks ==\n\n{}", t.render());
}
