//! Hot-path microbenchmarks — the profiling targets of the §Perf pass
//! (EXPERIMENTS.md).  Everything the GEMM datapath touches per tile is
//! timed in isolation: softfloat ops (baseline arithmetic), bigint
//! multiply kernels, plane packing, tile extraction.

use apfp::bench_util::{bench, fmt_rate, Table};
use apfp::bigint;
use apfp::coordinator::Matrix;
use apfp::pack::PlaneBatch;
use apfp::softfloat::ApFloat;
use apfp::testkit::{rand_ap, Rng};

fn main() {
    let mut rng = Rng::from_seed(7);
    let mut t = Table::new(&["op", "median", "rate"]);

    for prec in [448u32, 960] {
        let a = rand_ap(&mut rng, prec, 40);
        let b = rand_ap(&mut rng, prec, 40);
        let mut acc = rand_ap(&mut rng, prec, 40);
        let r = bench(&format!("softfloat mul {prec}"), 1000, 20000, || {
            std::hint::black_box(a.mul(&b));
        });
        t.row(&[format!("softfloat mul ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        // the allocation-free arena path (ISSUE 1 tentpole)
        let mut scratch = apfp::bigint::MulScratch::new();
        let mut sink = a.mul(&b);
        let r = bench(&format!("softfloat mul_into {prec}"), 1000, 20000, || {
            a.mul_into(&b, &mut sink, &mut scratch);
        });
        std::hint::black_box(&sink);
        t.row(&[format!("softfloat mul_into ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        let r = bench(&format!("softfloat add {prec}"), 1000, 20000, || {
            std::hint::black_box(a.add(&b));
        });
        t.row(&[format!("softfloat add ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        let r = bench(&format!("softfloat mac {prec}"), 1000, 20000, || {
            acc = acc.mac(&a, &b);
            if acc.exp() > 1 << 30 {
                acc = a.clone();
            }
        });
        t.row(&[format!("softfloat mac ({prec}b)"), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
    }

    // bigint multiply kernels at the two paper widths
    for limbs in [7usize, 15, 32, 64] {
        let a = rng.limbs(limbs);
        let b = rng.limbs(limbs);
        let mut out = vec![0u64; 2 * limbs];
        let r = bench(&format!("schoolbook {limbs}"), 500, 5000, || {
            bigint::mul_schoolbook(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        t.row(&[format!("schoolbook mul ({} bits)", limbs * 64), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        if limbs >= 16 {
            let r = bench(&format!("karatsuba {limbs}"), 500, 5000, || {
                bigint::mul_karatsuba(&a, &b, &mut out, 8);
                std::hint::black_box(&out);
            });
            t.row(&[format!("karatsuba mul ({} bits)", limbs * 64), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);
        }
    }

    // Comba columnwise kernel vs row-wise schoolbook at the paper widths —
    // the bottom-out kernel swap must not regress (ISSUE 1 acceptance).
    for limbs in [7usize, 15] {
        let a = rng.limbs(limbs);
        let b = rng.limbs(limbs);
        let mut out = vec![0u64; 2 * limbs];
        let rs = bench(&format!("row schoolbook {limbs}"), 2000, 20000, || {
            bigint::mul_schoolbook(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        let rc = bench(&format!("comba {limbs}"), 2000, 20000, || {
            bigint::mul_comba(&a, &b, &mut out);
            std::hint::black_box(&out);
        });
        t.row(&[format!("comba mul ({} bits)", limbs * 64), apfp::bench_util::fmt_duration(rc.median_s()), fmt_rate(rc.throughput())]);
        let speedup = rc.speedup_vs(&rs);
        println!("comba vs schoolbook at {} bits: {speedup:.2}x", limbs * 64);
        if speedup <= 0.8 {
            // timing ratios are noisy on shared hosts: warn by default so
            // the remaining benches still run, hard-fail only when asked
            eprintln!(
                "WARNING: comba below 0.8x of schoolbook at {} bits ({speedup:.2}x)",
                limbs * 64
            );
            assert!(
                std::env::var_os("APFP_BENCH_STRICT").is_none(),
                "comba kernel regressed the schoolbook path at {} bits: {speedup:.2}x",
                limbs * 64
            );
        }
    }

    // marshaling: plane pack/unpack and tile extraction
    let vals: Vec<ApFloat> = (0..256).map(|_| rand_ap(&mut rng, 448, 40)).collect();
    let r = bench("plane pack 256", 50, 2000, || {
        std::hint::black_box(PlaneBatch::from_slice(&vals, 448));
    });
    t.row(&["plane pack (256 values)".into(), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput() * 256.0)]);
    let planes = PlaneBatch::from_slice(&vals, 448);
    let r = bench("plane unpack 256", 50, 2000, || {
        std::hint::black_box(planes.to_vec());
    });
    t.row(&["plane unpack (256 values)".into(), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput() * 256.0)]);

    let m = Matrix::random(64, 64, 448, 3, 40);
    let r = bench("tile extract 16x16", 50, 2000, || {
        std::hint::black_box(m.extract_tile(8, 8, 16, 16));
    });
    t.row(&["tile extract (16x16)".into(), apfp::bench_util::fmt_duration(r.median_s()), fmt_rate(r.throughput())]);

    println!("== hot-path microbenchmarks ==\n\n{}", t.render());
}
