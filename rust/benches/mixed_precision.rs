//! Mixed-precision streams (see BENCH.md): one device hosting kernels at
//! two mantissa widths, with interleaved independent launches at 128 and
//! 512 bits flowing through the same worker queues.
//!
//! The structural claims are asserted, not just timed:
//!
//! * interleaved launches at *different* widths pipeline — the mixed
//!   round must reach `inflight_max >= 2` on a fresh device;
//! * the model ledger attributes every tile and launch to the width that
//!   executed it, and the per-width sums equal the device totals (the
//!   conservation invariant, checked here on a sim-backend replay of the
//!   exact same schedule).
//!
//! The timed comparison puts a number on the knob: the same launch count
//! at 128 bits, at 512 bits, and interleaved — the low width's cheaper
//! MACs are the whole reason a refinement loop wants to mix widths in
//! one stream (`examples/hilbert_refinement.rs`).

use apfp::bench_util::{bench, fmt_duration, Table};
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::runtime::BackendKind;

fn main() {
    let cus = std::thread::available_parallelism().map(|v| v.get().min(4)).unwrap_or(2);
    let cfg = ApfpConfig {
        compute_units: cus,
        tile_n: 8,
        tile_m: 8,
        tile_k: 8,
        widths: vec![128, 512],
        ..Default::default()
    };
    if cfg.backend != BackendKind::Native {
        eprintln!("mixed_precision: needs the native backend (APFP_BACKEND=native)");
        return;
    }
    let dir = apfp::runtime::default_artifact_dir();

    let n = 24usize; // matrix side
    let chain = 8usize; // launches per round (half per width when mixed)
    let a = Matrix::random(n, n, 448, 1, 25);
    let b = Matrix::random(n, n, 448, 2, 25);
    let c0 = Matrix::zeros(n, n, 448);
    let (a_lo, b_lo, c0_lo) = (a.to_prec(64), b.to_prec(64), c0.to_prec(64));

    println!(
        "== mixed_precision: {chain} {n}x{n} GEMM launches, {} CUs, widths 128+512 ==\n",
        cfg.compute_units
    );

    // -- all launches at the default 512-bit width ------------------------
    let dev_hi = Device::new(cfg.clone(), &dir).expect("native device");
    let high = bench("512-bit x N", 1, 5, || {
        let mut s = dev_hi.stream().expect("stream");
        let ha = s.upload(&a);
        let hb = s.upload(&b);
        let hcs: Vec<_> = (0..chain).map(|_| s.upload(&c0)).collect();
        for &hc in &hcs {
            s.enqueue_gemm(ha, hb, hc).expect("enqueue");
        }
        s.wait().expect("wait");
        std::hint::black_box(&s.download(hcs[chain - 1]).expect("download"));
    });

    // -- all launches at 128 bits -----------------------------------------
    let dev_lo = Device::new(cfg.clone(), &dir).expect("native device");
    let low = bench("128-bit x N", 1, 5, || {
        let mut s = dev_lo.stream().expect("stream");
        let ha = s.upload(&a_lo);
        let hb = s.upload(&b_lo);
        let hcs: Vec<_> = (0..chain).map(|_| s.upload(&c0_lo)).collect();
        for &hc in &hcs {
            s.enqueue_gemm_at(128, ha, hb, hc).expect("enqueue");
        }
        s.wait().expect("wait");
        std::hint::black_box(&s.download(hcs[chain - 1]).expect("download"));
    });

    // -- interleaved: alternate widths, disjoint buffer sets --------------
    let dev_mix = Device::new(cfg.clone(), &dir).expect("native device");
    let mixed = bench("interleaved 128/512 x N", 1, 5, || {
        let mut s = dev_mix.stream().expect("stream");
        let ha = s.upload(&a);
        let hb = s.upload(&b);
        let la = s.upload(&a_lo);
        let lb = s.upload(&b_lo);
        let his: Vec<_> = (0..chain / 2).map(|_| s.upload(&c0)).collect();
        let los: Vec<_> = (0..chain / 2).map(|_| s.upload(&c0_lo)).collect();
        for i in 0..chain / 2 {
            s.enqueue_gemm_at(512, ha, hb, his[i]).expect("enqueue hi");
            s.enqueue_gemm_at(128, la, lb, los[i]).expect("enqueue lo");
        }
        s.wait().expect("wait");
        std::hint::black_box(&s.download(los[chain / 2 - 1]).expect("download"));
    });
    let mix_metrics = dev_mix.metrics();
    assert!(
        mix_metrics.inflight_max >= 2,
        "interleaved mixed-width launches must overlap (got inflight_max {})",
        mix_metrics.inflight_max
    );
    assert_eq!(
        (mix_metrics.retries, mix_metrics.respawns, mix_metrics.quarantined_cus),
        (0, 0, 0),
        "a fault-free mixed round must never touch the healing ladder"
    );

    // -- structural: replay the mixed schedule on sim, read the ledger ----
    let dev_sim = Device::new(
        ApfpConfig { backend: BackendKind::Sim, ..cfg.clone() },
        &dir,
    )
    .expect("sim device");
    {
        let mut s = dev_sim.stream().expect("stream");
        let ha = s.upload(&a);
        let hb = s.upload(&b);
        let la = s.upload(&a_lo);
        let lb = s.upload(&b_lo);
        let hi = s.upload(&c0);
        let lo = s.upload(&c0_lo);
        for _ in 0..2 {
            s.enqueue_gemm_at(512, ha, hb, hi).expect("enqueue hi");
            s.enqueue_gemm_at(128, la, lb, lo).expect("enqueue lo");
            s.wait().expect("wait");
        }
    }
    let m = dev_sim.model_metrics();
    let w512 = m.width_breakdown().find(|w| w.bits == 512).expect("512 slot");
    let w128 = m.width_breakdown().find(|w| w.bits == 128).expect("128 slot");
    assert_eq!((w512.launches, w128.launches), (2, 2), "per-width launch split");
    assert_eq!(w512.tiles, w128.tiles, "same geometry: same tile count per width");
    assert_eq!(w512.tiles + w128.tiles, m.tiles, "tile conservation");
    assert_eq!(w512.macs + w128.macs, m.macs, "MAC conservation");
    assert!(
        w512.energy_pj > w128.energy_pj && w512.dram_bytes > w128.dram_bytes,
        "a 512-bit tile must model more energy and traffic than a 128-bit one"
    );

    println!("{}", high.report());
    println!("{}", low.report());
    println!("{}", mixed.report());
    println!("\n128-bit vs 512-bit: {:.2}x on wall time", low.speedup_vs(&high));

    let mut t = Table::new(&["round", "launches", "inflight_max", "median"]);
    for (name, dev, res) in [
        ("512-bit", &dev_hi, &high),
        ("128-bit", &dev_lo, &low),
        ("interleaved", &dev_mix, &mixed),
    ] {
        let dm = dev.metrics();
        t.row(&[
            name.into(),
            dm.launches.to_string(),
            dm.inflight_max.to_string(),
            fmt_duration(res.median_s()),
        ]);
    }
    println!("\n{}", t.render());
    println!(
        "sim ledger: 512-bit {} pJ vs 128-bit {} pJ over equal tile counts",
        w512.energy_pj, w128.energy_pj
    );
}
