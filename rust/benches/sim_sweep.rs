//! SimBackend sweep: the device stack run end-to-end on the simulated
//! backend (`APFP_BACKEND=sim`), with the hardware-model ledger it feeds
//! checked against the standalone Fig. 5 / Tab. III dataflow model.
//!
//! Three layers, each asserted:
//!
//! 1. the *analytic* sweep — per-width peak throughput and the N=4096
//!    design points straight out of `sim::gemm_sim` (what `repro
//!    modelgold` pins in `model_golden.json`);
//! 2. the *executed* ledger — a real multi-launch GEMM on a sim-backend
//!    `Device`, whose `ModelMetrics` totals must factor exactly into
//!    `tiles x k_steps x tile_cost` (the conservation invariant) and whose
//!    output must be bit-identical to the native backend;
//! 3. the *overhead* of modeling — sim vs native wall time on the same
//!    workload, which must stay within a small constant factor since the
//!    sim backend runs the identical arena kernels plus O(1) accounting.

use apfp::bench_util::{bench, fmt_duration, Table};
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::hwmodel::DesignPoint;
use apfp::runtime::sim_backend::tile_cost;
use apfp::runtime::BackendKind;
use apfp::sim::gemm_sim;

fn device(backend: BackendKind, cus: usize, bits: u32) -> Device {
    let cfg = ApfpConfig {
        backend,
        bits,
        compute_units: cus,
        tile_n: 8,
        tile_m: 8,
        tile_k: 8,
        ..Default::default()
    };
    let dir = apfp::runtime::default_artifact_dir();
    Device::new(cfg, &dir).expect("builtin-manifest device")
}

fn main() {
    // -- 1. analytic sweep: the design points the golden file pins --------
    println!("== modeled design points (sim::gemm_sim, U250) ==\n");
    let designs: Vec<(&str, DesignPoint)> = vec![
        ("512b x1", DesignPoint::gemm_512(1)),
        ("512b x2", DesignPoint::gemm_512(2)),
        ("512b x4", DesignPoint::gemm_512(4)),
        ("512b x8", DesignPoint::gemm_512(8)),
        ("1024b x1", DesignPoint::gemm_1024(1)),
    ];
    let mut t = Table::new(&["design", "freq [MHz]", "peak [MMAC/s]", "n4096 [MMAC/s]", "n4096 eff"]);
    for (name, d) in &designs {
        let s = d.synthesize();
        assert!(s.failure.is_none(), "{name}: paper design must synthesize");
        let pk = gemm_sim::peak(d, 32);
        let p4 = gemm_sim::simulate(d, 4096, 32, 32);
        t.row(&[
            name.to_string(),
            format!("{:.0}", s.frequency_mhz),
            format!("{:.0}", pk.mmacs / 1e6),
            format!("{:.0}", p4.mmacs / 1e6),
            format!("{:.3}", p4.efficiency),
        ]);
    }
    println!("{}", t.render());

    // Tab. III anchors (same tolerances as the unit tests)
    for (cus, paper) in [(1usize, 322.0f64), (2, 540.0), (4, 1049.0), (8, 2002.0)] {
        let got = gemm_sim::peak(&DesignPoint::gemm_512(cus), 32).mmacs / 1e6;
        assert!((got - paper).abs() / paper < 0.18, "Tab III {cus} CU: {got:.0} vs {paper}");
    }

    // -- 2. executed ledger on the sim backend ----------------------------
    println!("\n== executed: sim-backend device, 3 launches of 16x16 GEMM ==\n");
    let n = 16usize;
    let launches = 3usize;
    for bits in [512u32, 1024] {
        let prec = bits - 64;
        let a = Matrix::random(n, n, prec, 11, 25);
        let b = Matrix::random(n, n, prec, 12, 25);
        let c0 = Matrix::zeros(n, n, prec);

        let run = |dev: &Device| -> Matrix {
            let mut s = dev.stream().expect("stream");
            let ha = s.upload(&a);
            let hb = s.upload(&b);
            let hc = s.upload(&c0);
            for _ in 0..launches {
                s.enqueue_gemm(ha, hb, hc).expect("enqueue");
            }
            s.wait().expect("wait");
            s.download(hc).expect("download")
        };

        let sim_dev = device(BackendKind::Sim, 2, bits);
        let native_dev = device(BackendKind::Native, 2, bits);
        let sim_out = run(&sim_dev);
        let native_out = run(&native_dev);
        assert_eq!(sim_out, native_out, "{bits}-bit: sim must be bit-identical to native");

        let m = sim_dev.model_metrics();
        assert!(m.is_live(), "sim ledger must be live");
        assert!(!native_dev.model_metrics().is_live(), "native ledger must stay dead");

        // conservation: totals factor exactly into tiles x k_steps x cost
        let metas = apfp::runtime::manifest::builtin(bits, sim_dev.config().tile_shape())
            .expect("builtin manifest");
        let meta = metas
            .iter()
            .find(|m| m.kind == apfp::runtime::ArtifactKind::Gemm)
            .expect("builtin gemm meta");
        let per_call = tile_cost(meta);
        let tiles_per_launch = n.div_ceil(8) * n.div_ceil(8);
        let k_steps = n.div_ceil(8) as u64;
        let want_tiles = (tiles_per_launch * launches) as u64;
        assert_eq!(m.tiles, want_tiles, "{bits}-bit: settled tile replies");
        assert_eq!(m.launches, launches as u64, "one launch record per retired launch");
        assert_eq!(m.macs, want_tiles * k_steps * per_call.macs, "MAC conservation");
        assert_eq!(m.cycles, want_tiles * k_steps * per_call.cycles, "cycle conservation");
        assert_eq!(
            m.dram_bytes,
            want_tiles * k_steps * per_call.dram_bytes,
            "DRAM-traffic conservation"
        );

        println!(
            "{bits:>5}b: tiles {:>3}  cycles {:>8}  dram {:>8} B  energy {:>6.1} uJ  \
             modeled {:>8}  eff {:.3}  power {:.1} W",
            m.tiles,
            m.cycles,
            m.dram_bytes,
            m.energy_pj as f64 * 1e-6,
            fmt_duration(m.total_s()),
            m.efficiency(),
            m.power_w(),
        );
    }

    // -- 3. modeling overhead: sim vs native wall time --------------------
    println!("\n== modeling overhead: same workload, sim vs native ==\n");
    let prec = 448;
    let a = Matrix::random(24, 24, prec, 21, 25);
    let b = Matrix::random(24, 24, prec, 22, 25);
    let c0 = Matrix::zeros(24, 24, prec);
    let mut t = Table::new(&["backend", "time/gemm", "ratio"]);
    let mut times = Vec::new();
    for backend in [BackendKind::Native, BackendKind::Sim] {
        let dev = device(backend, 2, 512);
        let r = bench(&format!("{backend} gemm"), 2, 8, || {
            let (out, _) = dev.gemm(&a, &b, &c0).expect("gemm");
            std::hint::black_box(&out);
        });
        times.push(r.median_s());
        let ratio = times[0] / r.median_s().max(1e-12);
        t.row(&[backend.to_string(), fmt_duration(r.median_s()), format!("{:.2}x", 1.0 / ratio)]);
    }
    println!("{}", t.render());
    let overhead = times[1] / times[0];
    println!("sim/native wall-time ratio: {overhead:.2}x (accounting is O(1) per tile)");
    assert!(overhead < 3.0, "modeling must not dominate the kernels: {overhead:.2}x");
}
