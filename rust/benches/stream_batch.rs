//! Packing amortization of the batched stream API (see BENCH.md):
//! N chained one-shot `Device::gemm` calls — each of which re-packs A/B/C
//! and round-trips C through the host — against one `Device::stream()`
//! holding operands resident across N `enqueue_gemm` launches.
//!
//! The metrics counters make the reuse visible alongside the wall times:
//! one-shot repacks the B tile grid every call (`panel_builds == N` per
//! round), the stream packs it once and reuses it (`panel_reuses` grows).

use apfp::bench_util::{bench, fmt_duration, Table};
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::runtime::BackendKind;

fn main() {
    let cus = std::thread::available_parallelism().map(|v| v.get().min(4)).unwrap_or(2);
    let cfg = ApfpConfig {
        compute_units: cus,
        tile_n: 8,
        tile_m: 8,
        tile_k: 8,
        ..Default::default()
    };
    if cfg.backend != BackendKind::Native {
        eprintln!("stream_batch: needs the native backend (APFP_BACKEND=native)");
        return;
    }
    let dir = apfp::runtime::default_artifact_dir();
    let dev = Device::new(cfg.clone(), &dir).expect("native device");

    let n = 24usize; // matrix side: small enough that packing is visible
    let chain = 8usize; // launches per round
    let a = Matrix::random(n, n, 448, 1, 25);
    let b = Matrix::random(n, n, 448, 2, 25);
    let c0 = Matrix::zeros(n, n, 448);

    println!(
        "== stream_batch: {chain} chained {n}x{n} GEMMs, {} CUs, tiles {}x{}x{} ==\n",
        cfg.compute_units, cfg.tile_n, cfg.tile_m, cfg.tile_k
    );

    // -- N one-shot calls: C round-trips through the host every launch ----
    let before_oneshot = dev.metrics();
    let oneshot = bench("one-shot gemm x N", 1, 5, || {
        let mut c = c0.clone();
        for _ in 0..chain {
            let (next, _) = dev.gemm(&a, &b, &c).expect("gemm");
            c = next;
        }
        std::hint::black_box(&c);
    });
    let after_oneshot = dev.metrics();

    // -- one stream: pack once, enqueue N times, C stays resident ---------
    let before_stream = dev.metrics();
    let streamed = bench("stream enqueue x N", 1, 5, || {
        let mut s = dev.stream().expect("stream");
        let (ha, hb) = (s.upload(&a), s.upload(&b));
        let hc = s.upload(&c0);
        for _ in 0..chain {
            s.enqueue_gemm(ha, hb, hc).expect("enqueue");
        }
        std::hint::black_box(&s.download(hc).expect("download"));
    });
    let after_stream = dev.metrics();

    println!("{}", oneshot.report());
    println!("{}", streamed.report());
    let speedup = streamed.speedup_vs(&oneshot);
    println!("\nstream vs one-shot: {speedup:.2}x on wall time");

    let mut t = Table::new(&["path", "launches", "B-grid packs", "B-grid reuses", "median"]);
    let rounds = 6u64; // 1 warmup + 5 samples
    t.row(&[
        "one-shot".into(),
        (after_oneshot.enqueues - before_oneshot.enqueues).to_string(),
        (after_oneshot.panel_builds - before_oneshot.panel_builds).to_string(),
        (after_oneshot.panel_reuses - before_oneshot.panel_reuses).to_string(),
        fmt_duration(oneshot.median_s()),
    ]);
    t.row(&[
        "stream".into(),
        (after_stream.enqueues - before_stream.enqueues).to_string(),
        (after_stream.panel_builds - before_stream.panel_builds).to_string(),
        (after_stream.panel_reuses - before_stream.panel_reuses).to_string(),
        fmt_duration(streamed.median_s()),
    ]);
    println!("\n{}", t.render());

    // The structural claim the bench exists to check: the one-shot path
    // packs a B grid per launch, the stream packs one per round.
    let oneshot_builds = after_oneshot.panel_builds - before_oneshot.panel_builds;
    let stream_builds = after_stream.panel_builds - before_stream.panel_builds;
    assert_eq!(oneshot_builds, rounds * chain as u64, "one-shot must pack per launch");
    assert_eq!(stream_builds, rounds, "stream must pack once per round");
    assert_eq!(
        after_stream.panel_reuses - before_stream.panel_reuses,
        rounds * (chain as u64 - 1),
        "stream must reuse the cached grid for every later enqueue"
    );
    // And the healing ladder stays untouched on a fault-free device: both
    // paths ran every launch first-try on the original worker incarnations.
    let end = dev.metrics();
    assert_eq!(
        (end.retries, end.respawns, end.quarantined_cus),
        (0, 0, 0),
        "a fault-free bench must never retry, respawn, or quarantine"
    );
}
