//! Pipelining of independent launches through the hazard tracker (see
//! BENCH.md): a *dependent* chain — every `enqueue_gemm(c, b, c)` reads
//! the previous launch's output, so each enqueue must drain its
//! predecessor — against *independent* launches over disjoint C buffers,
//! which the per-launch hazard check keeps in flight simultaneously so
//! leader-side drain/writeback of one launch overlaps worker compute of
//! the next.
//!
//! The structural claim is asserted, not just timed: the dependent chain
//! must never have two launches in flight (`inflight_max == 1` on a fresh
//! device), and the independent round must (`inflight_max >= 2`) — the
//! ISSUE 5 acceptance criterion.  Total arithmetic is identical on both
//! paths (same launch count over the same shapes), so the wall-time delta
//! is pure pipeline overlap.

use apfp::bench_util::{bench, fmt_duration, Table};
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::runtime::BackendKind;

fn main() {
    let cus = std::thread::available_parallelism().map(|v| v.get().min(4)).unwrap_or(2);
    let cfg = ApfpConfig {
        compute_units: cus,
        tile_n: 8,
        tile_m: 8,
        tile_k: 8,
        ..Default::default()
    };
    if cfg.backend != BackendKind::Native {
        eprintln!("stream_overlap: needs the native backend (APFP_BACKEND=native)");
        return;
    }
    let dir = apfp::runtime::default_artifact_dir();

    let n = 24usize; // matrix side
    let chain = 8usize; // launches per round
    let a = Matrix::random(n, n, 448, 1, 25);
    let b = Matrix::random(n, n, 448, 2, 25);
    let c0 = Matrix::zeros(n, n, 448);

    println!(
        "== stream_overlap: {chain} {n}x{n} GEMM launches, {} CUs, tiles {}x{}x{} ==\n",
        cfg.compute_units, cfg.tile_n, cfg.tile_m, cfg.tile_k
    );

    // -- dependent chain: every launch reads the previous C ---------------
    // Fresh device per path so inflight_max (a high-water mark) is
    // attributable to that path alone.
    let dev_dep = Device::new(cfg.clone(), &dir).expect("native device");
    let dependent = bench("dependent chain x N", 1, 5, || {
        let mut s = dev_dep.stream().expect("stream");
        let hb = s.upload(&b);
        let hc = s.upload(&c0);
        for _ in 0..chain {
            s.enqueue_gemm(hc, hb, hc).expect("enqueue");
        }
        std::hint::black_box(&s.download(hc).expect("download"));
    });
    let dep_metrics = dev_dep.metrics();
    assert_eq!(
        dep_metrics.inflight_max, 1,
        "a dependent chain must drain between launches (RAW hazard)"
    );
    assert_eq!(
        (dep_metrics.retries, dep_metrics.respawns, dep_metrics.quarantined_cus),
        (0, 0, 0),
        "a fault-free run must never touch the healing ladder"
    );

    // -- independent launches: disjoint C buffers stay in flight ----------
    let dev_ind = Device::new(cfg.clone(), &dir).expect("native device");
    let independent = bench("independent x N", 1, 5, || {
        let mut s = dev_ind.stream().expect("stream");
        let ha = s.upload(&a);
        let hb = s.upload(&b);
        let hcs: Vec<_> = (0..chain).map(|_| s.upload(&c0)).collect();
        for &hc in &hcs {
            s.enqueue_gemm(ha, hb, hc).expect("enqueue");
        }
        s.wait().expect("wait");
        std::hint::black_box(&s.download(hcs[chain - 1]).expect("download"));
    });
    let ind_metrics = dev_ind.metrics();
    assert!(
        ind_metrics.inflight_max >= 2,
        "independent launches must overlap (got inflight_max {})",
        ind_metrics.inflight_max
    );
    assert_eq!(
        (ind_metrics.retries, ind_metrics.respawns, ind_metrics.quarantined_cus),
        (0, 0, 0),
        "pipelined fault-free launches must never touch the healing ladder"
    );

    println!("{}", dependent.report());
    println!("{}", independent.report());
    let speedup = independent.speedup_vs(&dependent);
    println!("\nindependent vs dependent: {speedup:.2}x on wall time");

    let mut t = Table::new(&["path", "launches", "inflight_max", "drain/launch", "median"]);
    t.row(&[
        "dependent".into(),
        dep_metrics.launches.to_string(),
        dep_metrics.inflight_max.to_string(),
        fmt_duration(dep_metrics.drain_ns_per_launch() / 1e9),
        fmt_duration(dependent.median_s()),
    ]);
    t.row(&[
        "independent".into(),
        ind_metrics.launches.to_string(),
        ind_metrics.inflight_max.to_string(),
        fmt_duration(ind_metrics.drain_ns_per_launch() / 1e9),
        fmt_duration(independent.median_s()),
    ]);
    println!("\n{}", t.render());
}
