//! Tab. I regeneration: the 512-bit multiplier microbenchmark.
//!
//! Columns come from three sources, all reported:
//!   modeled  — the hwmodel/sim U250 rows (the paper's FPGA numbers);
//!   paper    — the reported 36-core MPFR reference;
//!   measured — this host's softfloat throughput, single core and all
//!              cores (our honest MPFR stand-in, §V-B methodology).

use apfp::baseline;
use apfp::bench_util::{fmt_rate, Table};
use apfp::sim::mult_sim;

fn main() {
    let bits = 512;
    let prec = 448;
    println!("== Tab. I: 512-bit (448-bit mantissa) multiplier ==\n");
    let mut t = Table::new(&["Configuration", "Freq.", "CLBs", "DSPs", "Throughput", "Speedup", "#Cores"]);
    for r in mult_sim::table(bits) {
        t.row(&[
            r.label.clone(),
            if r.frequency_mhz > 0.0 { format!("{:.0} MHz", r.frequency_mhz) } else { "-".into() },
            if r.clb_pct > 0.0 { format!("{:.1}%", r.clb_pct) } else { "-".into() },
            if r.dsp_pct > 0.0 { format!("{:.1}%", r.dsp_pct) } else { "-".into() },
            format!("{:.0} MOp/s", r.throughput_mops),
            format!("{:.1}x", r.speedup_vs_node),
            format!("{:.1}x", r.equivalent_cores),
        ]);
    }
    println!("{}", t.render());

    println!("\nmeasured softfloat multiply on this host (L1-resident working set):");
    let one = baseline::measure_mul_throughput(prec, 300_000);
    println!("  1 core:  {}", fmt_rate(one));
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let all = baseline::measure_mul_throughput_threaded(prec, 300_000, threads);
    println!("  {threads} cores: {}", fmt_rate(all));
    println!(
        "  modeled 16-CU FPGA / measured host-total ratio: {:.1}x",
        mult_sim::fpga_row(bits, 16).throughput_mops * 1e6 / all
    );
}
