//! Tab. II regeneration: the 1024-bit multiplier microbenchmark
//! (see tab1_mult512.rs for the three reporting sources).

use apfp::baseline;
use apfp::bench_util::{fmt_rate, Table};
use apfp::sim::mult_sim;

fn main() {
    let bits = 1024;
    let prec = 960;
    println!("== Tab. II: 1024-bit (960-bit mantissa) multiplier ==\n");
    let mut t = Table::new(&["Configuration", "Freq.", "CLBs", "DSPs", "Throughput", "Speedup", "#Cores"]);
    for r in mult_sim::table(bits) {
        t.row(&[
            r.label.clone(),
            if r.frequency_mhz > 0.0 { format!("{:.0} MHz", r.frequency_mhz) } else { "-".into() },
            if r.clb_pct > 0.0 { format!("{:.1}%", r.clb_pct) } else { "-".into() },
            if r.dsp_pct > 0.0 { format!("{:.1}%", r.dsp_pct) } else { "-".into() },
            format!("{:.0} MOp/s", r.throughput_mops),
            format!("{:.1}x", r.speedup_vs_node),
            format!("{:.1}x", r.equivalent_cores),
        ]);
    }
    println!("{}", t.render());

    println!("\nmeasured softfloat multiply on this host:");
    let one = baseline::measure_mul_throughput(prec, 100_000);
    println!("  1 core:  {}", fmt_rate(one));
    let p448 = baseline::measure_mul_throughput(448, 100_000);
    println!(
        "  512->1024-bit slowdown: {:.2}x (paper's MPFR slows {:.2}x: 490 -> 227 MOp/s)",
        p448 / one,
        490.0 / 227.0
    );
}
