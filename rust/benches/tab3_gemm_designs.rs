//! Tab. III regeneration: the evaluated 512-bit GEMM design points
//! (frequency, CLB/DSP utilization, peak MMAC/s over the Fig. 5 n-range).

use apfp::bench_util::Table;
use apfp::hwmodel::DesignPoint;
use apfp::sim::gemm_sim;

fn main() {
    println!("== Tab. III: overview of 512-bit GEMM designs ==\n");
    let mut t = Table::new(&["Precision", "CUs", "Frequency", "CLBs", "DSPs", "Max. Performance"]);
    let paper = [(1usize, 322.0f64), (2, 540.0), (4, 1049.0), (8, 2002.0)];
    for (cus, paper_mmacs) in paper {
        let d = DesignPoint::gemm_512(cus);
        let s = d.synthesize();
        assert!(s.failure.is_none(), "design {cus} CUs must fit: {:?}", s.failure);
        let peak = gemm_sim::peak(&d, 32);
        let got = peak.mmacs / 1e6;
        t.row(&[
            "512 (448)".into(),
            cus.to_string(),
            format!("{:.0} MHz", s.frequency_mhz),
            format!("{:.1}%", s.clb_frac * 100.0),
            format!("{:.1}%", s.dsp_frac * 100.0),
            format!("{got:.0} MMAC/s (paper {paper_mmacs:.0})"),
        ]);
        assert!((got - paper_mmacs).abs() / paper_mmacs < 0.20, "CUs={cus}: {got:.0} vs paper {paper_mmacs}");
    }
    println!("{}", t.render());
    println!("\nall four design points within 20% of the paper's reported peaks");
}
