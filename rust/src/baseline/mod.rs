//! Software CPU baseline — the role MPFR + Elemental play in the paper.
//!
//! * [`gemm_serial`] / [`gemm_threaded`] — blocked GEMM over `softfloat`
//!   scalars; the threaded version partitions output rows across cores the
//!   way Elemental's MPI ranks partition the distributed matrix.
//! * [`measure_mul_throughput`] / [`measure_mac_throughput`] — the §V-B
//!   microbenchmark on this host: a hot loop over an L1-resident working
//!   set, giving the measured ops/s the benches compare the accelerator
//!   model against.

use crate::coordinator::Matrix;
use crate::softfloat::ApFloat;

/// Reference GEMM: C += A*B, sequential K accumulation per element —
/// the exact operation order of the accelerator datapath, so results are
/// bit-comparable with the device output.
pub fn gemm_serial(a: &Matrix, b: &Matrix, c: &Matrix) -> Matrix {
    let mut out = c.clone();
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut acc = c.get(i, j).clone();
            for k in 0..a.cols() {
                acc = acc.mac(a.get(i, k), b.get(k, j));
            }
            out.set(i, j, acc);
        }
    }
    out
}

/// Multithreaded blocked GEMM (row bands across `threads` cores).
pub fn gemm_threaded(a: &Matrix, b: &Matrix, c: &Matrix, threads: usize) -> Matrix {
    assert_eq!(a.cols(), b.rows());
    let n = a.rows();
    let threads = threads.clamp(1, n.max(1));
    let band = n.div_ceil(threads);
    let mut out = c.clone();

    // compute bands in parallel, collect rows, then write back
    let results: Vec<Vec<(usize, Vec<ApFloat>)>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..threads {
            let (a, b, c) = (&*a, &*b, &*c);
            handles.push(scope.spawn(move || {
                let start = (t * band).min(n);
                let end = ((t + 1) * band).min(n);
                let mut rows = Vec::with_capacity(end - start);
                for i in start..end {
                    let mut row = Vec::with_capacity(b.cols());
                    for j in 0..b.cols() {
                        let mut acc = c.get(i, j).clone();
                        for k in 0..a.cols() {
                            acc = acc.mac(a.get(i, k), b.get(k, j));
                        }
                        row.push(acc);
                    }
                    rows.push((i, row));
                }
                rows
            }));
        }
        handles.into_iter().map(|h| h.join().expect("baseline worker")).collect()
    });
    for rows in results {
        for (i, row) in rows {
            for (j, v) in row.into_iter().enumerate() {
                out.set(i, j, v);
            }
        }
    }
    out
}

/// Measured multiplication throughput (ops/s) of one core on this host,
/// L1-resident operands (the paper's §V-B CPU methodology).  Runs the
/// allocation-free `mul_into` path against a private scratch arena — the
/// honest analog of MPFR's `mpfr_mul` into a preallocated result.
pub fn measure_mul_throughput(prec: u32, iters: usize) -> f64 {
    let set = working_set(prec, 64);
    let mut scratch = crate::bigint::MulScratch::new();
    let mut sink = set[0].clone();
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let a = &set[i % set.len()];
        let b = &set[(i * 7 + 3) % set.len()];
        a.mul_into(b, &mut sink, &mut scratch);
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(&sink);
    iters as f64 / dt
}

/// Measured multiply-add throughput (MAC/s) of one core on this host.
pub fn measure_mac_throughput(prec: u32, iters: usize) -> f64 {
    let set = working_set(prec, 64);
    let t0 = std::time::Instant::now();
    let mut acc = set[0].clone();
    for i in 0..iters {
        let a = &set[i % set.len()];
        let b = &set[(i * 7 + 3) % set.len()];
        acc = acc.mac(a, b);
        if acc.is_zero() || acc.exp() > 1 << 40 {
            acc = set[1].clone(); // keep exponents bounded in the hot loop
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(&acc);
    iters as f64 / dt
}

/// Multithreaded mul throughput (ops/s aggregated over `threads` cores).
pub fn measure_mul_throughput_threaded(prec: u32, iters: usize, threads: usize) -> f64 {
    let per: Vec<f64> = std::thread::scope(|scope| {
        (0..threads)
            .map(|_| scope.spawn(move || measure_mul_throughput(prec, iters)))
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().expect("bench thread"))
            .collect()
    });
    per.iter().sum()
}

fn working_set(prec: u32, n: usize) -> Vec<ApFloat> {
    let mut rng = crate::testkit::Rng::from_seed(0xBEEF);
    (0..n)
        .map(|_| {
            let limbs = (prec / 64) as usize;
            let mut mant = rng.limbs(limbs);
            mant[limbs - 1] |= 1 << 63;
            ApFloat::from_parts(rng.bool(), rng.range_i64(-30, 30), mant, prec)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threaded_matches_serial_bitwise() {
        let a = Matrix::random(13, 9, 448, 1, 20);
        let b = Matrix::random(9, 11, 448, 2, 20);
        let c = Matrix::random(13, 11, 448, 3, 20);
        let serial = gemm_serial(&a, &b, &c);
        for threads in [1, 2, 4, 7] {
            assert_eq!(gemm_threaded(&a, &b, &c, threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn gemm_identity() {
        let prec = 448;
        let n = 5;
        let a = Matrix::random(n, n, prec, 9, 10);
        let eye = Matrix::from_fn(n, n, prec, |i, j| {
            if i == j { ApFloat::from_u64(1, prec) } else { ApFloat::zero(prec) }
        });
        let zero = Matrix::zeros(n, n, prec);
        assert_eq!(gemm_serial(&a, &eye, &zero), a);
        assert_eq!(gemm_serial(&eye, &a, &zero), a);
    }

    #[test]
    fn throughput_measure_is_positive() {
        let ops = measure_mul_throughput(448, 2_000);
        assert!(ops > 1000.0, "{ops} ops/s looks wrong");
        let macs = measure_mac_throughput(448, 2_000);
        assert!(macs > 1000.0);
    }
}
