//! Software CPU baseline — the role MPFR + Elemental play in the paper.
//!
//! * [`gemm_serial`] / [`gemm_threaded`] / [`gemm_into`] — tiled GEMM over
//!   `softfloat` scalars on the allocation-free `mac_into` pipeline; the
//!   threaded version partitions output rows across cores the way
//!   Elemental's MPI ranks partition the distributed matrix, one arena per
//!   thread.
//! * [`measure_mul_throughput`] / [`measure_mac_throughput`] — the §V-B
//!   microbenchmark on this host: a hot loop over an L1-resident working
//!   set, giving the measured ops/s the benches compare the accelerator
//!   model against.

use crate::bigint::Scratch;
use crate::coordinator::Matrix;
use crate::softfloat::{ApFloat, ApFloatN};

/// Output columns advanced together in the register-blocked inner loop:
/// each A element is loaded once and fed to `JB` accumulators, so the
/// A-panel traffic is amortized `JB`-fold (the software shape of the
/// paper's T_N x T_M output tile).
const JB: usize = 4;

/// Reusable GEMM workspace: the packed B column panels plus the operator
/// arena.  Repeated same-shape [`gemm_into`] calls against one warm
/// `GemmScratch` perform zero heap allocations (see tests/alloc_free.rs).
#[derive(Default)]
pub struct GemmScratch {
    scratch: Scratch,
    /// B packed column-major: column j at `bt[j*k .. (j+1)*k]`.  Packing
    /// clones each column's values back-to-back once per GEMM, so the
    /// k-innermost scan walks freshly co-allocated mantissas instead of
    /// striding `b.cols()` scattered elements per step.
    bt: Vec<ApFloat>,
}

impl GemmScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Refresh the packed B panel in place (allocation-free once warm).
    fn pack_b(&mut self, b: &Matrix) {
        let (k, m) = (b.rows(), b.cols());
        let prec = b.prec();
        if self.bt.len() != k * m {
            self.bt.clear();
            self.bt.resize(k * m, ApFloat::zero(prec));
        }
        for j in 0..m {
            for kk in 0..k {
                self.bt[j * k + kk].assign(b.get(kk, j));
            }
        }
    }
}

/// One output row band of C += A*B on the packed panel: rows `i0..` of A
/// against every packed B column, `JB` output columns per pass, sequential
/// K accumulation per element through [`ApFloat::mac_into`] — the exact
/// operation order of the accelerator datapath, so results stay
/// bit-comparable with the device output.
fn gemm_band(
    a: &Matrix,
    bt: &[ApFloat],
    k: usize,
    out: &mut [ApFloat],
    i0: usize,
    cols: usize,
    scratch: &mut Scratch,
) {
    debug_assert_eq!(out.len() % cols.max(1), 0);
    let rows = if cols == 0 { 0 } else { out.len() / cols };
    for r in 0..rows {
        let arow = a.row(i0 + r);
        let out_row = &mut out[r * cols..(r + 1) * cols];
        for j0 in (0..cols).step_by(JB) {
            let jw = JB.min(cols - j0);
            for (kk, x) in arow.iter().enumerate() {
                for jj in 0..jw {
                    let j = j0 + jj;
                    out_row[j].mac_into(x, &bt[j * k + kk], scratch);
                }
            }
        }
    }
}

/// In-place tiled GEMM: `out += a * b` with sequential K accumulation per
/// element (bit-identical to [`gemm_serial`] on the same inputs).  `out`
/// plays the role of C and is updated in place; with a warm `ws` the call
/// performs zero heap allocations.
pub fn gemm_into(a: &Matrix, b: &Matrix, out: &mut Matrix, ws: &mut GemmScratch) {
    assert_eq!(a.cols(), b.rows(), "inner dimensions");
    assert!(a.rows() == out.rows() && b.cols() == out.cols(), "output shape");
    ws.pack_b(b);
    let k = a.cols();
    let cols = out.cols();
    gemm_band(a, &ws.bt, k, out.values_mut(), 0, cols, &mut ws.scratch);
}

/// Reference GEMM: C += A*B, sequential K accumulation per element —
/// the exact operation order of the accelerator datapath, so results are
/// bit-comparable with the device output.
pub fn gemm_serial(a: &Matrix, b: &Matrix, c: &Matrix) -> Matrix {
    let mut out = c.clone();
    let mut ws = GemmScratch::new();
    gemm_into(a, b, &mut out, &mut ws);
    out
}

/// Multithreaded tiled GEMM (row bands across `threads` cores).  The B
/// panel is packed once and shared read-only; each worker accumulates its
/// band of the output in place with a private arena, so the inner loops
/// allocate nothing.
///
/// At the compiled fixed widths (448 / 960 bits of mantissa) the bands
/// run the register-blocked [`gemm_fixed`] lane instead of the arena
/// pipeline — bit-identical by construction, and the same
/// `APFP_FIXED_PATH=0` escape hatch that governs the device backend
/// disables it here too.  This is the lane every host-side caller
/// (`linalg`'s `MatmulBackend::Host`, and through it `blas`) inherits.
pub fn gemm_threaded(a: &Matrix, b: &Matrix, c: &Matrix, threads: usize) -> Matrix {
    gemm_threaded_with(a, b, c, threads, crate::runtime::native::fixed_path_env_enabled())
}

/// [`gemm_threaded`] with the fixed-width lane pinned on or off instead
/// of reading `APFP_FIXED_PATH` — parity tests drive both lanes inside a
/// single process.
pub fn gemm_threaded_with(
    a: &Matrix,
    b: &Matrix,
    c: &Matrix,
    threads: usize,
    fixed: bool,
) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "inner dimensions");
    assert!(a.rows() == c.rows() && b.cols() == c.cols(), "output shape");
    if fixed && a.rows() > 0 && b.cols() > 0 && a.prec() == b.prec() && a.prec() == c.prec() {
        match a.prec() {
            448 => return gemm_threaded_fixed::<7>(a, b, c, threads),
            960 => return gemm_threaded_fixed::<15>(a, b, c, threads),
            _ => {}
        }
    }
    let n = a.rows();
    let threads = threads.clamp(1, n.max(1));
    let band = n.div_ceil(threads);
    let mut out = c.clone();
    let mut ws = GemmScratch::new();
    ws.pack_b(b);
    let k = a.cols();
    let cols = out.cols();
    if cols == 0 || n == 0 {
        return out;
    }

    let bt = &ws.bt;
    std::thread::scope(|scope| {
        for (t, band_vals) in out.values_mut().chunks_mut(band * cols).enumerate() {
            let a = &*a;
            scope.spawn(move || {
                let mut scratch = Scratch::new();
                gemm_band(a, bt, k, band_vals, t * band, cols, &mut scratch);
            });
        }
    });
    out
}

/// The threaded fixed-width lane: convert the operands into stack-limb
/// [`ApFloatN`] storage once, band the output rows across `threads`
/// cores running [`gemm_fixed`], and convert back.  Per output element
/// the K accumulation is sequential ascending — the dynamic order — so
/// the result is bit-identical to the arena path on the same inputs
/// (pinned in `threaded_fixed_lane_matches_the_dynamic_lane_bitwise`).
// apfp-lint: allow(alloc, scope=fn, reason="one-shot host entry point: the fixed-lane conversion buffers are built once per call, not per MAC")
fn gemm_threaded_fixed<const L: usize>(
    a: &Matrix,
    b: &Matrix,
    c: &Matrix,
    threads: usize,
) -> Matrix {
    let (n, k, m) = (a.rows(), a.cols(), b.cols());
    let mut af: Vec<ApFloatN<L>> = Vec::with_capacity(n * k);
    for i in 0..n {
        af.extend(a.row(i).iter().map(ApFloatN::<L>::from_ap));
    }
    let mut bt = Vec::new();
    pack_b_fixed::<L>(b, &mut bt);
    let mut cf: Vec<ApFloatN<L>> = Vec::with_capacity(n * m);
    for i in 0..n {
        cf.extend(c.row(i).iter().map(ApFloatN::<L>::from_ap));
    }
    let threads = threads.clamp(1, n.max(1));
    let band = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for (t, band_vals) in cf.chunks_mut(band * m).enumerate() {
            let (af, bt) = (&af, &bt);
            scope.spawn(move || {
                let rows = band_vals.len() / m;
                let i0 = t * band;
                gemm_fixed(&af[i0 * k..(i0 + rows) * k], bt, band_vals, rows, k, m);
            });
        }
    });
    let mut out = c.clone();
    for (slot, v) in out.values_mut().iter_mut().zip(cf.iter()) {
        *slot = v.to_ap();
    }
    out
}

/// Register-blocked fixed-width GEMM micro-kernel: `c += a * b` over
/// stack-allocated [`ApFloatN`] scalars, with `b` pre-packed column-major
/// (`bt[j*k .. (j+1)*k]` holds column `j`, see [`pack_b_fixed`]).
///
/// The inner loop accumulates into a flat `[ApFloatN<L>; JB]` stack tile:
/// each A element is loaded once and fed to `JB` accumulators whose limb
/// arrays sit contiguously in registers/stack — the columnwise shape
/// `core::simd`/AVX2 autovectorizes, with no arena, no `Vec`, and no
/// pointer chase per MAC.  Per output element the K accumulation is
/// sequential ascending, exactly the dynamic [`gemm_into`] order, so the
/// result is bit-identical to [`gemm_serial`] on converted operands
/// (pinned in tests/fixed_parity.rs at both paper widths).
// apfp-lint: no_alloc
pub fn gemm_fixed<const L: usize>(
    a: &[ApFloatN<L>],
    bt: &[ApFloatN<L>],
    c: &mut [ApFloatN<L>],
    n: usize,
    k: usize,
    m: usize,
) {
    assert_eq!(a.len(), n * k, "A shape");
    assert_eq!(bt.len(), m * k, "packed B shape");
    assert_eq!(c.len(), n * m, "C shape");
    for r in 0..n {
        let arow = &a[r * k..(r + 1) * k];
        let crow = &mut c[r * m..(r + 1) * m];
        for j0 in (0..m).step_by(JB) {
            let jw = JB.min(m - j0);
            let mut tile = [ApFloatN::<L>::ZERO; JB];
            tile[..jw].copy_from_slice(&crow[j0..j0 + jw]);
            for (kk, x) in arow.iter().enumerate() {
                for (jj, acc) in tile[..jw].iter_mut().enumerate() {
                    acc.mac_into(x, &bt[(j0 + jj) * k + kk]);
                }
            }
            crow[j0..j0 + jw].copy_from_slice(&tile[..jw]);
        }
    }
}

/// Pack a dynamic matrix into the column-major fixed-width B panel
/// [`gemm_fixed`] consumes (column `j` at `out[j*k .. (j+1)*k]`) — the
/// fixed-lane analog of the dynamic `GemmScratch` packing.  Cold
/// conversion path: reuses `out`'s capacity but is not allocation-free.
pub fn pack_b_fixed<const L: usize>(b: &Matrix, out: &mut Vec<ApFloatN<L>>) {
    let (k, m) = (b.rows(), b.cols());
    out.clear();
    out.resize(k * m, ApFloatN::ZERO);
    for j in 0..m {
        for kk in 0..k {
            out[j * k + kk] = ApFloatN::from_ap(b.get(kk, j));
        }
    }
}

/// Measured multiplication throughput (ops/s) of one core on this host,
/// L1-resident operands (the paper's §V-B CPU methodology).  Runs the
/// allocation-free `mul_into` path against a private scratch arena — the
/// honest analog of MPFR's `mpfr_mul` into a preallocated result.
pub fn measure_mul_throughput(prec: u32, iters: usize) -> f64 {
    let set = working_set(prec, 64);
    let mut scratch = Scratch::new();
    let mut sink = set[0].clone();
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        let a = &set[i % set.len()];
        let b = &set[(i * 7 + 3) % set.len()];
        a.mul_into(b, &mut sink, &mut scratch);
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(&sink);
    iters as f64 / dt
}

/// Measured multiply-add throughput (MAC/s) of one core on this host.
/// Runs the allocation-free `mac_into` accumulation against a private
/// arena — the honest analog of an MPFR harness accumulating into a
/// preallocated `mpfr_t`, so the CPU numbers the benches report reflect
/// the preallocated path, not allocator overhead.
pub fn measure_mac_throughput(prec: u32, iters: usize) -> f64 {
    let set = working_set(prec, 64);
    let mut scratch = Scratch::new();
    let t0 = std::time::Instant::now();
    let mut acc = set[0].clone();
    for i in 0..iters {
        let a = &set[i % set.len()];
        let b = &set[(i * 7 + 3) % set.len()];
        acc.mac_into(a, b, &mut scratch);
        if acc.is_zero() || acc.exp() > 1 << 40 {
            acc.assign(&set[1]); // keep exponents bounded in the hot loop
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(&acc);
    iters as f64 / dt
}

/// Multithreaded mul throughput (ops/s aggregated over `threads` cores).
// join() fails only when a bench thread panicked; propagating that panic
// is the right behavior for a measurement harness.
#[allow(clippy::expect_used)]
pub fn measure_mul_throughput_threaded(prec: u32, iters: usize, threads: usize) -> f64 {
    let per: Vec<f64> = std::thread::scope(|scope| {
        (0..threads)
            .map(|_| scope.spawn(move || measure_mul_throughput(prec, iters)))
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().expect("bench thread"))
            .collect()
    });
    per.iter().sum()
}

/// Multithreaded MAC throughput (MAC/s aggregated over `threads` cores,
/// one arena per thread).
// join() fails only when a bench thread panicked; see above.
#[allow(clippy::expect_used)]
pub fn measure_mac_throughput_threaded(prec: u32, iters: usize, threads: usize) -> f64 {
    let per: Vec<f64> = std::thread::scope(|scope| {
        (0..threads)
            .map(|_| scope.spawn(move || measure_mac_throughput(prec, iters)))
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().expect("bench thread"))
            .collect()
    });
    per.iter().sum()
}

fn working_set(prec: u32, n: usize) -> Vec<ApFloat> {
    let mut rng = crate::testkit::Rng::from_seed(0xBEEF);
    (0..n)
        .map(|_| {
            let limbs = (prec / 64) as usize;
            let mut mant = rng.limbs(limbs);
            mant[limbs - 1] |= 1 << 63;
            ApFloat::from_parts(rng.bool(), rng.range_i64(-30, 30), mant, prec)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threaded_matches_serial_bitwise() {
        let a = Matrix::random(13, 9, 448, 1, 20);
        let b = Matrix::random(9, 11, 448, 2, 20);
        let c = Matrix::random(13, 11, 448, 3, 20);
        let serial = gemm_serial(&a, &b, &c);
        for threads in [1, 2, 4, 7] {
            assert_eq!(gemm_threaded(&a, &b, &c, threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn gemm_identity() {
        let prec = 448;
        let n = 5;
        let a = Matrix::random(n, n, prec, 9, 10);
        let eye = Matrix::from_fn(n, n, prec, |i, j| {
            if i == j { ApFloat::from_u64(1, prec) } else { ApFloat::zero(prec) }
        });
        let zero = Matrix::zeros(n, n, prec);
        assert_eq!(gemm_serial(&a, &eye, &zero), a);
        assert_eq!(gemm_serial(&eye, &a, &zero), a);
    }

    #[test]
    fn gemm_into_matches_serial_and_reuses_workspace() {
        // one warm GemmScratch across shapes and calls must stay bit-exact
        let mut ws = GemmScratch::new();
        for (n, k, m, seed) in [(5usize, 4usize, 6usize, 7u64), (3, 8, 3, 8), (6, 4, 5, 9)] {
            let a = Matrix::random(n, k, 448, seed, 20);
            let b = Matrix::random(k, m, 448, seed + 1, 20);
            let c = Matrix::random(n, m, 448, seed + 2, 20);
            let want = gemm_serial(&a, &b, &c);
            let mut out = c.clone();
            gemm_into(&a, &b, &mut out, &mut ws);
            assert_eq!(out, want, "n={n} k={k} m={m}");
            // accumulating again == C + 2AB, still bit-exact vs reference
            gemm_into(&a, &b, &mut out, &mut ws);
            assert_eq!(out, gemm_serial(&a, &b, &want), "second accumulation");
        }
    }

    #[test]
    fn gemm_matches_per_element_mac_chain() {
        // the tiled/packed kernel must preserve the per-element sequential
        // K order: compare against the naive triple loop written out
        let (n, k, m) = (7usize, 5usize, 9usize); // m not a multiple of JB
        let a = Matrix::random(n, k, 448, 21, 25);
        let b = Matrix::random(k, m, 448, 22, 25);
        let c = Matrix::random(n, m, 448, 23, 25);
        let got = gemm_serial(&a, &b, &c);
        for i in 0..n {
            for j in 0..m {
                let mut acc = c.get(i, j).clone();
                for kk in 0..k {
                    acc = acc.mac(a.get(i, kk), b.get(kk, j));
                }
                assert_eq!(*got.get(i, j), acc, "element ({i}, {j})");
            }
        }
    }

    #[test]
    fn gemm_degenerate_shapes() {
        let prec = 448;
        // k = 0: C passes through untouched
        let a = Matrix::zeros(3, 0, prec);
        let b = Matrix::zeros(0, 4, prec);
        let c = Matrix::random(3, 4, prec, 4, 10);
        assert_eq!(gemm_serial(&a, &b, &c), c);
        assert_eq!(gemm_threaded(&a, &b, &c, 2), c);
        // 1x1
        let a = Matrix::random(1, 1, prec, 5, 10);
        let b = Matrix::random(1, 1, prec, 6, 10);
        let c = Matrix::zeros(1, 1, prec);
        let got = gemm_serial(&a, &b, &c);
        assert_eq!(got.get(0, 0), &a.get(0, 0).mul(b.get(0, 0)));
        // more threads than rows
        let a = Matrix::random(2, 3, prec, 7, 10);
        let b = Matrix::random(3, 2, prec, 8, 10);
        let c = Matrix::zeros(2, 2, prec);
        assert_eq!(gemm_threaded(&a, &b, &c, 16), gemm_serial(&a, &b, &c));
    }

    #[test]
    fn gemm_fixed_matches_serial_bitwise_at_paper_widths() {
        fn run<const L: usize>(prec: u32, seed: u64) {
            let (n, k, m) = (5usize, 6usize, 7usize); // m not a multiple of JB
            let mut a = Matrix::random(n, k, prec, seed, 20);
            let b = Matrix::random(k, m, prec, seed + 1, 20);
            let c = Matrix::random(n, m, prec, seed + 2, 20);
            // a zero operand rides along to exercise the absorbing path
            a.values_mut()[3] = ApFloat::zero(prec);
            let want = gemm_serial(&a, &b, &c);

            let mut af = Vec::new();
            for i in 0..n {
                for kk in 0..k {
                    af.push(ApFloatN::<L>::from_ap(a.get(i, kk)));
                }
            }
            let mut bt = Vec::new();
            pack_b_fixed::<L>(&b, &mut bt);
            let mut cf = Vec::new();
            for i in 0..n {
                for j in 0..m {
                    cf.push(ApFloatN::<L>::from_ap(c.get(i, j)));
                }
            }
            gemm_fixed(&af, &bt, &mut cf, n, k, m);
            for i in 0..n {
                for j in 0..m {
                    assert_eq!(cf[i * m + j].to_ap(), *want.get(i, j), "({i},{j}) prec {prec}");
                }
            }
            // second accumulation on the warm tile stays bit-exact too
            gemm_fixed(&af, &bt, &mut cf, n, k, m);
            let want2 = gemm_serial(&a, &b, &want);
            for i in 0..n {
                for j in 0..m {
                    assert_eq!(cf[i * m + j].to_ap(), *want2.get(i, j), "2nd ({i},{j})");
                }
            }
        }
        run::<7>(448, 31);
        run::<15>(960, 37);
    }

    #[test]
    fn threaded_fixed_lane_matches_the_dynamic_lane_bitwise() {
        // the host fixed lane (what linalg/blas callers get at the paper
        // widths unless APFP_FIXED_PATH=0) must be bit-identical to the
        // arena pipeline, across band splits and at both compiled widths
        for (prec, seed) in [(448u32, 41u64), (960, 43)] {
            let a = Matrix::random(13, 9, prec, seed, 20);
            let b = Matrix::random(9, 11, prec, seed + 1, 20);
            let c = Matrix::random(13, 11, prec, seed + 2, 20);
            let dynamic = gemm_threaded_with(&a, &b, &c, 3, false);
            assert_eq!(dynamic, gemm_serial(&a, &b, &c), "dynamic lane vs serial");
            for threads in [1, 2, 4, 7] {
                let fixed = gemm_threaded_with(&a, &b, &c, threads, true);
                assert_eq!(fixed, dynamic, "prec {prec}, threads {threads}");
            }
        }
        // a width with no compiled lane falls through to the dynamic path
        let a = Matrix::random(5, 4, 64, 51, 20);
        let b = Matrix::random(4, 6, 64, 52, 20);
        let c = Matrix::zeros(5, 6, 64);
        assert_eq!(gemm_threaded_with(&a, &b, &c, 2, true), gemm_serial(&a, &b, &c));
    }

    #[test]
    fn gemm_fixed_degenerate_shapes() {
        // k = 0: C passes through untouched
        let mut c = [ApFloatN::<7>::from_ap(&ApFloat::from_i64(-3, 448))];
        let before = c[0];
        gemm_fixed::<7>(&[], &[], &mut c, 1, 0, 1);
        assert_eq!(c[0], before);
        // m = 0 and n = 0: no-ops on empty outputs
        gemm_fixed::<7>(&[before], &[], &mut [], 1, 1, 0);
        gemm_fixed::<7>(&[], &[before], &mut [], 0, 1, 1);
    }

    #[test]
    fn throughput_measure_is_positive() {
        let ops = measure_mul_throughput(448, 2_000);
        assert!(ops > 1000.0, "{ops} ops/s looks wrong");
        let macs = measure_mac_throughput(448, 2_000);
        assert!(macs > 1000.0);
        let macs2 = measure_mac_throughput_threaded(448, 1_000, 2);
        assert!(macs2 > 1000.0);
    }
}
