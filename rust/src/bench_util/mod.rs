//! From-scratch micro-benchmark harness + table rendering (criterion is
//! unavailable offline).
//!
//! `bench()` warms up, runs timed samples, and reports median/mean/min —
//! enough statistics for the paper-table regeneration benches, with the
//! whole harness under our control (no global state, deterministic sample
//! counts).

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl BenchResult {
    pub fn median_s(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        s[s.len() / 2]
    }

    pub fn mean_s(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min_s(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// iterations/second at the median sample.
    pub fn throughput(&self) -> f64 {
        1.0 / self.median_s()
    }

    /// Median-time ratio `baseline / self`: > 1 means `self` is faster.
    /// Used by the hot-path benches to assert kernel swaps don't regress.
    pub fn speedup_vs(&self, baseline: &BenchResult) -> f64 {
        baseline.median_s() / self.median_s()
    }

    /// Soft perf regression gate shared by the hot-path benches: when
    /// `self` runs below `floor` x the speed of `baseline`, print a
    /// warning — and hard-fail only when `APFP_BENCH_STRICT` is set, since
    /// timing ratios are noisy on shared hosts.  Returns the speedup.
    pub fn gate_speedup(&self, baseline: &BenchResult, floor: f64, what: &str) -> f64 {
        let speedup = self.speedup_vs(baseline);
        println!("{what}: {speedup:.2}x vs {}", baseline.name);
        if speedup <= floor {
            eprintln!("WARNING: {what} below {floor:.2}x of {} ({speedup:.2}x)", baseline.name);
            assert!(
                std::env::var_os("APFP_BENCH_STRICT").is_none(),
                "{what} regressed vs {}: {speedup:.2}x (floor {floor:.2}x)",
                baseline.name
            );
        }
        speedup
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>12} mean {:>12} min {:>12}",
            self.name,
            fmt_duration(self.median_s()),
            fmt_duration(self.mean_s()),
            fmt_duration(self.min_s()),
        )
    }
}

/// Time `f` (one logical iteration per call): `warmup` unmeasured calls,
/// then `samples` measured ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), samples: out }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.1} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.1} k/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} /s")
    }
}

/// Minimal fixed-width table printer for the paper-table benches.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let sep = widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  ");
        let mut out = vec![line(&self.header), sep];
        out.extend(self.rows.iter().map(|r| line(r)));
        out.join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 2, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples.len(), 5);
        assert!(r.median_s() >= 0.0);
        assert!(r.min_s() <= r.mean_s() * 1.0001);
    }

    #[test]
    fn speedup_ratio() {
        let fast = BenchResult { name: "fast".into(), samples: vec![1.0, 1.0, 1.0] };
        let slow = BenchResult { name: "slow".into(), samples: vec![2.0, 2.0, 2.0] };
        assert!((fast.speedup_vs(&slow) - 2.0).abs() < 1e-12);
        assert!((slow.speedup_vs(&fast) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn gate_speedup_reports_ratio_without_failing_by_default() {
        let fast = BenchResult { name: "fast".into(), samples: vec![1.0] };
        let slow = BenchResult { name: "slow".into(), samples: vec![2.0] };
        assert!((fast.gate_speedup(&slow, 0.5, "fast vs slow") - 2.0).abs() < 1e-12);
        // below the floor: warns but must not panic unless APFP_BENCH_STRICT
        if std::env::var_os("APFP_BENCH_STRICT").is_none() {
            assert!((slow.gate_speedup(&fast, 1.0, "slow vs fast") - 0.5).abs() < 1e-12);
        }
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(2.5e-9).ends_with("ns"));
        assert!(fmt_duration(2.5e-5).ends_with("us"));
        assert!(fmt_duration(2.5e-2).ends_with("ms"));
        assert!(fmt_duration(2.5).ends_with('s'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let r = t.render();
        assert_eq!(r.lines().count(), 4);
        let widths: Vec<usize> = r.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }
}
