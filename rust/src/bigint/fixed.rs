//! Compile-time fixed-width limb kernels on `[u64; L]` arrays — the
//! software analog of the paper's generated-per-width FPGA pipeline.
//!
//! The dynamic kernels in [`super`] take slice widths at run time and draw
//! workspaces from a [`super::Scratch`] arena; everything here is
//! monomorphized per `LIMBS`, lives entirely on the stack, and is written
//! so every loop bound is a compile-time constant the optimizer can fully
//! unroll (no arena, no bounds checks after const-folding, no pointer
//! chases).  Each kernel is a *stage-for-stage mirror* of its dynamic
//! counterpart — same column order, same carry discipline, same clamps —
//! so results are bit-identical at every width (pinned by
//! `tests/fixed_parity.rs` and the Python port).
//!
//! A product of two `L`-limb operands needs `2 * L` limbs, which stable
//! Rust cannot spell as `[u64; 2 * L]`; the kernels therefore return the
//! double-width product as a `(lo, hi)` pair of `[u64; L]` halves, and the
//! softfloat adder workspace (`[1 guard | L | 1 overflow]` limbs) is the
//! [`Guarded`] struct rather than a `[u64; L + 2]`.

use std::cmp::Ordering;

use super::KARATSUBA_THRESHOLD;

/// Minimal limb abstraction (the SNIPPETS.md `bloat` idiom): one primitive
/// per limb type providing the double-width multiply every kernel is built
/// from.  Stable-Rust spelling of the unstable `u64::widening_mul`.
pub trait Limb: Copy {
    /// `(low, high)` halves of the full double-width product.
    fn widening_mul(self, rhs: Self) -> (Self, Self);
}

impl Limb for u64 {
    #[inline(always)]
    fn widening_mul(self, rhs: Self) -> (u64, u64) {
        let t = self as u128 * rhs as u128;
        (t as u64, (t >> 64) as u64)
    }
}

/// Whether the fixed kernels use the single-level Karatsuba split at this
/// width.  Decided at **compile time** from `LIMBS` against the *compiled*
/// [`KARATSUBA_THRESHOLD`] — deliberately not [`super::karatsuba_threshold`]:
/// the env-var override tunes the dynamic path's crossover per host, but a
/// monomorphized kernel cannot change shape at run time, and reading the
/// `OnceLock` per call would put an atomic load on the hot path.  Both
/// selections bottom out in the same Comba column order, so an override can
/// only move *where* the dynamic path splits, never *what bits* either path
/// produces (pinned by `threshold_override_cannot_desync_fixed_path`).
/// Odd widths stay Comba, exactly like `kara_rec`'s odd-`n` bottom-out.
pub const fn fixed_uses_karatsuba(limbs: usize) -> bool {
    limbs >= KARATSUBA_THRESHOLD && limbs % 2 == 0
}

/// Fixed-width product: `(lo, hi)` halves of `a * b`, selecting Comba or
/// the single-level Karatsuba split at compile time (the branch below
/// const-folds away per `L`; see [`fixed_uses_karatsuba`]).
// apfp-lint: no_alloc
#[inline]
pub fn mul_fixed<const L: usize>(a: &[u64; L], b: &[u64; L]) -> ([u64; L], [u64; L]) {
    if fixed_uses_karatsuba(L) {
        mul_karatsuba1_fixed(a, b)
    } else {
        mul_comba_fixed(a, b)
    }
}

/// Comba columnwise multiply on fixed arrays — the column order, 128-bit
/// accumulator and overflow counter of [`super::mul_comba`] verbatim, with
/// the single output buffer split into `(lo, hi)` halves: columns
/// `0..L` land in `lo`, columns `L..2L-1` in `hi`, and the final carry in
/// `hi[L - 1]`.  With `L` a constant the compiler fully unrolls both
/// column loops.
// apfp-lint: no_alloc
#[inline]
pub fn mul_comba_fixed<const L: usize>(a: &[u64; L], b: &[u64; L]) -> ([u64; L], [u64; L]) {
    let mut lo = [0u64; L];
    let mut hi = [0u64; L];
    if L == 0 {
        return (lo, hi);
    }
    let mut acc: u128 = 0; // low 128 bits of the running column sum
    let mut over: u64 = 0; // count of 2^128 overflows within one column
    for k in 0..L {
        for i in 0..=k {
            let (plo, phi) = a[i].widening_mul(b[k - i]);
            let (s, c) = acc.overflowing_add(((phi as u128) << 64) | plo as u128);
            acc = s;
            over += c as u64;
        }
        lo[k] = acc as u64;
        acc = (acc >> 64) | ((over as u128) << 64);
        over = 0;
    }
    for k in L..(2 * L - 1) {
        for i in (k - (L - 1))..L {
            let (plo, phi) = a[i].widening_mul(b[k - i]);
            let (s, c) = acc.overflowing_add(((phi as u128) << 64) | plo as u128);
            acc = s;
            over += c as u64;
        }
        hi[k - L] = acc as u64;
        acc = (acc >> 64) | ((over as u128) << 64);
        over = 0;
    }
    hi[L - 1] = acc as u64;
    debug_assert_eq!(acc >> 64, 0, "comba column carry must be consumed");
    (lo, hi)
}

/// Single-level Karatsuba on fixed arrays (`L` even): three half-width
/// Comba products plus the `|a1 - a0| * |b1 - b0|` recombination — one
/// level only, because a monomorphized recursion would instantiate kernels
/// for every half-width.  Reached only when `L >=` the compiled crossover
/// ([`fixed_uses_karatsuba`]); the paper's 7/15-limb widths never take it.
// apfp-lint: no_alloc
fn mul_karatsuba1_fixed<const L: usize>(a: &[u64; L], b: &[u64; L]) -> ([u64; L], [u64; L]) {
    debug_assert!(L >= 2 && L % 2 == 0, "single-level split needs an even width");
    let h = L / 2;
    // c0 = a0*b0 fills lo (2h = L limbs); c2 = a1*b1 fills hi.
    let mut lo = [0u64; L];
    let mut hi = [0u64; L];
    super::mul_comba(&a[..h], &b[..h], &mut lo);
    super::mul_comba(&a[h..], &b[h..], &mut hi);
    // t = |a1 - a0| * |b1 - b0|, sign tracked like kara_rec's abs_diff.
    let mut da = [0u64; L];
    let mut db = [0u64; L];
    let sa = abs_diff_halves(&a[h..], &a[..h], &mut da[..h]);
    let sb = abs_diff_halves(&b[h..], &b[..h], &mut db[..h]);
    let mut t = [0u64; L];
    super::mul_comba(&da[..h], &db[..h], &mut t);
    // middle = c0 + c2 -+ t, held in L limbs plus a top carry limb.
    let mut c1 = lo;
    let mut c1_top: u64 = 0;
    if super::add_assign(&mut c1, &hi) {
        c1_top += 1;
    }
    if sa != sb {
        // (a1 - a0)(b1 - b0) < 0: the cross term gains t
        if super::add_assign(&mut c1, &t) {
            c1_top += 1;
        }
    } else if super::sub_assign(&mut c1, &t) {
        debug_assert!(c1_top > 0, "karatsuba middle term must be nonnegative");
        c1_top -= 1;
    }
    add_middle_at(&mut lo, &mut hi, h, &c1, c1_top);
    (lo, hi)
}

/// `|x - y|` into `out` for equal-length halves; returns true when the
/// difference is negative (`x < y`).
// apfp-lint: no_alloc
fn abs_diff_halves(x: &[u64], y: &[u64], out: &mut [u64]) -> bool {
    if super::cmp(x, y) == Ordering::Less {
        out.copy_from_slice(y);
        let borrow = super::sub_assign(out, x);
        debug_assert!(!borrow);
        true
    } else {
        out.copy_from_slice(x);
        let borrow = super::sub_assign(out, y);
        debug_assert!(!borrow);
        false
    }
}

/// Add the `(v, v_top)` middle term into the split product at limb
/// position `pos` of the conceptual `2L`-limb number `(lo, hi)`,
/// propagating the carry to the top.
// apfp-lint: no_alloc
fn add_middle_at<const L: usize>(
    lo: &mut [u64; L],
    hi: &mut [u64; L],
    pos: usize,
    v: &[u64; L],
    v_top: u64,
) {
    let mut carry = 0u64;
    for i in 0..=L {
        let limb = if i < L { v[i] } else { v_top };
        let j = pos + i;
        let dst = if j < L { &mut lo[j] } else { &mut hi[j - L] };
        let (s1, c1) = dst.overflowing_add(limb);
        let (s2, c2) = s1.overflowing_add(carry);
        *dst = s2;
        carry = (c1 | c2) as u64;
    }
    let mut j = pos + L + 1;
    while carry != 0 && j < 2 * L {
        let dst = if j < L { &mut lo[j] } else { &mut hi[j - L] };
        let (s, c) = dst.overflowing_add(carry);
        *dst = s;
        carry = c as u64;
        j += 1;
    }
    debug_assert_eq!(carry, 0, "karatsuba recombination cannot overflow 2L limbs");
}

/// The fixed-width adder workspace: `[1 guard limb | L mantissa limbs |
/// 1 overflow limb]`, the exact layout `softfloat`'s dynamic `add_core`
/// builds in its `ws = n + 2` stack/arena buffer, as a struct because
/// stable Rust cannot spell `[u64; L + 2]`.  Limb index 0 is the guard,
/// `1..=L` the mantissa window, `L + 1` the overflow limb; every operation
/// mirrors the corresponding [`super`] slice helper on that virtual
/// `(L + 2)`-limb little-endian vector.
#[derive(Clone, Copy, Debug)]
pub struct Guarded<const L: usize> {
    guard: u64,
    mid: [u64; L],
    over: u64,
}

impl<const L: usize> Guarded<L> {
    /// Number of limbs of the virtual vector (the dynamic path's `ws`).
    pub const WS: usize = L + 2;

    /// A mantissa placed in the window: MSB at bit `64 + 64*L - 1`, guard
    /// and overflow limbs clear — exactly `ws_big[1..1 + n]` in `add_core`.
    #[inline]
    pub fn place(mant: &[u64; L]) -> Self {
        Guarded { guard: 0, mid: *mant, over: 0 }
    }

    #[inline(always)]
    fn limb(&self, i: usize) -> u64 {
        if i == 0 {
            self.guard
        } else if i <= L {
            self.mid[i - 1]
        } else if i == L + 1 {
            self.over
        } else {
            0 // reads past the top zero-extend, like the dynamic slices
        }
    }

    #[inline(always)]
    fn set_limb(&mut self, i: usize, v: u64) {
        if i == 0 {
            self.guard = v;
        } else if i <= L {
            self.mid[i - 1] = v;
        } else {
            debug_assert_eq!(i, L + 1);
            self.over = v;
        }
    }

    /// `self >>= s`, mirroring [`super::shr`] on the `(L + 2)`-limb vector.
    /// In place is safe: limb `i` is written after only limbs `>= i` are
    /// read, and the walk ascends.
    #[inline]
    pub fn shr_assign(&mut self, s: usize) {
        let (q, r) = (s / 64, s % 64);
        for i in 0..L + 2 {
            let lo = self.limb(i + q);
            let hi = self.limb(i + q + 1);
            self.set_limb(i, if r == 0 { lo } else { (lo >> r) | (hi << (64 - r)) });
        }
    }

    /// True iff any bit strictly below position `s` is set — the sticky
    /// signal, mirroring [`super::sticky_below`].
    #[inline]
    pub fn sticky_below(&self, s: usize) -> bool {
        let (q, r) = (s / 64, s % 64);
        for i in 0..q.min(L + 2) {
            if self.limb(i) != 0 {
                return true;
            }
        }
        r > 0 && q < L + 2 && self.limb(q) & ((1u64 << r) - 1) != 0
    }

    /// `self += other`; returns the carry out of the overflow limb.
    #[inline]
    pub fn add_assign(&mut self, other: &Self) -> bool {
        let mut carry = false;
        for i in 0..L + 2 {
            let (s1, c1) = self.limb(i).overflowing_add(other.limb(i));
            let (s2, c2) = s1.overflowing_add(carry as u64);
            self.set_limb(i, s2);
            carry = c1 | c2;
        }
        carry
    }

    /// `self -= other`; returns the borrow out of the overflow limb.
    #[inline]
    pub fn sub_assign(&mut self, other: &Self) -> bool {
        let mut borrow = false;
        for i in 0..L + 2 {
            let (d1, b1) = self.limb(i).overflowing_sub(other.limb(i));
            let (d2, b2) = d1.overflowing_sub(borrow as u64);
            self.set_limb(i, d2);
            borrow = b1 | b2;
        }
        borrow
    }

    /// `self -= v` (single limb); returns the borrow out of the top.
    #[inline]
    pub fn sub_limb(&mut self, v: u64) -> bool {
        let mut borrow = v;
        for i in 0..L + 2 {
            if borrow == 0 {
                return false;
            }
            let (d, b) = self.limb(i).overflowing_sub(borrow);
            self.set_limb(i, d);
            borrow = b as u64;
        }
        borrow != 0
    }

    /// Number of significant bits, mirroring [`super::bit_length`].
    #[inline]
    pub fn bit_length(&self) -> usize {
        if self.over != 0 {
            return 64 * (L + 1) + (64 - self.over.leading_zeros() as usize);
        }
        for i in (0..L).rev() {
            if self.mid[i] != 0 {
                return 64 * (i + 1) + (64 - self.mid[i].leading_zeros() as usize);
            }
        }
        if self.guard != 0 { 64 - self.guard.leading_zeros() as usize } else { 0 }
    }

    /// `out = self >> s`, truncated to `L` limbs ([`super::shr`] with a
    /// narrower output) — the renormalize-right step of the adder.
    #[inline]
    pub fn shr_into(&self, s: usize, out: &mut [u64; L]) {
        let (q, r) = (s / 64, s % 64);
        for i in 0..L {
            let lo = self.limb(i + q);
            let hi = self.limb(i + q + 1);
            out[i] = if r == 0 { lo } else { (lo >> r) | (hi << (64 - r)) };
        }
    }

    /// `out = self << s`, truncated to `L` limbs ([`super::shl`] with a
    /// narrower output) — the renormalize-left step of the adder.
    #[inline]
    pub fn shl_into(&self, s: usize, out: &mut [u64; L]) {
        let (q, r) = (s / 64, s % 64);
        for i in (0..L).rev() {
            let lo = if i >= q { self.limb(i - q) } else { 0 };
            let lo2 = if i >= q + 1 { self.limb(i - q - 1) } else { 0 };
            out[i] = if r == 0 { lo } else { (lo << r) | (lo2 >> (64 - r)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{
        bit_length, mul_comba, mul_karatsuba_with, shl, shr, sticky_below, Scratch,
    };
    use super::*;
    use crate::testkit;

    fn arr<const L: usize>(rng: &mut testkit::Rng) -> [u64; L] {
        let mut a = [0u64; L];
        for x in a.iter_mut() {
            *x = rng.next_u64();
        }
        a
    }

    fn joined<const L: usize>(lo: &[u64; L], hi: &[u64; L]) -> Vec<u64> {
        let mut v = lo.to_vec();
        v.extend_from_slice(hi);
        v
    }

    #[test]
    fn comba_fixed_matches_dynamic_comba_at_paper_widths() {
        testkit::check(300, |rng| {
            {
                let (a, b) = (arr::<7>(rng), arr::<7>(rng));
                let mut want = vec![0u64; 14];
                mul_comba(&a, &b, &mut want);
                let (lo, hi) = mul_comba_fixed(&a, &b);
                assert_eq!(joined(&lo, &hi), want, "L=7");
            }
            {
                let (a, b) = (arr::<15>(rng), arr::<15>(rng));
                let mut want = vec![0u64; 30];
                mul_comba(&a, &b, &mut want);
                let (lo, hi) = mul_comba_fixed(&a, &b);
                assert_eq!(joined(&lo, &hi), want, "L=15");
            }
        });
    }

    #[test]
    fn comba_fixed_column_overflow_stress() {
        // all-ones operands wrap the 128-bit accumulator maximally, so the
        // `over` counter must carry every wrap — same stress as the
        // dynamic kernel's test, on the fixed split-output form
        let a = [u64::MAX; 15];
        let mut want = vec![0u64; 30];
        mul_comba(&a, &a, &mut want);
        let (lo, hi) = mul_comba_fixed(&a, &a);
        assert_eq!(joined(&lo, &hi), want);
    }

    #[test]
    fn comba_fixed_single_limb() {
        let (lo, hi) = mul_comba_fixed(&[u64::MAX], &[u64::MAX]);
        let t = u64::MAX as u128 * u64::MAX as u128;
        assert_eq!((lo[0], hi[0]), (t as u64, (t >> 64) as u64));
    }

    #[test]
    fn karatsuba1_fixed_matches_comba_at_even_widths() {
        // the single-level split is below the live crossover for 7/15, so
        // exercise it directly at even widths (including the crossover
        // width itself)
        testkit::check(200, |rng| {
            {
                let (a, b) = (arr::<8>(rng), arr::<8>(rng));
                let (wl, wh) = mul_comba_fixed(&a, &b);
                let (gl, gh) = mul_karatsuba1_fixed(&a, &b);
                assert_eq!((gl, gh), (wl, wh), "L=8");
            }
            {
                let (a, b) = (arr::<40>(rng), arr::<40>(rng));
                let (wl, wh) = mul_comba_fixed(&a, &b);
                let (gl, gh) = mul_karatsuba1_fixed(&a, &b);
                assert_eq!((gl, gh), (wl, wh), "L=40 (crossover width)");
            }
        });
    }

    #[test]
    fn karatsuba1_fixed_recombination_edges() {
        // operand halves crafted to flip the abs_diff signs and saturate
        // the middle-term carry: equal halves (t = 0), max low / zero high
        // and vice versa
        let mut a = [0u64; 8];
        let mut b = [0u64; 8];
        for i in 0..4 {
            a[i] = u64::MAX; // a0 = max, a1 = 0  -> sa flips
            b[i + 4] = u64::MAX; // b0 = 0, b1 = max  -> sb flips
        }
        let (wl, wh) = mul_comba_fixed(&a, &b);
        assert_eq!(mul_karatsuba1_fixed(&a, &b), (wl, wh));
        let c = [u64::MAX; 8]; // equal halves: t = 0
        let (wl, wh) = mul_comba_fixed(&c, &c);
        assert_eq!(mul_karatsuba1_fixed(&c, &c), (wl, wh));
    }

    #[test]
    fn compile_time_selection_matches_spec() {
        // paper widths stay Comba; the crossover and only even widths
        // at/above it take the single-level split (odd -> Comba, exactly
        // like kara_rec's odd-n bottom-out)
        assert!(!fixed_uses_karatsuba(7));
        assert!(!fixed_uses_karatsuba(15));
        assert!(!fixed_uses_karatsuba(39));
        assert!(fixed_uses_karatsuba(KARATSUBA_THRESHOLD));
        assert!(!fixed_uses_karatsuba(KARATSUBA_THRESHOLD + 1)); // odd
        assert!(fixed_uses_karatsuba(KARATSUBA_THRESHOLD + 2));
    }

    #[test]
    fn threshold_override_cannot_desync_fixed_path() {
        // Satellite: APFP_KARATSUBA_THRESHOLD only moves where the dynamic
        // path splits.  Emulate every override class by calling the dynamic
        // kernel with explicit thresholds and require bit-equality with the
        // fixed kernel, whose selection is compiled in.
        let mut scratch = Scratch::new();
        testkit::check(100, |rng| {
            let (a, b) = (arr::<8>(rng), arr::<8>(rng));
            let (lo, hi) = mul_fixed(&a, &b);
            let got = joined(&lo, &hi);
            for threshold in [2usize, 4, 8, KARATSUBA_THRESHOLD, 1000] {
                let mut want = vec![0u64; 16];
                mul_karatsuba_with(&a, &b, &mut want, threshold, &mut scratch);
                assert_eq!(got, want, "threshold={threshold}");
            }
            // and at a live paper width
            let (a, b) = (arr::<7>(rng), arr::<7>(rng));
            let (lo, hi) = mul_fixed(&a, &b);
            let got = joined(&lo, &hi);
            for threshold in [2usize, 7, 1000] {
                let mut want = vec![0u64; 14];
                mul_karatsuba_with(&a, &b, &mut want, threshold, &mut scratch);
                assert_eq!(got, want, "threshold={threshold} L=7");
            }
        });
    }

    #[test]
    fn guarded_mirrors_dynamic_slice_helpers() {
        testkit::check(300, |rng| {
            const L: usize = 7;
            let m = arr::<L>(rng);
            // the dynamic workspace: [guard | L | overflow]
            let mut ws = vec![0u64; L + 2];
            ws[1..1 + L].copy_from_slice(&m);
            let g = Guarded::<L>::place(&m);
            assert_eq!(g.bit_length(), bit_length(&ws));

            let s = rng.below((64 * (L + 2) + 5) as u64) as usize;
            assert_eq!(g.sticky_below(s), sticky_below(&ws, s), "sticky s={s}");

            let mut shifted = g;
            shifted.shr_assign(s);
            let mut want = vec![0u64; L + 2];
            shr(&ws, s, &mut want);
            let got: Vec<u64> = (0..L + 2).map(|i| shifted.limb(i)).collect();
            assert_eq!(got, want, "shr_assign s={s}");

            // narrowing extracts
            let mut out = [0u64; L];
            g.shr_into(s, &mut out);
            let mut want_n = vec![0u64; L];
            shr(&ws, s, &mut want_n);
            assert_eq!(out.to_vec(), want_n, "shr_into s={s}");
            let sl = rng.below(64 * L as u64) as usize;
            g.shl_into(sl, &mut out);
            shl(&ws, sl, &mut want_n);
            assert_eq!(out.to_vec(), want_n, "shl_into s={sl}");
        });
    }

    #[test]
    fn guarded_add_sub_roundtrip_with_flags() {
        testkit::check(200, |rng| {
            const L: usize = 7;
            let a = Guarded::<L>::place(&arr::<L>(rng));
            let b = Guarded::<L>::place(&arr::<L>(rng));
            let mut c = a;
            let carry = c.add_assign(&b);
            assert!(!carry, "overflow limb absorbs mantissa-window carries");
            let borrow = c.sub_assign(&b);
            assert!(!borrow);
            let got: Vec<u64> = (0..L + 2).map(|i| c.limb(i)).collect();
            let want: Vec<u64> = (0..L + 2).map(|i| a.limb(i)).collect();
            assert_eq!(got, want);
            // sub_limb borrows through zero limbs
            let mut z = Guarded::<L>::place(&[0; L]);
            z.over = 1;
            assert!(!z.sub_limb(1));
            assert_eq!(z.bit_length(), 64 * (L + 1));
        });
    }

    #[test]
    fn widening_mul_limb_trait() {
        let (lo, hi) = 0xFFFF_FFFF_FFFF_FFFFu64.widening_mul(2);
        assert_eq!((lo, hi), (0xFFFF_FFFF_FFFF_FFFE, 1));
        let (lo, hi) = 3u64.widening_mul(4);
        assert_eq!((lo, hi), (12, 0));
    }
}
