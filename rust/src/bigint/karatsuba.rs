//! Recursive Karatsuba multiplication — the software mirror of the paper's
//! §II-A decomposition (Lst. 1).
//!
//! A single recursion step on 2n-limb operands a = a0 + B·a1, b = b0 + B·b1
//! (B = 2^(64n)) computes, exactly as the paper writes it:
//!
//! ```text
//!     c0 = a0·b0
//!     c2 = a1·b1
//!     t  = |a1 - a0| · |b1 - b0|
//!     s  = sign[(a1 - a0)(b1 - b0)]
//!     c1 = c0 + c2 - (-1)^s · t
//!     c  = c0 + B·c1 + B²·c2
//! ```
//!
//! The sign bit `s` is tracked explicitly so that all three
//! sub-multiplications stay at n limbs — the same trick the paper uses to
//! keep its FPGA multipliers at half width (in the JAX/Pallas kernel we use
//! the carry-save (a0+a1)(b0+b1) variant instead; see DESIGN.md
//! §Hardware-Adaptation for why each substrate gets its own variant).
//!
//! The recursion bottoms out on [`super::mul_comba`] below `base_limbs`,
//! the software analog of `APFP_MULT_BASE_BITS`.

use super::{add_assign, add_limb, cmp, mul_comba, sub_assign, Scratch};
use std::cmp::Ordering;

/// Default limb count at/above which `mul_auto` prefers Karatsuba.
///
/// The 32-limb (2048-bit) crossover was measured against the row-wise
/// schoolbook (EXPERIMENTS.md §Perf P3); the Comba columnwise swap lowers
/// the basecase constant (one memory write per output limb), which moves
/// the crossover *up* — the recursion's add/recombination overhead did not
/// get cheaper, only the n^2 side did.  40 limbs is the re-estimated
/// default on that reasoning; both paper widths (7 / 15 limbs) sit far
/// below either value on the Comba kernel, exactly as MPFR stays on `mpn`
/// basecase at these sizes.  Pin the measured value per host with
/// `cargo bench --bench fig3_sweep` (it prints the direct Comba-vs-
/// Karatsuba crossover table) and the `APFP_KARATSUBA_THRESHOLD` override
/// (read once, see [`karatsuba_threshold`]).
pub const KARATSUBA_THRESHOLD: usize = 40;

/// Strict parse of an `APFP_KARATSUBA_THRESHOLD` override value: a
/// positive integer, clamped to >= 2 so the recursion stays meaningful.
/// `None` when the value is malformed (non-numeric, negative, zero) —
/// [`karatsuba_threshold`] then warns and falls back, while the strict
/// config path ([`crate::config::ApfpConfig::try_from_env_with`]) turns
/// it into a typed error.
pub fn parse_threshold(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().filter(|&t| t > 0).map(|t| t.max(2))
}

/// The active Karatsuba crossover: `APFP_KARATSUBA_THRESHOLD` when set to
/// a positive integer (clamped to >= 2 so the recursion stays meaningful),
/// otherwise [`KARATSUBA_THRESHOLD`].  Parsed once per process; a
/// malformed value warns on stderr and keeps the default.
pub fn karatsuba_threshold() -> usize {
    static THRESHOLD: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *THRESHOLD.get_or_init(|| match std::env::var("APFP_KARATSUBA_THRESHOLD") {
        Ok(v) => parse_threshold(&v).unwrap_or_else(|| {
            eprintln!(
                "APFP_KARATSUBA_THRESHOLD={v:?} is not a positive integer; \
                 using {KARATSUBA_THRESHOLD}"
            );
            KARATSUBA_THRESHOLD
        }),
        Err(_) => KARATSUBA_THRESHOLD,
    })
}

/// out = a * b with recursive Karatsuba bottoming out at `base_limbs`,
/// using the thread-local scratch arena (steady-state allocation-free).
/// Requires a.len() == b.len() and out.len() == 2 * a.len().
pub fn mul_karatsuba(a: &[u64], b: &[u64], out: &mut [u64], base_limbs: usize) {
    super::with_scratch(|s| mul_karatsuba_with(a, b, out, base_limbs, s));
}

/// [`mul_karatsuba`] against an explicit [`Scratch`] arena.
///
/// One workspace is taken from the arena at the top and partitioned down
/// the recursion (§Perf P2 in EXPERIMENTS.md: per-level `Vec` allocations
/// made the recursion slower than schoolbook at every practical width; the
/// arena removes even the single top-level allocation across calls).
// apfp-lint: no_alloc
pub fn mul_karatsuba_with(
    a: &[u64],
    b: &[u64],
    out: &mut [u64],
    base_limbs: usize,
    scratch: &mut Scratch,
) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(out.len(), 2 * a.len());
    // scratch need: S(n) = 3n + 1 + S(n/2)  =>  < 7n; round up generously
    let ws = scratch.kara_ws(8 * a.len() + 8);
    kara_rec(a, b, out, ws, base_limbs);
}

/// Recursive step writing into `out`, using (a prefix of) `scratch`.
fn kara_rec(a: &[u64], b: &[u64], out: &mut [u64], scratch: &mut [u64], base_limbs: usize) {
    let n = a.len();
    // Odd splits complicate the |a1-a0| step; recurse only on even sizes.
    if n <= base_limbs.max(1) || n % 2 != 0 {
        mul_comba(a, b, out);
        return;
    }
    let h = n / 2;
    let (a0, a1) = a.split_at(h);
    let (b0, b1) = b.split_at(h);

    // scratch layout: [da: h | db: h | t: n | c1: n+1 | child scratch]
    let (da, rest) = scratch.split_at_mut(h);
    let (db, rest) = rest.split_at_mut(h);
    let (t, rest) = rest.split_at_mut(n);
    let (c1, child) = rest.split_at_mut(n + 1);

    // c0 = a0*b0, c2 = a1*b1 — straight into the (disjoint) halves of the
    // output buffer; the recombination then reads them back as c0 + B^2 c2.
    {
        let (lo, hi) = out.split_at_mut(n);
        kara_rec(a0, b0, lo, child, base_limbs);
        kara_rec(a1, b1, hi, child, base_limbs);
    }

    // |a1 - a0| and |b1 - b0| with explicit sign tracking (paper's `s`).
    let sa = abs_diff(a1, a0, da);
    let sb = abs_diff(b1, b0, db);
    let s_negative = sa != sb; // sign of (a1-a0)(b1-b0)
    kara_rec(da, db, t, child, base_limbs);

    // c1 = c0 + c2 -+ t, built in n+1 limbs (the paper's (2n+2)-bit c1).
    c1[..n].copy_from_slice(&out[..n]);
    c1[n] = 0;
    let carry = add_assign(&mut c1[..n], &out[n..]);
    if carry {
        add_limb(&mut c1[n..], 1);
    }
    if s_negative {
        // (a1-a0)(b1-b0) < 0  =>  c1 = c0 + c2 + t
        let carry = add_assign(&mut c1[..n], t);
        if carry {
            add_limb(&mut c1[n..], 1);
        }
    } else {
        // c1 = c0 + c2 - t; never underflows (c1 = a0*b1 + a1*b0 >= 0)
        let borrow = sub_assign(&mut c1[..n], t);
        if borrow {
            let under = sub_limb(&mut c1[n..], 1);
            debug_assert!(!under, "karatsuba middle term underflow");
        }
    }

    // c = (c0 + B^2 c2, already in out) + B*c1
    let carry = add_assign(&mut out[h..h + n + 1], c1);
    if carry {
        let over = add_limb(&mut out[h + n + 1..], 1);
        debug_assert!(!over, "karatsuba recombination overflow");
    }
}

use super::sub_limb;

/// out = |x - y|; returns true iff x < y (the tracked sign bit).
fn abs_diff(x: &[u64], y: &[u64], out: &mut [u64]) -> bool {
    match cmp(x, y) {
        Ordering::Less => {
            out.copy_from_slice(y);
            let borrow = sub_assign(out, x);
            debug_assert!(!borrow);
            true
        }
        _ => {
            out.copy_from_slice(x);
            let borrow = sub_assign(out, y);
            debug_assert!(!borrow);
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigint::mul_schoolbook;
    use crate::testkit;

    fn check_vs_schoolbook(n: usize, base: usize, cases: u64) {
        testkit::check(cases, |rng| {
            let a = rng.limbs(n);
            let b = rng.limbs(n);
            let mut want = vec![0u64; 2 * n];
            let mut got = vec![0u64; 2 * n];
            mul_schoolbook(&a, &b, &mut want);
            mul_karatsuba(&a, &b, &mut got, base);
            assert_eq!(got, want, "n={n} base={base}");
        });
    }

    #[test]
    fn matches_schoolbook_power_of_two_sizes() {
        for n in [2, 4, 8, 16, 32] {
            check_vs_schoolbook(n, 1, 20);
        }
    }

    #[test]
    fn matches_schoolbook_odd_and_mixed_sizes() {
        for n in [3, 6, 7, 10, 14, 24] {
            check_vs_schoolbook(n, 2, 20);
        }
    }

    #[test]
    fn base_width_sweep() {
        // Every bottom-out threshold must give identical results — the
        // software version of the paper's Fig. 3 MULT_BASE_BITS sweep.
        for base in [1, 2, 4, 8, 16] {
            check_vs_schoolbook(16, base, 10);
        }
    }

    #[test]
    fn extreme_operands() {
        let n = 8;
        for (a, b) in [
            (vec![u64::MAX; n], vec![u64::MAX; n]),
            (vec![0u64; n], vec![u64::MAX; n]),
            ({ let mut v = vec![0u64; n]; v[0] = 1; v }, vec![u64::MAX; n]),
            ({ let mut v = vec![0u64; n]; v[n - 1] = u64::MAX; v },
             { let mut v = vec![0u64; n]; v[n - 1] = u64::MAX; v }),
        ] {
            let mut want = vec![0u64; 2 * n];
            let mut got = vec![0u64; 2 * n];
            mul_schoolbook(&a, &b, &mut want);
            mul_karatsuba(&a, &b, &mut got, 2);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn sign_tracking_both_branches() {
        // force a1 < a0 (negative diff) against b1 > b0 and vice versa
        let a = vec![u64::MAX, u64::MAX, 1, 0]; // a1 << a0
        let b = vec![1, 0, u64::MAX, u64::MAX]; // b1 >> b0
        let mut want = vec![0u64; 8];
        let mut got = vec![0u64; 8];
        mul_schoolbook(&a, &b, &mut want);
        mul_karatsuba(&a, &b, &mut got, 2);
        assert_eq!(got, want);
    }

    #[test]
    fn deep_recursion() {
        check_vs_schoolbook(64, 2, 5); // 5 levels of decomposition
    }

    #[test]
    fn explicit_arena_matches_wrapper_and_is_reusable() {
        let mut scratch = Scratch::new();
        testkit::check(20, |rng| {
            for n in [8usize, 16, 32] {
                let a = rng.limbs(n);
                let b = rng.limbs(n);
                let mut want = vec![0u64; 2 * n];
                let mut got = vec![0u64; 2 * n];
                mul_karatsuba(&a, &b, &mut want, 2);
                mul_karatsuba_with(&a, &b, &mut got, 2, &mut scratch);
                assert_eq!(got, want, "n={n}");
            }
        });
    }
}
