//! Fixed-width unsigned big-integer arithmetic on little-endian `u64` limbs.
//!
//! This is the software substrate the APFP float library (`softfloat`) is
//! built on — the role GMP's `mpn` layer plays under MPFR in the paper's CPU
//! baseline.  Limb vectors are little-endian (`a[0]` least significant) and
//! most operations take fixed-width slices.
//!
//! Multiplication follows GMP's strategy: a Comba-style columnwise
//! schoolbook (the `MULX`/`ADCX` column kernel a Broadwell Xeon runs, here
//! expressed as `u128` multiply-accumulate) below a threshold, and the
//! recursive Karatsuba decomposition of the paper's §II-A above it (see
//! [`karatsuba`]).  All kernels run against a reusable [`Scratch`]
//! arena, so the hot path is allocation-free in steady state.

pub mod fixed;
pub mod karatsuba;
pub mod toom3;

use std::cell::RefCell;
use std::cmp::Ordering;

pub use fixed::{fixed_uses_karatsuba, mul_comba_fixed, mul_fixed, Guarded, Limb};
pub use karatsuba::{karatsuba_threshold, mul_karatsuba, mul_karatsuba_with, KARATSUBA_THRESHOLD};
pub use toom3::{mul_toom3, mul_toom3_with};

/// Reusable scratch arena for the arithmetic hot paths (mul, add/sub/mac
/// alignment, div normalization).
///
/// One instance serves any operand width and every operator: each buffer
/// grows to its high-water mark and is reused across calls, so the whole
/// steady-state MAC pipeline — [`mul_auto_with`], `ApFloat::{mul_into,
/// add_into, mac_into}` and the GEMM inner loops built on them — performs
/// zero heap allocations.  A thread-local instance backs the scratch-free
/// convenience wrappers ([`mul_auto`], [`mul_karatsuba`], [`mul_toom3`],
/// `ApFloat::{mul, add, sub, mac, div}`); the `*_with` kernels never touch
/// the thread-local, so a borrowed arena can be threaded down a whole call
/// tree (one arena per GEMM worker thread).
#[derive(Debug, Default)]
pub struct Scratch {
    /// Karatsuba recursion workspace (partitioned down the recursion).
    kara: Vec<u64>,
    /// Double-width product buffer for the softfloat mantissa multiply.
    prod: Vec<u64>,
    /// Adder alignment workspace for widths beyond the stack fast path.
    addws: Vec<u64>,
    /// Recycled result buffers (see `softfloat::recycle`).
    pool: Vec<Vec<u64>>,
    /// Count of arena operations (workspace takes) since the last
    /// [`Scratch::reset_arena_ops`] — the structural counter
    /// `benches/fixed_vs_dynamic.rs` asserts on: every take is at least
    /// one pointer chase the fixed-width stack kernels do not pay.
    ops: u64,
}

/// Former name of [`Scratch`], kept while it was multiply-only; the arena
/// now also backs the adder and divider paths.
pub type MulScratch = Scratch;

/// Recycle-pool depth cap, so stray widths cannot grow the arena unbounded.
const POOL_CAP: usize = 32;

impl Scratch {
    pub const fn new() -> Self {
        Scratch { kara: Vec::new(), prod: Vec::new(), addws: Vec::new(), pool: Vec::new(), ops: 0 }
    }

    /// Arena operations (workspace takes) performed since the last
    /// [`Scratch::reset_arena_ops`].  Each counted op is a buffer handoff
    /// through the arena — at minimum one pointer chase on the dynamic hot
    /// path; the `ApFloatN` fixed path performs none by construction.
    pub fn arena_ops(&self) -> u64 {
        self.ops
    }

    /// Reset the [`Scratch::arena_ops`] counter (bench bookkeeping).
    pub fn reset_arena_ops(&mut self) {
        self.ops = 0;
    }

    /// Karatsuba workspace of at least `len` limbs.  Contents are
    /// arbitrary: the recursion fully writes every region before reading it.
    fn kara_ws(&mut self, len: usize) -> &mut [u64] {
        self.ops += 1;
        if self.kara.len() < len {
            // apfp-lint: allow(alloc, reason="arena growth: reallocates only when a wider operand arrives; warm widths hit the len check")
            self.kara.resize(len, 0);
        }
        &mut self.kara[..len]
    }

    /// Take the double-width product buffer, resized to `len` zeroed limbs.
    /// Return it with [`Scratch::put_prod`] when done so the next call
    /// reuses the capacity (the buffer moves out to sidestep the borrow of
    /// `self` that the multiply kernels need concurrently).
    pub fn take_prod(&mut self, len: usize) -> Vec<u64> {
        self.ops += 1;
        let mut v = std::mem::take(&mut self.prod);
        v.clear();
            // apfp-lint: allow(alloc, reason="pool reuse: clear+resize fills recycled capacity; reallocates only when the width grows")
        v.resize(len, 0);
        v
    }

    /// Return the product buffer taken by [`Scratch::take_prod`].
    pub fn put_prod(&mut self, v: Vec<u64>) {
        if v.capacity() > self.prod.capacity() {
            self.prod = v;
        }
    }

    /// Take the adder alignment workspace, resized to `len` zeroed limbs
    /// (the `ApFloat` adder needs it only for widths past its stack fast
    /// path).  Same move-out contract as [`Scratch::take_prod`].
    pub fn take_addws(&mut self, len: usize) -> Vec<u64> {
        self.ops += 1;
        let mut v = std::mem::take(&mut self.addws);
        v.clear();
            // apfp-lint: allow(alloc, reason="pool reuse: clear+resize fills recycled capacity; reallocates only when the width grows")
        v.resize(len, 0);
        v
    }

    /// Return the workspace taken by [`Scratch::take_addws`].
    pub fn put_addws(&mut self, v: Vec<u64>) {
        if v.capacity() > self.addws.capacity() {
            self.addws = v;
        }
    }

    /// Take a recycled result buffer of exactly `len` zeroed limbs
    /// (allocates only when the pool is empty or the capacity is short).
    pub fn take_limbs(&mut self, len: usize) -> Vec<u64> {
        self.ops += 1;
        let mut v = self.pool.pop().unwrap_or_default();
        v.clear();
            // apfp-lint: allow(alloc, reason="pool reuse: clear+resize fills recycled capacity; reallocates only when the width grows")
        v.resize(len, 0);
        v
    }

    /// Return a result buffer to the recycle pool.
    pub fn put_limbs(&mut self, v: Vec<u64>) {
        if self.pool.len() < POOL_CAP {
            self.pool.push(v);
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = const { RefCell::new(Scratch::new()) };
}

/// Run `f` on this thread's shared [`Scratch`].  Not re-entrant: the
/// `*_with` kernels take the arena by `&mut` precisely so nothing below
/// them needs to borrow the thread-local again.
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// a += b (equal lengths); returns the carry out of the top limb.
pub fn add_assign(a: &mut [u64], b: &[u64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut carry = false;
    for (x, &y) in a.iter_mut().zip(b.iter()) {
        let (s1, c1) = x.overflowing_add(y);
        let (s2, c2) = s1.overflowing_add(carry as u64);
        *x = s2;
        carry = c1 | c2;
    }
    carry
}

/// a += v (single limb); returns the carry out of the top limb.
pub fn add_limb(a: &mut [u64], v: u64) -> bool {
    let mut carry = v;
    for x in a.iter_mut() {
        if carry == 0 {
            return false;
        }
        let (s, c) = x.overflowing_add(carry);
        *x = s;
        carry = c as u64;
    }
    carry != 0
}

/// a -= b (equal lengths); returns the borrow out of the top limb.
pub fn sub_assign(a: &mut [u64], b: &[u64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut borrow = false;
    for (x, &y) in a.iter_mut().zip(b.iter()) {
        let (d1, b1) = x.overflowing_sub(y);
        let (d2, b2) = d1.overflowing_sub(borrow as u64);
        *x = d2;
        borrow = b1 | b2;
    }
    borrow
}

/// a -= v (single limb); returns the borrow out of the top limb.
pub fn sub_limb(a: &mut [u64], v: u64) -> bool {
    let mut borrow = v;
    for x in a.iter_mut() {
        if borrow == 0 {
            return false;
        }
        let (d, b) = x.overflowing_sub(borrow);
        *x = d;
        borrow = b as u64;
    }
    borrow != 0
}

/// Lexicographic magnitude comparison of equal-length limb vectors.
pub fn cmp(a: &[u64], b: &[u64]) -> Ordering {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().rev().zip(b.iter().rev()) {
        match x.cmp(y) {
            Ordering::Equal => continue,
            ord => return ord,
        }
    }
    Ordering::Equal
}

pub fn is_zero(a: &[u64]) -> bool {
    a.iter().all(|&x| x == 0)
}

/// Number of significant bits (0 for the zero vector) — the LZC circuit of
/// the paper's adder, software edition.
pub fn bit_length(a: &[u64]) -> usize {
    for (i, &x) in a.iter().enumerate().rev() {
        if x != 0 {
            return 64 * i + (64 - x.leading_zeros() as usize);
        }
    }
    0
}

/// Read bit `i` (0 = LSB).
pub fn get_bit(a: &[u64], i: usize) -> bool {
    let (q, r) = (i / 64, i % 64);
    q < a.len() && (a[q] >> r) & 1 == 1
}

/// out = a << s, truncated to `out.len()` limbs (bits shifted beyond the top
/// are dropped, low bits fill with zero).  `out` may alias nothing.
pub fn shl(a: &[u64], s: usize, out: &mut [u64]) {
    let (q, r) = (s / 64, s % 64);
    for i in (0..out.len()).rev() {
        let lo = if i >= q && i - q < a.len() { a[i - q] } else { 0 };
        let lo2 = if i >= q + 1 && i - q - 1 < a.len() { a[i - q - 1] } else { 0 };
        out[i] = if r == 0 { lo } else { (lo << r) | (lo2 >> (64 - r)) };
    }
}

/// out = a >> s (bits shifted below bit 0 are dropped).
pub fn shr(a: &[u64], s: usize, out: &mut [u64]) {
    let (q, r) = (s / 64, s % 64);
    for i in 0..out.len() {
        let lo = if i + q < a.len() { a[i + q] } else { 0 };
        let hi = if i + q + 1 < a.len() { a[i + q + 1] } else { 0 };
        out[i] = if r == 0 { lo } else { (lo >> r) | (hi << (64 - r)) };
    }
}

/// a <<= 1 in place; returns the bit shifted out of the top limb.  The
/// divider uses this to place its guard bit without cloning the numerator.
pub fn shl1_in_place(a: &mut [u64]) -> u64 {
    let mut carry = 0u64;
    for x in a.iter_mut() {
        let next = *x >> 63;
        *x = (*x << 1) | carry;
        carry = next;
    }
    carry
}

/// True iff any bit of `a` strictly below position `s` is set — the sticky
/// signal for RNDZ subtraction correction (DESIGN.md §5).
pub fn sticky_below(a: &[u64], s: usize) -> bool {
    let (q, r) = (s / 64, s % 64);
    for &x in a.iter().take(q.min(a.len())) {
        if x != 0 {
            return true;
        }
    }
    if r > 0 && q < a.len() && a[q] & ((1u64 << r) - 1) != 0 {
        return true;
    }
    false
}

/// out = a * b, schoolbook (out.len() == a.len() + b.len()).
///
/// The inner step is a 64x64->128 multiply with carry chains — exactly the
/// MULX + ADCX/ADOX instruction mix the paper credits the Broadwell Xeon
/// baseline with (§V, Related Work), which LLVM emits for this u128 code.
pub fn mul_schoolbook(a: &[u64], b: &[u64], out: &mut [u64]) {
    debug_assert_eq!(out.len(), a.len() + b.len());
    out.fill(0);
    for (i, &x) in a.iter().enumerate() {
        if x == 0 {
            continue;
        }
        let mut carry: u64 = 0;
        for (j, &y) in b.iter().enumerate() {
            let t = x as u128 * y as u128 + out[i + j] as u128 + carry as u128;
            out[i + j] = t as u64;
            carry = (t >> 64) as u64;
        }
        out[i + b.len()] = carry;
    }
}

/// out = a * b, Comba-style columnwise schoolbook
/// (out.len() == a.len() + b.len()).
///
/// Where [`mul_schoolbook`] walks row-by-row and read-modify-writes every
/// output limb once per row, this kernel accumulates each output *column*
/// into a 128-bit accumulator (plus an overflow counter: two near-maximal
/// 64x64 products already exceed 2^128, so every wrap of the accumulator is
/// counted and re-injected one limb up) and writes each output limb exactly
/// once — the memory-traffic shape of the MULX/ADCX column kernels GMP uses
/// below its Karatsuba threshold.  This is the bottom-out kernel of
/// `mul_auto` and the Karatsuba recursion.
pub fn mul_comba(a: &[u64], b: &[u64], out: &mut [u64]) {
    debug_assert_eq!(out.len(), a.len() + b.len());
    let (na, nb) = (a.len(), b.len());
    if na == 0 || nb == 0 {
        out.fill(0);
        return;
    }
    let mut acc: u128 = 0; // low 128 bits of the running column sum
    let mut over: u64 = 0; // count of 2^128 overflows within one column
    for k in 0..na + nb - 1 {
        let i_lo = k.saturating_sub(nb - 1);
        let i_hi = k.min(na - 1);
        for i in i_lo..=i_hi {
            let (s, c) = acc.overflowing_add(a[i] as u128 * b[k - i] as u128);
            acc = s;
            over += c as u64;
        }
        out[k] = acc as u64;
        acc = (acc >> 64) | ((over as u128) << 64);
        over = 0;
    }
    out[na + nb - 1] = acc as u64;
    debug_assert_eq!(acc >> 64, 0, "comba column carry must be consumed");
}

/// out = a * b, choosing the Comba kernel or Karatsuba per GMP's threshold
/// strategy, on the thread-local scratch arena.  This is what `softfloat`
/// calls on its hot path when no explicit arena is in hand.
pub fn mul_auto(a: &[u64], b: &[u64], out: &mut [u64]) {
    with_scratch(|s| mul_auto_with(a, b, out, s));
}

/// [`mul_auto`] against an explicit scratch arena: allocation-free once the
/// arena is warm.  The crossover is [`karatsuba_threshold`] — compiled
/// default [`KARATSUBA_THRESHOLD`], overridable per host via the
/// `APFP_KARATSUBA_THRESHOLD` environment variable.
// apfp-lint: no_alloc
pub fn mul_auto_with(a: &[u64], b: &[u64], out: &mut [u64], scratch: &mut Scratch) {
    let threshold = karatsuba_threshold();
    if a.len() < threshold || a.len() != b.len() {
        mul_comba(a, b, out);
    } else {
        mul_karatsuba_with(a, b, out, threshold, scratch);
    }
}

/// Long division: (quotient, remainder) of num / den, den != 0, on the
/// thread-local scratch arena.
pub fn div_rem(num: &[u64], den: &[u64]) -> (Vec<u64>, Vec<u64>) {
    with_scratch(|s| div_rem_with(num, den, s))
}

/// [`div_rem`] against an explicit arena: the normalization workspaces come
/// from the recycle pool, and so do the returned quotient/remainder buffers
/// (hand them back with [`Scratch::put_limbs`] to keep a hot loop
/// allocation-free once the pool is warm).
///
/// Knuth-style limb division with a 128/64 digit estimate refined by the
/// classic at-most-two correction steps.  Division is *not* on the paper's
/// accelerated path (it inherits its cost from multiplication, §I); this
/// exists for the softfloat `div` operator and the linalg substrate.
pub fn div_rem_with(num: &[u64], den: &[u64], scratch: &mut Scratch) -> (Vec<u64>, Vec<u64>) {
    let dn = bit_length(den);
    assert!(dn > 0, "division by zero");
    let nn = bit_length(num);
    if nn < dn {
        let q = scratch.take_limbs(num.len());
        let mut r = scratch.take_limbs(num.len());
        r.copy_from_slice(num);
        return (q, r);
    }
    // normalize: shift den so its top bit is the MSB of its top limb
    let den_limbs = dn.div_ceil(64);
    let shift = den_limbs * 64 - dn;
    let mut d = scratch.take_limbs(den_limbs);
    shl(&den[..den_limbs.min(den.len())], shift, &mut d);
    // numerator gets the same shift (one extra limb of headroom; `shl`
    // zero-extends the shorter source across the top limb)
    let num_limbs = nn.div_ceil(64);
    let mut r = scratch.take_limbs(num_limbs + 1);
    shl(&num[..num_limbs], shift, &mut r[..]);
    let m = num_limbs + 1 - den_limbs; // quotient digits
    let mut q = scratch.take_limbs(num.len().max(m));
    let d_top = d[den_limbs - 1];
    let d_next = if den_limbs >= 2 { d[den_limbs - 2] } else { 0 };

    for j in (0..m).rev() {
        // estimate q_hat from the top two remainder limbs vs d_top
        let r_hi = r[j + den_limbs];
        let r_lo = r[j + den_limbs - 1];
        let mut q_hat = if r_hi >= d_top {
            u64::MAX
        } else {
            (((r_hi as u128) << 64 | r_lo as u128) / d_top as u128) as u64
        };
        // refine with the next digit (Knuth's two-correction bound)
        if q_hat > 0 {
            let r_3rd = if j + den_limbs >= 2 { r[j + den_limbs - 2] } else { 0 };
            loop {
                let lhs = q_hat as u128 * d_next as u128;
                let rem128 = ((r_hi as u128) << 64 | r_lo as u128)
                    .wrapping_sub(q_hat as u128 * d_top as u128);
                if rem128 >> 64 == 0 && lhs > (rem128 << 64 | r_3rd as u128) {
                    q_hat -= 1;
                } else {
                    break;
                }
            }
        }
        // r -= q_hat * d  (at position j); fix up if we overshot by one
        let borrow = sub_mul_limb(&mut r[j..j + den_limbs + 1], &d, q_hat);
        if borrow {
            q_hat -= 1;
            let carry = add_assign(&mut r[j..j + den_limbs], &d);
            if carry {
                r[j + den_limbs] = r[j + den_limbs].wrapping_add(1);
            }
        }
        q[j] = q_hat;
    }

    // un-normalize the remainder (den_limbs <= den.len(), so the tail of
    // the pool-zeroed buffer is already the required zero padding)
    let mut rem = scratch.take_limbs(den.len());
    shr(&r[..den_limbs], shift, &mut rem[..den_limbs]);
    scratch.put_limbs(d);
    scratch.put_limbs(r);
    (q, rem)
}

/// a -= v * b (b zero-extended); returns true if the subtraction borrowed
/// out of the top limb of `a` (i.e. v was one too large).
fn sub_mul_limb(a: &mut [u64], b: &[u64], v: u64) -> bool {
    let mut borrow: u64 = 0; // accumulated high part + borrows
    for i in 0..b.len() {
        let prod = v as u128 * b[i] as u128 + borrow as u128;
        let (lo, hi) = (prod as u64, (prod >> 64) as u64);
        let (d, b1) = a[i].overflowing_sub(lo);
        a[i] = d;
        borrow = hi + b1 as u64; // hi < 2^64 - 1, so no overflow
    }
    for x in a.iter_mut().skip(b.len()) {
        if borrow == 0 {
            return false;
        }
        let (d, b1) = x.overflowing_sub(borrow);
        *x = d;
        borrow = b1 as u64;
    }
    borrow != 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    /// Reference via u128 on 2-limb values.
    fn to_u128(a: &[u64]) -> u128 {
        debug_assert!(a.len() <= 2);
        a.iter().enumerate().map(|(i, &x)| (x as u128) << (64 * i)).sum()
    }

    #[test]
    fn add_with_carry_chain() {
        let mut a = vec![u64::MAX, u64::MAX, 0];
        let b = vec![1, 0, 0];
        assert!(!add_assign(&mut a, &b));
        assert_eq!(a, vec![0, 0, 1]);
    }

    #[test]
    fn add_carry_out() {
        let mut a = vec![u64::MAX, u64::MAX];
        assert!(add_assign(&mut a.clone(), &[1, 0]) || {
            add_limb(&mut a, 1)
        });
        let mut a = vec![u64::MAX, u64::MAX];
        assert!(add_limb(&mut a, 1));
        assert_eq!(a, vec![0, 0]);
    }

    #[test]
    fn sub_with_borrow_chain() {
        let mut a = vec![0, 0, 1];
        let b = vec![1, 0, 0];
        assert!(!sub_assign(&mut a, &b));
        assert_eq!(a, vec![u64::MAX, u64::MAX, 0]);
    }

    #[test]
    fn sub_borrow_out() {
        let mut a = vec![0u64, 0];
        assert!(sub_assign(&mut a, &[1, 0]));
    }

    #[test]
    fn add_sub_roundtrip_property() {
        testkit::check(200, |rng| {
            let n = 1 + rng.below(6) as usize;
            let a = rng.limbs(n);
            let b = rng.limbs(n);
            let mut c = a.clone();
            let carry = add_assign(&mut c, &b);
            let borrow = sub_assign(&mut c, &b);
            assert_eq!(carry, borrow); // (a+b)-b == a mod 2^(64n), flags match
            assert_eq!(c, a);
        });
    }

    #[test]
    fn cmp_ordering() {
        assert_eq!(cmp(&[0, 1], &[u64::MAX, 0]), Ordering::Greater);
        assert_eq!(cmp(&[5, 5], &[5, 5]), Ordering::Equal);
        assert_eq!(cmp(&[4, 5], &[5, 5]), Ordering::Less);
    }

    #[test]
    fn bit_length_cases() {
        assert_eq!(bit_length(&[0, 0]), 0);
        assert_eq!(bit_length(&[1, 0]), 1);
        assert_eq!(bit_length(&[0, 1]), 65);
        assert_eq!(bit_length(&[u64::MAX, u64::MAX]), 128);
    }

    #[test]
    fn shifts_roundtrip() {
        testkit::check(200, |rng| {
            let a = rng.limbs(3);
            let s = rng.below(64 * 3) as usize;
            let mut wide = vec![0u64; 6];
            shl(&a, s, &mut wide);
            let mut back = vec![0u64; 3];
            shr(&wide, s, &mut back);
            assert_eq!(back, a);
        });
    }

    #[test]
    fn shl_drops_top_bits() {
        let a = vec![u64::MAX];
        let mut out = vec![0u64; 1];
        shl(&a, 32, &mut out);
        assert_eq!(out[0], u64::MAX << 32);
    }

    #[test]
    fn shr_exactness_vs_u128() {
        testkit::check(200, |rng| {
            let a = rng.limbs(2);
            let s = rng.below(128) as usize;
            let mut out = vec![0u64; 2];
            shr(&a, s, &mut out);
            assert_eq!(to_u128(&out), to_u128(&a) >> s);
        });
    }

    #[test]
    fn sticky_matches_mask() {
        testkit::check(200, |rng| {
            let a = rng.limbs(2);
            let s = rng.below(130) as usize;
            let mask = if s >= 128 { u128::MAX } else { (1u128 << s) - 1 };
            assert_eq!(sticky_below(&a, s), to_u128(&a) & mask != 0);
        });
    }

    #[test]
    fn schoolbook_vs_u128() {
        testkit::check(300, |rng| {
            let a = rng.limbs(1);
            let b = rng.limbs(1);
            let mut out = vec![0u64; 2];
            mul_schoolbook(&a, &b, &mut out);
            assert_eq!(to_u128(&out), a[0] as u128 * b[0] as u128);
        });
    }

    #[test]
    fn schoolbook_identity_and_zero() {
        let a = vec![0x1234_5678_9ABC_DEF0u64, 42];
        let one = vec![1u64, 0];
        let zero = vec![0u64, 0];
        let mut out = vec![0u64; 4];
        mul_schoolbook(&a, &one, &mut out);
        assert_eq!(&out[..2], &a[..]);
        assert!(is_zero(&out[2..]));
        mul_schoolbook(&a, &zero, &mut out);
        assert!(is_zero(&out));
    }

    #[test]
    fn div_rem_vs_u128() {
        testkit::check(400, |rng| {
            let num = rng.limbs(2);
            let mut den = rng.limbs(2);
            if rng.bool() {
                den[1] = 0; // exercise single-limb divisors
            }
            if is_zero(&den) {
                den[0] = 1;
            }
            let (q, r) = div_rem(&num, &den);
            let (nu, de) = (to_u128(&num), to_u128(&den));
            assert_eq!(to_u128(&q[..2]), nu / de, "quotient {nu} / {de}");
            assert_eq!(to_u128(&r[..2]), nu % de, "remainder {nu} % {de}");
        });
    }

    #[test]
    fn div_rem_reconstructs_property() {
        // num = q*den + r with r < den, at widths beyond u128
        testkit::check(100, |rng| {
            let n = 2 + rng.below(5) as usize;
            let dl = 1 + rng.below(n as u64) as usize;
            let num = rng.limbs(n);
            let mut den = rng.limbs(n);
            for x in den[dl..].iter_mut() {
                *x = 0;
            }
            if is_zero(&den) {
                den[0] = 3;
            }
            let (q, r) = div_rem(&num, &den);
            assert_eq!(cmp(&r, &den), Ordering::Less, "remainder must be < divisor");
            // reconstruct: q*den + r == num
            let mut prod = vec![0u64; q.len() + den.len()];
            mul_schoolbook(&q, &den, &mut prod);
            let carry = add_assign(&mut prod[..r.len()], &r);
            if carry {
                add_limb(&mut prod[r.len()..], 1);
            }
            assert_eq!(&prod[..n], &num[..], "q*den + r != num");
            assert!(is_zero(&prod[n..]));
        });
    }

    #[test]
    fn div_rem_edges() {
        // exact division, divisor = 1, num < den
        let (q, r) = div_rem(&[42, 0], &[7, 0]);
        assert_eq!((q[0], r[0]), (6, 0));
        let (q, r) = div_rem(&[u64::MAX, u64::MAX], &[1, 0]);
        assert_eq!(q, vec![u64::MAX, u64::MAX]);
        assert!(is_zero(&r));
        let (q, r) = div_rem(&[5, 0], &[0, 1]);
        assert!(is_zero(&q));
        assert_eq!(r, vec![5, 0]);
        // the q_hat = MAX correction path: num just below den << 64
        let (q, _r) = div_rem(&[0, u64::MAX - 1, u64::MAX - 1], &[u64::MAX, u64::MAX, 0]);
        assert_eq!(q[0], u64::MAX - 1);
    }

    #[test]
    fn shl_shr_exhaustive_small_width_vs_u128() {
        // Satellite: every shift amount 0..=130 on 2-limb values against a
        // u128 reference — covers r == 0 limb boundaries (s = 64, 128) and
        // the whole-vector overshoot (s >= 64 * len) in one sweep.
        testkit::check(100, |rng| {
            let a = rng.limbs(2);
            let v = to_u128(&a);
            for s in 0..=130usize {
                let mut out = vec![0u64; 2];
                shl(&a, s, &mut out);
                let want = if s >= 128 { 0 } else { v << s };
                assert_eq!(to_u128(&out), want, "shl s={s}");
                let mut out = vec![0u64; 2];
                shr(&a, s, &mut out);
                let want = if s >= 128 { 0 } else { v >> s };
                assert_eq!(to_u128(&out), want, "shr s={s}");
                let mask = if s >= 128 { u128::MAX } else { (1u128 << s) - 1 };
                assert_eq!(sticky_below(&a, s), v & mask != 0, "sticky s={s}");
            }
        });
    }

    #[test]
    fn shl_widening_and_shr_narrowing_widths() {
        // out wider than a (shl must zero-extend), out narrower than a
        // (shr must window the right limbs), at limb-exact shifts too.
        testkit::check(100, |rng| {
            let a = rng.limbs(2);
            let v = to_u128(&a);
            for s in [0usize, 1, 63, 64, 65, 127, 128, 129, 191, 192, 256, 300] {
                // widening shl: reference is (v << s) split into 256 bits
                let (lo, hi): (u128, u128) = if s == 0 {
                    (v, 0)
                } else if s < 128 {
                    (v << s, v >> (128 - s))
                } else if s < 256 {
                    (0, v << (s - 128))
                } else {
                    (0, 0)
                };
                let want = vec![lo as u64, (lo >> 64) as u64, hi as u64, (hi >> 64) as u64];
                let mut wide = vec![0u64; 4];
                shl(&a, s, &mut wide);
                assert_eq!(wide, want, "shl wide s={s}");

                // narrowing shr: 3-limb source, 1-limb output = bits s..s+64
                let src = vec![a[0], a[1], !a[0]];
                let lo2 = src[0] as u128 | (src[1] as u128) << 64; // bits 0..128
                let hi2 = src[1] as u128 | (src[2] as u128) << 64; // bits 64..192
                let expect: u64 = if s >= 192 {
                    0
                } else if s >= 64 {
                    (hi2 >> (s - 64)) as u64
                } else {
                    (lo2 >> s) as u64
                };
                let mut narrow = vec![0u64; 1];
                shr(&src, s, &mut narrow);
                assert_eq!(narrow[0], expect, "shr narrow s={s}");
            }
        });
    }

    #[test]
    fn comba_matches_schoolbook_property() {
        testkit::check(300, |rng| {
            let na = 1 + rng.below(12) as usize;
            let nb = if rng.bool() { na } else { 1 + rng.below(12) as usize };
            let a = rng.limbs(na);
            let b = rng.limbs(nb);
            let mut want = vec![0u64; na + nb];
            let mut got = vec![0u64; na + nb];
            mul_schoolbook(&a, &b, &mut want);
            mul_comba(&a, &b, &mut got);
            assert_eq!(got, want, "na={na} nb={nb}");
        });
    }

    #[test]
    fn comba_column_overflow_stress() {
        // All-ones operands maximize every column sum, wrapping the 128-bit
        // accumulator as often as possible so the `over` counter must carry
        // every wrap.  Cover the paper widths and deeper columns.
        for n in [1usize, 7, 15, 31, 32, 33, 40, 64] {
            let a = vec![u64::MAX; n];
            let mut want = vec![0u64; 2 * n];
            let mut got = vec![0u64; 2 * n];
            mul_schoolbook(&a, &a, &mut want);
            mul_comba(&a, &a, &mut got);
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn mul_auto_with_reuses_one_arena_across_widths() {
        let mut scratch = Scratch::new();
        let mut rng = testkit::Rng::from_seed(42);
        for n in [7usize, 15, 32, 48, 64, 7] {
            let a = rng.limbs(n);
            let b = rng.limbs(n);
            let mut want = vec![0u64; 2 * n];
            let mut got = vec![0u64; 2 * n];
            mul_schoolbook(&a, &b, &mut want);
            mul_auto_with(&a, &b, &mut got, &mut scratch);
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn scratch_prod_and_pool_roundtrip() {
        let mut s = Scratch::new();
        let mut p = s.take_prod(14);
        assert_eq!(p.len(), 14);
        assert!(is_zero(&p));
        p[13] = 7;
        let cap = p.capacity();
        s.put_prod(p);
        let p2 = s.take_prod(10);
        assert_eq!(p2.len(), 10);
        assert!(is_zero(&p2), "take_prod must re-zero recycled buffers");
        assert_eq!(p2.capacity(), cap, "capacity must be reused");
        s.put_prod(p2);

        let v = s.take_limbs(7);
        assert_eq!(v.len(), 7);
        s.put_limbs(v);
        let v2 = s.take_limbs(7);
        assert_eq!(v2.len(), 7);
        assert!(is_zero(&v2));
    }

    #[test]
    fn arena_ops_counter_counts_takes() {
        let mut s = Scratch::new();
        assert_eq!(s.arena_ops(), 0);
        let p = s.take_prod(4);
        s.put_prod(p);
        let w = s.take_addws(4);
        s.put_addws(w);
        let v = s.take_limbs(4);
        s.put_limbs(v);
        assert_eq!(s.arena_ops(), 3, "every take counts; puts are free");
        s.reset_arena_ops();
        assert_eq!(s.arena_ops(), 0);
        let mut out = vec![0u64; 4];
        mul_auto_with(&[1, 2], &[3, 4], &mut out, &mut s);
        assert_eq!(s.arena_ops(), 0, "below-threshold comba touches no workspace");
    }

    #[test]
    fn shl1_in_place_vs_u128() {
        testkit::check(200, |rng| {
            let mut a = rng.limbs(2);
            let v = to_u128(&a);
            let carry = shl1_in_place(&mut a);
            assert_eq!(to_u128(&a), v << 1);
            assert_eq!(carry, (v >> 127) as u64);
        });
    }

    #[test]
    fn addws_roundtrip_rezeroes_and_reuses_capacity() {
        let mut s = Scratch::new();
        let mut w = s.take_addws(21);
        assert_eq!(w.len(), 21);
        assert!(is_zero(&w));
        w[20] = 9;
        let cap = w.capacity();
        s.put_addws(w);
        let w2 = s.take_addws(15);
        assert_eq!(w2.len(), 15);
        assert!(is_zero(&w2), "take_addws must re-zero recycled buffers");
        assert_eq!(w2.capacity(), cap, "capacity must be reused");
    }

    #[test]
    fn div_rem_with_matches_div_rem_on_one_arena() {
        let mut scratch = Scratch::new();
        testkit::check(100, |rng| {
            let n = 1 + rng.below(5) as usize;
            let num = rng.limbs(n);
            let mut den = rng.limbs(n);
            if is_zero(&den) {
                den[0] = 5;
            }
            let (q0, r0) = div_rem(&num, &den);
            let (q1, r1) = div_rem_with(&num, &den, &mut scratch);
            assert_eq!(q0, q1);
            assert_eq!(r0, r1);
            scratch.put_limbs(q1);
            scratch.put_limbs(r1);
        });
    }

    #[test]
    fn get_bit_matches_shift() {
        let a = vec![0b1010u64, 1 << 63];
        assert!(!get_bit(&a, 0));
        assert!(get_bit(&a, 1));
        assert!(get_bit(&a, 127));
        assert!(!get_bit(&a, 128)); // out of range reads as 0
    }
}
