//! Toom-3 (Toom–Cook 3-way) multiplication — the generalization of
//! Karatsuba the paper names in §II-A ("later generalized by Toom and
//! described by Cook").  The paper stops at Karatsuba because its widths
//! (448/960 bits) sit below the Toom-3 payoff; this implementation is the
//! "beyond the paper" extension for higher precisions (DESIGN.md §8),
//! with the same exactness guarantees as the other multipliers.
//!
//! Scheme:
//!
//! ```text
//!   a = a0 + a1 B + a2 B^2,  B = 2^(64 k),  k = ceil(n/3)
//!   w0   = a(0) b(0)        = a0 b0
//!   w1   = a(1) b(1)
//!   wm1  = a(-1) b(-1)          (signed)
//!   wm2  = a(-2) b(-2)          (signed)
//!   winf = a(inf) b(inf)    = a2 b2
//! ```
//!
//! (evaluation points 0, 1, -1, -2, inf — the Bodrato/GMP sequence)
//! followed by the classical interpolation with exact divisions by 2 and 3.
//! Intermediates are signed, so the module carries a tiny sign-magnitude
//! helper (`SInt`) — growing numbers stay exact throughout.

use super::{add_assign, add_limb, cmp, is_zero, mul_auto_with, mul_comba, sub_assign, Scratch};
use std::cmp::Ordering;

/// Signed arbitrary big integer: sign + little-endian magnitude.
#[derive(Clone, Debug)]
struct SInt {
    neg: bool,
    mag: Vec<u64>,
}

impl SInt {
    fn from_slice(s: &[u64], extra: usize) -> Self {
        let mut mag = s.to_vec();
        mag.resize(s.len() + extra, 0);
        SInt { neg: false, mag }
    }

    #[cfg(test)]
    fn zero(limbs: usize) -> Self {
        SInt { neg: false, mag: vec![0; limbs] }
    }

    fn grow(&mut self, limbs: usize) {
        if self.mag.len() < limbs {
            self.mag.resize(limbs, 0);
        }
    }

    fn add(&mut self, other: &SInt) {
        self.grow(other.mag.len() + 1);
        let mut rhs = other.mag.clone();
        rhs.resize(self.mag.len(), 0);
        if self.neg == other.neg {
            let carry = add_assign(&mut self.mag, &rhs);
            debug_assert!(!carry);
        } else {
            // differing signs: subtract the smaller magnitude
            match cmp(&self.mag, &rhs) {
                Ordering::Less => {
                    let mut m = rhs;
                    let borrow = sub_assign(&mut m, &self.mag);
                    debug_assert!(!borrow);
                    self.mag = m;
                    self.neg = other.neg;
                }
                _ => {
                    let borrow = sub_assign(&mut self.mag, &rhs);
                    debug_assert!(!borrow);
                }
            }
        }
        if is_zero(&self.mag) {
            self.neg = false;
        }
    }

    fn sub(&mut self, other: &SInt) {
        let flipped = SInt { neg: !other.neg && !is_zero(&other.mag), mag: other.mag.clone() };
        self.add(&flipped);
    }

    fn mul(&self, other: &SInt, scratch: &mut Scratch) -> SInt {
        let mut out = vec![0u64; self.mag.len() + other.mag.len()];
        mul_auto_unequal(&self.mag, &other.mag, &mut out, scratch);
        SInt { neg: self.neg != other.neg && !is_zero(&out), mag: out }
    }

    /// Exact division by a small constant (panics in debug if inexact).
    fn div_exact(&mut self, d: u64) {
        let mut rem: u64 = 0;
        for x in self.mag.iter_mut().rev() {
            let t = ((rem as u128) << 64) | *x as u128;
            *x = (t / d as u128) as u64;
            rem = (t % d as u128) as u64;
        }
        debug_assert_eq!(rem, 0, "toom3 interpolation division must be exact");
    }

    /// self = self * 2 (shift left one bit).
    fn double(&mut self) {
        self.grow(self.mag.len() + 1);
        let mut carry = 0u64;
        for x in self.mag.iter_mut() {
            let nc = *x >> 63;
            *x = (*x << 1) | carry;
            carry = nc;
        }
        debug_assert_eq!(carry, 0);
    }
}

/// mul for possibly unequal lengths (pads the shorter operand).
fn mul_auto_unequal(a: &[u64], b: &[u64], out: &mut [u64], scratch: &mut Scratch) {
    if a.len() == b.len() {
        mul_auto_with(a, b, out, scratch);
    } else {
        mul_comba(a, b, out);
    }
}

/// out = a * b via Toom-3 on the thread-local scratch arena;
/// a.len() == b.len(), out.len() == 2 * a.len().
pub fn mul_toom3(a: &[u64], b: &[u64], out: &mut [u64]) {
    super::with_scratch(|s| mul_toom3_with(a, b, out, s));
}

/// [`mul_toom3`] against an explicit [`Scratch`]: the five pointwise
/// sub-multiplications go through `mul_auto_with` (Comba / Karatsuba) on
/// the shared arena.  The signed interpolation intermediates still own
/// their (growing) buffers — Toom-3 sits above the `ApFloat::mul` hot path,
/// so only its sub-multiplications need the arena.
pub fn mul_toom3_with(a: &[u64], b: &[u64], out: &mut [u64], scratch: &mut Scratch) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(out.len(), 2 * a.len());
    let n = a.len();
    if n < 9 {
        // below three full parts, the split degenerates
        mul_comba(a, b, out);
        return;
    }
    let k = n.div_ceil(3);

    let part = |x: &[u64], i: usize| -> Vec<u64> {
        let lo = (i * k).min(n);
        let hi = ((i + 1) * k).min(n);
        let mut v = x[lo..hi].to_vec();
        v.resize(k, 0);
        v
    };
    let (a0, a1, a2) = (part(a, 0), part(a, 1), part(a, 2));
    let (b0, b1, b2) = (part(b, 0), part(b, 1), part(b, 2));

    // evaluations (signed where needed), one extra limb of headroom
    let eval = |p0: &[u64], p1: &[u64], p2: &[u64]| -> [SInt; 5] {
        let s0 = SInt::from_slice(p0, 1);
        let s1 = SInt::from_slice(p1, 1);
        let s2 = SInt::from_slice(p2, 1);
        let mut at1 = s0.clone(); // p0 + p1 + p2
        at1.add(&s1);
        at1.add(&s2);
        let mut atm1 = s0.clone(); // p0 - p1 + p2
        atm1.sub(&s1);
        atm1.add(&s2);
        let mut atm2 = s2.clone(); // p(-2) = 4 p2 - 2 p1 + p0 (Horner)
        atm2.double();
        atm2.sub(&s1);
        atm2.double();
        atm2.add(&s0);
        [s0, at1, atm1, atm2, s2]
    };
    let ea = eval(&a0, &a1, &a2);
    let eb = eval(&b0, &b1, &b2);

    // pointwise products
    let w0 = ea[0].mul(&eb[0], scratch);
    let w1 = ea[1].mul(&eb[1], scratch);
    let wm1 = ea[2].mul(&eb[2], scratch);
    let wm2 = ea[3].mul(&eb[3], scratch);
    let winf = ea[4].mul(&eb[4], scratch);

    // interpolation (classical sequence; all divisions exact)
    let mut r3 = wm2.clone(); // (wm2 - w1)/3
    r3.sub(&w1);
    r3.div_exact(3);
    let mut r1 = w1.clone(); // (w1 - wm1)/2
    r1.sub(&wm1);
    r1.div_exact(2);
    let mut r2 = wm1.clone(); // wm1 - w0
    r2.sub(&w0);
    // r3 = (r2 - r3)/2 + 2*winf
    let mut t = r2.clone();
    t.sub(&r3);
    t.div_exact(2);
    let mut two_winf = winf.clone();
    two_winf.double();
    t.add(&two_winf);
    r3 = t;
    // r2 = r2 + r1 - winf
    r2.add(&r1);
    r2.sub(&winf);
    // r1 = r1 - r3
    r1.sub(&r3);

    // recombine: out = w0 + r1 B + r2 B^2 + r3 B^3 + winf B^4
    out.fill(0);
    let acc = |out: &mut [u64], r: &SInt, pos: usize| {
        debug_assert!(!r.neg || is_zero(&r.mag), "final coefficients are nonnegative");
        let end = (pos + r.mag.len()).min(out.len());
        if pos >= out.len() {
            debug_assert!(is_zero(&r.mag));
            return;
        }
        let width = end - pos;
        let carry = add_assign(&mut out[pos..end], &r.mag[..width]);
        debug_assert!(is_zero(&r.mag[width..]), "coefficient spills the product");
        if carry {
            let over = add_limb(&mut out[end..], 1);
            debug_assert!(!over);
        }
    };
    acc(out, &w0, 0);
    acc(out, &r1, k);
    acc(out, &r2, 2 * k);
    acc(out, &r3, 3 * k);
    acc(out, &winf, 4 * k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bigint::mul_schoolbook;
    use crate::testkit;

    fn check(n: usize, cases: u64) {
        testkit::check(cases, |rng| {
            let a = rng.limbs(n);
            let b = rng.limbs(n);
            let mut want = vec![0u64; 2 * n];
            let mut got = vec![0u64; 2 * n];
            mul_schoolbook(&a, &b, &mut want);
            mul_toom3(&a, &b, &mut got);
            assert_eq!(got, want, "n={n}");
        });
    }

    #[test]
    fn matches_schoolbook_various_sizes() {
        for n in [9, 10, 11, 12, 15, 16, 21, 24, 30, 33, 48] {
            check(n, 10);
        }
    }

    #[test]
    fn small_sizes_fall_back() {
        for n in [1, 2, 5, 8] {
            check(n, 5);
        }
    }

    #[test]
    fn extreme_operands() {
        for n in [9usize, 12, 24] {
            let all = vec![u64::MAX; n];
            let mut one = vec![0u64; n];
            one[0] = 1;
            let mut top = vec![0u64; n];
            top[n - 1] = u64::MAX;
            for (a, b) in [(&all, &all), (&all, &one), (&top, &all), (&top, &top)] {
                let mut want = vec![0u64; 2 * n];
                let mut got = vec![0u64; 2 * n];
                mul_schoolbook(a, b, &mut want);
                mul_toom3(a, b, &mut got);
                assert_eq!(got, want, "n={n}");
            }
        }
    }

    #[test]
    fn signed_helper_arithmetic() {
        let mut x = SInt::from_slice(&[5], 1);
        let y = SInt::from_slice(&[9], 1);
        x.sub(&y); // -4
        assert!(x.neg);
        assert_eq!(x.mag[0], 4);
        x.add(&y); // 5
        assert!(!x.neg);
        assert_eq!(x.mag[0], 5);
        x.double();
        assert_eq!(x.mag[0], 10);
        x.div_exact(2);
        assert_eq!(x.mag[0], 5);
        let z = x.mul(&SInt { neg: true, mag: vec![3] }, &mut Scratch::new());
        assert!(z.neg);
        assert_eq!(z.mag[0], 15);
    }

    #[test]
    fn explicit_arena_matches_wrapper() {
        let mut scratch = Scratch::new();
        testkit::check(10, |rng| {
            for n in [9usize, 16, 33] {
                let a = rng.limbs(n);
                let b = rng.limbs(n);
                let mut want = vec![0u64; 2 * n];
                let mut got = vec![0u64; 2 * n];
                mul_schoolbook(&a, &b, &mut want);
                mul_toom3_with(&a, &b, &mut got, &mut scratch);
                assert_eq!(got, want, "n={n}");
            }
        });
    }

    #[test]
    fn zero_operand() {
        let n = 12;
        let z = SInt::zero(3);
        assert!(!z.neg);
        let a = vec![0u64; n];
        let b = vec![u64::MAX; n];
        let mut got = vec![0u64; 2 * n];
        mul_toom3(&a, &b, &mut got);
        assert!(is_zero(&got));
    }
}
