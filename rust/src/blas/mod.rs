//! BLAS-like software interface (§IV-B, Lst. 2).
//!
//! The paper exposes the accelerator as a drop-in for Elemental/MLAPACK:
//! `apfp::Gemm` accepts *indexing functions* (closures mapping a linear
//! index to a scalar) so callers keep their own storage (e.g. MPFR values
//! inside Elemental matrices) without copies into an intermediate layout or
//! leaking the internal packed format.  This module is that interface over
//! [`crate::coordinator::Device`], using the same column-major + leading-
//! dimension convention as BLAS/Elemental.

use anyhow::Result;

use crate::coordinator::{Device, GemmStats, Matrix};
use crate::softfloat::ApFloat;

/// Transposition argument, as in the paper's `apfp::BlasTrans`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlasTrans {
    Normal,
    Transpose,
}

/// C += A * B (alpha = beta = 1, §III), with column-major indexing
/// functions and leading dimensions, mirroring Lst. 2:
///
/// * `index_a(i)` returns element i of A's column-major storage (size
///   `lda * k` for Normal); likewise `index_b`.
/// * `index_c(i)` reads and `write_c(i, v)` writes C's storage.
///
/// m, n, k: C is m x n, the inner dimension is k (BLAS convention).
#[allow(clippy::too_many_arguments)]
pub fn gemm(
    device: &Device,
    trans_a: BlasTrans,
    trans_b: BlasTrans,
    m: usize,
    n: usize,
    k: usize,
    index_a: impl Fn(usize) -> ApFloat,
    lda: usize,
    index_b: impl Fn(usize) -> ApFloat,
    ldb: usize,
    index_c: impl Fn(usize) -> ApFloat,
    mut write_c: impl FnMut(usize, ApFloat),
    ldc: usize,
) -> Result<GemmStats> {
    let prec = device.config().prec();
    // gather into device matrices (row-major internally)
    let a = match trans_a {
        BlasTrans::Normal => Matrix::from_fn(m, k, prec, |i, j| index_a(j * lda + i)),
        BlasTrans::Transpose => Matrix::from_fn(m, k, prec, |i, j| index_a(i * lda + j)),
    };
    let b = match trans_b {
        BlasTrans::Normal => Matrix::from_fn(k, n, prec, |i, j| index_b(j * ldb + i)),
        BlasTrans::Transpose => Matrix::from_fn(k, n, prec, |i, j| index_b(i * ldb + j)),
    };
    let c = Matrix::from_fn(m, n, prec, |i, j| index_c(j * ldc + i));

    let (out, stats) = device.gemm(&a, &b, &c)?;

    // hand the results back by value, consuming the device matrix row-major
    // — no per-element clone on the marshaling path
    let mut vals = out.into_values().into_iter();
    #[allow(clippy::expect_used)] // device.gemm returned an m x n matrix above
    for i in 0..m {
        for j in 0..n {
            write_c(j * ldc + i, vals.next().expect("m*n values"));
        }
    }
    Ok(stats)
}

/// Symmetric rank-k update, `C += A * A^T` on the lower triangle — the
/// derived routine the paper names as the other SDP workhorse (§III).
/// A is m x k (column-major through `index_a`), C is m x m.
pub fn syrk(
    device: &Device,
    m: usize,
    k: usize,
    index_a: impl Fn(usize) -> ApFloat + Copy,
    lda: usize,
    index_c: impl Fn(usize) -> ApFloat,
    mut write_c: impl FnMut(usize, ApFloat),
    ldc: usize,
) -> Result<GemmStats> {
    // full GEMM against A^T, then commit only the lower triangle (a
    // triangle-aware tile schedule is the paper's "derived routine" future
    // work; the arithmetic and interface semantics are what SDP codes need)
    let mut dropped = Vec::with_capacity(m * (m + 1) / 2);
    let stats = gemm(
        device,
        BlasTrans::Normal,
        BlasTrans::Transpose,
        m,
        m,
        k,
        index_a,
        lda,
        index_a,
        lda,
        index_c,
        |idx, v| {
            let (j, i) = (idx / ldc, idx % ldc);
            if i >= j {
                dropped.push((idx, v));
            }
        },
        ldc,
    )?;
    for (idx, v) in dropped {
        write_c(idx, v);
    }
    Ok(stats)
}

/// Convenience: GEMM directly on [`Matrix`] values (row-major callers).
pub fn gemm_matrices(device: &Device, a: &Matrix, b: &Matrix, c: &Matrix) -> Result<(Matrix, GemmStats)> {
    device.gemm(a, b, c)
}
