//! Runtime configuration — the paper's CMake-time knobs (§IV-A) as a config
//! system: defaults, config-file parsing (`key = value` lines), CLI
//! `--set key=value` overrides, and environment-variable defaults for the
//! tile geometry and backend.
//!
//! | paper option            | field            | env default            |
//! |-------------------------|------------------|------------------------|
//! | `APFP_BITS`             | `bits`           | —                      |
//! | —                       | `widths`         | `APFP_WIDTHS`          |
//! | `APFP_COMPUTE_UNITS`    | `compute_units`  | —                      |
//! | `APFP_TILE_SIZE_N`      | `tile_n`         | `APFP_TILE_N`          |
//! | `APFP_TILE_SIZE_M`      | `tile_m`         | `APFP_TILE_M`          |
//! | `APFP_TILE_SIZE_K`      | `tile_k`         | `APFP_TILE_K`          |
//! | `APFP_MULT_BASE_BITS`   | `mult_base_bits` | —                      |
//! | `APFP_ADD_BASE_BITS`    | `add_base_bits`  | —                      |
//! | —                       | `backend`        | `APFP_BACKEND`         |
//! | —                       | `reply_timeout`  | `APFP_REPLY_TIMEOUT_MS`|
//! | —                       | `retry.retry_limit`   | `APFP_RETRY_LIMIT` |
//! | —                       | `retry.backoff_ms`    | `APFP_RETRY_BACKOFF_MS` |
//! | —                       | `retry.respawn_limit` | `APFP_RESPAWN_LIMIT` |
//!
//! The tile fields shape the **builtin GEMM artifact** end to end: they
//! flow through [`crate::runtime::manifest::builtin`] into the scheduler's
//! band/tile partition, the native backend's tile executor, and each
//! worker's staging buffers — the host-side analog of re-synthesizing the
//! bitstream with different `APFP_TILE_SIZE_*` values.  (An on-disk
//! `artifacts/manifest.txt` still wins: its geometry describes compiled
//! artifacts, which a host config cannot reshape.)
//!
//! ```
//! use apfp::config::ApfpConfig;
//!
//! let mut cfg = ApfpConfig::default();
//! cfg.set("APFP_TILE_SIZE_N", "16").unwrap();
//! cfg.set("tile_k", "8").unwrap();
//! cfg.validate().unwrap();
//! assert_eq!((cfg.tile_n, cfg.tile_k), (16, 8));
//! assert!(cfg.set("tile_n", "0").is_ok());   // set() records,
//! assert!(cfg.validate().is_err());          // validate() rejects
//! ```

use std::path::Path;
use std::time::Duration;

use crate::runtime::manifest::{TileShape, DEFAULT_WIDTHS};
use crate::runtime::BackendKind;

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("cannot read config file: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed config line {line}: {text:?}")]
    Malformed { line: usize, text: String },
    #[error("unknown config key: {0:?}")]
    UnknownKey(String),
    #[error("invalid value for {key}: {value:?}")]
    InvalidValue { key: String, value: String },
    #[error("malformed environment override {key}={value:?}")]
    MalformedEnv { key: String, value: String },
    #[error(transparent)]
    Tile(#[from] crate::runtime::manifest::ManifestError),
    #[error("invalid configuration: {0}")]
    Invalid(String),
}

/// Test-only fault injection ("failpoints") for the device stack.
///
/// The default is no faults, and nothing sets these from config files or
/// the environment on purpose: faults are wired explicitly by the
/// failure-path tests (`tests/stream_faults.rs`) so the stream's error
/// handling — typed errors, pool recovery, no hangs, no panics — stays
/// under test without a way to trip it in production.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Fail `Runtime` construction on this compute unit: its worker comes
    /// up as a reply-only drain and every tile routed to it reports an
    /// error (the same path a real backend-init failure takes).
    pub init_fail_cu: Option<usize>,
    /// Inject a failure on the output tile with this `(row, column)`
    /// origin, on whichever CU owns it.
    pub fail_tile: Option<(usize, usize)>,
    /// Make the injected tile fault *transient*: only the first `K`
    /// delivery attempts of [`Self::fail_tile`] fail, later attempts
    /// succeed (`fail_tile=RxC*K`).  `None` means every attempt fails —
    /// the pre-retry behavior.
    pub fail_attempts: Option<u32>,
    /// Make the injected tile fault a panic (exercising the worker's
    /// catch-and-reply containment) instead of a returned error.
    pub panic_tile: bool,
    /// Kill the worker thread (it exits without replying or draining its
    /// queue) when it receives the tile with this `(row, column)` origin —
    /// models a crashed CU, exercising the stream's reply-liveness
    /// detection and the supervisor's respawn path.
    pub die_on_tile: Option<(usize, usize)>,
    /// Respawn-compatible variant of [`Self::die_on_tile`]: only the first
    /// `K` delivery attempts kill the worker (`die_on_tile=RxC*K`), so a
    /// respawned CU replaying the tile at a higher attempt survives.
    /// `None` means every delivery kills — respawns die again until the
    /// budget quarantines the CU.
    pub die_attempts: Option<u32>,
}

/// `"ROWxCOL"` → `(row, col)`, e.g. `"2x3"`; `None` when malformed.
fn parse_tile_origin(v: &str) -> Option<(usize, usize)> {
    let (r, c) = v.split_once('x')?;
    Some((r.trim().parse().ok()?, c.trim().parse().ok()?))
}

/// `"ROWxCOL"` or `"ROWxCOL*K"` → `((row, col), attempts)`: the origin of
/// an injected tile fault plus the optional transient-attempt count (fail
/// the first `K` deliveries, then succeed).  `None` when malformed; a
/// literal `*0` is malformed too — "fail zero attempts" spells no fault.
fn parse_tile_fault(v: &str) -> Option<((usize, usize), Option<u32>)> {
    match v.split_once('*') {
        None => Some((parse_tile_origin(v)?, None)),
        Some((origin, k)) => {
            let k: u32 = k.trim().parse().ok()?;
            (k > 0).then_some(())?;
            Some((parse_tile_origin(origin)?, Some(k)))
        }
    }
}

impl FaultSpec {
    /// Parse the comma-separated fault-spec string the failure-injection
    /// harnesses use, e.g. `"init_fail_cu=1,fail_tile=2x3,panic_tile"`:
    ///
    /// * `init_fail_cu=<cu>` — fail `Runtime` construction on that CU
    /// * `fail_tile=<row>x<col>[*<k>]` — error the tile at that origin;
    ///   with `*<k>`, only its first `<k>` delivery attempts (transient)
    /// * `panic_tile` (or `panic_tile=true|false`) — make the injected
    ///   fault a panic instead of a returned error
    /// * `die_on_tile=<row>x<col>[*<k>]` — kill the owning worker
    ///   reply-less; with `*<k>`, only on its first `<k>` deliveries (so
    ///   a respawned CU survives the replay)
    ///
    /// Unknown keys and malformed counts are typed [`ConfigError`]s.  This
    /// is deliberately *not* wired to any `APFP_*` variable read by
    /// production code — faults stay explicit in the tests that want them
    /// (see the `FaultSpec` docs above).
    pub fn parse(spec: &str) -> Result<Self, ConfigError> {
        let mut f = FaultSpec::default();
        for item in spec.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (key, value) = match item.split_once('=') {
                Some((k, v)) => (k.trim(), Some(v.trim())),
                None => (item, None),
            };
            let invalid = || ConfigError::InvalidValue {
                key: key.into(),
                value: value.unwrap_or("").into(),
            };
            match (key, value) {
                ("init_fail_cu", Some(v)) => {
                    f.init_fail_cu = Some(v.parse().map_err(|_| invalid())?)
                }
                ("fail_tile", Some(v)) => {
                    let (origin, attempts) = parse_tile_fault(v).ok_or_else(invalid)?;
                    f.fail_tile = Some(origin);
                    f.fail_attempts = attempts;
                }
                ("die_on_tile", Some(v)) => {
                    let (origin, attempts) = parse_tile_fault(v).ok_or_else(invalid)?;
                    f.die_on_tile = Some(origin);
                    f.die_attempts = attempts;
                }
                ("panic_tile", None) => f.panic_tile = true,
                ("panic_tile", Some(v)) => {
                    f.panic_tile = match v {
                        "true" | "1" => true,
                        "false" | "0" => false,
                        _ => return Err(invalid()),
                    }
                }
                ("init_fail_cu" | "fail_tile" | "die_on_tile", None) => return Err(invalid()),
                _ => return Err(ConfigError::UnknownKey(key.into())),
            }
        }
        Ok(f)
    }

    /// True when the injected tile *error* fires for the 0-based delivery
    /// `attempt` of the tile at `origin`.  Attempt counting is carried in
    /// the job itself, so the predicate is deterministic across retries,
    /// replays, and respawned workers.
    pub fn tile_fails(&self, origin: (usize, usize), attempt: u32) -> bool {
        self.fail_tile == Some(origin)
            && match self.fail_attempts {
                Some(k) => attempt < k,
                None => true,
            }
    }

    /// True when the injected worker *death* fires for the 0-based
    /// delivery `attempt` of the tile at `origin`.
    pub fn tile_kills(&self, origin: (usize, usize), attempt: u32) -> bool {
        self.die_on_tile == Some(origin)
            && match self.die_attempts {
                Some(k) => attempt < k,
                None => true,
            }
    }
}

/// Bounded-retry and respawn budgets for the self-healing stream: how many
/// times a failed tile job is redispatched, how long to back off between
/// redispatches, and how many times a dead compute unit is respawned
/// before it is quarantined (see `docs/ARCHITECTURE.md` § Failure
/// recovery).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Redispatches allowed per tile beyond its first attempt: a tile is
    /// delivered at most `retry_limit + 1` times before its error
    /// surfaces in [`LaunchFailed`](crate::coordinator::StreamError).
    /// `0` restores fail-fast.
    pub retry_limit: u32,
    /// Base backoff before redispatch `n` (1-based): `backoff_ms << (n-1)`
    /// milliseconds, capped at [`RetryPolicy::BACKOFF_CAP_MS`].  `0`
    /// disables the sleep entirely (what the fault tests use).
    pub backoff_ms: u64,
    /// Respawns allowed per compute unit before the supervisor quarantines
    /// it and the stream rebalances onto the survivors.  `0` quarantines
    /// on the first death.
    pub respawn_limit: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        // one transient hiccup per tile absorbed twice over, a millisecond
        // of first backoff, and one free respawn per CU — conservative
        // enough that a hard fault still surfaces in well under a second
        RetryPolicy { retry_limit: 2, backoff_ms: 1, respawn_limit: 1 }
    }
}

impl RetryPolicy {
    /// Ceiling on a single exponential-backoff sleep.
    pub const BACKOFF_CAP_MS: u64 = 1_000;

    /// Sleep before 1-based redispatch `n`: bounded exponential backoff,
    /// `Duration::ZERO` when [`Self::backoff_ms`] is zero.
    pub fn backoff(&self, n: u32) -> Duration {
        let shift = n.saturating_sub(1).min(20);
        Duration::from_millis((self.backoff_ms << shift).min(Self::BACKOFF_CAP_MS))
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ApfpConfig {
    /// Total packed bits per number (Fig. 1), incl. the 64-bit head word.
    /// This is the *default launch width* of a device; the full set of
    /// widths the device hosts side by side is [`Self::widths`].
    pub bits: u32,
    /// Every packed width the device loads kernels for (`APFP_WIDTHS`,
    /// comma-separated).  One `Device` hosts all of them simultaneously
    /// and each launch picks one (`enqueue_gemm_at`); [`Self::bits`] is
    /// appended automatically when absent, so the default launch width is
    /// always servable.
    pub widths: Vec<u32>,
    /// Replication factor of the compute pipeline (§IV-A).
    pub compute_units: usize,
    /// Output tile rows per compute unit (§III).
    pub tile_n: usize,
    /// Output tile columns per compute unit (§III).
    pub tile_m: usize,
    /// Inner-dimension depth of one K step of the tile datapath (§III).
    pub tile_k: usize,
    /// Karatsuba bottom-out threshold in bits (§II-A / Fig. 3).
    pub mult_base_bits: u32,
    /// Bits added per pipeline stage in wide adders (§II-A / Fig. 3).
    pub add_base_bits: u32,
    /// Worker threads backing the virtual device (host-side knob).
    pub worker_threads: usize,
    /// Execution backend for the device stack (`APFP_BACKEND`): the native
    /// in-process executor (default; works on a clean checkout), the
    /// hardware-model-accounting simulator (`sim` — bit-identical results
    /// plus modeled cycles/traffic/energy), or the XLA/PJRT artifact path.
    pub backend: BackendKind,
    /// How long a stream drain waits between reply-liveness probes of the
    /// owing worker threads (`APFP_REPLY_TIMEOUT_MS`): a dead CU is
    /// detected within one interval.  Widen it on slow CI machines;
    /// narrow it in fault tests that drive the respawn ladder.
    pub reply_timeout: Duration,
    /// Tile-retry and CU-respawn budgets for the self-healing stream
    /// (`APFP_RETRY_LIMIT`, `APFP_RETRY_BACKOFF_MS`, `APFP_RESPAWN_LIMIT`).
    pub retry: RetryPolicy,
    /// Test-only failure injection (see [`FaultSpec`]); no faults by
    /// default and not settable from files or the environment.
    pub faults: FaultSpec,
}

/// Lenient `APFP_WIDTHS` read for [`ApfpConfig::default`], mirroring
/// [`TileShape::from_env`]: a well-formed comma list of widths (each a
/// multiple of 64, `>= 128`, no duplicates) wins; anything malformed or
/// empty falls back to [`DEFAULT_WIDTHS`].  The strict, erroring parse
/// lives in [`ApfpConfig::try_from_env_with`].
fn widths_from_env() -> Vec<u32> {
    parse_widths_lenient(std::env::var("APFP_WIDTHS").ok().as_deref())
}

/// The fallible half of [`widths_from_env`], split out so tests can
/// exercise the fallback rules without mutating process state.
fn parse_widths_lenient(raw: Option<&str>) -> Vec<u32> {
    let Some(raw) = raw else {
        return DEFAULT_WIDTHS.to_vec();
    };
    let mut out = Vec::new();
    for part in raw.split(',') {
        match part.trim().parse::<u32>() {
            Ok(w) if w >= 128 && w % 64 == 0 && !out.contains(&w) => out.push(w),
            _ => return DEFAULT_WIDTHS.to_vec(),
        }
    }
    if out.is_empty() {
        return DEFAULT_WIDTHS.to_vec();
    }
    out
}

impl Default for ApfpConfig {
    fn default() -> Self {
        // The paper's evaluated configuration: 512-bit numbers, 32x32 tiles,
        // the Fig. 3 Pareto point (72-bit mult bottom-out, 64-bit adder
        // stages), one compute unit.  Tile geometry, backend, and the loaded
        // width set honor their environment overrides (`APFP_TILE_N/M/K`,
        // `APFP_BACKEND`, `APFP_WIDTHS`).
        let tile = TileShape::from_env();
        ApfpConfig {
            bits: 512,
            widths: widths_from_env(),
            compute_units: 1,
            tile_n: tile.n,
            tile_m: tile.m,
            tile_k: tile.k,
            mult_base_bits: 72,
            add_base_bits: 64,
            worker_threads: 0, // 0 = one per compute unit
            backend: BackendKind::from_env(),
            reply_timeout: Duration::from_millis(250),
            retry: RetryPolicy::default(),
            faults: FaultSpec::default(),
        }
    }
}

impl ApfpConfig {
    /// Mantissa precision in bits (Fig. 1: total minus the 64-bit head).
    pub fn prec(&self) -> u32 {
        crate::softfloat::prec_for_bits(self.bits)
    }

    /// The widths the device actually loads: [`Self::widths`] with
    /// [`Self::bits`] appended when absent, preserving declaration order.
    /// This is what `Device::new` hands to the builtin-manifest
    /// synthesizer, so the default launch width is always servable even
    /// under a narrowed `APFP_WIDTHS`.
    pub fn effective_widths(&self) -> Vec<u32> {
        let mut w = self.widths.clone();
        if !w.contains(&self.bits) {
            w.push(self.bits);
        }
        w
    }

    /// The GEMM tile geometry as one value — what `Device::new` threads
    /// into the builtin manifest and each worker's runtime.
    pub fn tile_shape(&self) -> TileShape {
        TileShape { n: self.tile_n, m: self.tile_m, k: self.tile_k }
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let err = |m: String| Err(ConfigError::Invalid(m));
        if self.bits % 64 != 0 || self.bits < 128 {
            return err(format!(
                "bits must be a multiple of 64 with at least one mantissa limb (>= 128), got {}",
                self.bits
            ));
        }
        for (i, &w) in self.widths.iter().enumerate() {
            if w % 64 != 0 || w < 128 {
                return err(format!(
                    "widths entries must be multiples of 64 and >= 128, got {w}"
                ));
            }
            if self.widths[..i].contains(&w) {
                return err(format!("duplicate width {w} in widths"));
            }
        }
        if self.compute_units == 0 {
            return err("compute_units must be >= 1".into());
        }
        // zero or oversized tiles would otherwise surface as panics deep in
        // a worker thread — reject them here with the typed manifest error
        if let Err(e) = self.tile_shape().validate() {
            return err(e.to_string());
        }
        if self.mult_base_bits < 17 {
            return err("mult_base_bits below the DSP width is meaningless".into());
        }
        if self.add_base_bits == 0 {
            return err("add_base_bits must be >= 1".into());
        }
        // a zero probe interval would spin the drain loop hot and flag
        // every in-flight worker as overdue on the first poll
        if self.reply_timeout.is_zero() {
            return err("reply_timeout must be > 0".into());
        }
        Ok(())
    }

    /// Apply one `key=value` override (CLI `--set`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        let invalid = || ConfigError::InvalidValue { key: key.into(), value: value.into() };
        match key {
            "bits" | "APFP_BITS" => self.bits = value.parse().map_err(|_| invalid())?,
            "widths" | "APFP_WIDTHS" => {
                self.widths = value
                    .split(',')
                    .map(|w| w.trim().parse::<u32>().map_err(|_| invalid()))
                    .collect::<Result<_, _>>()?
            }
            "compute_units" | "APFP_COMPUTE_UNITS" => {
                self.compute_units = value.parse().map_err(|_| invalid())?
            }
            "tile_n" | "APFP_TILE_SIZE_N" | "APFP_TILE_N" => {
                self.tile_n = value.parse().map_err(|_| invalid())?
            }
            "tile_m" | "APFP_TILE_SIZE_M" | "APFP_TILE_M" => {
                self.tile_m = value.parse().map_err(|_| invalid())?
            }
            "tile_k" | "APFP_TILE_SIZE_K" | "APFP_TILE_K" => {
                self.tile_k = value.parse().map_err(|_| invalid())?
            }
            "mult_base_bits" | "APFP_MULT_BASE_BITS" => {
                self.mult_base_bits = value.parse().map_err(|_| invalid())?
            }
            "add_base_bits" | "APFP_ADD_BASE_BITS" => {
                self.add_base_bits = value.parse().map_err(|_| invalid())?
            }
            "worker_threads" => self.worker_threads = value.parse().map_err(|_| invalid())?,
            "backend" | "APFP_BACKEND" => {
                self.backend = BackendKind::parse(value).ok_or_else(invalid)?
            }
            "reply_timeout_ms" | "APFP_REPLY_TIMEOUT_MS" => {
                self.reply_timeout =
                    Duration::from_millis(value.parse().map_err(|_| invalid())?)
            }
            "retry_limit" | "APFP_RETRY_LIMIT" => {
                self.retry.retry_limit = value.parse().map_err(|_| invalid())?
            }
            "retry_backoff_ms" | "APFP_RETRY_BACKOFF_MS" => {
                self.retry.backoff_ms = value.parse().map_err(|_| invalid())?
            }
            "respawn_limit" | "APFP_RESPAWN_LIMIT" => {
                self.retry.respawn_limit = value.parse().map_err(|_| invalid())?
            }
            _ => return Err(ConfigError::UnknownKey(key.into())),
        }
        Ok(())
    }

    /// [`Default::default`] with `from_file` strictness for the
    /// environment: every malformed `APFP_*` override is a typed
    /// [`ConfigError`] naming the offending key instead of a stderr
    /// warning and a silent fallback.  `lookup` stands in for
    /// `std::env::var` so tests can inject an environment without
    /// mutating process state; [`Self::try_from_env`] wires the real one.
    pub fn try_from_env_with<F>(lookup: F) -> Result<Self, ConfigError>
    where
        F: Fn(&str) -> Option<String>,
    {
        let malformed = |key: &str, value: String| ConfigError::MalformedEnv {
            key: key.into(),
            value,
        };
        let tile = TileShape::try_from_env_with(&lookup)?;
        let mut cfg = ApfpConfig::default();
        cfg.tile_n = tile.n;
        cfg.tile_m = tile.m;
        cfg.tile_k = tile.k;
        if let Some(v) = lookup("APFP_BACKEND") {
            cfg.backend =
                BackendKind::parse(&v).ok_or_else(|| malformed("APFP_BACKEND", v.clone()))?;
        }
        if let Some(v) = lookup("APFP_WIDTHS") {
            cfg.widths = v
                .split(',')
                .map(|w| {
                    w.trim()
                        .parse::<u32>()
                        .map_err(|_| malformed("APFP_WIDTHS", v.clone()))
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = lookup("APFP_REPLY_TIMEOUT_MS") {
            let ms: u64 = v
                .trim()
                .parse()
                .map_err(|_| malformed("APFP_REPLY_TIMEOUT_MS", v.clone()))?;
            cfg.reply_timeout = Duration::from_millis(ms);
        }
        if let Some(v) = lookup("APFP_RETRY_LIMIT") {
            cfg.retry.retry_limit =
                v.trim().parse().map_err(|_| malformed("APFP_RETRY_LIMIT", v.clone()))?;
        }
        if let Some(v) = lookup("APFP_RETRY_BACKOFF_MS") {
            cfg.retry.backoff_ms = v
                .trim()
                .parse()
                .map_err(|_| malformed("APFP_RETRY_BACKOFF_MS", v.clone()))?;
        }
        if let Some(v) = lookup("APFP_RESPAWN_LIMIT") {
            cfg.retry.respawn_limit =
                v.trim().parse().map_err(|_| malformed("APFP_RESPAWN_LIMIT", v.clone()))?;
        }
        // the threshold lives in a process-wide OnceLock, not in the
        // config; strict mode still rejects a malformed override so it
        // cannot silently run with the default crossover
        if let Some(v) = lookup("APFP_KARATSUBA_THRESHOLD") {
            crate::bigint::karatsuba::parse_threshold(&v)
                .ok_or_else(|| malformed("APFP_KARATSUBA_THRESHOLD", v.clone()))?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// [`Self::try_from_env_with`] against the process environment.
    pub fn try_from_env() -> Result<Self, ConfigError> {
        Self::try_from_env_with(|key| std::env::var(key).ok())
    }

    /// Parse a config file of `key = value` lines (`#` comments allowed).
    pub fn from_file(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = ApfpConfig::default();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Malformed { line: i + 1, text: raw.into() })?;
            cfg.set(k.trim(), v.trim())?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// True when no `APFP_TILE_*` override is present, so tests asserting
    /// the paper defaults don't fail spuriously under the very env knobs
    /// this module documents.
    fn tile_env_unset() -> bool {
        ["N", "M", "K"].iter().all(|d| {
            std::env::var_os(format!("APFP_TILE_{d}")).is_none()
                && std::env::var_os(format!("APFP_TILE_SIZE_{d}")).is_none()
        })
    }

    #[test]
    fn default_is_paper_config() {
        let c = ApfpConfig::default();
        assert_eq!(c.bits, 512);
        assert_eq!(c.prec(), 448);
        assert_eq!(c.tile_shape(), TileShape::from_env(), "defaults honor the env");
        if tile_env_unset() {
            assert_eq!((c.tile_n, c.tile_m, c.tile_k), (32, 32, 32));
            assert_eq!(c.tile_shape(), TileShape::default());
        }
        assert_eq!(c.mult_base_bits, 72);
        c.validate().unwrap();
    }

    #[test]
    fn set_accepts_both_naming_schemes() {
        let mut c = ApfpConfig::default();
        c.set("APFP_BITS", "1024").unwrap();
        assert_eq!(c.bits, 1024);
        assert_eq!(c.prec(), 960);
        c.set("compute_units", "8").unwrap();
        assert_eq!(c.compute_units, 8);
        c.set("APFP_BACKEND", "xla").unwrap();
        assert_eq!(c.backend, BackendKind::Xla);
        c.set("backend", "sim").unwrap();
        assert_eq!(c.backend, BackendKind::Sim);
        c.set("APFP_BACKEND", "simulator").unwrap();
        assert_eq!(c.backend, BackendKind::Sim);
        c.set("backend", "native").unwrap();
        assert_eq!(c.backend, BackendKind::Native);
        assert!(matches!(
            c.set("backend", "fpga"),
            Err(ConfigError::InvalidValue { .. })
        ));
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let mut c = ApfpConfig::default();
        assert!(matches!(c.set("nope", "1"), Err(ConfigError::UnknownKey(_))));
        assert!(matches!(
            c.set("bits", "abc"),
            Err(ConfigError::InvalidValue { .. })
        ));
    }

    #[test]
    fn validation_catches_bad_geometry() {
        let c = ApfpConfig { bits: 500, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ApfpConfig { bits: 64, ..Default::default() };
        assert!(c.validate().is_err(), "no mantissa limb under the head");
        let c = ApfpConfig { compute_units: 0, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ApfpConfig { mult_base_bits: 8, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn widths_parse_validate_and_cover_the_default_launch_width() {
        let c = ApfpConfig::default();
        assert_eq!(c.widths, widths_from_env(), "defaults honor the env");
        c.validate().unwrap();

        // the lenient read behind Default: well-formed lists win, anything
        // else (malformed entry, sub-128 width, duplicate, empty) falls
        // back to the full builtin set rather than erroring
        assert_eq!(parse_widths_lenient(None), DEFAULT_WIDTHS.to_vec());
        assert_eq!(parse_widths_lenient(Some("512")), vec![512]);
        assert_eq!(parse_widths_lenient(Some(" 128, 512 ")), vec![128, 512]);
        for bad in ["512;1024", "96", "512,512", "", "512,big"] {
            assert_eq!(
                parse_widths_lenient(Some(bad)),
                DEFAULT_WIDTHS.to_vec(),
                "lenient parse of {bad:?} must fall back"
            );
        }

        // both spellings of the knob parse a comma list
        let mut c = ApfpConfig::default();
        c.set("APFP_WIDTHS", "512, 1024").unwrap();
        assert_eq!(c.widths, vec![512, 1024]);
        c.set("widths", "128").unwrap();
        assert_eq!(c.widths, vec![128]);
        // bits is appended when the list omits it
        assert_eq!(c.effective_widths(), vec![128, 512]);
        c.validate().unwrap();
        assert!(matches!(c.set("widths", "512,big"), Err(ConfigError::InvalidValue { .. })));

        // degenerate entries and duplicates are validation errors
        let c = ApfpConfig { widths: vec![512, 96], ..Default::default() };
        assert!(c.validate().is_err());
        let c = ApfpConfig { widths: vec![512, 512], ..Default::default() };
        assert!(c.validate().is_err());

        // the env path reads APFP_WIDTHS strictly
        let c =
            ApfpConfig::try_from_env_with(env_of(&[("APFP_WIDTHS", "128,512")])).unwrap();
        assert_eq!(c.widths, vec![128, 512]);
        let err = ApfpConfig::try_from_env_with(env_of(&[("APFP_WIDTHS", "128;512")]))
            .expect_err("malformed width list must fail strictly");
        assert!(
            matches!(&err, ConfigError::MalformedEnv { key, .. } if key == "APFP_WIDTHS"),
            "{err:?}"
        );
    }

    #[test]
    fn validation_rejects_degenerate_tiles() {
        use crate::runtime::manifest::MAX_TILE_DIM;
        for (n, m, k) in [(0, 8, 8), (8, 0, 8), (8, 8, 0), (MAX_TILE_DIM + 1, 8, 8)] {
            let c = ApfpConfig { tile_n: n, tile_m: m, tile_k: k, ..Default::default() };
            let err = c.validate().expect_err("degenerate tile must be rejected");
            assert!(matches!(err, ConfigError::Invalid(_)), "{err:?}");
            assert!(err.to_string().contains("tile"), "{err}");
        }
        // the tile_k knob parses through every spelling (fixed base shape,
        // so the assertions hold under APFP_TILE_* env overrides too)
        let mut c = ApfpConfig { tile_n: 32, tile_m: 32, tile_k: 32, ..Default::default() };
        c.set("APFP_TILE_SIZE_K", "4").unwrap();
        assert_eq!(c.tile_k, 4);
        c.set("APFP_TILE_K", "6").unwrap();
        assert_eq!(c.tile_k, 6);
        c.set("tile_k", "2").unwrap();
        assert_eq!(c.tile_shape(), TileShape { n: 32, m: 32, k: 2 });
    }

    /// A fake environment as a slice of pairs — no process-env mutation
    /// (env writes race under the parallel test harness).
    fn env_of(pairs: &[(&str, &str)]) -> impl Fn(&str) -> Option<String> + '_ {
        move |key: &str| {
            pairs.iter().find(|(k, _)| *k == key).map(|(_, v)| v.to_string())
        }
    }

    #[test]
    fn try_from_env_empty_environment_is_default() {
        let c = ApfpConfig::try_from_env_with(|_| None).unwrap();
        assert_eq!((c.tile_n, c.tile_m, c.tile_k), (32, 32, 32));
        assert_eq!(c.backend, BackendKind::Native);
        c.validate().unwrap();
    }

    #[test]
    fn try_from_env_applies_well_formed_overrides() {
        let c = ApfpConfig::try_from_env_with(env_of(&[
            ("APFP_TILE_N", "16"),
            ("APFP_TILE_SIZE_M", "8"),
            ("APFP_BACKEND", "xla"),
            ("APFP_KARATSUBA_THRESHOLD", "24"),
        ]))
        .unwrap();
        assert_eq!((c.tile_n, c.tile_m, c.tile_k), (16, 8, 32));
        assert_eq!(c.backend, BackendKind::Xla);
        let c = ApfpConfig::try_from_env_with(env_of(&[("APFP_BACKEND", "sim")])).unwrap();
        assert_eq!(c.backend, BackendKind::Sim);
    }

    #[test]
    fn try_from_env_rejects_malformed_tile() {
        let err = ApfpConfig::try_from_env_with(env_of(&[("APFP_TILE_N", "abc")]))
            .expect_err("malformed tile env must fail");
        assert!(matches!(err, ConfigError::Tile(_)), "{err:?}");
        let msg = err.to_string();
        assert!(msg.contains("APFP_TILE_N") && msg.contains("abc"), "{msg}");
    }

    #[test]
    fn try_from_env_rejects_malformed_backend_and_threshold() {
        let err = ApfpConfig::try_from_env_with(env_of(&[("APFP_BACKEND", "fpga")]))
            .expect_err("unknown backend must fail strictly");
        assert!(
            matches!(&err, ConfigError::MalformedEnv { key, value }
                if key == "APFP_BACKEND" && value == "fpga"),
            "{err:?}"
        );
        for bad in ["zero?", "0", "-1", "1e3"] {
            let err = ApfpConfig::try_from_env_with(env_of(&[(
                "APFP_KARATSUBA_THRESHOLD",
                bad,
            )]))
            .expect_err("malformed threshold must fail strictly");
            assert!(
                matches!(&err, ConfigError::MalformedEnv { key, .. }
                    if key == "APFP_KARATSUBA_THRESHOLD"),
                "{bad:?}: {err:?}"
            );
        }
        // well-formed thresholds clamp to >= 2 on the lenient path
        assert_eq!(crate::bigint::karatsuba::parse_threshold(" 24 "), Some(24));
        assert_eq!(crate::bigint::karatsuba::parse_threshold("1"), Some(2));
        assert_eq!(crate::bigint::karatsuba::parse_threshold("0"), None);
    }

    #[test]
    fn fault_spec_parses_valid_strings() {
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::default());
        let f = FaultSpec::parse("init_fail_cu=1, fail_tile=2x3, panic_tile").unwrap();
        assert_eq!(f.init_fail_cu, Some(1));
        assert_eq!(f.fail_tile, Some((2, 3)));
        assert!(f.panic_tile);
        assert_eq!(f.die_on_tile, None);
        let f = FaultSpec::parse("die_on_tile=0x1,panic_tile=false").unwrap();
        assert_eq!(f.die_on_tile, Some((0, 1)));
        assert!(!f.panic_tile);
    }

    #[test]
    fn fault_spec_rejects_unknown_keys() {
        assert!(matches!(
            FaultSpec::parse("explode=yes"),
            Err(ConfigError::UnknownKey(k)) if k == "explode"
        ));
    }

    #[test]
    fn fault_spec_rejects_malformed_counts() {
        for bad in [
            "init_fail_cu=abc",
            "init_fail_cu",          // key without a count
            "fail_tile=2",           // missing column
            "fail_tile=2x",          // empty column
            "fail_tile=x3",          // empty row
            "die_on_tile=axb",
            "panic_tile=maybe",
        ] {
            assert!(
                matches!(FaultSpec::parse(bad), Err(ConfigError::InvalidValue { .. })),
                "{bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn fault_spec_parses_transient_forms() {
        // fail_tile=RxC*K: fail the first K attempts, then succeed
        let f = FaultSpec::parse("fail_tile=2x3*2").unwrap();
        assert_eq!(f.fail_tile, Some((2, 3)));
        assert_eq!(f.fail_attempts, Some(2));
        assert!(f.tile_fails((2, 3), 0) && f.tile_fails((2, 3), 1));
        assert!(!f.tile_fails((2, 3), 2), "attempt K succeeds");
        assert!(!f.tile_fails((0, 0), 0), "other origins never fault");
        // die_on_tile=RxC*K: the respawn-compatible death
        let f = FaultSpec::parse("die_on_tile=0x4*1").unwrap();
        assert_eq!(f.die_on_tile, Some((0, 4)));
        assert_eq!(f.die_attempts, Some(1));
        assert!(f.tile_kills((0, 4), 0));
        assert!(!f.tile_kills((0, 4), 1), "the respawned CU survives the replay");
        // without *K every attempt faults — the pre-retry behavior
        let f = FaultSpec::parse("fail_tile=1x1,die_on_tile=1x2").unwrap();
        assert_eq!((f.fail_attempts, f.die_attempts), (None, None));
        for attempt in [0, 1, 7] {
            assert!(f.tile_fails((1, 1), attempt));
            assert!(f.tile_kills((1, 2), attempt));
        }
    }

    #[test]
    fn fault_spec_rejects_malformed_transient_counts() {
        for bad in [
            "fail_tile=2x3*",    // empty count
            "fail_tile=2x3*abc", // non-numeric count
            "fail_tile=2x3*0",   // "fail zero attempts" spells no fault
            "fail_tile=*2",      // count without an origin
            "die_on_tile=2*2",   // origin missing its column
            "die_on_tile=2x3*-1",
        ] {
            assert!(
                matches!(FaultSpec::parse(bad), Err(ConfigError::InvalidValue { .. })),
                "{bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn retry_policy_backoff_is_bounded_exponential() {
        let p = RetryPolicy { retry_limit: 3, backoff_ms: 2, respawn_limit: 1 };
        assert_eq!(p.backoff(1), Duration::from_millis(2));
        assert_eq!(p.backoff(2), Duration::from_millis(4));
        assert_eq!(p.backoff(3), Duration::from_millis(8));
        // the cap holds even past the shift guard
        assert_eq!(p.backoff(40), Duration::from_millis(RetryPolicy::BACKOFF_CAP_MS));
        // zero base disables the sleep entirely (fault-test mode)
        let p = RetryPolicy { backoff_ms: 0, ..p };
        assert_eq!(p.backoff(1), Duration::ZERO);
        assert_eq!(p.backoff(40), Duration::ZERO);
    }

    #[test]
    fn retry_and_timeout_env_overrides_parse_strictly() {
        let c = ApfpConfig::try_from_env_with(env_of(&[
            ("APFP_REPLY_TIMEOUT_MS", "25"),
            ("APFP_RETRY_LIMIT", "5"),
            ("APFP_RETRY_BACKOFF_MS", "0"),
            ("APFP_RESPAWN_LIMIT", "3"),
        ]))
        .unwrap();
        assert_eq!(c.reply_timeout, Duration::from_millis(25));
        assert_eq!(c.retry.retry_limit, 5);
        assert_eq!(c.retry.backoff_ms, 0);
        assert_eq!(c.retry.respawn_limit, 3);
        for key in
            ["APFP_REPLY_TIMEOUT_MS", "APFP_RETRY_LIMIT", "APFP_RETRY_BACKOFF_MS", "APFP_RESPAWN_LIMIT"]
        {
            let err = ApfpConfig::try_from_env_with(env_of(&[(key, "soon")]))
                .expect_err("malformed override must fail strictly");
            assert!(
                matches!(&err, ConfigError::MalformedEnv { key: k, value } if k == key && value == "soon"),
                "{key}: {err:?}"
            );
        }
        // a zero probe interval parses but fails validation
        let err = ApfpConfig::try_from_env_with(env_of(&[("APFP_REPLY_TIMEOUT_MS", "0")]))
            .expect_err("zero reply timeout must fail validation");
        assert!(matches!(err, ConfigError::Invalid(_)), "{err:?}");
        // set() accepts both naming schemes for the new knobs
        let mut c = ApfpConfig::default();
        c.set("reply_timeout_ms", "40").unwrap();
        c.set("APFP_RETRY_LIMIT", "1").unwrap();
        c.set("retry_backoff_ms", "7").unwrap();
        c.set("APFP_RESPAWN_LIMIT", "0").unwrap();
        assert_eq!(c.reply_timeout, Duration::from_millis(40));
        assert_eq!(
            c.retry,
            RetryPolicy { retry_limit: 1, backoff_ms: 7, respawn_limit: 0 }
        );
    }

    #[test]
    fn config_error_source_chains() {
        use std::error::Error as _;
        // Io wraps the underlying error as source()
        let err = ApfpConfig::from_file(Path::new("/nonexistent/apfp.cfg")).unwrap_err();
        assert!(matches!(err, ConfigError::Io(_)));
        assert!(err.source().is_some(), "Io must expose the underlying error");
        // the transparent Tile variant delegates Display to ManifestError
        let tile_err = ConfigError::from(
            TileShape::try_from_env_with(|k| {
                (k == "APFP_TILE_N").then(|| "bogus".to_string())
            })
            .unwrap_err(),
        );
        assert!(tile_err.to_string().contains("APFP_TILE_N"), "{tile_err}");
        // leaf variants carry their payload in Display and have no source
        let leaf = ConfigError::MalformedEnv { key: "K".into(), value: "v".into() };
        assert!(leaf.to_string().contains("K") && leaf.to_string().contains("v"));
        assert!(leaf.source().is_none());
    }

    #[test]
    fn try_from_env_still_validates_geometry() {
        // parses fine, but a zero tile must be rejected by validate()
        let err = ApfpConfig::try_from_env_with(env_of(&[("APFP_TILE_K", "0")]))
            .expect_err("zero tile must fail validation");
        assert!(matches!(err, ConfigError::Invalid(_)), "{err:?}");
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("apfp_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.cfg");
        std::fs::write(
            &path,
            "# paper Tab. III, 8-CU row\nAPFP_BITS = 512\ncompute_units = 8\ntile_n=32 # inline\n",
        )
        .unwrap();
        let c = ApfpConfig::from_file(&path).unwrap();
        assert_eq!(c.compute_units, 8);
        assert_eq!(c.bits, 512);
    }

    #[test]
    fn malformed_file_reports_line() {
        let dir = std::env::temp_dir().join("apfp_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.cfg");
        std::fs::write(&path, "bits 512\n").unwrap();
        match ApfpConfig::from_file(&path) {
            Err(ConfigError::Malformed { line: 1, .. }) => {}
            other => panic!("{other:?}"),
        }
    }
}
