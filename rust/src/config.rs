//! Runtime configuration — the paper's CMake-time knobs (§IV-A) as a config
//! system: defaults, config-file parsing (`key = value` lines), CLI
//! `--set key=value` overrides, and environment-variable defaults for the
//! tile geometry and backend.
//!
//! | paper option            | field            | env default            |
//! |-------------------------|------------------|------------------------|
//! | `APFP_BITS`             | `bits`           | —                      |
//! | `APFP_COMPUTE_UNITS`    | `compute_units`  | —                      |
//! | `APFP_TILE_SIZE_N`      | `tile_n`         | `APFP_TILE_N`          |
//! | `APFP_TILE_SIZE_M`      | `tile_m`         | `APFP_TILE_M`          |
//! | `APFP_TILE_SIZE_K`      | `tile_k`         | `APFP_TILE_K`          |
//! | `APFP_MULT_BASE_BITS`   | `mult_base_bits` | —                      |
//! | `APFP_ADD_BASE_BITS`    | `add_base_bits`  | —                      |
//! | —                       | `backend`        | `APFP_BACKEND`         |
//!
//! The tile fields shape the **builtin GEMM artifact** end to end: they
//! flow through [`crate::runtime::manifest::builtin`] into the scheduler's
//! band/tile partition, the native backend's tile executor, and each
//! worker's staging buffers — the host-side analog of re-synthesizing the
//! bitstream with different `APFP_TILE_SIZE_*` values.  (An on-disk
//! `artifacts/manifest.txt` still wins: its geometry describes compiled
//! artifacts, which a host config cannot reshape.)
//!
//! ```
//! use apfp::config::ApfpConfig;
//!
//! let mut cfg = ApfpConfig::default();
//! cfg.set("APFP_TILE_SIZE_N", "16").unwrap();
//! cfg.set("tile_k", "8").unwrap();
//! cfg.validate().unwrap();
//! assert_eq!((cfg.tile_n, cfg.tile_k), (16, 8));
//! assert!(cfg.set("tile_n", "0").is_ok());   // set() records,
//! assert!(cfg.validate().is_err());          // validate() rejects
//! ```

use std::path::Path;

use crate::runtime::manifest::TileShape;
use crate::runtime::BackendKind;

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("cannot read config file: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed config line {line}: {text:?}")]
    Malformed { line: usize, text: String },
    #[error("unknown config key: {0:?}")]
    UnknownKey(String),
    #[error("invalid value for {key}: {value:?}")]
    InvalidValue { key: String, value: String },
    #[error("invalid configuration: {0}")]
    Invalid(String),
}

/// Test-only fault injection ("failpoints") for the device stack.
///
/// The default is no faults, and nothing sets these from config files or
/// the environment on purpose: faults are wired explicitly by the
/// failure-path tests (`tests/stream_faults.rs`) so the stream's error
/// handling — typed errors, pool recovery, no hangs, no panics — stays
/// under test without a way to trip it in production.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Fail `Runtime` construction on this compute unit: its worker comes
    /// up as a reply-only drain and every tile routed to it reports an
    /// error (the same path a real backend-init failure takes).
    pub init_fail_cu: Option<usize>,
    /// Inject a failure on the output tile with this `(row, column)`
    /// origin, on whichever CU owns it.
    pub fail_tile: Option<(usize, usize)>,
    /// Make the injected tile fault a panic (exercising the worker's
    /// catch-and-reply containment) instead of a returned error.
    pub panic_tile: bool,
    /// Kill the worker thread (it exits without replying or draining its
    /// queue) when it receives the tile with this `(row, column)` origin —
    /// models a crashed CU, exercising the stream's reply-liveness
    /// detection and poisoning instead of a hang.
    pub die_on_tile: Option<(usize, usize)>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ApfpConfig {
    /// Total packed bits per number (Fig. 1), incl. the 64-bit head word.
    pub bits: u32,
    /// Replication factor of the compute pipeline (§IV-A).
    pub compute_units: usize,
    /// Output tile rows per compute unit (§III).
    pub tile_n: usize,
    /// Output tile columns per compute unit (§III).
    pub tile_m: usize,
    /// Inner-dimension depth of one K step of the tile datapath (§III).
    pub tile_k: usize,
    /// Karatsuba bottom-out threshold in bits (§II-A / Fig. 3).
    pub mult_base_bits: u32,
    /// Bits added per pipeline stage in wide adders (§II-A / Fig. 3).
    pub add_base_bits: u32,
    /// Worker threads backing the virtual device (host-side knob).
    pub worker_threads: usize,
    /// Execution backend for the device stack (`APFP_BACKEND`): the native
    /// in-process executor (default; works on a clean checkout) or the
    /// XLA/PJRT artifact path.
    pub backend: BackendKind,
    /// Test-only failure injection (see [`FaultSpec`]); no faults by
    /// default and not settable from files or the environment.
    pub faults: FaultSpec,
}

impl Default for ApfpConfig {
    fn default() -> Self {
        // The paper's evaluated configuration: 512-bit numbers, 32x32 tiles,
        // the Fig. 3 Pareto point (72-bit mult bottom-out, 64-bit adder
        // stages), one compute unit.  Tile geometry and backend honor their
        // environment overrides (`APFP_TILE_N/M/K`, `APFP_BACKEND`).
        let tile = TileShape::from_env();
        ApfpConfig {
            bits: 512,
            compute_units: 1,
            tile_n: tile.n,
            tile_m: tile.m,
            tile_k: tile.k,
            mult_base_bits: 72,
            add_base_bits: 64,
            worker_threads: 0, // 0 = one per compute unit
            backend: BackendKind::from_env(),
            faults: FaultSpec::default(),
        }
    }
}

impl ApfpConfig {
    /// Mantissa precision in bits (Fig. 1: total minus the 64-bit head).
    pub fn prec(&self) -> u32 {
        crate::softfloat::prec_for_bits(self.bits)
    }

    /// The GEMM tile geometry as one value — what `Device::new` threads
    /// into the builtin manifest and each worker's runtime.
    pub fn tile_shape(&self) -> TileShape {
        TileShape { n: self.tile_n, m: self.tile_m, k: self.tile_k }
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let err = |m: String| Err(ConfigError::Invalid(m));
        if self.bits % 512 != 0 || self.bits == 0 {
            return err(format!("bits must be a positive multiple of 512, got {}", self.bits));
        }
        if self.compute_units == 0 {
            return err("compute_units must be >= 1".into());
        }
        // zero or oversized tiles would otherwise surface as panics deep in
        // a worker thread — reject them here with the typed manifest error
        if let Err(e) = self.tile_shape().validate() {
            return err(e.to_string());
        }
        if self.mult_base_bits < 17 {
            return err("mult_base_bits below the DSP width is meaningless".into());
        }
        if self.add_base_bits == 0 {
            return err("add_base_bits must be >= 1".into());
        }
        Ok(())
    }

    /// Apply one `key=value` override (CLI `--set`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        let invalid = || ConfigError::InvalidValue { key: key.into(), value: value.into() };
        match key {
            "bits" | "APFP_BITS" => self.bits = value.parse().map_err(|_| invalid())?,
            "compute_units" | "APFP_COMPUTE_UNITS" => {
                self.compute_units = value.parse().map_err(|_| invalid())?
            }
            "tile_n" | "APFP_TILE_SIZE_N" | "APFP_TILE_N" => {
                self.tile_n = value.parse().map_err(|_| invalid())?
            }
            "tile_m" | "APFP_TILE_SIZE_M" | "APFP_TILE_M" => {
                self.tile_m = value.parse().map_err(|_| invalid())?
            }
            "tile_k" | "APFP_TILE_SIZE_K" | "APFP_TILE_K" => {
                self.tile_k = value.parse().map_err(|_| invalid())?
            }
            "mult_base_bits" | "APFP_MULT_BASE_BITS" => {
                self.mult_base_bits = value.parse().map_err(|_| invalid())?
            }
            "add_base_bits" | "APFP_ADD_BASE_BITS" => {
                self.add_base_bits = value.parse().map_err(|_| invalid())?
            }
            "worker_threads" => self.worker_threads = value.parse().map_err(|_| invalid())?,
            "backend" | "APFP_BACKEND" => {
                self.backend = BackendKind::parse(value).ok_or_else(invalid)?
            }
            _ => return Err(ConfigError::UnknownKey(key.into())),
        }
        Ok(())
    }

    /// Parse a config file of `key = value` lines (`#` comments allowed).
    pub fn from_file(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = ApfpConfig::default();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Malformed { line: i + 1, text: raw.into() })?;
            cfg.set(k.trim(), v.trim())?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// True when no `APFP_TILE_*` override is present, so tests asserting
    /// the paper defaults don't fail spuriously under the very env knobs
    /// this module documents.
    fn tile_env_unset() -> bool {
        ["N", "M", "K"].iter().all(|d| {
            std::env::var_os(format!("APFP_TILE_{d}")).is_none()
                && std::env::var_os(format!("APFP_TILE_SIZE_{d}")).is_none()
        })
    }

    #[test]
    fn default_is_paper_config() {
        let c = ApfpConfig::default();
        assert_eq!(c.bits, 512);
        assert_eq!(c.prec(), 448);
        assert_eq!(c.tile_shape(), TileShape::from_env(), "defaults honor the env");
        if tile_env_unset() {
            assert_eq!((c.tile_n, c.tile_m, c.tile_k), (32, 32, 32));
            assert_eq!(c.tile_shape(), TileShape::default());
        }
        assert_eq!(c.mult_base_bits, 72);
        c.validate().unwrap();
    }

    #[test]
    fn set_accepts_both_naming_schemes() {
        let mut c = ApfpConfig::default();
        c.set("APFP_BITS", "1024").unwrap();
        assert_eq!(c.bits, 1024);
        assert_eq!(c.prec(), 960);
        c.set("compute_units", "8").unwrap();
        assert_eq!(c.compute_units, 8);
        c.set("APFP_BACKEND", "xla").unwrap();
        assert_eq!(c.backend, BackendKind::Xla);
        c.set("backend", "native").unwrap();
        assert_eq!(c.backend, BackendKind::Native);
        assert!(matches!(
            c.set("backend", "fpga"),
            Err(ConfigError::InvalidValue { .. })
        ));
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let mut c = ApfpConfig::default();
        assert!(matches!(c.set("nope", "1"), Err(ConfigError::UnknownKey(_))));
        assert!(matches!(
            c.set("bits", "abc"),
            Err(ConfigError::InvalidValue { .. })
        ));
    }

    #[test]
    fn validation_catches_bad_geometry() {
        let c = ApfpConfig { bits: 500, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ApfpConfig { compute_units: 0, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ApfpConfig { mult_base_bits: 8, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_rejects_degenerate_tiles() {
        use crate::runtime::manifest::MAX_TILE_DIM;
        for (n, m, k) in [(0, 8, 8), (8, 0, 8), (8, 8, 0), (MAX_TILE_DIM + 1, 8, 8)] {
            let c = ApfpConfig { tile_n: n, tile_m: m, tile_k: k, ..Default::default() };
            let err = c.validate().expect_err("degenerate tile must be rejected");
            assert!(matches!(err, ConfigError::Invalid(_)), "{err:?}");
            assert!(err.to_string().contains("tile"), "{err}");
        }
        // the tile_k knob parses through every spelling (fixed base shape,
        // so the assertions hold under APFP_TILE_* env overrides too)
        let mut c = ApfpConfig { tile_n: 32, tile_m: 32, tile_k: 32, ..Default::default() };
        c.set("APFP_TILE_SIZE_K", "4").unwrap();
        assert_eq!(c.tile_k, 4);
        c.set("APFP_TILE_K", "6").unwrap();
        assert_eq!(c.tile_k, 6);
        c.set("tile_k", "2").unwrap();
        assert_eq!(c.tile_shape(), TileShape { n: 32, m: 32, k: 2 });
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("apfp_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.cfg");
        std::fs::write(
            &path,
            "# paper Tab. III, 8-CU row\nAPFP_BITS = 512\ncompute_units = 8\ntile_n=32 # inline\n",
        )
        .unwrap();
        let c = ApfpConfig::from_file(&path).unwrap();
        assert_eq!(c.compute_units, 8);
        assert_eq!(c.bits, 512);
    }

    #[test]
    fn malformed_file_reports_line() {
        let dir = std::env::temp_dir().join("apfp_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.cfg");
        std::fs::write(&path, "bits 512\n").unwrap();
        match ApfpConfig::from_file(&path) {
            Err(ConfigError::Malformed { line: 1, .. }) => {}
            other => panic!("{other:?}"),
        }
    }
}
