//! Runtime configuration — the paper's CMake-time knobs (§IV-A) as a config
//! system: defaults, config-file parsing (`key = value` lines), and CLI
//! `--set key=value` overrides.
//!
//! | paper option            | field            |
//! |-------------------------|------------------|
//! | `APFP_BITS`             | `bits`           |
//! | `APFP_COMPUTE_UNITS`    | `compute_units`  |
//! | `APFP_TILE_SIZE_N`      | `tile_n`         |
//! | `APFP_TILE_SIZE_M`      | `tile_m`         |
//! | `APFP_MULT_BASE_BITS`   | `mult_base_bits` |
//! | `APFP_ADD_BASE_BITS`    | `add_base_bits`  |

use std::path::Path;

use crate::runtime::BackendKind;

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("cannot read config file: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed config line {line}: {text:?}")]
    Malformed { line: usize, text: String },
    #[error("unknown config key: {0:?}")]
    UnknownKey(String),
    #[error("invalid value for {key}: {value:?}")]
    InvalidValue { key: String, value: String },
    #[error("invalid configuration: {0}")]
    Invalid(String),
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ApfpConfig {
    /// Total packed bits per number (Fig. 1), incl. the 64-bit head word.
    pub bits: u32,
    /// Replication factor of the compute pipeline (§IV-A).
    pub compute_units: usize,
    /// Output tile rows per compute unit (§III).
    pub tile_n: usize,
    /// Output tile columns per compute unit (§III).
    pub tile_m: usize,
    /// Karatsuba bottom-out threshold in bits (§II-A / Fig. 3).
    pub mult_base_bits: u32,
    /// Bits added per pipeline stage in wide adders (§II-A / Fig. 3).
    pub add_base_bits: u32,
    /// Worker threads backing the virtual device (host-side knob).
    pub worker_threads: usize,
    /// Execution backend for the device stack (`APFP_BACKEND`): the native
    /// in-process executor (default; works on a clean checkout) or the
    /// XLA/PJRT artifact path.
    pub backend: BackendKind,
}

impl Default for ApfpConfig {
    fn default() -> Self {
        // The paper's evaluated configuration: 512-bit numbers, 32x32 tiles,
        // the Fig. 3 Pareto point (72-bit mult bottom-out, 64-bit adder
        // stages), one compute unit.
        ApfpConfig {
            bits: 512,
            compute_units: 1,
            tile_n: 32,
            tile_m: 32,
            mult_base_bits: 72,
            add_base_bits: 64,
            worker_threads: 0, // 0 = one per compute unit
            backend: BackendKind::from_env(),
        }
    }
}

impl ApfpConfig {
    /// Mantissa precision in bits (Fig. 1: total minus the 64-bit head).
    pub fn prec(&self) -> u32 {
        crate::softfloat::prec_for_bits(self.bits)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let err = |m: String| Err(ConfigError::Invalid(m));
        if self.bits % 512 != 0 || self.bits == 0 {
            return err(format!("bits must be a positive multiple of 512, got {}", self.bits));
        }
        if self.compute_units == 0 {
            return err("compute_units must be >= 1".into());
        }
        if self.tile_n == 0 || self.tile_m == 0 {
            return err("tile sizes must be >= 1".into());
        }
        if self.mult_base_bits < 17 {
            return err("mult_base_bits below the DSP width is meaningless".into());
        }
        if self.add_base_bits == 0 {
            return err("add_base_bits must be >= 1".into());
        }
        Ok(())
    }

    /// Apply one `key=value` override (CLI `--set`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        let invalid = || ConfigError::InvalidValue { key: key.into(), value: value.into() };
        match key {
            "bits" | "APFP_BITS" => self.bits = value.parse().map_err(|_| invalid())?,
            "compute_units" | "APFP_COMPUTE_UNITS" => {
                self.compute_units = value.parse().map_err(|_| invalid())?
            }
            "tile_n" | "APFP_TILE_SIZE_N" => self.tile_n = value.parse().map_err(|_| invalid())?,
            "tile_m" | "APFP_TILE_SIZE_M" => self.tile_m = value.parse().map_err(|_| invalid())?,
            "mult_base_bits" | "APFP_MULT_BASE_BITS" => {
                self.mult_base_bits = value.parse().map_err(|_| invalid())?
            }
            "add_base_bits" | "APFP_ADD_BASE_BITS" => {
                self.add_base_bits = value.parse().map_err(|_| invalid())?
            }
            "worker_threads" => self.worker_threads = value.parse().map_err(|_| invalid())?,
            "backend" | "APFP_BACKEND" => {
                self.backend = BackendKind::parse(value).ok_or_else(invalid)?
            }
            _ => return Err(ConfigError::UnknownKey(key.into())),
        }
        Ok(())
    }

    /// Parse a config file of `key = value` lines (`#` comments allowed).
    pub fn from_file(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = ApfpConfig::default();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Malformed { line: i + 1, text: raw.into() })?;
            cfg.set(k.trim(), v.trim())?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_config() {
        let c = ApfpConfig::default();
        assert_eq!(c.bits, 512);
        assert_eq!(c.prec(), 448);
        assert_eq!((c.tile_n, c.tile_m), (32, 32));
        assert_eq!(c.mult_base_bits, 72);
        c.validate().unwrap();
    }

    #[test]
    fn set_accepts_both_naming_schemes() {
        let mut c = ApfpConfig::default();
        c.set("APFP_BITS", "1024").unwrap();
        assert_eq!(c.bits, 1024);
        assert_eq!(c.prec(), 960);
        c.set("compute_units", "8").unwrap();
        assert_eq!(c.compute_units, 8);
        c.set("APFP_BACKEND", "xla").unwrap();
        assert_eq!(c.backend, BackendKind::Xla);
        c.set("backend", "native").unwrap();
        assert_eq!(c.backend, BackendKind::Native);
        assert!(matches!(
            c.set("backend", "fpga"),
            Err(ConfigError::InvalidValue { .. })
        ));
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        let mut c = ApfpConfig::default();
        assert!(matches!(c.set("nope", "1"), Err(ConfigError::UnknownKey(_))));
        assert!(matches!(
            c.set("bits", "abc"),
            Err(ConfigError::InvalidValue { .. })
        ));
    }

    #[test]
    fn validation_catches_bad_geometry() {
        let mut c = ApfpConfig::default();
        c.bits = 500;
        assert!(c.validate().is_err());
        c = ApfpConfig::default();
        c.compute_units = 0;
        assert!(c.validate().is_err());
        c = ApfpConfig::default();
        c.mult_base_bits = 8;
        assert!(c.validate().is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("apfp_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("test.cfg");
        std::fs::write(
            &path,
            "# paper Tab. III, 8-CU row\nAPFP_BITS = 512\ncompute_units = 8\ntile_n=32 # inline\n",
        )
        .unwrap();
        let c = ApfpConfig::from_file(&path).unwrap();
        assert_eq!(c.compute_units, 8);
        assert_eq!(c.bits, 512);
    }

    #[test]
    fn malformed_file_reports_line() {
        let dir = std::env::temp_dir().join("apfp_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.cfg");
        std::fs::write(&path, "bits 512\n").unwrap();
        match ApfpConfig::from_file(&path) {
            Err(ConfigError::Malformed { line: 1, .. }) => {}
            other => panic!("{other:?}"),
        }
    }
}
