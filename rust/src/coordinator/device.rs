//! The CUDA-like device interface (§IV-B) over the virtual accelerator.
//!
//! `Device::new` "programs the bitstream": it validates the configuration
//! (tile geometry included — degenerate shapes are typed errors, never
//! worker panics), spawns one worker thread per configured compute unit,
//! each with its own runtime shaped to the configured tiles, and records
//! the Fig. 4 SLR/DDR-bank placement.  [`Device::gemm`] launches the §III
//! dataflow across the CUs as a one-shot wrapper over [`Device::stream`],
//! the batched API that keeps operands resident between launches;
//! `mul_stream`/`add_stream`/`mac_stream` drive the Tab. I/II
//! microbenchmark path.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::matrix::Matrix;
use super::metrics::{Metrics, MetricsSnapshot};
use super::model_metrics::{ModelMetrics, ModelMetricsSnapshot};
use super::stream::DeviceStream;
use super::worker::{CuHealth, Job, StreamKind, Supervisor};
use crate::config::ApfpConfig;
use crate::hwmodel::floorplan::{self, Placement};
use crate::pack::PlaneBatch;
use crate::runtime::{self, manifest, ArtifactKind};

pub struct Device {
    pub(super) config: ApfpConfig,
    /// One supervised worker per compute unit.  Supervision keeps the
    /// handle replaceable: a stream that detects a dead CU asks its
    /// supervisor to respawn (or quarantine) it without tearing the
    /// device down.
    pub(super) workers: Vec<Supervisor>,
    pub(super) placements: Vec<Placement>,
    pub(super) metrics: Arc<Metrics>,
    /// The hardware-model ledger, fed by the stream's retirement drain
    /// when the backend is `sim`; all-zero on native/xla.
    pub(super) model_metrics: Arc<ModelMetrics>,
    pub(super) artifacts: Vec<manifest::ArtifactMeta>,
}

#[derive(Clone, Debug)]
pub struct GemmStats {
    pub wall_s: f64,
    pub tiles: u64,
    pub artifact_calls: u64,
    pub macs: u64,
    /// fraction of datapath time in marshaling (coordinator overhead)
    pub marshal_fraction: f64,
}

impl Device {
    /// Open the virtual device with `config.compute_units` workers on
    /// `config.backend`, reading artifacts from `artifact_dir`.  On the
    /// native backend a missing artifact directory is fine: the builtin
    /// in-memory manifest — GEMM tiles shaped by `config.tile_shape()` —
    /// lights up the full device stack on a clean checkout.
    pub fn new(config: ApfpConfig, artifact_dir: &std::path::Path) -> Result<Self> {
        config.validate()?;
        let widths = config.effective_widths();
        let artifacts = runtime::load_metas_widths(
            artifact_dir,
            config.backend,
            config.tile_shape(),
            &widths,
        )
        .context("opening device")?;
        let metrics = Metrics::new();
        let cus = config.compute_units;
        let workers = (0..cus)
            .map(|cu| {
                Supervisor::spawn(
                    cu,
                    artifact_dir.to_path_buf(),
                    config.backend,
                    config.tile_shape(),
                    widths.clone(),
                    config.faults,
                    metrics.clone(),
                    config.retry.respawn_limit,
                )
            })
            .collect::<std::io::Result<Vec<_>>>()
            .context("spawning CU workers")?;
        // per-width ledger slots follow the widths actually loaded (an
        // on-disk manifest may differ from the configured set)
        let mut loaded: Vec<u32> = Vec::new();
        for m in &artifacts {
            if !loaded.contains(&m.bits) {
                loaded.push(m.bits);
            }
        }
        Ok(Device {
            placements: floorplan::assign(cus),
            config,
            workers,
            metrics,
            model_metrics: ModelMetrics::with_widths(&loaded),
            artifacts,
        })
    }

    pub fn config(&self) -> &ApfpConfig {
        &self.config
    }

    /// Fig. 4 placement of each CU (bank/SLR).
    pub fn placements(&self) -> &[Placement] {
        &self.placements
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The hardware-model ledger: modeled cycles, DRAM traffic, energy and
    /// per-phase seconds accumulated by retired launches on the simulated
    /// backend (`APFP_BACKEND=sim`).  All-zero (`!is_live()`) on native
    /// and xla.
    pub fn model_metrics(&self) -> ModelMetricsSnapshot {
        self.model_metrics.snapshot()
    }

    /// The per-CU health ledger: respawn counts, quarantine flags, and
    /// the most recent incident per compute unit.
    pub fn health(&self) -> Vec<CuHealth> {
        self.workers.iter().map(Supervisor::health).collect()
    }

    /// Allocate a zeroed host-side matrix at the device's default
    /// precision ([`ApfpConfig::bits`]).
    pub fn alloc(&self, rows: usize, cols: usize) -> Matrix {
        Matrix::zeros(rows, cols, self.config.prec())
    }

    /// Allocate a zeroed host-side matrix at an explicit packed width.
    pub fn alloc_at(&self, bits: u32, rows: usize, cols: usize) -> Matrix {
        Matrix::zeros(rows, cols, crate::softfloat::prec_for_bits(bits))
    }

    /// Every packed width this device loaded kernels for, in manifest
    /// order.  Each is a valid `bits` argument to the `*_at` launch APIs.
    pub fn widths(&self) -> Vec<u32> {
        let mut w: Vec<u32> = Vec::new();
        for m in &self.artifacts {
            if !w.contains(&m.bits) {
                w.push(m.bits);
            }
        }
        w
    }

    pub(super) fn artifact_for_at(
        &self,
        kind: ArtifactKind,
        bits: u32,
    ) -> Result<&manifest::ArtifactMeta, manifest::ManifestError> {
        self.artifacts
            .iter()
            .filter(|m| m.kind == kind && m.bits == bits)
            .max_by_key(|m| m.t_n * m.t_m)
            .ok_or_else(|| manifest::ManifestError::NoArtifact {
                kind: kind.clone(),
                bits,
                loaded: self.widths(),
            })
    }

    fn artifact_for(&self, kind: ArtifactKind) -> Result<&manifest::ArtifactMeta> {
        Ok(self.artifact_for_at(kind, self.config.bits)?)
    }

    // ---- GEMM (§III) ------------------------------------------------------

    /// Open a batched GEMM stream: device-resident buffers, packed once,
    /// with chained launches that keep C on the device and hazard-tracked
    /// pipelining of launches with disjoint buffer sets (see
    /// [`crate::coordinator::stream`]).  The stream serves **every** width
    /// the device loaded: `enqueue_gemm` launches at the default width,
    /// `enqueue_gemm_at` picks one per launch.
    pub fn stream(&self) -> Result<DeviceStream<'_>> {
        // the default launch width must be servable up front
        self.artifact_for(ArtifactKind::Gemm)?;
        Ok(DeviceStream::new(self))
    }

    /// C += A @ B across all compute units; returns the updated C and
    /// stats.  One-shot wrapper over [`Device::stream`]: upload all three
    /// operands, enqueue, wait, download.  Workloads with many launches
    /// over shared operands should hold a stream instead and amortize the
    /// packing (alpha = beta = 1 exactly as the paper fixes, §III).
    pub fn gemm(&self, a: &Matrix, b: &Matrix, c: &Matrix) -> Result<(Matrix, GemmStats)> {
        self.gemm_at(self.config.bits, a, b, c)
    }

    /// [`Device::gemm`] at an explicit packed width: the one-shot
    /// mixed-precision entry point (operands must already be at
    /// `prec_for_bits(bits)`; see `Matrix::to_prec` for conversion).
    pub fn gemm_at(
        &self,
        bits: u32,
        a: &Matrix,
        b: &Matrix,
        c: &Matrix,
    ) -> Result<(Matrix, GemmStats)> {
        anyhow::ensure!(a.cols() == b.rows(), "inner dimensions: {} vs {}", a.cols(), b.rows());
        anyhow::ensure!(a.rows() == c.rows() && b.cols() == c.cols(), "output shape");
        // unknown widths surface the typed manifest error before any upload
        self.artifact_for_at(ArtifactKind::Gemm, bits)?;
        let before = self.metrics.snapshot();
        let t0 = Instant::now();

        let mut stream = self.stream()?;
        let ha = stream.upload(a);
        let hb = stream.upload(b);
        let hc = stream.upload(c);
        stream.enqueue_gemm_at(bits, ha, hb, hc)?;
        stream.wait()?;
        let out = stream.download(hc)?;

        let after = self.metrics.snapshot();
        let stats = GemmStats {
            wall_s: t0.elapsed().as_secs_f64(),
            tiles: after.tiles - before.tiles,
            artifact_calls: after.artifact_calls - before.artifact_calls,
            macs: after.macs - before.macs,
            marshal_fraction: {
                let exec = after.exec_ns - before.exec_ns;
                let marshal = after.marshal_ns - before.marshal_ns;
                if exec + marshal == 0 { 0.0 } else { marshal as f64 / (exec + marshal) as f64 }
            },
        };
        Ok((out, stats))
    }

    // ---- stream operators (§V-B path) ---------------------------------------

    fn stream_op(
        &self,
        kind: ArtifactKind,
        stream_kind: StreamKind,
        operands: &[&[crate::softfloat::ApFloat]],
    ) -> Result<Vec<crate::softfloat::ApFloat>> {
        let meta = self.artifact_for(kind)?;
        let artifact = meta.name.clone();
        let Some(first) = operands.first() else {
            anyhow::bail!("stream op needs at least one operand");
        };
        let len = first.len();
        for o in operands {
            anyhow::ensure!(o.len() == len, "stream operand lengths differ");
        }
        let prec = self.config.prec();
        // partition the stream across the *live* CUs (the paper
        // "partitions the input problem across the replications");
        // quarantined units take no further work
        let live: Vec<usize> =
            (0..self.workers.len()).filter(|&i| !self.workers[i].is_quarantined()).collect();
        anyhow::ensure!(!live.is_empty(), "every compute unit is quarantined");
        let chunk = len.div_ceil(live.len()).max(1);
        let (reply_tx, reply_rx) = channel();
        let mut pending = 0;
        for (w, start) in (0..len).step_by(chunk).enumerate() {
            let end = (start + chunk).min(len);
            let planes: Vec<PlaneBatch> = operands
                .iter()
                .map(|o| PlaneBatch::from_slice(&o[start..end], prec))
                .collect();
            let cu = live[w % live.len()];
            let job = Job::Stream {
                artifact: artifact.clone(),
                kind: stream_kind,
                operands: planes,
                offset: start,
                reply: reply_tx.clone(),
            };
            if self.workers[cu].submit(job).is_err() {
                // worker thread gone: abort with a typed-ish error instead
                // of panicking; replies already in flight are discarded
                // with the receiver
                return Err(anyhow!("compute unit {cu} is gone; stream operator aborted"));
            }
            pending += 1;
        }
        drop(reply_tx);
        let mut out = vec![crate::softfloat::ApFloat::zero(prec); len];
        for _ in 0..pending {
            let res = reply_rx.recv()?;
            let planes = res.planes?;
            for (i, v) in planes.to_vec().into_iter().enumerate() {
                out[res.offset + i] = v;
            }
        }
        Ok(out)
    }

    /// Element-wise c[i] = a[i] * b[i] through the multiplier artifacts.
    pub fn mul_stream(
        &self,
        a: &[crate::softfloat::ApFloat],
        b: &[crate::softfloat::ApFloat],
    ) -> Result<Vec<crate::softfloat::ApFloat>> {
        self.stream_op(ArtifactKind::Mul, StreamKind::Binop, &[a, b])
    }

    /// Element-wise c[i] = a[i] + b[i].
    pub fn add_stream(
        &self,
        a: &[crate::softfloat::ApFloat],
        b: &[crate::softfloat::ApFloat],
    ) -> Result<Vec<crate::softfloat::ApFloat>> {
        self.stream_op(ArtifactKind::Add, StreamKind::Binop, &[a, b])
    }

    /// Element-wise out[i] = c[i] + a[i] * b[i].
    pub fn mac_stream(
        &self,
        c: &[crate::softfloat::ApFloat],
        a: &[crate::softfloat::ApFloat],
        b: &[crate::softfloat::ApFloat],
    ) -> Result<Vec<crate::softfloat::ApFloat>> {
        self.stream_op(ArtifactKind::Mac, StreamKind::Mac, &[c, a, b])
    }
}
