//! The CUDA-like device interface (§IV-B) over the virtual accelerator.
//!
//! `Device::new` "programs the bitstream": it spawns one worker thread per
//! configured compute unit, each with its own PJRT runtime, and records the
//! Fig. 4 SLR/DDR-bank placement.  `gemm` launches the §III dataflow across
//! the CUs; `mul_stream`/`add_stream`/`mac_stream` drive the Tab. I/II
//! microbenchmark path.  Data stays on the "device" as [`Matrix`] buffers
//! between calls, so workloads with many small operations amortize
//! transfer, as the paper recommends for fine-grained use.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::matrix::Matrix;
use super::metrics::{Metrics, MetricsSnapshot};
use super::scheduler::Partition;
use super::worker::{GemmOperands, Job, StreamKind, WorkerHandle};
use crate::config::ApfpConfig;
use crate::hwmodel::floorplan::{self, Placement};
use crate::pack::PlaneBatch;
use crate::runtime::{self, manifest, ArtifactKind};

pub struct Device {
    config: ApfpConfig,
    workers: Vec<WorkerHandle>,
    placements: Vec<Placement>,
    metrics: Arc<Metrics>,
    artifacts: Vec<manifest::ArtifactMeta>,
}

#[derive(Clone, Debug)]
pub struct GemmStats {
    pub wall_s: f64,
    pub tiles: u64,
    pub artifact_calls: u64,
    pub macs: u64,
    /// fraction of datapath time in marshaling (coordinator overhead)
    pub marshal_fraction: f64,
}

impl Device {
    /// Open the virtual device with `config.compute_units` workers on
    /// `config.backend`, reading artifacts from `artifact_dir`.  On the
    /// native backend a missing artifact directory is fine: the builtin
    /// in-memory manifest lights up the full device stack on a clean
    /// checkout.
    pub fn new(config: ApfpConfig, artifact_dir: &std::path::Path) -> Result<Self> {
        config.validate().map_err(|e| anyhow!("{e}"))?;
        let artifacts =
            runtime::load_metas(artifact_dir, config.backend).context("opening device")?;
        let metrics = Metrics::new();
        let cus = config.compute_units;
        let workers = (0..cus)
            .map(|cu| {
                WorkerHandle::spawn(cu, artifact_dir.to_path_buf(), config.backend, metrics.clone())
            })
            .collect();
        Ok(Device {
            placements: floorplan::assign(cus),
            config,
            workers,
            metrics,
            artifacts,
        })
    }

    pub fn config(&self) -> &ApfpConfig {
        &self.config
    }

    /// Fig. 4 placement of each CU (bank/SLR).
    pub fn placements(&self) -> &[Placement] {
        &self.placements
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Allocate a zeroed device matrix (CUDA-like `cudaMalloc`).
    pub fn alloc(&self, rows: usize, cols: usize) -> Matrix {
        Matrix::zeros(rows, cols, self.config.prec())
    }

    fn artifact_for(&self, kind: ArtifactKind) -> Result<&manifest::ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|m| m.kind == kind && m.bits == self.config.bits)
            .max_by_key(|m| m.t_n * m.t_m)
            .ok_or_else(|| {
                anyhow!("no {kind:?} artifact for {} bits — run `make artifacts`", self.config.bits)
            })
    }

    // ---- GEMM (§III) ------------------------------------------------------

    /// C += A @ B across all compute units; returns the updated C and stats.
    ///
    /// alpha = beta = 1 exactly as the paper fixes (§III).
    pub fn gemm(&self, a: &Matrix, b: &Matrix, c: &Matrix) -> Result<(Matrix, GemmStats)> {
        anyhow::ensure!(a.cols() == b.rows(), "inner dimensions: {} vs {}", a.cols(), b.rows());
        anyhow::ensure!(a.rows() == c.rows() && b.cols() == c.cols(), "output shape");
        let meta = self.artifact_for(ArtifactKind::Gemm)?;
        let part = Partition {
            n: a.rows(),
            m: b.cols(),
            k: a.cols(),
            tile_n: meta.t_n,
            tile_m: meta.t_m,
            k_tile: meta.k_tile,
            compute_units: self.workers.len(),
        };
        let artifact = meta.name.clone();
        let before = self.metrics.snapshot();
        let t0 = Instant::now();

        // Pack the three operands into shared plane panels exactly once —
        // the "copy to device DDR" step.  Workers extract tiles from these
        // with plane-row copies; nothing clones a full Matrix per launch.
        let t_pack = Instant::now();
        let ops =
            Arc::new(GemmOperands { a: a.to_panel(), b: b.to_panel(), c: c.to_panel() });
        self.metrics.add_marshal_ns(t_pack.elapsed().as_nanos() as u64);
        let (reply_tx, reply_rx) = channel();

        // Submit each CU's row-band tiles to its own queue.  Submission
        // round-robins across CUs one tile at a time so the bounded queues
        // fill evenly and a stalled CU backpressures only its own band.
        let mut pending = 0usize;
        let mut iters: Vec<_> =
            (0..self.workers.len()).map(|cu| part.tiles_for(cu).into_iter()).collect();
        let mut active = true;
        while active {
            active = false;
            for (cu, it) in iters.iter_mut().enumerate() {
                if let Some(tile) = it.next() {
                    self.workers[cu].submit(Job::GemmTile {
                        artifact: artifact.clone(),
                        ops: ops.clone(),
                        tile,
                        part: part.clone(),
                        reply: reply_tx.clone(),
                    });
                    pending += 1;
                    active = true;
                }
            }
        }
        drop(reply_tx);

        // Assemble the output as tiles complete (any order).  Every output
        // element is owned by exactly one tile (bands clip `tile.rows`), so
        // the result starts zeroed and each write lands once.
        let mut out = Matrix::zeros(c.rows(), c.cols(), c.prec());
        for _ in 0..pending {
            let res = reply_rx.recv().context("collecting tile result")?;
            let planes = res.planes.with_context(|| {
                format!("tile at ({}, {}) on CU{}", res.tile.r0, res.tile.c0, res.tile.cu)
            })?;
            out.write_tile(res.tile.r0, res.tile.c0, res.tile.rows, part.tile_m, &planes);
        }

        let after = self.metrics.snapshot();
        let stats = GemmStats {
            wall_s: t0.elapsed().as_secs_f64(),
            tiles: after.tiles - before.tiles,
            artifact_calls: after.artifact_calls - before.artifact_calls,
            macs: after.macs - before.macs,
            marshal_fraction: {
                let exec = after.exec_ns - before.exec_ns;
                let marshal = after.marshal_ns - before.marshal_ns;
                if exec + marshal == 0 { 0.0 } else { marshal as f64 / (exec + marshal) as f64 }
            },
        };
        Ok((out, stats))
    }

    // ---- stream operators (§V-B path) ---------------------------------------

    fn stream(
        &self,
        kind: ArtifactKind,
        stream_kind: StreamKind,
        operands: &[&[crate::softfloat::ApFloat]],
    ) -> Result<Vec<crate::softfloat::ApFloat>> {
        let meta = self.artifact_for(kind)?;
        let artifact = meta.name.clone();
        let len = operands[0].len();
        for o in operands {
            anyhow::ensure!(o.len() == len, "stream operand lengths differ");
        }
        let prec = self.config.prec();
        // partition the stream across CUs (the paper "partitions the input
        // problem across the replications")
        let chunk = len.div_ceil(self.workers.len()).max(1);
        let (reply_tx, reply_rx) = channel();
        let mut pending = 0;
        for (w, start) in (0..len).step_by(chunk).enumerate() {
            let end = (start + chunk).min(len);
            let planes: Vec<PlaneBatch> = operands
                .iter()
                .map(|o| PlaneBatch::from_slice(&o[start..end], prec))
                .collect();
            self.workers[w % self.workers.len()].submit(Job::Stream {
                artifact: artifact.clone(),
                kind: stream_kind,
                operands: planes,
                offset: start,
                reply: reply_tx.clone(),
            });
            pending += 1;
        }
        drop(reply_tx);
        let mut out = vec![crate::softfloat::ApFloat::zero(prec); len];
        for _ in 0..pending {
            let res = reply_rx.recv()?;
            let planes = res.planes?;
            for (i, v) in planes.to_vec().into_iter().enumerate() {
                out[res.offset + i] = v;
            }
        }
        Ok(out)
    }

    /// Element-wise c[i] = a[i] * b[i] through the multiplier artifacts.
    pub fn mul_stream(
        &self,
        a: &[crate::softfloat::ApFloat],
        b: &[crate::softfloat::ApFloat],
    ) -> Result<Vec<crate::softfloat::ApFloat>> {
        self.stream(ArtifactKind::Mul, StreamKind::Binop, &[a, b])
    }

    /// Element-wise c[i] = a[i] + b[i].
    pub fn add_stream(
        &self,
        a: &[crate::softfloat::ApFloat],
        b: &[crate::softfloat::ApFloat],
    ) -> Result<Vec<crate::softfloat::ApFloat>> {
        self.stream(ArtifactKind::Add, StreamKind::Binop, &[a, b])
    }

    /// Element-wise out[i] = c[i] + a[i] * b[i].
    pub fn mac_stream(
        &self,
        c: &[crate::softfloat::ApFloat],
        a: &[crate::softfloat::ApFloat],
        b: &[crate::softfloat::ApFloat],
    ) -> Result<Vec<crate::softfloat::ApFloat>> {
        self.stream(ArtifactKind::Mac, StreamKind::Mac, &[c, a, b])
    }
}
