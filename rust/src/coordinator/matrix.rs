//! Row-major APFP matrices and tile extraction for the GEMM datapath.

use crate::pack::{PlaneBatch, PlanePanel};
use crate::softfloat::ApFloat;
use crate::testkit::Rng;

/// A dense row-major matrix of `ApFloat` scalars, all at one precision.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    prec: u32,
    vals: Vec<ApFloat>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize, prec: u32) -> Self {
        Matrix { rows, cols, prec, vals: vec![ApFloat::zero(prec); rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, prec: u32, mut f: impl FnMut(usize, usize) -> ApFloat) -> Self {
        let mut vals = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                let v = f(i, j);
                assert_eq!(v.prec(), prec);
                vals.push(v);
            }
        }
        Matrix { rows, cols, prec, vals }
    }

    /// Uniform random normalized values with exponents in +-`exp_range`
    /// (deterministic: seeded testkit PRNG).
    pub fn random(rows: usize, cols: usize, prec: u32, seed: u64, exp_range: i64) -> Self {
        let mut rng = Rng::from_seed(seed);
        Matrix::from_fn(rows, cols, prec, |_, _| {
            let n = (prec / 64) as usize;
            let mut mant = rng.limbs(n);
            if let Some(top) = mant.last_mut() {
                *top |= 1 << 63;
            }
            ApFloat::from_parts(rng.bool(), rng.range_i64(-exp_range, exp_range), mant, prec)
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn prec(&self) -> u32 {
        self.prec
    }

    // apfp-lint: allow(index, scope=fn, reason="row-major accessor: panicking on out-of-range is the Index-trait contract; device paths go through clipped tiles")
    pub fn get(&self, i: usize, j: usize) -> &ApFloat {
        &self.vals[i * self.cols + j]
    }

    /// Mutable element access for in-place accumulation (`mac_into`); the
    /// caller must keep the element at the matrix's precision.
    // apfp-lint: allow(index, scope=fn, reason="row-major accessor: panicking on out-of-range is the Index-trait contract; device paths go through clipped tiles")
    pub fn get_mut(&mut self, i: usize, j: usize) -> &mut ApFloat {
        &mut self.vals[i * self.cols + j]
    }

    /// Row `i` as a contiguous slice (the natural A-panel of the row-major
    /// GEMM kernel — no packing step needed on the A side).
    pub fn row(&self, i: usize) -> &[ApFloat] {
        &self.vals[i * self.cols..(i + 1) * self.cols]
    }

    // apfp-lint: allow(index, scope=fn, reason="row-major accessor: panicking on out-of-range is the Index-trait contract; device paths go through clipped tiles")
    pub fn set(&mut self, i: usize, j: usize, v: ApFloat) {
        assert_eq!(v.prec(), self.prec);
        self.vals[i * self.cols + j] = v;
    }

    pub fn values(&self) -> &[ApFloat] {
        &self.vals
    }

    /// Mutable row-major storage, for kernels that update elements in
    /// place (the tiled GEMM writes output row bands through this).
    /// Crate-internal: writers must preserve the uniform-precision
    /// invariant that [`Matrix::set`] enforces.
    pub(crate) fn values_mut(&mut self) -> &mut [ApFloat] {
        &mut self.vals
    }

    /// Consume the matrix into its row-major values — the clone-free
    /// marshaling path for handing results back to caller-owned storage
    /// (`blas::gemm`'s write-back).
    pub fn into_values(self) -> Vec<ApFloat> {
        self.vals
    }

    /// Pack the whole matrix into the plane layout once (the "copy to
    /// device DDR" step): after this, tile extraction is plane-row copies
    /// instead of per-element encodes.
    pub fn to_panel(&self) -> PlanePanel {
        let mut p = PlanePanel::zeros(self.rows, self.cols, self.prec);
        for i in 0..self.rows {
            for j in 0..self.cols {
                p.set(i, j, self.get(i, j));
            }
        }
        p
    }

    /// Decode a device-resident panel back into a host matrix — the
    /// "copy from device DDR" step a stream's `download` performs.
    pub fn from_panel(p: &PlanePanel) -> Self {
        Matrix::from_fn(p.rows(), p.cols(), p.prec(), |i, j| p.get(i, j))
    }

    /// Extract a `tn x tm` tile starting at (r0, c0) into the plane layout;
    /// out-of-range positions pad with APFP zero (absorbing for mul,
    /// identity for add — exactly how the hardware pads partial tiles).
    pub fn extract_tile(&self, r0: usize, c0: usize, tn: usize, tm: usize) -> PlaneBatch {
        let mut b = PlaneBatch::zeros(tn * tm, self.prec);
        self.extract_tile_into(r0, c0, tn, tm, &mut b);
        b
    }

    /// [`Matrix::extract_tile`] into a caller-owned batch: reuses `out`'s
    /// storage, so a hot tile loop extracts with zero allocations.
    pub fn extract_tile_into(
        &self,
        r0: usize,
        c0: usize,
        tn: usize,
        tm: usize,
        out: &mut PlaneBatch,
    ) {
        out.reset(tn * tm, self.prec);
        for i in 0..tn {
            if r0 + i >= self.rows {
                break;
            }
            for j in 0..tm {
                if c0 + j >= self.cols {
                    break;
                }
                out.set(i * tm + j, self.get(r0 + i, c0 + j));
            }
        }
    }

    /// Write a tile's planes back into the matrix (clipping at the edges).
    /// Host-side utility (tests, ad-hoc tooling): the device path lands
    /// tiles in panels via [`PlanePanel::write_tile`] without ever
    /// materializing a `Matrix`.
    pub fn write_tile(&mut self, r0: usize, c0: usize, tn: usize, tm: usize, b: &PlaneBatch) {
        for i in 0..tn {
            if r0 + i >= self.rows {
                break;
            }
            for j in 0..tm {
                if c0 + j >= self.cols {
                    break;
                }
                self.set(r0 + i, c0 + j, b.get(i * tm + j));
            }
        }
    }

    /// Re-round every element to `new_prec` bits of mantissa — the host
    /// side of a device width conversion ([`ApFloat::to_prec`] per
    /// element: RNDZ truncation on narrowing, zero-fill on widening).
    pub fn to_prec(&self, new_prec: u32) -> Self {
        Matrix::from_fn(self.rows, self.cols, new_prec, |i, j| self.get(i, j).to_prec(new_prec))
    }

    /// Max |relative error| vs another matrix through f64 (diagnostics).
    pub fn max_rel_err_f64(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut worst: f64 = 0.0;
        for (x, y) in self.vals.iter().zip(other.vals.iter()) {
            let (fx, fy) = (x.to_f64(), y.to_f64());
            let denom = fx.abs().max(fy.abs()).max(1e-300);
            worst = worst.max((fx - fy).abs() / denom);
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_roundtrip() {
        let m = Matrix::random(10, 7, 448, 42, 20);
        let t = m.extract_tile(2, 3, 4, 4);
        let mut m2 = m.clone();
        m2.write_tile(2, 3, 4, 4, &t);
        assert_eq!(m, m2);
    }

    #[test]
    fn edge_tiles_pad_with_zero() {
        let m = Matrix::random(5, 5, 448, 1, 10);
        let t = m.extract_tile(4, 4, 4, 4); // only (0,0) in range
        assert_eq!(&t.get(0), m.get(4, 4));
        for idx in 1..16 {
            assert!(t.get(idx).is_zero());
        }
    }

    #[test]
    fn row_get_mut_and_into_values_agree_with_get() {
        let mut m = Matrix::random(4, 3, 448, 5, 10);
        assert_eq!(m.row(2)[1], *m.get(2, 1));
        let want = m.get(1, 2).neg();
        let slot = m.get_mut(1, 2);
        *slot = slot.neg();
        assert_eq!(*m.get(1, 2), want);
        let snapshot: Vec<_> = m.values().to_vec();
        assert_eq!(m.into_values(), snapshot);
    }

    #[test]
    fn panel_and_direct_extraction_agree() {
        let m = Matrix::random(11, 9, 448, 7, 30);
        let p = m.to_panel();
        assert_eq!((p.rows(), p.cols(), p.prec()), (11, 9, 448));
        assert_eq!(Matrix::from_panel(&p), m, "panel roundtrip");
        let mut from_panel = PlaneBatch::default();
        let mut from_matrix = PlaneBatch::default();
        // interior, right edge, bottom edge, far corner (pure padding rows)
        for (r0, c0) in [(0usize, 0usize), (3, 6), (8, 2), (10, 8)] {
            p.extract_tile_into(r0, c0, 4, 4, &mut from_panel);
            m.extract_tile_into(r0, c0, 4, 4, &mut from_matrix);
            assert_eq!(from_panel, from_matrix, "tile at ({r0},{c0})");
            assert_eq!(from_matrix, m.extract_tile(r0, c0, 4, 4));
        }
    }

    #[test]
    fn to_prec_casts_every_element_and_round_trips() {
        let m = Matrix::random(5, 4, 448, 9, 20);
        let wide = m.to_prec(960);
        assert_eq!((wide.rows(), wide.cols(), wide.prec()), (5, 4, 960));
        // widening is exact: narrowing back is the identity
        assert_eq!(wide.to_prec(448), m);
        let narrow = m.to_prec(64);
        assert_eq!(narrow.prec(), 64);
        for i in 0..5 {
            for j in 0..4 {
                assert_eq!(narrow.get(i, j), &m.get(i, j).to_prec(64));
            }
        }
    }

    #[test]
    fn from_fn_layout() {
        let m = Matrix::from_fn(3, 2, 448, |i, j| ApFloat::from_u64((i * 10 + j) as u64 + 1, 448));
        assert_eq!(m.get(2, 1), &ApFloat::from_u64(22, 448));
    }
}
