//! Shared atomic counters for the coordinator (the paper's host runtime
//! reports the same quantities per kernel invocation).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

#[derive(Debug, Default)]
pub struct Metrics {
    /// GEMM tiles completed.
    pub tiles: AtomicU64,
    /// PJRT artifact invocations (tile K-steps + stream chunks).
    pub artifact_calls: AtomicU64,
    /// APFP multiply-add operations flowed through the datapath.
    pub macs: AtomicU64,
    /// Nanoseconds spent inside artifact execution (sum over workers).
    pub exec_ns: AtomicU64,
    /// Nanoseconds spent marshaling tiles (extract/writeback, sum over workers).
    pub marshal_ns: AtomicU64,
    /// GEMM launches enqueued (one-shot `Device::gemm` counts one each).
    pub enqueues: AtomicU64,
    /// B tile-grids packed (stream cache misses: first use of a buffer as
    /// B, or reuse after it was written).
    pub panel_builds: AtomicU64,
    /// B tile-grids reused from a stream's cache (the packing a batched
    /// launch amortized away; always 0 for one-shot calls).
    pub panel_reuses: AtomicU64,
    /// High-water mark of launches simultaneously in flight on any stream
    /// (hazard-tracked pipelining: >= 2 proves independent launches
    /// overlapped instead of draining between enqueues).
    pub inflight_max: AtomicU64,
    /// Nanoseconds the leader spent blocked collecting tile replies —
    /// divide by `launches` for the per-launch drain time.
    pub drain_ns: AtomicU64,
    /// Launches retired (drained and written back, or failed cleanly).
    pub launches: AtomicU64,
    /// Tile jobs redispatched after a failed/panicked attempt or a lost
    /// dispatch (self-healing retry arms; 0 on every healthy path).
    pub retries: AtomicU64,
    /// Dead compute units brought back with a fresh worker + runtime.
    pub respawns: AtomicU64,
    /// Compute units quarantined after exhausting their respawn budget.
    pub quarantined_cus: AtomicU64,
}

impl Metrics {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn add_tiles(&self, n: u64) {
        self.tiles.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_calls(&self, n: u64) {
        self.artifact_calls.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_macs(&self, n: u64) {
        self.macs.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_exec_ns(&self, n: u64) {
        self.exec_ns.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_marshal_ns(&self, n: u64) {
        self.marshal_ns.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_enqueues(&self, n: u64) {
        self.enqueues.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_panel_builds(&self, n: u64) {
        self.panel_builds.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_panel_reuses(&self, n: u64) {
        self.panel_reuses.fetch_add(n, Ordering::Relaxed);
    }

    /// Record an observed in-flight launch depth; keeps the maximum.
    pub fn record_inflight(&self, n: u64) {
        self.inflight_max.fetch_max(n, Ordering::Relaxed);
    }

    pub fn add_drain_ns(&self, n: u64) {
        self.drain_ns.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_launches(&self, n: u64) {
        self.launches.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_retries(&self, n: u64) {
        self.retries.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_respawns(&self, n: u64) {
        self.respawns.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_quarantined(&self, n: u64) {
        self.quarantined_cus.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            tiles: self.tiles.load(Ordering::Relaxed),
            artifact_calls: self.artifact_calls.load(Ordering::Relaxed),
            macs: self.macs.load(Ordering::Relaxed),
            exec_ns: self.exec_ns.load(Ordering::Relaxed),
            marshal_ns: self.marshal_ns.load(Ordering::Relaxed),
            enqueues: self.enqueues.load(Ordering::Relaxed),
            panel_builds: self.panel_builds.load(Ordering::Relaxed),
            panel_reuses: self.panel_reuses.load(Ordering::Relaxed),
            inflight_max: self.inflight_max.load(Ordering::Relaxed),
            drain_ns: self.drain_ns.load(Ordering::Relaxed),
            launches: self.launches.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            quarantined_cus: self.quarantined_cus.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub tiles: u64,
    pub artifact_calls: u64,
    pub macs: u64,
    pub exec_ns: u64,
    pub marshal_ns: u64,
    pub enqueues: u64,
    pub panel_builds: u64,
    pub panel_reuses: u64,
    pub inflight_max: u64,
    pub drain_ns: u64,
    pub launches: u64,
    pub retries: u64,
    pub respawns: u64,
    pub quarantined_cus: u64,
}

impl MetricsSnapshot {
    /// Coordinator overhead: fraction of datapath time spent outside the
    /// artifacts (the §Perf L3 target keeps this small).
    pub fn marshal_fraction(&self) -> f64 {
        let total = self.exec_ns + self.marshal_ns;
        if total == 0 {
            0.0
        } else {
            self.marshal_ns as f64 / total as f64
        }
    }

    /// Mean leader-side drain time per retired launch, in nanoseconds.
    pub fn drain_ns_per_launch(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.drain_ns as f64 / self.launches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.add_tiles(3);
        m.add_tiles(2);
        m.add_calls(7);
        m.add_macs(1000);
        m.add_enqueues(2);
        m.add_panel_builds(1);
        m.add_panel_reuses(4);
        m.add_drain_ns(500);
        m.add_launches(2);
        m.add_retries(3);
        m.add_respawns(1);
        m.add_quarantined(1);
        let s = m.snapshot();
        assert_eq!(s.tiles, 5);
        assert_eq!(s.artifact_calls, 7);
        assert_eq!(s.macs, 1000);
        assert_eq!((s.enqueues, s.panel_builds, s.panel_reuses), (2, 1, 4));
        assert_eq!((s.drain_ns, s.launches), (500, 2));
        assert_eq!((s.retries, s.respawns, s.quarantined_cus), (3, 1, 1));
        assert!((s.drain_ns_per_launch() - 250.0).abs() < 1e-12);
        assert_eq!(Metrics::new().snapshot().drain_ns_per_launch(), 0.0);
    }

    #[test]
    fn inflight_max_is_a_high_water_mark() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().inflight_max, 0);
        m.record_inflight(1);
        m.record_inflight(3);
        m.record_inflight(2);
        assert_eq!(m.snapshot().inflight_max, 3, "fetch_max keeps the peak");
    }

    #[test]
    fn marshal_fraction() {
        let m = Metrics::new();
        m.add_exec_ns(900);
        m.add_marshal_ns(100);
        assert!((m.snapshot().marshal_fraction() - 0.1).abs() < 1e-12);
        assert_eq!(Metrics::new().snapshot().marshal_fraction(), 0.0);
    }
}
