//! The accelerator coordinator — Layer 3's system contribution.
//!
//! The paper exposes its FPGA as a device with P replicated compute units,
//! each bound to a DDR bank/SLR (Fig. 4), driven by a host runtime (XRT)
//! through a CUDA-like interface (§IV-B).  This module is that runtime for
//! the reproduction's virtual device:
//!
//! * [`matrix::Matrix`] — host-side APFP matrices;
//! * [`device::Device`] — the device handle: buffer management, stream
//!   operators, and the tiled GEMM launch (CUDA-like API);
//! * [`stream::DeviceStream`] — the batched launch API: device-resident
//!   buffers packed once, shared B tile grids, chained GEMMs whose C stays
//!   on the device between launches, and per-launch hazard tracking that
//!   lets launches with disjoint buffer sets pipeline through the worker
//!   queues while dependent chains stay serialized (`Device::gemm` is its
//!   one-shot wrapper; failures surface as typed [`stream::StreamError`]s,
//!   never panics);
//! * [`worker`] — one OS thread per compute unit, each owning its own
//!   [`crate::runtime::Runtime`] on the configured backend and tile
//!   geometry (its own "circuit replica") and executing tile jobs from a
//!   bounded queue (backpressure).  Each worker is held through a
//!   [`worker::Supervisor`]: a dead thread is respawned with a fresh
//!   runtime (up to its respawn budget, then quarantined), every incident
//!   lands in the per-CU health ledger ([`worker::CuHealth`], surfaced by
//!   [`device::Device::health`]), and the stream schedules around
//!   quarantined units instead of failing;
//! * [`scheduler`] — the §III work partition: output rows are split into
//!   N/P bands (one per CU), each band is tiled T_N x T_M with edge tiles
//!   clipped in every dimension, and every tile accumulates over K in
//!   sequential k_tile steps;
//! * [`metrics`] — counters for tiles, artifact calls, stage wall times
//!   and the stream's panel-packing reuse;
//! * [`model_metrics`] — the hardware-model ledger: modeled cycles, DRAM
//!   traffic, energy and per-phase seconds accumulated when the device
//!   runs on the simulated backend (`APFP_BACKEND=sim`), surfaced by
//!   [`device::Device::model_metrics`].
//!
//! Performance of the *physical* accelerator is modeled by [`crate::sim`];
//! this module provides the *functional* datapath (every result flows
//! through the runtime's pluggable backend — native in-process execution
//! by default, the hardware-model-accounting simulator under
//! `APFP_BACKEND=sim`, AOT artifacts under `APFP_BACKEND=xla`) plus the
//! coordination logic itself.

pub mod device;
pub mod matrix;
pub mod metrics;
pub mod model_metrics;
pub mod scheduler;
pub mod stream;
pub mod worker;

pub use device::{Device, GemmStats};
pub use matrix::Matrix;
pub use model_metrics::{ModelMetrics, ModelMetricsSnapshot, WidthModelSnapshot};
pub use stream::{BufId, DeviceStream, StreamError};
pub use worker::{CuHealth, RespawnOutcome};
