//! The hardware-model ledger: what the simulated backend says the device
//! *would have* cost.
//!
//! When the device runs on [`crate::runtime::SimBackend`]
//! (`APFP_BACKEND=sim`), every settled tile reply carries the modeled
//! [`TileModelCost`] of the K-steps it executed; the stream accumulates
//! those costs here at **retirement** — not on dispatch, not on receipt —
//! which is what makes the ledger conservation-exact under the
//! self-healing ladder:
//!
//! * a retried tile's failed attempts never accrue ([`crate::runtime::
//!   SimBackend`] accounts only successful kernel calls, and the retry arm
//!   redispatches with a `..` functional update that drops any stale
//!   payload);
//! * a failed launch drains its replies to the buffer pool and writes
//!   nothing — modeled cost included;
//! * the per-launch fixed cost ([`crate::sim::gemm_sim::LAUNCH_S`]) is
//!   added exactly once per retired launch that carried model data.
//!
//! On the native and xla backends every counter stays 0.  Like
//! [`super::metrics::Metrics`], counters are relaxed atomics so the
//! accumulation rides the zero-alloc retire path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::runtime::TileModelCost;
use crate::sim::gemm_sim;

#[derive(Debug, Default)]
pub struct ModelMetrics {
    /// Modeled datapath cycles (II-adjusted MAC issues + pipeline drains).
    pub cycles: AtomicU64,
    /// Modeled MAC lanes (full padded tiles; the functional `macs` counter
    /// in [`super::metrics::Metrics`] counts useful lanes only).
    pub macs: AtomicU64,
    /// Modeled DRAM-bank traffic, bytes.
    pub dram_bytes: AtomicU64,
    /// Modeled compute time, picoseconds (summed over CUs).
    pub compute_ps: AtomicU64,
    /// Modeled DRAM streaming time, picoseconds (summed over CUs).
    pub mem_ps: AtomicU64,
    /// Modeled per-launch fixed cost (kernel launch / orchestration),
    /// picoseconds.
    pub fixed_ps: AtomicU64,
    /// Modeled dynamic energy, picojoules.
    pub energy_pj: AtomicU64,
    /// Tile replies whose modeled cost was accumulated.
    pub tiles: AtomicU64,
    /// Launches that retired with model data.
    pub launches: AtomicU64,
}

impl ModelMetrics {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Accumulate one settled tile reply's modeled cost.  Called from the
    /// stream's retirement drain, which is `no_alloc`: relaxed `fetch_add`
    /// only.
    pub fn add_tile(&self, c: &TileModelCost) {
        self.cycles.fetch_add(c.cycles, Ordering::Relaxed);
        self.macs.fetch_add(c.macs, Ordering::Relaxed);
        self.dram_bytes.fetch_add(c.dram_bytes, Ordering::Relaxed);
        self.compute_ps.fetch_add(c.compute_ps, Ordering::Relaxed);
        self.mem_ps.fetch_add(c.mem_ps, Ordering::Relaxed);
        self.energy_pj.fetch_add(c.energy_pj, Ordering::Relaxed);
        self.tiles.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one retired launch that carried model data: counts it and
    /// charges the modeled kernel-launch fixed cost.
    pub fn add_launch(&self) {
        self.launches.fetch_add(1, Ordering::Relaxed);
        self.fixed_ps.fetch_add((gemm_sim::LAUNCH_S * 1e12) as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ModelMetricsSnapshot {
        ModelMetricsSnapshot {
            cycles: self.cycles.load(Ordering::Relaxed),
            macs: self.macs.load(Ordering::Relaxed),
            dram_bytes: self.dram_bytes.load(Ordering::Relaxed),
            compute_ps: self.compute_ps.load(Ordering::Relaxed),
            mem_ps: self.mem_ps.load(Ordering::Relaxed),
            fixed_ps: self.fixed_ps.load(Ordering::Relaxed),
            energy_pj: self.energy_pj.load(Ordering::Relaxed),
            tiles: self.tiles.load(Ordering::Relaxed),
            launches: self.launches.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of [`ModelMetrics`] with the derived quantities
/// the paper reports (Fig. 5 / Tab. III): modeled seconds per phase,
/// roofline efficiency, modeled MMAC/s.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelMetricsSnapshot {
    pub cycles: u64,
    pub macs: u64,
    pub dram_bytes: u64,
    pub compute_ps: u64,
    pub mem_ps: u64,
    pub fixed_ps: u64,
    pub energy_pj: u64,
    pub tiles: u64,
    pub launches: u64,
}

impl ModelMetricsSnapshot {
    /// True when any modeled work was recorded (always false off-sim).
    pub fn is_live(&self) -> bool {
        self.tiles > 0
    }

    pub fn compute_s(&self) -> f64 {
        self.compute_ps as f64 * 1e-12
    }

    pub fn mem_s(&self) -> f64 {
        self.mem_ps as f64 * 1e-12
    }

    pub fn fixed_s(&self) -> f64 {
        self.fixed_ps as f64 * 1e-12
    }

    /// Modeled wall time: compute and memory overlap (double-buffered
    /// streams, as in `sim::gemm_sim`), fixed costs do not.
    pub fn total_s(&self) -> f64 {
        self.compute_s().max(self.mem_s()) + self.fixed_s()
    }

    /// Roofline efficiency: MAC issues per modeled datapath cycle.  1.0
    /// means II=1 with no pipeline-fill overhead; the monolithic-CU
    /// penalty and per-tile fills push it below 1.
    pub fn efficiency(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.cycles as f64
        }
    }

    /// Modeled throughput over the modeled wall time, MMAC/s.
    pub fn mmacs(&self) -> f64 {
        let t = self.total_s();
        if t == 0.0 {
            0.0
        } else {
            self.macs as f64 / t / 1e6
        }
    }

    /// Modeled average dynamic power over the compute interval, watts.
    pub fn power_w(&self) -> f64 {
        let t = self.compute_s();
        if t == 0.0 {
            0.0
        } else {
            self.energy_pj as f64 * 1e-12 / t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(scale: u64) -> TileModelCost {
        TileModelCost {
            cycles: 100 * scale,
            macs: 80 * scale,
            dram_bytes: 640 * scale,
            compute_ps: 1_000 * scale,
            mem_ps: 500 * scale,
            energy_pj: 2_000 * scale,
        }
    }

    #[test]
    fn tiles_and_launches_accumulate() {
        let m = ModelMetrics::new();
        assert!(!m.snapshot().is_live());
        m.add_tile(&cost(1));
        m.add_tile(&cost(2));
        m.add_launch();
        let s = m.snapshot();
        assert!(s.is_live());
        assert_eq!(s.tiles, 2);
        assert_eq!(s.launches, 1);
        assert_eq!(s.cycles, 300);
        assert_eq!(s.macs, 240);
        assert_eq!(s.dram_bytes, 1920);
        assert_eq!(s.compute_ps, 3_000);
        assert_eq!(s.mem_ps, 1_500);
        assert_eq!(s.energy_pj, 6_000);
        assert_eq!(s.fixed_ps, (gemm_sim::LAUNCH_S * 1e12) as u64);
    }

    #[test]
    fn derived_quantities() {
        let m = ModelMetrics::new();
        m.add_tile(&cost(1));
        m.add_launch();
        let s = m.snapshot();
        assert!((s.efficiency() - 0.8).abs() < 1e-12);
        assert!((s.compute_s() - 1e-9).abs() < 1e-21);
        assert!((s.mem_s() - 5e-10).abs() < 1e-21);
        // compute > mem, so total = compute + fixed
        let want_total = 1e-9 + gemm_sim::LAUNCH_S;
        assert!((s.total_s() - want_total).abs() < 1e-15);
        assert!(s.mmacs() > 0.0);
        assert!((s.power_w() - 2.0).abs() < 1e-9, "2000 pJ over 1 ns = 2 W");
        // the empty snapshot divides nothing by zero
        let empty = ModelMetrics::new().snapshot();
        assert_eq!(empty.efficiency(), 0.0);
        assert_eq!(empty.mmacs(), 0.0);
        assert_eq!(empty.power_w(), 0.0);
    }
}
