//! The hardware-model ledger: what the simulated backend says the device
//! *would have* cost.
//!
//! When the device runs on [`crate::runtime::SimBackend`]
//! (`APFP_BACKEND=sim`), every settled tile reply carries the modeled
//! [`TileModelCost`] of the K-steps it executed; the stream accumulates
//! those costs here at **retirement** — not on dispatch, not on receipt —
//! which is what makes the ledger conservation-exact under the
//! self-healing ladder:
//!
//! * a retried tile's failed attempts never accrue ([`crate::runtime::
//!   SimBackend`] accounts only successful kernel calls, and the retry arm
//!   redispatches with a `..` functional update that drops any stale
//!   payload);
//! * a failed launch drains its replies to the buffer pool and writes
//!   nothing — modeled cost included;
//! * the per-launch fixed cost ([`crate::sim::gemm_sim::LAUNCH_S`]) is
//!   added exactly once per retired launch that carried model data.
//!
//! On the native and xla backends every counter stays 0.  Like
//! [`super::metrics::Metrics`], counters are relaxed atomics so the
//! accumulation rides the zero-alloc retire path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::runtime::TileModelCost;
use crate::sim::gemm_sim;

/// Per-width ledger slots a device preallocates (the snapshot stays `Copy`,
/// so the breakdown is a fixed-size array).  Widths beyond this many accrue
/// into the device totals only.
pub const MAX_WIDTHS: usize = 8;

/// One width's slice of the ledger: the same counters as the device
/// totals, keyed by packed bits.  Slots are preallocated at device
/// construction so the retire-path accumulation stays lock- and
/// allocation-free (a linear scan over at most [`MAX_WIDTHS`] entries).
#[derive(Debug)]
struct WidthLedger {
    bits: u32,
    cycles: AtomicU64,
    macs: AtomicU64,
    dram_bytes: AtomicU64,
    compute_ps: AtomicU64,
    mem_ps: AtomicU64,
    fixed_ps: AtomicU64,
    energy_pj: AtomicU64,
    tiles: AtomicU64,
    launches: AtomicU64,
}

impl WidthLedger {
    fn new(bits: u32) -> Self {
        WidthLedger {
            bits,
            cycles: AtomicU64::new(0),
            macs: AtomicU64::new(0),
            dram_bytes: AtomicU64::new(0),
            compute_ps: AtomicU64::new(0),
            mem_ps: AtomicU64::new(0),
            fixed_ps: AtomicU64::new(0),
            energy_pj: AtomicU64::new(0),
            tiles: AtomicU64::new(0),
            launches: AtomicU64::new(0),
        }
    }
}

#[derive(Debug, Default)]
pub struct ModelMetrics {
    /// Modeled datapath cycles (II-adjusted MAC issues + pipeline drains).
    pub cycles: AtomicU64,
    /// Modeled MAC lanes (full padded tiles; the functional `macs` counter
    /// in [`super::metrics::Metrics`] counts useful lanes only).
    pub macs: AtomicU64,
    /// Modeled DRAM-bank traffic, bytes.
    pub dram_bytes: AtomicU64,
    /// Modeled compute time, picoseconds (summed over CUs).
    pub compute_ps: AtomicU64,
    /// Modeled DRAM streaming time, picoseconds (summed over CUs).
    pub mem_ps: AtomicU64,
    /// Modeled per-launch fixed cost (kernel launch / orchestration),
    /// picoseconds.
    pub fixed_ps: AtomicU64,
    /// Modeled dynamic energy, picojoules.
    pub energy_pj: AtomicU64,
    /// Tile replies whose modeled cost was accumulated.
    pub tiles: AtomicU64,
    /// Launches that retired with model data.
    pub launches: AtomicU64,
    /// Per-width slices of every counter above, preallocated by
    /// [`ModelMetrics::with_widths`].  Empty when the device was built
    /// without a width set (totals-only accounting).
    widths: Vec<WidthLedger>,
}

impl ModelMetrics {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// A ledger with one preallocated per-width slot per entry of
    /// `widths` (first [`MAX_WIDTHS`] entries; the rest accrue into the
    /// device totals only).  What `Device::new` builds, so interleaved
    /// launches of different widths attribute their modeled cost without
    /// touching the allocator on the retire path.
    pub fn with_widths(widths: &[u32]) -> Arc<Self> {
        Arc::new(ModelMetrics {
            widths: widths.iter().take(MAX_WIDTHS).map(|&b| WidthLedger::new(b)).collect(),
            ..Default::default()
        })
    }

    fn slot(&self, bits: u32) -> Option<&WidthLedger> {
        self.widths.iter().find(|w| w.bits == bits)
    }

    /// Accumulate one settled tile reply's modeled cost.  Called from the
    /// stream's retirement drain, which is `no_alloc`: relaxed `fetch_add`
    /// only.
    pub fn add_tile(&self, c: &TileModelCost) {
        self.cycles.fetch_add(c.cycles, Ordering::Relaxed);
        self.macs.fetch_add(c.macs, Ordering::Relaxed);
        self.dram_bytes.fetch_add(c.dram_bytes, Ordering::Relaxed);
        self.compute_ps.fetch_add(c.compute_ps, Ordering::Relaxed);
        self.mem_ps.fetch_add(c.mem_ps, Ordering::Relaxed);
        self.energy_pj.fetch_add(c.energy_pj, Ordering::Relaxed);
        self.tiles.fetch_add(1, Ordering::Relaxed);
    }

    /// [`Self::add_tile`] plus attribution to the launch width's slot —
    /// the device totals and the width slice move together, which is the
    /// conservation invariant `tests/sim_backend.rs` pins.
    pub fn add_tile_at(&self, bits: u32, c: &TileModelCost) {
        self.add_tile(c);
        if let Some(w) = self.slot(bits) {
            w.cycles.fetch_add(c.cycles, Ordering::Relaxed);
            w.macs.fetch_add(c.macs, Ordering::Relaxed);
            w.dram_bytes.fetch_add(c.dram_bytes, Ordering::Relaxed);
            w.compute_ps.fetch_add(c.compute_ps, Ordering::Relaxed);
            w.mem_ps.fetch_add(c.mem_ps, Ordering::Relaxed);
            w.energy_pj.fetch_add(c.energy_pj, Ordering::Relaxed);
            w.tiles.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one retired launch that carried model data: counts it and
    /// charges the modeled kernel-launch fixed cost.
    pub fn add_launch(&self) {
        self.launches.fetch_add(1, Ordering::Relaxed);
        self.fixed_ps.fetch_add((gemm_sim::LAUNCH_S * 1e12) as u64, Ordering::Relaxed);
    }

    /// [`Self::add_launch`] plus attribution to the launch width's slot.
    pub fn add_launch_at(&self, bits: u32) {
        self.add_launch();
        if let Some(w) = self.slot(bits) {
            w.launches.fetch_add(1, Ordering::Relaxed);
            w.fixed_ps.fetch_add((gemm_sim::LAUNCH_S * 1e12) as u64, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> ModelMetricsSnapshot {
        let mut widths = [WidthModelSnapshot::default(); MAX_WIDTHS];
        for (slot, w) in widths.iter_mut().zip(&self.widths) {
            *slot = WidthModelSnapshot {
                bits: w.bits,
                cycles: w.cycles.load(Ordering::Relaxed),
                macs: w.macs.load(Ordering::Relaxed),
                dram_bytes: w.dram_bytes.load(Ordering::Relaxed),
                compute_ps: w.compute_ps.load(Ordering::Relaxed),
                mem_ps: w.mem_ps.load(Ordering::Relaxed),
                fixed_ps: w.fixed_ps.load(Ordering::Relaxed),
                energy_pj: w.energy_pj.load(Ordering::Relaxed),
                tiles: w.tiles.load(Ordering::Relaxed),
                launches: w.launches.load(Ordering::Relaxed),
            };
        }
        ModelMetricsSnapshot {
            cycles: self.cycles.load(Ordering::Relaxed),
            macs: self.macs.load(Ordering::Relaxed),
            dram_bytes: self.dram_bytes.load(Ordering::Relaxed),
            compute_ps: self.compute_ps.load(Ordering::Relaxed),
            mem_ps: self.mem_ps.load(Ordering::Relaxed),
            fixed_ps: self.fixed_ps.load(Ordering::Relaxed),
            energy_pj: self.energy_pj.load(Ordering::Relaxed),
            tiles: self.tiles.load(Ordering::Relaxed),
            launches: self.launches.load(Ordering::Relaxed),
            widths,
        }
    }
}

/// A point-in-time copy of [`ModelMetrics`] with the derived quantities
/// the paper reports (Fig. 5 / Tab. III): modeled seconds per phase,
/// roofline efficiency, modeled MMAC/s.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelMetricsSnapshot {
    pub cycles: u64,
    pub macs: u64,
    pub dram_bytes: u64,
    pub compute_ps: u64,
    pub mem_ps: u64,
    pub fixed_ps: u64,
    pub energy_pj: u64,
    pub tiles: u64,
    pub launches: u64,
    /// Per-width slices, in device width order; unused slots have
    /// `bits == 0`.  Use [`Self::width_breakdown`] to iterate the live
    /// ones.
    pub widths: [WidthModelSnapshot; MAX_WIDTHS],
}

/// One width's slice of a [`ModelMetricsSnapshot`] (`bits == 0` marks an
/// unused slot).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WidthModelSnapshot {
    pub bits: u32,
    pub cycles: u64,
    pub macs: u64,
    pub dram_bytes: u64,
    pub compute_ps: u64,
    pub mem_ps: u64,
    pub fixed_ps: u64,
    pub energy_pj: u64,
    pub tiles: u64,
    pub launches: u64,
}

impl ModelMetricsSnapshot {
    /// True when any modeled work was recorded (always false off-sim).
    pub fn is_live(&self) -> bool {
        self.tiles > 0
    }

    /// The per-width slices that belong to a real width (slots the device
    /// preallocated), in device width order.
    pub fn width_breakdown(&self) -> impl Iterator<Item = &WidthModelSnapshot> {
        self.widths.iter().filter(|w| w.bits != 0)
    }

    pub fn compute_s(&self) -> f64 {
        self.compute_ps as f64 * 1e-12
    }

    pub fn mem_s(&self) -> f64 {
        self.mem_ps as f64 * 1e-12
    }

    pub fn fixed_s(&self) -> f64 {
        self.fixed_ps as f64 * 1e-12
    }

    /// Modeled wall time: compute and memory overlap (double-buffered
    /// streams, as in `sim::gemm_sim`), fixed costs do not.
    pub fn total_s(&self) -> f64 {
        self.compute_s().max(self.mem_s()) + self.fixed_s()
    }

    /// Roofline efficiency: MAC issues per modeled datapath cycle.  1.0
    /// means II=1 with no pipeline-fill overhead; the monolithic-CU
    /// penalty and per-tile fills push it below 1.
    pub fn efficiency(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.cycles as f64
        }
    }

    /// Modeled throughput over the modeled wall time, MMAC/s.
    pub fn mmacs(&self) -> f64 {
        let t = self.total_s();
        if t == 0.0 {
            0.0
        } else {
            self.macs as f64 / t / 1e6
        }
    }

    /// Modeled average dynamic power over the compute interval, watts.
    pub fn power_w(&self) -> f64 {
        let t = self.compute_s();
        if t == 0.0 {
            0.0
        } else {
            self.energy_pj as f64 * 1e-12 / t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(scale: u64) -> TileModelCost {
        TileModelCost {
            cycles: 100 * scale,
            macs: 80 * scale,
            dram_bytes: 640 * scale,
            compute_ps: 1_000 * scale,
            mem_ps: 500 * scale,
            energy_pj: 2_000 * scale,
        }
    }

    #[test]
    fn tiles_and_launches_accumulate() {
        let m = ModelMetrics::new();
        assert!(!m.snapshot().is_live());
        m.add_tile(&cost(1));
        m.add_tile(&cost(2));
        m.add_launch();
        let s = m.snapshot();
        assert!(s.is_live());
        assert_eq!(s.tiles, 2);
        assert_eq!(s.launches, 1);
        assert_eq!(s.cycles, 300);
        assert_eq!(s.macs, 240);
        assert_eq!(s.dram_bytes, 1920);
        assert_eq!(s.compute_ps, 3_000);
        assert_eq!(s.mem_ps, 1_500);
        assert_eq!(s.energy_pj, 6_000);
        assert_eq!(s.fixed_ps, (gemm_sim::LAUNCH_S * 1e12) as u64);
    }

    #[test]
    fn derived_quantities() {
        let m = ModelMetrics::new();
        m.add_tile(&cost(1));
        m.add_launch();
        let s = m.snapshot();
        assert!((s.efficiency() - 0.8).abs() < 1e-12);
        assert!((s.compute_s() - 1e-9).abs() < 1e-21);
        assert!((s.mem_s() - 5e-10).abs() < 1e-21);
        // compute > mem, so total = compute + fixed
        let want_total = 1e-9 + gemm_sim::LAUNCH_S;
        assert!((s.total_s() - want_total).abs() < 1e-15);
        assert!(s.mmacs() > 0.0);
        assert!((s.power_w() - 2.0).abs() < 1e-9, "2000 pJ over 1 ns = 2 W");
        // the empty snapshot divides nothing by zero
        let empty = ModelMetrics::new().snapshot();
        assert_eq!(empty.efficiency(), 0.0);
        assert_eq!(empty.mmacs(), 0.0);
        assert_eq!(empty.power_w(), 0.0);
    }

    #[test]
    fn width_slots_attribute_and_conserve() {
        let m = ModelMetrics::with_widths(&[128, 512]);
        m.add_tile_at(128, &cost(1));
        m.add_tile_at(512, &cost(2));
        m.add_tile_at(512, &cost(3));
        m.add_launch_at(128);
        m.add_launch_at(512);
        let s = m.snapshot();
        // device totals behave exactly as the width-less path
        assert_eq!(s.tiles, 3);
        assert_eq!(s.launches, 2);
        assert_eq!(s.cycles, 600);
        // per-width slices carry their own launches' share
        let w128 = s.width_breakdown().find(|w| w.bits == 128).unwrap();
        let w512 = s.width_breakdown().find(|w| w.bits == 512).unwrap();
        assert_eq!((w128.tiles, w128.cycles, w128.launches), (1, 100, 1));
        assert_eq!((w512.tiles, w512.cycles, w512.launches), (2, 500, 1));
        // conservation: per-width sums equal the device totals, counter by
        // counter (the invariant tests/sim_backend.rs re-asserts end to end)
        let sums = s.width_breakdown().fold([0u64; 9], |mut acc, w| {
            for (a, v) in acc.iter_mut().zip([
                w.cycles, w.macs, w.dram_bytes, w.compute_ps, w.mem_ps, w.fixed_ps,
                w.energy_pj, w.tiles, w.launches,
            ]) {
                *a += v;
            }
            acc
        });
        assert_eq!(
            sums,
            [
                s.cycles, s.macs, s.dram_bytes, s.compute_ps, s.mem_ps, s.fixed_ps,
                s.energy_pj, s.tiles, s.launches
            ]
        );
        // a width the device never preallocated folds into totals only
        let m = ModelMetrics::with_widths(&[512]);
        m.add_tile_at(4096, &cost(1));
        let s = m.snapshot();
        assert_eq!(s.tiles, 1);
        assert_eq!(s.width_breakdown().map(|w| w.tiles).sum::<u64>(), 0);
    }
}
