//! The §III work partition.
//!
//! For `C (n x m) += A (n x k) * B (k x m)` with P compute units and
//! per-CU output tiles T_N x T_M:
//!
//! * the N dimension is split into P row *bands* of ceil(n/P) rows — the
//!   paper copies each band's A and C rows to the owning CU's DDR bank and
//!   replicates B to every bank;
//! * within a band, the CU walks its output tiles; each tile accumulates
//!   over K in sequential `k_tile`-sized steps (the artifact performs one
//!   step: a T_N x k_tile by k_tile x T_M update).

/// One output tile owned by one compute unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    pub cu: usize,
    /// output row / column origin
    pub r0: usize,
    pub c0: usize,
    /// Output rows this tile *owns*: `tile_n` clipped at the band end, so
    /// a band that is not a multiple of `tile_n` never writes rows
    /// belonging to the next CU's band (the artifact still computes the
    /// full `tile_n` rows; the extras are padding, discarded on write).
    pub rows: usize,
    /// Output columns this tile owns: `tile_m` clipped at the matrix's
    /// right edge.  Like `rows`, the artifact computes the full `tile_m`
    /// columns and the padding is discarded on write — clipping here makes
    /// ownership explicit so writebacks into a resident C panel touch only
    /// real elements.
    pub cols: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct Partition {
    pub n: usize,
    pub m: usize,
    pub k: usize,
    pub tile_n: usize,
    pub tile_m: usize,
    pub k_tile: usize,
    pub compute_units: usize,
}

impl Partition {
    /// Row band [start, end) owned by compute unit `cu`.
    pub fn band(&self, cu: usize) -> (usize, usize) {
        let band = self.n.div_ceil(self.compute_units);
        let start = (cu * band).min(self.n);
        let end = ((cu + 1) * band).min(self.n);
        (start, end)
    }

    /// Tiles owned by `cu`, in execution order (row-major over the band).
    pub fn tiles_for(&self, cu: usize) -> Vec<Tile> {
        let mut tiles = Vec::new();
        self.tiles_into(cu, &mut tiles);
        tiles
    }

    /// [`Partition::tiles_for`] into a caller-owned vector (cleared first):
    /// the allocation-free form the stream's warm enqueue path uses.
    pub fn tiles_into(&self, cu: usize, out: &mut Vec<Tile>) {
        out.clear();
        let (start, end) = self.band(cu);
        let mut r0 = start;
        while r0 < end {
            let rows = self.tile_n.min(end - r0);
            let mut c0 = 0;
            while c0 < self.m {
                let cols = self.tile_m.min(self.m - c0);
                out.push(Tile { cu, r0, c0, rows, cols });
                c0 += self.tile_m;
            }
            r0 += self.tile_n;
        }
    }

    /// Number of sequential K steps per tile.
    pub fn k_steps(&self) -> usize {
        self.k.div_ceil(self.k_tile)
    }

    /// Number of tile columns across the output (the width of the shared
    /// B-tile grid: one pre-packed B tile per (K step, tile column)).
    pub fn m_tiles(&self) -> usize {
        self.m.div_ceil(self.tile_m)
    }

    /// Total output tiles across every CU's band — the number of tile
    /// replies one launch produces (the stream sizes a launch's bounded
    /// reply channel with this so a worker never blocks sending a result).
    pub fn total_tiles(&self) -> usize {
        (0..self.compute_units)
            .map(|cu| {
                let (start, end) = self.band(cu);
                (end - start).div_ceil(self.tile_n) * self.m_tiles()
            })
            .sum()
    }

    /// All tiles across all CUs (diagnostics / tests).
    pub fn all_tiles(&self) -> Vec<Tile> {
        (0..self.compute_units).flat_map(|cu| self.tiles_for(cu)).collect()
    }

    /// The degraded-mode partition after quarantining one compute unit:
    /// the same problem re-banded across one fewer CU, so the survivors
    /// absorb the quarantined unit's rows.  Band slots are positional —
    /// the stream maps slot -> live physical CU separately — so `cu`
    /// names *which* unit left for the record, without changing the
    /// resulting geometry.  Excluding the last CU saturates at one band:
    /// reachability of the zero-survivor state is the stream's decision
    /// (`Poisoned`), not the scheduler's.
    pub fn excluding(&self, _cu: usize) -> Partition {
        Partition { compute_units: (self.compute_units - 1).max(1), ..*self }
    }

    /// Total artifact invocations for the whole GEMM.
    pub fn total_calls(&self) -> usize {
        self.all_tiles().len() * self.k_steps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(n: usize, m: usize, k: usize, p: usize) -> Partition {
        Partition { n, m, k, tile_n: 8, tile_m: 8, k_tile: 8, compute_units: p }
    }

    #[test]
    fn bands_cover_all_rows_disjointly() {
        for (n, p) in [(64, 4), (65, 4), (7, 4), (100, 3), (8, 1)] {
            let pt = part(n, 16, 8, p);
            let mut covered = vec![false; n];
            for cu in 0..p {
                let (s, e) = pt.band(cu);
                for r in s..e {
                    assert!(!covered[r], "row {r} double-owned (n={n}, p={p})");
                    covered[r] = true;
                }
            }
            assert!(covered.iter().all(|&c| c), "rows uncovered (n={n}, p={p})");
        }
    }

    #[test]
    fn tiles_cover_output_exactly_once() {
        let pt = part(20, 20, 16, 3);
        let mut hit = vec![vec![0u32; 20]; 20];
        for t in pt.all_tiles() {
            // t.rows/t.cols are the tile's owned extents: no manual clipping
            for i in t.r0..t.r0 + t.rows {
                for j in t.c0..t.c0 + t.cols {
                    hit[i][j] += 1;
                }
            }
        }
        // every output element covered exactly once by its band's tiles
        for (i, row) in hit.iter().enumerate() {
            for (j, &h) in row.iter().enumerate() {
                assert_eq!(h, 1, "({i},{j}) covered {h} times");
            }
        }
    }

    #[test]
    fn edge_tiles_clip_columns_and_tiles_into_reuses_storage() {
        let pt = part(8, 20, 16, 1); // m = 20, tile_m = 8 -> cols 8, 8, 4
        let tiles = pt.tiles_for(0);
        assert_eq!(pt.m_tiles(), 3);
        let widths: Vec<usize> = tiles.iter().map(|t| t.cols).collect();
        assert_eq!(widths, vec![8, 8, 4]);
        for t in &tiles {
            assert!(t.c0 + t.cols <= pt.m, "tile escapes the right edge");
            assert_eq!(t.c0 % pt.tile_m, 0, "origins stay on the tile grid");
        }
        // tiles_into refills a warm vector without reallocating
        let mut buf = Vec::with_capacity(tiles.len());
        pt.tiles_into(0, &mut buf);
        assert_eq!(buf, tiles);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        pt.tiles_into(0, &mut buf);
        assert_eq!(buf, tiles);
        assert_eq!((buf.capacity(), buf.as_ptr()), (cap, ptr), "refill must reuse storage");
    }

    #[test]
    fn band_boundary_tiles_clip_their_rows() {
        // Regression: when a CU's band is not a multiple of tile_n, its
        // last tile row used to spill into the next CU's band and both CUs
        // wrote the same output rows.  t.rows must clip at the band end so
        // no row is owned (computed-and-written) twice.
        for (n, m, p) in [(20usize, 20usize, 3usize), (37, 23, 3), (65, 16, 4), (9, 8, 2)] {
            let pt = part(n, m, 16, p);
            let mut owner = vec![0u32; n];
            for t in pt.all_tiles() {
                assert!(t.rows > 0 && t.rows <= pt.tile_n, "rows {} (n={n} p={p})", t.rows);
                let (start, end) = pt.band(t.cu);
                assert!(
                    t.r0 >= start && t.r0 + t.rows <= end,
                    "tile r0={} rows={} escapes band [{start},{end}) (n={n} p={p})",
                    t.r0,
                    t.rows
                );
                if t.c0 == 0 {
                    for r in t.r0..t.r0 + t.rows {
                        owner[r] += 1;
                    }
                }
            }
            for (r, &h) in owner.iter().enumerate() {
                assert_eq!(h, 1, "row {r} owned {h} times (n={n} p={p})");
            }
        }
    }

    #[test]
    fn total_tiles_matches_enumeration() {
        for (n, m, p) in [(20, 20, 3), (37, 23, 3), (65, 16, 4), (8, 8, 4), (2, 8, 4), (1, 1, 1)] {
            let pt = part(n, m, 16, p);
            assert_eq!(pt.total_tiles(), pt.all_tiles().len(), "n={n} m={m} p={p}");
        }
    }

    #[test]
    fn k_steps_round_up() {
        assert_eq!(part(8, 8, 8, 1).k_steps(), 1);
        assert_eq!(part(8, 8, 9, 1).k_steps(), 2);
        assert_eq!(part(8, 8, 64, 1).k_steps(), 8);
    }

    #[test]
    fn more_cus_fewer_tiles_each() {
        let p1 = part(64, 64, 8, 1);
        let p4 = part(64, 64, 8, 4);
        assert_eq!(p1.tiles_for(0).len(), 64);
        assert_eq!(p4.tiles_for(0).len(), 16);
        assert_eq!(p1.total_calls(), p4.total_calls());
    }

    #[test]
    fn excluding_rebalances_onto_survivors() {
        for (n, m, p) in [(20usize, 20usize, 3usize), (65, 16, 4), (9, 8, 2)] {
            let pt = part(n, m, 16, p);
            let degraded = pt.excluding(p - 1);
            assert_eq!(degraded.compute_units, p - 1);
            // the survivors' bands still cover every output row exactly once
            let mut covered = vec![0u32; n];
            for cu in 0..degraded.compute_units {
                let (s, e) = degraded.band(cu);
                for r in s..e {
                    covered[r] += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "degraded bands must re-cover (n={n} p={p})");
            // and the tile count matches its own enumeration (reply sizing)
            assert_eq!(degraded.total_tiles(), degraded.all_tiles().len());
        }
        // excluding the last CU saturates: the scheduler never produces a
        // zero-band partition (zero survivors is the stream's poison case)
        let pt = part(8, 8, 8, 1);
        assert_eq!(pt.excluding(0).compute_units, 1);
    }

    #[test]
    fn empty_band_when_more_cus_than_rows() {
        let pt = part(8, 8, 8, 4); // band = 2 rows... ceil(8/4)=2
        assert_eq!(pt.band(0), (0, 2));
        let pt = part(2, 8, 8, 4); // bands beyond the matrix are empty
        assert_eq!(pt.band(2), (2, 2));
        assert!(pt.tiles_for(3).is_empty());
    }
}
