//! The batched device stream — "keep data on the device" (§IV-B) as an API,
//! with hazard-tracked pipelining of independent launches.
//!
//! A [`DeviceStream`] owns device-resident buffers ([`DeviceBuf`], packed
//! limb-plane panels) and launches GEMMs against them by handle:
//!
//! * [`DeviceStream::upload`] packs a host [`Matrix`] into the plane layout
//!   **once** — the "copy to device DDR" step;
//! * [`DeviceStream::enqueue_gemm`] launches `C += A @ B` over the worker
//!   queues; the updated C stays resident, so it can be the A, B or C of
//!   the next enqueue with **no host round-trip**;
//! * [`DeviceStream::wait`] drains every outstanding launch into its C
//!   panel; [`DeviceStream::download`] drains only the launches the read
//!   depends on, then decodes that buffer back into host values.
//!
//! # Launch hazards
//!
//! Each `enqueue_gemm(a, b, c)` has the read set `{A, B, C}` (the C input
//! is read too — the launch accumulates onto it) and the write set `{C}`.
//! An enqueue only waits for in-flight launches it actually conflicts
//! with: a launch that **writes** one of our three buffers (RAW/WAW —
//! our inputs must be its retired output), or any launch still referencing
//! B when B's tile grid has to be (re)built.  Launches with disjoint
//! buffer sets flow through the worker queues concurrently — the
//! `inflight_max` metric records the high-water mark, and the
//! `stream_overlap` bench demonstrates the pipelining.  Write-after-read
//! needs no wait at all: writebacks are deferred to retirement, and
//! launches retire strictly in enqueue order, so a later writer can never
//! overtake an earlier reader.
//!
//! Dependent chains keep their serial semantics: `enqueue_gemm(c, b, c)`
//! reads pre-launch buffer contents and stays bit-identical to
//! [`crate::baseline::gemm_serial`] (`tests/tile_property.rs`).
//!
//! # Precision as a launch parameter
//!
//! The device loads kernel artifacts at several mantissa widths side by
//! side (`APFP_WIDTHS`), and each launch picks one:
//! [`DeviceStream::enqueue_gemm_at`] names the width in bits, while
//! [`DeviceStream::enqueue_gemm`] launches at the device default
//! (`config.bits`).  Every [`DeviceBuf`] records the width it was packed
//! at ([`DeviceStream::upload`] infers it from the host matrix,
//! [`DeviceStream::alloc_at`] names it explicitly), and an enqueue whose
//! operand widths disagree with the launch width is a typed
//! [`StreamError::WidthMismatch`] **before any hazard or dispatch state
//! is touched** — never a silent mixed-width MAC.
//! [`DeviceStream::convert`] re-encodes a buffer at another width (RNDZ
//! truncation on narrowing, zero-fill on widening).  Hazard tracking,
//! retry/replay, and the model ledger all key off the *launch*, not a
//! stream-global width, so independent launches at different widths
//! pipeline through the same worker queues concurrently
//! (`benches/mixed_precision.rs` pins the overlap structurally).
//!
//! # Failure semantics: the self-healing ladder
//!
//! No stream failure path panics; failures climb a recovery ladder
//! (retry → respawn → quarantine → poison, see `docs/ARCHITECTURE.md`
//! § Failure recovery) and only the last rung surfaces as an error:
//!
//! * a **failed/panicked tile** is redispatched up to
//!   [`RetryPolicy::retry_limit`](crate::config::RetryPolicy) times with
//!   bounded exponential backoff — a transient fault is invisible to the
//!   caller; only a tile that exhausts its retries settles as a failure,
//!   and then the launch drains **completely** — every pooled staging
//!   buffer is recovered — writes **nothing** (C keeps its pre-launch
//!   contents), and reports every exhausted tile in one
//!   [`StreamError::LaunchFailed`] (the stream stays usable);
//! * a **dead worker thread** (detected by the reply-liveness probe, or
//!   by a failed submit) is respawned with a fresh runtime through its
//!   CU's [`Supervisor`](super::worker::Supervisor), the incident is
//!   recorded in the per-CU health ledger, and the dead worker's un-acked
//!   dispatches are replayed — every dispatch is stamped with the worker
//!   *incarnation* it was submitted to, so any launch can tell its lost
//!   jobs from its slow ones;
//! * a CU that **exhausts its respawn budget is quarantined**: new
//!   launches re-band across the survivors
//!   ([`Partition::excluding`](super::scheduler::Partition::excluding)),
//!   in-flight tiles re-route to live CUs, and the device keeps serving
//!   at reduced throughput;
//! * a handle minted by another stream is rejected up front
//!   ([`StreamError::ForeignHandle`]) — [`BufId`]s are stamped with their
//!   stream's token, so a foreign handle can never index the wrong buffer;
//! * only the bottom of the ladder poisons: **zero surviving CUs**
//!   ([`StreamError::NoSurvivors`]) or a broken internal invariant.  The
//!   failing call returns the root error and every later call returns
//!   [`StreamError::Poisoned`] instead of hanging or panicking.
//!
//! # What makes a warm stream cheap
//!
//! * **Shared B tiles.** The first time a buffer is used as B, its panel is
//!   cut into the tile grid once (`k_steps x m_tiles` pre-packed tiles,
//!   one [`crate::pack::PlaneBatch`] each) and every compute unit reads the
//!   same grid through the buffer's `Arc`.  The grid records the panel
//!   *version* it was cut from; a version is bumped only when a launch
//!   that writes the buffer retires, so the grid stays valid across any
//!   number of non-conflicting launches and waits (`panel_builds` /
//!   `panel_reuses` in the device metrics make the amortization visible).
//! * **Pooled everything.** Tile C-staging buffers cycle leader -> worker
//!   -> leader through a pool (on success *and* on failure), per-launch
//!   reply channels and tile lists are reused, and job payloads are `Arc`
//!   clones — in steady state (same shapes, warm pools) an `enqueue_gemm`
//!   + [`DeviceStream::wait`] round performs **zero heap allocations** end
//!   to end, workers included, even with several launches in flight
//!   (`tests/alloc_free.rs`).
//!
//! [`crate::coordinator::Device::gemm`] is a one-shot wrapper over this
//! API: upload, enqueue, wait, download.
//!
//! ```no_run
//! use apfp::config::ApfpConfig;
//! use apfp::coordinator::{Device, Matrix};
//!
//! # fn main() -> anyhow::Result<()> {
//! let dev = Device::new(ApfpConfig::default(), std::path::Path::new("artifacts"))?;
//! let prec = dev.config().prec();
//! let mut s = dev.stream()?;
//! let a = s.upload(&Matrix::random(64, 64, prec, 1, 30));
//! let b = s.upload(&Matrix::random(64, 64, prec, 2, 30));
//! let c = s.alloc(64, 64);
//! let d = s.alloc(64, 64);
//! s.enqueue_gemm(a, b, c)?; // C += A @ B at the device default width
//! s.enqueue_gemm(b, a, d)?; // disjoint write set: overlaps with the first
//! s.enqueue_gemm(c, b, c)?; // dependent chain: waits for launch 1 only
//! // mixed precision: the same stream launches at another loaded width
//! let (al, bl) = (s.convert(a, 128)?, s.convert(b, 128)?);
//! let cl = s.alloc_at(128, 64, 64);
//! s.enqueue_gemm_at(128, al, bl, cl)?;
//! let out = s.download(c)?;
//! # let _ = out;
//! # Ok(())
//! # }
//! ```

use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::device::Device;
use super::matrix::Matrix;
use super::scheduler::{Partition, Tile};
use super::worker::{Job, RespawnOutcome, TileResult};
use crate::pack::{PlaneBatch, PlanePanel};
use crate::runtime::ArtifactMeta;

/// Every stream failure mode, as one typed error.  Stream methods return
/// `anyhow::Result`; callers that need to dispatch on the failure
/// downcast with `err.downcast_ref::<StreamError>()`
/// (`tests/stream_faults.rs` pins every variant).
#[derive(Debug, thiserror::Error)]
pub enum StreamError {
    /// A [`BufId`] minted by a different stream: handles are stream-local
    /// (they index that stream's buffer table), so a foreign handle is
    /// rejected before it can touch the wrong buffer.
    #[error(
        "buffer handle #{index} belongs to stream {handle_stream}, not stream {this_stream}: \
         device buffers are stream-local"
    )]
    ForeignHandle { index: usize, handle_stream: u64, this_stream: u64 },
    /// A handle whose index is out of range for this stream (defensive —
    /// the stream token check makes this unreachable through the API).
    #[error("unknown device buffer id {index}")]
    UnknownBuffer { index: usize },
    /// A launch whose operand buffers disagree with the launch width.
    /// Every device buffer carries the mantissa width it was packed at
    /// (bits, 64-bit head included); `a`/`b`/`c` report the operand
    /// widths against the requested launch width `bits`.  Caught before
    /// any hazard or dispatch state is touched, so a width mismatch can
    /// never corrupt a panel — [`DeviceStream::convert`] re-encodes a
    /// buffer at the launch width when mixing is intended.
    #[error(
        "launch {launch}: operand widths {a}/{b}/{c} bits do not all match the \
         {bits}-bit launch width; convert() re-encodes a buffer across widths"
    )]
    WidthMismatch { launch: u64, bits: u32, a: u32, b: u32, c: u32 },
    /// One or more tiles of a launch exhausted their retry budget.  The
    /// launch drained fully, recovered its pooled staging buffers, and
    /// wrote **nothing** — the C buffer keeps its pre-launch contents —
    /// and `tiles` lists every exhausted tile.  The stream stays usable.
    #[error("launch {launch}: {failed} of {total} tiles failed; C left unchanged: {tiles}")]
    LaunchFailed { launch: u64, failed: usize, total: usize, tiles: String },
    /// The reply channel disconnected with tile results still outstanding.
    /// Defensive: the leader holds a sender, so this means the channel
    /// state itself broke.  The stream is poisoned.
    #[error("launch {launch}: reply channel closed with {missing} of {total} tiles outstanding")]
    ReplyLost { launch: u64, missing: usize, total: usize },
    /// Every compute unit is quarantined (all respawn budgets exhausted),
    /// so no survivor can take the launch's tiles.  The bottom of the
    /// recovery ladder: the stream is poisoned.
    #[error(
        "launch {launch}: zero of {total} compute units survive (all quarantined); \
         the stream is poisoned"
    )]
    NoSurvivors { launch: u64, total: usize },
    /// An internal invariant broke (a drained launch left a live buffer
    /// reference).  The stream is poisoned.
    #[error("stream invariant broken: {what}; the stream is poisoned")]
    Invariant { what: &'static str },
    /// An earlier unrecoverable failure poisoned this stream; every call
    /// after it reports the original reason instead of hanging/panicking.
    #[error("stream poisoned by an earlier failure: {reason}")]
    Poisoned { reason: String },
    /// Several launches failed in one drain; `summary` joins their
    /// individual [`StreamError::LaunchFailed`] reports.
    #[error("{count} launches failed: {summary}")]
    Multi { count: usize, summary: String },
}

/// Fold the per-launch failures of one drain into a single error: `None`
/// when nothing failed, the error itself for exactly one failure, and
/// [`StreamError::Multi`] for several — joining the individual reports
/// with `" | "` **in launch order** (oldest launch first, the order
/// `retire_n` drained them), so the summary reads as a timeline.
// apfp-lint: allow(alloc, scope=fn, reason="failure path: the multi-error summary exists only when launches failed")
fn join_failures(mut errs: Vec<StreamError>) -> Option<StreamError> {
    if errs.len() > 1 {
        let count = errs.len();
        let mut summary = String::new();
        for (i, e) in errs.iter().enumerate() {
            if i > 0 {
                summary.push_str(" | ");
            }
            let _ = write!(summary, "{e}");
        }
        return Some(StreamError::Multi { count, summary });
    }
    errs.pop()
}

/// Source of unique per-stream tokens stamped into [`BufId`]s.
static NEXT_STREAM_TOKEN: AtomicU64 = AtomicU64::new(1);

/// Handle to one device-resident buffer of a [`DeviceStream`].  Stamped
/// with the owning stream's token: using it on another stream is a typed
/// [`StreamError::ForeignHandle`], never a silent wrong-buffer read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BufId {
    pub(crate) index: usize,
    pub(crate) stream: u64,
}

/// A device-resident matrix: the packed plane panel, its writeback
/// version, and the lazily built, shared B tile grid.  Workers hold these
/// through `Arc` for the duration of a launch; the stream regains
/// exclusive access (and with it the right to write the panel) only once
/// every tile of the launch has replied.
pub struct DeviceBuf {
    pub(crate) panel: PlanePanel,
    /// Mantissa width this buffer is packed at (bits, 64-bit head
    /// included): `panel.prec() + 64`.  Stamped at upload/alloc and
    /// checked against the launch width at every enqueue — the static
    /// half of the [`StreamError::WidthMismatch`] guarantee.
    pub(crate) bits: u32,
    /// Writeback generation of `panel`: bumped by the leader each time a
    /// launch writing this buffer retires.  The B tile grid records the
    /// version it was cut from, so the cache invalidation point is exactly
    /// "a conflicting writer completed" — not "any wait happened".
    pub(crate) version: u64,
    pub(crate) b_cache: BTileCache,
}

/// The pre-packed B tile grid: `k_steps x m_tiles` tiles of shape
/// `k_tile x tile_m`, extracted once per panel version and read by every
/// compute unit.
#[derive(Default)]
pub(crate) struct BTileCache {
    tiles: Vec<PlaneBatch>,
    k_tile: usize,
    tile_m: usize,
    m_tiles: usize,
    k_steps: usize,
    built: bool,
    /// Panel version the grid was cut from; stale when the buffer's
    /// `version` has moved past it (a writer launch retired).
    built_version: u64,
}

impl DeviceBuf {
    pub(crate) fn panel(&self) -> &PlanePanel {
        &self.panel
    }

    /// The shared pre-packed B tile for K step `step`, tile column `jt`.
    pub(crate) fn b_tile(&self, step: usize, jt: usize) -> Result<&PlaneBatch> {
        anyhow::ensure!(
            self.b_cache.built && self.b_cache.built_version == self.version,
            "B tile grid not packed for this panel version"
        );
        anyhow::ensure!(
            step < self.b_cache.k_steps && jt < self.b_cache.m_tiles,
            "B tile ({step},{jt}) outside the {}x{} grid",
            self.b_cache.k_steps,
            self.b_cache.m_tiles
        );
        Ok(&self.b_cache.tiles[step * self.b_cache.m_tiles + jt])
    }
}

/// A pooled bounded reply channel, rated for `cap` tile results (the
/// underlying channel holds `2 * cap` — headroom for duplicate replies
/// from raced replays).  Workers must never block sending a reply — that
/// would deadlock against the bounded job queues — so a launch only takes
/// a channel whose rating covers its whole tile count.
struct ReplyChannel {
    tx: SyncSender<TileResult>,
    rx: Receiver<TileResult>,
    cap: usize,
}

/// Where (and to *which incarnation* of the worker) a launch slot's tiles
/// were dispatched.  A dispatch is **lost** exactly when its stamped
/// incarnation is no longer live — the CU respawned or was quarantined
/// since, taking its queue (and any un-replied jobs) with it.  The
/// overdue-reply probe replays those dispatches, and only those: a slow
/// worker keeps its current incarnation and is simply waited on.
#[derive(Clone, Copy, Debug)]
struct SlotDispatch {
    /// Physical CU the slot's band was submitted to.
    phys: usize,
    /// [`Supervisor`](super::worker::Supervisor)`::incarnation()` at
    /// submit time.
    incarnation: u32,
}

/// One redispatch record — a retried, replayed, or re-routed tile —
/// stamped like the original slot dispatch so a second loss is detectable
/// too.  The newest entry for an origin is its authoritative in-flight
/// dispatch.
#[derive(Clone, Copy, Debug)]
struct RetrySlot {
    origin: (usize, usize),
    attempt: u32,
    phys: usize,
    incarnation: u32,
}

/// One launch currently in flight: its buffer read/write sets (by index),
/// the partition it runs under, how many tiles must settle, its private
/// reply channel, and the dispatch bookkeeping the self-healing drain
/// runs on.  Writeback into the C panel is deferred to retirement, which
/// happens strictly in enqueue order.
struct Launch {
    id: u64,
    /// Mantissa width this launch runs at (bits, 64-bit head included):
    /// selects the kernel artifact and attributes the launch's tiles in
    /// the per-width model ledger at retirement.
    width: u32,
    /// Interned name of the artifact serving `width`, cloned (a refcount
    /// bump) into every job this launch dispatches — retries and replays
    /// included, so a healed tile always lands on the same kernel.
    artifact: Arc<str>,
    /// Read set: A, B, and the C input (accumulated onto).
    a: usize,
    b: usize,
    /// Write set: the C buffer, written at retirement.
    c: usize,
    part: Partition,
    /// Total tiles — the launch retires once this many have *settled*
    /// (replied successfully, or failed with retries exhausted).
    pending: usize,
    reply: ReplyChannel,
    /// Initial dispatch stamp per partition slot (pooled storage).
    slots: Vec<SlotDispatch>,
    /// Settled replies (pooled storage).  `results.len()` is the settled
    /// count; an origin present here is final and any further reply for
    /// it is a duplicate from a raced replay, dropped on arrival.
    results: Vec<TileResult>,
    /// Redispatch log, newest last — empty (and allocation-free) on every
    /// healthy launch.
    retries: Vec<RetrySlot>,
}

/// One mantissa width this stream can launch at: the GEMM artifact
/// serving it (the widest-tile artifact at that width wins, mirroring
/// [`Device::artifact_for_at`]) and the artifact's interned name, cloned
/// into every job dispatched at this width.
struct WidthSlot {
    bits: u32,
    meta: ArtifactMeta,
    artifact: Arc<str>,
}

/// A batched GEMM stream over a [`Device`] — see the module docs.
///
/// Dropping a stream with work still in flight abandons those results:
/// workers finish their queued tiles and their replies are discarded.
pub struct DeviceStream<'d> {
    dev: &'d Device,
    /// One slot per mantissa width the device manifest serves with a GEMM
    /// artifact, in manifest order; every launch resolves its width here.
    width_slots: Vec<WidthSlot>,
    /// Launch width used by [`DeviceStream::enqueue_gemm`] and
    /// [`DeviceStream::alloc`]: the device's `config.bits`.
    default_bits: u32,
    /// This stream's identity, stamped into every [`BufId`] it mints.
    token: u64,
    next_launch: u64,
    bufs: Vec<Arc<DeviceBuf>>,
    /// Per-CU tile lists, refilled in place each enqueue.
    cu_tiles: Vec<Vec<Tile>>,
    /// Per-CU submission cursors (reset each enqueue).
    cursors: Vec<usize>,
    /// Recycled C-staging tile buffers (leader -> worker -> leader, on
    /// success and on failure alike).
    c_pool: Vec<PlaneBatch>,
    /// Recycled per-launch settled-reply staging (capacity reused).
    results_pool: Vec<Vec<TileResult>>,
    /// Recycled per-launch slot-dispatch tables.
    slot_pool: Vec<Vec<SlotDispatch>>,
    /// Recycled per-launch reply channels (each bounded at the tile count
    /// of the launch it was created for).
    reply_pool: Vec<ReplyChannel>,
    /// Live (non-quarantined) physical CU indices, rebuilt in place each
    /// enqueue; partition slot `i` initially dispatches to `live[i]`.
    live: Vec<usize>,
    /// Round-robin cursor for re-routing tiles off quarantined CUs.
    rr: usize,
    /// Launches in flight, oldest first; retirement pops from the front.
    inflight: VecDeque<Launch>,
    /// Set by an unrecoverable failure; every later call reports it.
    poisoned: Option<String>,
}

impl<'d> DeviceStream<'d> {
    // apfp-lint: allow(alloc, scope=fn, reason="cold constructor: the stream's pools and tables are allocated once at open, before any launch")
    pub(crate) fn new(dev: &'d Device) -> Self {
        let cus = dev.workers.len();
        let width_slots = dev
            .widths()
            .into_iter()
            .filter_map(|bits| {
                let meta =
                    dev.artifact_for_at(crate::runtime::ArtifactKind::Gemm, bits).ok()?.clone();
                Some(WidthSlot { bits, artifact: Arc::from(meta.name.as_str()), meta })
            })
            .collect();
        DeviceStream {
            width_slots,
            default_bits: dev.config.bits,
            dev,
            token: NEXT_STREAM_TOKEN.fetch_add(1, Ordering::Relaxed),
            next_launch: 0,
            bufs: Vec::new(),
            cu_tiles: (0..cus).map(|_| Vec::new()).collect(),
            cursors: vec![0; cus],
            c_pool: Vec::new(),
            results_pool: Vec::new(),
            slot_pool: Vec::new(),
            reply_pool: Vec::new(),
            live: Vec::with_capacity(cus),
            rr: 0,
            inflight: VecDeque::new(),
            poisoned: None,
        }
    }

    /// Pack a host matrix into a device-resident panel (the one-time
    /// "copy to DDR"); everything after this moves plane rows, not values.
    /// The buffer's width is inferred from the matrix precision
    /// (`prec + 64` bits) — it need not match the device default, only
    /// the width of the launches it later feeds.
    pub fn upload(&mut self, m: &Matrix) -> BufId {
        let t0 = Instant::now();
        let panel = m.to_panel();
        self.dev.metrics.add_marshal_ns(t0.elapsed().as_nanos() as u64);
        let bits = m.prec() + 64;
        self.push_buf(panel, bits)
    }

    /// Allocate a zeroed device-resident `rows x cols` buffer at the
    /// device's default width (the `cudaMalloc` analog).
    pub fn alloc(&mut self, rows: usize, cols: usize) -> BufId {
        self.alloc_at(self.default_bits, rows, cols)
    }

    /// Allocate a zeroed device-resident buffer at an explicit mantissa
    /// width (bits, 64-bit head included) — the mixed-precision analog
    /// of [`DeviceStream::alloc`].
    pub fn alloc_at(&mut self, bits: u32, rows: usize, cols: usize) -> BufId {
        let prec = crate::softfloat::prec_for_bits(bits);
        self.push_buf(PlanePanel::zeros(rows, cols, prec), bits)
    }

    /// The mantissa width (bits, 64-bit head included) buffer `id` is
    /// packed at.
    pub fn width(&self, id: BufId) -> Result<u32> {
        // apfp-lint: allow(index, reason="the subscript comes from index(), which validated the handle against this stream's buffer table")
        Ok(self.bufs[self.index(id)?].bits)
    }

    /// The widths this stream can launch at, in manifest order.
    pub fn launch_widths(&self) -> impl Iterator<Item = u32> + '_ {
        self.width_slots.iter().map(|s| s.bits)
    }

    fn push_buf(&mut self, panel: PlanePanel, bits: u32) -> BufId {
        self.bufs.push(Arc::new(DeviceBuf {
            panel,
            bits,
            version: 0,
            b_cache: BTileCache::default(),
        }));
        BufId { index: self.bufs.len() - 1, stream: self.token }
    }

    /// Resolve a handle to this stream's buffer index, rejecting handles
    /// minted by other streams.
    fn index(&self, id: BufId) -> Result<usize, StreamError> {
        if id.stream != self.token {
            return Err(StreamError::ForeignHandle {
                index: id.index,
                handle_stream: id.stream,
                this_stream: self.token,
            });
        }
        if id.index >= self.bufs.len() {
            return Err(StreamError::UnknownBuffer { index: id.index });
        }
        Ok(id.index)
    }

    fn check_live(&self) -> Result<(), StreamError> {
        match &self.poisoned {
            // apfp-lint: allow(alloc, reason="failure path: the poison reason is cloned only to report it")
            Some(reason) => Err(StreamError::Poisoned { reason: reason.clone() }),
            None => Ok(()),
        }
    }

    /// Record `e` as this stream's poison reason and hand it back.
    fn poison(&mut self, e: StreamError) -> StreamError {
        // apfp-lint: allow(alloc, reason="failure path: the poison reason is recorded once, at the failing call")
        self.poisoned = Some(e.to_string());
        e
    }

    /// Drain the launches a read of `id` depends on, then decode the
    /// buffer back to a host matrix — the only step of the stream that
    /// materializes `ApFloat`s.  Launches writing *other* buffers keep
    /// flowing; retirement is FIFO, so landing the last in-flight writer
    /// of this buffer retires exactly the prefix up to it.
    pub fn download(&mut self, id: BufId) -> Result<Matrix> {
        self.check_live()?;
        let idx = self.index(id)?;
        if let Some(i) = self.inflight.iter().rposition(|l| l.c == idx) {
            self.retire_n(i + 1).context("draining launches this download depends on")?;
        }
        Ok(Matrix::from_panel(&self.bufs[idx].panel))
    }

    /// Re-encode buffer `id` at mantissa width `bits` and mint a **new**
    /// handle at that width; the source buffer is untouched.  Narrowing
    /// truncates the mantissa toward zero (RNDZ, the §II rounding mode);
    /// widening zero-fills the new low limbs — so a narrow → wide → MAC
    /// chain sees exactly the narrow value, and a wide → narrow → wide
    /// round trip is the identity on the truncated value.  Drains the
    /// launches a read of `id` depends on first, exactly like
    /// [`DeviceStream::download`].
    // apfp-lint: allow(alloc, scope=fn, reason="cold conversion path: a width cast decodes, re-rounds, and re-packs one panel; the hot enqueue/wait loop never converts")
    pub fn convert(&mut self, id: BufId, bits: u32) -> Result<BufId> {
        self.check_live()?;
        let idx = self.index(id)?;
        if let Some(i) = self.inflight.iter().rposition(|l| l.c == idx) {
            self.retire_n(i + 1).context("draining launches this conversion depends on")?;
        }
        let t0 = Instant::now();
        let prec = crate::softfloat::prec_for_bits(bits);
        let panel = Matrix::from_panel(&self.bufs[idx].panel).to_prec(prec).to_panel();
        self.dev.metrics.add_marshal_ns(t0.elapsed().as_nanos() as u64);
        Ok(self.push_buf(panel, bits))
    }

    /// Launch `C += A @ B` at the device's **default** width
    /// (`config.bits`) — the width-explicit form is
    /// [`DeviceStream::enqueue_gemm_at`], which this delegates to.
    // apfp-lint: no_alloc
    pub fn enqueue_gemm(&mut self, a: BufId, b: BufId, c: BufId) -> Result<()> {
        self.enqueue_gemm_at(self.default_bits, a, b, c)
    }

    /// Launch `C += A @ B` (alpha = beta = 1, §III) at `bits` of mantissa
    /// width across the device's compute units.  All three operand
    /// buffers must be packed at `bits` — a disagreement is a typed
    /// [`StreamError::WidthMismatch`], raised **before** any hazard or
    /// dispatch state is touched.  Inputs are pre-launch buffer contents:
    /// any in-flight launch *writing* one of the three operands is
    /// drained first (RAW/WAW), so chains like `enqueue_gemm(c, b, c)`
    /// are well defined — while launches with disjoint buffer sets stay
    /// in flight and pipeline through the worker queues, whatever their
    /// widths.  Returns once every tile is submitted (the bounded worker
    /// queues backpressure the caller); [`DeviceStream::wait`] collects
    /// results.  A hazard drain that surfaces an earlier launch's failure
    /// returns that error here, and this launch is not submitted.
    // apfp-lint: no_alloc
    pub fn enqueue_gemm_at(&mut self, bits: u32, a: BufId, b: BufId, c: BufId) -> Result<()> {
        self.check_live()?;
        let (ai, bi, ci) = (self.index(a)?, self.index(b)?, self.index(c)?);
        // Resolve the launch width to its kernel artifact; an unloaded
        // width is the same typed manifest error the device-level lookup
        // reports, naming the widths that *are* loaded.  Built from the
        // stream's own width table so the hot path never re-enters the
        // device's (allocating) manifest lookup.
        let Some(si) = self.width_slots.iter().position(|s| s.bits == bits) else {
            return Err(crate::runtime::manifest::ManifestError::NoArtifact {
                kind: crate::runtime::ArtifactKind::Gemm,
                bits,
                // apfp-lint: allow(alloc, reason="failure path: the loaded-width list is collected only to report an unloaded launch width")
                loaded: self.width_slots.iter().map(|s| s.bits).collect(),
            }
            .into());
        };
        // Width agreement first — before the hazard scan, the partition,
        // or any dispatch bookkeeping — so a mismatched launch is a pure
        // no-op on stream state: WidthMismatch, never a corrupted panel.
        {
            // apfp-lint: allow(index, reason="ai/bi/ci come from index(), which validated the handle against this stream's buffer table")
            let (wa, wb, wc) = (self.bufs[ai].bits, self.bufs[bi].bits, self.bufs[ci].bits);
            if wa != bits || wb != bits || wc != bits {
                let launch = self.next_launch;
                return Err(
                    StreamError::WidthMismatch { launch, bits, a: wa, b: wb, c: wc }.into()
                );
            }
        }
        let (t_n, t_m, k_tile) = {
            // apfp-lint: allow(index, reason="si comes from position() over width_slots itself")
            let meta = &self.width_slots[si].meta;
            (meta.t_n, meta.t_m, meta.k_tile)
        };
        let (n, k, m) = {
            let (pa, pb, pc) =
                // apfp-lint: allow(index, reason="ai/bi/ci come from index(), which validated the handle against this stream's buffer table")
                (&self.bufs[ai].panel, &self.bufs[bi].panel, &self.bufs[ci].panel);
            anyhow::ensure!(
                pa.cols() == pb.rows(),
                "inner dimensions: {} vs {}",
                pa.cols(),
                pb.rows()
            );
            anyhow::ensure!(
                pa.rows() == pc.rows() && pb.cols() == pc.cols(),
                "output shape: {}x{} vs {}x{}",
                pa.rows(),
                pb.cols(),
                pc.rows(),
                pc.cols()
            );
            (pa.rows(), pa.cols(), pb.cols())
        };
        // Degraded-mode scheduling: band only across the live
        // (non-quarantined) CUs.  Partition slot `i` maps to physical CU
        // `live[i]`; `excluding` folds each quarantined unit out of the
        // base partition so the survivors absorb its rows.  Zero
        // survivors is the bottom of the recovery ladder: poison.
        let dev = self.dev;
        self.live.clear();
        self.live.extend((0..dev.workers.len()).filter(|&i| !dev.workers[i].is_quarantined()));
        if self.live.is_empty() {
            let (launch, total) = (self.next_launch, self.dev.workers.len());
            return Err(self.poison(StreamError::NoSurvivors { launch, total }).into());
        }
        let mut part = Partition {
            n,
            m,
            k,
            tile_n: t_n,
            tile_m: t_m,
            k_tile,
            compute_units: self.dev.workers.len(),
        };
        for w in &self.dev.workers {
            if w.is_quarantined() {
                part = part.excluding(w.cu());
            }
        }
        debug_assert_eq!(part.compute_units, self.live.len(), "one band slot per live CU");

        // Hazard scan: wait only for in-flight launches we conflict with.
        // A conflict is a launch *writing* one of our buffers (RAW on A/B/
        // the C input, WAW on C — its writeback must land before our
        // workers read the panel), or — when B's grid must be (re)built —
        // any launch still holding a reference to B (the build needs
        // exclusive access).  Write-after-read needs no wait: writebacks
        // are deferred to FIFO retirement, so ours can never overtake an
        // earlier reader.  Retirement is in order, so draining through the
        // *last* conflicting launch clears every conflict at once.
        // apfp-lint: allow(index, reason="bi comes from index(), which validated the handle against this stream's buffer table")
        let grid_fresh = Self::grid_fresh(&self.bufs[bi], &part);
        let mut drain_to = None;
        for (i, l) in self.inflight.iter().enumerate() {
            let writes_our_set = l.c == ai || l.c == bi || l.c == ci;
            let blocks_grid_build = !grid_fresh && (l.a == bi || l.b == bi || l.c == bi);
            if writes_our_set || blocks_grid_build {
                drain_to = Some(i);
            }
        }
        if let Some(i) = drain_to {
            self.retire_n(i + 1).context("draining conflicting launches")?;
        }
        self.build_b_cache(bi, &part)?;

        // Plan each slot's band; the reply channel must absorb every tile
        // of this launch without a worker ever blocking on it.  Slots at
        // or past `part.compute_units` plan empty (their bands clamp to
        // the matrix edge), which also clears any stale lists from a
        // less-degraded earlier enqueue.
        let total = part.total_tiles();
        let mut planned = 0;
        for (slot, tiles) in self.cu_tiles.iter_mut().enumerate() {
            part.tiles_into(slot, tiles);
            planned += tiles.len();
            self.cursors[slot] = 0;
        }
        debug_assert_eq!(planned, total, "Partition::total_tiles must match enumeration");
        let reply = self.take_reply_channel(total);
        let launch = self.next_launch;
        self.next_launch += 1;

        // Stamp each slot's dispatch target *before* submitting: a worker
        // that dies mid-submission (or later) is detectable because its
        // stamped incarnation stops being live.
        let mut slots = self.slot_pool.pop().unwrap_or_default();
        slots.clear();
        slots.extend(self.live.iter().map(|&phys| SlotDispatch {
            phys,
            // apfp-lint: allow(index, reason="phys comes from self.live, which was just rebuilt from 0..workers.len()")
            incarnation: self.dev.workers[phys].incarnation(),
        }));
        let mut results = self.results_pool.pop().unwrap_or_default();
        results.clear();
        let mut l = Launch {
            id: launch,
            width: bits,
            // apfp-lint: allow(index, reason="si comes from position() over width_slots itself")
            artifact: self.width_slots[si].artifact.clone(), // apfp-lint: allow(alloc, reason="Arc<str> refcount bump")
            a: ai,
            b: bi,
            c: ci,
            part,
            pending: total,
            reply,
            slots,
            results,
            // apfp-lint: allow(alloc, reason="Vec::new is allocation-free; the redispatch log grows only on the healing path")
            retries: Vec::new(),
        };

        // Submit round-robin, one tile per slot per pass, so the bounded
        // queues fill evenly and a stalled CU backpressures only its
        // band.  The fast path sends straight to the slot's stamped
        // worker; if that worker died since the stamp, the tile heals
        // through `submit_tile` (respawn or re-route) instead.
        // apfp-lint: allow(index, reason="ai/bi/ci come from index(), which validated the handle against this stream's buffer table")
        // apfp-lint: allow(alloc, reason="Arc clones: refcount bumps on the shared device buffers, no heap allocation")
        let (ab, bb, cb) = (self.bufs[ai].clone(), self.bufs[bi].clone(), self.bufs[ci].clone());
        let mut submitted = 0usize;
        let mut active = true;
        while active {
            active = false;
            for slot in 0..part.compute_units {
                let Some(&tile) = self.cu_tiles[slot].get(self.cursors[slot]) else { continue };
                self.cursors[slot] += 1;
                submitted += 1;
                active = true;
                let sd = l.slots[slot];
                if self.dev.workers[sd.phys].is_live_at(sd.incarnation) {
                    let job = Job::GemmTile {
                        launch,
                        artifact: l.artifact.clone(), // apfp-lint: allow(alloc, reason="Arc<str> refcount bump")
                        a: ab.clone(), // apfp-lint: allow(alloc, reason="Arc refcount bump")
                        b: bb.clone(), // apfp-lint: allow(alloc, reason="Arc refcount bump")
                        c: cb.clone(), // apfp-lint: allow(alloc, reason="Arc refcount bump")
                        c_buf: self.c_pool.pop().unwrap_or_default(),
                        tile,
                        part,
                        attempt: 0,
                        reply: l.reply.tx.clone(), // apfp-lint: allow(alloc, reason="SyncSender clone: channel refcount bump")
                    };
                    match self.dev.workers[sd.phys].submit(job) {
                        Ok(()) => continue,
                        Err(job) => {
                            // died between the stamp check and the send:
                            // reclaim the staging buffer and fall through
                            // to the healing slow path
                            if let Job::GemmTile { c_buf, .. } = job {
                                self.c_pool.push(c_buf);
                            }
                        }
                    }
                }
                let c_buf = self.c_pool.pop().unwrap_or_default();
                self.submit_tile(&mut l, tile, 0, c_buf)?;
            }
        }
        debug_assert_eq!(submitted, total, "every planned tile must have been submitted");
        self.dev.metrics.add_enqueues(1);
        self.inflight.push_back(l);
        self.dev.metrics.record_inflight(self.inflight.len() as u64);
        Ok(())
    }

    /// Dispatch one tile — a first attempt, an error retry, or a
    /// lost-dispatch replay — healing as it goes: a dead target is
    /// respawned through its supervisor (recorded in the health ledger);
    /// a quarantined one re-routes the tile to the next live CU
    /// round-robin.  Every dispatch made here is logged in the launch's
    /// redispatch table with the incarnation it went to, so a second loss
    /// is detectable too.  Fails — and poisons — only at the bottom of
    /// the ladder: zero surviving CUs.
    fn submit_tile(
        &mut self,
        l: &mut Launch,
        tile: Tile,
        attempt: u32,
        mut c_buf: PlaneBatch,
    ) -> Result<(), StreamError> {
        loop {
            let home = l.slots[tile.cu].phys;
            let phys = if self.dev.workers[home].is_quarantined() {
                match self.live_target() {
                    Some(p) => p,
                    None => {
                        self.c_pool.push(c_buf);
                        let (launch, total) = (l.id, self.dev.workers.len());
                        return Err(self.poison(StreamError::NoSurvivors { launch, total }));
                    }
                }
            } else {
                home
            };
            let incarnation = self.dev.workers[phys].incarnation();
            let job = Job::GemmTile {
                launch: l.id,
                artifact: l.artifact.clone(), // apfp-lint: allow(alloc, reason="Arc<str> refcount bump")
                // apfp-lint: allow(index, reason="launch buffer indices were validated by index() at enqueue")
                // apfp-lint: allow(alloc, reason="Arc clones: refcount bumps on the shared device buffers")
                a: self.bufs[l.a].clone(),
                b: self.bufs[l.b].clone(), // apfp-lint: allow(alloc, reason="Arc refcount bump")
                c: self.bufs[l.c].clone(), // apfp-lint: allow(alloc, reason="Arc refcount bump")
                c_buf,
                tile,
                part: l.part,
                attempt,
                reply: l.reply.tx.clone(), // apfp-lint: allow(alloc, reason="SyncSender clone: channel refcount bump")
            };
            match self.dev.workers[phys].submit(job) {
                Ok(()) => {
                    // apfp-lint: allow(alloc, reason="cold healing path: the redispatch log grows only when a tile needed re-dispatch")
                    l.retries.push(RetrySlot {
                        origin: (tile.r0, tile.c0),
                        attempt,
                        phys,
                        incarnation,
                    });
                    return Ok(());
                }
                Err(job) => {
                    c_buf = match job {
                        Job::GemmTile { c_buf, .. } => c_buf,
                        // unreachable: submit hands back the job it was
                        // given, and this one is a GemmTile
                        _ => PlaneBatch::default(),
                    };
                }
            }
            // The send failed: the worker thread died under us.  Climb
            // the ladder — respawn it (or quarantine it past its budget)
            // and go around: a respawned worker takes the tile on its
            // next incarnation; a quarantined one re-routes it.
            // apfp-lint: allow(alloc, reason="cold healing path: the incident string is built once per detected worker death")
            let incident = format!(
                "launch {} tile ({},{}) attempt {attempt}: submit failed (worker gone)",
                l.id, tile.r0, tile.c0
            );
            if self.dev.workers[phys].respawn(&incident) == RespawnOutcome::Quarantined
                && self.dev.workers.iter().all(|w| w.is_quarantined())
            {
                self.c_pool.push(c_buf);
                let (launch, total) = (l.id, self.dev.workers.len());
                return Err(self.poison(StreamError::NoSurvivors { launch, total }));
            }
        }
    }

    /// The next live CU in round-robin order, for re-routing tiles whose
    /// band owner is quarantined; `None` when no CU survives.
    fn live_target(&mut self) -> Option<usize> {
        let n = self.dev.workers.len();
        for _ in 0..n {
            let cu = self.rr % n;
            self.rr = (self.rr + 1) % n;
            if !self.dev.workers[cu].is_quarantined() {
                return Some(cu);
            }
        }
        None
    }

    /// Is `b`'s cached tile grid valid for `part` — cut from the current
    /// panel version at the same geometry?  Read-only, so a fresh grid is
    /// shared with in-flight launches without needing exclusive access.
    fn grid_fresh(buf: &DeviceBuf, part: &Partition) -> bool {
        let c = &buf.b_cache;
        c.built
            && c.built_version == buf.version
            && c.k_tile == part.k_tile
            && c.tile_m == part.tile_m
            && c.m_tiles == part.m_tiles()
            && c.k_steps == part.k_steps()
    }

    /// Pack (or reuse) the shared B tile grid for `part` on buffer `b`.
    /// The caller has already drained every launch referencing `b` when a
    /// rebuild is needed, so exclusive access is an invariant here.
    fn build_b_cache(&mut self, b: usize, part: &Partition) -> Result<()> {
        if Self::grid_fresh(&self.bufs[b], part) {
            self.dev.metrics.add_panel_reuses(1);
            return Ok(());
        }
        let Some(buf) = Arc::get_mut(&mut self.bufs[b]) else {
            return Err(self
                .poison(StreamError::Invariant {
                    what: "rebuilding a B tile grid while a launch still references the buffer",
                })
                .into());
        };
        let t0 = Instant::now();
        let (m_tiles, k_steps) = (part.m_tiles(), part.k_steps());
        let version = buf.version;
        let cache = &mut buf.b_cache;
        let count = k_steps * m_tiles;
        if cache.tiles.len() != count {
            // apfp-lint: allow(alloc, reason="B-grid (re)build: cut once per panel version and shared by every CU; panel_builds/panel_reuses metrics track the amortization")
            cache.tiles.resize_with(count, PlaneBatch::default);
        }
        for step in 0..k_steps {
            for jt in 0..m_tiles {
                buf.panel.extract_tile_into(
                    step * part.k_tile,
                    jt * part.tile_m,
                    part.k_tile,
                    part.tile_m,
                    &mut cache.tiles[step * m_tiles + jt],
                );
            }
        }
        cache.k_tile = part.k_tile;
        cache.tile_m = part.tile_m;
        cache.m_tiles = m_tiles;
        cache.k_steps = k_steps;
        cache.built = true;
        cache.built_version = version;
        self.dev.metrics.add_marshal_ns(t0.elapsed().as_nanos() as u64);
        self.dev.metrics.add_panel_builds(1);
        Ok(())
    }

    /// Take a pooled reply channel with room for `total` tile results, or
    /// create one.  Channels are minted at twice their rated capacity so
    /// duplicate replies from raced replays can never block a worker's
    /// send, and a pooled channel is drained of any late duplicates from
    /// its previous launch before reuse — a stale reply would otherwise
    /// corrupt the new launch's accounting.
    fn take_reply_channel(&mut self, total: usize) -> ReplyChannel {
        let need = total.max(1);
        if let Some(pos) = self.reply_pool.iter().position(|r| r.cap >= need) {
            let ch = self.reply_pool.swap_remove(pos);
            while let Ok(stale) = ch.rx.try_recv() {
                self.c_pool.push(stale.c_buf);
            }
            return ch;
        }
        // apfp-lint: allow(alloc, reason="pool miss: a reply channel is minted only when no pooled one has the capacity")
        let (tx, rx) = sync_channel(2 * need);
        ReplyChannel { tx, rx, cap: need }
    }

    /// Collect every outstanding launch and land each in its C buffer's
    /// panel (each output element is owned by exactly one clipped tile, so
    /// writes are disjoint).  Even when a launch fails, the remaining
    /// launches are still drained — an error never leaves replies pending.
    /// No-op when nothing is in flight.
    // apfp-lint: no_alloc
    pub fn wait(&mut self) -> Result<()> {
        self.check_live()?;
        let n = self.inflight.len();
        self.retire_n(n)
    }

    /// Retire the `n` oldest in-flight launches in order, aggregating
    /// failures so later launches always drain even when earlier ones
    /// error.
    fn retire_n(&mut self, n: usize) -> Result<()> {
        // apfp-lint: allow(alloc, reason="Vec::new is allocation-free; it grows only on the failure path")
        let mut errs: Vec<StreamError> = Vec::new();
        for _ in 0..n {
            if let Err(e) = self.retire_one() {
                errs.push(e);
            }
        }
        match join_failures(errs) {
            None => Ok(()),
            Some(e) => Err(e.into()),
        }
    }

    /// Retire the oldest in-flight launch: drain until every tile has
    /// settled — retrying errored tiles and replaying lost dispatches on
    /// the way — recover every pooled staging buffer, and either write
    /// the results back into the C panel (bumping its version, which is
    /// what invalidates cached B grids cut from it) or — if any tile
    /// exhausted its retries — write nothing and report every failure.
    fn retire_one(&mut self) -> Result<(), StreamError> {
        let Some(mut l) = self.inflight.pop_front() else { return Ok(()) };
        let t_drain = Instant::now();
        // The leader holds a sender for the pooled channel, so a plain
        // `recv` could never disconnect — a worker that died reply-less
        // would hang us forever.  Instead an overdue reply triggers the
        // liveness probe: dispatches whose stamped worker incarnation is
        // no longer live are lost, and the probe heals the worker and
        // replays exactly those.  A slow-but-live worker just keeps the
        // loop waiting.
        while l.results.len() < l.pending {
            let step = match l.reply.rx.recv_timeout(self.dev.config.reply_timeout) {
                Ok(res) => self.absorb(&mut l, res),
                Err(RecvTimeoutError::Timeout) => self.probe_and_replay(&mut l),
                Err(RecvTimeoutError::Disconnected) => {
                    // defensive: with the leader holding a sender this
                    // means the channel state itself broke
                    let (launch, missing, total) =
                        (l.id, l.pending - l.results.len(), l.pending);
                    Err(self.poison(StreamError::ReplyLost { launch, missing, total }))
                }
            };
            if let Err(e) = step {
                self.dev.metrics.add_drain_ns(t_drain.elapsed().as_nanos() as u64);
                self.dev.metrics.add_launches(1);
                self.salvage(l);
                return Err(e);
            }
        }
        self.dev.metrics.add_drain_ns(t_drain.elapsed().as_nanos() as u64);
        self.dev.metrics.add_launches(1);

        let mut failed = 0usize;
        // apfp-lint: allow(alloc, reason="String::new is allocation-free; it grows only when tiles failed")
        let mut tiles = String::new();
        for res in &l.results {
            if let Some(err) = &res.err {
                failed += 1;
                if !tiles.is_empty() {
                    tiles.push_str("; ");
                }
                let t = res.tile;
                let _ = write!(tiles, "slot{} tile({},{}): {:#}", t.cu, t.r0, t.c0, err);
            }
        }

        if failed > 0 {
            // Fully settled, but some tiles exhausted their retries:
            // recover every staging buffer into the pool, leave C
            // untouched (its pre-launch contents — and its version —
            // stand), and report every failed tile in one error.  The
            // stream stays usable.
            for res in l.results.drain(..) {
                self.c_pool.push(res.c_buf);
            }
            self.reply_pool.push(l.reply);
            self.results_pool.push(l.results);
            self.slot_pool.push(l.slots);
            let (launch, total) = (l.id, l.pending);
            return Err(StreamError::LaunchFailed { launch, failed, total, tiles });
        }

        // Healthy path: every tile settled successfully, and workers drop
        // their buffer references before replying — the stream owns the
        // panel again.
        let Some(buf) = Arc::get_mut(&mut self.bufs[l.c]) else {
            let e = self.poison(StreamError::Invariant {
                what: "a fully drained launch left a live reference to its C buffer",
            });
            self.salvage(l);
            return Err(e);
        };
        // The panel is about to change: bump its version so B grids cut
        // from the old contents read as stale from here on.
        buf.version += 1;
        let t0 = Instant::now();
        // The model-ledger accumulation point: only *settled successful*
        // replies reach this drain, so a retried tile's failed attempts and
        // a failed launch's partial results can never be counted (the
        // `docs/INVARIANTS.md` model-counter conservation row).  Each tile
        // is attributed to the launch's width slot as well as the device
        // totals, so interleaved mixed-width launches stay conservation-
        // exact per width.  Relaxed atomic adds only — the retire path
        // stays zero-alloc.
        let mut modeled = false;
        for res in l.results.drain(..) {
            let t = res.tile;
            buf.panel.write_tile(t.r0, t.c0, t.rows, t.cols, l.part.tile_m, &res.c_buf);
            if let Some(cost) = &res.model {
                self.dev.model_metrics.add_tile_at(l.width, cost);
                modeled = true;
            }
            self.c_pool.push(res.c_buf);
        }
        if modeled {
            // one fixed launch cost per retired launch that carried model
            // data, exactly once — dispatch retries never re-charge it
            self.dev.model_metrics.add_launch_at(l.width);
        }
        self.dev.metrics.add_marshal_ns(t0.elapsed().as_nanos() as u64);
        self.reply_pool.push(l.reply);
        self.results_pool.push(l.results);
        self.slot_pool.push(l.slots);
        Ok(())
    }

    /// Recover a launch's arrived staging buffers and recycle its pooled
    /// tables after a fatal (poisoning) drain error.  Its reply channel
    /// is dropped, not pooled: late replies may still be in flight toward
    /// it, and the poisoned stream will never launch again anyway.
    fn salvage(&mut self, mut l: Launch) {
        for res in l.results.drain(..) {
            self.c_pool.push(res.c_buf);
        }
        self.results_pool.push(l.results);
        self.slot_pool.push(l.slots);
    }

    /// Fold one reply into the launch: settle it, retry it, or — for a
    /// duplicate — recycle its staging buffer and drop it.  A reply is a
    /// duplicate when it names another launch or an origin that already
    /// settled; duplicates arise only when a replay raced the original
    /// reply (the dispatch was declared lost after its worker died, but
    /// the reply was already in the channel).
    fn absorb(&mut self, l: &mut Launch, res: TileResult) -> Result<(), StreamError> {
        let dup = res.launch != l.id
            || l.results.iter().any(|r| (r.tile.r0, r.tile.c0) == (res.tile.r0, res.tile.c0));
        if dup {
            self.c_pool.push(res.c_buf);
            return Ok(());
        }
        if res.err.is_some() && res.attempt < self.dev.config.retry.retry_limit {
            // The transient rung of the ladder: back off and redispatch,
            // reusing the errored reply's staging buffer — the retry arm
            // neither leaks nor mints pooled buffers.
            let backoff = self.dev.config.retry.backoff(res.attempt + 1);
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            self.dev.metrics.add_retries(1);
            let TileResult { tile, attempt, c_buf, .. } = res;
            return self.submit_tile(l, tile, attempt + 1, c_buf);
        }
        // settled: a success, or a failure with its retry budget spent
        l.results.push(res);
        Ok(())
    }

    /// The overdue-reply probe.  First drain whatever has already
    /// arrived; then, for every unsettled tile, decide whether its latest
    /// dispatch is still live.  A dispatch stamped with an incarnation
    /// that is no longer live can never reply — its worker respawned or
    /// was quarantined, taking the queued job with it — so it is
    /// replayed.  A dispatch whose stamped worker is *dead but not yet
    /// healed* is healed here first (respawn, or quarantine past the
    /// budget), which retires the stamp and makes the dispatch lost.
    /// Live-and-current dispatches are just slow: keep waiting.
    fn probe_and_replay(&mut self, l: &mut Launch) -> Result<(), StreamError> {
        // A reply that raced the timeout may settle a tile we would
        // otherwise replay (and double-dispatch): drain first.
        while let Ok(res) = l.reply.rx.try_recv() {
            self.absorb(l, res)?;
            if l.results.len() >= l.pending {
                return Ok(());
            }
        }
        // Walk every tile origin of the launch in closed form — the
        // shared `cu_tiles` planning buffers may have been overwritten by
        // later enqueues, so the partition itself is the source of truth.
        for slot in 0..l.part.compute_units {
            let (start, end) = l.part.band(slot);
            let mut r0 = start;
            while r0 < end {
                let rows = l.part.tile_n.min(end - r0);
                let mut c0 = 0;
                while c0 < l.part.m {
                    let cols = l.part.tile_m.min(l.part.m - c0);
                    let settled =
                        l.results.iter().any(|r| (r.tile.r0, r.tile.c0) == (r0, c0));
                    if !settled {
                        let (phys, incarnation, attempt) = l
                            .retries
                            .iter()
                            .rev()
                            .find(|rs| rs.origin == (r0, c0))
                            .map(|rs| (rs.phys, rs.incarnation, rs.attempt))
                            .unwrap_or((l.slots[slot].phys, l.slots[slot].incarnation, 0));
                        let lost = if self.dev.workers[phys].is_live_at(incarnation) {
                            if self.dev.workers[phys].is_finished() {
                                // current incarnation, dead thread: heal
                                // it, which retires the stamp.  Whether it
                                // respawned or was quarantined, the job
                                // died with the old thread — replay it
                                // (submit_tile poisons if the quarantine
                                // left zero survivors).
                                // apfp-lint: allow(alloc, reason="cold healing path: the incident string is built once per detected worker death")
                                let incident = format!(
                                    "launch {} tile ({r0},{c0}) attempt {attempt}: \
                                     no reply from dead worker",
                                    l.id
                                );
                                let _ = self.dev.workers[phys].respawn(&incident);
                                true
                            } else {
                                false // alive and current: just slow
                            }
                        } else {
                            true // the stamped incarnation took the job down with it
                        };
                        if lost {
                            self.dev.metrics.add_retries(1);
                            let tile = Tile { cu: slot, r0, c0, rows, cols };
                            let c_buf = self.c_pool.pop().unwrap_or_default();
                            self.submit_tile(l, tile, attempt + 1, c_buf)?;
                        }
                    }
                    c0 += l.part.tile_m;
                }
                r0 += l.part.tile_n;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ApfpConfig, FaultSpec};
    use crate::runtime::BackendKind;

    fn dev_on(backend: BackendKind, faults: FaultSpec) -> Device {
        let cfg = ApfpConfig {
            backend,
            compute_units: 1,
            tile_n: 4,
            tile_m: 4,
            tile_k: 4,
            // pinned (not env-derived) so the width-taxonomy tests below
            // stay deterministic under an APFP_WIDTHS override
            widths: vec![128, 512, 1024],
            faults,
            ..Default::default()
        };
        let dir = std::env::temp_dir().join("apfp_stream_unit_no_artifacts/none");
        Device::new(cfg, &dir).expect("builtin-manifest device on a clean checkout")
    }

    fn dev_with(faults: FaultSpec) -> Device {
        dev_on(BackendKind::Native, faults)
    }

    /// One exemplar of every [`StreamError`] variant, for taxonomy tests.
    fn every_variant() -> Vec<StreamError> {
        vec![
            StreamError::ForeignHandle { index: 3, handle_stream: 7, this_stream: 9 },
            StreamError::UnknownBuffer { index: 12 },
            StreamError::WidthMismatch { launch: 8, bits: 512, a: 512, b: 128, c: 512 },
            StreamError::LaunchFailed {
                launch: 4,
                failed: 1,
                total: 4,
                tiles: "(0,4): injected".to_string(),
            },
            StreamError::ReplyLost { launch: 5, missing: 2, total: 4 },
            StreamError::NoSurvivors { launch: 6, total: 2 },
            StreamError::Invariant { what: "drained launch left a live reference" },
            StreamError::Poisoned { reason: "compute unit 1 is gone".to_string() },
            StreamError::Multi { count: 2, summary: "a | b".to_string() },
        ]
    }

    #[test]
    fn stream_error_display_carries_the_dispatch_payload() {
        // every variant's Display names its discriminating fields, so a
        // log line alone is enough to identify the failure
        for (e, needles) in every_variant().iter().zip([
            vec!["#3", "stream 7", "stream 9"],
            vec!["buffer id 12"],
            vec!["launch 8", "512/128/512", "512-bit launch width", "convert()"],
            vec!["launch 4", "1 of 4", "(0,4): injected", "C left unchanged"],
            vec!["launch 5", "2 of 4", "outstanding"],
            vec!["launch 6", "zero of 2", "quarantined"],
            vec!["drained launch left a live reference", "poisoned"],
            vec!["poisoned by an earlier failure", "compute unit 1 is gone"],
            vec!["2 launches failed", "a | b"],
        ]) {
            let msg = e.to_string();
            for needle in needles {
                assert!(msg.contains(needle), "{e:?} display {msg:?} lacks {needle:?}");
            }
        }
    }

    #[test]
    fn stream_errors_are_leaves_without_source_chains() {
        // the taxonomy is flat on purpose: callers downcast to StreamError
        // and dispatch on the variant, never on a wrapped cause
        use std::error::Error as _;
        for e in every_variant() {
            assert!(e.source().is_none(), "{e:?} must not hide a source");
        }
    }

    #[test]
    fn multi_aggregation_preserves_launch_order() {
        let errs = vec![
            StreamError::LaunchFailed {
                launch: 11,
                failed: 1,
                total: 4,
                tiles: "(0,0): first".to_string(),
            },
            StreamError::NoSurvivors { launch: 12, total: 4 },
            StreamError::LaunchFailed {
                launch: 13,
                failed: 2,
                total: 4,
                tiles: "(4,4): third".to_string(),
            },
        ];
        match join_failures(errs) {
            Some(StreamError::Multi { count, summary }) => {
                assert_eq!(count, 3);
                let first = summary.find("launch 11").expect("first report present");
                let second = summary.find("launch 12").expect("second report present");
                let third = summary.find("launch 13").expect("third report present");
                assert!(first < second && second < third, "launch order lost: {summary}");
                assert_eq!(summary.matches(" | ").count(), 2, "{summary}");
            }
            other => panic!("expected Multi, got {other:?}"),
        }
    }

    #[test]
    fn join_failures_passes_singletons_through() {
        assert!(join_failures(Vec::new()).is_none());
        match join_failures(vec![StreamError::UnknownBuffer { index: 1 }]) {
            Some(StreamError::UnknownBuffer { index: 1 }) => {}
            other => panic!("singleton must pass through unchanged, got {other:?}"),
        }
    }

    #[test]
    fn failed_launch_recovers_every_staging_buffer_into_the_pool() {
        // 8x8 matrices on 4x4 tiles, 1 CU: 4 tiles per launch, one of which
        // (origin (0,4)) is injected to fail on *every* attempt — so the
        // retry rung runs dry (retry_limit redispatches) and the launch
        // still reports exactly one failed tile.
        let dev = dev_with(FaultSpec { fail_tile: Some((0, 4)), ..Default::default() });
        let retry_limit = u64::from(dev.config().retry.retry_limit);
        assert!(retry_limit > 0, "default policy must actually retry");
        let a = Matrix::random(8, 8, 448, 1, 20);
        let b = Matrix::random(8, 8, 448, 2, 20);
        let c = Matrix::random(8, 8, 448, 3, 20);
        let mut s = dev.stream().unwrap();
        let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
        for round in 0..3 {
            s.enqueue_gemm(ha, hb, hc).unwrap();
            let err = s.wait().expect_err("injected tile failure must surface");
            let se = err.downcast_ref::<StreamError>().expect("typed StreamError");
            match se {
                StreamError::LaunchFailed { failed, total, .. } => {
                    assert_eq!((*failed, *total), (1, 4), "round {round}");
                }
                other => panic!("round {round}: unexpected error {other:?}"),
            }
            // every tile's staging buffer came home — the failed one too —
            // so repeated failures never shrink the pool or grow it
            assert_eq!(s.c_pool.len(), 4, "round {round}: pool must recover all buffers");
            assert_eq!(s.reply_pool.len(), 1, "round {round}: reply channel recycled");
            assert!(s.poisoned.is_none(), "tile failures must not poison the stream");
            // the failing tile burned its full retry budget before settling
            assert_eq!(
                dev.metrics().retries,
                retry_limit * (round + 1),
                "round {round}: every redispatch is counted"
            );
            assert_eq!(dev.metrics().respawns, 0, "tile errors never respawn workers");
        }
        // the failed launches wrote nothing: C still decodes to its upload
        assert_eq!(s.download(hc).unwrap(), c);
    }

    #[test]
    fn sim_backend_feeds_the_model_ledger_at_retirement() {
        // 8x8x8 on 4x4x4 tiles, 1 CU: 4 output tiles, 2 K-steps each.
        let dev = dev_on(BackendKind::Sim, FaultSpec::default());
        let a = Matrix::random(8, 8, 448, 8, 20);
        let b = Matrix::random(8, 8, 448, 9, 20);
        let mut s = dev.stream().unwrap();
        let (ha, hb) = (s.upload(&a), s.upload(&b));
        let hc = s.alloc(8, 8);
        s.enqueue_gemm(ha, hb, hc).unwrap();
        // accumulation happens at retirement, not dispatch or receipt
        s.wait().unwrap();
        let m = dev.model_metrics();
        assert!(m.is_live());
        assert_eq!((m.tiles, m.launches), (4, 1));
        // every padded MAC lane modeled exactly once:
        // 4 tiles x 2 K-steps x (4*4*4) lanes per kernel call
        assert_eq!(m.macs, 512);
        // ... and attributed to the launch width's slot, not just totals
        let w512 = m.width_breakdown().find(|w| w.bits == 512).expect("512-bit slot");
        assert_eq!((w512.tiles, w512.launches, w512.macs), (4, 1, 512));
        assert!(m.width_breakdown().filter(|w| w.bits != 512).all(|w| w.tiles == 0));
        assert!(m.cycles > 0 && m.dram_bytes > 0 && m.energy_pj > 0);
        assert!(m.total_s() > 0.0 && m.efficiency() > 0.0 && m.efficiency() <= 1.0);
        // the functional result is bit-identical to the native backend
        let native = dev_with(FaultSpec::default());
        let (want, _) = native.gemm(&a, &b, &native.alloc(8, 8)).unwrap();
        assert_eq!(s.download(hc).unwrap(), want);
        assert!(!native.model_metrics().is_live(), "native accrues nothing");
    }

    #[test]
    fn writeback_bumps_the_version_and_staleness_is_per_buffer() {
        let dev = dev_with(FaultSpec::default());
        let a = Matrix::random(8, 8, 448, 4, 20);
        let b = Matrix::random(8, 8, 448, 5, 20);
        let c = Matrix::random(8, 8, 448, 6, 20);
        let mut s = dev.stream().unwrap();
        let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
        s.enqueue_gemm(ha, hb, hc).unwrap();
        s.wait().unwrap();
        assert_eq!(s.bufs[hc.index].version, 1, "writeback must bump the C version");
        assert_eq!(s.bufs[ha.index].version, 0, "read-only operands keep their version");
        assert_eq!(s.bufs[hb.index].version, 0);
        // B's grid was cut from version 0 and B was never written: fresh
        let part = Partition {
            n: 8,
            m: 8,
            k: 8,
            tile_n: 4,
            tile_m: 4,
            k_tile: 4,
            compute_units: 1,
        };
        assert!(DeviceStream::grid_fresh(&s.bufs[hb.index], &part));
        // C was written, so a grid cut from it before the launch would be
        // stale — and C never had one built anyway
        assert!(!DeviceStream::grid_fresh(&s.bufs[hc.index], &part));
    }

    #[test]
    fn foreign_handles_are_rejected_before_touching_state() {
        let dev = dev_with(FaultSpec::default());
        let a = Matrix::random(4, 4, 448, 7, 20);
        let mut s1 = dev.stream().unwrap();
        let mut s2 = dev.stream().unwrap();
        let h1 = s1.upload(&a);
        let h2 = s2.upload(&a);
        let err = s2.enqueue_gemm(h1, h2, h2).expect_err("foreign handle");
        assert!(
            matches!(err.downcast_ref::<StreamError>(), Some(StreamError::ForeignHandle { .. })),
            "{err:#}"
        );
        let err = s2.download(h1).expect_err("foreign handle on download");
        assert!(
            matches!(err.downcast_ref::<StreamError>(), Some(StreamError::ForeignHandle { .. })),
            "{err:#}"
        );
        // both streams remain fully usable with their own handles
        s1.enqueue_gemm(h1, h1, h1).unwrap();
        s1.wait().unwrap();
        s2.enqueue_gemm(h2, h2, h2).unwrap();
        s2.wait().unwrap();
    }

    #[test]
    fn width_mismatch_is_typed_and_leaves_the_stream_usable() {
        let dev = dev_with(FaultSpec::default());
        let mut s = dev.stream().unwrap();
        assert_eq!(s.launch_widths().collect::<Vec<_>>(), vec![128, 512, 1024]);
        let a = s.upload(&Matrix::random(8, 8, 448, 10, 20));
        let b = s.upload(&Matrix::random(8, 8, 448, 11, 20));
        let c128 = s.alloc_at(128, 8, 8);
        assert_eq!((s.width(a).unwrap(), s.width(c128).unwrap()), (512, 128));
        let err = s.enqueue_gemm(a, b, c128).expect_err("mixed operands at one launch width");
        match err.downcast_ref::<StreamError>() {
            Some(StreamError::WidthMismatch { bits: 512, a: 512, b: 512, c: 128, .. }) => {}
            other => panic!("expected a typed WidthMismatch, got {other:?}"),
        }
        assert!(s.poisoned.is_none(), "a width mismatch must not poison the stream");
        assert!(s.inflight.is_empty(), "a mismatched launch must touch no dispatch state");
        // a width the manifest does not serve is the typed manifest error
        let err = s.enqueue_gemm_at(2048, a, b, c128).expect_err("unloaded width");
        let me = err
            .downcast_ref::<crate::runtime::manifest::ManifestError>()
            .expect("typed ManifestError");
        match me {
            crate::runtime::manifest::ManifestError::NoArtifact { bits, loaded, .. } => {
                assert_eq!(*bits, 2048);
                assert_eq!(loaded, &vec![128, 512, 1024]);
            }
            other => panic!("expected NoArtifact, got {other:?}"),
        }
        // the stream stays fully usable, at the default and at 128 bits
        let c = s.alloc(8, 8);
        s.enqueue_gemm(a, b, c).unwrap();
        let (a1, b1) = (s.convert(a, 128).unwrap(), s.convert(b, 128).unwrap());
        s.enqueue_gemm_at(128, a1, b1, c128).unwrap();
        s.wait().unwrap();
        assert_eq!(s.download(c128).unwrap().prec(), 64);
    }

    #[test]
    fn convert_round_trips_and_feeds_the_other_width() {
        // narrow -> wide -> narrow is the identity on the narrow value,
        // and a converted buffer launches at its new width bit-identically
        // to a serial reference at that width
        let dev = dev_with(FaultSpec::default());
        let a = Matrix::random(8, 8, 448, 12, 20);
        let b = Matrix::random(8, 8, 448, 13, 20);
        let mut s = dev.stream().unwrap();
        let (ha, hb) = (s.upload(&a), s.upload(&b));
        let (la, lb) = (s.convert(ha, 128).unwrap(), s.convert(hb, 128).unwrap());
        let wide_again = s.convert(la, 512).unwrap();
        let narrow_again = s.convert(wide_again, 128).unwrap();
        assert_eq!(s.download(narrow_again).unwrap(), s.download(la).unwrap());
        let lc = s.alloc_at(128, 8, 8);
        s.enqueue_gemm_at(128, la, lb, lc).unwrap();
        s.wait().unwrap();
        let a64 = a.to_prec(64);
        let b64 = b.to_prec(64);
        let want = crate::baseline::gemm_serial(&a64, &b64, &Matrix::zeros(8, 8, 64));
        assert_eq!(s.download(lc).unwrap(), want);
    }
}
