//! The batched device stream — "keep data on the device" (§IV-B) as an API.
//!
//! A [`DeviceStream`] owns device-resident buffers ([`DeviceBuf`], packed
//! limb-plane panels) and launches GEMMs against them by handle:
//!
//! * [`DeviceStream::upload`] packs a host [`Matrix`] into the plane layout
//!   **once** — the "copy to device DDR" step;
//! * [`DeviceStream::enqueue_gemm`] launches `C += A @ B` over the worker
//!   queues; the updated C stays resident, so it can be the A, B or C of
//!   the next enqueue with **no host round-trip**;
//! * [`DeviceStream::wait`] drains outstanding tiles into the C panel;
//! * [`DeviceStream::download`] is the only step that decodes planes back
//!   into host values.
//!
//! Two forms of reuse make a warm stream cheap:
//!
//! * **Shared B tiles.** The first time a buffer is used as B, its panel is
//!   cut into the tile grid once (`k_steps x m_tiles` pre-packed tiles,
//!   one [`crate::pack::PlaneBatch`] each) and every compute unit reads the
//!   same grid through the buffer's `Arc` — the host analog of the paper
//!   replicating B to each CU's DDR bank, minus the copies.  The grid is
//!   cached on the buffer and reused by later enqueues until the buffer is
//!   written (`panel_builds` / `panel_reuses` in the device metrics make
//!   the amortization visible).
//! * **Pooled staging.** Tile C-staging buffers cycle leader -> worker ->
//!   leader through a pool, tile lists and reply channels are reused, and
//!   job payloads are `Arc` clones — in steady state (same shapes, warm
//!   pool) an `enqueue_gemm` + [`DeviceStream::wait`] round performs **zero
//!   heap allocations** end to end, workers included
//!   (`tests/alloc_free.rs`).
//!
//! [`crate::coordinator::Device::gemm`] is a one-shot wrapper over this
//! API: upload, enqueue, wait, download.
//!
//! ```no_run
//! use apfp::config::ApfpConfig;
//! use apfp::coordinator::{Device, Matrix};
//!
//! # fn main() -> anyhow::Result<()> {
//! let dev = Device::new(ApfpConfig::default(), std::path::Path::new("artifacts"))?;
//! let prec = dev.config().prec();
//! let mut s = dev.stream()?;
//! let a = s.upload(&Matrix::random(64, 64, prec, 1, 30));
//! let b = s.upload(&Matrix::random(64, 64, prec, 2, 30));
//! let c = s.alloc(64, 64);
//! s.enqueue_gemm(a, b, c)?; // C += A @ B
//! s.enqueue_gemm(c, b, c)?; // chain: C += C @ B, no round-trip
//! let out = s.download(c)?;
//! # let _ = out;
//! # Ok(())
//! # }
//! ```

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::device::Device;
use super::matrix::Matrix;
use super::scheduler::{Partition, Tile};
use super::worker::{Job, TileResult};
use crate::pack::{PlaneBatch, PlanePanel};
use crate::runtime::ArtifactMeta;

/// Handle to one device-resident buffer of a [`DeviceStream`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BufId(pub(crate) usize);

/// A device-resident matrix: the packed plane panel plus the lazily built,
/// shared B tile grid.  Workers hold these through `Arc` for the duration
/// of a launch; the stream regains exclusive access (and with it the right
/// to write the panel) only once every tile of the launch has replied.
pub struct DeviceBuf {
    pub(crate) panel: PlanePanel,
    pub(crate) b_cache: BTileCache,
}

/// The pre-packed B tile grid: `k_steps x m_tiles` tiles of shape
/// `k_tile x tile_m`, extracted once per panel version and read by every
/// compute unit.  `valid` drops when the owning buffer is written.
#[derive(Default)]
pub(crate) struct BTileCache {
    tiles: Vec<PlaneBatch>,
    k_tile: usize,
    tile_m: usize,
    m_tiles: usize,
    k_steps: usize,
    valid: bool,
}

impl DeviceBuf {
    pub(crate) fn panel(&self) -> &PlanePanel {
        &self.panel
    }

    /// The shared pre-packed B tile for K step `step`, tile column `jt`.
    pub(crate) fn b_tile(&self, step: usize, jt: usize) -> Result<&PlaneBatch> {
        anyhow::ensure!(self.b_cache.valid, "B tile grid not packed for this launch");
        anyhow::ensure!(
            step < self.b_cache.k_steps && jt < self.b_cache.m_tiles,
            "B tile ({step},{jt}) outside the {}x{} grid",
            self.b_cache.k_steps,
            self.b_cache.m_tiles
        );
        Ok(&self.b_cache.tiles[step * self.b_cache.m_tiles + jt])
    }
}

/// One launch currently in flight: which buffer receives the writeback,
/// under which partition, and how many tile replies are outstanding.
struct Inflight {
    c: usize,
    part: Partition,
    pending: usize,
}

/// A batched GEMM stream over a [`Device`] — see the module docs.
///
/// Dropping a stream with work still in flight abandons those results:
/// workers finish their queued tiles and their replies are discarded.
pub struct DeviceStream<'d> {
    dev: &'d Device,
    meta: ArtifactMeta,
    artifact: Arc<str>,
    bufs: Vec<Arc<DeviceBuf>>,
    /// Per-CU tile lists, refilled in place each enqueue.
    cu_tiles: Vec<Vec<Tile>>,
    /// Per-CU submission cursors (reset each enqueue).
    cursors: Vec<usize>,
    /// Recycled C-staging tile buffers (leader -> worker -> leader).
    c_pool: Vec<PlaneBatch>,
    /// Reply staging for [`DeviceStream::wait`] (capacity reused).
    results: Vec<TileResult>,
    /// Bounded reply channel, recreated only when a launch needs more
    /// capacity than any before it (workers must never block on replies —
    /// that would deadlock against the bounded job queues).
    reply: Option<(SyncSender<TileResult>, Receiver<TileResult>)>,
    reply_cap: usize,
    inflight: Option<Inflight>,
}

impl<'d> DeviceStream<'d> {
    pub(crate) fn new(dev: &'d Device, meta: ArtifactMeta) -> Self {
        let cus = dev.workers.len();
        DeviceStream {
            artifact: Arc::from(meta.name.as_str()),
            meta,
            dev,
            bufs: Vec::new(),
            cu_tiles: (0..cus).map(|_| Vec::new()).collect(),
            cursors: vec![0; cus],
            c_pool: Vec::new(),
            results: Vec::new(),
            reply: None,
            reply_cap: 0,
            inflight: None,
        }
    }

    /// Pack a host matrix into a device-resident panel (the one-time
    /// "copy to DDR"); everything after this moves plane rows, not values.
    pub fn upload(&mut self, m: &Matrix) -> BufId {
        let t0 = Instant::now();
        let panel = m.to_panel();
        self.dev.metrics.add_marshal_ns(t0.elapsed().as_nanos() as u64);
        self.push_buf(panel)
    }

    /// Allocate a zeroed device-resident `rows x cols` buffer at the
    /// device's precision (the `cudaMalloc` analog).
    pub fn alloc(&mut self, rows: usize, cols: usize) -> BufId {
        let prec = self.dev.config.prec();
        self.push_buf(PlanePanel::zeros(rows, cols, prec))
    }

    fn push_buf(&mut self, panel: PlanePanel) -> BufId {
        self.bufs.push(Arc::new(DeviceBuf { panel, b_cache: BTileCache::default() }));
        BufId(self.bufs.len() - 1)
    }

    fn buf(&self, id: BufId) -> Result<&Arc<DeviceBuf>> {
        self.bufs.get(id.0).ok_or_else(|| anyhow!("unknown device buffer id {}", id.0))
    }

    /// Drain pending work, then decode a buffer back to a host matrix —
    /// the only step of the stream that materializes `ApFloat`s.
    pub fn download(&mut self, id: BufId) -> Result<Matrix> {
        self.wait()?;
        let buf = self.buf(id)?;
        Ok(Matrix::from_panel(&buf.panel))
    }

    /// Launch `C += A @ B` (alpha = beta = 1, §III) across the device's
    /// compute units.  Inputs are pre-launch buffer contents: an earlier
    /// enqueue's output is drained into its panel before this launch reads
    /// it, so chains like `enqueue_gemm(c, b, c)` are well defined.
    /// Returns once every tile is submitted (the bounded worker queues
    /// backpressure the caller); [`DeviceStream::wait`] collects results.
    pub fn enqueue_gemm(&mut self, a: BufId, b: BufId, c: BufId) -> Result<()> {
        // Sequencing: earlier launches write panels this one may read.
        self.wait()?;
        let prec = self.meta.prec();
        let (n, k, m) = {
            let (pa, pb, pc) = (&self.buf(a)?.panel, &self.buf(b)?.panel, &self.buf(c)?.panel);
            anyhow::ensure!(
                pa.cols() == pb.rows(),
                "inner dimensions: {} vs {}",
                pa.cols(),
                pb.rows()
            );
            anyhow::ensure!(
                pa.rows() == pc.rows() && pb.cols() == pc.cols(),
                "output shape: {}x{} vs {}x{}",
                pa.rows(),
                pb.cols(),
                pc.rows(),
                pc.cols()
            );
            anyhow::ensure!(
                pa.prec() == prec && pb.prec() == prec && pc.prec() == prec,
                "operand precision vs device artifact ({prec} bits of mantissa)"
            );
            (pa.rows(), pa.cols(), pb.cols())
        };
        let part = Partition {
            n,
            m,
            k,
            tile_n: self.meta.t_n,
            tile_m: self.meta.t_m,
            k_tile: self.meta.k_tile,
            compute_units: self.dev.workers.len(),
        };
        self.build_b_cache(b, &part)?;

        // Plan each CU's band and make sure the reply channel can absorb
        // every tile of this launch without blocking a worker.
        let mut total = 0;
        for (cu, tiles) in self.cu_tiles.iter_mut().enumerate() {
            part.tiles_into(cu, tiles);
            total += tiles.len();
            self.cursors[cu] = 0;
        }
        if self.reply.is_none() || self.reply_cap < total {
            let cap = total.max(1);
            self.reply = Some(sync_channel(cap));
            self.reply_cap = cap;
        }
        let reply_tx = &self.reply.as_ref().expect("just ensured").0;

        // Submit round-robin, one tile per CU per pass, so the bounded
        // queues fill evenly and a stalled CU backpressures only its band.
        let c_id = c.0;
        let (a, b, c) = (self.buf(a)?.clone(), self.buf(b)?.clone(), self.buf(c)?.clone());
        let mut pending = 0usize;
        let mut active = true;
        while active {
            active = false;
            for cu in 0..self.dev.workers.len() {
                let Some(tile) = self.cu_tiles[cu].get(self.cursors[cu]) else { continue };
                self.cursors[cu] += 1;
                let c_buf = self.c_pool.pop().unwrap_or_default();
                self.dev.workers[cu].submit(Job::GemmTile {
                    artifact: self.artifact.clone(),
                    a: a.clone(),
                    b: b.clone(),
                    c: c.clone(),
                    c_buf,
                    tile: *tile,
                    part: part.clone(),
                    reply: reply_tx.clone(),
                });
                pending += 1;
                active = true;
            }
        }
        self.dev.metrics.add_enqueues(1);
        self.inflight = Some(Inflight { c: c_id, part, pending });
        Ok(())
    }

    /// Pack (or reuse) the shared B tile grid for `part` on buffer `b`.
    fn build_b_cache(&mut self, b: BufId, part: &Partition) -> Result<()> {
        let (m_tiles, k_steps) = (part.m_tiles(), part.k_steps());
        let buf = Arc::get_mut(&mut self.bufs[b.0])
            .expect("a drained stream has exclusive access to its buffers");
        let cache = &mut buf.b_cache;
        if cache.valid
            && cache.k_tile == part.k_tile
            && cache.tile_m == part.tile_m
            && cache.m_tiles == m_tiles
            && cache.k_steps == k_steps
        {
            self.dev.metrics.add_panel_reuses(1);
            return Ok(());
        }
        let t0 = Instant::now();
        let count = k_steps * m_tiles;
        if cache.tiles.len() != count {
            cache.tiles.resize_with(count, PlaneBatch::default);
        }
        for step in 0..k_steps {
            for jt in 0..m_tiles {
                buf.panel.extract_tile_into(
                    step * part.k_tile,
                    jt * part.tile_m,
                    part.k_tile,
                    part.tile_m,
                    &mut cache.tiles[step * m_tiles + jt],
                );
            }
        }
        cache.k_tile = part.k_tile;
        cache.tile_m = part.tile_m;
        cache.m_tiles = m_tiles;
        cache.k_steps = k_steps;
        cache.valid = true;
        self.dev.metrics.add_marshal_ns(t0.elapsed().as_nanos() as u64);
        self.dev.metrics.add_panel_builds(1);
        Ok(())
    }

    /// Collect every outstanding tile of the last enqueue and land it in
    /// the C buffer's panel (each output element is owned by exactly one
    /// clipped tile, so writes are disjoint).  No-op when nothing is in
    /// flight.
    pub fn wait(&mut self) -> Result<()> {
        let Some(fl) = self.inflight.take() else { return Ok(()) };
        let rx = &self.reply.as_ref().expect("inflight implies a reply channel").1;
        self.results.clear();
        for _ in 0..fl.pending {
            self.results.push(rx.recv().context("collecting tile result")?);
        }
        // Every job has replied, and workers drop their buffer references
        // before replying — the stream owns the panels again.
        let buf = Arc::get_mut(&mut self.bufs[fl.c])
            .expect("all launches drained, so the C buffer is exclusively ours");
        // The panel is about to change: any cached B tiles go stale.
        buf.b_cache.valid = false;
        let t0 = Instant::now();
        let mut first_err = None;
        for res in self.results.drain(..) {
            let t = res.tile;
            match res.planes {
                Ok(planes) => {
                    buf.panel.write_tile(t.r0, t.c0, t.rows, t.cols, fl.part.tile_m, &planes);
                    self.c_pool.push(planes);
                }
                Err(e) if first_err.is_none() => {
                    first_err =
                        Some(e.context(format!("tile at ({}, {}) on CU{}", t.r0, t.c0, t.cu)));
                }
                Err(_) => {}
            }
        }
        self.dev.metrics.add_marshal_ns(t0.elapsed().as_nanos() as u64);
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}
