//! Compute-unit worker threads.
//!
//! Each worker models one replicated compute unit: it owns a private
//! [`Runtime`] on the device's configured backend and tile geometry (its
//! own compiled "circuit"), pulls jobs from a bounded queue (backpressure
//! toward the leader), executes them through the artifacts, and reports
//! results on a reply channel.  GEMM operands arrive as `Arc`s of
//! device-resident [`DeviceBuf`]s — A and C are read out of their shared
//! panels into per-worker staging buffers kept warm across K steps *and*
//! across jobs, while B tiles come **pre-packed** from the buffer's shared
//! tile grid (cut once by the stream, read by every CU).  The C staging
//! buffer cycles leader -> worker -> leader through the stream's pool, so
//! a steady-state tile job touches the allocator not at all.
//!
//! Discipline, which the stream's hazard tracking depends on:
//!
//! * a worker drops every shared-buffer `Arc` *before* sending its reply —
//!   the stream counts replies per launch to know when it has regained
//!   exclusive access to a launch's panels (`Arc::get_mut`) for writeback;
//! * **every** submitted job produces exactly one reply, error or not:
//!   panics are caught and converted, a worker whose runtime never came up
//!   stays alive as a reply-only drain, and the pooled C staging buffer
//!   rides home inside the reply even when the tile failed (an errored
//!   tile must not shrink the leader's pool).
//!
//! [`crate::config::FaultSpec`] injects failures at exactly these seams
//! (runtime init, a chosen tile, panic vs error, worker death) so the
//! failure paths stay under test (`tests/stream_faults.rs`).
//!
//! Workers are wrapped in a [`Supervisor`]: when a thread dies (today only
//! via an injected fault; tomorrow a real backend crash) the stream asks
//! the supervisor to respawn the CU with a fresh runtime and replays its
//! un-acked jobs, or — once the respawn budget is spent — quarantines it
//! and rebalances onto the survivors.  Each supervisor keeps the per-CU
//! health ledger ([`CuHealth`]) those decisions are recorded in.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::metrics::Metrics;
use super::scheduler::{Partition, Tile};
use super::stream::DeviceBuf;
use crate::config::FaultSpec;
use crate::pack::PlaneBatch;
use crate::runtime::{BackendKind, Runtime, TileModelCost, TileShape};

/// Depth of each worker's job queue: small, so a slow CU exerts
/// backpressure on the leader instead of buffering unbounded work.
pub const QUEUE_DEPTH: usize = 4;

pub enum Job {
    /// One full output tile: accumulate C_tile over all K steps.
    GemmTile {
        /// Stream-local id of the launch this tile belongs to; echoed in
        /// the reply so mis-routed results are detectable.
        launch: u64,
        artifact: Arc<str>,
        /// A: n x k, read from the shared panel.
        a: Arc<DeviceBuf>,
        /// B: k x m, read from the shared pre-packed tile grid.
        b: Arc<DeviceBuf>,
        /// C input values: n x m, read from the shared panel (the leader
        /// writes results back only after the launch fully drains).
        c: Arc<DeviceBuf>,
        /// Pooled staging buffer the C tile is accumulated in; returned to
        /// the leader inside [`TileResult`] on success *and* failure.
        c_buf: PlaneBatch,
        tile: Tile,
        part: Partition,
        /// 0-based delivery attempt of this tile (0 = first dispatch,
        /// bumped by the stream on every retry or replay).  Carried in
        /// the job so transient-fault predicates stay deterministic
        /// across respawned workers, and echoed in the reply so the
        /// stream can match a result to the dispatch that produced it.
        attempt: u32,
        reply: SyncSender<TileResult>,
    },
    /// A chunk of a stream operator (Tab. I/II microbenchmark path).
    Stream {
        artifact: String,
        kind: StreamKind,
        operands: Vec<PlaneBatch>,
        offset: usize,
        reply: Sender<StreamResult>,
    },
    Shutdown,
}

#[derive(Clone, Copy, Debug)]
pub enum StreamKind {
    Binop,
    Mac,
}

pub struct TileResult {
    /// Launch id echoed from the job.
    pub launch: u64,
    pub tile: Tile,
    /// Delivery attempt echoed from the job, so the stream can tell a
    /// retried dispatch's reply from the original's.
    pub attempt: u32,
    /// The pooled C staging buffer, always returned to the leader.  On
    /// success it holds the accumulated C tile; when `err` is set its
    /// contents are unspecified (the leader recycles it without reading).
    pub c_buf: PlaneBatch,
    /// Modeled hardware cost of the K-steps this reply settles — `Some`
    /// only on the simulated backend, and only on success (a failed
    /// attempt's partial cost is discarded at the worker, so a retried
    /// tile is modeled exactly once by the attempt that lands).  The
    /// stream accumulates it into the device's `ModelMetrics` when the
    /// launch retires.
    pub model: Option<TileModelCost>,
    /// `None` on success; the tile's failure otherwise.
    pub err: Option<anyhow::Error>,
}

pub struct StreamResult {
    pub offset: usize,
    pub planes: Result<PlaneBatch>,
}

pub struct WorkerHandle {
    pub cu: usize,
    sender: SyncSender<Job>,
    thread: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn the worker; it creates its own Runtime on its own thread (no
    /// backend client is Send — PJRT is `Rc`-based and the native arena is
    /// private).  `tile` shapes the worker's builtin manifest so its
    /// artifact names and geometry match the leader's partition exactly;
    /// `faults` is the test-only failure injection (no faults in
    /// production configs).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        cu: usize,
        artifact_dir: std::path::PathBuf,
        backend: BackendKind,
        tile: TileShape,
        widths: Vec<u32>,
        faults: FaultSpec,
        metrics: Arc<Metrics>,
    ) -> std::io::Result<Self> {
        let (tx, rx) = sync_channel::<Job>(QUEUE_DEPTH);
        let thread = std::thread::Builder::new().name(format!("apfp-cu{cu}")).spawn(move || {
            worker_main(cu, &artifact_dir, backend, tile, &widths, faults, rx, metrics)
        })?;
        Ok(WorkerHandle { cu, sender: tx, thread: Some(thread) })
    }

    /// Enqueue a job (blocks when the queue is full — backpressure).
    /// Returns the job back when the worker thread is gone, so the caller
    /// can reclaim pooled buffers and surface a typed error instead of
    /// panicking.
    pub fn submit(&self, job: Job) -> std::result::Result<(), Job> {
        self.sender.send(job).map_err(|e| e.0)
    }

    /// Has this worker's thread exited?  A live worker replies to every
    /// submitted job, so a reply that never comes implies a finished
    /// thread — the stream's drain loop probes this (only when a reply is
    /// overdue) to turn a would-be hang into a typed error.
    pub fn is_finished(&self) -> bool {
        match &self.thread {
            Some(t) => t.is_finished(),
            None => true,
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.sender.send(Job::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// What [`Supervisor::respawn`] did about a dead worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RespawnOutcome {
    /// A fresh worker thread (with a fresh runtime) is live; the caller
    /// replays the dead CU's un-acked jobs against it.
    Respawned,
    /// The respawn budget is exhausted (or the respawn itself failed):
    /// the CU is quarantined and must be excluded from scheduling.
    Quarantined,
}

/// One row of the device's per-CU health ledger (see
/// `docs/ARCHITECTURE.md` § Failure recovery).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CuHealth {
    pub cu: usize,
    /// Times this CU has been respawned after a detected death.
    pub respawns: u32,
    /// Quarantined CUs take no further work; the stream schedules around
    /// them.
    pub quarantined: bool,
    /// Human-readable description of the most recent incident.
    pub last_incident: Option<String>,
}

struct SupervisorState {
    /// `None` only after quarantine (the dead handle is dropped/joined).
    handle: Option<WorkerHandle>,
    respawns: u32,
    quarantined: bool,
    last_incident: Option<String>,
}

/// Supervised compute unit: a [`WorkerHandle`] plus the spawn recipe
/// needed to replace it and the health ledger recording every incident.
///
/// The supervisor itself never polls — death detection stays in the
/// stream's reply-liveness probe — it only answers "respawn or
/// quarantine?" when the stream reports a dead worker, keeping the policy
/// (the [`RetryPolicy`](crate::config::RetryPolicy) respawn budget) in
/// one place.
pub struct Supervisor {
    cu: usize,
    artifact_dir: std::path::PathBuf,
    backend: BackendKind,
    tile: TileShape,
    /// Builtin packed widths the worker's runtime hosts (part of the
    /// spawn recipe: a respawned CU must serve the same width set).
    widths: Vec<u32>,
    faults: FaultSpec,
    metrics: Arc<Metrics>,
    respawn_limit: u32,
    inner: Mutex<SupervisorState>,
}

impl Supervisor {
    /// Spawn the CU under supervision, keeping the spawn recipe for later
    /// respawns.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        cu: usize,
        artifact_dir: std::path::PathBuf,
        backend: BackendKind,
        tile: TileShape,
        widths: Vec<u32>,
        faults: FaultSpec,
        metrics: Arc<Metrics>,
        respawn_limit: u32,
    ) -> std::io::Result<Self> {
        let handle = WorkerHandle::spawn(
            cu,
            artifact_dir.clone(),
            backend,
            tile,
            widths.clone(),
            faults,
            Arc::clone(&metrics),
        )?;
        Ok(Supervisor {
            cu,
            artifact_dir,
            backend,
            tile,
            widths,
            faults,
            metrics,
            respawn_limit,
            inner: Mutex::new(SupervisorState {
                handle: Some(handle),
                respawns: 0,
                quarantined: false,
                last_incident: None,
            }),
        })
    }

    pub fn cu(&self) -> usize {
        self.cu
    }

    /// Lock the ledger, recovering from a poisoned mutex: the state is
    /// plain bookkeeping scalars, valid at every await-free point, so a
    /// panicking peer cannot leave it torn.
    fn state(&self) -> std::sync::MutexGuard<'_, SupervisorState> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Enqueue a job on the current worker (blocking backpressure, same
    /// contract as [`WorkerHandle::submit`]).  Returns the job when the
    /// worker is gone or the CU is quarantined, so the caller can reclaim
    /// pooled buffers and escalate.
    pub fn submit(&self, job: Job) -> std::result::Result<(), Job> {
        match self.state().handle.as_ref() {
            Some(h) => h.submit(job),
            None => Err(job),
        }
    }

    /// Has the current worker thread exited?  Quarantined CUs report
    /// `true` (there is nothing live to reply).
    pub fn is_finished(&self) -> bool {
        match &self.state().handle {
            Some(h) => h.is_finished(),
            None => true,
        }
    }

    pub fn is_quarantined(&self) -> bool {
        self.state().quarantined
    }

    /// The worker's incarnation: bumped on every respawn.  A dispatch
    /// stamped with an older incarnation was submitted to a worker that
    /// has since died — its job is lost and must be replayed.
    pub fn incarnation(&self) -> u32 {
        self.state().respawns
    }

    /// Is the CU still the same live worker a dispatch stamped with
    /// `incarnation` was submitted to?  False once the CU respawned (the
    /// dispatch died with the old thread) or was quarantined.  One lock,
    /// cheap enough for per-dispatch checks.
    pub fn is_live_at(&self, incarnation: u32) -> bool {
        let st = self.state();
        !st.quarantined && st.respawns == incarnation
    }

    /// React to a detected worker death: respawn the CU with a fresh
    /// runtime while budget remains, quarantine it otherwise.  The
    /// incident is recorded in the health ledger either way.  Idempotent
    /// once quarantined.
    // apfp-lint: allow(alloc, scope=fn, reason="cold healing path: a respawn rebuilds the worker thread and its runtime, bounded by the respawn budget; the warm path never reaches it")
    pub fn respawn(&self, incident: &str) -> RespawnOutcome {
        let mut st = self.state();
        st.last_incident = Some(incident.to_string());
        if st.quarantined {
            return RespawnOutcome::Quarantined;
        }
        if st.respawns >= self.respawn_limit {
            // budget spent: drop (and join) the dead handle so the CU
            // holds no thread while quarantined
            st.handle = None;
            st.quarantined = true;
            self.metrics.add_quarantined(1);
            return RespawnOutcome::Quarantined;
        }
        match WorkerHandle::spawn(
            self.cu,
            self.artifact_dir.clone(),
            self.backend,
            self.tile,
            self.widths.clone(),
            self.faults,
            Arc::clone(&self.metrics),
        ) {
            Ok(fresh) => {
                st.respawns += 1;
                st.handle = Some(fresh);
                self.metrics.add_respawns(1);
                RespawnOutcome::Respawned
            }
            Err(e) => {
                // the replacement itself failed to come up — that is a
                // terminal incident regardless of remaining budget
                st.last_incident = Some(format!("respawn failed: {e}"));
                st.handle = None;
                st.quarantined = true;
                self.metrics.add_quarantined(1);
                RespawnOutcome::Quarantined
            }
        }
    }

    /// Snapshot this CU's row of the health ledger.
    pub fn health(&self) -> CuHealth {
        let st = self.state();
        CuHealth {
            cu: self.cu,
            respawns: st.respawns,
            quarantined: st.quarantined,
            last_incident: st.last_incident.clone(),
        }
    }
}

/// Per-worker A-tile staging, reused across K steps and across jobs.
#[derive(Default)]
struct TileBufs {
    a: PlaneBatch,
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

#[allow(clippy::too_many_arguments)]
fn worker_main(
    cu: usize,
    dir: &std::path::Path,
    backend: BackendKind,
    tile: TileShape,
    widths: &[u32],
    faults: FaultSpec,
    rx: Receiver<Job>,
    metrics: Arc<Metrics>,
) {
    let rt = if faults.init_fail_cu == Some(cu) {
        Err(anyhow::anyhow!("injected runtime init failure on CU{cu}"))
    } else {
        Runtime::with_backend_tiled_widths(dir, backend, tile, widths)
    };
    let rt = match rt {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("CU{cu}: runtime init failed: {e:#}");
            let reason = format!("CU{cu} runtime unavailable: {e:#}");
            // Drain jobs, reporting the failure to every reply channel.
            // (Destructuring with `..` drops the shared-buffer Arcs before
            // the send, same as the healthy path.)  The staging buffer
            // still rides home so the leader's pool survives a dead CU.
            for job in rx {
                match job {
                    Job::GemmTile { launch, tile, c_buf, attempt, reply, .. } => {
                        let _ = reply.send(TileResult {
                            launch,
                            tile,
                            attempt,
                            c_buf,
                            model: None,
                            err: Some(anyhow::anyhow!("{reason}")),
                        });
                    }
                    Job::Stream { offset, reply, .. } => {
                        let _ = reply.send(StreamResult {
                            offset,
                            planes: Err(anyhow::anyhow!("{reason}")),
                        });
                    }
                    Job::Shutdown => break,
                }
            }
            return;
        }
    };

    let mut bufs = TileBufs::default();
    for job in rx {
        match job {
            Job::Shutdown => break,
            Job::GemmTile { launch, artifact, a, b, c, mut c_buf, tile, part, attempt, reply } => {
                if faults.tile_kills((tile.r0, tile.c0), attempt) {
                    // Injected CU crash: the thread exits without replying
                    // or draining its queue.  The stream's liveness probe
                    // must turn this into a supervised respawn (or, past
                    // the budget, a quarantine), never a hang.
                    return;
                }
                // A panic inside the tile (an assert anywhere in the
                // pack/softfloat stack) must become an error *reply*: the
                // leader counts replies per launch, and a job that dies
                // silently would hang its retirement forever.
                // catch_unwind costs nothing on the non-panicking path.
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if faults.tile_fails((tile.r0, tile.c0), attempt) {
                        if faults.panic_tile {
                            // apfp-lint: allow(panic, reason="FaultSpec failpoint: this injected panic is the fault under test, contained by the catch_unwind above")
                            panic!("injected panic on tile ({}, {})", tile.r0, tile.c0);
                        }
                        anyhow::bail!(
                            "injected failure on tile ({}, {}) attempt {attempt}",
                            tile.r0,
                            tile.c0
                        );
                    }
                    run_tile(
                        &rt, &artifact, &a, &b, &c, tile, &part, &metrics, &mut bufs, &mut c_buf,
                    )
                }));
                // Release the shared buffers before replying: the leader
                // reclaims exclusive panel access by counting replies.
                drop((a, b, c, artifact));
                let err = match res {
                    Ok(Ok(())) => None,
                    Ok(Err(e)) => Some(e),
                    Err(panic) => Some(anyhow::anyhow!(
                        "CU{cu} panicked executing tile: {}",
                        panic_message(&panic)
                    )),
                };
                // Drain the simulator's model ledger on every arm so a
                // failed or panicked tile's partial cost cannot leak into
                // the next job's reply; attach it only when the tile
                // succeeded (a retried tile is re-modeled from scratch by
                // the attempt that lands — no double counting).
                let model = match rt.take_model_cost() {
                    Some(cost) if err.is_none() => Some(cost),
                    _ => None,
                };
                let _ = reply.send(TileResult { launch, tile, attempt, c_buf, model, err });
            }
            Job::Stream { artifact, kind, operands, offset, reply } => {
                let t0 = Instant::now();
                // Same containment as the tile path: a panic must not kill
                // the worker, or jobs queued behind it die reply-less and
                // their collectors hang.
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    match (kind, operands.as_slice()) {
                        (StreamKind::Binop, [a, b]) => rt.exec_stream_binop(&artifact, a, b),
                        (StreamKind::Mac, [c, a, b]) => rt.exec_stream_mac(&artifact, c, a, b),
                        (kind, ops) => Err(anyhow::anyhow!(
                            "stream job shape mismatch: {kind:?} with {} operands",
                            ops.len()
                        )),
                    }
                }));
                let planes = match res {
                    Ok(r) => r,
                    Err(panic) => Err(anyhow::anyhow!(
                        "CU{cu} panicked executing stream chunk: {}",
                        panic_message(&panic)
                    )),
                };
                metrics.add_exec_ns(t0.elapsed().as_nanos() as u64);
                metrics.add_calls(1);
                let _ = reply.send(StreamResult { offset, planes });
            }
        }
    }
}

/// Execute one output tile: sequential K accumulation through the artifact
/// (the §III dataflow).  The C tile stays "on chip" between K steps in the
/// pooled `c_tile` staging buffer — the backend updates it in place — the
/// A staging buffer is reused across steps and jobs, and B tiles are read
/// straight from the shared pre-packed grid, so the per-step marshaling
/// cost is one plane-row copy out of the A panel.
// apfp-lint: no_alloc
#[allow(clippy::too_many_arguments)]
fn run_tile(
    rt: &Runtime,
    artifact: &str,
    a: &DeviceBuf,
    b: &DeviceBuf,
    c: &DeviceBuf,
    tile: Tile,
    part: &Partition,
    metrics: &Metrics,
    bufs: &mut TileBufs,
    c_tile: &mut PlaneBatch,
) -> Result<()> {
    let (tn, tm, kt) = (part.tile_n, part.tile_m, part.k_tile);
    let jt = tile.c0 / tm;
    let t_marshal = Instant::now();
    c.panel().extract_tile_into(tile.r0, tile.c0, tn, tm, c_tile);
    metrics.add_marshal_ns(t_marshal.elapsed().as_nanos() as u64);

    for step in 0..part.k_steps() {
        let k0 = step * kt;
        let tm_marshal = Instant::now();
        a.panel().extract_tile_into(tile.r0, k0, tn, kt, &mut bufs.a);
        let b_tile = b.b_tile(step, jt)?;
        metrics.add_marshal_ns(tm_marshal.elapsed().as_nanos() as u64);

        let t_exec = Instant::now();
        rt.exec_gemm_tile(artifact, &bufs.a, b_tile, c_tile)?;
        metrics.add_exec_ns(t_exec.elapsed().as_nanos() as u64);
        metrics.add_calls(1);
        // Count useful MAC lanes — the owned extent x the real K depth of
        // this step, summed over all tiles exactly n * m * k regardless of
        // tiling fit.  Padding lanes are excluded (the backend skips their
        // zero products); lanes whose *data* happens to be zero still
        // count, like any dense-GEMM FLOP figure.
        let k_eff = kt.min(part.k - k0);
        metrics.add_macs((tile.rows * tile.cols * k_eff) as u64);
    }
    metrics.add_tiles(1);
    Ok(())
}
