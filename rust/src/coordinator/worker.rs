//! Compute-unit worker threads.
//!
//! Each worker models one replicated compute unit: it owns a private
//! [`Runtime`] on the device's configured backend (its own compiled
//! "circuit"), pulls jobs from a bounded queue (backpressure toward the
//! leader), executes them through the artifacts, and reports results on a
//! reply channel.  GEMM operands arrive as shared [`PlanePanel`]s — packed
//! once per launch by the leader — and each worker keeps its A/B tile
//! buffers warm across K steps *and* across jobs, so steady-state tile
//! marshaling is plane-row copies into reused storage.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::metrics::Metrics;
use super::scheduler::{Partition, Tile};
use crate::pack::{PlaneBatch, PlanePanel};
use crate::runtime::{BackendKind, Runtime};

/// Depth of each worker's job queue: small, so a slow CU exerts
/// backpressure on the leader instead of buffering unbounded work.
pub const QUEUE_DEPTH: usize = 4;

/// The three GEMM operands packed into the plane layout, shared read-only
/// across every tile job of one launch (the paper copies each band's A/C
/// rows to the owning CU's DDR bank and replicates B; the host-side analog
/// is one packing pass and `Arc` sharing instead of three full `Matrix`
/// clones per launch).
pub struct GemmOperands {
    /// A: n x k.
    pub a: PlanePanel,
    /// B: k x m.
    pub b: PlanePanel,
    /// C (input values): n x m.
    pub c: PlanePanel,
}

pub enum Job {
    /// One full output tile: accumulate C_tile over all K steps.
    GemmTile {
        artifact: String,
        ops: Arc<GemmOperands>,
        tile: Tile,
        part: Partition,
        reply: Sender<TileResult>,
    },
    /// A chunk of a stream operator (Tab. I/II microbenchmark path).
    Stream {
        artifact: String,
        kind: StreamKind,
        operands: Vec<PlaneBatch>,
        offset: usize,
        reply: Sender<StreamResult>,
    },
    Shutdown,
}

#[derive(Clone, Copy, Debug)]
pub enum StreamKind {
    Binop,
    Mac,
}

pub struct TileResult {
    pub tile: Tile,
    pub planes: Result<PlaneBatch>,
}

pub struct StreamResult {
    pub offset: usize,
    pub planes: Result<PlaneBatch>,
}

pub struct WorkerHandle {
    pub cu: usize,
    sender: SyncSender<Job>,
    thread: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn the worker; it creates its own Runtime on its own thread (no
    /// backend client is Send — PJRT is `Rc`-based and the native arena is
    /// private).
    pub fn spawn(
        cu: usize,
        artifact_dir: std::path::PathBuf,
        backend: BackendKind,
        metrics: Arc<Metrics>,
    ) -> Self {
        let (tx, rx) = sync_channel::<Job>(QUEUE_DEPTH);
        let thread = std::thread::Builder::new()
            .name(format!("apfp-cu{cu}"))
            .spawn(move || worker_main(cu, &artifact_dir, backend, rx, metrics))
            .expect("spawning CU worker");
        WorkerHandle { cu, sender: tx, thread: Some(thread) }
    }

    /// Enqueue a job (blocks when the queue is full — backpressure).
    pub fn submit(&self, job: Job) {
        self.sender.send(job).expect("CU worker hung up");
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.sender.send(Job::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Per-worker tile staging buffers, reused across K steps and across jobs.
#[derive(Default)]
struct TileBufs {
    a: PlaneBatch,
    b: PlaneBatch,
}

fn worker_main(
    cu: usize,
    dir: &std::path::Path,
    backend: BackendKind,
    rx: Receiver<Job>,
    metrics: Arc<Metrics>,
) {
    let rt = match Runtime::with_backend(dir, backend) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("CU{cu}: runtime init failed: {e:#}");
            // Drain jobs, reporting the failure to every reply channel.
            for job in rx {
                match job {
                    Job::GemmTile { tile, reply, .. } => {
                        let _ = reply.send(TileResult {
                            tile,
                            planes: Err(anyhow::anyhow!("CU{cu} runtime unavailable")),
                        });
                    }
                    Job::Stream { offset, reply, .. } => {
                        let _ = reply.send(StreamResult {
                            offset,
                            planes: Err(anyhow::anyhow!("CU{cu} runtime unavailable")),
                        });
                    }
                    Job::Shutdown => break,
                }
            }
            return;
        }
    };

    let mut bufs = TileBufs::default();
    for job in rx {
        match job {
            Job::Shutdown => break,
            Job::GemmTile { artifact, ops, tile, part, reply } => {
                let planes = run_tile(&rt, &artifact, &ops, tile, &part, &metrics, &mut bufs);
                let _ = reply.send(TileResult { tile, planes });
            }
            Job::Stream { artifact, kind, operands, offset, reply } => {
                let t0 = Instant::now();
                let planes = match kind {
                    StreamKind::Binop => {
                        rt.exec_stream_binop(&artifact, &operands[0], &operands[1])
                    }
                    StreamKind::Mac => {
                        rt.exec_stream_mac(&artifact, &operands[0], &operands[1], &operands[2])
                    }
                };
                metrics.add_exec_ns(t0.elapsed().as_nanos() as u64);
                metrics.add_calls(1);
                let _ = reply.send(StreamResult { offset, planes });
            }
        }
    }
}

/// Execute one output tile: sequential K accumulation through the artifact
/// (the §III dataflow).  The C tile stays "on chip" between K steps — the
/// backend updates it in place — and the A/B staging buffers are reused
/// across steps and jobs, so the per-step marshaling cost is the plane-row
/// copies out of the shared panels.
fn run_tile(
    rt: &Runtime,
    artifact: &str,
    ops: &GemmOperands,
    tile: Tile,
    part: &Partition,
    metrics: &Metrics,
    bufs: &mut TileBufs,
) -> Result<PlaneBatch> {
    let (tn, tm, kt) = (part.tile_n, part.tile_m, part.k_tile);
    let t_marshal = Instant::now();
    // default() + extract: extract's reset does the one required
    // initialization (zeros() here would zero everything a second time)
    let mut c_tile = PlaneBatch::default();
    ops.c.extract_tile_into(tile.r0, tile.c0, tn, tm, &mut c_tile);
    metrics.add_marshal_ns(t_marshal.elapsed().as_nanos() as u64);

    for step in 0..part.k_steps() {
        let k0 = step * kt;
        let tm_marshal = Instant::now();
        ops.a.extract_tile_into(tile.r0, k0, tn, kt, &mut bufs.a);
        ops.b.extract_tile_into(k0, tile.c0, kt, tm, &mut bufs.b);
        metrics.add_marshal_ns(tm_marshal.elapsed().as_nanos() as u64);

        let t_exec = Instant::now();
        rt.exec_gemm_tile(artifact, &bufs.a, &bufs.b, &mut c_tile)?;
        metrics.add_exec_ns(t_exec.elapsed().as_nanos() as u64);
        metrics.add_calls(1);
        metrics.add_macs((tn * tm * kt) as u64);
    }
    metrics.add_tiles(1);
    Ok(c_tile)
}
