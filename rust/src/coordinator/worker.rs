//! Compute-unit worker threads.
//!
//! Each worker models one replicated compute unit: it owns a private PJRT
//! [`Runtime`] (its own compiled "circuit"), pulls jobs from a bounded
//! queue (backpressure toward the leader), executes them through the AOT
//! artifacts, and reports results on a reply channel.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::matrix::Matrix;
use super::metrics::Metrics;
use super::scheduler::{Partition, Tile};
use crate::pack::PlaneBatch;
use crate::runtime::Runtime;

/// Depth of each worker's job queue: small, so a slow CU exerts
/// backpressure on the leader instead of buffering unbounded work.
pub const QUEUE_DEPTH: usize = 4;

pub enum Job {
    /// One full output tile: accumulate C_tile over all K steps.
    GemmTile {
        artifact: String,
        a: Arc<Matrix>,
        b: Arc<Matrix>,
        c: Arc<Matrix>,
        tile: Tile,
        part: Partition,
        reply: Sender<TileResult>,
    },
    /// A chunk of a stream operator (Tab. I/II microbenchmark path).
    Stream {
        artifact: String,
        kind: StreamKind,
        operands: Vec<PlaneBatch>,
        offset: usize,
        reply: Sender<StreamResult>,
    },
    Shutdown,
}

#[derive(Clone, Copy, Debug)]
pub enum StreamKind {
    Binop,
    Mac,
}

pub struct TileResult {
    pub tile: Tile,
    pub planes: Result<PlaneBatch>,
}

pub struct StreamResult {
    pub offset: usize,
    pub planes: Result<PlaneBatch>,
}

pub struct WorkerHandle {
    pub cu: usize,
    sender: SyncSender<Job>,
    thread: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn the worker; it creates its own Runtime on its own thread (the
    /// PJRT client is not Send).
    pub fn spawn(cu: usize, artifact_dir: std::path::PathBuf, metrics: Arc<Metrics>) -> Self {
        let (tx, rx) = sync_channel::<Job>(QUEUE_DEPTH);
        let thread = std::thread::Builder::new()
            .name(format!("apfp-cu{cu}"))
            .spawn(move || worker_main(cu, &artifact_dir, rx, metrics))
            .expect("spawning CU worker");
        WorkerHandle { cu, sender: tx, thread: Some(thread) }
    }

    /// Enqueue a job (blocks when the queue is full — backpressure).
    pub fn submit(&self, job: Job) {
        self.sender.send(job).expect("CU worker hung up");
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.sender.send(Job::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn worker_main(cu: usize, dir: &std::path::Path, rx: Receiver<Job>, metrics: Arc<Metrics>) {
    let rt = match Runtime::new(dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("CU{cu}: runtime init failed: {e:#}");
            // Drain jobs, reporting the failure to every reply channel.
            for job in rx {
                match job {
                    Job::GemmTile { tile, reply, .. } => {
                        let _ = reply.send(TileResult {
                            tile,
                            planes: Err(anyhow::anyhow!("CU{cu} runtime unavailable")),
                        });
                    }
                    Job::Stream { offset, reply, .. } => {
                        let _ = reply.send(StreamResult {
                            offset,
                            planes: Err(anyhow::anyhow!("CU{cu} runtime unavailable")),
                        });
                    }
                    Job::Shutdown => break,
                }
            }
            return;
        }
    };

    for job in rx {
        match job {
            Job::Shutdown => break,
            Job::GemmTile { artifact, a, b, c, tile, part, reply } => {
                let planes = run_tile(&rt, &artifact, &a, &b, &c, tile, &part, &metrics);
                let _ = reply.send(TileResult { tile, planes });
            }
            Job::Stream { artifact, kind, operands, offset, reply } => {
                let t0 = Instant::now();
                let planes = match kind {
                    StreamKind::Binop => {
                        rt.exec_stream_binop(&artifact, &operands[0], &operands[1])
                    }
                    StreamKind::Mac => {
                        rt.exec_stream_mac(&artifact, &operands[0], &operands[1], &operands[2])
                    }
                };
                metrics.add_exec_ns(t0.elapsed().as_nanos() as u64);
                metrics.add_calls(1);
                let _ = reply.send(StreamResult { offset, planes });
            }
        }
    }
}

/// Execute one output tile: sequential K accumulation through the artifact
/// (the §III dataflow; the C tile stays "on chip" between K steps).
fn run_tile(
    rt: &Runtime,
    artifact: &str,
    a: &Matrix,
    b: &Matrix,
    c: &Matrix,
    tile: Tile,
    part: &Partition,
    metrics: &Metrics,
) -> Result<PlaneBatch> {
    let (tn, tm, kt) = (part.tile_n, part.tile_m, part.k_tile);
    let t_marshal = Instant::now();
    let mut c_tile = c.extract_tile(tile.r0, tile.c0, tn, tm);
    metrics.add_marshal_ns(t_marshal.elapsed().as_nanos() as u64);

    for step in 0..part.k_steps() {
        let k0 = step * kt;
        let tm_marshal = Instant::now();
        let a_tile = a.extract_tile(tile.r0, k0, tn, kt);
        let b_tile = b.extract_tile(k0, tile.c0, kt, tm);
        metrics.add_marshal_ns(tm_marshal.elapsed().as_nanos() as u64);

        let t_exec = Instant::now();
        c_tile = rt.exec_gemm_tile(artifact, &a_tile, &b_tile, &c_tile)?;
        metrics.add_exec_ns(t_exec.elapsed().as_nanos() as u64);
        metrics.add_calls(1);
        metrics.add_macs((tn * tm * kt) as u64);
    }
    metrics.add_tiles(1);
    Ok(c_tile)
}
