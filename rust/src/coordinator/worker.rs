//! Compute-unit worker threads.
//!
//! Each worker models one replicated compute unit: it owns a private
//! [`Runtime`] on the device's configured backend and tile geometry (its
//! own compiled "circuit"), pulls jobs from a bounded queue (backpressure
//! toward the leader), executes them through the artifacts, and reports
//! results on a reply channel.  GEMM operands arrive as `Arc`s of
//! device-resident [`DeviceBuf`]s — A and C are read out of their shared
//! panels into per-worker staging buffers kept warm across K steps *and*
//! across jobs, while B tiles come **pre-packed** from the buffer's shared
//! tile grid (cut once by the stream, read by every CU).  The C staging
//! buffer cycles leader -> worker -> leader through the stream's pool, so
//! a steady-state tile job touches the allocator not at all.
//!
//! Discipline, which the stream's hazard tracking depends on:
//!
//! * a worker drops every shared-buffer `Arc` *before* sending its reply —
//!   the stream counts replies per launch to know when it has regained
//!   exclusive access to a launch's panels (`Arc::get_mut`) for writeback;
//! * **every** submitted job produces exactly one reply, error or not:
//!   panics are caught and converted, a worker whose runtime never came up
//!   stays alive as a reply-only drain, and the pooled C staging buffer
//!   rides home inside the reply even when the tile failed (an errored
//!   tile must not shrink the leader's pool).
//!
//! [`crate::config::FaultSpec`] injects failures at exactly these seams
//! (runtime init, a chosen tile, panic vs error) so the failure paths stay
//! under test (`tests/stream_faults.rs`).

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use super::metrics::Metrics;
use super::scheduler::{Partition, Tile};
use super::stream::DeviceBuf;
use crate::config::FaultSpec;
use crate::pack::PlaneBatch;
use crate::runtime::{BackendKind, Runtime, TileShape};

/// Depth of each worker's job queue: small, so a slow CU exerts
/// backpressure on the leader instead of buffering unbounded work.
pub const QUEUE_DEPTH: usize = 4;

pub enum Job {
    /// One full output tile: accumulate C_tile over all K steps.
    GemmTile {
        /// Stream-local id of the launch this tile belongs to; echoed in
        /// the reply so mis-routed results are detectable.
        launch: u64,
        artifact: Arc<str>,
        /// A: n x k, read from the shared panel.
        a: Arc<DeviceBuf>,
        /// B: k x m, read from the shared pre-packed tile grid.
        b: Arc<DeviceBuf>,
        /// C input values: n x m, read from the shared panel (the leader
        /// writes results back only after the launch fully drains).
        c: Arc<DeviceBuf>,
        /// Pooled staging buffer the C tile is accumulated in; returned to
        /// the leader inside [`TileResult`] on success *and* failure.
        c_buf: PlaneBatch,
        tile: Tile,
        part: Partition,
        reply: SyncSender<TileResult>,
    },
    /// A chunk of a stream operator (Tab. I/II microbenchmark path).
    Stream {
        artifact: String,
        kind: StreamKind,
        operands: Vec<PlaneBatch>,
        offset: usize,
        reply: Sender<StreamResult>,
    },
    Shutdown,
}

#[derive(Clone, Copy, Debug)]
pub enum StreamKind {
    Binop,
    Mac,
}

pub struct TileResult {
    /// Launch id echoed from the job.
    pub launch: u64,
    pub tile: Tile,
    /// The pooled C staging buffer, always returned to the leader.  On
    /// success it holds the accumulated C tile; when `err` is set its
    /// contents are unspecified (the leader recycles it without reading).
    pub c_buf: PlaneBatch,
    /// `None` on success; the tile's failure otherwise.
    pub err: Option<anyhow::Error>,
}

pub struct StreamResult {
    pub offset: usize,
    pub planes: Result<PlaneBatch>,
}

pub struct WorkerHandle {
    pub cu: usize,
    sender: SyncSender<Job>,
    thread: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Spawn the worker; it creates its own Runtime on its own thread (no
    /// backend client is Send — PJRT is `Rc`-based and the native arena is
    /// private).  `tile` shapes the worker's builtin manifest so its
    /// artifact names and geometry match the leader's partition exactly;
    /// `faults` is the test-only failure injection (no faults in
    /// production configs).
    pub fn spawn(
        cu: usize,
        artifact_dir: std::path::PathBuf,
        backend: BackendKind,
        tile: TileShape,
        faults: FaultSpec,
        metrics: Arc<Metrics>,
    ) -> std::io::Result<Self> {
        let (tx, rx) = sync_channel::<Job>(QUEUE_DEPTH);
        let thread = std::thread::Builder::new()
            .name(format!("apfp-cu{cu}"))
            .spawn(move || worker_main(cu, &artifact_dir, backend, tile, faults, rx, metrics))?;
        Ok(WorkerHandle { cu, sender: tx, thread: Some(thread) })
    }

    /// Enqueue a job (blocks when the queue is full — backpressure).
    /// Returns the job back when the worker thread is gone, so the caller
    /// can reclaim pooled buffers and surface a typed error instead of
    /// panicking.
    pub fn submit(&self, job: Job) -> std::result::Result<(), Job> {
        self.sender.send(job).map_err(|e| e.0)
    }

    /// Has this worker's thread exited?  A live worker replies to every
    /// submitted job, so a reply that never comes implies a finished
    /// thread — the stream's drain loop probes this (only when a reply is
    /// overdue) to turn a would-be hang into a typed error.
    pub fn is_finished(&self) -> bool {
        self.thread.as_ref().is_none_or(|t| t.is_finished())
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.sender.send(Job::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Per-worker A-tile staging, reused across K steps and across jobs.
#[derive(Default)]
struct TileBufs {
    a: PlaneBatch,
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

fn worker_main(
    cu: usize,
    dir: &std::path::Path,
    backend: BackendKind,
    tile: TileShape,
    faults: FaultSpec,
    rx: Receiver<Job>,
    metrics: Arc<Metrics>,
) {
    let rt = if faults.init_fail_cu == Some(cu) {
        Err(anyhow::anyhow!("injected runtime init failure on CU{cu}"))
    } else {
        Runtime::with_backend_tiled(dir, backend, tile)
    };
    let rt = match rt {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("CU{cu}: runtime init failed: {e:#}");
            let reason = format!("CU{cu} runtime unavailable: {e:#}");
            // Drain jobs, reporting the failure to every reply channel.
            // (Destructuring with `..` drops the shared-buffer Arcs before
            // the send, same as the healthy path.)  The staging buffer
            // still rides home so the leader's pool survives a dead CU.
            for job in rx {
                match job {
                    Job::GemmTile { launch, tile, c_buf, reply, .. } => {
                        let _ = reply.send(TileResult {
                            launch,
                            tile,
                            c_buf,
                            err: Some(anyhow::anyhow!("{reason}")),
                        });
                    }
                    Job::Stream { offset, reply, .. } => {
                        let _ = reply.send(StreamResult {
                            offset,
                            planes: Err(anyhow::anyhow!("{reason}")),
                        });
                    }
                    Job::Shutdown => break,
                }
            }
            return;
        }
    };

    let mut bufs = TileBufs::default();
    for job in rx {
        match job {
            Job::Shutdown => break,
            Job::GemmTile { launch, artifact, a, b, c, mut c_buf, tile, part, reply } => {
                if faults.die_on_tile == Some((tile.r0, tile.c0)) {
                    // Injected CU crash: the thread exits without replying
                    // or draining its queue.  The stream's liveness probe
                    // must turn this into a typed ReplyLost, never a hang.
                    return;
                }
                // A panic inside the tile (an assert anywhere in the
                // pack/softfloat stack) must become an error *reply*: the
                // leader counts replies per launch, and a job that dies
                // silently would hang its retirement forever.
                // catch_unwind costs nothing on the non-panicking path.
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if faults.fail_tile == Some((tile.r0, tile.c0)) {
                        if faults.panic_tile {
                            // apfp-lint: allow(panic, reason="FaultSpec failpoint: this injected panic is the fault under test, contained by the catch_unwind above")
                            panic!("injected panic on tile ({}, {})", tile.r0, tile.c0);
                        }
                        anyhow::bail!("injected failure on tile ({}, {})", tile.r0, tile.c0);
                    }
                    run_tile(
                        &rt, &artifact, &a, &b, &c, tile, &part, &metrics, &mut bufs, &mut c_buf,
                    )
                }));
                // Release the shared buffers before replying: the leader
                // reclaims exclusive panel access by counting replies.
                drop((a, b, c, artifact));
                let err = match res {
                    Ok(Ok(())) => None,
                    Ok(Err(e)) => Some(e),
                    Err(panic) => Some(anyhow::anyhow!(
                        "CU{cu} panicked executing tile: {}",
                        panic_message(&panic)
                    )),
                };
                let _ = reply.send(TileResult { launch, tile, c_buf, err });
            }
            Job::Stream { artifact, kind, operands, offset, reply } => {
                let t0 = Instant::now();
                // Same containment as the tile path: a panic must not kill
                // the worker, or jobs queued behind it die reply-less and
                // their collectors hang.
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    match (kind, operands.as_slice()) {
                        (StreamKind::Binop, [a, b]) => rt.exec_stream_binop(&artifact, a, b),
                        (StreamKind::Mac, [c, a, b]) => rt.exec_stream_mac(&artifact, c, a, b),
                        (kind, ops) => Err(anyhow::anyhow!(
                            "stream job shape mismatch: {kind:?} with {} operands",
                            ops.len()
                        )),
                    }
                }));
                let planes = match res {
                    Ok(r) => r,
                    Err(panic) => Err(anyhow::anyhow!(
                        "CU{cu} panicked executing stream chunk: {}",
                        panic_message(&panic)
                    )),
                };
                metrics.add_exec_ns(t0.elapsed().as_nanos() as u64);
                metrics.add_calls(1);
                let _ = reply.send(StreamResult { offset, planes });
            }
        }
    }
}

/// Execute one output tile: sequential K accumulation through the artifact
/// (the §III dataflow).  The C tile stays "on chip" between K steps in the
/// pooled `c_tile` staging buffer — the backend updates it in place — the
/// A staging buffer is reused across steps and jobs, and B tiles are read
/// straight from the shared pre-packed grid, so the per-step marshaling
/// cost is one plane-row copy out of the A panel.
// apfp-lint: no_alloc
#[allow(clippy::too_many_arguments)]
fn run_tile(
    rt: &Runtime,
    artifact: &str,
    a: &DeviceBuf,
    b: &DeviceBuf,
    c: &DeviceBuf,
    tile: Tile,
    part: &Partition,
    metrics: &Metrics,
    bufs: &mut TileBufs,
    c_tile: &mut PlaneBatch,
) -> Result<()> {
    let (tn, tm, kt) = (part.tile_n, part.tile_m, part.k_tile);
    let jt = tile.c0 / tm;
    let t_marshal = Instant::now();
    c.panel().extract_tile_into(tile.r0, tile.c0, tn, tm, c_tile);
    metrics.add_marshal_ns(t_marshal.elapsed().as_nanos() as u64);

    for step in 0..part.k_steps() {
        let k0 = step * kt;
        let tm_marshal = Instant::now();
        a.panel().extract_tile_into(tile.r0, k0, tn, kt, &mut bufs.a);
        let b_tile = b.b_tile(step, jt)?;
        metrics.add_marshal_ns(tm_marshal.elapsed().as_nanos() as u64);

        let t_exec = Instant::now();
        rt.exec_gemm_tile(artifact, &bufs.a, b_tile, c_tile)?;
        metrics.add_exec_ns(t_exec.elapsed().as_nanos() as u64);
        metrics.add_calls(1);
        // Count useful MAC lanes — the owned extent x the real K depth of
        // this step, summed over all tiles exactly n * m * k regardless of
        // tiling fit.  Padding lanes are excluded (the backend skips their
        // zero products); lanes whose *data* happens to be zero still
        // count, like any dense-GEMM FLOP figure.
        let k_eff = kt.min(part.k - k0);
        metrics.add_macs((tile.rows * tile.cols * k_eff) as u64);
    }
    metrics.add_tiles(1);
    Ok(())
}
