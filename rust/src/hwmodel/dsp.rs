//! DSP48E2 counting for the Karatsuba multiplier (§II-A).
//!
//! The recursion tree splits the `prec`-bit mantissa multiplication in
//! half per level (3 children each) until operands are at most
//! `mult_base_bits` wide, where a naive partial-product multiplier is
//! instantiated out of DSP48E2 slices.  The DSP48E2 multiplies 27x18-bit
//! signed operands; the paper drives it as an 18x18 integer multiplier, so
//! an unsigned w-bit naive multiplier tiles into ceil(w/17)^2 DSPs
//! (17 usable unsigned bits per port).
//!
//! Calibration check (tests in hwmodel::tests): 448-bit mantissa at the
//! 72-bit bottom-out gives 27 leaves x ceil(56/17)^2 = 27*16 = 432 DSPs =
//! 3.5% of the U250 — the paper's Tab. I reports 4% per CU.

/// Usable unsigned multiplier bits per DSP48E2 port in 18x18 mode.
pub const DSP_PORT_BITS: u32 = 17;

/// DSPs for a naive (partial-product array) w x w-bit multiplier.
pub fn naive_dsps(w: u32) -> u32 {
    let tiles = w.div_ceil(DSP_PORT_BITS);
    tiles * tiles
}

/// Karatsuba leaf geometry: (number of leaf multipliers, leaf width in bits).
///
/// Operand width halves per level (the sign-tracked |a1-a0| trick keeps
/// children at exactly half width); recursion stops at or below
/// `mult_base_bits`.
pub fn karatsuba_leaves(prec: u32, mult_base_bits: u32) -> (u32, u32) {
    let mut width = prec;
    let mut leaves = 1u32;
    while width > mult_base_bits {
        width = width.div_ceil(2);
        leaves *= 3;
    }
    (leaves, width)
}

/// Total DSP48E2s for one `prec`-bit Karatsuba multiplier.
pub fn multiplier_dsps(prec: u32, mult_base_bits: u32) -> u32 {
    let (leaves, width) = karatsuba_leaves(prec, mult_base_bits);
    leaves * naive_dsps(width)
}

/// Recursion depth (levels of decomposition).
pub fn karatsuba_depth(prec: u32, mult_base_bits: u32) -> u32 {
    let mut width = prec;
    let mut depth = 0;
    while width > mult_base_bits {
        width = width.div_ceil(2);
        depth += 1;
    }
    depth
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_tiles() {
        assert_eq!(naive_dsps(17), 1);
        assert_eq!(naive_dsps(18), 4);
        assert_eq!(naive_dsps(34), 4);
        assert_eq!(naive_dsps(56), 16);
        assert_eq!(naive_dsps(72), 25);
    }

    #[test]
    fn leaves_512() {
        // 448 -> 224 -> 112 -> 56 (<= 72): 3 levels, 27 leaves of 56 bits
        assert_eq!(karatsuba_leaves(448, 72), (27, 56));
        assert_eq!(karatsuba_depth(448, 72), 3);
        // bottom out at 36: one more level -> 81 leaves of 28 bits
        assert_eq!(karatsuba_leaves(448, 36), (81, 28));
        // huge base: no decomposition at all
        assert_eq!(karatsuba_leaves(448, 448), (1, 448));
    }

    #[test]
    fn dsp_counts_match_paper_scale() {
        // 512-bit numbers (448-bit mantissa), 72-bit bottom-out
        let d512 = multiplier_dsps(448, 72);
        assert_eq!(d512, 27 * 16); // 432 = 3.5% of 12288 (paper: "4%")
        // Karatsuba beats naive DSP count at full width
        assert!(d512 < naive_dsps(448));
        // 1024-bit (960-bit mantissa): 960->480->240->120->60, 81 leaves
        let d1024 = multiplier_dsps(960, 72);
        assert_eq!(d1024, 81 * naive_dsps(60));
        // each Karatsuba level costs 3 half-width multipliers (§V-D:
        // a 1024-bit unit "roughly corresponds" to three 512-bit ones)
        let ratio = d1024 as f64 / d512 as f64;
        assert!((2.0..4.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn smaller_base_fewer_or_equal_dsps() {
        // going one level deeper can only reduce DSPs (3 * (w/2 tiles)^2
        // <= (w tiles)^2 for w > 2 tiles) — the resource side of Fig. 3
        let d72 = multiplier_dsps(448, 72);
        let d36 = multiplier_dsps(448, 36);
        let d144 = multiplier_dsps(448, 144);
        assert!(d36 <= d72);
        assert!(d72 <= d144);
    }
}
