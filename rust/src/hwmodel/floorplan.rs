//! SLR / DDR-bank placement (the paper's Fig. 4 assignment scheme).
//!
//! Compute units are assigned to DDR banks round-robin starting at bank 1
//! (where the host-interface logic lives), then banks 0, 2, 3; each bank
//! maps onto the SLR it is attached to, so the first four CUs land on
//! distinct chiplets and replication wraps around.  Placement fails when a
//! chiplet's share of compute units no longer fits its usable area — the
//! constraint that caps the paper at 16 multiplier CUs / 8 GEMM CUs.

use super::{u250, DesignPoint};

/// Fig. 4 bank visit order.
pub const BANK_ORDER: [u32; 4] = [1, 0, 2, 3];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub cu: usize,
    pub ddr_bank: u32,
    pub slr: u32,
}

/// Round-robin CU -> (bank, SLR) assignment; SLR i hosts bank i.
pub fn assign(compute_units: usize) -> Vec<Placement> {
    (0..compute_units)
        .map(|cu| {
            let bank = BANK_ORDER[cu % BANK_ORDER.len()];
            Placement { cu, ddr_bank: bank, slr: bank }
        })
        .collect()
}

/// Check that a design point's CUs fit their SLRs; returns the placement.
pub fn place(d: &DesignPoint, cu_clbs: u32) -> Result<Vec<Placement>, String> {
    let placements = assign(d.compute_units);
    let slr_clbs = u250::CLB_TOTAL as f64 / u250::SLRS as f64 * u250::SLR_USABLE;
    for slr in 0..u250::SLRS {
        let on_slr = placements.iter().filter(|p| p.slr == slr).count();
        let mut used = on_slr as f64 * cu_clbs as f64;
        if slr <= 1 {
            // the shell occupies part of SLR0/SLR1 on the xdma shell
            used += super::resources::SHELL_CLBS as f64 / 2.0;
        }
        if used > slr_clbs {
            return Err(format!(
                "SLR{slr} over capacity: {on_slr} CUs x {cu_clbs} CLBs (+shell) \
                 > {:.0} usable CLBs",
                slr_clbs
            ));
        }
    }
    Ok(placements)
}

/// CUs per DDR bank (for the DRAM bandwidth-sharing model in `sim`).
pub fn cus_per_bank(compute_units: usize) -> [usize; 4] {
    let mut counts = [0usize; 4];
    for p in assign(compute_units) {
        counts[p.ddr_bank as usize] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 4: first 8 CUs -> banks 1,0,2,3,1,0,2,3.
    #[test]
    fn fig4_assignment() {
        let p = assign(8);
        let banks: Vec<u32> = p.iter().map(|x| x.ddr_bank).collect();
        assert_eq!(banks, vec![1, 0, 2, 3, 1, 0, 2, 3]);
        // each CU stays within the chiplet of its bank
        assert!(p.iter().all(|x| x.slr == x.ddr_bank));
    }

    #[test]
    fn first_four_on_distinct_slrs() {
        let p = assign(4);
        let mut slrs: Vec<u32> = p.iter().map(|x| x.slr).collect();
        slrs.sort();
        assert_eq!(slrs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn bank_sharing_counts() {
        assert_eq!(cus_per_bank(1), [0, 1, 0, 0]);
        assert_eq!(cus_per_bank(4), [1, 1, 1, 1]);
        assert_eq!(cus_per_bank(16), [4, 4, 4, 4]);
        assert_eq!(cus_per_bank(6), [2, 2, 1, 1]); // order 1,0,2,3,1,0
    }

    #[test]
    fn capacity_rejects_oversized() {
        // 4x-per-SLR of a ~4% CU fits; a ~25%-of-device CU does not at 8x
        let d = crate::hwmodel::DesignPoint::mult_512(16);
        assert!(place(&d, 8_000).is_ok());
        let d8 = crate::hwmodel::DesignPoint::gemm_1024(8);
        assert!(place(&d8, 40_000).is_err());
    }
}
