//! Achievable clock frequency model (the Fig. 3 annotations + the frequency
//! columns of Tab. I–III).
//!
//! Critical path = base routing/logic delay
//!               + wide-adder carry chain (grows with `add_base_bits`)
//!               + naive-leaf DSP cascade (grows with `mult_base_bits`)
//!               + datapath width term
//!               + (GEMM only) tile accumulation feedback path.
//!
//! Replication degrades routing (SLR crossings, congestion): the divisor
//! grows with (CUs - 1) x per-CU area.  Congestion alone cannot push a
//! design below the ~293 MHz the shell's kernel clock reliably closes at —
//! the paper's many-CU designs all land at 293–300 MHz — but a long
//! *pipeline* critical path can (the monolithic 1024-bit GEMM unit closes
//! at 212 MHz, §V-D).

use super::DesignPoint;

/// Naive multipliers wider than this fail synthesis outright (Fig. 3: the
/// 288-bit fallback "fails synthesis altogether").
pub const MAX_SYNTH_MULT_BASE: u32 = 256;

/// Device pipeline ceiling (DSP48E2 fmax region on the U250 -2 speed grade).
pub const F_CEILING_MHZ: f64 = 500.0;

/// Congestion floor: the slowest kernel clock the shell quantizes to.
pub const F_FLOOR_MHZ: f64 = 293.0;

/// ns per bit of combinational carry chain in one adder stage.
const T_CARRY_PER_BIT: f64 = 0.004;
/// ns per bit of naive-leaf multiplier width (DSP cascade + PP gather).
const T_LEAF_PER_BIT: f64 = 0.012;
/// ns per mantissa bit of general datapath fan-out.
const T_WIDTH_PER_BIT: f64 = 0.001;
/// ns per mantissa bit of GEMM tile accumulate/writeback feedback.
const T_GEMM_PER_BIT: f64 = 0.00195;
/// fixed routing + logic (ns).
const T_BASE: f64 = 0.62;
/// congestion sensitivity: delay grows with neighbours' area.
const CONGESTION: f64 = 1.5;

/// Pipeline-limited frequency of a single compute unit.
pub fn pipeline_mhz(d: &DesignPoint) -> f64 {
    let prec = d.prec() as f64;
    let mut t = T_BASE
        + T_WIDTH_PER_BIT * prec
        + T_CARRY_PER_BIT * d.add_base_bits as f64
        + T_LEAF_PER_BIT * d.mult_base_bits as f64;
    if d.gemm {
        t += T_GEMM_PER_BIT * prec;
    }
    (1000.0 / t).min(F_CEILING_MHZ)
}

/// Post-placement frequency including replication congestion.
pub fn achievable_mhz(d: &DesignPoint, _total_clb_frac: f64) -> f64 {
    let f_base = pipeline_mhz(d);
    let cu_frac = super::resources::cu_clbs(d) as f64 / super::u250::CLB_TOTAL as f64;
    let congestion = 1.0 + CONGESTION * (d.compute_units as f64 - 1.0) * cu_frac;
    let f_cong = f_base / congestion;
    // congestion saturates at the shell floor; a slow pipeline does not
    f_cong.max(F_FLOOR_MHZ.min(f_base))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwmodel::DesignPoint;

    /// Tab. I frequency column: 456 / 376 / 300 / 300 / 300 MHz.
    #[test]
    fn tab1_frequencies() {
        let f = |cus| achievable_mhz(&DesignPoint::mult_512(cus), 0.0);
        assert!((f(1) - 456.0).abs() < 20.0, "1 CU: {:.0}", f(1));
        assert!((f(4) - 376.0).abs() < 35.0, "4 CUs: {:.0}", f(4));
        for cus in [8, 12, 16] {
            assert!((f(cus) - 300.0).abs() < 40.0, "{cus} CUs: {:.0}", f(cus));
        }
        assert!(f(1) > f(4) && f(4) >= f(8));
    }

    /// Tab. II: 361 MHz @ 1 CU, 293 MHz @ 4 CUs (1024-bit).
    #[test]
    fn tab2_frequencies() {
        let f1 = achievable_mhz(&DesignPoint::mult_1024(1), 0.0);
        let f4 = achievable_mhz(&DesignPoint::mult_1024(4), 0.0);
        assert!((f1 - 361.0).abs() < 25.0, "1 CU: {f1:.0}");
        assert!((f4 - 293.0).abs() < 20.0, "4 CUs: {f4:.0}");
    }

    /// Tab. III: GEMM 512 closes at 327 (1 CU) down to ~278-293.
    #[test]
    fn tab3_gemm_frequencies() {
        let f1 = achievable_mhz(&DesignPoint::gemm_512(1), 0.0);
        assert!((f1 - 327.0).abs() < 15.0, "1 CU: {f1:.0}");
        for cus in [2, 4, 8] {
            let f = achievable_mhz(&DesignPoint::gemm_512(cus), 0.0);
            assert!((f - 285.0).abs() < 25.0, "{cus} CUs: {f:.0}");
        }
    }

    /// §V-D: the monolithic 1024-bit GEMM unit is downclocked to ~212 MHz.
    #[test]
    fn gemm_1024_downclock() {
        let f = achievable_mhz(&DesignPoint::gemm_1024(1), 0.0);
        assert!((f - 212.0).abs() < 20.0, "got {f:.0}");
    }

    /// Fig. 3 shape: 36-bit bottom-out clocks fastest, 144 hampers, wide
    /// adder stages degrade frequency.
    #[test]
    fn fig3_frequency_shape() {
        let f = |mult, add| {
            pipeline_mhz(&DesignPoint {
                bits: 512,
                compute_units: 1,
                mult_base_bits: mult,
                add_base_bits: add,
                gemm: false,
            })
        };
        assert!(f(36, 64) > f(72, 64));
        assert!(f(72, 64) > f(144, 64));
        assert!(f(144, 64) < 360.0); // "significantly hampers"
        assert!(f(72, 64) > f(72, 512));
        assert!(f(72, 512) > f(72, 1024));
    }
}
