//! Analytic model of the Alveo U250 implementation — the substitute for
//! Vitis/Vivado synthesis (DESIGN.md §1).
//!
//! The model predicts, for a given configuration (`APFP_BITS`,
//! `APFP_MULT_BASE_BITS`, `APFP_ADD_BASE_BITS`, compute units):
//!
//! * DSP48E2 usage — exact combinatorics of the Karatsuba recursion tree
//!   ([`dsp`]);
//! * CLB usage — recombination adders, pipeline registers, stream logic
//!   ([`resources`]);
//! * achievable frequency — carry-chain, DSP-cascade and congestion limits
//!   ([`frequency`]);
//! * placement — the Fig. 4 SLR / DDR-bank round-robin ([`floorplan`]).
//!
//! Constants are calibrated against the paper's reported design points
//! (Fig. 3, Tab. I–III); unit tests assert that the calibration reproduces
//! them.  The goal is the *shape* of the design space — which
//! configurations are Pareto-optimal, where synthesis fails, how frequency
//! degrades — from the physical causes the paper names, not a lookup table
//! of the paper's numbers.

pub mod dsp;
pub mod floorplan;
pub mod frequency;
pub mod resources;

/// Alveo U250 device constants (Xilinx DS962 / UG1120).
pub mod u250 {
    /// DSP48E2 slices on the device.
    pub const DSP_TOTAL: u32 = 12_288;
    /// Configurable logic blocks (8 LUT6 + 16 FF each).
    pub const CLB_TOTAL: u32 = 216_000;
    /// Super Logical Regions (chiplets).
    pub const SLRS: u32 = 4;
    /// DDR4 memory banks (one per SLR on the evaluated shell).
    pub const DDR_BANKS: u32 = 4;
    /// Peak bandwidth per DDR4 bank, bytes/s (§V: 19.2 GB/s).
    pub const DDR_BANK_BW: f64 = 19.2e9;
    /// Usable fraction of an SLR for user kernels (the shell occupies part
    /// of SLR0/SLR1 on the xdma shell).
    pub const SLR_USABLE: f64 = 0.92;
}

/// One evaluated hardware design point.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub bits: u32,
    pub compute_units: usize,
    pub mult_base_bits: u32,
    pub add_base_bits: u32,
    /// true for the GEMM accelerator (adds tile buffers + adder), false for
    /// the bare multiplier microbenchmark kernel.
    pub gemm: bool,
}

/// Synthesis outcome for a design point.
#[derive(Clone, Debug)]
pub struct Synthesis {
    pub dsps: u32,
    pub dsp_frac: f64,
    pub clbs: u32,
    pub clb_frac: f64,
    pub frequency_mhz: f64,
    /// None = fits; Some(reason) = synthesis/implementation fails, like the
    /// paper's 288-bit naive-multiplication configuration.
    pub failure: Option<String>,
}

impl DesignPoint {
    pub fn mult_512(cus: usize) -> Self {
        DesignPoint { bits: 512, compute_units: cus, mult_base_bits: 72, add_base_bits: 64, gemm: false }
    }

    pub fn mult_1024(cus: usize) -> Self {
        DesignPoint { bits: 1024, compute_units: cus, mult_base_bits: 72, add_base_bits: 64, gemm: false }
    }

    pub fn gemm_512(cus: usize) -> Self {
        DesignPoint { bits: 512, compute_units: cus, mult_base_bits: 72, add_base_bits: 64, gemm: true }
    }

    pub fn gemm_1024(cus: usize) -> Self {
        DesignPoint { bits: 1024, compute_units: cus, mult_base_bits: 72, add_base_bits: 64, gemm: true }
    }

    /// Mantissa bits (Fig. 1).
    pub fn prec(&self) -> u32 {
        self.bits - 64
    }

    /// Run the analytic "synthesis".
    pub fn synthesize(&self) -> Synthesis {
        let dsps_per_cu = dsp::multiplier_dsps(self.prec(), self.mult_base_bits);
        let dsps = dsps_per_cu * self.compute_units as u32;
        let clb_cu = resources::cu_clbs(self);
        let multi = if self.compute_units > 1 { resources::MULTI_CU_CLBS } else { 0 };
        let clbs = resources::SHELL_CLBS + multi + clb_cu * self.compute_units as u32;
        let clb_frac = clbs as f64 / u250::CLB_TOTAL as f64;
        let dsp_frac = dsps as f64 / u250::DSP_TOTAL as f64;

        let mut failure = None;
        if self.mult_base_bits > frequency::MAX_SYNTH_MULT_BASE {
            failure = Some(format!(
                "naive {}x{}-bit multiplier exceeds routable DSP cascade depth \
                 (paper Fig. 3: 288-bit fails synthesis)",
                self.mult_base_bits, self.mult_base_bits
            ));
        }
        match floorplan::place(self, clb_cu) {
            Ok(_) => {}
            Err(e) => failure = failure.or(Some(e)),
        }
        if clb_frac > 0.88 {
            failure = failure.or(Some(format!(
                "CLB utilization {:.1}% exceeds routable density",
                clb_frac * 100.0
            )));
        }

        Synthesis {
            dsps,
            dsp_frac,
            clbs,
            clb_frac,
            frequency_mhz: frequency::achievable_mhz(self, clb_frac),
            failure,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tab. I resource columns: 512-bit multiplier, CLB/DSP percentages.
    #[test]
    fn tab1_resource_calibration() {
        // (CUs, paper CLB %, paper DSP %)
        for (cus, clb, dsp) in [(1, 16.0, 4.0), (4, 37.0, 14.0), (8, 48.0, 28.0), (12, 62.0, 42.0), (16, 75.0, 56.0)] {
            let s = DesignPoint::mult_512(cus).synthesize();
            assert!(s.failure.is_none(), "CUs={cus}: {:?}", s.failure);
            let clb_got = s.clb_frac * 100.0;
            let dsp_got = s.dsp_frac * 100.0;
            assert!((clb_got - clb).abs() < 8.0, "CLB CUs={cus}: got {clb_got:.1}%, paper {clb}%");
            assert!((dsp_got - dsp).abs() < 2.0, "DSP CUs={cus}: got {dsp_got:.1}%, paper {dsp}%");
        }
    }

    /// Tab. II: 1024-bit multiplier DSP usage.
    #[test]
    fn tab2_resource_calibration() {
        let s1 = DesignPoint::mult_1024(1).synthesize();
        assert!((s1.dsp_frac * 100.0 - 8.0).abs() < 3.5, "got {:.1}%", s1.dsp_frac * 100.0);
        let s4 = DesignPoint::mult_1024(4).synthesize();
        assert!(s4.failure.is_none());
        assert!(s4.dsp_frac > 3.0 * s1.dsp_frac);
    }

    /// Tab. III: GEMM designs use more CLB per CU than the bare multiplier.
    #[test]
    fn tab3_gemm_overhead() {
        let m = DesignPoint::mult_512(1).synthesize();
        let g = DesignPoint::gemm_512(1).synthesize();
        assert!(g.clbs > m.clbs);
        let got = g.clb_frac * 100.0;
        assert!((got - 18.9).abs() < 6.0, "paper 18.9%, got {got:.1}%");
    }

    /// Frequency degrades with replication (Tab. I: 456 -> 300 MHz).
    #[test]
    fn frequency_degrades_with_cus() {
        let f1 = DesignPoint::mult_512(1).synthesize().frequency_mhz;
        let f16 = DesignPoint::mult_512(16).synthesize().frequency_mhz;
        assert!(f1 > 400.0, "1 CU should clock > 400 MHz, got {f1:.0}");
        assert!(f16 < 330.0, "16 CUs congested, got {f16:.0}");
        assert!(f1 > f16);
    }

    /// Fig. 3: 288-bit naive fallback fails synthesis.
    #[test]
    fn mult_base_288_fails() {
        let mut d = DesignPoint::mult_512(1);
        d.mult_base_bits = 288;
        assert!(d.synthesize().failure.is_some());
    }

    /// 17 CUs of the 512-bit multiplier exceed the device (paper stops at 16).
    #[test]
    fn replication_limit() {
        assert!(DesignPoint::mult_512(16).synthesize().failure.is_none());
        assert!(DesignPoint::mult_512(24).synthesize().failure.is_some());
    }

    /// A single 1024-bit GEMM CU occupies nearly a full SLR (§V-D).
    #[test]
    fn gemm_1024_nearly_fills_slr() {
        let s = DesignPoint::gemm_1024(1).synthesize();
        assert!(s.failure.is_none());
        let got = s.clb_frac * 100.0;
        assert!((got - 29.8).abs() < 9.0, "paper 29.8%, got {got:.1}%");
    }
}
