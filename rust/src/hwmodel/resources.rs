//! CLB estimation for multiplier / GEMM compute units.
//!
//! The dominant fabric consumers in the paper's design are (a) the
//! recombination adders of the Karatsuba tree, (b) the partial-product
//! accumulation of the naive leaf multipliers, and (c) pipeline registers.
//! We count LUT-equivalents for (a) and (b) from the recursion geometry and
//! convert to CLBs (8 LUT6 + 16 FF per CLB) with a routable packing factor;
//! calibration constants are fixed against Tab. I/II/III (see
//! hwmodel::tests) and the scaling between 512- and 1024-bit units follows
//! the paper's own observation that one Karatsuba level costs ~3x (§V-D).

use super::DesignPoint;

/// Static infrastructure: XDMA shell + host interface (~10% of the U250).
pub const SHELL_CLBS: u32 = 21_600;

/// One-time cost of the multi-CU interconnect / bank crossbar (the paper
/// places host logic at bank 1 and fans out round-robin, Fig. 4).
pub const MULTI_CU_CLBS: u32 = 12_960;

/// Per-CU fixed logic: operand stream FIFOs, control FSM (~0.5%).
const FIXED_CU_CLBS: u32 = 1_080;

/// LUTs -> CLBs: 8 LUTs + 16 FFs per CLB, 2 pipeline FFs per datapath LUT,
/// 55% routable packing density.
fn luts_to_clbs(luts: u64) -> u32 {
    let clb = (luts as f64 / 8.0 + 2.0 * luts as f64 / 16.0) / 0.55;
    clb.round() as u32
}

/// LUT-equivalents of the Karatsuba recombination adder tree: each node of
/// width w needs ~6w bits of addition (two c1-input adds + the shifted
/// recombination, §II-A).
pub fn recombination_luts(prec: u32, mult_base_bits: u32) -> u64 {
    let mut total: u64 = 0;
    let mut width = prec;
    let mut nodes: u64 = 1;
    while width > mult_base_bits {
        total += nodes * 6 * width as u64;
        width = width.div_ceil(2);
        nodes *= 3;
    }
    total
}

/// LUT-equivalents of the naive leaf multipliers' partial-product
/// accumulation that does not fit in the DSP cascade (~tiles * w / 2 each).
pub fn leaf_luts(prec: u32, mult_base_bits: u32) -> u64 {
    let (leaves, w) = super::dsp::karatsuba_leaves(prec, mult_base_bits);
    let tiles = w.div_ceil(super::dsp::DSP_PORT_BITS) as u64;
    leaves as u64 * tiles * (w as u64 / 2)
}

/// Total datapath LUTs of one bare multiplier.
pub fn multiplier_luts(prec: u32, mult_base_bits: u32) -> u64 {
    recombination_luts(prec, mult_base_bits) + leaf_luts(prec, mult_base_bits)
}

/// CLBs of one compute unit (bare multiplier, or GEMM unit with its tile
/// buffers, adder pipeline and writeback logic).
pub fn cu_clbs(d: &DesignPoint) -> u32 {
    let mut clbs = FIXED_CU_CLBS + luts_to_clbs(multiplier_luts(d.prec(), d.mult_base_bits));
    if d.gemm {
        // floating-point adder + tile accumulation storage control: scales
        // linearly with width (the tile itself lives in BRAM/URAM)
        clbs += 12 * d.prec();
    }
    clbs
}

/// Fig. 3 resource metric: CLBs of a *single multiplier only* (no shell),
/// including the pipeline-register sensitivity to `add_base_bits` (smaller
/// chunks => more stages => more registers).
pub fn fig3_multiplier_clbs(prec: u32, mult_base_bits: u32, add_base_bits: u32) -> u32 {
    let luts = multiplier_luts(prec, mult_base_bits) as f64;
    let stages = (2 * prec).div_ceil(add_base_bits) as f64;
    let ffs = luts * (1.0 + 0.25 * stages);
    ((luts / 8.0 + ffs / 16.0) / 0.55).round() as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwmodel::DesignPoint;

    #[test]
    fn cu_clbs_calibration() {
        // ~3.8% of 216k CLBs per 512-bit multiplier CU (Tab. I slope)
        let c512 = cu_clbs(&DesignPoint::mult_512(1)) as f64 / 216_000.0;
        assert!((0.030..0.048).contains(&c512), "512 CU frac = {c512:.3}");
        // 1024-bit CU ~3x (one extra Karatsuba level, §V-D)
        let c1024 = cu_clbs(&DesignPoint::mult_1024(1)) as f64 / 216_000.0;
        let ratio = c1024 / c512;
        assert!((2.5..4.0).contains(&ratio), "ratio = {ratio:.2}");
    }

    #[test]
    fn gemm_adds_tile_logic() {
        let m = cu_clbs(&DesignPoint::mult_512(1));
        let g = cu_clbs(&DesignPoint::gemm_512(1));
        assert!(g > m + 3000, "tile buffers/adder must cost CLBs: {m} -> {g}");
    }

    #[test]
    fn fig3_resource_ordering() {
        // resources shrink as adder stages get wider (fewer registers)...
        let narrow = fig3_multiplier_clbs(448, 72, 32);
        let mid = fig3_multiplier_clbs(448, 72, 64);
        let wide = fig3_multiplier_clbs(448, 72, 256);
        assert!(narrow > mid && mid > wide);
        // ...and the 36-bit bottom-out costs more fabric than 72 (Fig. 3:
        // "consistently high frequencies, but higher resource usage")
        let b36 = fig3_multiplier_clbs(448, 36, 64);
        let b72 = fig3_multiplier_clbs(448, 72, 64);
        assert!(b36 > b72, "36-bit {b36} should exceed 72-bit {b72}");
    }

    #[test]
    fn recombination_grows_with_depth() {
        assert!(recombination_luts(448, 36) > recombination_luts(448, 72));
        assert!(recombination_luts(448, 72) > recombination_luts(448, 144));
        assert_eq!(recombination_luts(448, 448), 0); // pure naive: no tree
    }
}
