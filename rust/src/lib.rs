//! `apfp` — the CPU side of a three-layer reproduction of *Fast Arbitrary
//! Precision Floating Point on FPGA* (cs.DC 2022).
//!
//! The crate is organized bottom-up, mirroring the paper's hardware stack
//! (see `docs/ARCHITECTURE.md` at the repository root for the full tour
//! with dataflow diagrams):
//!
//! * [`bigint`] — limb arithmetic with a reusable [`bigint::Scratch`]
//!   arena: Comba/Karatsuba/Toom-3 multiplication, shifts, division;
//! * [`softfloat`] — the paper's RNDZ arbitrary-precision float
//!   ([`softfloat::ApFloat`]) with allocation-free `mul`/`add`/`mac`
//!   pipelines, the MPFR-class reference every backend is bit-compared to;
//! * [`pack`] — the Fig. 1 word format and the limb-plane layout
//!   ([`pack::PlaneBatch`] / [`pack::PlanePanel`]) operands travel in;
//! * [`baseline`] / [`blas`] / [`linalg`] — host-side GEMM kernels and the
//!   §IV-B BLAS-style interfaces built on them;
//! * [`runtime`] — artifact manifests and the pluggable execution
//!   [`runtime::Backend`] (in-process [`runtime::NativeBackend`] by
//!   default, the XLA/PJRT artifact path behind `APFP_BACKEND=xla`, and
//!   the bit-identical hardware-model backend [`runtime::SimBackend`]
//!   behind `APFP_BACKEND=sim`, which feeds the
//!   [`coordinator::ModelMetrics`] cycle/traffic/energy ledger);
//! * [`coordinator`] — the virtual device: compute-unit workers, the §III
//!   band/tile scheduler, the CUDA-like [`coordinator::Device`], and the
//!   batched [`coordinator::DeviceStream`] launch API with hazard-tracked
//!   pipelining of independent launches, self-healing failure recovery
//!   (tile retry, supervised CU respawn, degraded-mode scheduling around
//!   quarantined units), and typed [`coordinator::StreamError`] failure
//!   paths;
//! * [`hwmodel`] / [`sim`] — the analytic U250 model that regenerates the
//!   paper's tables and figures;
//! * [`config`] / [`bench_util`] / [`testkit`] — configuration, the
//!   offline bench harness, and the property-testing kit.
//!
//! # Environment variables
//!
//! Every runtime knob the crate reads from the environment:
//!
//! | variable | effect | default |
//! |----------|--------|---------|
//! | `APFP_BACKEND` | Execution backend: `native`, `sim`/`simulator` (bit-identical to native plus the hardware-model ledger), or `xla`/`pjrt` ([`runtime::BackendKind::from_env`]) | `native` |
//! | `APFP_ARTIFACTS` | Artifact directory ([`runtime::default_artifact_dir`]) | `artifacts` |
//! | `APFP_TILE_N` | Builtin GEMM tile rows (long form `APFP_TILE_SIZE_N`; [`runtime::TileShape::from_env`]) | `32` |
//! | `APFP_TILE_M` | Builtin GEMM tile columns (long form `APFP_TILE_SIZE_M`) | `32` |
//! | `APFP_TILE_K` | Builtin GEMM K-step depth (long form `APFP_TILE_SIZE_K`) | `32` |
//! | `APFP_WIDTHS` | Comma list of packed widths (bits, ×64, ≥128) the device loads GEMM kernels for ([`config::ApfpConfig::widths`]); the launch-default `bits` is appended when absent, and a malformed list falls back to the full default set | `128,512,1024` |
//! | `APFP_KARATSUBA_THRESHOLD` | Karatsuba bottom-out in limbs ([`bigint`]) | `40` |
//! | `APFP_FIXED_PATH` | Escape hatch: `0`/`false`/`off` makes [`runtime::NativeBackend`] skip the const-generic fixed-width lane and run every width through the dynamic arena kernels | enabled |
//! | `APFP_REPLY_TIMEOUT_MS` | Overdue-reply probe interval of the stream drain ([`config::ApfpConfig::reply_timeout`]) | `250` |
//! | `APFP_RETRY_LIMIT` | Tile redispatches after a failed attempt ([`config::RetryPolicy`]) | `2` |
//! | `APFP_RETRY_BACKOFF_MS` | Base retry backoff, doubled per attempt and capped ([`config::RetryPolicy`]) | `1` |
//! | `APFP_RESPAWN_LIMIT` | CU respawns before quarantine ([`config::RetryPolicy`]) | `1` |
//!
//! The tile variables reshape builtin-manifest execution end to end — the
//! synthesized artifact, the scheduler partition, every worker's staging
//! buffers — exactly like re-synthesizing the bitstream with different
//! `APFP_TILE_SIZE_*` CMake options (§IV-A).  Config files and CLI
//! `--set key=value` overrides accept the same names ([`config`]).

pub mod baseline;
pub mod bench_util;
pub mod bigint;
pub mod blas;
pub mod config;
pub mod coordinator;
pub mod hwmodel;
pub mod linalg;
pub mod pack;
pub mod runtime;
pub mod sim;
pub mod softfloat;
pub mod testkit;
