//! Dense APFP linear algebra on [`Matrix`] — the routines the paper's
//! motivating SDP solvers (§I: SDPB-style interior-point methods) build on
//! top of GEMM: Cholesky decomposition, triangular solves and inverses.
//!
//! Everything here computes in full APFP precision through `softfloat`;
//! the O(n^3) matrix-matrix products can be routed through the accelerator
//! ([`MatmulBackend::Device`]) exactly as the paper drops its FPGA GEMM
//! into Elemental, while the O(n^3)/3 factorizations stay on the host
//! (also true of SDPB, whose GEMM/SYRK calls dominate).

use anyhow::Result;

use crate::baseline;
use crate::coordinator::{Device, Matrix};
use crate::softfloat::ApFloat;

/// Where to run matrix-matrix products.
pub enum MatmulBackend<'d> {
    /// Host softfloat (multithreaded blocked GEMM).
    Host { threads: usize },
    /// The virtual accelerator (bit-identical results).
    Device(&'d Device),
}

impl MatmulBackend<'_> {
    /// C = A*B (+C), dispatched to the selected backend.
    pub fn gemm(&self, a: &Matrix, b: &Matrix, c: &Matrix) -> Result<Matrix> {
        match self {
            MatmulBackend::Host { threads } => Ok(baseline::gemm_threaded(a, b, c, *threads)),
            MatmulBackend::Device(dev) => Ok(dev.gemm(a, b, c)?.0),
        }
    }
}

/// Transpose.
pub fn transpose(a: &Matrix) -> Matrix {
    Matrix::from_fn(a.cols(), a.rows(), a.prec(), |i, j| a.get(j, i).clone())
}

/// Identity matrix.
pub fn identity(n: usize, prec: u32) -> Matrix {
    Matrix::from_fn(n, n, prec, |i, j| {
        if i == j { ApFloat::from_u64(1, prec) } else { ApFloat::zero(prec) }
    })
}

/// Frobenius inner product `<A, B>` = sum_ij A_ij * B_ij, accumulated on the
/// allocation-free `mac_into` pipeline (thread-local arena).
pub fn frob_inner(a: &Matrix, b: &Matrix) -> ApFloat {
    let mut acc = ApFloat::zero(a.prec());
    crate::bigint::with_scratch(|scratch| {
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                acc.mac_into(a.get(i, j), b.get(i, j), scratch);
            }
        }
    });
    acc
}

/// Cholesky factorization A = L * L^T for symmetric positive-definite A.
/// Returns None when a pivot is non-positive (A not PD) — which doubles as
/// the PSD boundary test the barrier solver in examples/sdp_solver.rs uses.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs a square matrix");
    let prec = a.prec();
    let mut l = Matrix::zeros(n, n, prec);
    for j in 0..n {
        // d = A[j][j] - sum_k L[j][k]^2
        let mut d = a.get(j, j).clone();
        for k in 0..j {
            let v = l.get(j, k);
            d = d.sub(&v.mul(v));
        }
        if d.is_zero() || d.sign() {
            return None; // not positive definite
        }
        let ljj = sqrt(&d);
        let inv_ljj = reciprocal(&ljj);
        l.set(j, j, ljj);
        for i in (j + 1)..n {
            let mut s = a.get(i, j).clone();
            for k in 0..j {
                s = s.sub(&l.get(i, k).mul(l.get(j, k)));
            }
            l.set(i, j, s.mul(&inv_ljj));
        }
    }
    Some(l)
}

/// Solve L * X = B for lower-triangular L (forward substitution), matrix RHS.
pub fn solve_lower(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    let prec = l.prec();
    let mut x = Matrix::zeros(n, b.cols(), prec);
    // cache reciprocals of the diagonal (one Newton solve per row)
    let inv_diag: Vec<ApFloat> = (0..n).map(|i| reciprocal(l.get(i, i))).collect();
    for c in 0..b.cols() {
        for i in 0..n {
            let mut s = b.get(i, c).clone();
            for k in 0..i {
                s = s.sub(&l.get(i, k).mul(x.get(k, c)));
            }
            x.set(i, c, s.mul(&inv_diag[i]));
        }
    }
    x
}

/// Solve L^T * X = B for lower-triangular L (back substitution).
pub fn solve_lower_transpose(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    let prec = l.prec();
    let mut x = Matrix::zeros(n, b.cols(), prec);
    let inv_diag: Vec<ApFloat> = (0..n).map(|i| reciprocal(l.get(i, i))).collect();
    for c in 0..b.cols() {
        for i in (0..n).rev() {
            let mut s = b.get(i, c).clone();
            for k in (i + 1)..n {
                s = s.sub(&l.get(k, i).mul(x.get(k, c)));
            }
            x.set(i, c, s.mul(&inv_diag[i]));
        }
    }
    x
}

/// A^{-1} for SPD A via Cholesky: solve L Y = I, then L^T X = Y.
/// The two triangular solves are O(n^3); with `backend` the caller can
/// instead form A^{-1} = L^{-T} * L^{-1} with the accelerator GEMM.
pub fn spd_inverse(a: &Matrix) -> Option<Matrix> {
    let l = cholesky(a)?;
    let y = solve_lower(&l, &identity(a.rows(), a.prec()));
    Some(solve_lower_transpose(&l, &y))
}

/// sqrt by Newton iteration on APFP (converges quadratically; the seed
/// comes from f64, so ~6 iterations reach 448-bit precision).
pub fn sqrt(x: &ApFloat) -> ApFloat {
    assert!(!x.sign(), "sqrt of negative");
    if x.is_zero() {
        return x.clone();
    }
    let prec = x.prec();
    // seed from f64 with exponent handling for out-of-range values
    let e = x.exp();
    // scale x to ~1: x = m * 2^e -> sqrt(x) = sqrt(m * 2^(e mod 2)) * 2^(e div 2)
    let e_half = e.div_euclid(2);
    let e_rem = e - 2 * e_half; // 0 or 1
    let scaled = scale_exp(x, -e + e_rem); // in [0.5, 2)
    let mut y = ApFloat::from_f64(scaled.to_f64().sqrt(), prec);
    let half = ApFloat::from_f64(0.5, prec);
    // Newton: y <- (y + scaled/y) / 2 ; division via reciprocal
    for _ in 0..iterations_for(prec) {
        let q = scaled.mul(&reciprocal(&y));
        y = y.add(&q).mul(&half);
    }
    scale_exp(&y, e_half)
}

/// 1/x by Newton-Raphson on APFP: r <- r * (2 - x*r), f64 seed.
pub fn reciprocal(x: &ApFloat) -> ApFloat {
    assert!(!x.is_zero(), "reciprocal of zero");
    let prec = x.prec();
    // work on the mantissa scaled near 1 to keep the f64 seed in range
    let e = x.exp();
    let scaled = scale_exp(x, -e); // in [0.5, 1)
    let mut r = ApFloat::from_f64(1.0 / scaled.to_f64(), prec);
    let two = ApFloat::from_u64(2, prec);
    for _ in 0..iterations_for(prec) {
        r = r.mul(&two.sub(&scaled.mul(&r)));
    }
    scale_exp(&r, -e)
}

fn iterations_for(prec: u32) -> u32 {
    // f64 seed gives ~50 correct bits; Newton doubles per step (+ margin)
    let mut bits = 50u32;
    let mut iters = 0;
    while bits < prec + 8 {
        bits *= 2;
        iters += 1;
    }
    iters + 1
}

/// x * 2^k (exact exponent shift).
pub fn scale_exp(x: &ApFloat, k: i64) -> ApFloat {
    if x.is_zero() {
        return x.clone();
    }
    ApFloat::from_parts(x.sign(), x.exp() + k, x.limbs().to_vec(), x.prec())
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: u32 = 448;

    fn approx(a: &ApFloat, b: f64, tol: f64) {
        assert!((a.to_f64() - b).abs() <= tol * b.abs().max(1.0), "{} vs {}", a.to_f64(), b);
    }

    #[test]
    fn reciprocal_high_precision() {
        // 1/3 to 448 bits: 3 * (1/3) must round-trip to within 1 ulp of 1
        let three = ApFloat::from_u64(3, P);
        let r = reciprocal(&three);
        let prod = three.mul(&r);
        let one = ApFloat::from_u64(1, P);
        let diff = prod.sub(&one);
        assert!(diff.is_zero() || diff.exp() < -440, "residual exp {}", diff.exp());
        // huge/tiny exponents stay exact in scaling
        let big = scale_exp(&three, 1000);
        approx(&big.mul(&reciprocal(&big)), 1.0, 1e-15);
    }

    #[test]
    fn sqrt_high_precision() {
        let two = ApFloat::from_u64(2, P);
        let s = sqrt(&two);
        let sq = s.mul(&s);
        let diff = sq.sub(&two);
        assert!(diff.is_zero() || diff.exp() < -438, "residual exp {}", diff.exp());
        approx(&sqrt(&ApFloat::from_u64(9, P)), 3.0, 1e-15);
        assert!(sqrt(&ApFloat::zero(P)).is_zero());
        // odd exponent path
        let eight = ApFloat::from_u64(8, P);
        approx(&sqrt(&eight), 8f64.sqrt(), 1e-15);
    }

    #[test]
    fn cholesky_reconstructs() {
        // A = M*M^T + n*I is SPD
        let n = 6;
        let m = Matrix::random(n, n, P, 7, 3);
        let mt = transpose(&m);
        let mut a = baseline::gemm_serial(&m, &mt, &Matrix::zeros(n, n, P));
        for i in 0..n {
            a.set(i, i, a.get(i, i).add(&ApFloat::from_u64(1 << 20, P)));
        }
        let l = cholesky(&a).expect("SPD");
        let back = baseline::gemm_serial(&l, &transpose(&l), &Matrix::zeros(n, n, P));
        assert!(back.max_rel_err_f64(&a) < 1e-12);
        // strictly lower-triangular structure
        for i in 0..n {
            for j in (i + 1)..n {
                assert!(l.get(i, j).is_zero());
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = identity(3, P);
        a.set(2, 2, ApFloat::from_i64(-1, P));
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn triangular_solves() {
        let n = 5;
        let mut l = Matrix::random(n, n, P, 9, 2);
        for i in 0..n {
            l.set(i, i, ApFloat::from_u64(3, P)); // well-conditioned diagonal
            for j in (i + 1)..n {
                l.set(i, j, ApFloat::zero(P));
            }
        }
        let b = Matrix::random(n, 2, P, 10, 2);
        let x = solve_lower(&l, &b);
        let back = baseline::gemm_serial(&l, &x, &Matrix::zeros(n, 2, P));
        assert!(back.max_rel_err_f64(&b) < 1e-12);
        let xt = solve_lower_transpose(&l, &b);
        let back_t = baseline::gemm_serial(&transpose(&l), &xt, &Matrix::zeros(n, 2, P));
        assert!(back_t.max_rel_err_f64(&b) < 1e-12);
    }

    #[test]
    fn spd_inverse_roundtrip() {
        let n = 4;
        let m = Matrix::random(n, n, P, 11, 2);
        let mut a = baseline::gemm_serial(&m, &transpose(&m), &Matrix::zeros(n, n, P));
        for i in 0..n {
            a.set(i, i, a.get(i, i).add(&ApFloat::from_u64(1 << 12, P)));
        }
        let inv = spd_inverse(&a).unwrap();
        let prod = baseline::gemm_serial(&a, &inv, &Matrix::zeros(n, n, P));
        // off-diagonals of A*A^{-1} are ~2^-400: compare with *absolute*
        // tolerance (relative error against an exact 0 is meaningless)
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                let got = prod.get(i, j).to_f64();
                assert!((got - want).abs() < 1e-12, "({i},{j}): {got}");
            }
        }
    }

    #[test]
    fn frob_inner_matches_f64() {
        let a = Matrix::random(3, 3, P, 13, 2);
        let b = Matrix::random(3, 3, P, 14, 2);
        let mut want = 0.0;
        for i in 0..3 {
            for j in 0..3 {
                want += a.get(i, j).to_f64() * b.get(i, j).to_f64();
            }
        }
        approx(&frob_inner(&a, &b), want, 1e-12);
    }
}
