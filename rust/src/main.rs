//! `repro` — leader binary / CLI for the APFP accelerator reproduction.
//!
//! Subcommands regenerate every table and figure of the paper's evaluation
//! (§V) and drive the functional accelerator end-to-end:
//!
//! ```text
//! repro selftest                  quick e2e: device GEMM vs softfloat, bit-exact
//! repro tables  [--tab 1|2|3]     Tab. I / II / III (add --measured for host baseline)
//! repro figures [--fig 3|5|6]     Fig. 3 sweep / Fig. 5 / Fig. 6 series
//! repro gemm --n 64 [--check]     run an n x n GEMM on the device, report stats
//! repro multbench [--bits 512]    measured softfloat throughput vs modeled FPGA
//! repro placement [--cus 8]       Fig. 4 SLR/DDR-bank assignment
//! repro modelgold [--check|--write] [--file F]   perf-model regression gate
//! ```
//!
//! `gemm --json` emits a machine-readable report that includes the
//! device's hardware-model ledger (nonzero under `APFP_BACKEND=sim`).
//!
//! Config: `--config file.cfg` (key = value) and repeated `--set key=value`
//! overrides, exposing the paper's CMake options (§IV-A) at runtime.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use apfp::baseline;
use apfp::bench_util::{fmt_rate, Table};
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::hwmodel::{resources, DesignPoint};
use apfp::runtime::default_artifact_dir;
use apfp::sim::{cpu_ref, gemm_sim, mult_sim};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal argv parser: positional command + `--key value` / `--flag`.
struct Args {
    command: String,
    options: HashMap<String, Vec<String>>,
}

impl Args {
    fn parse() -> Result<Self> {
        let mut argv = std::env::args().skip(1);
        let command = argv.next().unwrap_or_else(|| "help".into());
        let mut options: HashMap<String, Vec<String>> = HashMap::new();
        let mut key: Option<String> = None;
        for a in argv {
            if let Some(k) = a.strip_prefix("--") {
                if let Some(prev) = key.take() {
                    options.entry(prev).or_default().push("true".into());
                }
                key = Some(k.to_string());
            } else if let Some(k) = key.take() {
                options.entry(k).or_default().push(a);
            } else {
                bail!("unexpected positional argument {a:?}");
            }
        }
        if let Some(prev) = key.take() {
            options.entry(prev).or_default().push("true".into());
        }
        Ok(Args { command, options })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("invalid --{key} value {s:?}")),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    fn config(&self) -> Result<ApfpConfig> {
        let mut cfg = match self.get("config") {
            Some(path) => ApfpConfig::from_file(std::path::Path::new(path))?,
            None => ApfpConfig::default(),
        };
        if let Some(sets) = self.options.get("set") {
            for s in sets {
                let (k, v) = s.split_once('=').ok_or_else(|| anyhow!("--set expects key=value"))?;
                cfg.set(k.trim(), v.trim())?;
            }
        }
        if let Some(b) = self.get("bits") {
            cfg.set("bits", b)?;
        }
        if let Some(c) = self.get("cus") {
            cfg.set("compute_units", c)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.command.as_str() {
        "selftest" => selftest(&args),
        "tables" => tables(&args),
        "figures" => figures(&args),
        "gemm" => gemm_cmd(&args),
        "multbench" => multbench(&args),
        "placement" => placement(&args),
        "modelgold" => modelgold(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `repro help`"),
    }
}

const HELP: &str = "\
repro — APFP-on-FPGA reproduction (three-layer Rust + JAX + Pallas)

commands:
  selftest                      e2e: device GEMM vs softfloat, bit-exact
  tables  [--tab 1|2|3] [--measured]   regenerate Tab. I / II / III
  figures [--fig 3|5|6]         regenerate figure data series
  gemm --n N [--check] [--json] [--cus P] [--bits 512|1024]
  multbench [--bits B] [--iters N] [--threads T]
  placement [--cus P]           Fig. 4 CU -> SLR/DDR-bank assignment
  modelgold [--check|--write] [--file model_golden.json]
                                diff (or regenerate) the pinned perf-model
                                goldens; --check fails on any drift
common options:
  --config FILE   key = value config (APFP_* names accepted)
  --set key=value repeated config overrides
";

// ---------------------------------------------------------------------------

fn selftest(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let dir = default_artifact_dir();
    println!(
        "opening device: {} CUs, {} bits, {} backend, artifacts at {}",
        cfg.compute_units,
        cfg.bits,
        cfg.backend,
        dir.display()
    );
    let dev = Device::new(cfg.clone(), &dir)?;
    let prec = cfg.prec();
    let n = 20;
    let a = Matrix::random(n, n, prec, 101, 40);
    let b = Matrix::random(n, n, prec, 102, 40);
    let c = Matrix::random(n, n, prec, 103, 40);
    let (got, stats) = dev.gemm(&a, &b, &c)?;
    let want = baseline::gemm_serial(&a, &b, &c);
    anyhow::ensure!(got == want, "device GEMM disagrees with softfloat reference!");
    println!(
        "OK: {n}x{n} GEMM bit-exact vs softfloat ({} tiles, {} artifact calls, {:.2}s, marshal {:.1}%)",
        stats.tiles,
        stats.artifact_calls,
        stats.wall_s,
        stats.marshal_fraction * 100.0
    );
    Ok(())
}

fn mult_table(bits: u32, measured: bool) -> Table {
    let mut t = Table::new(&["Configuration", "Freq.", "CLBs", "DSPs", "Throughput", "Speedup", "#Cores"]);
    for r in mult_sim::table(bits) {
        push_mult_row(&mut t, &r);
    }
    if measured {
        let host = baseline::measure_mul_throughput(apfp::softfloat::prec_for_bits(bits), 50_000);
        let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        let host_all =
            baseline::measure_mul_throughput_threaded(apfp::softfloat::prec_for_bits(bits), 50_000, threads);
        push_mult_row(&mut t, &mult_sim::measured_cpu_row("this host, 1 core (measured)", host, bits));
        push_mult_row(
            &mut t,
            &mult_sim::measured_cpu_row(&format!("this host, {threads} cores (measured)"), host_all, bits),
        );
    }
    t
}

fn push_mult_row(t: &mut Table, r: &mult_sim::MultRow) {
    t.row(&[
        r.label.clone(),
        if r.frequency_mhz > 0.0 { format!("{:.0} MHz", r.frequency_mhz) } else { "-".into() },
        if r.clb_pct > 0.0 { format!("{:.1}%", r.clb_pct) } else { "-".into() },
        if r.dsp_pct > 0.0 { format!("{:.1}%", r.dsp_pct) } else { "-".into() },
        format!("{:.0} MOp/s", r.throughput_mops),
        format!("{:.1}x", r.speedup_vs_node),
        format!("{:.1}x", r.equivalent_cores),
    ]);
}

fn tables(args: &Args) -> Result<()> {
    let which: u32 = args.get_parse("tab", 0)?;
    let measured = args.flag("measured");
    if which == 0 || which == 1 {
        println!("\n== Tab. I: 512-bit multiplier (448-bit mantissa) ==");
        println!("{}", mult_table(512, measured).render());
    }
    if which == 0 || which == 2 {
        println!("\n== Tab. II: 1024-bit multiplier (960-bit mantissa) ==");
        println!("{}", mult_table(1024, measured).render());
    }
    if which == 0 || which == 3 {
        println!("\n== Tab. III: 512-bit GEMM designs ==");
        let mut t = Table::new(&["Precision", "CUs", "Frequency", "CLBs", "DSPs", "Max. Performance"]);
        for cus in [1usize, 2, 4, 8] {
            let d = DesignPoint::gemm_512(cus);
            let s = d.synthesize();
            let peak = gemm_sim::peak(&d, 32);
            t.row(&[
                "512 (448)".into(),
                cus.to_string(),
                format!("{:.0} MHz", s.frequency_mhz),
                format!("{:.1}%", s.clb_frac * 100.0),
                format!("{:.1}%", s.dsp_frac * 100.0),
                format!("{:.0} MMAC/s", peak.mmacs / 1e6),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

fn figures(args: &Args) -> Result<()> {
    let which: u32 = args.get_parse("fig", 0)?;
    if which == 0 || which == 3 {
        println!("\n== Fig. 3: multiplier design-space sweep (512-bit) ==");
        let mut t = Table::new(&["mult_base", "add_base", "freq [MHz]", "CLBs", "status"]);
        for mult_base in [18u32, 36, 72, 144, 288] {
            for add_base in [32u32, 64, 128, 256, 512, 1024] {
                let d = DesignPoint {
                    bits: 512,
                    compute_units: 1,
                    mult_base_bits: mult_base,
                    add_base_bits: add_base,
                    gemm: false,
                };
                let s = d.synthesize();
                let clbs = resources::fig3_multiplier_clbs(448, mult_base, add_base);
                t.row(&[
                    mult_base.to_string(),
                    add_base.to_string(),
                    format!("{:.0}", s.frequency_mhz),
                    clbs.to_string(),
                    s.failure.map(|_| "FAILS SYNTHESIS".into()).unwrap_or_else(|| "ok".to_string()),
                ]);
            }
        }
        println!("{}", t.render());
    }
    if which == 0 || which == 5 {
        println!("\n== Fig. 5: 512-bit GEMM MMAC/s vs n ==");
        figure_gemm(512)?;
    }
    if which == 0 || which == 6 {
        println!("\n== Fig. 6: 1024-bit GEMM MMAC/s vs n ==");
        figure_gemm(1024)?;
    }
    Ok(())
}

fn figure_gemm(bits: u32) -> Result<()> {
    let sizes = [256usize, 512, 1024, 2048, 4096, 8192, 16384];
    let cu_counts: &[usize] = if bits == 512 { &[1, 2, 4, 8] } else { &[1] };
    let mut header: Vec<String> = vec!["n".into()];
    header.extend(cu_counts.iter().map(|c| format!("FPGA {c} CU [MMAC/s]")));
    for nodes in [1, 2, 4, 8] {
        header.push(format!("{nodes} node{} [MMAC/s]", if nodes == 1 { "" } else { "s" }));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    for &n in &sizes {
        let mut row = vec![n.to_string()];
        for &cus in cu_counts {
            let d = if bits == 512 { DesignPoint::gemm_512(cus) } else { DesignPoint::gemm_1024(cus) };
            let pt = gemm_sim::simulate(&d, n, 32, 32);
            row.push(format!("{:.0}", pt.mmacs / 1e6));
        }
        for nodes in [1, 2, 4, 8] {
            row.push(format!("{:.0}", cpu_ref::gemm_mmacs(bits, nodes, n) / 1e6));
        }
        t.row(&row);
    }
    println!("{}", t.render());
    Ok(())
}

fn gemm_cmd(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let n: usize = args.get_parse("n", 64)?;
    let check = args.flag("check");
    let json = args.flag("json");
    let dir = default_artifact_dir();
    let dev = Device::new(cfg.clone(), &dir)?;
    let prec = cfg.prec();
    if !json {
        println!("n={n}, {} CUs, {} bits", cfg.compute_units, cfg.bits);
    }
    let a = Matrix::random(n, n, prec, 201, 60);
    let b = Matrix::random(n, n, prec, 202, 60);
    let c = Matrix::zeros(n, n, prec);
    let t0 = std::time::Instant::now();
    let (got, stats) = dev.gemm(&a, &b, &c)?;
    let wall = t0.elapsed().as_secs_f64();
    let macs = (n * n * n) as f64;
    if check {
        let want = baseline::gemm_serial(&a, &b, &c);
        anyhow::ensure!(got == want, "MISMATCH vs softfloat");
    }
    if json {
        let d = if cfg.bits == 512 {
            DesignPoint::gemm_512(cfg.compute_units)
        } else {
            DesignPoint::gemm_1024(cfg.compute_units)
        };
        let pt = gemm_sim::simulate(&d, n, cfg.tile_n, cfg.tile_m);
        let m = dev.model_metrics();
        let mut fields: Vec<(String, String)> = vec![
            ("n".into(), n.to_string()),
            ("cus".into(), cfg.compute_units.to_string()),
            ("bits".into(), cfg.bits.to_string()),
            ("backend".into(), format!("\"{}\"", cfg.backend)),
            ("wall_s".into(), format!("{wall:.6}")),
            ("tiles".into(), stats.tiles.to_string()),
            ("artifact_calls".into(), stats.artifact_calls.to_string()),
            ("marshal_fraction".into(), format!("{:.6}", stats.marshal_fraction)),
            ("checked".into(), check.to_string()),
        ];
        for (k, v) in [
            ("model_tiles", m.tiles as f64),
            ("model_launches", m.launches as f64),
            ("model_cycles", m.cycles as f64),
            ("model_macs", m.macs as f64),
            ("model_dram_bytes", m.dram_bytes as f64),
            ("model_energy_pj", m.energy_pj as f64),
        ] {
            fields.push((k.into(), format!("{v:.0}")));
        }
        // per-width model breakdown: one row set per loaded width that
        // retired launches (sums across widths equal the device totals —
        // the conservation invariant `tests/sim_backend.rs` pins)
        for w in m.width_breakdown() {
            for (k, v) in [
                ("tiles", w.tiles),
                ("launches", w.launches),
                ("cycles", w.cycles),
                ("macs", w.macs),
                ("dram_bytes", w.dram_bytes),
                ("energy_pj", w.energy_pj),
            ] {
                fields.push((format!("model_w{}_{k}", w.bits), v.to_string()));
            }
        }
        for (k, v) in [
            ("model_compute_s", m.compute_s()),
            ("model_mem_s", m.mem_s()),
            ("model_fixed_s", m.fixed_s()),
            ("model_total_s", m.total_s()),
            ("model_efficiency", m.efficiency()),
            ("model_mmacs", m.mmacs()),
            ("model_power_w", m.power_w()),
            ("sim_mmacs", pt.mmacs / 1e6),
            ("sim_efficiency", pt.efficiency),
            ("sim_freq_mhz", d.synthesize().frequency_mhz),
        ] {
            fields.push((k.into(), format!("{v:.9}")));
        }
        let mut out = String::from("{\n");
        for (i, (k, v)) in fields.iter().enumerate() {
            out.push_str(&format!(
                "  \"{k}\": {v}{}\n",
                if i + 1 == fields.len() { "" } else { "," }
            ));
        }
        out.push('}');
        println!("{out}");
        return Ok(());
    }
    println!(
        "device GEMM: {:.2}s wall, {} tiles, {} artifact calls, {} MAC/s through \
         the functional {} backend on this CPU host",
        wall,
        stats.tiles,
        stats.artifact_calls,
        fmt_rate(macs / wall),
        cfg.backend,
    );
    println!("coordinator marshal overhead: {:.2}%", stats.marshal_fraction * 100.0);
    // modeled hardware performance of the same call
    let d = if cfg.bits == 512 {
        DesignPoint::gemm_512(cfg.compute_units)
    } else {
        DesignPoint::gemm_1024(cfg.compute_units)
    };
    let pt = gemm_sim::simulate(&d, n, cfg.tile_n, cfg.tile_m);
    println!(
        "modeled U250 ({} CUs): {:.0} MMAC/s at {:.0} MHz (efficiency {:.0}%)",
        cfg.compute_units,
        pt.mmacs / 1e6,
        d.synthesize().frequency_mhz,
        pt.efficiency * 100.0
    );
    let m = dev.model_metrics();
    if m.is_live() {
        println!(
            "model ledger ({} tiles, {} launch{}): {:.0} cycles, {} DRAM bytes, \
             {:.3} ms modeled ({:.0} MMAC/s, efficiency {:.0}%, {:.1} W)",
            m.tiles,
            m.launches,
            if m.launches == 1 { "" } else { "es" },
            m.cycles as f64,
            m.dram_bytes,
            m.total_s() * 1e3,
            m.mmacs(),
            m.efficiency() * 100.0,
            m.power_w(),
        );
    }
    if check {
        println!("check: bit-exact vs softfloat reference");
    }
    Ok(())
}

/// The perf-model regression gate: every pinned constant of the hardware
/// model — per-tile modeled costs on the builtin GEMM geometry, and the
/// `sim::gemm_sim` throughput/efficiency the paper's figures regenerate
/// from — as one flat `key -> value` table.  `--write` regenerates
/// `model_golden.json`; `--check` (the default, run by CI's analysis job)
/// recomputes every value and fails on any drift beyond 1e-6 relative,
/// so an accidental change to a model constant cannot land silently.
fn model_golden_values() -> Result<Vec<(String, f64)>> {
    use apfp::runtime::manifest::{self, ArtifactKind, TileShape};
    use apfp::runtime::sim_backend::tile_cost;
    let mut out: Vec<(String, f64)> = Vec::new();
    for bits in [512u32, 1024] {
        let metas = manifest::builtin(bits, TileShape::default())
            .map_err(|e| anyhow!("builtin manifest for {bits} bits: {e}"))?;
        let gemm = metas
            .iter()
            .find(|m| m.kind == ArtifactKind::Gemm)
            .ok_or_else(|| anyhow!("builtin manifest lacks a {bits}-bit GEMM artifact"))?;
        let c = tile_cost(gemm);
        out.push((format!("tile{bits}_cycles"), c.cycles as f64));
        out.push((format!("tile{bits}_macs"), c.macs as f64));
        out.push((format!("tile{bits}_dram_bytes"), c.dram_bytes as f64));
        out.push((format!("tile{bits}_compute_ps"), c.compute_ps as f64));
        out.push((format!("tile{bits}_mem_ps"), c.mem_ps as f64));
        out.push((format!("tile{bits}_energy_pj"), c.energy_pj as f64));
    }
    for (bits, cus) in [(512u32, 1usize), (512, 2), (512, 4), (512, 8), (1024, 1)] {
        let d = if bits == 512 { DesignPoint::gemm_512(cus) } else { DesignPoint::gemm_1024(cus) };
        out.push((format!("gemm{bits}_cu{cus}_freq_mhz"), d.synthesize().frequency_mhz));
        out.push((format!("gemm{bits}_cu{cus}_peak_mmacs"), gemm_sim::peak(&d, 32).mmacs / 1e6));
        let pt = gemm_sim::simulate(&d, 4096, 32, 32);
        out.push((format!("gemm{bits}_cu{cus}_n4096_mmacs"), pt.mmacs / 1e6));
        out.push((format!("gemm{bits}_cu{cus}_n4096_efficiency"), pt.efficiency));
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

/// Parse the flat `{"key": value, ...}` golden file written by
/// `modelgold --write` (one pair per line; no nested objects).
fn parse_golden(text: &str) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim().trim_end_matches(',');
        let Some(rest) = line.strip_prefix('"') else { continue };
        let (key, val) = rest
            .split_once("\":")
            .ok_or_else(|| anyhow!("malformed golden line {}: {raw:?}", i + 1))?;
        let v: f64 = val
            .trim()
            .parse()
            .map_err(|_| anyhow!("malformed golden value on line {}: {raw:?}", i + 1))?;
        out.push((key.to_string(), v));
    }
    Ok(out)
}

fn modelgold(args: &Args) -> Result<()> {
    const REL_TOL: f64 = 1e-6;
    let path = args.get("file").unwrap_or("model_golden.json").to_string();
    let fresh = model_golden_values()?;
    if args.flag("write") {
        let mut s = String::from("{\n");
        for (i, (k, v)) in fresh.iter().enumerate() {
            s.push_str(&format!(
                "  \"{k}\": {v:.9}{}\n",
                if i + 1 == fresh.len() { "" } else { "," }
            ));
        }
        s.push_str("}\n");
        std::fs::write(&path, s)?;
        println!("wrote {} model goldens to {path}", fresh.len());
        return Ok(());
    }
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("reading {path}: {e} (regenerate with `repro modelgold --write`)"))?;
    let pinned: HashMap<String, f64> = parse_golden(&text)?.into_iter().collect();
    anyhow::ensure!(!pinned.is_empty(), "{path} pins no goldens");
    let mut drifted = 0usize;
    for (key, now) in &fresh {
        match pinned.get(key) {
            None => {
                drifted += 1;
                println!("MISSING {key}: model computes {now:.9} but {path} does not pin it");
            }
            Some(&want) => {
                let scale = want.abs().max(now.abs()).max(1e-30);
                if (now - want).abs() / scale > REL_TOL {
                    drifted += 1;
                    println!("DRIFT {key}: pinned {want:.9}, model now computes {now:.9}");
                }
            }
        }
    }
    for key in pinned.keys() {
        if !fresh.iter().any(|(k, _)| k == key) {
            drifted += 1;
            println!("STALE {key}: pinned in {path} but no longer computed by the model");
        }
    }
    anyhow::ensure!(
        drifted == 0,
        "{drifted} perf-model golden(s) drifted; if intentional, regenerate with \
         `repro modelgold --write --file {path}` and commit the diff"
    );
    println!("OK: {} perf-model goldens match within {REL_TOL:e} relative", fresh.len());
    Ok(())
}

fn multbench(args: &Args) -> Result<()> {
    let cfg = args.config()?;
    let iters: usize = args.get_parse("iters", 200_000)?;
    let threads: usize = args.get_parse(
        "threads",
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
    )?;
    let prec = cfg.prec();
    println!("softfloat {}-bit multiply, {iters} iters:", cfg.bits);
    let one = baseline::measure_mul_throughput(prec, iters);
    println!("  1 core (measured):        {}", fmt_rate(one));
    let all = baseline::measure_mul_throughput_threaded(prec, iters, threads);
    println!("  {threads} cores (measured):     {}", fmt_rate(all));
    println!("  paper 36-core node (MPFR): {}", fmt_rate(cpu_ref::mult_node_mops(cfg.bits)));
    let row = mult_sim::fpga_row(cfg.bits, cfg.compute_units);
    println!(
        "  modeled FPGA {} CUs:       {} ({:.1}x node, {:.0}x cores)",
        cfg.compute_units,
        fmt_rate(row.throughput_mops * 1e6),
        row.speedup_vs_node,
        row.equivalent_cores
    );
    Ok(())
}

fn placement(args: &Args) -> Result<()> {
    let cus: usize = args.get_parse("cus", 8)?;
    let mut t = Table::new(&["CU", "DDR bank", "SLR"]);
    for p in apfp::hwmodel::floorplan::assign(cus) {
        t.row(&[format!("CU[{}]", p.cu), p.ddr_bank.to_string(), format!("SLR{}", p.slr)]);
    }
    println!("{}", t.render());
    Ok(())
}
