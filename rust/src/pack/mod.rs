//! The paper's Fig. 1 hardware number format + the PJRT limb-plane layout.
//!
//! Two packed representations live here:
//!
//! 1. **Fig. 1 words** (`pack_words`/`unpack_words`): the DRAM format — the
//!    63-bit two's-complement exponent with the sign packed into bit 63 of
//!    the head word, followed by the tightly packed mantissa, padded to a
//!    multiple of 512 bits for efficient memory access.  Byte-compatible
//!    with python/compile/apfp_types.py (pinned by artifacts/test_vectors).
//!
//! 2. **Limb planes** (`PlaneBatch`): the struct-of-arrays layout the AOT
//!    artifacts consume — i32 sign plane, i64 exponent plane, and the
//!    mantissa as 8-bit limbs in i32 lanes.  This is the HBM layout of the
//!    TPU re-think (DESIGN.md §Hardware-Adaptation).

use crate::softfloat::{ApFloat, ZERO_EXP};

/// Total packed bits for a given precision (Fig. 1: next multiple of 512
/// covering prec + 64 head bits).
pub fn bits_for_prec(prec: u32) -> u32 {
    (prec + 64).div_ceil(512) * 512
}

/// Number of u64 words in the packed representation.
pub fn words_for_bits(bits: u32) -> usize {
    (bits / 64) as usize
}

/// Pack into Fig. 1 words.  Word 0: exponent (63-bit two's complement) with
/// the sign in bit 63; words 1..: mantissa, least-significant limb first.
pub fn pack_words(v: &ApFloat, out: &mut [u64]) {
    let bits = bits_for_prec(v.prec());
    assert_eq!(out.len(), words_for_bits(bits));
    let exp63 = (v.exp() as u64) & ((1 << 63) - 1);
    out[0] = exp63 | ((v.sign() as u64) << 63);
    out[1..1 + v.limbs().len()].copy_from_slice(v.limbs());
    out[1 + v.limbs().len()..].fill(0);
}

/// Unpack from Fig. 1 words.
pub fn unpack_words(words: &[u64], prec: u32) -> ApFloat {
    let head = words[0];
    let sign = head >> 63 == 1;
    // sign-extend the 63-bit two's-complement field: shift the field into
    // the top 63 bits, then arithmetic-shift back down
    let exp = ((head << 1) as i64) >> 1;
    let n = (prec / 64) as usize;
    let mant = words[1..1 + n].to_vec();
    if crate::bigint::is_zero(&mant) {
        return ApFloat::zero(prec);
    }
    ApFloat::from_parts(sign, exp, mant, prec)
}

/// Struct-of-arrays batch in the artifact plane layout.
///
/// `mant` is row-major `[batch, limbs8]` where `limbs8 = prec / 8` —
/// little-endian 8-bit limbs widened into i32 lanes.
#[derive(Clone, Debug, PartialEq)]
pub struct PlaneBatch {
    pub sign: Vec<i32>,
    pub exp: Vec<i64>,
    pub mant: Vec<i32>,
    pub limbs8: usize,
    pub prec: u32,
}

impl PlaneBatch {
    pub fn zeros(batch: usize, prec: u32) -> Self {
        let limbs8 = (prec / 8) as usize;
        PlaneBatch {
            sign: vec![0; batch],
            exp: vec![ZERO_EXP; batch],
            mant: vec![0; batch * limbs8],
            limbs8,
            prec,
        }
    }

    pub fn len(&self) -> usize {
        self.sign.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sign.is_empty()
    }

    /// Write one value into slot `i`.
    pub fn set(&mut self, i: usize, v: &ApFloat) {
        assert_eq!(v.prec(), self.prec);
        self.sign[i] = v.sign() as i32;
        self.exp[i] = v.exp();
        let row = &mut self.mant[i * self.limbs8..(i + 1) * self.limbs8];
        for (k, slot) in row.iter_mut().enumerate() {
            let word = v.limbs()[k / 8];
            *slot = ((word >> (8 * (k % 8))) & 0xFF) as i32;
        }
    }

    /// Read slot `i` back into an ApFloat.
    pub fn get(&self, i: usize) -> ApFloat {
        if self.exp[i] == ZERO_EXP {
            return ApFloat::zero(self.prec);
        }
        let row = &self.mant[i * self.limbs8..(i + 1) * self.limbs8];
        let mut mant = vec![0u64; (self.prec / 64) as usize];
        for (k, &limb) in row.iter().enumerate() {
            debug_assert!((0..256).contains(&limb), "non-canonical limb from artifact");
            mant[k / 8] |= ((limb as u64) & 0xFF) << (8 * (k % 8));
        }
        ApFloat::from_parts(self.sign[i] != 0, self.exp[i], mant, self.prec)
    }

    pub fn from_slice(vals: &[ApFloat], prec: u32) -> Self {
        let mut b = PlaneBatch::zeros(vals.len(), prec);
        for (i, v) in vals.iter().enumerate() {
            b.set(i, v);
        }
        b
    }

    pub fn to_vec(&self) -> Vec<ApFloat> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{self, Rng};

    const P: u32 = 448;

    fn rand_ap(rng: &mut Rng, prec: u32) -> ApFloat {
        let n = (prec / 64) as usize;
        let mut mant = rng.limbs(n);
        mant[n - 1] |= 1 << 63;
        ApFloat::from_parts(rng.bool(), rng.range_i64(-(1 << 40), 1 << 40), mant, prec)
    }

    #[test]
    fn fig1_geometry() {
        assert_eq!(bits_for_prec(448), 512);
        assert_eq!(bits_for_prec(960), 1024);
        assert_eq!(words_for_bits(512), 8);
        assert_eq!(words_for_bits(1024), 16);
    }

    #[test]
    fn words_roundtrip_property() {
        testkit::check(200, |rng| {
            for prec in [448u32, 960] {
                let v = rand_ap(rng, prec);
                let mut w = vec![0u64; words_for_bits(bits_for_prec(prec))];
                pack_words(&v, &mut w);
                assert_eq!(unpack_words(&w, prec), v);
            }
        });
    }

    #[test]
    fn sign_bit_position() {
        let mut m = vec![0u64; 7];
        m[6] = 1 << 63;
        let pos = ApFloat::from_parts(false, 42, m.clone(), P);
        let neg = ApFloat::from_parts(true, 42, m, P);
        let mut wp = vec![0u64; 8];
        let mut wn = vec![0u64; 8];
        pack_words(&pos, &mut wp);
        pack_words(&neg, &mut wn);
        assert_eq!(wn[0], wp[0] | (1 << 63));
        assert_eq!(wn[1..], wp[1..]);
    }

    #[test]
    fn negative_exponent_two_complement() {
        let mut m = vec![0u64; 7];
        m[6] = 1 << 63;
        let v = ApFloat::from_parts(false, -1, m, P);
        let mut w = vec![0u64; 8];
        pack_words(&v, &mut w);
        assert_eq!(w[0], (1 << 63) - 1); // 63-bit -1, sign bit clear
        assert_eq!(unpack_words(&w, P), v);
    }

    #[test]
    fn zero_roundtrip() {
        let z = ApFloat::zero(P);
        let mut w = vec![0u64; 8];
        pack_words(&z, &mut w);
        assert!(unpack_words(&w, P).is_zero());
    }

    #[test]
    fn planes_roundtrip_property() {
        testkit::check(50, |rng| {
            for prec in [448u32, 960] {
                let vals: Vec<_> = (0..5)
                    .map(|i| {
                        if i == 2 {
                            ApFloat::zero(prec)
                        } else {
                            rand_ap(rng, prec)
                        }
                    })
                    .collect();
                let planes = PlaneBatch::from_slice(&vals, prec);
                assert_eq!(planes.to_vec(), vals);
            }
        });
    }

    #[test]
    fn plane_limbs_are_bytes_little_endian() {
        let v = ApFloat::from_i64(1, P); // mantissa = 2^447
        let b = PlaneBatch::from_slice(std::slice::from_ref(&v), P);
        assert_eq!(b.limbs8, 56);
        assert_eq!(b.mant[55], 0x80); // MSB limb holds the top byte
        assert!(b.mant[..55].iter().all(|&x| x == 0));
    }
}
