//! The paper's Fig. 1 hardware number format + the PJRT limb-plane layout.
//!
//! Two packed representations live here:
//!
//! 1. **Fig. 1 words** (`pack_words`/`unpack_words`): the DRAM format — the
//!    63-bit two's-complement exponent with the sign packed into bit 63 of
//!    the head word, followed by the tightly packed mantissa, padded to a
//!    multiple of 512 bits for efficient memory access.  Byte-compatible
//!    with python/compile/apfp_types.py (pinned by artifacts/test_vectors).
//!
//! 2. **Limb planes** (`PlaneBatch`): the struct-of-arrays layout the AOT
//!    artifacts consume — i32 sign plane, i64 exponent plane, and the
//!    mantissa as 8-bit limbs in i32 lanes.  This is the HBM layout of the
//!    TPU re-think (DESIGN.md §Hardware-Adaptation).
//!
//! [`PlanePanel`] wraps a 2-D batch as a device-resident matrix: packed
//! once, then tiles move in and out as plane-row `memcpy`s
//! ([`PlanePanel::extract_tile_into`] / [`PlanePanel::write_tile`]) — the
//! data layout both the one-shot GEMM launch and the batched stream keep
//! operands in between kernel invocations.
//!
//! ```
//! use apfp::pack::PlaneBatch;
//! use apfp::softfloat::ApFloat;
//!
//! let vals = [ApFloat::from_i64(-3, 448), ApFloat::zero(448)];
//! let planes = PlaneBatch::from_slice(&vals, 448);
//! assert_eq!(planes.to_vec(), vals); // lossless struct-of-arrays roundtrip
//! ```

use crate::softfloat::{ApFloat, ApFloatN, ZERO_EXP};

/// Total packed bits for a given precision (Fig. 1: next multiple of 512
/// covering prec + 64 head bits).
pub fn bits_for_prec(prec: u32) -> u32 {
    (prec + 64).div_ceil(512) * 512
}

/// Number of u64 words in the packed representation.
pub fn words_for_bits(bits: u32) -> usize {
    (bits / 64) as usize
}

/// Pack into Fig. 1 words.  Word 0: exponent (63-bit two's complement) with
/// the sign in bit 63; words 1..: mantissa, least-significant limb first.
pub fn pack_words(v: &ApFloat, out: &mut [u64]) {
    let bits = bits_for_prec(v.prec());
    assert_eq!(out.len(), words_for_bits(bits));
    let exp63 = (v.exp() as u64) & ((1 << 63) - 1);
    out[0] = exp63 | ((v.sign() as u64) << 63);
    out[1..1 + v.limbs().len()].copy_from_slice(v.limbs());
    out[1 + v.limbs().len()..].fill(0);
}

/// Unpack from Fig. 1 words.
pub fn unpack_words(words: &[u64], prec: u32) -> ApFloat {
    let head = words[0];
    let sign = head >> 63 == 1;
    // sign-extend the 63-bit two's-complement field: shift the field into
    // the top 63 bits, then arithmetic-shift back down
    let exp = ((head << 1) as i64) >> 1;
    let n = (prec / 64) as usize;
    let mant = words[1..1 + n].to_vec();
    if crate::bigint::is_zero(&mant) {
        return ApFloat::zero(prec);
    }
    ApFloat::from_parts(sign, exp, mant, prec)
}

/// Struct-of-arrays batch in the artifact plane layout.
///
/// `mant` is row-major `[batch, limbs8]` where `limbs8 = prec / 8` —
/// little-endian 8-bit limbs widened into i32 lanes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlaneBatch {
    pub sign: Vec<i32>,
    pub exp: Vec<i64>,
    pub mant: Vec<i32>,
    pub limbs8: usize,
    pub prec: u32,
}

impl PlaneBatch {
    pub fn zeros(batch: usize, prec: u32) -> Self {
        let limbs8 = (prec / 8) as usize;
        PlaneBatch {
            sign: vec![0; batch],
            exp: vec![ZERO_EXP; batch],
            mant: vec![0; batch * limbs8],
            limbs8,
            prec,
        }
    }

    /// Re-shape in place to `batch` all-zero lanes at `prec`, reusing the
    /// existing capacity — the allocation-free counterpart of
    /// [`PlaneBatch::zeros`] for buffers that live across calls.
    // apfp-lint: no_alloc
    pub fn reset(&mut self, batch: usize, prec: u32) {
        self.prec = prec;
        self.limbs8 = (prec / 8) as usize;
        self.sign.clear();
        // apfp-lint: allow(alloc, reason="capacity reuse: clear+resize refills the existing planes; reallocates only when the batch or width grows")
        self.sign.resize(batch, 0);
        self.exp.clear();
        // apfp-lint: allow(alloc, reason="capacity reuse: clear+resize refills the existing planes; reallocates only when the batch or width grows")
        self.exp.resize(batch, ZERO_EXP);
        self.mant.clear();
        // apfp-lint: allow(alloc, reason="capacity reuse: clear+resize refills the existing planes; reallocates only when the batch or width grows")
        self.mant.resize(batch * self.limbs8, 0);
    }

    pub fn len(&self) -> usize {
        self.sign.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sign.is_empty()
    }

    /// Write one value into slot `i`.
    pub fn set(&mut self, i: usize, v: &ApFloat) {
        assert_eq!(v.prec(), self.prec);
        self.sign[i] = v.sign() as i32;
        self.exp[i] = v.exp();
        let row = &mut self.mant[i * self.limbs8..(i + 1) * self.limbs8];
        for (k, slot) in row.iter_mut().enumerate() {
            let word = v.limbs()[k / 8];
            *slot = ((word >> (8 * (k % 8))) & 0xFF) as i32;
        }
    }

    /// Read slot `i` back into an ApFloat.
    pub fn get(&self, i: usize) -> ApFloat {
        let mut out = ApFloat::zero(self.prec.max(128));
        self.get_into(i, &mut out);
        out
    }

    /// Decode slot `i` into a caller-owned `ApFloat`, reusing its mantissa
    /// buffer — the allocation-free decode the native backend and the tile
    /// marshaling loops run per lane.
    // apfp-lint: no_alloc
    pub fn get_into(&self, i: usize, out: &mut ApFloat) {
        out.prec = self.prec;
        let n = (self.prec / 64) as usize;
        if out.mant.len() != n {
            out.mant.clear();
            // apfp-lint: allow(alloc, reason="capacity reuse: clear+resize refills the existing buffer; reallocates only when the width grows")
            out.mant.resize(n, 0);
        }
        if self.exp[i] == ZERO_EXP {
            out.sign = false;
            out.exp = ZERO_EXP;
            out.mant.fill(0);
            return;
        }
        out.mant.fill(0);
        let row = &self.mant[i * self.limbs8..(i + 1) * self.limbs8];
        for (k, &limb) in row.iter().enumerate() {
            debug_assert!((0..256).contains(&limb), "non-canonical limb from artifact");
            out.mant[k / 8] |= ((limb as u64) & 0xFF) << (8 * (k % 8));
        }
        if crate::bigint::is_zero(&out.mant) {
            // canonicalize a zero mantissa exactly like ApFloat::from_parts
            out.sign = false;
            out.exp = ZERO_EXP;
            return;
        }
        // Hard check (like ApFloat::from_parts): a backend returning a
        // non-normalized mantissa must fail loudly at the decode boundary,
        // not poison downstream arithmetic.  Cheap: bit_length looks at
        // the top limb first, which is nonzero for every normalized value.
        assert!(
            crate::bigint::bit_length(&out.mant) == self.prec as usize,
            "non-normalized mantissa from artifact"
        );
        out.sign = self.sign[i] != 0;
        out.exp = self.exp[i];
    }

    /// Decode slot `i` directly into a stack-allocated fixed-width float —
    /// the plane-batch decode the native backend's fixed lane runs per
    /// element.  Unlike [`PlaneBatch::get_into`] there is no buffer
    /// management at all: the mantissa is a `[u64; L]` on the caller's
    /// stack, so the decode is alloc-free by construction, not by capacity
    /// reuse.  Byte-plane semantics (zero canonicalization, normalization
    /// hard check) are identical to the dynamic decode.
    // apfp-lint: no_alloc
    pub fn get_fixed_into<const L: usize>(&self, i: usize, out: &mut ApFloatN<L>) {
        assert_eq!((self.prec / 64) as usize, L, "width mismatch: plane prec vs LIMBS");
        if self.exp[i] == ZERO_EXP {
            *out = ApFloatN::ZERO;
            return;
        }
        out.mant = [0u64; L];
        let row = &self.mant[i * self.limbs8..(i + 1) * self.limbs8];
        for (k, &limb) in row.iter().enumerate() {
            debug_assert!((0..256).contains(&limb), "non-canonical limb from artifact");
            out.mant[k / 8] |= ((limb as u64) & 0xFF) << (8 * (k % 8));
        }
        if crate::bigint::is_zero(&out.mant) {
            // canonicalize a zero mantissa exactly like ApFloat::from_parts
            *out = ApFloatN::ZERO;
            return;
        }
        assert!(
            crate::bigint::bit_length(&out.mant) == self.prec as usize,
            "non-normalized mantissa from artifact"
        );
        out.sign = self.sign[i] != 0;
        out.exp = self.exp[i];
    }

    /// Write one fixed-width value into slot `i` — the encode mirror of
    /// [`PlaneBatch::get_fixed_into`], byte-plane identical to
    /// [`PlaneBatch::set`] for the same value.
    // apfp-lint: no_alloc
    pub fn set_fixed<const L: usize>(&mut self, i: usize, v: &ApFloatN<L>) {
        assert_eq!((self.prec / 64) as usize, L, "width mismatch: plane prec vs LIMBS");
        self.sign[i] = v.sign() as i32;
        self.exp[i] = v.exp();
        let row = &mut self.mant[i * self.limbs8..(i + 1) * self.limbs8];
        for (k, slot) in row.iter_mut().enumerate() {
            let word = v.mant[k / 8];
            *slot = ((word >> (8 * (k % 8))) & 0xFF) as i32;
        }
    }

    pub fn from_slice(vals: &[ApFloat], prec: u32) -> Self {
        let mut b = PlaneBatch::zeros(vals.len(), prec);
        for (i, v) in vals.iter().enumerate() {
            b.set(i, v);
        }
        b
    }

    pub fn to_vec(&self) -> Vec<ApFloat> {
        (0..self.len()).map(|i| self.get(i)).collect()
    }
}

/// A 2-D matrix packed once into the plane layout (lane `r * cols + c`),
/// the shared-operand form `Device::gemm` hands its workers: each launch
/// encodes A/B/C into panels exactly once, and every tile extraction after
/// that is a plane-row `memcpy` instead of a per-element `ApFloat`
/// materialization.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanePanel {
    planes: PlaneBatch,
    rows: usize,
    cols: usize,
}

impl PlanePanel {
    pub fn zeros(rows: usize, cols: usize, prec: u32) -> Self {
        PlanePanel { planes: PlaneBatch::zeros(rows * cols, prec), rows, cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn prec(&self) -> u32 {
        self.planes.prec
    }

    pub fn set(&mut self, r: usize, c: usize, v: &ApFloat) {
        assert!(r < self.rows && c < self.cols);
        self.planes.set(r * self.cols + c, v);
    }

    pub fn get(&self, r: usize, c: usize) -> ApFloat {
        assert!(r < self.rows && c < self.cols);
        self.planes.get(r * self.cols + c)
    }

    /// Extract a `tn x tm` tile at (r0, c0) into a caller-owned batch
    /// (lane `i * tm + j`), zero-padding positions outside the panel —
    /// APFP zero is absorbing for mul and identity for add, exactly how
    /// the hardware pads partial tiles.  Pure plane-row copies: no
    /// per-element decode, no allocation once `out` has capacity.
    // apfp-lint: no_alloc
    pub fn extract_tile_into(
        &self,
        r0: usize,
        c0: usize,
        tn: usize,
        tm: usize,
        out: &mut PlaneBatch,
    ) {
        out.reset(tn * tm, self.planes.prec);
        if c0 >= self.cols {
            return;
        }
        let w = tm.min(self.cols - c0);
        let l8 = self.planes.limbs8;
        for i in 0..tn {
            let r = r0 + i;
            if r >= self.rows {
                break;
            }
            let s = r * self.cols + c0;
            let d = i * tm;
            out.sign[d..d + w].copy_from_slice(&self.planes.sign[s..s + w]);
            out.exp[d..d + w].copy_from_slice(&self.planes.exp[s..s + w]);
            out.mant[d * l8..(d + w) * l8]
                .copy_from_slice(&self.planes.mant[s * l8..(s + w) * l8]);
        }
    }

    /// Write a `rows x cols` region of a tile batch back into the panel at
    /// (r0, c0) — the inverse of [`PlanePanel::extract_tile_into`], used to
    /// land completed C tiles in a device-resident panel without decoding a
    /// single element.  `stride` is the tile's full row width (`tile_m`):
    /// row `i` of the region occupies batch lanes
    /// `i * stride .. i * stride + cols`, so a band/edge-clipped tile
    /// writes only the elements it owns and the padding lanes never leave
    /// the batch.  Pure plane-row copies; never allocates.
    // apfp-lint: no_alloc
    pub fn write_tile(
        &mut self,
        r0: usize,
        c0: usize,
        rows: usize,
        cols: usize,
        stride: usize,
        b: &PlaneBatch,
    ) {
        assert_eq!(b.prec, self.planes.prec, "tile precision vs panel");
        assert!(cols <= stride, "owned columns exceed the tile row stride");
        assert!(
            r0 + rows <= self.rows && c0 + cols <= self.cols,
            "clipped tile escapes the panel: ({r0},{c0}) + {rows}x{cols} vs {}x{}",
            self.rows,
            self.cols
        );
        assert!(rows * stride <= b.len(), "tile batch too small for the region");
        let l8 = self.planes.limbs8;
        for i in 0..rows {
            let s = i * stride;
            let d = (r0 + i) * self.cols + c0;
            self.planes.sign[d..d + cols].copy_from_slice(&b.sign[s..s + cols]);
            self.planes.exp[d..d + cols].copy_from_slice(&b.exp[s..s + cols]);
            self.planes.mant[d * l8..(d + cols) * l8]
                .copy_from_slice(&b.mant[s * l8..(s + cols) * l8]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{self, Rng};

    const P: u32 = 448;

    fn rand_ap(rng: &mut Rng, prec: u32) -> ApFloat {
        let n = (prec / 64) as usize;
        let mut mant = rng.limbs(n);
        mant[n - 1] |= 1 << 63;
        ApFloat::from_parts(rng.bool(), rng.range_i64(-(1 << 40), 1 << 40), mant, prec)
    }

    #[test]
    fn fig1_geometry() {
        assert_eq!(bits_for_prec(448), 512);
        assert_eq!(bits_for_prec(960), 1024);
        assert_eq!(words_for_bits(512), 8);
        assert_eq!(words_for_bits(1024), 16);
    }

    #[test]
    fn words_roundtrip_property() {
        testkit::check(200, |rng| {
            for prec in [448u32, 960] {
                let v = rand_ap(rng, prec);
                let mut w = vec![0u64; words_for_bits(bits_for_prec(prec))];
                pack_words(&v, &mut w);
                assert_eq!(unpack_words(&w, prec), v);
            }
        });
    }

    #[test]
    fn sign_bit_position() {
        let mut m = vec![0u64; 7];
        m[6] = 1 << 63;
        let pos = ApFloat::from_parts(false, 42, m.clone(), P);
        let neg = ApFloat::from_parts(true, 42, m, P);
        let mut wp = vec![0u64; 8];
        let mut wn = vec![0u64; 8];
        pack_words(&pos, &mut wp);
        pack_words(&neg, &mut wn);
        assert_eq!(wn[0], wp[0] | (1 << 63));
        assert_eq!(wn[1..], wp[1..]);
    }

    #[test]
    fn negative_exponent_two_complement() {
        let mut m = vec![0u64; 7];
        m[6] = 1 << 63;
        let v = ApFloat::from_parts(false, -1, m, P);
        let mut w = vec![0u64; 8];
        pack_words(&v, &mut w);
        assert_eq!(w[0], (1 << 63) - 1); // 63-bit -1, sign bit clear
        assert_eq!(unpack_words(&w, P), v);
    }

    #[test]
    fn zero_roundtrip() {
        let z = ApFloat::zero(P);
        let mut w = vec![0u64; 8];
        pack_words(&z, &mut w);
        assert!(unpack_words(&w, P).is_zero());
    }

    #[test]
    fn planes_roundtrip_property() {
        testkit::check(50, |rng| {
            for prec in [448u32, 960] {
                let vals: Vec<_> = (0..5)
                    .map(|i| {
                        if i == 2 {
                            ApFloat::zero(prec)
                        } else {
                            rand_ap(rng, prec)
                        }
                    })
                    .collect();
                let planes = PlaneBatch::from_slice(&vals, prec);
                assert_eq!(planes.to_vec(), vals);
            }
        });
    }

    #[test]
    fn words_and_planes_pin_each_other() {
        // Cross-representation consistency: the Fig. 1 word format and the
        // limb-plane layout must agree on every value — including zero and
        // negative-exponent lanes — at both evaluated widths.
        testkit::check(100, |rng| {
            for prec in [448u32, 960] {
                let n = (prec / 64) as usize;
                let mut neg_exp = rng.limbs(n);
                neg_exp[n - 1] |= 1 << 63;
                let vals = [
                    rand_ap(rng, prec),
                    ApFloat::zero(prec),
                    ApFloat::from_parts(rng.bool(), -rng.range_i64(1, 1 << 40), neg_exp, prec),
                ];
                let planes = PlaneBatch::from_slice(&vals, prec);
                let mut w = vec![0u64; words_for_bits(bits_for_prec(prec))];
                for (i, v) in vals.iter().enumerate() {
                    pack_words(v, &mut w);
                    let from_words = unpack_words(&w, prec);
                    let from_planes = planes.get(i);
                    assert_eq!(&from_words, v, "words roundtrip lane {i} prec {prec}");
                    assert_eq!(&from_planes, v, "planes roundtrip lane {i} prec {prec}");
                    assert_eq!(from_words, from_planes, "formats disagree lane {i} prec {prec}");
                }
            }
        });
    }

    #[test]
    fn get_into_reuses_buffers_across_lanes_and_widths() {
        let mut rng = Rng::from_seed(77);
        let vals = [rand_ap(&mut rng, 448), ApFloat::zero(448), rand_ap(&mut rng, 448)];
        let planes = PlaneBatch::from_slice(&vals, 448);
        let mut out = rand_ap(&mut rng, 448);
        let ptr = out.limbs().as_ptr();
        for (i, v) in vals.iter().enumerate() {
            planes.get_into(i, &mut out);
            assert_eq!(&out, v, "lane {i}");
            assert_eq!(out.limbs().as_ptr(), ptr, "same-width decode must not reallocate");
        }
        // width change reallocates once, then decodes correctly
        let wide = [rand_ap(&mut rng, 960)];
        let wide_planes = PlaneBatch::from_slice(&wide, 960);
        wide_planes.get_into(0, &mut out);
        assert_eq!(out, wide[0]);
    }

    #[test]
    fn panel_tile_extraction_matches_per_element_reads() {
        let mut rng = Rng::from_seed(99);
        let (rows, cols) = (7usize, 9usize);
        let mut panel = PlanePanel::zeros(rows, cols, 448);
        let mut vals = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let v = if (r + c) % 5 == 0 { ApFloat::zero(448) } else { rand_ap(&mut rng, 448) };
                panel.set(r, c, &v);
                vals.push(v);
            }
        }
        let mut tile = PlaneBatch::default();
        // interior, right-edge, bottom-edge, and fully-padded corners
        for (r0, c0, tn, tm) in [(1, 2, 4, 4), (0, 6, 4, 4), (5, 0, 4, 4), (6, 8, 4, 4)] {
            panel.extract_tile_into(r0, c0, tn, tm, &mut tile);
            assert_eq!(tile.len(), tn * tm);
            for i in 0..tn {
                for j in 0..tm {
                    let want = if r0 + i < rows && c0 + j < cols {
                        vals[(r0 + i) * cols + (c0 + j)].clone()
                    } else {
                        ApFloat::zero(448)
                    };
                    assert_eq!(tile.get(i * tm + j), want, "tile ({r0},{c0}) elem ({i},{j})");
                }
            }
        }
        // out-of-range column origin yields an all-zero tile
        panel.extract_tile_into(0, 20, 2, 2, &mut tile);
        assert!(tile.to_vec().iter().all(|v| v.is_zero()));
    }

    #[test]
    fn panel_write_tile_roundtrips_and_ignores_padding_lanes() {
        let mut rng = Rng::from_seed(123);
        let (rows, cols) = (6usize, 7usize);
        let mut panel = PlanePanel::zeros(rows, cols, 448);
        let mut vals = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let v = rand_ap(&mut rng, 448);
                panel.set(r, c, &v);
                vals.push(v);
            }
        }
        let reference = panel.clone();

        // extract an edge tile (4x4 at (4,4): only 2x3 in range), poison the
        // padding lanes, write the owned region back: panel must be unchanged
        let (tn, tm) = (4usize, 4usize);
        let (r0, c0) = (4usize, 4usize);
        let mut tile = PlaneBatch::default();
        panel.extract_tile_into(r0, c0, tn, tm, &mut tile);
        let (owned_rows, owned_cols) = (rows - r0, cols - c0);
        let poison = rand_ap(&mut rng, 448);
        for i in 0..tn {
            for j in 0..tm {
                if i >= owned_rows || j >= owned_cols {
                    tile.set(i * tm + j, &poison);
                }
            }
        }
        panel.write_tile(r0, c0, owned_rows, owned_cols, tm, &tile);
        assert_eq!(panel, reference, "padding lanes must never land in the panel");

        // an interior tile actually moves data
        let v = rand_ap(&mut rng, 448);
        let mut tile2 = PlaneBatch::zeros(tn * tm, 448);
        tile2.set(tm + 2, &v);
        panel.write_tile(0, 0, tn, tm, tm, &tile2);
        assert_eq!(panel.get(1, 2), v);
        assert_eq!(panel.get(4, 4), vals[4 * cols + 4], "outside the write is untouched");
    }

    #[test]
    fn fixed_plane_decode_matches_dynamic_decode() {
        use crate::softfloat::{ApFloat448, ApFloat960};
        testkit::check(100, |rng| {
            let vals = [rand_ap(rng, 448), ApFloat::zero(448), rand_ap(rng, 448)];
            let planes = PlaneBatch::from_slice(&vals, 448);
            for (i, v) in vals.iter().enumerate() {
                let mut fx = ApFloat448::ZERO;
                planes.get_fixed_into(i, &mut fx);
                assert_eq!(fx.to_ap(), *v, "448 lane {i}");
            }
            let vals = [ApFloat::zero(960), rand_ap(rng, 960)];
            let planes = PlaneBatch::from_slice(&vals, 960);
            for (i, v) in vals.iter().enumerate() {
                let mut fx = ApFloat960::ZERO;
                planes.get_fixed_into(i, &mut fx);
                assert_eq!(fx.to_ap(), *v, "960 lane {i}");
            }
        });
    }

    #[test]
    fn fixed_plane_encode_matches_dynamic_encode() {
        use crate::softfloat::ApFloat448;
        testkit::check(100, |rng| {
            let v = rand_ap(rng, 448);
            let fx = ApFloat448::from_ap(&v);
            let mut dynamic = PlaneBatch::zeros(2, 448);
            let mut fixed = PlaneBatch::zeros(2, 448);
            dynamic.set(0, &v);
            fixed.set_fixed(0, &fx);
            dynamic.set(1, &ApFloat::zero(448));
            fixed.set_fixed(1, &ApFloat448::ZERO);
            assert_eq!(dynamic, fixed, "byte planes must be identical");
        });
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn fixed_plane_decode_rejects_width_mismatch() {
        let planes = PlaneBatch::zeros(1, 448);
        let mut fx = crate::softfloat::ApFloat960::ZERO;
        planes.get_fixed_into(0, &mut fx);
    }

    #[test]
    fn plane_limbs_are_bytes_little_endian() {
        let v = ApFloat::from_i64(1, P); // mantissa = 2^447
        let b = PlaneBatch::from_slice(std::slice::from_ref(&v), P);
        assert_eq!(b.limbs8, 56);
        assert_eq!(b.mant[55], 0x80); // MSB limb holds the top byte
        assert!(b.mant[..55].iter().all(|&x| x == 0));
    }
}
