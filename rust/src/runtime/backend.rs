//! Pluggable execution backends for the [`super::Runtime`].
//!
//! The paper's §IV-B interface is "plug-and-play": the host runtime does not
//! care what executes a kernel as long as the results are bit-exact.  The
//! reproduction mirrors that with a [`Backend`] trait over limb-plane
//! batches and three implementations:
//!
//! * [`XlaBackend`] (here) — the AOT-artifact path through the PJRT CPU
//!   client; offline builds compile against the stub in `runtime/xla.rs`
//!   and fail cleanly at construction, exactly as before the refactor;
//! * [`super::NativeBackend`] — in-process execution of the same artifact
//!   semantics on the arena-backed softfloat pipeline, the bit-exact
//!   software twin the device stack is validated against;
//! * [`super::SimBackend`] — the native backend wrapped in the hardware
//!   model: every tile also accrues a modeled [`TileModelCost`]
//!   (cycles / DRAM traffic / compute+mem time from
//!   [`crate::hwmodel`] + [`crate::sim`]), drained by the coordinator
//!   into the device's `ModelMetrics` ledger.
//!
//! Selection: `$APFP_BACKEND` (`native` | `sim` | `xla`, default
//! `native`), or explicitly through
//! [`crate::config::ApfpConfig::backend`] /
//! [`super::Runtime::with_backend`].

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Result};

use super::manifest::ArtifactMeta;
use super::xla;
use crate::pack::PlaneBatch;
use crate::softfloat::ZERO_EXP;

/// Which execution backend a runtime (and the devices/workers above it)
/// drives.
///
/// ```
/// use apfp::runtime::BackendKind;
///
/// assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
/// assert_eq!(BackendKind::parse("PJRT"), Some(BackendKind::Xla));
/// assert_eq!(BackendKind::parse("sim"), Some(BackendKind::Sim));
/// assert_eq!(BackendKind::parse("fpga"), None);
/// assert_eq!(BackendKind::Xla.to_string(), "xla");
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// In-process softfloat execution of the artifact semantics.
    Native,
    /// Native execution plus hardware-model cost accounting per tile.
    Sim,
    /// AOT HLO artifacts through the PJRT CPU client (`xla` crate).
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(Self::Native),
            "sim" | "simulator" => Some(Self::Sim),
            "xla" | "pjrt" => Some(Self::Xla),
            _ => None,
        }
    }

    /// `$APFP_BACKEND`, defaulting to [`BackendKind::Native`] (which works
    /// on a clean checkout with no artifacts).  Unrecognized values warn on
    /// stderr and fall back to native rather than failing a whole run.
    pub fn from_env() -> Self {
        match std::env::var("APFP_BACKEND") {
            Ok(v) => Self::parse(&v).unwrap_or_else(|| {
                eprintln!("APFP_BACKEND={v:?} not recognized (native|sim|xla); using native");
                Self::Native
            }),
            Err(_) => Self::Native,
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Native => "native",
            Self::Sim => "sim",
            Self::Xla => "xla",
        })
    }
}

/// Modeled hardware cost of executed tile work, accumulated by
/// [`super::SimBackend`] and drained by the coordinator's worker loop once
/// per settled tile reply.
///
/// Times are in integer **picoseconds** so the struct stays `Copy` and the
/// coordinator can sum it with relaxed atomics on the zero-alloc drain
/// path; the `ModelMetrics` snapshot converts back to seconds.  All fields
/// follow the per-compute-unit convention: costs are what *one* CU spends
/// on the tiles it executed (the device-level ledger sums over CUs, which
/// for the compute/cycle terms models the per-CU share of the paper's
/// `sim::gemm_sim` aggregate).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TileModelCost {
    /// Modeled datapath cycles (II-adjusted MAC issue + pipeline drain).
    pub cycles: u64,
    /// Useful MAC lanes in the modeled tiles (rows x cols x k).
    pub macs: u64,
    /// Modeled DRAM-bank traffic in bytes (A strided + B + C contiguous).
    pub dram_bytes: u64,
    /// Modeled compute time in picoseconds (`cycles / f_achievable`).
    pub compute_ps: u64,
    /// Modeled DRAM streaming time in picoseconds (bank-shared bandwidth
    /// with the contiguous/strided efficiency split).
    pub mem_ps: u64,
    /// Modeled dynamic energy in picojoules (DSP + CLB activity over the
    /// compute interval).
    pub energy_pj: u64,
}

impl TileModelCost {
    /// Saturating field-wise sum — model accounting must never panic on
    /// the device stack's hot path.
    pub fn add(&mut self, other: &TileModelCost) {
        self.cycles = self.cycles.saturating_add(other.cycles);
        self.macs = self.macs.saturating_add(other.macs);
        self.dram_bytes = self.dram_bytes.saturating_add(other.dram_bytes);
        self.compute_ps = self.compute_ps.saturating_add(other.compute_ps);
        self.mem_ps = self.mem_ps.saturating_add(other.mem_ps);
        self.energy_pj = self.energy_pj.saturating_add(other.energy_pj);
    }

    /// True when no modeled work has been recorded.
    pub fn is_zero(&self) -> bool {
        *self == TileModelCost::default()
    }
}

/// One execution engine over limb-plane batches.
///
/// Implementations must be *bit-exact*: every output lane equals the
/// corresponding RNDZ softfloat operator (`mul`/`add`/`mac`, and the
/// sequential-K tile accumulation for GEMM) — the acceptance criterion the
/// paper applies to its FPGA against MPFR, and what the integration tests
/// assert against `baseline::gemm_serial`.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Pre-compile / warm one artifact (no-op for backends with nothing to
    /// compile).
    fn warm(&self, _meta: &ArtifactMeta) -> Result<()> {
        Ok(())
    }

    /// Element-wise binary stream operator (`mul` / `add` artifact kinds)
    /// on arbitrary-length batches.
    fn exec_stream_binop(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch>;

    /// Element-wise ternary MAC stream: `c + a*b` per lane.
    fn exec_stream_mac(
        &self,
        meta: &ArtifactMeta,
        c: &PlaneBatch,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch>;

    /// One GEMM tile K-step in place: `c += a @ b` at the artifact's fixed
    /// shapes (A: `t_n x k_tile`, B: `k_tile x t_m`, C: `t_n x t_m`;
    /// callers zero-pad partial tiles).  Updating `c` in place keeps the
    /// accumulator tile "on chip" across K steps with no per-step
    /// allocation.
    fn exec_gemm_tile(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
        c: &mut PlaneBatch,
    ) -> Result<()>;

    /// Drain the modeled cost accumulated since the previous drain.
    ///
    /// Backends without a hardware model (native, xla) return `None`; the
    /// simulator returns the per-tile ledger and resets it.  The worker
    /// loop drains after every tile job so a retried tile's cost cannot
    /// leak into a later reply.
    fn take_model_cost(&self) -> Option<TileModelCost> {
        None
    }
}

// ---------------------------------------------------------------------------
// The XLA/PJRT backend (the path the real hardware artifacts take).
// ---------------------------------------------------------------------------

/// PJRT execution of AOT HLO-text artifacts.  One instance is
/// **thread-local by construction** (the `xla` crate's `PjRtClient` is
/// `Rc`-based); the coordinator gives each compute-unit worker its own.
pub struct XlaBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl XlaBackend {
    /// Create the PJRT CPU client over an artifact directory.  With the
    /// offline stub this fails with a clear "backend unavailable" error and
    /// the callers degrade exactly as before (workers report per job).
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(XlaBackend {
            client,
            dir: artifact_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Lazily compile + cache an executable (compile once, like programming
    /// the bitstream before timing anything).
    fn executable(&self, meta: &ArtifactMeta) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(&meta.name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", meta.name))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    // ---- plane <-> literal marshaling -------------------------------------

    fn literals_for(&self, b: &PlaneBatch, dims: &[i64]) -> Result<[xla::Literal; 3]> {
        let limbs = b.limbs8 as i64;
        let mut mant_dims: Vec<i64> = dims.to_vec();
        mant_dims.push(limbs);
        let sign = xla::Literal::vec1(&b.sign)
            .reshape(dims)
            .map_err(|e| anyhow!("sign reshape: {e:?}"))?;
        let exp = xla::Literal::vec1(&b.exp)
            .reshape(dims)
            .map_err(|e| anyhow!("exp reshape: {e:?}"))?;
        let mant = xla::Literal::vec1(&b.mant)
            .reshape(&mant_dims)
            .map_err(|e| anyhow!("mant reshape: {e:?}"))?;
        Ok([sign, exp, mant])
    }

    fn batch_from_literals(
        &self,
        parts: Vec<xla::Literal>,
        len: usize,
        limbs: usize,
        prec: u32,
    ) -> Result<PlaneBatch> {
        let [sign_lit, exp_lit, mant_lit] = parts.as_slice() else {
            anyhow::bail!("artifact must return (sign, exp, mant), got {} parts", parts.len());
        };
        let sign = sign_lit.to_vec::<i32>().map_err(|e| anyhow!("sign: {e:?}"))?;
        let exp = exp_lit.to_vec::<i64>().map_err(|e| anyhow!("exp: {e:?}"))?;
        let mant = mant_lit.to_vec::<i32>().map_err(|e| anyhow!("mant: {e:?}"))?;
        if sign.len() != len || mant.len() != len * limbs {
            return Err(anyhow!(
                "artifact output shape mismatch: sign {} mant {} (expect {len} x {limbs})",
                sign.len(),
                mant.len()
            ));
        }
        Ok(PlaneBatch { sign, exp, mant, limbs8: limbs, prec })
    }

    fn run(&self, meta: &ArtifactMeta, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(meta)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", meta.name))?;
        let lit = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("empty result from {}", meta.name))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e:?}", meta.name))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {}: {e:?}", meta.name))
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn warm(&self, meta: &ArtifactMeta) -> Result<()> {
        self.executable(meta).map(|_| ())
    }

    /// Arbitrary-length batches run in chunks of the artifact's fixed
    /// `batch`, zero-padded at the tail.
    fn exec_stream_binop(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        anyhow::ensure!(a.len() == b.len(), "stream operand length mismatch");
        let batch = meta.batch;
        let limbs = meta.limbs;
        let prec = meta.prec();
        let mut out = PlaneBatch::zeros(a.len(), prec);
        let mut start = 0;
        while start < a.len() {
            let n = (a.len() - start).min(batch);
            let pa = pad_slice(a, start, n, batch);
            let pb = pad_slice(b, start, n, batch);
            let ia = self.literals_for(&pa, &[batch as i64])?;
            let ib = self.literals_for(&pb, &[batch as i64])?;
            let inputs: Vec<xla::Literal> = ia.into_iter().chain(ib).collect();
            let parts = self.run(meta, &inputs)?;
            let chunk = self.batch_from_literals(parts, batch, limbs, prec)?;
            copy_into(&mut out, start, &chunk, n);
            start += n;
        }
        Ok(out)
    }

    fn exec_stream_mac(
        &self,
        meta: &ArtifactMeta,
        c: &PlaneBatch,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        anyhow::ensure!(
            a.len() == b.len() && a.len() == c.len(),
            "stream operand length mismatch"
        );
        let batch = meta.batch;
        let limbs = meta.limbs;
        let prec = meta.prec();
        let mut out = PlaneBatch::zeros(a.len(), prec);
        let mut start = 0;
        while start < a.len() {
            let n = (a.len() - start).min(batch);
            let pc = pad_slice(c, start, n, batch);
            let pa = pad_slice(a, start, n, batch);
            let pb = pad_slice(b, start, n, batch);
            let ic = self.literals_for(&pc, &[batch as i64])?;
            let ia = self.literals_for(&pa, &[batch as i64])?;
            let ib = self.literals_for(&pb, &[batch as i64])?;
            let inputs: Vec<xla::Literal> = ic.into_iter().chain(ia).chain(ib).collect();
            let parts = self.run(meta, &inputs)?;
            let chunk = self.batch_from_literals(parts, batch, limbs, prec)?;
            copy_into(&mut out, start, &chunk, n);
            start += n;
        }
        Ok(out)
    }

    fn exec_gemm_tile(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
        c: &mut PlaneBatch,
    ) -> Result<()> {
        let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
        let ia = self.literals_for(a, &[tn as i64, kt as i64])?;
        let ib = self.literals_for(b, &[kt as i64, tm as i64])?;
        let ic = self.literals_for(c, &[tn as i64, tm as i64])?;
        let inputs: Vec<xla::Literal> = ia.into_iter().chain(ib).chain(ic).collect();
        let parts = self.run(meta, &inputs)?;
        *c = self.batch_from_literals(parts, tn * tm, meta.limbs, meta.prec())?;
        Ok(())
    }
}

/// Extract `n` rows starting at `start`, zero-padded to `batch` rows.
/// Padding rows are APFP zero (absorbing for mul, identity for add), so
/// padded lanes never contaminate real outputs.
fn pad_slice(src: &PlaneBatch, start: usize, n: usize, batch: usize) -> PlaneBatch {
    let mut out = PlaneBatch::zeros(batch, src.prec);
    out.sign[..n].copy_from_slice(&src.sign[start..start + n]);
    out.exp[..n].copy_from_slice(&src.exp[start..start + n]);
    out.mant[..n * src.limbs8]
        .copy_from_slice(&src.mant[start * src.limbs8..(start + n) * src.limbs8]);
    for e in out.exp[n..].iter_mut() {
        *e = ZERO_EXP;
    }
    out
}

fn copy_into(dst: &mut PlaneBatch, start: usize, src: &PlaneBatch, n: usize) {
    dst.sign[start..start + n].copy_from_slice(&src.sign[..n]);
    dst.exp[start..start + n].copy_from_slice(&src.exp[..n]);
    dst.mant[start * dst.limbs8..(start + n) * dst.limbs8]
        .copy_from_slice(&src.mant[..n * src.limbs8]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parses_both_names_and_env_synonyms() {
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("NATIVE"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("sim"), Some(BackendKind::Sim));
        assert_eq!(BackendKind::parse("SIM"), Some(BackendKind::Sim));
        assert_eq!(BackendKind::parse("simulator"), Some(BackendKind::Sim));
        assert_eq!(BackendKind::parse("xla"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("tpu"), None);
        assert_eq!(BackendKind::Native.to_string(), "native");
        assert_eq!(BackendKind::Sim.to_string(), "sim");
        assert_eq!(BackendKind::Xla.to_string(), "xla");
    }

    #[test]
    fn tile_model_cost_sums_saturating_and_reports_zero() {
        let mut acc = TileModelCost::default();
        assert!(acc.is_zero());
        let one = TileModelCost {
            cycles: 3,
            macs: 2,
            dram_bytes: 5,
            compute_ps: 7,
            mem_ps: 11,
            energy_pj: 13,
        };
        acc.add(&one);
        acc.add(&one);
        assert_eq!(acc.cycles, 6);
        assert_eq!(acc.macs, 4);
        assert_eq!(acc.dram_bytes, 10);
        assert_eq!(acc.compute_ps, 14);
        assert_eq!(acc.mem_ps, 22);
        assert_eq!(acc.energy_pj, 26);
        assert!(!acc.is_zero());
        let big = TileModelCost { cycles: u64::MAX, ..TileModelCost::default() };
        acc.add(&big);
        assert_eq!(acc.cycles, u64::MAX, "saturates instead of panicking");
    }

    #[test]
    fn offline_xla_backend_fails_at_construction() {
        // With the offline stub the client cannot be built; the error is
        // what workers degrade on.
        let err = match XlaBackend::new(Path::new("/nonexistent")) {
            Err(e) => format!("{e:#}"),
            Ok(_) => return, // a real xla crate is linked in: nothing to assert
        };
        assert!(err.contains("PJRT"), "unexpected error: {err}");
    }
}
