//! Artifact manifest parsing and builtin-manifest synthesis.
//!
//! `make artifacts` writes `artifacts/manifest.txt` with one line per AOT
//! artifact (see python/compile/aot.py):
//!
//! ```text
//!     name kind bits batch t_n t_m k_tile limbs file
//! ```
//!
//! `kind` is one of `mul`/`add`/`mac` (stream operators, fixed batch) or
//! `gemm` (the tile datapath, shapes t_n x k_tile / k_tile x t_m).
//!
//! When no manifest exists on disk, the native backend synthesizes one in
//! memory with [`builtin`], shaping the GEMM tile to the configured
//! [`TileShape`] — the host-side analog of re-synthesizing the bitstream
//! for a different `APFP_TILE_SIZE_N/M` (§IV-A).

use std::path::{Path, PathBuf};

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("cannot read manifest {path}: {source}")]
    Io { path: PathBuf, source: std::io::Error },
    #[error("malformed manifest line {line}: {text:?}")]
    Malformed { line: usize, text: String },
    #[error("builtin manifest needs bits to be a multiple of 64 with at least one \
             mantissa limb under the 64-bit head (>= 128), got {0}")]
    InvalidBits(u32),
    #[error(
        "no {kind:?} artifact at {bits} bits — loaded widths: {loaded:?}; \
         run `make artifacts` or extend APFP_WIDTHS"
    )]
    NoArtifact { kind: ArtifactKind, bits: u32, loaded: Vec<u32> },
    #[error("degenerate tile geometry {n}x{m}x{k}: {reason}")]
    InvalidTile { n: usize, m: usize, k: usize, reason: &'static str },
    #[error("malformed environment override {key}={value:?}: expected a positive integer")]
    MalformedEnv { key: &'static str, value: String },
}

/// Hard cap on any single builtin tile dimension.  A tile is a *compute
/// unit's* working set (decoded operand slots live per worker); dimensions
/// beyond this are configuration mistakes, not workloads, and are rejected
/// with a typed error instead of exhausting memory downstream.
pub const MAX_TILE_DIM: usize = 1024;

/// The GEMM tile geometry of a compute unit: `n x m` output tiles
/// accumulated over `k`-deep K steps (the paper's `APFP_TILE_SIZE_N` /
/// `APFP_TILE_SIZE_M` CMake knobs, plus the K-step depth of the §III
/// datapath).
///
/// ```
/// use apfp::runtime::manifest::TileShape;
///
/// let t = TileShape { n: 16, m: 8, k: 4 };
/// t.validate().unwrap();
/// assert_eq!(t.suffix(), "t16x8x4");
/// assert_eq!(TileShape::default().suffix(), "t32"); // uniform tiles abbreviate
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileShape {
    /// Output tile rows per compute unit.
    pub n: usize,
    /// Output tile columns per compute unit.
    pub m: usize,
    /// Inner-dimension depth of one K step.
    pub k: usize,
}

impl Default for TileShape {
    /// The paper's evaluated 32x32 output tile, with a matching K depth.
    fn default() -> Self {
        TileShape { n: 32, m: 32, k: 32 }
    }
}

impl TileShape {
    /// Reject degenerate geometry (zero or absurdly large tiles) with a
    /// typed error.  Called by [`builtin`] and by
    /// [`crate::config::ApfpConfig::validate`], so a bad shape surfaces at
    /// configuration time instead of panicking in a worker thread.
    pub fn validate(&self) -> Result<(), ManifestError> {
        let err =
            |reason| Err(ManifestError::InvalidTile { n: self.n, m: self.m, k: self.k, reason });
        if self.n == 0 || self.m == 0 || self.k == 0 {
            return err("tile dimensions must be >= 1");
        }
        if self.n > MAX_TILE_DIM || self.m > MAX_TILE_DIM || self.k > MAX_TILE_DIM {
            return err("tile dimension exceeds MAX_TILE_DIM");
        }
        Ok(())
    }

    /// Artifact-name suffix: `t8` for uniform 8x8x8 tiles (the historical
    /// builtin name), `t16x8x4` otherwise.
    pub fn suffix(&self) -> String {
        if self.n == self.m && self.m == self.k {
            format!("t{}", self.n)
        } else {
            format!("t{}x{}x{}", self.n, self.m, self.k)
        }
    }

    /// One dimension from the env: the short spelling wins, then the long
    /// one; `Ok(None)` when neither is set, a typed [`ManifestError`] when
    /// a set value does not parse as a tile size.
    fn env_dim<F>(lookup: &F, short: &'static str, long: &'static str)
        -> Result<Option<usize>, ManifestError>
    where
        F: Fn(&str) -> Option<String>,
    {
        for key in [short, long] {
            if let Some(v) = lookup(key) {
                return match v.trim().parse::<usize>() {
                    Ok(n) => Ok(Some(n)),
                    Err(_) => Err(ManifestError::MalformedEnv { key, value: v }),
                };
            }
        }
        Ok(None)
    }

    /// Strict [`TileShape::from_env`] with an injectable environment:
    /// a malformed `APFP_TILE_*` value is a typed [`ManifestError`]
    /// naming the offending key, not a silent fallback.  `lookup` stands
    /// in for `std::env::var` so tests can drive it without mutating
    /// process state (env writes race under the parallel test harness).
    pub fn try_from_env_with<F>(lookup: F) -> Result<Self, ManifestError>
    where
        F: Fn(&str) -> Option<String>,
    {
        let d = TileShape::default();
        Ok(TileShape {
            n: Self::env_dim(&lookup, "APFP_TILE_N", "APFP_TILE_SIZE_N")?.unwrap_or(d.n),
            m: Self::env_dim(&lookup, "APFP_TILE_M", "APFP_TILE_SIZE_M")?.unwrap_or(d.m),
            k: Self::env_dim(&lookup, "APFP_TILE_K", "APFP_TILE_SIZE_K")?.unwrap_or(d.k),
        })
    }

    /// [`TileShape::try_from_env_with`] against the process environment.
    pub fn try_from_env() -> Result<Self, ManifestError> {
        Self::try_from_env_with(|key| std::env::var(key).ok())
    }

    /// Tile geometry from `APFP_TILE_N` / `APFP_TILE_M` / `APFP_TILE_K`
    /// (long forms `APFP_TILE_SIZE_*` also accepted), defaulting each
    /// missing dimension.  Unparsable values warn on stderr and fall back
    /// to the default rather than failing a whole run — the same contract
    /// as `APFP_BACKEND`; strict callers use [`TileShape::try_from_env`],
    /// and validation still happens at device construction.
    pub fn from_env() -> Self {
        let lookup = |key: &str| std::env::var(key).ok();
        let d = TileShape::default();
        let lenient = |short, long, default| match Self::env_dim(&lookup, short, long) {
            Ok(Some(n)) => n,
            Ok(None) => default,
            Err(e) => {
                eprintln!("{e}; using {default}");
                default
            }
        };
        TileShape {
            n: lenient("APFP_TILE_N", "APFP_TILE_SIZE_N", d.n),
            m: lenient("APFP_TILE_M", "APFP_TILE_SIZE_M", d.m),
            k: lenient("APFP_TILE_K", "APFP_TILE_SIZE_K", d.k),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Mul,
    Add,
    Mac,
    Gemm,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "mul" => Some(Self::Mul),
            "add" => Some(Self::Add),
            "mac" => Some(Self::Mac),
            "gemm" => Some(Self::Gemm),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    /// total packed bits (e.g. 128 / 512 / 1024)
    pub bits: u32,
    /// stream batch (0 for gemm)
    pub batch: usize,
    pub t_n: usize,
    pub t_m: usize,
    pub k_tile: usize,
    /// mantissa limbs in the plane layout (8-bit limbs)
    pub limbs: usize,
    /// HLO text file, relative to the artifact directory
    pub file: String,
}

impl ArtifactMeta {
    pub fn prec(&self) -> u32 {
        (self.limbs * 8) as u32
    }

    /// Synthesize the hardware-model design point this artifact stands in
    /// for: the paper's evaluated configuration at this packed width
    /// (72-bit multiplier bottom-out, 64-bit adder base — Tab. I/II), with
    /// the GEMM datapath flag set from the artifact kind.
    ///
    /// The point is a **single compute unit**: the simulator backend runs
    /// inside one worker thread per CU, so each worker models its own CU
    /// and the device-level ledger sums over them.  `sim::gemm_sim` keeps
    /// modeling the aggregate device for the sweep benches.
    pub fn design_point(&self) -> crate::hwmodel::DesignPoint {
        crate::hwmodel::DesignPoint {
            bits: self.bits,
            compute_units: 1,
            mult_base_bits: 72,
            add_base_bits: 64,
            gemm: self.kind == ArtifactKind::Gemm,
        }
    }
}

/// The in-memory manifest the native backend synthesizes when no artifact
/// directory exists: the stream operators plus a GEMM tile at the
/// configured [`TileShape`], at one packed width.  Names match what
/// `make artifacts` would emit (`mul_512`, ..., `gemm_512_t8`), so tests
/// and callers address builtin and on-disk artifacts identically.
///
/// Degenerate geometry (zero, oversized tiles, bad packing width) is a
/// typed [`ManifestError`], never a panic — `Device::new` surfaces it
/// before any worker spawns.
pub fn builtin(bits: u32, tile: TileShape) -> Result<Vec<ArtifactMeta>, ManifestError> {
    if bits % 64 != 0 || bits < 128 {
        return Err(ManifestError::InvalidBits(bits));
    }
    tile.validate()?;
    let limbs = ((bits - 64) / 8) as usize;
    let stream = |prefix: &str, kind: ArtifactKind| ArtifactMeta {
        name: format!("{prefix}_{bits}"),
        kind,
        bits,
        batch: 64,
        t_n: 0,
        t_m: 0,
        k_tile: 0,
        limbs,
        file: "<builtin>".to_string(),
    };
    Ok(vec![
        stream("mul", ArtifactKind::Mul),
        stream("add", ArtifactKind::Add),
        stream("mac", ArtifactKind::Mac),
        ArtifactMeta {
            name: format!("gemm_{bits}_{}", tile.suffix()),
            kind: ArtifactKind::Gemm,
            bits,
            batch: 0,
            t_n: tile.n,
            t_m: tile.m,
            k_tile: tile.k,
            limbs,
            file: "<builtin>".to_string(),
        },
    ])
}

/// The packed widths a builtin device hosts by default: the paper's two
/// evaluated widths plus the 128-bit short width (one mantissa limb —
/// the bulk lane of mixed-precision refinement, cf. arXiv 2306.04087).
pub const DEFAULT_WIDTHS: [u32; 3] = [128, 512, 1024];

/// Builtin manifests for an explicit set of packed widths, tiled to one
/// configured shape.  Duplicate widths are rejected as [`InvalidBits`]
/// (a device keys kernel state by width, so each may appear once).
///
/// [`InvalidBits`]: ManifestError::InvalidBits
pub fn builtin_widths(widths: &[u32], tile: TileShape) -> Result<Vec<ArtifactMeta>, ManifestError> {
    let mut all = Vec::with_capacity(4 * widths.len());
    for (i, &bits) in widths.iter().enumerate() {
        if widths[..i].contains(&bits) {
            return Err(ManifestError::InvalidBits(bits));
        }
        all.extend(builtin(bits, tile)?);
    }
    Ok(all)
}

/// Builtin manifests for every default width ([`DEFAULT_WIDTHS`]), tiled
/// to one configured shape.
pub fn builtin_all(tile: TileShape) -> Result<Vec<ArtifactMeta>, ManifestError> {
    builtin_widths(&DEFAULT_WIDTHS, tile)
}

/// Parse `<dir>/manifest.txt`.
pub fn load(dir: &Path) -> Result<Vec<ArtifactMeta>, ManifestError> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .map_err(|source| ManifestError::Io { path: path.clone(), source })?;
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mal = || ManifestError::Malformed { line: i + 1, text: raw.to_string() };
        let f: Vec<&str> = line.split_whitespace().collect();
        let &[name, kind, bits, batch, t_n, t_m, k_tile, limbs, file] = f.as_slice() else {
            return Err(mal());
        };
        out.push(ArtifactMeta {
            name: name.to_string(),
            kind: ArtifactKind::parse(kind).ok_or_else(mal)?,
            bits: bits.parse().map_err(|_| mal())?,
            batch: batch.parse().map_err(|_| mal())?,
            t_n: t_n.parse().map_err(|_| mal())?,
            t_m: t_m.parse().map_err(|_| mal())?,
            k_tile: k_tile.parse().map_err(|_| mal())?,
            limbs: limbs.parse().map_err(|_| mal())?,
            file: file.to_string(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unique per-call temp dir: two manifests of equal length must not
    /// collide (keying on `content.len()` raced under `cargo test`).
    fn write_manifest(content: &str) -> PathBuf {
        use std::sync::atomic::{AtomicU32, Ordering};
        static DIR_SEQ: AtomicU32 = AtomicU32::new(0);
        let dir = std::env::temp_dir().join(format!(
            "apfp_manifest_{}_{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), content).unwrap();
        dir
    }

    #[test]
    fn parses_valid_lines() {
        let dir = write_manifest(
            "# name kind bits batch t_n t_m k_tile limbs file\n\
             mul_512 mul 512 64 0 0 0 56 mul_512.hlo.txt\n\
             gemm_512_t8 gemm 512 0 8 8 8 56 gemm_512_t8.hlo.txt\n",
        );
        let m = load(&dir).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].kind, ArtifactKind::Mul);
        assert_eq!(m[0].batch, 64);
        assert_eq!(m[1].kind, ArtifactKind::Gemm);
        assert_eq!((m[1].t_n, m[1].t_m, m[1].k_tile), (8, 8, 8));
        assert_eq!(m[1].prec(), 448);
    }

    #[test]
    fn rejects_malformed() {
        let dir = write_manifest("mul_512 mul 512 64\n");
        assert!(matches!(load(&dir), Err(ManifestError::Malformed { line: 1, .. })));
        let dir = write_manifest("x unknownkind 512 64 0 0 0 56 f.hlo\n");
        assert!(matches!(load(&dir), Err(ManifestError::Malformed { .. })));
    }

    #[test]
    fn builtin_manifests_are_well_formed() {
        let tile = TileShape { n: 8, m: 8, k: 8 };
        for bits in [128u32, 512, 1024] {
            let m = builtin(bits, tile).unwrap();
            assert_eq!(m.len(), 4);
            for kind in [ArtifactKind::Mul, ArtifactKind::Add, ArtifactKind::Mac] {
                let a = m.iter().find(|a| a.kind == kind).unwrap();
                assert_eq!(a.bits, bits);
                assert!(a.batch > 0, "stream artifacts have a fixed batch");
                assert_eq!(a.prec(), bits - 64);
            }
            let g = m.iter().find(|a| a.kind == ArtifactKind::Gemm).unwrap();
            assert_eq!((g.t_n, g.t_m, g.k_tile), (8, 8, 8));
            assert_eq!(g.name, format!("gemm_{bits}_t8"), "historical uniform-tile name");
        }
        assert_eq!(builtin_all(tile).unwrap().len(), 12, "4 artifacts per default width");
        // explicit width sets compose the same entries
        assert_eq!(builtin_widths(&[512], tile).unwrap().len(), 4);
        assert_eq!(builtin_widths(&[128, 512], tile).unwrap().len(), 8);
        // duplicates are configuration mistakes, not a bigger device
        assert!(matches!(
            builtin_widths(&[512, 512], tile),
            Err(ManifestError::InvalidBits(512))
        ));
    }

    #[test]
    fn builtin_tiles_follow_the_configured_shape() {
        let m = builtin(512, TileShape { n: 16, m: 8, k: 4 }).unwrap();
        let g = m.iter().find(|a| a.kind == ArtifactKind::Gemm).unwrap();
        assert_eq!((g.t_n, g.t_m, g.k_tile), (16, 8, 4));
        assert_eq!(g.name, "gemm_512_t16x8x4");
        let d = builtin(1024, TileShape::default()).unwrap();
        let g = d.iter().find(|a| a.kind == ArtifactKind::Gemm).unwrap();
        assert_eq!(g.name, "gemm_1024_t32");
    }

    #[test]
    fn builtin_rejects_degenerate_geometry_with_typed_errors() {
        let ok = TileShape::default();
        assert!(matches!(builtin(500, ok), Err(ManifestError::InvalidBits(500))));
        assert!(matches!(builtin(0, ok), Err(ManifestError::InvalidBits(0))));
        // whole limbs but no mantissa limb under the 64-bit head
        assert!(matches!(builtin(64, ok), Err(ManifestError::InvalidBits(64))));
        for bad in [
            TileShape { n: 0, m: 8, k: 8 },
            TileShape { n: 8, m: 0, k: 8 },
            TileShape { n: 8, m: 8, k: 0 },
            TileShape { n: MAX_TILE_DIM + 1, m: 8, k: 8 },
            TileShape { n: 8, m: 8, k: MAX_TILE_DIM + 1 },
        ] {
            assert!(
                matches!(builtin(512, bad), Err(ManifestError::InvalidTile { .. })),
                "{bad:?} must be rejected"
            );
            assert!(bad.validate().is_err());
        }
        // the boundary itself is legal
        let huge = TileShape { n: MAX_TILE_DIM, m: 1, k: 1 };
        huge.validate().unwrap();
        assert!(builtin(512, huge).is_ok());
    }

    #[test]
    fn env_tile_shape_parses_both_spellings() {
        let env = |key: &str| match key {
            "APFP_TILE_N" => Some("16".to_string()),
            "APFP_TILE_SIZE_M" => Some(" 8 ".to_string()), // whitespace tolerated
            _ => None,
        };
        let t = TileShape::try_from_env_with(env).unwrap();
        assert_eq!(t, TileShape { n: 16, m: 8, k: 32 }, "unset dims keep the default");
    }

    #[test]
    fn env_tile_shape_short_form_wins() {
        let env = |key: &str| match key {
            "APFP_TILE_K" => Some("4".to_string()),
            "APFP_TILE_SIZE_K" => Some("64".to_string()),
            _ => None,
        };
        assert_eq!(TileShape::try_from_env_with(env).unwrap().k, 4);
    }

    #[test]
    fn env_tile_shape_reports_malformed_values() {
        for bad in ["abc", "-3", "3.5", "", "32x32"] {
            let env = |key: &str| (key == "APFP_TILE_SIZE_N").then(|| bad.to_string());
            match TileShape::try_from_env_with(env) {
                Err(ManifestError::MalformedEnv { key: "APFP_TILE_SIZE_N", value }) => {
                    assert_eq!(value, bad);
                }
                other => panic!("{bad:?} must be a MalformedEnv error, got {other:?}"),
            }
        }
        // the error message names the key and the offending value
        let env = |key: &str| (key == "APFP_TILE_M").then(|| "huge".to_string());
        let msg = TileShape::try_from_env_with(env).unwrap_err().to_string();
        assert!(msg.contains("APFP_TILE_M") && msg.contains("huge"), "{msg}");
    }

    #[test]
    fn env_tile_shape_empty_env_is_default() {
        assert_eq!(TileShape::try_from_env_with(|_| None).unwrap(), TileShape::default());
    }

    #[test]
    fn design_point_mirrors_the_paper_configuration() {
        let m = builtin_all(TileShape::default()).unwrap();
        for a in &m {
            let d = a.design_point();
            assert_eq!(d.bits, a.bits);
            assert_eq!(d.compute_units, 1, "one worker models one CU");
            assert_eq!((d.mult_base_bits, d.add_base_bits), (72, 64), "Tab. I/II bases");
            assert_eq!(d.gemm, a.kind == ArtifactKind::Gemm);
            let s = d.synthesize();
            assert!(s.failure.is_none(), "paper points must synthesize: {:?}", s.failure);
            assert!(s.frequency_mhz > 0.0);
        }
    }

    #[test]
    fn missing_file_is_io_error() {
        let dir = std::env::temp_dir().join("apfp_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(matches!(load(&dir), Err(ManifestError::Io { .. })));
    }
}
