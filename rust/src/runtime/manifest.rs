//! Artifact manifest parsing.
//!
//! `make artifacts` writes `artifacts/manifest.txt` with one line per AOT
//! artifact (see python/compile/aot.py):
//!
//! ```text
//!     name kind bits batch t_n t_m k_tile limbs file
//! ```
//!
//! `kind` is one of `mul`/`add`/`mac` (stream operators, fixed batch) or
//! `gemm` (the tile datapath, shapes t_n x k_tile / k_tile x t_m).

use std::path::{Path, PathBuf};

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("cannot read manifest {path}: {source}")]
    Io { path: PathBuf, source: std::io::Error },
    #[error("malformed manifest line {line}: {text:?}")]
    Malformed { line: usize, text: String },
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Mul,
    Add,
    Mac,
    Gemm,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "mul" => Some(Self::Mul),
            "add" => Some(Self::Add),
            "mac" => Some(Self::Mac),
            "gemm" => Some(Self::Gemm),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    /// total packed bits (512 / 1024)
    pub bits: u32,
    /// stream batch (0 for gemm)
    pub batch: usize,
    pub t_n: usize,
    pub t_m: usize,
    pub k_tile: usize,
    /// mantissa limbs in the plane layout (8-bit limbs)
    pub limbs: usize,
    /// HLO text file, relative to the artifact directory
    pub file: String,
}

impl ArtifactMeta {
    pub fn prec(&self) -> u32 {
        (self.limbs * 8) as u32
    }
}

/// The in-memory manifest the native backend synthesizes when no artifact
/// directory exists: the stream operators plus an 8x8x8 GEMM tile at one
/// packed width.  Names match what `make artifacts` would emit
/// (`mul_512`, ..., `gemm_512_t8`), so tests and callers address builtin
/// and on-disk artifacts identically.
pub fn builtin(bits: u32) -> Vec<ArtifactMeta> {
    assert!(bits % 512 == 0 && bits >= 512, "Fig. 1 packing");
    let limbs = ((bits - 64) / 8) as usize;
    let stream = |prefix: &str, kind: ArtifactKind| ArtifactMeta {
        name: format!("{prefix}_{bits}"),
        kind,
        bits,
        batch: 64,
        t_n: 0,
        t_m: 0,
        k_tile: 0,
        limbs,
        file: "<builtin>".to_string(),
    };
    vec![
        stream("mul", ArtifactKind::Mul),
        stream("add", ArtifactKind::Add),
        stream("mac", ArtifactKind::Mac),
        ArtifactMeta {
            name: format!("gemm_{bits}_t8"),
            kind: ArtifactKind::Gemm,
            bits,
            batch: 0,
            t_n: 8,
            t_m: 8,
            k_tile: 8,
            limbs,
            file: "<builtin>".to_string(),
        },
    ]
}

/// Builtin manifests for both packed widths the paper evaluates.
pub fn builtin_all() -> Vec<ArtifactMeta> {
    let mut all = builtin(512);
    all.extend(builtin(1024));
    all
}

/// Parse `<dir>/manifest.txt`.
pub fn load(dir: &Path) -> Result<Vec<ArtifactMeta>, ManifestError> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .map_err(|source| ManifestError::Io { path: path.clone(), source })?;
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mal = || ManifestError::Malformed { line: i + 1, text: raw.to_string() };
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 9 {
            return Err(mal());
        }
        out.push(ArtifactMeta {
            name: f[0].to_string(),
            kind: ArtifactKind::parse(f[1]).ok_or_else(mal)?,
            bits: f[2].parse().map_err(|_| mal())?,
            batch: f[3].parse().map_err(|_| mal())?,
            t_n: f[4].parse().map_err(|_| mal())?,
            t_m: f[5].parse().map_err(|_| mal())?,
            k_tile: f[6].parse().map_err(|_| mal())?,
            limbs: f[7].parse().map_err(|_| mal())?,
            file: f[8].to_string(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unique per-call temp dir: two manifests of equal length must not
    /// collide (keying on `content.len()` raced under `cargo test`).
    fn write_manifest(content: &str) -> PathBuf {
        use std::sync::atomic::{AtomicU32, Ordering};
        static DIR_SEQ: AtomicU32 = AtomicU32::new(0);
        let dir = std::env::temp_dir().join(format!(
            "apfp_manifest_{}_{}",
            std::process::id(),
            DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), content).unwrap();
        dir
    }

    #[test]
    fn parses_valid_lines() {
        let dir = write_manifest(
            "# name kind bits batch t_n t_m k_tile limbs file\n\
             mul_512 mul 512 64 0 0 0 56 mul_512.hlo.txt\n\
             gemm_512_t8 gemm 512 0 8 8 8 56 gemm_512_t8.hlo.txt\n",
        );
        let m = load(&dir).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].kind, ArtifactKind::Mul);
        assert_eq!(m[0].batch, 64);
        assert_eq!(m[1].kind, ArtifactKind::Gemm);
        assert_eq!((m[1].t_n, m[1].t_m, m[1].k_tile), (8, 8, 8));
        assert_eq!(m[1].prec(), 448);
    }

    #[test]
    fn rejects_malformed() {
        let dir = write_manifest("mul_512 mul 512 64\n");
        assert!(matches!(load(&dir), Err(ManifestError::Malformed { line: 1, .. })));
        let dir = write_manifest("x unknownkind 512 64 0 0 0 56 f.hlo\n");
        assert!(matches!(load(&dir), Err(ManifestError::Malformed { .. })));
    }

    #[test]
    fn builtin_manifests_are_well_formed() {
        for bits in [512u32, 1024] {
            let m = builtin(bits);
            assert_eq!(m.len(), 4);
            for kind in [ArtifactKind::Mul, ArtifactKind::Add, ArtifactKind::Mac] {
                let a = m.iter().find(|a| a.kind == kind).unwrap();
                assert_eq!(a.bits, bits);
                assert!(a.batch > 0, "stream artifacts have a fixed batch");
                assert_eq!(a.prec(), bits - 64);
            }
            let g = m.iter().find(|a| a.kind == ArtifactKind::Gemm).unwrap();
            assert_eq!((g.t_n, g.t_m, g.k_tile), (8, 8, 8));
            assert_eq!(g.name, format!("gemm_{bits}_t8"));
        }
        assert_eq!(builtin_all().len(), 8);
    }

    #[test]
    fn missing_file_is_io_error() {
        let dir = std::env::temp_dir().join("apfp_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(matches!(load(&dir), Err(ManifestError::Io { .. })));
    }
}
