//! PJRT execution runtime: load AOT artifacts (HLO text), compile them on
//! the PJRT CPU client, and execute them on limb-plane batches.
//!
//! This is the only place the `xla` crate is touched — in offline builds
//! via the [`xla`] stub module, which compiles the same call sites but
//! fails at client construction (workers degrade gracefully; integration
//! tests skip without artifacts).  One `Runtime` is **thread-local by
//! construction** (the crate's `PjRtClient` is `Rc`-based); the coordinator
//! gives each compute-unit worker its own `Runtime`, which is also the
//! honest analogy: each CU on the FPGA is its own replica of the circuit.
//!
//! Python never runs here: artifacts were lowered once by `make artifacts`
//! (see python/compile/aot.py and the HLO-text-vs-proto note there).

pub mod manifest;
mod xla;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

pub use manifest::{ArtifactKind, ArtifactMeta};

use crate::pack::PlaneBatch;
use crate::softfloat::ZERO_EXP;

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    metas: Vec<ArtifactMeta>,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let metas = manifest::load(artifact_dir).context("loading artifact manifest")?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: artifact_dir.to_path_buf(),
            metas,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.metas
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("no artifact named {name:?} in manifest"))
    }

    /// Pick an artifact by kind + precision (gemm: prefers the largest tile;
    /// callers pad partial tiles).
    pub fn find(&self, kind: ArtifactKind, bits: u32) -> Result<&ArtifactMeta> {
        self.metas
            .iter()
            .filter(|m| m.kind == kind && m.bits == bits)
            .max_by_key(|m| m.t_n * m.t_m)
            .ok_or_else(|| anyhow!("no {kind:?} artifact for {bits} bits"))
    }

    /// Lazily compile + cache an executable.
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.meta(name)?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Warm the executable cache (compile everything needed up front, like
    /// programming the bitstream before timing anything).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    // ---- plane <-> literal marshaling -------------------------------------

    fn literals_for(&self, b: &PlaneBatch, dims: &[i64]) -> Result<[xla::Literal; 3]> {
        let limbs = b.limbs8 as i64;
        let mut mant_dims: Vec<i64> = dims.to_vec();
        mant_dims.push(limbs);
        let sign = xla::Literal::vec1(&b.sign)
            .reshape(dims)
            .map_err(|e| anyhow!("sign reshape: {e:?}"))?;
        let exp = xla::Literal::vec1(&b.exp)
            .reshape(dims)
            .map_err(|e| anyhow!("exp reshape: {e:?}"))?;
        let mant = xla::Literal::vec1(&b.mant)
            .reshape(&mant_dims)
            .map_err(|e| anyhow!("mant reshape: {e:?}"))?;
        Ok([sign, exp, mant])
    }

    fn batch_from_literals(
        &self,
        parts: Vec<xla::Literal>,
        len: usize,
        limbs: usize,
        prec: u32,
    ) -> Result<PlaneBatch> {
        anyhow::ensure!(parts.len() == 3, "artifact must return (sign, exp, mant)");
        let sign = parts[0].to_vec::<i32>().map_err(|e| anyhow!("sign: {e:?}"))?;
        let exp = parts[1].to_vec::<i64>().map_err(|e| anyhow!("exp: {e:?}"))?;
        let mant = parts[2].to_vec::<i32>().map_err(|e| anyhow!("mant: {e:?}"))?;
        if sign.len() != len || mant.len() != len * limbs {
            return Err(anyhow!(
                "artifact output shape mismatch: sign {} mant {} (expect {len} x {limbs})",
                sign.len(),
                mant.len()
            ));
        }
        Ok(PlaneBatch { sign, exp, mant, limbs8: limbs, prec })
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    // ---- stream operators (mul/add/mac) ------------------------------------

    /// Execute a binary stream artifact on arbitrary-length batches
    /// (chunks + zero padding to the artifact's fixed batch).
    pub fn exec_stream_binop(
        &self,
        name: &str,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        let meta = self.meta(name)?.clone();
        anyhow::ensure!(a.len() == b.len(), "stream operand length mismatch");
        let batch = meta.batch;
        let limbs = meta.limbs;
        let prec = meta.prec();
        let mut out = PlaneBatch::zeros(a.len(), prec);
        let mut start = 0;
        while start < a.len() {
            let n = (a.len() - start).min(batch);
            let pa = pad_slice(a, start, n, batch);
            let pb = pad_slice(b, start, n, batch);
            let ia = self.literals_for(&pa, &[batch as i64])?;
            let ib = self.literals_for(&pb, &[batch as i64])?;
            let inputs: Vec<xla::Literal> = ia.into_iter().chain(ib).collect();
            let parts = self.run(&meta.name, &inputs)?;
            let chunk = self.batch_from_literals(parts, batch, limbs, prec)?;
            copy_into(&mut out, start, &chunk, n);
            start += n;
        }
        Ok(out)
    }

    /// Execute the ternary MAC stream artifact: c + a*b element-wise.
    pub fn exec_stream_mac(
        &self,
        name: &str,
        c: &PlaneBatch,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        let meta = self.meta(name)?.clone();
        anyhow::ensure!(a.len() == b.len() && a.len() == c.len());
        let batch = meta.batch;
        let limbs = meta.limbs;
        let prec = meta.prec();
        let mut out = PlaneBatch::zeros(a.len(), prec);
        let mut start = 0;
        while start < a.len() {
            let n = (a.len() - start).min(batch);
            let pc = pad_slice(c, start, n, batch);
            let pa = pad_slice(a, start, n, batch);
            let pb = pad_slice(b, start, n, batch);
            let ic = self.literals_for(&pc, &[batch as i64])?;
            let ia = self.literals_for(&pa, &[batch as i64])?;
            let ib = self.literals_for(&pb, &[batch as i64])?;
            let inputs: Vec<xla::Literal> = ic.into_iter().chain(ia).chain(ib).collect();
            let parts = self.run(&meta.name, &inputs)?;
            let chunk = self.batch_from_literals(parts, batch, limbs, prec)?;
            copy_into(&mut out, start, &chunk, n);
            start += n;
        }
        Ok(out)
    }

    // ---- GEMM tile (the compute-unit datapath) -----------------------------

    /// One tile update: C += A @ B with A: (t_n, k_tile), B: (k_tile, t_m),
    /// C: (t_n, t_m), all exactly the artifact's shapes (callers pad).
    pub fn exec_gemm_tile(
        &self,
        name: &str,
        a: &PlaneBatch,
        b: &PlaneBatch,
        c: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        let meta = self.meta(name)?.clone();
        let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
        anyhow::ensure!(a.len() == tn * kt, "A tile shape");
        anyhow::ensure!(b.len() == kt * tm, "B tile shape");
        anyhow::ensure!(c.len() == tn * tm, "C tile shape");
        let ia = self.literals_for(a, &[tn as i64, kt as i64])?;
        let ib = self.literals_for(b, &[kt as i64, tm as i64])?;
        let ic = self.literals_for(c, &[tn as i64, tm as i64])?;
        let inputs: Vec<xla::Literal> = ia.into_iter().chain(ib).chain(ic).collect();
        let parts = self.run(&meta.name, &inputs)?;
        self.batch_from_literals(parts, tn * tm, meta.limbs, meta.prec())
    }
}

/// Extract `n` rows starting at `start`, zero-padded to `batch` rows.
/// Padding rows are APFP zero (absorbing for mul, identity for add), so
/// padded lanes never contaminate real outputs.
fn pad_slice(src: &PlaneBatch, start: usize, n: usize, batch: usize) -> PlaneBatch {
    let mut out = PlaneBatch::zeros(batch, src.prec);
    out.sign[..n].copy_from_slice(&src.sign[start..start + n]);
    out.exp[..n].copy_from_slice(&src.exp[start..start + n]);
    out.mant[..n * src.limbs8]
        .copy_from_slice(&src.mant[start * src.limbs8..(start + n) * src.limbs8]);
    for e in out.exp[n..].iter_mut() {
        *e = ZERO_EXP;
    }
    out
}

fn copy_into(dst: &mut PlaneBatch, start: usize, src: &PlaneBatch, n: usize) {
    dst.sign[start..start + n].copy_from_slice(&src.sign[..n]);
    dst.exp[start..start + n].copy_from_slice(&src.exp[..n]);
    dst.mant[start * dst.limbs8..(start + n) * dst.limbs8]
        .copy_from_slice(&src.mant[..n * src.limbs8]);
}

/// Default artifact directory: $APFP_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("APFP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
