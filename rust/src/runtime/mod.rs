//! Execution runtime: resolve artifacts from a manifest and run them on a
//! pluggable [`Backend`] over limb-plane batches.
//!
//! Three backends implement the same artifact semantics (§IV-B's
//! "plug-and-play" promise):
//!
//! * [`NativeBackend`] (`APFP_BACKEND=native`, the default) executes in
//!   process on the arena-backed softfloat pipeline, synthesizing the
//!   builtin manifest when no artifact directory exists — so the whole
//!   device stack runs end to end on a clean checkout, bit-identically to
//!   the software baseline;
//! * [`SimBackend`] (`APFP_BACKEND=sim`) wraps the native backend in the
//!   analytic hardware model: results stay bit-identical while every GEMM
//!   tile accrues modeled cycles / DRAM traffic / energy
//!   ([`backend::TileModelCost`]), drained into the coordinator's
//!   `ModelMetrics` ledger — the design-space-exploration backend;
//! * [`backend::XlaBackend`] (`APFP_BACKEND=xla`) loads AOT artifacts (HLO
//!   text), compiles them on the PJRT CPU client and executes them.  In
//!   offline builds it compiles against the `xla` stub module and fails
//!   at client construction (workers degrade gracefully).
//!
//! One `Runtime` is **thread-local by construction** (the `xla` crate's
//! `PjRtClient` is `Rc`-based, and the native backend keeps a private
//! arena); the coordinator gives each compute-unit worker its own
//! `Runtime`, which is also the honest analogy: each CU on the FPGA is its
//! own replica of the circuit.
//!
//! Python never runs here: artifacts were lowered once by `make artifacts`
//! (see python/compile/aot.py and the HLO-text-vs-proto note there).

pub mod backend;
pub mod manifest;
mod native;
pub mod sim_backend;
mod xla;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

pub use backend::{Backend, BackendKind, TileModelCost};
pub use manifest::{ArtifactKind, ArtifactMeta, TileShape};
pub use native::NativeBackend;
pub use sim_backend::SimBackend;

use crate::pack::PlaneBatch;

pub struct Runtime {
    backend: Box<dyn Backend>,
    metas: Vec<ArtifactMeta>,
}

/// Load artifact metadata for a backend: the on-disk manifest when present,
/// else (native/sim only, and only when the manifest is genuinely *absent*)
/// the builtin in-memory manifest shaped to `tile`, synthesized at every
/// width in `widths` so one device hosts all of them side by side.  A
/// manifest that exists but cannot be read (permissions, it's a directory,
/// ...) stays a hard error on every backend — silently substituting builtin
/// tile geometry for a configured one would be worse than failing.  The XLA
/// path cannot run without HLO files, so a missing manifest stays a hard
/// error there too.
pub fn load_metas_widths(
    artifact_dir: &Path,
    kind: BackendKind,
    tile: TileShape,
    widths: &[u32],
) -> Result<Vec<ArtifactMeta>> {
    match manifest::load(artifact_dir) {
        Ok(m) => Ok(m),
        Err(manifest::ManifestError::Io { ref source, .. })
            if matches!(kind, BackendKind::Native | BackendKind::Sim)
                && source.kind() == std::io::ErrorKind::NotFound =>
        {
            manifest::builtin_widths(widths, tile).context("synthesizing builtin manifest")
        }
        Err(e) => Err(e).context("loading artifact manifest"),
    }
}

/// [`load_metas_widths`] at every default width
/// ([`manifest::DEFAULT_WIDTHS`]).
pub fn load_metas(
    artifact_dir: &Path,
    kind: BackendKind,
    tile: TileShape,
) -> Result<Vec<ArtifactMeta>> {
    load_metas_widths(artifact_dir, kind, tile, &manifest::DEFAULT_WIDTHS)
}

impl Runtime {
    /// Create a runtime over an artifact directory on the `$APFP_BACKEND`
    /// backend (default: native, which works without any artifacts),
    /// builtin tiles shaped by `$APFP_TILE_N/M/K`.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        Self::with_backend(artifact_dir, BackendKind::from_env())
    }

    /// Create a runtime on an explicit backend (builtin tiles still honor
    /// the `APFP_TILE_*` environment, like [`Runtime::new`]).
    pub fn with_backend(artifact_dir: &Path, kind: BackendKind) -> Result<Self> {
        Self::with_backend_tiled(artifact_dir, kind, TileShape::from_env())
    }

    /// Create a runtime on an explicit backend with an explicit builtin
    /// tile geometry — what each compute-unit worker uses so its synthesized
    /// manifest matches the leader's partition exactly.
    pub fn with_backend_tiled(
        artifact_dir: &Path,
        kind: BackendKind,
        tile: TileShape,
    ) -> Result<Self> {
        Self::with_backend_tiled_widths(artifact_dir, kind, tile, &manifest::DEFAULT_WIDTHS)
    }

    /// [`Runtime::with_backend_tiled`] with an explicit builtin width set
    /// — what each worker uses so its synthesized manifest carries exactly
    /// the widths the device was configured to host (`APFP_WIDTHS`).
    pub fn with_backend_tiled_widths(
        artifact_dir: &Path,
        kind: BackendKind,
        tile: TileShape,
        widths: &[u32],
    ) -> Result<Self> {
        let metas = load_metas_widths(artifact_dir, kind, tile, widths)?;
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Native => Box::new(NativeBackend::new()),
            BackendKind::Sim => Box::new(SimBackend::new()),
            BackendKind::Xla => Box::new(backend::XlaBackend::new(artifact_dir)?),
        };
        Ok(Runtime { backend, metas })
    }

    /// Drain the backend's modeled-cost ledger ([`Backend::take_model_cost`]):
    /// `Some` only on the simulated backend after GEMM tile work.
    pub fn take_model_cost(&self) -> Option<TileModelCost> {
        self.backend.take_model_cost()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.metas
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("no artifact named {name:?} in manifest"))
    }

    /// Pick an artifact by kind + precision (gemm: prefers the largest tile;
    /// callers pad partial tiles).
    pub fn find(&self, kind: ArtifactKind, bits: u32) -> Result<&ArtifactMeta> {
        self.metas
            .iter()
            .filter(|m| m.kind == kind && m.bits == bits)
            .max_by_key(|m| m.t_n * m.t_m)
            .ok_or_else(|| anyhow!("no {kind:?} artifact for {bits} bits"))
    }

    /// Warm the backend (compile everything needed up front, like
    /// programming the bitstream before timing anything; a no-op on the
    /// native backend).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.backend.warm(self.meta(n)?)?;
        }
        Ok(())
    }

    // ---- stream operators (mul/add/mac) ------------------------------------

    /// Execute a binary stream artifact on arbitrary-length batches.
    pub fn exec_stream_binop(
        &self,
        name: &str,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        let meta = self.meta(name)?;
        anyhow::ensure!(a.len() == b.len(), "stream operand length mismatch");
        self.backend.exec_stream_binop(meta, a, b)
    }

    /// Execute the ternary MAC stream artifact: c + a*b element-wise.
    pub fn exec_stream_mac(
        &self,
        name: &str,
        c: &PlaneBatch,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        let meta = self.meta(name)?;
        anyhow::ensure!(a.len() == b.len() && a.len() == c.len());
        self.backend.exec_stream_mac(meta, c, a, b)
    }

    // ---- GEMM tile (the compute-unit datapath) -----------------------------

    /// One tile update in place: C += A @ B with A: (t_n, k_tile),
    /// B: (k_tile, t_m), C: (t_n, t_m), all exactly the artifact's shapes
    /// (callers pad partial tiles; C stays "on chip" across K steps).
    pub fn exec_gemm_tile(
        &self,
        name: &str,
        a: &PlaneBatch,
        b: &PlaneBatch,
        c: &mut PlaneBatch,
    ) -> Result<()> {
        let meta = self.meta(name)?;
        let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
        anyhow::ensure!(a.len() == tn * kt, "A tile shape");
        anyhow::ensure!(b.len() == kt * tm, "B tile shape");
        anyhow::ensure!(c.len() == tn * tm, "C tile shape");
        self.backend.exec_gemm_tile(meta, a, b, c)
    }
}

/// Default artifact directory: $APFP_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("APFP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_runtime_works_without_any_artifact_dir() {
        let dir = std::env::temp_dir().join("apfp_rt_no_artifacts/definitely/absent");
        let rt = Runtime::with_backend(&dir, BackendKind::Native).unwrap();
        assert_eq!(rt.backend_name(), "native");
        assert_eq!(rt.artifacts().len(), 12, "builtin manifest covers every default width");
        for bits in [128u32, 512, 1024] {
            for kind in [ArtifactKind::Mul, ArtifactKind::Add, ArtifactKind::Mac, ArtifactKind::Gemm]
            {
                assert!(rt.find(kind.clone(), bits).is_ok(), "{kind:?} at {bits}");
            }
        }
        // warm is a no-op but must resolve names
        let gemm_name = rt.find(ArtifactKind::Gemm, 1024).unwrap().name.clone();
        rt.warm(&["mul_512", &gemm_name]).unwrap();
        assert!(rt.warm(&["nope"]).is_err());
    }

    #[test]
    fn sim_runtime_works_without_any_artifact_dir() {
        let dir = std::env::temp_dir().join("apfp_rt_sim_no_artifacts/definitely/absent");
        let rt = Runtime::with_backend(&dir, BackendKind::Sim).unwrap();
        assert_eq!(rt.backend_name(), "sim");
        assert_eq!(rt.artifacts().len(), 12, "builtin manifest covers every default width");
        assert!(rt.take_model_cost().is_none(), "no work modeled yet");
        // a native runtime never reports model cost
        let native = Runtime::with_backend(&dir, BackendKind::Native).unwrap();
        assert!(native.take_model_cost().is_none());
    }

    #[test]
    fn builtin_manifest_follows_an_explicit_tile_shape() {
        let dir = std::env::temp_dir().join("apfp_rt_tiled/definitely/absent");
        let tile = TileShape { n: 16, m: 8, k: 4 };
        let rt = Runtime::with_backend_tiled(&dir, BackendKind::Native, tile).unwrap();
        let g = rt.find(ArtifactKind::Gemm, 512).unwrap();
        assert_eq!((g.t_n, g.t_m, g.k_tile), (16, 8, 4));
        assert_eq!(g.name, "gemm_512_t16x8x4");
        // degenerate geometry is a clean error, not a panic
        let bad = TileShape { n: 0, m: 8, k: 8 };
        assert!(Runtime::with_backend_tiled(&dir, BackendKind::Native, bad).is_err());
    }

    #[test]
    fn explicit_width_set_narrows_the_builtin_manifest() {
        let dir = std::env::temp_dir().join("apfp_rt_widths/definitely/absent");
        let tile = TileShape { n: 8, m: 8, k: 8 };
        let rt =
            Runtime::with_backend_tiled_widths(&dir, BackendKind::Native, tile, &[512]).unwrap();
        assert_eq!(rt.artifacts().len(), 4, "one width, four artifacts");
        assert!(rt.find(ArtifactKind::Gemm, 512).is_ok());
        assert!(rt.find(ArtifactKind::Gemm, 1024).is_err(), "1024 not loaded");
        // a mixed pair loads both and nothing else
        let rt = Runtime::with_backend_tiled_widths(&dir, BackendKind::Native, tile, &[128, 512])
            .unwrap();
        assert_eq!(rt.artifacts().len(), 8);
        assert_eq!(rt.find(ArtifactKind::Gemm, 128).unwrap().prec(), 64);
    }

    #[test]
    fn xla_runtime_without_manifest_is_a_manifest_error() {
        let dir = std::env::temp_dir().join("apfp_rt_xla_no_manifest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = match Runtime::with_backend(&dir, BackendKind::Xla) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("xla runtime must not fabricate a manifest"),
        };
        assert!(err.contains("manifest"), "unexpected error: {err}");
    }

    #[test]
    fn on_disk_manifest_overrides_builtin_for_native() {
        let dir = std::env::temp_dir().join(format!("apfp_rt_disk_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "gemm_512_t4 gemm 512 0 4 4 4 56 gemm_512_t4.hlo.txt\n",
        )
        .unwrap();
        let rt = Runtime::with_backend(&dir, BackendKind::Native).unwrap();
        assert_eq!(rt.artifacts().len(), 1);
        let m = rt.find(ArtifactKind::Gemm, 512).unwrap();
        assert_eq!((m.t_n, m.t_m, m.k_tile), (4, 4, 4));
        // and the native backend honors the on-disk tile geometry
        use crate::pack::PlaneBatch;
        use crate::testkit::{rand_ap, Rng};
        let mut rng = Rng::from_seed(11);
        let vals = |n: usize, rng: &mut Rng| -> Vec<crate::softfloat::ApFloat> {
            (0..n).map(|_| rand_ap(rng, 448, 40)).collect()
        };
        let (av, bv, cv) = (vals(16, &mut rng), vals(16, &mut rng), vals(16, &mut rng));
        let (a, b) = (PlaneBatch::from_slice(&av, 448), PlaneBatch::from_slice(&bv, 448));
        let mut c = PlaneBatch::from_slice(&cv, 448);
        rt.exec_gemm_tile("gemm_512_t4", &a, &b, &mut c).unwrap();
        for i in 0..4 {
            for j in 0..4 {
                let mut acc = cv[i * 4 + j].clone();
                for k in 0..4 {
                    acc = acc.mac(&av[i * 4 + k], &bv[k * 4 + j]);
                }
                assert_eq!(c.get(i * 4 + j), acc, "({i},{j})");
            }
        }
    }
}
