//! The native in-process backend: executes the artifact semantics directly
//! on limb planes using the arena-backed softfloat operators.
//!
//! This is the reproduction's analog of validating an FPGA datapath against
//! a bit-exact software executor over the *same tiled dataflow* (Kono et
//! al., 2306.04087): every lane decodes into a reused `ApFloat`, runs the
//! RNDZ pipeline (`mul_into` / `add_into` / `mac_into` against one
//! [`Scratch`] arena per backend), and re-encodes into the caller's planes.
//! Nothing is materialized per element, so a steady-state
//! [`Backend::exec_gemm_tile`] loop performs **zero heap allocations**
//! after warmup (proven in `tests/alloc_free.rs`).
//!
//! Because the backend runs real artifact *semantics* — fixed tile shapes,
//! zero-padded partial tiles, sequential-K accumulation — the whole device
//! stack above it (scheduler partition, bounded worker queues, tile
//! K-accumulation, metrics) executes end to end on a clean checkout, and
//! its results are bit-identical to `baseline::gemm_serial`.
//!
//! GEMM tiles additionally have a **fixed-width fast lane**: when the
//! artifact's precision matches a compiled [`ApFloatN`] width (448 or 960
//! bits — the paper's two evaluated configs), [`Backend::exec_gemm_tile`]
//! decodes straight into `[u64; LIMBS]` stack mantissas and runs the
//! unrolled fixed kernels instead of the arena pipeline.  Any other width
//! falls back to the dynamic lane, and `APFP_FIXED_PATH=0` disables the
//! lane entirely (read per backend construction).  Both lanes are
//! bit-identical by construction and by test (tests/fixed_parity.rs).

use std::cell::RefCell;

use anyhow::{bail, ensure, Result};

use super::backend::Backend;
use super::manifest::{ArtifactKind, ArtifactMeta};
use crate::bigint::Scratch;
use crate::pack::PlaneBatch;
use crate::softfloat::{ApFloat, ApFloatN};

/// In-process executor.  Like its PJRT counterpart it is thread-local by
/// construction (interior mutability via `RefCell`, no `Sync`): the
/// coordinator gives each compute-unit worker its own instance, which is
/// also what keeps each worker's arena private.
pub struct NativeBackend {
    /// Whether GEMM tiles at a compiled width take the fixed-width lane.
    /// Snapshotted from `APFP_FIXED_PATH` at construction (not once per
    /// process), so one test binary can drive both lanes side by side.
    fixed_enabled: bool,
    state: RefCell<State>,
}

/// All reusable buffers: the operator arena plus decoded-operand slots.
/// Sized lazily on first use; steady state over one artifact shape never
/// touches the allocator again.
struct State {
    scratch: Scratch,
    x: ApFloat,
    y: ApFloat,
    acc: ApFloat,
    /// Decoded A tile (`t_n * k_tile` values), reused across calls.
    a_vals: Vec<ApFloat>,
    /// Decoded B tile (`k_tile * t_m` values), reused across calls.
    b_vals: Vec<ApFloat>,
    /// Fixed-lane operand slots for the 448-bit (7-limb) config.
    fixed7: FixedSlots<7>,
    /// Fixed-lane operand slots for the 960-bit (15-limb) config.
    fixed15: FixedSlots<15>,
}

/// Decoded fixed-width tile operands: plain `Vec`s of `Copy` values, so
/// reshaping is one `resize` with no per-slot buffer management.
struct FixedSlots<const L: usize> {
    a: Vec<ApFloatN<L>>,
    b: Vec<ApFloatN<L>>,
}

impl<const L: usize> FixedSlots<L> {
    fn new() -> Self {
        FixedSlots { a: Vec::new(), b: Vec::new() }
    }
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::with_fixed_path(fixed_path_env_enabled())
    }

    /// Build a backend with the fixed-width lane explicitly on or off,
    /// ignoring `APFP_FIXED_PATH` — parity and allocation tests construct
    /// one of each to compare the lanes inside a single process.
    pub fn with_fixed_path(enabled: bool) -> Self {
        // Placeholder width: every decode fixes the width of the slot it
        // writes, so the smallest legal ApFloat is fine here.
        let slot = || ApFloat::zero(128);
        NativeBackend {
            fixed_enabled: enabled,
            state: RefCell::new(State {
                scratch: Scratch::new(),
                x: slot(),
                y: slot(),
                acc: slot(),
                a_vals: Vec::new(),
                b_vals: Vec::new(),
                fixed7: FixedSlots::new(),
                fixed15: FixedSlots::new(),
            }),
        }
    }
}

/// `APFP_FIXED_PATH=0|false|off` (case-insensitive) disables the
/// fixed-width GEMM lane — the escape hatch if a width regression is ever
/// suspected in the field; anything else, including unset, leaves it on.
/// Shared with the host baseline: [`crate::baseline::gemm_threaded`]
/// consults the same knob, so one env var governs both the device and
/// CPU fixed lanes.
pub(crate) fn fixed_path_env_enabled() -> bool {
    match std::env::var("APFP_FIXED_PATH") {
        Ok(v) => !fixed_path_disabled_value(&v),
        Err(_) => true,
    }
}

fn fixed_path_disabled_value(v: &str) -> bool {
    matches!(v.trim().to_ascii_lowercase().as_str(), "0" | "false" | "off")
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Ensure `v` holds exactly `n` slots (reallocates only on shape change;
/// widths are corrected per slot by the decode).
// apfp-lint: allow(alloc, scope=fn, reason="cold shaping path: slots are (re)built only when the tile shape changes; steady-state calls hit the len check and return")
fn resize_slots(v: &mut Vec<ApFloat>, n: usize) {
    if v.len() != n {
        v.resize(n, ApFloat::zero(128));
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn exec_stream_binop(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        let mul = match meta.kind {
            ArtifactKind::Mul => true,
            ArtifactKind::Add => false,
            ref k => bail!("{k:?} is not a binary stream artifact"),
        };
        ensure!(a.len() == b.len(), "stream operand length mismatch");
        let prec = meta.prec();
        ensure!(a.prec == prec && b.prec == prec, "operand precision vs artifact");
        let st = &mut *self.state.borrow_mut();
        let mut out = PlaneBatch::zeros(a.len(), prec);
        for i in 0..a.len() {
            a.get_into(i, &mut st.x);
            b.get_into(i, &mut st.y);
            if mul {
                st.x.mul_into(&st.y, &mut st.acc, &mut st.scratch);
            } else {
                st.x.add_into(&st.y, &mut st.acc, &mut st.scratch);
            }
            out.set(i, &st.acc);
        }
        Ok(out)
    }

    fn exec_stream_mac(
        &self,
        meta: &ArtifactMeta,
        c: &PlaneBatch,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        ensure!(meta.kind == ArtifactKind::Mac, "{:?} is not a mac artifact", meta.kind);
        ensure!(a.len() == b.len() && a.len() == c.len(), "stream operand length mismatch");
        let prec = meta.prec();
        ensure!(
            a.prec == prec && b.prec == prec && c.prec == prec,
            "operand precision vs artifact"
        );
        let st = &mut *self.state.borrow_mut();
        let mut out = PlaneBatch::zeros(a.len(), prec);
        for i in 0..a.len() {
            a.get_into(i, &mut st.x);
            b.get_into(i, &mut st.y);
            c.get_into(i, &mut st.acc);
            st.acc.mac_into(&st.x, &st.y, &mut st.scratch);
            out.set(i, &st.acc);
        }
        Ok(out)
    }

    // apfp-lint: no_alloc
    fn exec_gemm_tile(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
        c: &mut PlaneBatch,
    ) -> Result<()> {
        ensure!(meta.kind == ArtifactKind::Gemm, "{:?} is not a gemm artifact", meta.kind);
        let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
        ensure!(a.len() == tn * kt, "A tile shape");
        ensure!(b.len() == kt * tm, "B tile shape");
        ensure!(c.len() == tn * tm, "C tile shape");
        let prec = meta.prec();
        ensure!(
            a.prec == prec && b.prec == prec && c.prec == prec,
            "operand precision vs artifact"
        );
        let st = &mut *self.state.borrow_mut();
        // Fixed-width fast lane: precisions with a compiled ApFloatN width
        // skip the arena pipeline entirely.  Unmatched widths (and
        // APFP_FIXED_PATH=0) fall through to the dynamic lane below.
        if self.fixed_enabled {
            match prec {
                448 => return exec_gemm_tile_fixed::<7>(meta, a, b, c, &mut st.fixed7),
                960 => return exec_gemm_tile_fixed::<15>(meta, a, b, c, &mut st.fixed15),
                _ => {}
            }
        }
        resize_slots(&mut st.a_vals, tn * kt);
        resize_slots(&mut st.b_vals, kt * tm);
        for (i, slot) in st.a_vals.iter_mut().enumerate() {
            a.get_into(i, slot);
        }
        for (i, slot) in st.b_vals.iter_mut().enumerate() {
            b.get_into(i, slot);
        }
        // Sequential K per output element — the artifact's accumulation
        // order, which composed over the coordinator's ascending K-step
        // loop reproduces baseline::gemm_serial bit for bit.  A MAC whose
        // product is zero is skipped: `acc + 0` is exact under RNDZ (the
        // adder copies the accumulator through unchanged), so zero-padded
        // lanes — edge tiles clipped in any of the three dimensions — cost
        // a flag check instead of a full multiply-add.
        for i in 0..tn {
            for j in 0..tm {
                c.get_into(i * tm + j, &mut st.acc);
                for k in 0..kt {
                    let (ax, bx) = (&st.a_vals[i * kt + k], &st.b_vals[k * tm + j]);
                    if ax.is_zero() || bx.is_zero() {
                        continue;
                    }
                    st.acc.mac_into(ax, bx, &mut st.scratch);
                }
                c.set(i * tm + j, &st.acc);
            }
        }
        Ok(())
    }
}

/// Ensure a fixed-slot vector holds exactly `n` values (reallocates only
/// on shape change; `ApFloatN` is `Copy`, so no per-slot buffers exist).
// apfp-lint: allow(alloc, scope=fn, reason="cold shaping path: slots are (re)built only when the tile shape changes; steady-state calls hit the len check and return")
fn resize_fixed_slots<const L: usize>(v: &mut Vec<ApFloatN<L>>, n: usize) {
    if v.len() != n {
        v.resize(n, ApFloatN::ZERO);
    }
}

/// The fixed-width lane of [`Backend::exec_gemm_tile`]: decode the tile
/// straight into `[u64; L]` stack mantissas, run the unrolled `ApFloatN`
/// MAC pipeline, re-encode.  Shape/precision validation already happened
/// in the dispatching caller.  Same zero-skip and sequential-K order as
/// the dynamic lane, so the two lanes are bit-identical (pinned in
/// tests/fixed_parity.rs); with warm slots the loop is allocation-free
/// (proven in tests/alloc_free.rs).
// apfp-lint: no_alloc
fn exec_gemm_tile_fixed<const L: usize>(
    meta: &ArtifactMeta,
    a: &PlaneBatch,
    b: &PlaneBatch,
    c: &mut PlaneBatch,
    slots: &mut FixedSlots<L>,
) -> Result<()> {
    let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
    resize_fixed_slots(&mut slots.a, tn * kt);
    resize_fixed_slots(&mut slots.b, kt * tm);
    for (i, slot) in slots.a.iter_mut().enumerate() {
        a.get_fixed_into(i, slot);
    }
    for (i, slot) in slots.b.iter_mut().enumerate() {
        b.get_fixed_into(i, slot);
    }
    for i in 0..tn {
        for j in 0..tm {
            let mut acc = ApFloatN::<L>::ZERO;
            c.get_fixed_into(i * tm + j, &mut acc);
            for k in 0..kt {
                let (ax, bx) = (&slots.a[i * kt + k], &slots.b[k * tm + j]);
                if ax.is_zero() || bx.is_zero() {
                    continue;
                }
                acc.mac_into(ax, bx);
            }
            c.set_fixed(i * tm + j, &acc);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest;
    use crate::testkit::{rand_ap, Rng};

    fn metas(bits: u32) -> Vec<ArtifactMeta> {
        manifest::builtin(bits, manifest::TileShape { n: 8, m: 8, k: 8 }).unwrap()
    }

    fn meta_of(bits: u32, kind: ArtifactKind) -> ArtifactMeta {
        metas(bits).into_iter().find(|m| m.kind == kind).unwrap()
    }

    fn batch_of(rng: &mut Rng, n: usize, prec: u32) -> (Vec<ApFloat>, PlaneBatch) {
        let vals: Vec<ApFloat> = (0..n).map(|_| rand_ap(rng, prec, 60)).collect();
        let planes = PlaneBatch::from_slice(&vals, prec);
        (vals, planes)
    }

    #[test]
    fn binop_streams_bit_exact_with_zero_and_cancellation_lanes() {
        for bits in [512u32, 1024] {
            let prec = bits - 64;
            let be = NativeBackend::new();
            let mut rng = Rng::from_seed(7);
            let (av, ap) = batch_of(&mut rng, 33, prec);
            let (mut bv, _) = batch_of(&mut rng, 33, prec);
            bv[2] = ApFloat::zero(prec); // absorbing lane for mul
            bv[5] = av[5].neg(); // exact cancellation lane for add
            let bp = PlaneBatch::from_slice(&bv, prec);
            let mul = be.exec_stream_binop(&meta_of(bits, ArtifactKind::Mul), &ap, &bp).unwrap();
            let add = be.exec_stream_binop(&meta_of(bits, ArtifactKind::Add), &ap, &bp).unwrap();
            for i in 0..av.len() {
                assert_eq!(mul.get(i), av[i].mul(&bv[i]), "mul lane {i} at {bits} bits");
                assert_eq!(add.get(i), av[i].add(&bv[i]), "add lane {i} at {bits} bits");
            }
        }
    }

    #[test]
    fn mac_stream_bit_exact() {
        for bits in [512u32, 1024] {
            let prec = bits - 64;
            let be = NativeBackend::new();
            let mut rng = Rng::from_seed(8);
            let (cv, cp) = batch_of(&mut rng, 17, prec);
            let (av, ap) = batch_of(&mut rng, 17, prec);
            let (bv, bp) = batch_of(&mut rng, 17, prec);
            let got = be.exec_stream_mac(&meta_of(bits, ArtifactKind::Mac), &cp, &ap, &bp).unwrap();
            for i in 0..cv.len() {
                assert_eq!(got.get(i), cv[i].mac(&av[i], &bv[i]), "lane {i} at {bits} bits");
            }
        }
    }

    #[test]
    fn gemm_tile_matches_sequential_mac_chain_and_accumulates_in_place() {
        for bits in [512u32, 1024] {
            let prec = bits - 64;
            let be = NativeBackend::new();
            let meta = meta_of(bits, ArtifactKind::Gemm);
            let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
            let mut rng = Rng::from_seed(9);
            let (mut av, _) = batch_of(&mut rng, tn * kt, prec);
            let (mut bv, _) = batch_of(&mut rng, kt * tm, prec);
            let (cv, cp) = batch_of(&mut rng, tn * tm, prec);
            // zero lanes exercise the skip path: the reference mac chain
            // below still includes them, pinning `acc + 0*b == acc` exactly
            av[3] = ApFloat::zero(prec);
            bv[kt * tm / 2] = ApFloat::zero(prec);
            let ap = PlaneBatch::from_slice(&av, prec);
            let bp = PlaneBatch::from_slice(&bv, prec);
            let mut c = cp.clone();
            be.exec_gemm_tile(&meta, &ap, &bp, &mut c).unwrap();
            // second in-place step accumulates another A@B on top
            be.exec_gemm_tile(&meta, &ap, &bp, &mut c).unwrap();
            for i in 0..tn {
                for j in 0..tm {
                    let mut acc = cv[i * tm + j].clone();
                    for _ in 0..2 {
                        for k in 0..kt {
                            acc = acc.mac(&av[i * kt + k], &bv[k * tm + j]);
                        }
                    }
                    assert_eq!(c.get(i * tm + j), acc, "element ({i},{j}) at {bits} bits");
                }
            }
        }
    }

    #[test]
    fn fixed_lane_matches_dynamic_lane_bitwise() {
        for bits in [512u32, 1024] {
            let prec = bits - 64;
            let fixed = NativeBackend::with_fixed_path(true);
            let dynamic = NativeBackend::with_fixed_path(false);
            let meta = meta_of(bits, ArtifactKind::Gemm);
            let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
            let mut rng = Rng::from_seed(11);
            let (mut av, _) = batch_of(&mut rng, tn * kt, prec);
            let (_, bp) = batch_of(&mut rng, kt * tm, prec);
            let (_, cp) = batch_of(&mut rng, tn * tm, prec);
            av[1] = ApFloat::zero(prec); // exercise the zero-skip on both lanes
            let ap = PlaneBatch::from_slice(&av, prec);
            let mut c_fixed = cp.clone();
            let mut c_dyn = cp;
            fixed.exec_gemm_tile(&meta, &ap, &bp, &mut c_fixed).unwrap();
            dynamic.exec_gemm_tile(&meta, &ap, &bp, &mut c_dyn).unwrap();
            assert_eq!(c_fixed, c_dyn, "lanes disagree at {bits} bits");
            // structural proof the lanes actually diverged: the fixed lane
            // never touches the arena, the dynamic lane lives on it
            assert_eq!(fixed.state.borrow().scratch.arena_ops(), 0, "fixed lane used the arena");
            assert!(dynamic.state.borrow().scratch.arena_ops() > 0, "dynamic lane skipped the arena");
        }
    }

    #[test]
    fn unmatched_width_falls_back_to_dynamic_lane() {
        // 1536-bit artifacts (prec 1472, 23 limbs) have no compiled fixed
        // width: the fixed-enabled backend must fall through to the arena
        // pipeline and still produce the exact mac-chain result.
        let prec = 1472u32;
        let be = NativeBackend::with_fixed_path(true);
        let meta = meta_of(1536, ArtifactKind::Gemm);
        let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
        let mut rng = Rng::from_seed(12);
        let (av, ap) = batch_of(&mut rng, tn * kt, prec);
        let (bv, bp) = batch_of(&mut rng, kt * tm, prec);
        let (cv, cp) = batch_of(&mut rng, tn * tm, prec);
        let mut c = cp;
        be.exec_gemm_tile(&meta, &ap, &bp, &mut c).unwrap();
        assert!(be.state.borrow().scratch.arena_ops() > 0, "fallback must use the dynamic lane");
        for i in 0..tn {
            for j in 0..tm {
                let mut acc = cv[i * tm + j].clone();
                for k in 0..kt {
                    acc = acc.mac(&av[i * kt + k], &bv[k * tm + j]);
                }
                assert_eq!(c.get(i * tm + j), acc, "element ({i},{j})");
            }
        }
    }

    #[test]
    fn fixed_path_env_values_parse() {
        for v in ["0", "false", "off", " 0 ", "FALSE", "Off"] {
            assert!(fixed_path_disabled_value(v), "{v:?} must disable the lane");
        }
        for v in ["1", "true", "on", "", "yes"] {
            assert!(!fixed_path_disabled_value(v), "{v:?} must leave the lane on");
        }
    }

    #[test]
    fn shape_and_kind_mismatches_are_errors() {
        let be = NativeBackend::new();
        let gemm = meta_of(512, ArtifactKind::Gemm);
        let mul = meta_of(512, ArtifactKind::Mul);
        let mut rng = Rng::from_seed(10);
        let (_, a) = batch_of(&mut rng, 4, 448);
        let (_, b) = batch_of(&mut rng, 5, 448);
        assert!(be.exec_stream_binop(&mul, &a, &b).is_err(), "length mismatch");
        assert!(be.exec_stream_binop(&gemm, &a, &a).is_err(), "gemm is not a binop");
        let mut c = PlaneBatch::zeros(4, 448);
        assert!(be.exec_gemm_tile(&gemm, &a, &b, &mut c).is_err(), "bad tile shapes");
        let (_, w) = batch_of(&mut rng, 4, 960);
        assert!(be.exec_stream_binop(&mul, &w, &w).is_err(), "precision mismatch");
    }
}
