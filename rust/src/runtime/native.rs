//! The native in-process backend: executes the artifact semantics directly
//! on limb planes using the arena-backed softfloat operators.
//!
//! This is the reproduction's analog of validating an FPGA datapath against
//! a bit-exact software executor over the *same tiled dataflow* (Kono et
//! al., 2306.04087): every lane decodes into a reused `ApFloat`, runs the
//! RNDZ pipeline (`mul_into` / `add_into` / `mac_into` against one
//! [`Scratch`] arena per backend), and re-encodes into the caller's planes.
//! Nothing is materialized per element, so a steady-state
//! [`Backend::exec_gemm_tile`] loop performs **zero heap allocations**
//! after warmup (proven in `tests/alloc_free.rs`).
//!
//! Because the backend runs real artifact *semantics* — fixed tile shapes,
//! zero-padded partial tiles, sequential-K accumulation — the whole device
//! stack above it (scheduler partition, bounded worker queues, tile
//! K-accumulation, metrics) executes end to end on a clean checkout, and
//! its results are bit-identical to `baseline::gemm_serial`.

use std::cell::RefCell;

use anyhow::{bail, ensure, Result};

use super::backend::Backend;
use super::manifest::{ArtifactKind, ArtifactMeta};
use crate::bigint::Scratch;
use crate::pack::PlaneBatch;
use crate::softfloat::ApFloat;

/// In-process executor.  Like its PJRT counterpart it is thread-local by
/// construction (interior mutability via `RefCell`, no `Sync`): the
/// coordinator gives each compute-unit worker its own instance, which is
/// also what keeps each worker's arena private.
pub struct NativeBackend {
    state: RefCell<State>,
}

/// All reusable buffers: the operator arena plus decoded-operand slots.
/// Sized lazily on first use; steady state over one artifact shape never
/// touches the allocator again.
struct State {
    scratch: Scratch,
    x: ApFloat,
    y: ApFloat,
    acc: ApFloat,
    /// Decoded A tile (`t_n * k_tile` values), reused across calls.
    a_vals: Vec<ApFloat>,
    /// Decoded B tile (`k_tile * t_m` values), reused across calls.
    b_vals: Vec<ApFloat>,
}

impl NativeBackend {
    pub fn new() -> Self {
        // Placeholder width: every decode fixes the width of the slot it
        // writes, so the smallest legal ApFloat is fine here.
        let slot = || ApFloat::zero(128);
        NativeBackend {
            state: RefCell::new(State {
                scratch: Scratch::new(),
                x: slot(),
                y: slot(),
                acc: slot(),
                a_vals: Vec::new(),
                b_vals: Vec::new(),
            }),
        }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

/// Ensure `v` holds exactly `n` slots (reallocates only on shape change;
/// widths are corrected per slot by the decode).
// apfp-lint: allow(alloc, scope=fn, reason="cold shaping path: slots are (re)built only when the tile shape changes; steady-state calls hit the len check and return")
fn resize_slots(v: &mut Vec<ApFloat>, n: usize) {
    if v.len() != n {
        v.resize(n, ApFloat::zero(128));
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn exec_stream_binop(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        let mul = match meta.kind {
            ArtifactKind::Mul => true,
            ArtifactKind::Add => false,
            ref k => bail!("{k:?} is not a binary stream artifact"),
        };
        ensure!(a.len() == b.len(), "stream operand length mismatch");
        let prec = meta.prec();
        ensure!(a.prec == prec && b.prec == prec, "operand precision vs artifact");
        let st = &mut *self.state.borrow_mut();
        let mut out = PlaneBatch::zeros(a.len(), prec);
        for i in 0..a.len() {
            a.get_into(i, &mut st.x);
            b.get_into(i, &mut st.y);
            if mul {
                st.x.mul_into(&st.y, &mut st.acc, &mut st.scratch);
            } else {
                st.x.add_into(&st.y, &mut st.acc, &mut st.scratch);
            }
            out.set(i, &st.acc);
        }
        Ok(out)
    }

    fn exec_stream_mac(
        &self,
        meta: &ArtifactMeta,
        c: &PlaneBatch,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        ensure!(meta.kind == ArtifactKind::Mac, "{:?} is not a mac artifact", meta.kind);
        ensure!(a.len() == b.len() && a.len() == c.len(), "stream operand length mismatch");
        let prec = meta.prec();
        ensure!(
            a.prec == prec && b.prec == prec && c.prec == prec,
            "operand precision vs artifact"
        );
        let st = &mut *self.state.borrow_mut();
        let mut out = PlaneBatch::zeros(a.len(), prec);
        for i in 0..a.len() {
            a.get_into(i, &mut st.x);
            b.get_into(i, &mut st.y);
            c.get_into(i, &mut st.acc);
            st.acc.mac_into(&st.x, &st.y, &mut st.scratch);
            out.set(i, &st.acc);
        }
        Ok(out)
    }

    // apfp-lint: no_alloc
    fn exec_gemm_tile(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
        c: &mut PlaneBatch,
    ) -> Result<()> {
        ensure!(meta.kind == ArtifactKind::Gemm, "{:?} is not a gemm artifact", meta.kind);
        let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
        ensure!(a.len() == tn * kt, "A tile shape");
        ensure!(b.len() == kt * tm, "B tile shape");
        ensure!(c.len() == tn * tm, "C tile shape");
        let prec = meta.prec();
        ensure!(
            a.prec == prec && b.prec == prec && c.prec == prec,
            "operand precision vs artifact"
        );
        let st = &mut *self.state.borrow_mut();
        resize_slots(&mut st.a_vals, tn * kt);
        resize_slots(&mut st.b_vals, kt * tm);
        for (i, slot) in st.a_vals.iter_mut().enumerate() {
            a.get_into(i, slot);
        }
        for (i, slot) in st.b_vals.iter_mut().enumerate() {
            b.get_into(i, slot);
        }
        // Sequential K per output element — the artifact's accumulation
        // order, which composed over the coordinator's ascending K-step
        // loop reproduces baseline::gemm_serial bit for bit.  A MAC whose
        // product is zero is skipped: `acc + 0` is exact under RNDZ (the
        // adder copies the accumulator through unchanged), so zero-padded
        // lanes — edge tiles clipped in any of the three dimensions — cost
        // a flag check instead of a full multiply-add.
        for i in 0..tn {
            for j in 0..tm {
                c.get_into(i * tm + j, &mut st.acc);
                for k in 0..kt {
                    let (ax, bx) = (&st.a_vals[i * kt + k], &st.b_vals[k * tm + j]);
                    if ax.is_zero() || bx.is_zero() {
                        continue;
                    }
                    st.acc.mac_into(ax, bx, &mut st.scratch);
                }
                c.set(i * tm + j, &st.acc);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest;
    use crate::testkit::{rand_ap, Rng};

    fn metas(bits: u32) -> Vec<ArtifactMeta> {
        manifest::builtin(bits, manifest::TileShape { n: 8, m: 8, k: 8 }).unwrap()
    }

    fn meta_of(bits: u32, kind: ArtifactKind) -> ArtifactMeta {
        metas(bits).into_iter().find(|m| m.kind == kind).unwrap()
    }

    fn batch_of(rng: &mut Rng, n: usize, prec: u32) -> (Vec<ApFloat>, PlaneBatch) {
        let vals: Vec<ApFloat> = (0..n).map(|_| rand_ap(rng, prec, 60)).collect();
        let planes = PlaneBatch::from_slice(&vals, prec);
        (vals, planes)
    }

    #[test]
    fn binop_streams_bit_exact_with_zero_and_cancellation_lanes() {
        for bits in [512u32, 1024] {
            let prec = bits - 64;
            let be = NativeBackend::new();
            let mut rng = Rng::from_seed(7);
            let (av, ap) = batch_of(&mut rng, 33, prec);
            let (mut bv, _) = batch_of(&mut rng, 33, prec);
            bv[2] = ApFloat::zero(prec); // absorbing lane for mul
            bv[5] = av[5].neg(); // exact cancellation lane for add
            let bp = PlaneBatch::from_slice(&bv, prec);
            let mul = be.exec_stream_binop(&meta_of(bits, ArtifactKind::Mul), &ap, &bp).unwrap();
            let add = be.exec_stream_binop(&meta_of(bits, ArtifactKind::Add), &ap, &bp).unwrap();
            for i in 0..av.len() {
                assert_eq!(mul.get(i), av[i].mul(&bv[i]), "mul lane {i} at {bits} bits");
                assert_eq!(add.get(i), av[i].add(&bv[i]), "add lane {i} at {bits} bits");
            }
        }
    }

    #[test]
    fn mac_stream_bit_exact() {
        for bits in [512u32, 1024] {
            let prec = bits - 64;
            let be = NativeBackend::new();
            let mut rng = Rng::from_seed(8);
            let (cv, cp) = batch_of(&mut rng, 17, prec);
            let (av, ap) = batch_of(&mut rng, 17, prec);
            let (bv, bp) = batch_of(&mut rng, 17, prec);
            let got = be.exec_stream_mac(&meta_of(bits, ArtifactKind::Mac), &cp, &ap, &bp).unwrap();
            for i in 0..cv.len() {
                assert_eq!(got.get(i), cv[i].mac(&av[i], &bv[i]), "lane {i} at {bits} bits");
            }
        }
    }

    #[test]
    fn gemm_tile_matches_sequential_mac_chain_and_accumulates_in_place() {
        for bits in [512u32, 1024] {
            let prec = bits - 64;
            let be = NativeBackend::new();
            let meta = meta_of(bits, ArtifactKind::Gemm);
            let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
            let mut rng = Rng::from_seed(9);
            let (mut av, _) = batch_of(&mut rng, tn * kt, prec);
            let (mut bv, _) = batch_of(&mut rng, kt * tm, prec);
            let (cv, cp) = batch_of(&mut rng, tn * tm, prec);
            // zero lanes exercise the skip path: the reference mac chain
            // below still includes them, pinning `acc + 0*b == acc` exactly
            av[3] = ApFloat::zero(prec);
            bv[kt * tm / 2] = ApFloat::zero(prec);
            let ap = PlaneBatch::from_slice(&av, prec);
            let bp = PlaneBatch::from_slice(&bv, prec);
            let mut c = cp.clone();
            be.exec_gemm_tile(&meta, &ap, &bp, &mut c).unwrap();
            // second in-place step accumulates another A@B on top
            be.exec_gemm_tile(&meta, &ap, &bp, &mut c).unwrap();
            for i in 0..tn {
                for j in 0..tm {
                    let mut acc = cv[i * tm + j].clone();
                    for _ in 0..2 {
                        for k in 0..kt {
                            acc = acc.mac(&av[i * kt + k], &bv[k * tm + j]);
                        }
                    }
                    assert_eq!(c.get(i * tm + j), acc, "element ({i},{j}) at {bits} bits");
                }
            }
        }
    }

    #[test]
    fn shape_and_kind_mismatches_are_errors() {
        let be = NativeBackend::new();
        let gemm = meta_of(512, ArtifactKind::Gemm);
        let mul = meta_of(512, ArtifactKind::Mul);
        let mut rng = Rng::from_seed(10);
        let (_, a) = batch_of(&mut rng, 4, 448);
        let (_, b) = batch_of(&mut rng, 5, 448);
        assert!(be.exec_stream_binop(&mul, &a, &b).is_err(), "length mismatch");
        assert!(be.exec_stream_binop(&gemm, &a, &a).is_err(), "gemm is not a binop");
        let mut c = PlaneBatch::zeros(4, 448);
        assert!(be.exec_gemm_tile(&gemm, &a, &b, &mut c).is_err(), "bad tile shapes");
        let (_, w) = batch_of(&mut rng, 4, 960);
        assert!(be.exec_stream_binop(&mul, &w, &w).is_err(), "precision mismatch");
    }
}
