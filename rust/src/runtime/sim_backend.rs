//! The simulated backend: bit-exact native execution plus hardware-model
//! cost accounting (`APFP_BACKEND=sim`).
//!
//! [`SimBackend`] wraps a [`NativeBackend`] and delegates every operator
//! to it unchanged, so results are bit-identical to the native path by
//! construction — the same arena/fixed softfloat kernels execute.  On top
//! of that, every successful GEMM tile K-step accrues a modeled
//! [`TileModelCost`] derived from the paper's analytic hardware model
//! ([`crate::hwmodel`]) and dataflow simulator ([`crate::sim`]):
//!
//! * **cycles** — `T_N*T_M*K_TILE` MAC issues at the II the design point
//!   sustains (monolithic-CU penalty past half an SLR, §V-D), plus one
//!   [`gemm_sim::PIPELINE_DEPTH`] fill/drain per kernel call;
//! * **DRAM traffic** — the A column-piece (strided), B row-piece and C
//!   writeback (contiguous) at the bank efficiencies of [`sim::dram`];
//! * **compute / memory time** — cycles over the synthesized achievable
//!   frequency, and bytes over the CU's bank share;
//! * **energy** — DSP + CLB dynamic activity over the compute interval
//!   ([`DSP_PJ_PER_CYCLE`] / [`CLB_PJ_PER_CYCLE`]).
//!
//! The convention is **per compute unit**: each worker thread owns one
//! `SimBackend` and models the CU it stands in for
//! ([`ArtifactMeta::design_point`] synthesizes at `compute_units = 1`),
//! and the coordinator sums workers into the device-wide `ModelMetrics`
//! ledger.  Costs ride [`TileResult`](crate::coordinator) replies and are
//! accumulated only when a launch's results retire, so retried tiles are
//! never double-counted (see `docs/INVARIANTS.md`).
//!
//! Stream operators (`mul`/`add`/`mac`) are deliberately *not* modeled:
//! the paper's sweep results (Fig. 5, Tab. III) are GEMM dataflow, and the
//! stream paths are host-marshaling-dominated.  They delegate and accrue
//! nothing.

use std::cell::{Cell, RefCell};

use anyhow::Result;

use super::backend::{Backend, TileModelCost};
use super::manifest::ArtifactMeta;
use super::native::NativeBackend;
use crate::hwmodel::{dsp, resources, u250};
use crate::pack::PlaneBatch;
use crate::sim::{dram, gemm_sim};

/// Modeled dynamic energy of one active DSP48E2 per cycle, picojoules.
/// Calibrated to put a 512-bit GEMM CU at a few watts of DSP activity at
/// its achievable clock (DS962-order numbers, not a lookup).
pub const DSP_PJ_PER_CYCLE: f64 = 22.0;
/// Modeled dynamic energy of one active CLB per cycle, picojoules
/// (recombination adders + stream logic toggling alongside the DSPs).
pub const CLB_PJ_PER_CYCLE: f64 = 0.55;

/// Modeled cost of one `exec_gemm_tile` call (one K-step of one output
/// tile) on the artifact's design point, per compute unit.
///
/// This is the single formula the calibration goldens, the Python mirror
/// (`python/tests/test_sim_backend.py`) and `repro modelgold` all pin:
/// change it and the perf-model regression gate trips.
pub fn tile_cost(meta: &ArtifactMeta) -> TileModelCost {
    let d = meta.design_point();
    let s = d.synthesize();
    let f_hz = s.frequency_mhz * 1e6;
    let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
    let macs = (tn * tm * kt) as u64;

    // initiation-interval penalty, exactly as sim::gemm_sim models it
    let cu_frac =
        resources::cu_clbs(&d) as f64 / (u250::CLB_TOTAL as f64 / u250::SLRS as f64);
    let ii = 1.0 + (cu_frac - 0.5).max(0.0);
    let cycles_f = macs as f64 * ii + gemm_sim::PIPELINE_DEPTH;

    // DRAM traffic of this K-step: A strided, B + C writeback contiguous
    let bytes_per_elem = (meta.bits / 8) as f64;
    let read_a = (tn * kt) as f64 * bytes_per_elem;
    let read_b = (kt * tm) as f64 * bytes_per_elem;
    let write_c = (tn * tm) as f64 * bytes_per_elem;
    let mem_s = dram::stream_time(read_a, 1, dram::STRIDED_EFF)
        + dram::stream_time(read_b, 1, dram::CONTIGUOUS_EFF)
        + dram::stream_time(write_c, 1, dram::CONTIGUOUS_EFF);

    let dsps = dsp::multiplier_dsps(d.prec(), d.mult_base_bits) as f64;
    let clbs = resources::cu_clbs(&d) as f64;
    let energy_pj = cycles_f * (dsps * DSP_PJ_PER_CYCLE + clbs * CLB_PJ_PER_CYCLE);

    TileModelCost {
        cycles: cycles_f.ceil() as u64,
        macs,
        dram_bytes: (read_a + read_b + write_c) as u64,
        compute_ps: (cycles_f / f_hz * 1e12).round() as u64,
        mem_ps: (mem_s * 1e12).round() as u64,
        energy_pj: energy_pj.round() as u64,
    }
}

/// The third backend: native execution with hardware-model accounting.
///
/// Like [`NativeBackend`] it is **not `Sync`** (interior mutability via
/// `Cell`/`RefCell`); the coordinator gives each worker thread its own
/// instance, which is exactly the per-CU modeling convention.
pub struct SimBackend {
    native: NativeBackend,
    /// Per-artifact memo of the constant per-call cost (model synthesis is
    /// float-heavy; the warm path is a linear scan over a handful of
    /// artifacts).
    costs: RefCell<Vec<(String, TileModelCost)>>,
    /// Cost accrued since the last [`Backend::take_model_cost`] drain.
    pending: Cell<TileModelCost>,
}

impl SimBackend {
    pub fn new() -> Self {
        SimBackend {
            native: NativeBackend::new(),
            costs: RefCell::new(Vec::new()),
            pending: Cell::new(TileModelCost::default()),
        }
    }

    /// Like [`NativeBackend::with_fixed_path`]: pin the fixed-width lane
    /// on or off instead of reading `APFP_FIXED_PATH`.
    pub fn with_fixed_path(enabled: bool) -> Self {
        SimBackend {
            native: NativeBackend::with_fixed_path(enabled),
            costs: RefCell::new(Vec::new()),
            pending: Cell::new(TileModelCost::default()),
        }
    }

    /// Memoized [`tile_cost`]: synthesize once per artifact, then the hot
    /// path is an alloc-free scan.
    // apfp-lint: allow(alloc, scope=fn, reason="cold per-artifact memoization: model synthesis runs once per artifact name, every later call is a read-only scan")
    fn cached_cost(&self, meta: &ArtifactMeta) -> TileModelCost {
        if let Some((_, c)) = self.costs.borrow().iter().find(|(n, _)| *n == meta.name) {
            return *c;
        }
        let c = tile_cost(meta);
        self.costs.borrow_mut().push((meta.name.clone(), c));
        c
    }
}

impl Default for SimBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn warm(&self, meta: &ArtifactMeta) -> Result<()> {
        self.native.warm(meta)
    }

    fn exec_stream_binop(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        self.native.exec_stream_binop(meta, a, b)
    }

    fn exec_stream_mac(
        &self,
        meta: &ArtifactMeta,
        c: &PlaneBatch,
        a: &PlaneBatch,
        b: &PlaneBatch,
    ) -> Result<PlaneBatch> {
        self.native.exec_stream_mac(meta, c, a, b)
    }

    /// Bit-identical delegation to the native kernels, then (only on
    /// success) accrue the modeled cost of the K-step just executed.
    // apfp-lint: no_alloc
    fn exec_gemm_tile(
        &self,
        meta: &ArtifactMeta,
        a: &PlaneBatch,
        b: &PlaneBatch,
        c: &mut PlaneBatch,
    ) -> Result<()> {
        self.native.exec_gemm_tile(meta, a, b, c)?;
        let mut acc = self.pending.get();
        acc.add(&self.cached_cost(meta));
        self.pending.set(acc);
        Ok(())
    }

    fn take_model_cost(&self) -> Option<TileModelCost> {
        let cost = self.pending.replace(TileModelCost::default());
        if cost.is_zero() {
            None
        } else {
            Some(cost)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{builtin, TileShape};
    use crate::runtime::ArtifactKind;

    fn gemm_meta(bits: u32, tile: TileShape) -> ArtifactMeta {
        builtin(bits, tile)
            .unwrap()
            .into_iter()
            .find(|a| a.kind == ArtifactKind::Gemm)
            .unwrap()
    }

    #[test]
    fn tile_cost_matches_the_dataflow_model() {
        let meta = gemm_meta(512, TileShape::default());
        let c = tile_cost(&meta);
        assert_eq!(c.macs, 32 * 32 * 32);
        // 512-bit CU is below the half-SLR II knee: cycles = macs + fill
        assert_eq!(c.cycles, 32 * 32 * 32 + gemm_sim::PIPELINE_DEPTH as u64);
        // A + B + C at 64 bytes/elem
        assert_eq!(c.dram_bytes, (3 * 32 * 32 * 64) as u64);
        assert!(c.compute_ps > 0 && c.mem_ps > 0 && c.energy_pj > 0);
        // compute-bound at the paper tile (arithmetic intensity 16)
        assert!(c.compute_ps > c.mem_ps, "compute {} vs mem {}", c.compute_ps, c.mem_ps);
    }

    #[test]
    fn wider_precision_costs_more_everywhere() {
        let tile = TileShape::default();
        let c512 = tile_cost(&gemm_meta(512, tile));
        let c1024 = tile_cost(&gemm_meta(1024, tile));
        assert!(c1024.cycles >= c512.cycles, "II penalty can only grow");
        assert_eq!(c1024.dram_bytes, 2 * c512.dram_bytes);
        assert!(c1024.compute_ps > c512.compute_ps, "slower clock + II");
        assert!(c1024.energy_pj > c512.energy_pj, "more DSPs/CLBs active");
    }

    #[test]
    fn accrues_only_on_success_and_drains_exactly_once() {
        let be = SimBackend::new();
        assert!(be.take_model_cost().is_none(), "nothing accrued yet");

        let meta = gemm_meta(512, TileShape { n: 4, m: 4, k: 4 });
        let zeros = |n: usize| PlaneBatch::zeros(n, meta.prec());
        let a = zeros(meta.t_n * meta.k_tile);
        let b = zeros(meta.k_tile * meta.t_m);
        let mut cm = zeros(meta.t_n * meta.t_m);

        // a rejected call (wrong artifact kind) accrues nothing
        let bad = ArtifactMeta { kind: ArtifactKind::Mul, ..meta.clone() };
        assert!(be.exec_gemm_tile(&bad, &a, &b, &mut cm).is_err());
        assert!(be.take_model_cost().is_none());

        be.exec_gemm_tile(&meta, &a, &b, &mut cm).unwrap();
        be.exec_gemm_tile(&meta, &a, &b, &mut cm).unwrap();
        let per_call = tile_cost(&meta);
        let drained = be.take_model_cost().expect("two calls accrued");
        assert_eq!(drained.cycles, 2 * per_call.cycles);
        assert_eq!(drained.macs, 2 * per_call.macs);
        assert_eq!(drained.dram_bytes, 2 * per_call.dram_bytes);
        assert!(be.take_model_cost().is_none(), "drain resets the ledger");
    }

    #[test]
    fn sim_results_are_bit_identical_to_native() {
        use crate::testkit::{rand_ap, Rng};

        let meta = gemm_meta(512, TileShape { n: 4, m: 4, k: 4 });
        let prec = meta.prec();
        let mut rng = Rng::from_seed(0x51ABAC);
        let fill = |rng: &mut Rng, n: usize| {
            let mut pb = PlaneBatch::zeros(n, prec);
            for i in 0..n {
                pb.set(i, &rand_ap(rng, prec, 8));
            }
            pb
        };
        let a = fill(&mut rng, meta.t_n * meta.k_tile);
        let b = fill(&mut rng, meta.k_tile * meta.t_m);
        let c0 = fill(&mut rng, meta.t_n * meta.t_m);

        let native = NativeBackend::new();
        let sim = SimBackend::new();
        let mut c_native = c0.clone();
        let mut c_sim = c0.clone();
        native.exec_gemm_tile(&meta, &a, &b, &mut c_native).unwrap();
        sim.exec_gemm_tile(&meta, &a, &b, &mut c_sim).unwrap();
        assert_eq!(c_native.sign, c_sim.sign);
        assert_eq!(c_native.exp, c_sim.exp);
        assert_eq!(c_native.mant, c_sim.mant);
        assert!(sim.take_model_cost().is_some(), "and the model ledger is live");
    }
}
