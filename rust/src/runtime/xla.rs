//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The real PJRT CPU client comes from the `xla` crate (xla-rs), which
//! needs a vendored libxla and is unavailable in offline builds.  This shim
//! exposes exactly the API surface `runtime::backend::XlaBackend` touches
//! so the whole coordinator stack compiles and tests; constructing the
//! client fails with a clear error, which the compute-unit workers already
//! degrade on (they report "runtime unavailable" per job instead of
//! panicking).  A clean checkout runs everything on the native backend
//! instead (`runtime::NativeBackend`), which needs none of this.
//!
//! To light up the real backend, delete this module, add the `xla` crate to
//! Cargo.toml, and replace `use super::xla;` in `runtime/backend.rs` with
//! `use xla;` — the call sites are written against the real crate's API.

#![allow(dead_code)]

use std::fmt;

/// Error type mirroring the real crate's (callers only format it).
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "PJRT backend unavailable: built with the offline xla stub \
         (see rust/src/runtime/xla.rs)"
            .to_string(),
    )
}

/// Element types the plane layout marshals (i32 limb lanes, i64 exponents).
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for i64 {}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}
