//! DDR4 bank bandwidth accounting (§III / §V).
//!
//! Each of the U250's four banks peaks at 19.2 GB/s; compute units share
//! the bank they are placed on (Fig. 4 round-robin).  Strided (column-
//! major) reads of the non-contiguous GEMM operand still burst at least one
//! full number per access because every APFP element spans >= 512 bits
//! (§III), but lose some row-buffer locality — modeled as a derate.

use crate::hwmodel::{floorplan, u250};

/// Burst efficiency of contiguous streaming reads.
pub const CONTIGUOUS_EFF: f64 = 0.93;
/// Burst efficiency of the column-wise (strided) operand; the paper notes
/// the access is "less efficient" but still bursts >= one full number.
pub const STRIDED_EFF: f64 = 0.78;

/// Effective bandwidth available to one CU, given total replication.
pub fn per_cu_bandwidth(compute_units: usize) -> f64 {
    let counts = floorplan::cus_per_bank(compute_units);
    // the most-loaded bank limits the aggregate (synchronized K loops)
    let worst = counts.iter().max().copied().unwrap_or(0);
    if worst == 0 {
        return u250::DDR_BANK_BW;
    }
    u250::DDR_BANK_BW / worst as f64
}

/// Seconds to stream `bytes` at a given efficiency on one CU's share.
pub fn stream_time(bytes: f64, compute_units: usize, efficiency: f64) -> f64 {
    bytes / (per_cu_bandwidth(compute_units) * efficiency)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_shares() {
        assert_eq!(per_cu_bandwidth(1), 19.2e9);
        assert_eq!(per_cu_bandwidth(4), 19.2e9); // one per bank
        assert_eq!(per_cu_bandwidth(8), 9.6e9); // two per bank
        assert_eq!(per_cu_bandwidth(16), 4.8e9);
    }

    #[test]
    fn stream_time_scales() {
        let t1 = stream_time(19.2e9, 1, 1.0);
        assert!((t1 - 1.0).abs() < 1e-9);
        let t8 = stream_time(19.2e9, 8, 1.0);
        assert!((t8 - 2.0).abs() < 1e-9);
    }
}
