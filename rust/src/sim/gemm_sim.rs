//! §III / §V-C/D tiled-GEMM dataflow model — regenerates Fig. 5, Tab. III
//! and Fig. 6.
//!
//! Dataflow (per compute unit, §III): the output C is tiled T_N x T_M; for
//! each tile the K loop streams one column-piece of A (T_N operands) and
//! one row-piece of B (T_M operands) per step, performing T_N*T_M MACs on
//! the single fully-pipelined multiply-add unit (II = 1, so T_N*T_M cycles
//! per step).  P compute units partition the N dimension into row bands of
//! N/P; every CU streams the full B.
//!
//! Per-call fixed costs modeled (these create the rising small-n region of
//! Fig. 5, where "more replications require larger matrices to reach peak"):
//!   * host-side MPFR <-> packed-format conversion of A, B, C (§IV-B);
//!   * PCIe transfer of the operands to the per-CU DRAM banks;
//!   * kernel launch + pipeline fill/drain per tile.

use crate::hwmodel::DesignPoint;
use crate::sim::dram;

/// Host-side conversion cost per element (MPFR heap layout -> Fig. 1 packed),
/// seconds.  Dominates small-n efficiency; see module docs.
pub const CONVERT_S_PER_ELEM: f64 = 120e-9;
/// Effective host->device PCIe bandwidth (Gen3 x16 with overheads).
pub const PCIE_BW: f64 = 11.0e9;
/// Kernel launch + per-call orchestration (XRT), seconds.
pub const LAUNCH_S: f64 = 250e-6;
/// Multiply-add pipeline depth in cycles (fill + drain per output tile).
pub const PIPELINE_DEPTH: f64 = 400.0;

#[derive(Clone, Debug)]
pub struct GemmPoint {
    pub n: usize,
    pub mmacs: f64,
    /// fraction of the f*P roofline achieved
    pub efficiency: f64,
    pub compute_s: f64,
    pub mem_s: f64,
    pub fixed_s: f64,
}

/// Simulate C += A*B for n x n matrices on `d` (GEMM design point), with
/// tile sizes from the paper's evaluation (32 x 32).
pub fn simulate(d: &DesignPoint, n: usize, tile_n: usize, tile_m: usize) -> GemmPoint {
    let s = d.synthesize();
    let f = s.frequency_mhz * 1e6;
    let p = d.compute_units;
    let bytes_per_elem = (d.bits / 8) as f64;

    // per-CU geometry: row band of ceil(n/P) rows, padded to tile multiples
    let rows_cu = n.div_ceil(p);
    let tiles_n = rows_cu.div_ceil(tile_n);
    let tiles_m = n.div_ceil(tile_m);
    let tiles = (tiles_n * tiles_m) as f64;

    // compute: K loop of n steps, T_N*T_M cycles each, + fill/drain per tile.
    // A compute unit that fills most of an SLR is "scheduled in a monolithic
    // manner" (§V-D) and loses II=1: model the initiation-interval penalty
    // as growing once the unit exceeds half the chiplet (the paper's
    // 1024-bit GEMM unit, ~0.7 SLR, runs visibly below its clock roofline).
    let cu_frac = crate::hwmodel::resources::cu_clbs(d) as f64
        / (crate::hwmodel::u250::CLB_TOTAL as f64 / crate::hwmodel::u250::SLRS as f64);
    let ii = 1.0 + (cu_frac - 0.5).max(0.0);
    let cycles_per_tile = (n * tile_n * tile_m) as f64 * ii + PIPELINE_DEPTH;
    let compute_s = tiles * cycles_per_tile / f;

    // memory per CU: each tile streams (T_N + T_M) * n operands (A strided,
    // B contiguous) and writes back T_N*T_M results
    let tile_read_a = (tile_n * n) as f64 * bytes_per_elem;
    let tile_read_b = (tile_m * n) as f64 * bytes_per_elem;
    let tile_write_c = (tile_n * tile_m) as f64 * bytes_per_elem;
    let mem_s = tiles
        * (dram::stream_time(tile_read_a, p, dram::STRIDED_EFF)
            + dram::stream_time(tile_read_b, p, dram::CONTIGUOUS_EFF)
            + dram::stream_time(tile_write_c, p, dram::CONTIGUOUS_EFF));

    // per-call fixed costs (host side, serial): format conversion of A, B, C
    // + transfer (A and C partitioned across banks; B replicated to 4 banks)
    let elems = (n * n) as f64;
    let convert_s = 3.0 * elems * CONVERT_S_PER_ELEM;
    let transfer_bytes = (2.0 + 4.0_f64.min(p as f64)) * elems * bytes_per_elem;
    let fixed_s = convert_s + transfer_bytes / PCIE_BW + LAUNCH_S * p as f64;

    // compute and memory overlap (double-buffered streams); fixed costs don't
    let kernel_s = compute_s.max(mem_s);
    let total_s = kernel_s + fixed_s;

    let macs = (n as f64).powi(3);
    let mmacs = macs / total_s;
    GemmPoint {
        n,
        mmacs,
        efficiency: mmacs / (f * p as f64),
        compute_s,
        mem_s,
        fixed_s,
    }
}

/// Peak (max over the paper's Fig. 5 n-range) performance of a design.
pub fn peak(d: &DesignPoint, tile: usize) -> GemmPoint {
    let mut best = simulate(d, 256, tile, tile);
    let mut n = 512;
    while n <= 16384 {
        let pt = simulate(d, n, tile, tile);
        if pt.mmacs > best.mmacs {
            best = pt;
        }
        n *= 2;
    }
    best
}

/// The Fig. 5/6 series: MMAC/s over matrix sizes for one design point.
pub fn series(d: &DesignPoint, tile: usize, sizes: &[usize]) -> Vec<GemmPoint> {
    sizes.iter().map(|&n| simulate(d, n, tile, tile)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwmodel::DesignPoint;
    use crate::sim::cpu_ref;

    /// Tab. III max-performance column (within 15%): 322 / 540 / 1049 / 2002.
    #[test]
    fn tab3_peaks() {
        for (cus, paper) in [(1, 322.0), (2, 540.0), (4, 1049.0), (8, 2002.0)] {
            let pt = peak(&DesignPoint::gemm_512(cus), 32);
            let got = pt.mmacs / 1e6;
            let rel = (got - paper).abs() / paper;
            assert!(rel < 0.18, "CUs={cus}: got {got:.0} MMAC/s, paper {paper}");
        }
    }

    /// Fig. 6: single 1024-bit CU peaks near 158 MMAC/s and beats the
    /// 36-core node.
    #[test]
    fn fig6_peak() {
        let pt = peak(&DesignPoint::gemm_1024(1), 32);
        let got = pt.mmacs / 1e6;
        assert!((got - 158.0).abs() / 158.0 < 0.35, "got {got:.0}");
        assert!(pt.mmacs > cpu_ref::gemm_mmacs(1024, 1, 8192));
    }

    /// Fig. 5 shape: curves rise with n, and more CUs need larger n to
    /// approach peak (strong-scaling effect the paper describes).
    #[test]
    fn fig5_rising_curves() {
        let d8 = DesignPoint::gemm_512(8);
        let s = series(&d8, 32, &[512, 1024, 2048, 4096, 8192, 16384]);
        for w in s.windows(2) {
            assert!(w[1].mmacs >= w[0].mmacs * 0.98, "non-rising at n={}", w[1].n);
        }
        let d1 = DesignPoint::gemm_512(1);
        let eff1_small = simulate(&d1, 1024, 32, 32).efficiency;
        let eff8_small = simulate(&d8, 1024, 32, 32).efficiency;
        assert!(eff1_small > eff8_small, "1 CU should saturate earlier");
    }

    /// Fig. 5 headline: the 8-CU accelerator outperforms 8 Xeon nodes
    /// (>10 nodes in the paper; >= 8 within our CPU-model tolerance).
    #[test]
    fn fig5_beats_node_cluster() {
        let fpga = peak(&DesignPoint::gemm_512(8), 32).mmacs;
        let nodes8 = cpu_ref::gemm_mmacs(512, 8, 16384);
        assert!(fpga > nodes8, "fpga {fpga:.2e} vs 8 nodes {nodes8:.2e}");
        // equivalent cores > 300 (paper: 375x)
        let cores = fpga / (cpu_ref::gemm_mmacs(512, 1, 16384) / 36.0);
        assert!(cores > 300.0, "{cores:.0} cores");
    }

    /// A single 512-bit CU corresponds to ~1-2 Xeon nodes (§V-C).
    #[test]
    fn fig5_single_cu_vs_nodes() {
        let fpga = peak(&DesignPoint::gemm_512(1), 32).mmacs;
        let one_node = cpu_ref::gemm_mmacs(512, 1, 16384);
        let two_nodes = cpu_ref::gemm_mmacs(512, 2, 16384);
        assert!(fpga > one_node * 0.9);
        assert!(fpga < two_nodes * 1.3);
    }

    /// GEMM is compute-bound at the paper's 32x32 tile (the whole point of
    /// the 2D tiling: arithmetic intensity T_N*T_M/(T_N+T_M) = 16).
    #[test]
    fn compute_bound_at_paper_tile() {
        let pt = simulate(&DesignPoint::gemm_512(8), 8192, 32, 32);
        assert!(pt.compute_s > pt.mem_s, "compute {:.3}s vs mem {:.3}s", pt.compute_s, pt.mem_s);
        // at tiny tiles the same design becomes memory-bound
        let pt4 = simulate(&DesignPoint::gemm_512(8), 8192, 4, 4);
        assert!(pt4.mem_s > pt4.compute_s);
    }
}
