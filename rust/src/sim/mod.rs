//! Cycle-level performance simulator of the accelerator — the substitute
//! for running on a physical U250 (DESIGN.md §1).
//!
//! * [`dram`] — DDR4 bank bandwidth accounting (Fig. 4 sharing).
//! * [`mult_sim`] — the §V-B multiplier microbenchmark (Tab. I / Tab. II).
//! * [`gemm_sim`] — the §V-C/D tiled-GEMM dataflow (Fig. 5 / Tab. III /
//!   Fig. 6).
//!
//! The simulator consumes design points synthesized by [`crate::hwmodel`]
//! (frequency, placement) and first-principles dataflow counts (operands
//! moved, pipeline occupancy); its outputs are the rows/series of the
//! paper's tables and figures.  CPU reference lines use the paper's
//! reported MPFR/Elemental measurements as constants (`cpu_ref`), while the
//! benches additionally *measure* this host's softfloat throughput for an
//! honest second baseline (EXPERIMENTS.md reports both).

pub mod dram;
pub mod gemm_sim;
pub mod mult_sim;

/// Paper-reported CPU reference numbers (36-core dual-socket Xeon E5-2695
/// v4 node, MPFR 4.1.0 / Elemental, §V).
pub mod cpu_ref {
    /// Tab. I: 512-bit multiplication, full node, operands in L1.
    pub const MULT_512_NODE_MOPS: f64 = 490.0e6;
    /// Tab. II: 1024-bit multiplication, full node.
    pub const MULT_1024_NODE_MOPS: f64 = 227.0e6;
    /// Cores per node.
    pub const NODE_CORES: f64 = 36.0;
    /// Elemental/MPFR 512-bit GEMM on one node, large-n asymptote
    /// (read off Fig. 5: the 1-node dashed line saturates near 200 MMAC/s).
    pub const GEMM_512_NODE_MMACS: f64 = 200.0e6;
    /// Fig. 6: 1024-bit GEMM node asymptote (~70 MMAC/s).
    pub const GEMM_1024_NODE_MMACS: f64 = 70.0e6;
    /// MPI scaling efficiency of Elemental at 8 nodes (Fig. 5 spacing).
    pub const MPI_EFFICIENCY: f64 = 0.88;

    /// Reference throughput for a multiplier stream at a given width.
    pub fn mult_node_mops(bits: u32) -> f64 {
        match bits {
            512 => MULT_512_NODE_MOPS,
            1024 => MULT_1024_NODE_MOPS,
            // MPFR multiplication is ~quadratic at these sizes
            _ => MULT_512_NODE_MOPS * (512.0 / bits as f64).powi(2),
        }
    }

    /// Elemental GEMM throughput model for `nodes` nodes at matrix size n
    /// (saturating rise with n: MPI distribution + per-rank overhead).
    pub fn gemm_mmacs(bits: u32, nodes: usize, n: usize) -> f64 {
        let node_rate = match bits {
            512 => GEMM_512_NODE_MMACS,
            1024 => GEMM_1024_NODE_MMACS,
            _ => GEMM_512_NODE_MMACS * (512.0 / bits as f64).powi(2),
        };
        // sub-linear node scaling: nodes^alpha with alpha chosen so that
        // 8 nodes deliver 8 * MPI_EFFICIENCY times one node
        let alpha = 1.0 + (MPI_EFFICIENCY.ln() / 8.0f64.ln());
        let peak = node_rate * (nodes as f64).powf(alpha);
        // rise: work n^3 vs per-node fixed cost (distribution, latency)
        let work = (n as f64).powi(3);
        let overhead = 2.0e9 * nodes as f64; // MAC-equivalents of fixed cost
        peak * work / (work + overhead)
    }
}

#[cfg(test)]
mod tests {
    use super::cpu_ref;

    #[test]
    fn mult_reference_widths() {
        assert_eq!(cpu_ref::mult_node_mops(512), 490.0e6);
        assert_eq!(cpu_ref::mult_node_mops(1024), 227.0e6);
        // quadratic extrapolation beyond evaluated widths
        assert!(cpu_ref::mult_node_mops(2048) < 227.0e6 / 2.0);
    }

    #[test]
    fn gemm_reference_scales_with_nodes_and_n() {
        let one = cpu_ref::gemm_mmacs(512, 1, 8192);
        let eight = cpu_ref::gemm_mmacs(512, 8, 8192);
        assert!(eight > 6.0 * one, "8-node scaling too weak: {one} -> {eight}");
        assert!(eight < 8.0 * one, "scaling cannot be super-linear");
        // rising in n
        assert!(cpu_ref::gemm_mmacs(512, 8, 1024) < cpu_ref::gemm_mmacs(512, 8, 8192));
        // large-n single node approaches the Fig. 5 asymptote
        assert!((one - 200.0e6).abs() / 200.0e6 < 0.05);
    }
}
