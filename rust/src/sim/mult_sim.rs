//! §V-B multiplier microbenchmark model — regenerates Tab. I and Tab. II.
//!
//! The paper's benchmark streams operand pairs through the multiplier with
//! the memory bottleneck artificially removed (same element re-fed), so a
//! fully pipelined CU delivers exactly one multiplication per cycle:
//! throughput = CUs x f.  The CPU column is the paper's measured MPFR
//! throughput with all operands resident in L1.

use crate::hwmodel::DesignPoint;
use crate::sim::cpu_ref;

#[derive(Clone, Debug)]
pub struct MultRow {
    pub label: String,
    pub frequency_mhz: f64,
    pub clb_pct: f64,
    pub dsp_pct: f64,
    pub throughput_mops: f64,
    pub speedup_vs_node: f64,
    pub equivalent_cores: f64,
    pub failed: Option<String>,
}

/// One FPGA row of Tab. I/II for `cus` compute units at `bits` precision.
pub fn fpga_row(bits: u32, cus: usize) -> MultRow {
    let d = match bits {
        512 => DesignPoint::mult_512(cus),
        1024 => DesignPoint::mult_1024(cus),
        _ => DesignPoint {
            bits,
            compute_units: cus,
            mult_base_bits: 72,
            add_base_bits: 64,
            gemm: false,
        },
    };
    let s = d.synthesize();
    // one op per cycle per CU; memory bottleneck removed as in the paper
    let throughput = s.frequency_mhz * 1e6 * cus as f64;
    let node = cpu_ref::mult_node_mops(bits);
    MultRow {
        label: format!("FPGA {cus} CU{}", if cus == 1 { "" } else { "s" }),
        frequency_mhz: s.frequency_mhz,
        clb_pct: s.clb_frac * 100.0,
        dsp_pct: s.dsp_frac * 100.0,
        throughput_mops: throughput / 1e6,
        speedup_vs_node: throughput / node,
        equivalent_cores: throughput / (node / cpu_ref::NODE_CORES),
        failed: s.failure,
    }
}

/// The CPU reference row (paper-reported MPFR on the 36-core node).
pub fn cpu_row(bits: u32) -> MultRow {
    let node = cpu_ref::mult_node_mops(bits);
    MultRow {
        label: "36-core CPU (paper MPFR)".into(),
        frequency_mhz: 2100.0,
        clb_pct: 0.0,
        dsp_pct: 0.0,
        throughput_mops: node / 1e6,
        speedup_vs_node: 1.0,
        equivalent_cores: cpu_ref::NODE_CORES,
        failed: None,
    }
}

/// A CPU row from a *measured* host throughput (ops/s) — the honest local
/// baseline the benches feed in (EXPERIMENTS.md reports both).
pub fn measured_cpu_row(label: &str, ops_per_sec: f64, bits: u32) -> MultRow {
    let node = cpu_ref::mult_node_mops(bits);
    MultRow {
        label: label.into(),
        frequency_mhz: 0.0,
        clb_pct: 0.0,
        dsp_pct: 0.0,
        throughput_mops: ops_per_sec / 1e6,
        speedup_vs_node: ops_per_sec / node,
        equivalent_cores: ops_per_sec / (node / cpu_ref::NODE_CORES),
        failed: None,
    }
}

/// All rows of Tab. I (512-bit: 1/4/8/12/16 CUs) or Tab. II (1024: 1/4).
pub fn table(bits: u32) -> Vec<MultRow> {
    let cu_counts: &[usize] = match bits {
        512 => &[1, 4, 8, 12, 16],
        _ => &[1, 4],
    };
    let mut rows = vec![cpu_row(bits)];
    rows.extend(cu_counts.iter().map(|&c| fpga_row(bits, c)));
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tab. I headline: 16 CUs reach ~4.8 GOp/s, ~9.8x the node, ~351 cores.
    #[test]
    fn tab1_headline() {
        let r = fpga_row(512, 16);
        assert!(r.failed.is_none());
        assert!((r.throughput_mops - 4784.0).abs() / 4784.0 < 0.10, "{:.0} MOp/s", r.throughput_mops);
        assert!((r.speedup_vs_node - 9.8).abs() < 1.2, "{:.1}x", r.speedup_vs_node);
        assert!((r.equivalent_cores - 351.0).abs() < 45.0, "{:.0} cores", r.equivalent_cores);
    }

    /// Tab. I: a single CU roughly matches the full 36-core node (0.9x).
    #[test]
    fn tab1_single_cu_parity() {
        let r = fpga_row(512, 1);
        assert!((0.75..1.15).contains(&r.speedup_vs_node), "{:.2}x", r.speedup_vs_node);
    }

    /// Tab. II headline: 4 CUs at 1024 bits ~1.2 GOp/s, ~5.3x, ~191 cores.
    #[test]
    fn tab2_headline() {
        let r = fpga_row(1024, 4);
        assert!(r.failed.is_none());
        assert!((r.throughput_mops - 1202.0).abs() / 1202.0 < 0.10, "{:.0} MOp/s", r.throughput_mops);
        assert!((r.speedup_vs_node - 5.3).abs() < 0.8, "{:.1}x", r.speedup_vs_node);
        assert!((r.equivalent_cores - 191.0).abs() < 30.0, "{:.0} cores", r.equivalent_cores);
    }

    /// Tab. II: one 1024-bit CU beats the node (1.6x).
    #[test]
    fn tab2_single_cu() {
        let r = fpga_row(1024, 1);
        assert!((r.speedup_vs_node - 1.6).abs() < 0.3, "{:.2}x", r.speedup_vs_node);
    }

    #[test]
    fn table_shapes() {
        assert_eq!(table(512).len(), 6); // CPU + 5 FPGA rows
        assert_eq!(table(1024).len(), 3);
        // throughput strictly increases with replication
        let t = table(512);
        for w in t[1..].windows(2) {
            assert!(w[1].throughput_mops > w[0].throughput_mops);
        }
    }
}
