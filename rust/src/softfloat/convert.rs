//! Conversions: f64 <-> ApFloat, decimal strings -> ApFloat, the
//! `ApFloat ⇄ ApFloatN` fixed-width shims, display.
//!
//! Apart from [`ApFloatN::write_to`] (used when a fixed-lane kernel hands
//! results back to dynamic consumers), these are host-side conveniences
//! (loading matrices, printing results) off the accelerator hot path.

use super::fixed::ApFloatN;
use super::ApFloat;
use crate::bigint;

#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum ParseApFloatError {
    #[error("empty or malformed number: {0:?}")]
    Malformed(String),
    #[error("exponent out of range: {0:?}")]
    ExponentRange(String),
}

impl ApFloat {
    /// Exact embedding of an f64 (doubles have 53-bit significands, far
    /// below any supported precision, so this never rounds).
    pub fn from_f64(x: f64, prec: u32) -> Self {
        assert!(x.is_finite(), "inf/NaN are outside the APFP domain");
        if x == 0.0 {
            return ApFloat::zero(prec);
        }
        let bits = x.to_bits();
        let sign = bits >> 63 == 1;
        let biased = ((bits >> 52) & 0x7FF) as i64;
        let frac = bits & ((1u64 << 52) - 1);
        let (mant53, e) = if biased == 0 {
            (frac, -1074i64) // subnormal double
        } else {
            (frac | (1 << 52), biased - 1075)
        };
        ApFloat::from_int_scaled(sign, &[mant53], e, prec)
    }

    /// Truncating conversion to f64 (exact RNDZ to the f64 grid, built
    /// directly from the bit pattern; saturates to +-inf / 0 at the range
    /// edges like `mpfr_get_d`).
    pub fn to_f64(&self) -> f64 {
        if self.is_zero() {
            return 0.0;
        }
        let sign_bit = (self.sign as u64) << 63;
        // value in [2^(exp-1), 2^exp)  =>  unbiased f64 exponent = exp - 1
        let e = self.exp - 1;
        let top = self.mant[self.mant.len() - 1]; // bit 63 set (normalized)
        let bits = if e > 1023 {
            0x7FF0_0000_0000_0000 // +inf magnitude
        } else if e >= -1022 {
            // normal: drop the implicit leading 1, keep the next 52 bits
            let frac = (top << 1) >> 12;
            (((e + 1023) as u64) << 52) | frac
        } else {
            // subnormal: the significand keeps 52 - (-1022 - e - 1) bits,
            // leading 1 included explicitly
            let shift = (-1022 - e) as u64; // >= 1
            if shift > 52 {
                0 // underflows to zero
            } else {
                top >> (11 + shift)
            }
        };
        f64::from_bits(sign_bit | bits)
    }

    /// Parse a decimal string: `[+-]digits[.digits][eE[+-]digits]`.
    /// The value is computed exactly and truncated (RNDZ) to `prec` bits,
    /// so parsing agrees bit-for-bit with MPFR's `mpfr_set_str(..., RNDZ)`.
    pub fn parse_decimal(s: &str, prec: u32) -> Result<Self, ParseApFloatError> {
        let t = s.trim();
        let malformed = || ParseApFloatError::Malformed(s.to_string());
        let (sign, rest) = match t.as_bytes().first() {
            Some(b'-') => (true, &t[1..]),
            Some(b'+') => (false, &t[1..]),
            Some(_) => (false, t),
            None => return Err(malformed()),
        };
        let (mant_part, exp_part) = match rest.find(['e', 'E']) {
            Some(i) => (&rest[..i], Some(&rest[i + 1..])),
            None => (rest, None),
        };
        let e10_extra: i64 = match exp_part {
            Some(e) => e.parse().map_err(|_| malformed())?,
            None => 0,
        };
        let (int_part, frac_part) = match mant_part.find('.') {
            Some(i) => (&mant_part[..i], &mant_part[i + 1..]),
            None => (mant_part, ""),
        };
        if int_part.is_empty() && frac_part.is_empty() {
            return Err(malformed());
        }
        if !int_part.bytes().all(|b| b.is_ascii_digit())
            || !frac_part.bytes().all(|b| b.is_ascii_digit())
        {
            return Err(malformed());
        }
        // digits as a big integer D; value = D * 10^e10
        let mut digits = vec![0u64; 1];
        for b in int_part.bytes().chain(frac_part.bytes()) {
            mul_small_grow(&mut digits, 10);
            if bigint::add_limb(&mut digits, (b - b'0') as u64) {
                digits.push(1);
            }
        }
        let e10 = e10_extra - frac_part.len() as i64;
        if e10.unsigned_abs() > 1 << 24 {
            return Err(ParseApFloatError::ExponentRange(s.to_string()));
        }
        Ok(Self::from_decimal_parts(sign, digits, e10, prec))
    }

    /// value = (-1)^sign * D * 10^e10, exact then RNDZ-truncated.
    fn from_decimal_parts(sign: bool, mut digits: Vec<u64>, e10: i64, prec: u32) -> Self {
        if bigint::is_zero(&digits) {
            return ApFloat::zero(prec);
        }
        if e10 >= 0 {
            for _ in 0..e10 {
                mul_small_grow(&mut digits, 10);
            }
            return ApFloat::from_int_scaled(sign, &digits, 0, prec);
        }
        // D / 10^k: widen D so the quotient keeps prec + 64 significant
        // bits, divide by 10 k times; any nonzero remainder only lowers the
        // true value, which truncation (RNDZ) already accounts for.
        let k = (-e10) as u64;
        // 10^k < 2^(4k): give the numerator prec + 64 + 4k extra low bits
        let extra_bits = prec as u64 + 64 + 4 * k;
        let shift_limbs = extra_bits.div_ceil(64) as usize;
        let mut num = vec![0u64; digits.len() + shift_limbs];
        num[shift_limbs..].copy_from_slice(&digits);
        for _ in 0..k {
            div_small(&mut num, 10);
        }
        ApFloat::from_int_scaled(sign, &num, -((shift_limbs * 64) as i64), prec)
    }

    /// Scientific-notation decimal rendering with `sig_digits` significant
    /// digits (exact digit extraction; truncated toward zero).
    pub fn to_decimal_string(&self, sig_digits: usize) -> String {
        if self.is_zero() {
            return "0".to_string();
        }
        // Compute D = floor(|x| * 10^s) for s chosen so D has ~sig_digits
        // digits: x = M * 2^(exp - prec).
        let e2 = self.exp as i128 - self.prec as i128;
        // decimal exponent of x is about exp * log10(2)
        let dec_exp = (self.exp as f64 * std::f64::consts::LOG10_2).floor() as i64;
        let s = sig_digits as i64 - dec_exp; // scale: multiply by 10^s
        let mut acc = self.mant.clone();
        // acc * 10^s * 2^e2, tracked in (acc, bin_shift)
        let mut bin: i128 = e2;
        if s >= 0 {
            for _ in 0..s {
                mul_small_grow(&mut acc, 10);
            }
        } else {
            let k = (-s) as u64;
            let extra = (4 * k + 64).div_ceil(64) as usize;
            let mut wide = vec![0u64; acc.len() + extra];
            wide[extra..].copy_from_slice(&acc);
            bin -= (extra * 64) as i128;
            for _ in 0..k {
                div_small(&mut wide, 10);
            }
            acc = wide;
        }
        // apply the binary scale exactly (truncating on right shifts)
        if bin >= 0 {
            let grow = (bin as usize).div_ceil(64) + 1;
            let mut wide = vec![0u64; acc.len() + grow];
            bigint::shl(&acc, bin as usize, &mut wide[..]);
            // shl keeps width; rebuild with room
            let mut src = acc.clone();
            src.resize(acc.len() + grow, 0);
            bigint::shl(&src, bin as usize, &mut wide);
            acc = wide;
        } else {
            let sh = (-bin) as usize;
            let mut out = vec![0u64; acc.len()];
            bigint::shr(&acc, sh, &mut out);
            acc = out;
        }
        // extract decimal digits of acc
        let mut digits = Vec::new();
        while !bigint::is_zero(&acc) {
            let r = div_small(&mut acc, 10);
            digits.push(b'0' + r as u8);
        }
        if digits.is_empty() {
            digits.push(b'0');
        }
        digits.reverse();
        let text: String = digits.iter().map(|&b| b as char).collect();
        let shown = &text[..sig_digits.min(text.len())];
        let point_exp = text.len() as i64 - s - 1; // value = 0.text * 10^(len - s)
        let mantissa = if shown.len() > 1 {
            format!("{}.{}", &shown[..1], &shown[1..])
        } else {
            shown.to_string()
        };
        let sign = if self.sign { "-" } else { "" };
        format!("{sign}{mantissa}e{point_exp}")
    }
}

impl<const L: usize> ApFloatN<L> {
    /// Exact conversion from a dynamic value of the matching width.  Both
    /// representations store the same `(sign, exp, mantissa)` triple, so
    /// this is a limb copy — no rounding, round-trips bit-for-bit.
    pub fn from_ap(v: &ApFloat) -> Self {
        assert_eq!(v.prec() as usize, 64 * L, "width mismatch: ApFloat prec vs LIMBS");
        let mut mant = [0u64; L];
        mant.copy_from_slice(&v.mant);
        ApFloatN { sign: v.sign, exp: v.exp, mant }
    }

    /// Exact conversion to the dynamic representation (allocates the
    /// mantissa vector; hot loops should reuse a slot via
    /// [`ApFloatN::write_to`] instead).
    pub fn to_ap(&self) -> ApFloat {
        ApFloat { sign: self.sign, exp: self.exp, mant: self.mant.to_vec(), prec: 64 * L as u32 }
    }

    /// Write this value into a dynamic slot, reusing the slot's mantissa
    /// buffer — the allocation-free half of the round-trip, mirroring
    /// `ApFloat::assign`.
    // apfp-lint: no_alloc
    pub fn write_to(&self, out: &mut ApFloat) {
        out.sign = self.sign;
        out.exp = self.exp;
        out.prec = 64 * L as u32;
        if out.mant.len() != L {
            out.mant.clear();
            // apfp-lint: allow(alloc, reason="capacity reuse: clear+resize refills the existing buffer; reallocates only when the width changes")
            out.mant.resize(L, 0);
        }
        out.mant.copy_from_slice(&self.mant);
    }
}

/// a *= m (small multiplier), growing the vector if it overflows.
fn mul_small_grow(a: &mut Vec<u64>, m: u64) {
    let mut carry: u64 = 0;
    for x in a.iter_mut() {
        let t = *x as u128 * m as u128 + carry as u128;
        *x = t as u64;
        carry = (t >> 64) as u64;
    }
    if carry != 0 {
        a.push(carry);
    }
}

/// a /= d (small divisor); returns the remainder.
fn div_small(a: &mut [u64], d: u64) -> u64 {
    let mut rem: u64 = 0;
    for x in a.iter_mut().rev() {
        let t = ((rem as u128) << 64) | *x as u128;
        *x = (t / d as u128) as u64;
        rem = (t % d as u128) as u64;
    }
    rem
}

#[cfg(test)]
mod tests {
    use super::super::ApFloat;
    use crate::testkit;

    const P: u32 = 448;

    #[test]
    fn f64_roundtrip_exact() {
        for x in [1.0, -1.0, 0.5, 3.141592653589793, 1e300, -1e-300, 2f64.powi(-1074)] {
            let v = ApFloat::from_f64(x, P);
            assert_eq!(v.to_f64(), x, "{x}");
        }
        assert_eq!(ApFloat::from_f64(0.0, P).to_f64(), 0.0);
    }

    #[test]
    fn f64_roundtrip_property() {
        testkit::check(300, |rng| {
            let x = f64::from_bits(rng.next_u64());
            if x.is_finite() {
                assert_eq!(ApFloat::from_f64(x, P).to_f64(), x, "{x:?}");
            }
        });
    }

    #[test]
    fn parse_integers() {
        assert_eq!(ApFloat::parse_decimal("42", P).unwrap(), ApFloat::from_i64(42, P));
        assert_eq!(ApFloat::parse_decimal("-7", P).unwrap(), ApFloat::from_i64(-7, P));
        assert_eq!(ApFloat::parse_decimal("+0", P).unwrap(), ApFloat::zero(P));
        // 10^24 exactly (1e24 as an f64 literal would NOT be exact)
        let e12 = ApFloat::from_i64(1_000_000_000_000, P);
        assert_eq!(
            ApFloat::parse_decimal("1000000000000000000000000", P).unwrap(),
            e12.mul(&e12)
        );
    }

    #[test]
    fn parse_fractions_exact_binary() {
        assert_eq!(ApFloat::parse_decimal("0.5", P).unwrap(), ApFloat::from_f64(0.5, P));
        assert_eq!(ApFloat::parse_decimal("2.5e1", P).unwrap(), ApFloat::from_i64(25, P));
        assert_eq!(ApFloat::parse_decimal("1e3", P).unwrap(), ApFloat::from_i64(1000, P));
        assert_eq!(ApFloat::parse_decimal(".25", P).unwrap(), ApFloat::from_f64(0.25, P));
    }

    #[test]
    fn parse_tenth_truncates_toward_zero() {
        // 0.1 is not binary-representable; RNDZ result must be < 0.1
        let v = ApFloat::parse_decimal("0.1", P).unwrap();
        let f = v.to_f64();
        assert!((f - 0.1).abs() < 1e-15);
        // check strict truncation via 10 * v <= 1
        let ten = ApFloat::from_i64(10, P);
        let one = ApFloat::from_i64(1, P);
        assert_eq!(v.mul(&ten).cmp_total(&one), std::cmp::Ordering::Less);
    }

    #[test]
    fn parse_errors() {
        assert!(ApFloat::parse_decimal("", P).is_err());
        assert!(ApFloat::parse_decimal("abc", P).is_err());
        assert!(ApFloat::parse_decimal("1.2.3", P).is_err());
        assert!(ApFloat::parse_decimal("1e99999999999", P).is_err());
    }

    #[test]
    fn decimal_string_roundtrip() {
        for s in ["1", "-2.5", "3.25e10", "7.625e-5"] {
            let v = ApFloat::parse_decimal(s, P).unwrap();
            let shown = v.to_decimal_string(30);
            let back = ApFloat::parse_decimal(&shown, P).unwrap();
            let rel = (back.to_f64() - v.to_f64()).abs() / v.to_f64().abs().max(1e-300);
            assert!(rel < 1e-25, "{s} -> {shown} rel={rel}");
        }
    }

    #[test]
    fn decimal_string_pi() {
        let pi = ApFloat::from_f64(std::f64::consts::PI, P);
        let s = pi.to_decimal_string(16);
        assert!(s.starts_with("3.14159265358979"), "{s}");
    }

    #[test]
    fn fixed_roundtrip_exact_property() {
        use crate::softfloat::{ApFloat448, ApFloat960};
        testkit::check(300, |rng| {
            let a = testkit::rand_ap(rng, 448, 500);
            let f = ApFloat448::from_ap(&a);
            assert_eq!(f.to_ap(), a, "448 round-trip");
            assert_eq!((f.sign(), f.exp()), (a.sign(), a.exp()));
            let w = testkit::rand_ap(rng, 960, 500);
            let g = ApFloat960::from_ap(&w);
            assert_eq!(g.to_ap(), w, "960 round-trip");
        });
        // zero round-trips canonically at both widths
        let z = ApFloat448::from_ap(&ApFloat::zero(448));
        assert!(z.is_zero());
        assert_eq!(z.to_ap(), ApFloat::zero(448));
    }

    #[test]
    fn fixed_write_to_reuses_buffer_and_corrects_width() {
        use crate::softfloat::ApFloat448;
        let mut rng = testkit::Rng::from_seed(31);
        let v = ApFloat448::from_ap(&testkit::rand_ap(&mut rng, 448, 100));
        // same-width slot: pointer stable
        let mut slot = ApFloat::zero(448);
        let ptr = slot.limbs().as_ptr();
        v.write_to(&mut slot);
        assert_eq!(slot, v.to_ap());
        assert_eq!(slot.limbs().as_ptr(), ptr, "same-width write_to must not reallocate");
        // wrong-width slot: reshaped once, then value matches
        let mut wide = ApFloat::zero(960);
        v.write_to(&mut wide);
        assert_eq!(wide, v.to_ap());
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn fixed_from_ap_rejects_width_mismatch() {
        use crate::softfloat::ApFloat448;
        let _ = ApFloat448::from_ap(&ApFloat::zero(960));
    }
}
