//! `ApFloatN<const LIMBS: usize>` — the compile-time fixed-width softfloat
//! fast path (the paper's operands *are* compile-time fixed precision: the
//! FPGA pipeline is generated for one mantissa width).
//!
//! Same value semantics as [`ApFloat`](super::ApFloat):
//!
//! ```text
//!     value = (-1)^sign * M * 2^(exp - 64 * LIMBS)
//! ```
//!
//! with `M` normalized into `[2^(p-1), 2^p)` for `p = 64 * LIMBS`, zero as
//! `(sign = +, exp = ZERO_EXP, M = 0)`, and RNDZ everywhere — but the
//! mantissa is a `[u64; LIMBS]` array, the value is `Copy`, and no
//! operator touches an arena or the heap.  Every operator mirrors its
//! dynamic counterpart in `softfloat::ops` stage for stage (same swap
//! rule, same `d` clamp, same sticky correction, same truncation), so the
//! two paths are bit-identical at every width — the acceptance criterion
//! `tests/fixed_parity.rs` and the Python port pin with randomized suites.
//!
//! The crate instantiates the paper's hot configs, 448-bit ([`ApFloat448`],
//! 7 limbs) and 960-bit ([`ApFloat960`], 15 limbs); any other multiple of
//! 64 works the same way.  Conversions to/from [`ApFloat`](super::ApFloat)
//! live in `softfloat::convert`.

use crate::bigint::{self, fixed::Guarded};

use super::ZERO_EXP;

/// The paper's 512-bit packed word: 448 mantissa bits in 7 limbs.
pub type ApFloat448 = ApFloatN<7>;
/// The paper's 1024-bit packed word: 960 mantissa bits in 15 limbs.
pub type ApFloat960 = ApFloatN<15>;

/// Stack-allocated fixed-width APFP value.  `Copy`, arena-free, and
/// bit-identical to the dynamic [`ApFloat`](super::ApFloat) pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ApFloatN<const LIMBS: usize> {
    pub(crate) sign: bool,
    pub(crate) exp: i64,
    /// little-endian; normalized (top bit set) unless zero
    pub(crate) mant: [u64; LIMBS],
}

impl<const LIMBS: usize> ApFloatN<LIMBS> {
    /// Canonical zero (sign = +, exp = `ZERO_EXP`, mantissa clear).
    pub const ZERO: Self = ApFloatN { sign: false, exp: ZERO_EXP, mant: [0; LIMBS] };

    /// Mantissa bits of this width.
    pub const PREC: u32 = 64 * LIMBS as u32;

    pub const fn zero() -> Self {
        Self::ZERO
    }

    /// Construct from parts; mantissa must be normalized or all-zero
    /// (mirrors `ApFloat::from_parts`).
    pub fn from_parts(sign: bool, exp: i64, mant: [u64; LIMBS]) -> Self {
        if bigint::is_zero(&mant) {
            return Self::ZERO;
        }
        assert!(
            bigint::bit_length(&mant) == 64 * LIMBS,
            "mantissa must be normalized (MSB set)"
        );
        ApFloatN { sign, exp, mant }
    }

    // ---- accessors --------------------------------------------------------

    pub fn prec(&self) -> u32 {
        Self::PREC
    }

    pub fn limbs(&self) -> &[u64; LIMBS] {
        &self.mant
    }

    pub fn sign(&self) -> bool {
        self.sign
    }

    pub fn exp(&self) -> i64 {
        self.exp
    }

    pub fn is_zero(&self) -> bool {
        self.exp == ZERO_EXP
    }

    pub fn neg(&self) -> Self {
        if self.is_zero() {
            *self
        } else {
            ApFloatN { sign: !self.sign, ..*self }
        }
    }

    /// Magnitude comparison |self| vs |other| (mirrors `ApFloat::cmp_mag`).
    pub fn cmp_mag(&self, other: &Self) -> std::cmp::Ordering {
        use std::cmp::Ordering;
        match (self.is_zero(), other.is_zero()) {
            (true, true) => Ordering::Equal,
            (true, false) => Ordering::Less,
            (false, true) => Ordering::Greater,
            (false, false) => self
                .exp
                .cmp(&other.exp)
                .then_with(|| bigint::cmp(&self.mant, &other.mant)),
        }
    }

    // ---- operators --------------------------------------------------------

    /// `out = self * other` (RNDZ), stage-for-stage mirror of the dynamic
    /// `mul_into`: exact double-width product, truncate the low bits.  The
    /// product's bit length is `2p` or `2p - 1` for normalized operands, so
    /// the renormalizing shift is either "take the high half" or "take the
    /// high half shifted up one" — no general shifter needed.
    // apfp-lint: no_alloc
    pub fn mul_into(&self, other: &Self, out: &mut Self) {
        if self.is_zero() || other.is_zero() {
            *out = Self::ZERO;
            return;
        }
        let (lo, hi) = bigint::fixed::mul_fixed(&self.mant, &other.mant);
        if hi[LIMBS - 1] >> 63 != 0 {
            // nbits == 2p: shr by p is exactly the high half
            out.mant = hi;
            out.exp = self.exp + other.exp;
        } else {
            // nbits == 2p - 1: shr by p - 1 pulls one bit up from lo
            let mut carry = lo[LIMBS - 1] >> 63;
            for i in 0..LIMBS {
                let next = hi[i] >> 63;
                out.mant[i] = (hi[i] << 1) | carry;
                carry = next;
            }
            out.exp = self.exp + other.exp - 1;
        }
        debug_assert!(out.mant[LIMBS - 1] >> 63 == 1, "product renormalizes");
        out.sign = self.sign != other.sign;
    }

    /// `out = self + other` (RNDZ), mirror of the dynamic `add_into`.
    // apfp-lint: no_alloc
    pub fn add_into(&self, other: &Self, out: &mut Self) {
        add_core_fixed(self, other, false, out);
    }

    /// `out = self - other` (RNDZ), mirror of the dynamic `sub_into`.
    // apfp-lint: no_alloc
    pub fn sub_into(&self, other: &Self, out: &mut Self) {
        add_core_fixed(self, other, true, out);
    }

    /// In-place MAC: `*self += a * b` with the product rounded to width
    /// before accumulation — the same fused-pipeline semantics as the
    /// dynamic `mac_into`, with both intermediates on the stack.
    // apfp-lint: no_alloc
    pub fn mac_into(&mut self, a: &Self, b: &Self) {
        let mut prod = Self::ZERO;
        a.mul_into(b, &mut prod);
        let mut sum = Self::ZERO;
        add_core_fixed(self, &prod, false, &mut sum);
        *self = sum;
    }

    // value-returning conveniences (tests, conversions)

    pub fn mul(&self, other: &Self) -> Self {
        let mut out = Self::ZERO;
        self.mul_into(other, &mut out);
        out
    }

    pub fn add(&self, other: &Self) -> Self {
        let mut out = Self::ZERO;
        self.add_into(other, &mut out);
        out
    }

    pub fn sub(&self, other: &Self) -> Self {
        let mut out = Self::ZERO;
        self.sub_into(other, &mut out);
        out
    }

    pub fn mac(&self, a: &Self, b: &Self) -> Self {
        let mut out = *self;
        out.mac_into(a, b);
        out
    }
}

/// The shared fixed-width adder pipeline: `out = x + (-1)^flip_y * y`
/// (RNDZ) — the dynamic `add_core` stage for stage on [`Guarded`]
/// workspaces instead of arena slices: order by magnitude, barrel shift
/// with the `64 * (L + 2)` clamp + sticky, wide add/sub with the RNDZ
/// sticky correction, LZC renormalize, truncate.
// apfp-lint: no_alloc
fn add_core_fixed<const L: usize>(
    x: &ApFloatN<L>,
    y: &ApFloatN<L>,
    flip_y: bool,
    out: &mut ApFloatN<L>,
) {
    let y_sign = y.sign != flip_y;
    if y.is_zero() {
        // covers x == y == 0 too: x's canonical zero is copied through
        *out = *x;
        return;
    }
    if x.is_zero() {
        out.sign = y_sign;
        out.exp = y.exp;
        out.mant = y.mant;
        return;
    }

    // -- stage 1: order by magnitude ------------------------------------
    let swap = x.cmp_mag(y) == std::cmp::Ordering::Less;
    let (big_sign, big_exp) = if swap { (y_sign, y.exp) } else { (x.sign, x.exp) };
    let small_exp = if swap { x.exp } else { y.exp };
    let same_sign = x.sign == y_sign;

    // -- stage 2: alignment ----------------------------------------------
    // Workspace layout [1 guard | L | 1 overflow]; big's MSB at bit
    // 64 + p - 1.  Sticky is read before the in-place shift consumes the
    // pre-shift bits (the dynamic path shifts out of place and reads the
    // preserved original — same result).
    let p = 64 * L;
    let (big_mant, small_mant) = if swap { (&y.mant, &x.mant) } else { (&x.mant, &y.mant) };
    let mut v = Guarded::<L>::place(big_mant);
    let mut small = Guarded::<L>::place(small_mant);
    let d_wide = (big_exp as i128) - (small_exp as i128); // >= 0
    let d = d_wide.min((64 * (L + 2)) as i128) as usize; // beyond this all bits are sticky
    let sticky = small.sticky_below(d);
    small.shr_assign(d);

    // -- stage 3: wide add / subtract -------------------------------------
    if same_sign {
        let carry = v.add_assign(&small);
        debug_assert!(!carry, "overflow limb absorbs the carry");
    } else {
        let borrow = v.sub_assign(&small);
        debug_assert!(!borrow, "|big| >= |small| by stage 1");
        if sticky {
            // RNDZ correction: the truncated small operand under-shoots,
            // so the raw difference over-shoots by <1 ws-ulp.
            let borrow = v.sub_limb(1);
            debug_assert!(!borrow);
        }
    }

    // -- stages 4+5: renormalize + truncate --------------------------------
    let nbits = v.bit_length();
    if nbits == 0 {
        // exact cancellation -> +0
        *out = ApFloatN::ZERO;
    } else {
        if nbits >= p {
            v.shr_into(nbits - p, &mut out.mant);
        } else {
            v.shl_into(p - nbits, &mut out.mant);
        }
        out.sign = big_sign;
        out.exp = big_exp + (nbits as i64 - (64 + p) as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::super::ApFloat;
    use super::*;
    use crate::testkit::{self, rand_ap};

    fn rand_fixed<const L: usize>(rng: &mut testkit::Rng, exp_range: i64) -> ApFloatN<L> {
        ApFloatN::from_ap(&rand_ap(rng, 64 * L as u32, exp_range))
    }

    #[test]
    fn zero_is_canonical_and_copy() {
        let z = ApFloat448::ZERO;
        assert!(z.is_zero());
        assert!(!z.sign());
        assert_eq!(z.exp(), ZERO_EXP);
        assert_eq!(z.neg(), z);
        let w = z; // Copy
        assert_eq!(w, z);
        assert_eq!(ApFloat448::PREC, 448);
        assert_eq!(ApFloat960::PREC, 960);
    }

    #[test]
    fn from_parts_normalization_contract() {
        let mut m = [0u64; 7];
        assert!(ApFloat448::from_parts(true, 3, m).is_zero(), "all-zero -> canonical zero");
        m[6] = 1 << 63;
        let x = ApFloat448::from_parts(true, 3, m);
        assert!(x.sign() && x.exp() == 3);
    }

    #[test]
    #[should_panic(expected = "normalized")]
    fn from_parts_rejects_denormal() {
        let mut m = [0u64; 7];
        m[0] = 1;
        let _ = ApFloat448::from_parts(false, 0, m);
    }

    #[test]
    fn mul_matches_dynamic_property() {
        let mut scratch = crate::bigint::Scratch::new();
        let mut out = ApFloat::zero(448);
        testkit::check(400, |rng| {
            let a = rand_ap(rng, 448, 300);
            let b = rand_ap(rng, 448, 300);
            a.mul_into(&b, &mut out, &mut scratch);
            let got = ApFloat448::from_ap(&a).mul(&ApFloat448::from_ap(&b));
            assert_eq!(got.to_ap(), out);
        });
    }

    #[test]
    fn add_sub_match_dynamic_property() {
        let mut scratch = crate::bigint::Scratch::new();
        let mut out = ApFloat::zero(960);
        testkit::check(400, |rng| {
            // tight exponent range maximizes overlap (carry/cancel cases)
            let a = rand_ap(rng, 960, 12);
            let b = rand_ap(rng, 960, 12);
            a.add_into(&b, &mut out, &mut scratch);
            let (fa, fb) = (ApFloat960::from_ap(&a), ApFloat960::from_ap(&b));
            assert_eq!(fa.add(&fb).to_ap(), out, "add");
            a.sub_into(&b, &mut out, &mut scratch);
            assert_eq!(fa.sub(&fb).to_ap(), out, "sub");
        });
    }

    #[test]
    fn mac_matches_dynamic_including_zero_operands() {
        let mut scratch = crate::bigint::Scratch::new();
        testkit::check(300, |rng| {
            let mut acc = rand_ap(rng, 448, 40);
            let mut facc = ApFloat448::from_ap(&acc);
            for _ in 0..4 {
                let a = if rng.below(8) == 0 { ApFloat::zero(448) } else { rand_ap(rng, 448, 40) };
                let b = if rng.below(8) == 0 { ApFloat::zero(448) } else { rand_ap(rng, 448, 40) };
                acc.mac_into(&a, &b, &mut scratch);
                facc.mac_into(&ApFloat448::from_ap(&a), &ApFloat448::from_ap(&b));
                assert_eq!(facc.to_ap(), acc);
            }
        });
    }

    #[test]
    fn exact_cancellation_gives_plus_zero() {
        let mut rng = testkit::Rng::from_seed(5);
        let a = rand_fixed::<7>(&mut rng, 20);
        let d = a.sub(&a);
        assert!(d.is_zero());
        assert!(!d.sign());
        assert_eq!(d, ApFloat448::ZERO);
    }

    #[test]
    fn sticky_correction_one_ulp_mirror() {
        // the dynamic suite's sticky test, fixed edition: big - tiny must
        // dip below big by exactly one ulp when the tiny operand is all
        // sticky (shifted past the guard limb)
        let one = ApFloat448::from_ap(&ApFloat::from_u64(1, 448));
        let mut tiny = one;
        tiny.exp -= 64 * 9; // far beyond the workspace: pure sticky
        let d = one.sub(&tiny);
        assert!(!d.is_zero());
        // result is just below 1: exponent drops by 1, mantissa all ones
        assert_eq!(d.exp(), one.exp() - 1);
        assert!(d.limbs().iter().all(|&w| w == u64::MAX));
    }

    #[test]
    fn carry_chain_boundary_diffs_match_dynamic() {
        // the dynamic guard_limb_boundary_diffs sweep: exponent gaps that
        // land exactly on limb boundaries of the guard workspace
        let mut scratch = crate::bigint::Scratch::new();
        let mut out = ApFloat::zero(448);
        let mut rng = testkit::Rng::from_seed(77);
        for d in [0i64, 1, 2, 63, 64, 65, 447, 448, 449, 511, 512, 513, 600] {
            for flip in [false, true] {
                let a = rand_ap(&mut rng, 448, 5);
                let mut b = rand_ap(&mut rng, 448, 5);
                b.assign(&ApFloat::from_parts(flip, a.exp() - d, b.limbs().to_vec(), 448));
                a.add_into(&b, &mut out, &mut scratch);
                let got = ApFloat448::from_ap(&a).add(&ApFloat448::from_ap(&b));
                assert_eq!(got.to_ap(), out, "d={d} flip={flip}");
                a.sub_into(&b, &mut out, &mut scratch);
                let got = ApFloat448::from_ap(&a).sub(&ApFloat448::from_ap(&b));
                assert_eq!(got.to_ap(), out, "sub d={d} flip={flip}");
            }
        }
    }
}
