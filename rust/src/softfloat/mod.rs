//! `ApFloat` — arbitrary-precision floating point with MPFR-compatible
//! round-to-zero semantics (the paper's `MPFR_RNDZ` baseline arithmetic).
//!
//! Representation (DESIGN.md §5, identical to the Python/JAX layers):
//!
//! ```text
//!     value = (-1)^sign * M * 2^(exp - prec)
//! ```
//!
//! with `M` a `prec`-bit mantissa normalized into [2^(prec-1), 2^prec)
//! stored as little-endian u64 limbs, `exp` a 63-bit signed exponent, and
//! zero represented as (sign = +, exp = ZERO_EXP, M = 0).  Subnormals,
//! infinities and NaN are out of scope, exactly as in the paper.
//!
//! This library plays two roles in the reproduction:
//!   1. the *CPU baseline* — what the paper benchmarks MPFR for (§V-B/C);
//!   2. the *verification reference* for the accelerator path — results
//!      coming back from the PJRT artifacts are bit-compared against it
//!      (the paper compares its FPGA output against MPFR the same way).

mod convert;
pub mod fixed;
mod ops;

pub use convert::ParseApFloatError;
pub use fixed::{ApFloat448, ApFloat960, ApFloatN};

use crate::bigint;

/// Exponent sentinel for the zero value (matches python/compile/config.py).
pub const ZERO_EXP: i64 = -(1 << 61);

/// Default total widths evaluated in the paper (Fig. 1: multiples of 512
/// bits, 64 of which hold sign+exponent).
pub const BITS_512_PREC: u32 = 448;
pub const BITS_1024_PREC: u32 = 960;
/// The 128-bit short width (arXiv 2306.04087 territory): one limb of
/// mantissa under the same 64-bit sign+exponent head.
pub const BITS_128_PREC: u32 = 64;

/// Precision (mantissa bits) for a total packed width (Fig. 1 layout:
/// 64-bit sign+exponent head, whole little-endian limbs of mantissa).
/// Any width with whole limbs and at least one mantissa limb packs.
pub fn prec_for_bits(total_bits: u32) -> u32 {
    assert!(total_bits % 64 == 0 && total_bits >= 128, "Fig. 1 packing");
    total_bits - 64
}

/// Return a spent value's mantissa buffer to the thread-local arithmetic
/// arena so a subsequent operator ([`ApFloat::mul`], [`ApFloat::add`],
/// [`ApFloat::mac`], …) can reuse it.  This is the steady-state contract
/// that makes the whole operator set allocation-free in hot loops:
///
/// ```ignore
/// let r = a.mul(&b);       // buffer drawn from the recycle pool
/// consume(&r);
/// softfloat::recycle(r);   // buffer returned: no allocator traffic
/// ```
///
/// Loops that instead keep one output alive should prefer the `*_into`
/// operators ([`ApFloat::mul_into`], [`ApFloat::add_into`],
/// [`ApFloat::mac_into`]), which need no pool at all, and loops running an
/// *explicit* arena pair the `*_with` operators with [`recycle_into`] —
/// this function only refills the thread-local arena that the plain
/// operators draw from.
// apfp-lint: no_alloc
pub fn recycle(f: ApFloat) {
    crate::bigint::with_scratch(|s| s.put_limbs(f.mant));
}

/// Like [`recycle`], but returns the buffer to an explicit arena — the
/// partner of [`ApFloat::mul_with`], whose results are drawn from
/// `scratch`'s pool, so the explicit-arena path is also allocation-free
/// in steady state.
// apfp-lint: no_alloc
pub fn recycle_into(f: ApFloat, scratch: &mut crate::bigint::Scratch) {
    scratch.put_limbs(f.mant);
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ApFloat {
    pub(crate) sign: bool,
    pub(crate) exp: i64,
    /// little-endian; len = ceil(prec / 64); normalized (top bit set) unless zero
    pub(crate) mant: Vec<u64>,
    pub(crate) prec: u32,
}

impl ApFloat {
    // ---- constructors -----------------------------------------------------

    pub fn zero(prec: u32) -> Self {
        assert!(prec % 64 == 0 && prec >= 64, "prec must be a multiple of 64");
        ApFloat { sign: false, exp: ZERO_EXP, mant: vec![0; (prec / 64) as usize], prec }
    }

    /// Construct from parts; mantissa must be normalized or all-zero.
    pub fn from_parts(sign: bool, exp: i64, mant: Vec<u64>, prec: u32) -> Self {
        assert_eq!(mant.len(), (prec / 64) as usize);
        if bigint::is_zero(&mant) {
            return ApFloat::zero(prec);
        }
        assert!(
            bigint::bit_length(&mant) == prec as usize,
            "mantissa must be normalized (MSB set)"
        );
        ApFloat { sign, exp, mant, prec }
    }

    /// Exact value `signed * 2^scale_exp`, truncated toward zero to `prec`
    /// bits (RNDZ) — the canonical normalizer shared by all constructors.
    pub fn from_int_scaled(sign: bool, mag: &[u64], scale_exp: i64, prec: u32) -> Self {
        let nbits = bigint::bit_length(mag);
        if nbits == 0 {
            return ApFloat::zero(prec);
        }
        let n = (prec / 64) as usize;
        let mut mant = vec![0u64; n];
        if nbits >= prec as usize {
            bigint::shr(mag, nbits - prec as usize, &mut mant); // truncate = RNDZ
        } else {
            bigint::shl(mag, prec as usize - nbits, &mut mant);
        }
        ApFloat { sign, exp: scale_exp + nbits as i64, mant, prec }
    }

    pub fn from_u64(v: u64, prec: u32) -> Self {
        ApFloat::from_int_scaled(false, &[v], 0, prec)
    }

    pub fn from_i64(v: i64, prec: u32) -> Self {
        ApFloat::from_int_scaled(v < 0, &[v.unsigned_abs()], 0, prec)
    }

    /// Re-express the value at another mantissa precision.  Widening
    /// zero-extends the low limbs (exact); narrowing keeps the top
    /// `new_prec` bits and drops the rest — truncation toward zero, the
    /// same RNDZ rule every operator applies (§II-B).  The exponent (and
    /// therefore the represented magnitude's leading bit) is unchanged,
    /// and zero stays the canonical zero at the new width.
    pub fn to_prec(&self, new_prec: u32) -> Self {
        assert!(new_prec % 64 == 0 && new_prec >= 64, "prec must be a multiple of 64");
        if self.is_zero() {
            return ApFloat::zero(new_prec);
        }
        if new_prec == self.prec {
            return self.clone();
        }
        let old_n = self.mant.len();
        let new_n = (new_prec / 64) as usize;
        let mut mant = vec![0u64; new_n];
        if new_n >= old_n {
            // widen: value bits move to the top limbs, zeros below
            mant[new_n - old_n..].copy_from_slice(&self.mant);
        } else {
            // narrow: keep the most-significant limbs (RNDZ truncate);
            // the top bit stays set, so normalization is preserved
            mant.copy_from_slice(&self.mant[old_n - new_n..]);
        }
        ApFloat { sign: self.sign, exp: self.exp, mant, prec: new_prec }
    }

    // ---- accessors ----------------------------------------------------------

    pub fn prec(&self) -> u32 {
        self.prec
    }

    pub fn limbs(&self) -> &[u64] {
        &self.mant
    }

    pub fn sign(&self) -> bool {
        self.sign
    }

    pub fn exp(&self) -> i64 {
        self.exp
    }

    pub fn is_zero(&self) -> bool {
        self.exp == ZERO_EXP
    }

    /// Copy `src`'s value into `self`, reusing `self`'s mantissa buffer —
    /// the allocation-free counterpart of `*self = src.clone()` whenever
    /// the widths already match (tile packing, accumulator resets).
    // apfp-lint: no_alloc
    pub fn assign(&mut self, src: &ApFloat) {
        self.sign = src.sign;
        self.exp = src.exp;
        self.prec = src.prec;
        if self.mant.len() != src.mant.len() {
            self.mant.clear();
            // apfp-lint: allow(alloc, reason="capacity reuse: clear+resize refills the existing buffer; reallocates only when the width grows")
            self.mant.resize(src.mant.len(), 0);
        }
        self.mant.copy_from_slice(&src.mant);
    }

    pub fn neg(&self) -> Self {
        if self.is_zero() {
            self.clone()
        } else {
            ApFloat { sign: !self.sign, ..self.clone() }
        }
    }

    pub fn abs(&self) -> Self {
        if self.is_zero() {
            self.clone()
        } else {
            ApFloat { sign: false, ..self.clone() }
        }
    }

    /// Magnitude comparison |self| vs |other|.
    pub fn cmp_mag(&self, other: &Self) -> std::cmp::Ordering {
        use std::cmp::Ordering;
        match (self.is_zero(), other.is_zero()) {
            (true, true) => Ordering::Equal,
            (true, false) => Ordering::Less,
            (false, true) => Ordering::Greater,
            (false, false) => self
                .exp
                .cmp(&other.exp)
                .then_with(|| bigint::cmp(&self.mant, &other.mant)),
        }
    }

    /// Signed total order.
    pub fn cmp_total(&self, other: &Self) -> std::cmp::Ordering {
        use std::cmp::Ordering;
        match (self.is_zero(), other.is_zero()) {
            (true, true) => Ordering::Equal,
            (true, false) => {
                if other.sign { Ordering::Greater } else { Ordering::Less }
            }
            (false, true) => {
                if self.sign { Ordering::Less } else { Ordering::Greater }
            }
            (false, false) => match (self.sign, other.sign) {
                (false, true) => Ordering::Greater,
                (true, false) => Ordering::Less,
                (false, false) => self.cmp_mag(other),
                (true, true) => other.cmp_mag(self),
            },
        }
    }
}

impl PartialOrd for ApFloat {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp_total(other))
    }
}

impl std::fmt::Display for ApFloat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: u32 = 448;

    #[test]
    fn zero_is_canonical() {
        let z = ApFloat::zero(P);
        assert!(z.is_zero());
        assert!(!z.sign());
        assert_eq!(z.exp(), ZERO_EXP);
        assert_eq!(z.neg(), z); // -0 stays +0 in this representation
    }

    #[test]
    fn from_u64_normalizes() {
        let x = ApFloat::from_u64(1, P);
        assert_eq!(x.exp(), 1); // 1 = 0.5 * 2^1
        assert_eq!(bigint::bit_length(x.limbs()), P as usize);
        let y = ApFloat::from_u64(6, P);
        assert_eq!(y.exp(), 3); // 6 = 0.75 * 2^3
    }

    #[test]
    fn from_i64_sign() {
        assert!(ApFloat::from_i64(-5, P).sign());
        assert!(!ApFloat::from_i64(5, P).sign());
        assert!(ApFloat::from_i64(0, P).is_zero());
        assert_eq!(ApFloat::from_i64(i64::MIN, P).to_f64(), i64::MIN as f64);
    }

    #[test]
    fn from_int_scaled_truncates_rndz() {
        // 2^448 + 1 doesn't fit 448 bits; RNDZ drops the low 1
        let mut mag = vec![0u64; 8];
        mag[0] = 1;
        mag[7] = 1 << 0; // bit 448
        let x = ApFloat::from_int_scaled(false, &mag, 0, P);
        assert_eq!(x.exp(), 449);
        // mantissa = 2^447 exactly (the +1 truncated away)
        assert_eq!(bigint::bit_length(x.limbs()), 448);
        let mut expect = vec![0u64; 7];
        expect[6] = 1 << 63;
        assert_eq!(x.limbs(), &expect[..]);
    }

    #[test]
    fn from_int_scaled_truncation_at_exact_limb_boundaries() {
        // Satellite regression: when nbits - prec is an exact multiple of
        // 64, the truncating shift takes the r == 0 limb-copy path of
        // bigint::shr.  Pin the result against hand-built references.
        for extra_limbs in [1usize, 2, 4] {
            let n = 7 + extra_limbs; // nbits = 64 * n, shift = 64 * extra
            let mut mag = vec![u64::MAX; n];
            mag[0] = 123; // entirely inside the truncated-away low limbs
            let x = ApFloat::from_int_scaled(false, &mag, -9, P);
            assert_eq!(x.exp(), (64 * n) as i64 - 9, "extra={extra_limbs}");
            // top 448 bits of mag are all ones
            assert!(x.limbs().iter().all(|&w| w == u64::MAX), "extra={extra_limbs}");
        }
        // one bit past a limb boundary: shift = 65 mixes both limbs
        let mut mag = vec![0u64; 9]; // 513 significant bits
        mag[8] = 1; // bit 512
        mag[0] = u64::MAX; // low bits, all truncated
        let x = ApFloat::from_int_scaled(true, &mag, 0, P);
        assert_eq!(x.exp(), 513);
        assert!(x.sign());
        // mantissa = 2^447 exactly (the low ones vanish under RNDZ)
        let mut expect = vec![0u64; 7];
        expect[6] = 1 << 63;
        assert_eq!(x.limbs(), &expect[..]);
        // trailing zero limbs above the MSB must not confuse bit_length
        let mut mag = vec![0u64; 12];
        mag[6] = 1 << 63; // exactly prec bits: shift = 0
        mag[0] = 1;
        let x = ApFloat::from_int_scaled(false, &mag, 4, P);
        assert_eq!(x.exp(), 448 + 4);
        assert_eq!(x.limbs()[0], 1);
        assert_eq!(x.limbs()[6], 1 << 63);
    }

    #[test]
    fn assign_reuses_buffer_and_handles_width_changes() {
        let src = ApFloat::from_i64(-42, P);
        let mut dst = ApFloat::from_u64(7, P);
        let buf_ptr = dst.limbs().as_ptr();
        dst.assign(&src);
        assert_eq!(dst, src);
        assert_eq!(dst.limbs().as_ptr(), buf_ptr, "same-width assign must not reallocate");
        // width change reallocates once, then value matches
        let wide = ApFloat::from_i64(9, 960);
        dst.assign(&wide);
        assert_eq!(dst, wide);
        // zero propagates canonically
        dst.assign(&ApFloat::zero(P));
        assert!(dst.is_zero());
        assert_eq!(dst, ApFloat::zero(P));
    }

    #[test]
    fn cmp_total_orders_signs_and_magnitudes() {
        use std::cmp::Ordering::*;
        let a = ApFloat::from_i64(3, P);
        let b = ApFloat::from_i64(-7, P);
        let z = ApFloat::zero(P);
        assert_eq!(a.cmp_total(&b), Greater);
        assert_eq!(b.cmp_total(&a), Less);
        assert_eq!(z.cmp_total(&a), Less);
        assert_eq!(z.cmp_total(&b), Greater);
        assert_eq!(b.cmp_total(&ApFloat::from_i64(-2, P)), Less);
    }

    #[test]
    fn prec_for_bits_fig1() {
        assert_eq!(prec_for_bits(512), 448);
        assert_eq!(prec_for_bits(1024), 960);
        assert_eq!(prec_for_bits(1536), 1472);
        assert_eq!(prec_for_bits(128), 64);
    }

    #[test]
    fn to_prec_round_trips_and_truncates_rndz() {
        // widen is exact: the round trip through a larger width is identity
        let x = ApFloat::from_f64(std::f64::consts::PI, 448);
        let wide = x.to_prec(960);
        assert_eq!(wide.prec(), 960);
        assert_eq!(wide.exp(), x.exp());
        assert_eq!(wide.to_prec(448), x);
        // narrow keeps the top bits: equal to rebuilding from the kept limbs
        let narrowed = wide.to_prec(64);
        assert_eq!(narrowed.exp(), x.exp());
        assert_eq!(narrowed.limbs(), &x.limbs()[x.limbs().len() - 1..]);
        // narrowing is the same RNDZ truncation from_int_scaled applies
        let direct = ApFloat::from_f64(std::f64::consts::PI, 64);
        assert_eq!(narrowed, direct);
        // zero stays canonical at every width
        assert!(ApFloat::zero(448).to_prec(64).is_zero());
        assert_eq!(ApFloat::zero(64).to_prec(960), ApFloat::zero(960));
        // same width is a plain clone
        assert_eq!(x.to_prec(448), x);
    }
}
