//! Arithmetic on `ApFloat`: the software editions of the paper's §II
//! operators, bit-compatible with the JAX model and the Python oracle.

use super::{ApFloat, ZERO_EXP};
use crate::bigint::{self, Scratch};

/// Widths up to `STACK_LIMBS * 64` bits (2048) use stack scratch in the
/// adder pipeline (§Perf P1 in EXPERIMENTS.md); wider operands draw the
/// alignment workspace from the [`Scratch`] arena, the same pool that
/// backs `mul` — so every operator is allocation-free in steady state.
const STACK_LIMBS: usize = 32;

impl ApFloat {
    /// RNDZ multiplication (§II-A).  The mantissa product is exact, so
    /// truncating its low bits *is* round-to-zero.
    ///
    /// Runs on the thread-local [`Scratch`] arena: the product workspace
    /// and any Karatsuba scratch are reused across calls, and the result
    /// mantissa is drawn from the arena's recycle pool.  A hot loop that
    /// returns spent values via [`super::recycle`] (or that reuses an
    /// output with [`ApFloat::mul_into`]) therefore performs zero heap
    /// allocations in steady state.
    pub fn mul(&self, other: &Self) -> Self {
        bigint::with_scratch(|s| self.mul_with(other, s))
    }

    /// [`ApFloat::mul`] against an explicit scratch arena (the result
    /// buffer is drawn from the arena's recycle pool).
    // apfp-lint: no_alloc
    pub fn mul_with(&self, other: &Self, scratch: &mut Scratch) -> Self {
        assert_eq!(self.prec, other.prec);
        let mant = scratch.take_limbs(self.mant.len());
        let mut out = ApFloat { sign: false, exp: ZERO_EXP, mant, prec: self.prec };
        self.mul_into(other, &mut out, scratch);
        out
    }

    /// Write `self * other` (RNDZ) into `out`, reusing `out`'s mantissa
    /// buffer and the scratch arena: zero heap allocations once both are
    /// warm.  `out` may have any prior value/precision; it is overwritten.
    // apfp-lint: no_alloc
    pub fn mul_into(&self, other: &Self, out: &mut ApFloat, scratch: &mut Scratch) {
        assert_eq!(self.prec, other.prec);
        let n = self.mant.len();
        out.prec = self.prec;
        if out.mant.len() != n {
            out.mant.clear();
            // apfp-lint: allow(alloc, reason="capacity reuse: clear+resize refills the existing buffer; reallocates only when the width grows")
            out.mant.resize(n, 0);
        }
        if self.is_zero() || other.is_zero() {
            out.sign = false;
            out.exp = ZERO_EXP;
            out.mant.fill(0);
            return;
        }
        let p = self.prec as usize;
        let mut prod = scratch.take_prod(2 * n);
        bigint::mul_auto_with(&self.mant, &other.mant, &mut prod, scratch);
        let nbits = bigint::bit_length(&prod); // 2p or 2p-1
        debug_assert!(nbits == 2 * p || nbits == 2 * p - 1);
        bigint::shr(&prod, nbits - p, &mut out.mant); // truncate = RNDZ
        scratch.put_prod(prod);
        out.sign = self.sign != other.sign;
        out.exp = self.exp + other.exp + (nbits as i64 - 2 * p as i64);
    }

    /// RNDZ addition/subtraction (§II-B), bit-exact vs exact-integer
    /// arithmetic via the guard-limb workspace + sticky correction
    /// (DESIGN.md §5).  Stages mirror the hardware adder pipeline:
    /// swap, barrel shift + sticky, wide add/sub, LZC renormalize, truncate.
    ///
    /// Runs on the thread-local [`Scratch`] arena: the alignment workspace
    /// comes from the stack (paper widths) or the arena, and the result
    /// mantissa is drawn from the arena's recycle pool — a hot loop that
    /// returns spent values via [`super::recycle`] (or reuses an output
    /// with [`ApFloat::add_into`]) performs zero heap allocations.
    pub fn add(&self, other: &Self) -> Self {
        bigint::with_scratch(|s| self.add_with(other, s))
    }

    /// [`ApFloat::add`] against an explicit scratch arena (the result
    /// buffer is drawn from the arena's recycle pool).
    // apfp-lint: no_alloc
    pub fn add_with(&self, other: &Self, scratch: &mut Scratch) -> Self {
        assert_eq!(self.prec, other.prec);
        let mant = scratch.take_limbs(self.mant.len());
        let mut out = ApFloat { sign: false, exp: ZERO_EXP, mant, prec: self.prec };
        self.add_into(other, &mut out, scratch);
        out
    }

    /// Write `self + other` (RNDZ) into `out`, reusing `out`'s mantissa
    /// buffer and the scratch arena: zero heap allocations once both are
    /// warm.  `out` may have any prior value/precision; it is overwritten.
    // apfp-lint: no_alloc
    pub fn add_into(&self, other: &Self, out: &mut ApFloat, scratch: &mut Scratch) {
        add_core(self, other, false, out, scratch);
    }

    pub fn sub(&self, other: &Self) -> Self {
        bigint::with_scratch(|s| self.sub_with(other, s))
    }

    /// [`ApFloat::sub`] against an explicit scratch arena.
    // apfp-lint: no_alloc
    pub fn sub_with(&self, other: &Self, scratch: &mut Scratch) -> Self {
        assert_eq!(self.prec, other.prec);
        let mant = scratch.take_limbs(self.mant.len());
        let mut out = ApFloat { sign: false, exp: ZERO_EXP, mant, prec: self.prec };
        self.sub_into(other, &mut out, scratch);
        out
    }

    /// Write `self - other` (RNDZ) into `out` — [`ApFloat::add_into`] with
    /// the subtrahend's sign flipped in the pipeline (no operand clone).
    // apfp-lint: no_alloc
    pub fn sub_into(&self, other: &Self, out: &mut ApFloat, scratch: &mut Scratch) {
        add_core(self, other, true, out, scratch);
    }

    /// RNDZ division — the "dependent operation" the paper notes inherits
    /// multiplication's cost (§I).  q = floor(Ma * 2^(p+1) / Mb) keeps one
    /// guard + one headroom bit; truncating q to p bits equals truncating
    /// the exact quotient (floor composed with a coarser floor).
    pub fn div(&self, other: &Self) -> Self {
        bigint::with_scratch(|s| self.div_with(other, s))
    }

    /// [`ApFloat::div`] against an explicit arena: the widened numerator,
    /// the division workspaces and the quotient/remainder all come from the
    /// recycle pool, and the guard-bit shift happens in place — no
    /// numerator clone on the divider path.
    pub fn div_with(&self, other: &Self, scratch: &mut Scratch) -> Self {
        assert_eq!(self.prec, other.prec);
        assert!(!other.is_zero(), "APFP division by zero");
        if self.is_zero() {
            return self.clone();
        }
        let n = self.mant.len();
        let p = self.prec as i64;
        // numerator = mant << (p + 1): the mantissa placed n limbs up (p
        // bits, since prec % 64 == 0), then one guard-bit shift in place
        let mut num = scratch.take_limbs(2 * n + 1);
        num[n..2 * n].copy_from_slice(&self.mant);
        let carry = bigint::shl1_in_place(&mut num);
        debug_assert_eq!(carry, 0, "top limb is headroom");
        let (q, r) = bigint::div_rem_with(&num, &other.mant, scratch);
        let out = ApFloat::from_int_scaled(
            self.sign != other.sign,
            &q,
            self.exp - other.exp - (p + 1),
            self.prec,
        );
        scratch.put_limbs(num);
        scratch.put_limbs(q);
        scratch.put_limbs(r);
        out
    }

    /// Fused pipeline semantics: `self + a*b` with the product rounded to
    /// `prec` before accumulation (the multiplier normalizes its output
    /// before feeding the adder, as in the paper's combined pipeline).
    /// The intermediate product lives entirely in the thread-local arena.
    pub fn mac(&self, a: &Self, b: &Self) -> Self {
        bigint::with_scratch(|s| {
            let prod = a.mul_with(b, s);
            let out = self.add_with(&prod, s);
            s.put_limbs(prod.mant);
            out
        })
    }

    /// In-place MAC: `*self += a * b` (product rounded to `prec` before
    /// accumulation, bit-identical to [`ApFloat::mac`]).  This is the GEMM
    /// inner-loop primitive: the product and the sum cycle through the
    /// arena's recycle pool, so a steady-state accumulation chain performs
    /// zero heap allocations (proven by `tests/alloc_free.rs`).
    // apfp-lint: no_alloc
    pub fn mac_into(&mut self, a: &Self, b: &Self, scratch: &mut Scratch) {
        assert_eq!(self.prec, a.prec);
        let n = self.mant.len();
        let mant = scratch.take_limbs(n);
        let mut prod = ApFloat { sign: false, exp: ZERO_EXP, mant, prec: self.prec };
        a.mul_into(b, &mut prod, scratch);
        let mant = scratch.take_limbs(n);
        let mut sum = ApFloat { sign: false, exp: ZERO_EXP, mant, prec: self.prec };
        add_core(self, &prod, false, &mut sum, scratch);
        std::mem::swap(self, &mut sum);
        scratch.put_limbs(prod.mant);
        scratch.put_limbs(sum.mant); // the accumulator's previous buffer
    }
}

/// The shared §II-B adder pipeline: `out = x + (-1)^flip_y * y` (RNDZ),
/// reusing `out`'s mantissa buffer.  Alignment workspaces live on
/// the stack up to `STACK_LIMBS`-limb mantissas and in the arena beyond,
/// so the path allocates nothing once `out` and `scratch` are warm.
fn add_core(x: &ApFloat, y: &ApFloat, flip_y: bool, out: &mut ApFloat, scratch: &mut Scratch) {
    assert_eq!(x.prec, y.prec);
    let n = x.mant.len();
    out.prec = x.prec;
    if out.mant.len() != n {
        out.mant.clear();
        // apfp-lint: allow(alloc, reason="capacity reuse: clear+resize refills the existing buffer; reallocates only when the width grows")
        out.mant.resize(n, 0);
    }
    let y_sign = y.sign != flip_y;
    if y.is_zero() {
        // covers x == y == 0 too: x's canonical zero is copied through
        out.sign = x.sign;
        out.exp = x.exp;
        out.mant.copy_from_slice(&x.mant);
        return;
    }
    if x.is_zero() {
        out.sign = y_sign;
        out.exp = y.exp;
        out.mant.copy_from_slice(&y.mant);
        return;
    }

    // -- stage 1: order by magnitude ------------------------------------
    let swap = x.cmp_mag(y) == std::cmp::Ordering::Less;
    let (big_sign, big_exp) = if swap { (y_sign, y.exp) } else { (x.sign, x.exp) };
    let small_exp = if swap { x.exp } else { y.exp };
    let same_sign = x.sign == y_sign;

    // -- stage 2: alignment ----------------------------------------------
    // Workspace: [1 guard limb | n mantissa limbs | 1 overflow limb];
    // `big`'s MSB sits at bit 64 + p - 1.
    let p = x.prec as usize;
    let ws = n + 2;
    // all three workspaces on the stack for the paper's widths (P1);
    // wider mantissas borrow the arena's adder workspace (zeroed on take)
    let mut stack = [0u64; 3 * (STACK_LIMBS + 2)];
    let mut pooled: Option<Vec<u64>> = None;
    let bufs: &mut [u64] = if ws <= STACK_LIMBS + 2 {
        &mut stack[..3 * ws]
    } else {
        pooled.insert(scratch.take_addws(3 * ws))
    };
    let (ws_big, rest) = bufs.split_at_mut(ws);
    let (placed_small, ws_small) = rest.split_at_mut(ws);
    {
        let (big_mant, small_mant) =
            if swap { (&y.mant, &x.mant) } else { (&x.mant, &y.mant) };
        ws_big[1..1 + n].copy_from_slice(big_mant);
        placed_small[1..1 + n].copy_from_slice(small_mant);
    }

    let d_wide = (big_exp as i128) - (small_exp as i128); // >= 0
    let d = d_wide.min((64 * ws) as i128) as usize; // beyond this all bits are sticky
    bigint::shr(placed_small, d, ws_small);
    let sticky = bigint::sticky_below(placed_small, d);

    // -- stage 3: wide add / subtract -------------------------------------
    let v = ws_big;
    if same_sign {
        let carry = bigint::add_assign(v, ws_small);
        debug_assert!(!carry, "overflow limb absorbs the carry");
    } else {
        let borrow = bigint::sub_assign(v, ws_small);
        debug_assert!(!borrow, "|big| >= |small| by stage 1");
        if sticky {
            // RNDZ correction: the truncated small operand under-shoots,
            // so the raw difference over-shoots by <1 ws-ulp.
            let borrow = bigint::sub_limb(v, 1);
            debug_assert!(!borrow);
        }
    }

    // -- stages 4+5: renormalize + truncate --------------------------------
    let nbits = bigint::bit_length(v);
    if nbits == 0 {
        // exact cancellation -> +0
        out.sign = false;
        out.exp = ZERO_EXP;
        out.mant.fill(0);
    } else {
        if nbits >= p {
            bigint::shr(v, nbits - p, &mut out.mant);
        } else {
            bigint::shl(v, p - nbits, &mut out.mant);
        }
        out.sign = big_sign;
        out.exp = big_exp + (nbits as i64 - (64 + p) as i64);
    }
    if let Some(buf) = pooled {
        scratch.put_addws(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{self, rand_ap};

    const P: u32 = 448;

    #[test]
    fn mul_small_integers() {
        let a = ApFloat::from_i64(6, P);
        let b = ApFloat::from_i64(-7, P);
        assert_eq!(a.mul(&b), ApFloat::from_i64(-42, P));
        assert_eq!(b.mul(&b), ApFloat::from_i64(49, P));
    }

    #[test]
    fn mul_into_matches_mul_property() {
        // the arena/in-place path must be bit-identical to plain mul,
        // including reuse of a stale output across widths and zeros
        use crate::bigint::Scratch;
        let mut scratch = Scratch::new();
        let mut out = ApFloat::zero(960); // wrong precision on purpose
        testkit::check(200, |rng| {
            let prec = *rng.choice(&[448u32, 960]);
            let a = rand_ap(rng, prec, 300);
            let b = rand_ap(rng, prec, 300);
            let want = a.mul(&b);
            a.mul_into(&b, &mut out, &mut scratch);
            assert_eq!(out, want, "mul_into vs mul at prec {prec}");
            let got = a.mul_with(&b, &mut scratch);
            assert_eq!(got, want, "mul_with vs mul at prec {prec}");
            crate::softfloat::recycle_into(got, &mut scratch);
        });
        // zero operands through the in-place path
        let z = ApFloat::zero(P);
        let x = ApFloat::from_i64(3, P);
        x.mul_into(&z, &mut out, &mut scratch);
        assert!(out.is_zero());
        assert_eq!(out, ApFloat::zero(P));
    }

    #[test]
    fn add_into_and_sub_into_match_add_sub_property() {
        // the arena/in-place adder must be bit-identical to the plain ops,
        // including reuse of a stale output across precisions and zeros
        let mut scratch = Scratch::new();
        let mut out = ApFloat::zero(960); // wrong precision on purpose
        testkit::check(300, |rng| {
            let prec = *rng.choice(&[448u32, 960]);
            let a = rand_ap(rng, prec, 300);
            let b = rand_ap(rng, prec, 300);
            let want = a.add(&b);
            a.add_into(&b, &mut out, &mut scratch);
            assert_eq!(out, want, "add_into vs add at prec {prec}");
            let got = a.add_with(&b, &mut scratch);
            assert_eq!(got, want, "add_with vs add at prec {prec}");
            crate::softfloat::recycle_into(got, &mut scratch);
            let want = a.sub(&b);
            a.sub_into(&b, &mut out, &mut scratch);
            assert_eq!(out, want, "sub_into vs sub at prec {prec}");
            let got = a.sub_with(&b, &mut scratch);
            assert_eq!(got, want, "sub_with vs sub at prec {prec}");
            crate::softfloat::recycle_into(got, &mut scratch);
        });
        // zero operands through the in-place path, both sides and both ops
        let z = ApFloat::zero(P);
        let x = ApFloat::from_i64(3, P);
        z.add_into(&x, &mut out, &mut scratch);
        assert_eq!(out, x);
        x.add_into(&z, &mut out, &mut scratch);
        assert_eq!(out, x);
        z.sub_into(&x, &mut out, &mut scratch);
        assert_eq!(out, x.neg());
        x.sub_into(&z, &mut out, &mut scratch);
        assert_eq!(out, x);
        z.add_into(&z, &mut out, &mut scratch);
        assert_eq!(out, z);
        z.sub_into(&z, &mut out, &mut scratch);
        assert_eq!(out, z, "0 - 0 must stay canonical +0");
    }

    #[test]
    fn add_nearly_cancelling_through_in_place_path() {
        // exact cancellation and the sticky-correction branch via add_into
        let mut scratch = Scratch::new();
        let mut out = ApFloat::from_i64(7, P); // stale nonzero output
        let a = ApFloat::from_i64(5, P);
        a.sub_into(&a, &mut out, &mut scratch);
        assert!(out.is_zero());
        assert_eq!(out, ApFloat::zero(P));
        let one = ApFloat::from_i64(1, P);
        let mut tiny_m = vec![0u64; 7];
        tiny_m[6] = 1 << 63;
        let tiny = ApFloat::from_parts(true, -999, tiny_m, P); // -(2^-1000)
        one.add_into(&tiny, &mut out, &mut scratch);
        assert_eq!(out.exp(), 0);
        assert!(out.limbs().iter().all(|&w| w == u64::MAX), "sticky path");
    }

    #[test]
    fn add_beyond_stack_limbs_uses_arena_workspace() {
        // 4096-bit mantissas exceed STACK_LIMBS: the pooled-workspace branch
        // must be bit-identical to integer reference arithmetic
        let prec = 4096;
        let mut scratch = Scratch::new();
        let mut out = ApFloat::zero(prec);
        testkit::check(40, |rng| {
            let x = rng.range_i64(-(1 << 40), 1 << 40);
            let y = rng.range_i64(-(1 << 40), 1 << 40);
            let a = ApFloat::from_i64(x, prec);
            let b = ApFloat::from_i64(y, prec);
            a.add_into(&b, &mut out, &mut scratch);
            assert_eq!(out, ApFloat::from_i64(x + y, prec), "{x} + {y}");
            assert_eq!(a.add(&b), out);
        });
        // and the sticky/cancellation branch at the wide width
        let a = rand_ap(&mut testkit::Rng::from_seed(9), prec, 100);
        a.sub_into(&a, &mut out, &mut scratch);
        assert!(out.is_zero());
    }

    #[test]
    fn mac_into_matches_mac_property() {
        let mut scratch = Scratch::new();
        testkit::check(200, |rng| {
            let prec = *rng.choice(&[448u32, 960]);
            let mut acc = rand_ap(rng, prec, 120);
            let a = rand_ap(rng, prec, 120);
            let b = rand_ap(rng, prec, 120);
            let want = acc.mac(&a, &b);
            acc.mac_into(&a, &b, &mut scratch);
            assert_eq!(acc, want, "mac_into vs mac at prec {prec}");
        });
        // accumulation chains stay bit-identical step by step
        let mut rng = testkit::Rng::from_seed(0xC41);
        let mut acc_into = ApFloat::zero(P);
        let mut acc_ref = ApFloat::zero(P);
        for _ in 0..50 {
            let a = rand_ap(&mut rng, P, 30);
            let b = rand_ap(&mut rng, P, 30);
            acc_ref = acc_ref.mac(&a, &b);
            acc_into.mac_into(&a, &b, &mut scratch);
            assert_eq!(acc_into, acc_ref);
        }
        // zero product leaves the accumulator unchanged
        let z = ApFloat::zero(P);
        let x = ApFloat::from_i64(3, P);
        let before = acc_into.clone();
        acc_into.mac_into(&x, &z, &mut scratch);
        assert_eq!(acc_into, before);
        // zero accumulator picks up the rounded product
        let mut acc = ApFloat::zero(P);
        acc.mac_into(&x, &x, &mut scratch);
        assert_eq!(acc, ApFloat::from_i64(9, P));
    }

    #[test]
    fn add_small_integers() {
        let a = ApFloat::from_i64(100, P);
        let b = ApFloat::from_i64(-58, P);
        assert_eq!(a.add(&b), ApFloat::from_i64(42, P));
        assert_eq!(b.add(&a), ApFloat::from_i64(42, P));
        assert_eq!(a.sub(&a), ApFloat::zero(P));
    }

    #[test]
    fn add_is_exact_on_integers_property() {
        testkit::check(300, |rng| {
            let x = rng.range_i64(-(1 << 40), 1 << 40);
            let y = rng.range_i64(-(1 << 40), 1 << 40);
            let got = ApFloat::from_i64(x, P).add(&ApFloat::from_i64(y, P));
            assert_eq!(got, ApFloat::from_i64(x + y, P), "{x} + {y}");
        });
    }

    #[test]
    fn mul_is_exact_on_integers_property() {
        testkit::check(300, |rng| {
            let x = rng.range_i64(-(1 << 30), 1 << 30);
            let y = rng.range_i64(-(1 << 30), 1 << 30);
            let got = ApFloat::from_i64(x, P).mul(&ApFloat::from_i64(y, P));
            assert_eq!(got, ApFloat::from_i64(x * y, P), "{x} * {y}");
        });
    }

    #[test]
    fn commutativity_property() {
        testkit::check(100, |rng| {
            let a = rand_ap(rng, P, 500);
            let b = rand_ap(rng, P, 500);
            assert_eq!(a.mul(&b), b.mul(&a));
            assert_eq!(a.add(&b), b.add(&a));
        });
    }

    #[test]
    fn identities_property() {
        let one = ApFloat::from_i64(1, P);
        let zero = ApFloat::zero(P);
        testkit::check(100, |rng| {
            let a = rand_ap(rng, P, 500);
            assert_eq!(a.mul(&one), a);
            assert_eq!(a.add(&zero), a);
            assert!(a.mul(&zero).is_zero());
            assert!(a.sub(&a).is_zero());
            assert_eq!(a.neg().neg(), a);
        });
    }

    #[test]
    fn rndz_never_increases_magnitude() {
        // |fl(a*b)| <= |a*b| exactly: check via exponent/mantissa when the
        // product is exactly representable vs truncated.
        testkit::check(100, |rng| {
            let a = rand_ap(rng, P, 100);
            let b = rand_ap(rng, P, 100);
            let ab = a.mul(&b);
            // multiply back the other way and compare magnitudes loosely
            let fa = a.to_f64().abs();
            let fb = b.to_f64().abs();
            let fab = ab.to_f64().abs();
            let rel = (fab - fa * fb).abs() / (fa * fb);
            assert!(rel < 1e-12, "rel={rel}");
        });
    }

    #[test]
    fn catastrophic_cancellation_keeps_low_bits() {
        // (2^200 + 1) - 2^200 must give exactly 1 (guard limb at work
        // it is not: d=0 subtraction is exact by construction)
        let mut big_plus = vec![0u64; 7];
        big_plus[0] = 1;
        big_plus[6] = 1 << 63; // 2^447 + 1 as mantissa, exp = 448
        let x = ApFloat::from_parts(false, 448, big_plus, P); // 2^447+1 scaled
        let mut big = vec![0u64; 7];
        big[6] = 1 << 63;
        let y = ApFloat::from_parts(true, 448, big, P); // -(2^447)
        let diff = x.add(&y);
        assert_eq!(diff, ApFloat::from_i64(1, P));
    }

    #[test]
    fn sticky_correction_one_ulp() {
        // 1 - 2^-1000: exact result is 0.111...1 (1000 ones); RNDZ at 448
        // bits = 0.111...1 (448 ones) * 2^0 — requires the sticky path.
        let one = ApFloat::from_i64(1, P);
        let mut tiny_m = vec![0u64; 7];
        tiny_m[6] = 1 << 63;
        let tiny = ApFloat::from_parts(true, -999, tiny_m, P); // -(2^-1000)
        let got = one.add(&tiny);
        assert_eq!(got.exp(), 0);
        assert!(got.limbs().iter().all(|&w| w == u64::MAX), "all-ones mantissa");
    }

    #[test]
    fn guard_limb_boundary_diffs() {
        // exponent differences straddling the guard-limb capacity (64 bits)
        // and the workspace edge: compare against exact integer arithmetic
        // done in 4096-bit software (via from_int_scaled on wide buffers).
        for d in [1usize, 2, 63, 64, 65, 447, 448, 449, 511, 512, 513, 600] {
            let one = ApFloat::from_i64(1, P); // exp = 1
            let mut m = vec![0u64; 7];
            m[6] = 1 << 63;
            m[0] = 1; // mantissa 2^447 + 1 => value has bits at both ends
            let small = ApFloat::from_parts(true, 1 - d as i64, m, P);
            let got = one.add(&small);
            // exact: 1 - (2^447+1)*2^(1-d-448) = 1 - 2^-d - 2^(-447-d)
            // compute reference with wide integers: scale 2^(448+d+64)
            let scale = 448 + d + 64;
            let mut acc = vec![0u64; (scale + 64).div_ceil(64)];
            let limb = scale / 64;
            acc[limb] |= 1 << (scale % 64); // 1
            let mut sub = vec![0u64; acc.len()];
            sub[(scale - d) / 64] |= 1 << ((scale - d) % 64); // 2^-d
            let borrow = bigint::sub_assign(&mut acc, &sub);
            assert!(!borrow);
            sub.fill(0);
            sub[(scale - d - 447) / 64] |= 1 << ((scale - d - 447) % 64);
            let borrow = bigint::sub_assign(&mut acc, &sub);
            assert!(!borrow);
            let want = ApFloat::from_int_scaled(false, &acc, -(scale as i64), P);
            assert_eq!(got, want, "d={d}");
        }
    }

    #[test]
    fn div_small_integers() {
        let a = ApFloat::from_i64(42, P);
        let b = ApFloat::from_i64(-7, P);
        assert_eq!(a.div(&b), ApFloat::from_i64(-6, P));
        assert_eq!(b.div(&b), ApFloat::from_i64(1, P));
        assert!(ApFloat::zero(P).div(&a).is_zero());
    }

    #[test]
    fn div_mul_roundtrip_property() {
        // (a / b) * b agrees with a to within 2 ulps (two RNDZ roundings)
        testkit::check(150, |rng| {
            let a = rand_ap(rng, P, 200);
            let b = rand_ap(rng, P, 200);
            let back = a.div(&b).mul(&b);
            let diff = back.sub(&a);
            assert!(
                diff.is_zero() || diff.exp() <= a.exp() - (P as i64) + 2,
                "residual exp {} vs a exp {}",
                diff.exp(),
                a.exp()
            );
        });
    }

    #[test]
    fn div_truncates_toward_zero() {
        // 1 / 3 in RNDZ: 3 * (1/3) must be strictly <= 1
        let one = ApFloat::from_i64(1, P);
        let three = ApFloat::from_i64(3, P);
        let third = one.div(&three);
        assert!(third.mul(&three).cmp_total(&one) == std::cmp::Ordering::Less);
        // and the negative mirror truncates toward zero too (magnitude down)
        let neg_third = one.neg().div(&three);
        assert!(neg_third.neg() == third);
    }

    /// The pre-arena divider, verbatim: clone-based numerator widening and
    /// the allocating `div_rem`.  Kept as the bit-exactness oracle for the
    /// in-place guard-shift + pooled-workspace path that replaced it.
    fn div_reference(a: &ApFloat, b: &ApFloat) -> ApFloat {
        assert!(!b.is_zero());
        if a.is_zero() {
            return a.clone();
        }
        let n = a.mant.len();
        let p = a.prec as i64;
        let mut num = vec![0u64; 2 * n + 1];
        num[n..2 * n].copy_from_slice(&a.mant);
        let src = num.clone();
        bigint::shl(&src, 1, &mut num);
        let (q, _r) = bigint::div_rem(&num, &b.mant);
        ApFloat::from_int_scaled(a.sign != b.sign, &q, a.exp - b.exp - (p + 1), a.prec)
    }

    #[test]
    fn div_matches_pre_arena_path_bitwise() {
        let mut scratch = Scratch::new();
        testkit::check(200, |rng| {
            let prec = *rng.choice(&[448u32, 960]);
            let a = rand_ap(rng, prec, 250);
            let b = rand_ap(rng, prec, 250);
            let want = div_reference(&a, &b);
            assert_eq!(a.div(&b), want, "div vs old path at prec {prec}");
            assert_eq!(a.div_with(&b, &mut scratch), want, "div_with at prec {prec}");
        });
        // exact quotients and zero numerator through both entry points
        let a = ApFloat::from_i64(-84, P);
        let b = ApFloat::from_i64(7, P);
        assert_eq!(a.div(&b), div_reference(&a, &b));
        assert_eq!(ApFloat::zero(P).div_with(&b, &mut scratch), ApFloat::zero(P));
    }

    #[test]
    fn div_at_960_bits() {
        let p = 960;
        let a = ApFloat::from_i64(1 << 40, p);
        let b = ApFloat::from_i64(1 << 20, p);
        assert_eq!(a.div(&b), ApFloat::from_i64(1 << 20, p));
    }

    #[test]
    fn mac_matches_mul_then_add() {
        testkit::check(50, |rng| {
            let c = rand_ap(rng, P, 50);
            let a = rand_ap(rng, P, 50);
            let b = rand_ap(rng, P, 50);
            assert_eq!(c.mac(&a, &b), c.add(&a.mul(&b)));
        });
    }

    #[test]
    fn works_at_960_bit_precision() {
        let p = 960;
        let a = ApFloat::from_i64(123456789, p);
        let b = ApFloat::from_i64(-987654321, p);
        assert_eq!(a.mul(&b), ApFloat::from_i64(123456789 * -987654321, p));
        assert_eq!(a.add(&b), ApFloat::from_i64(123456789 - 987654321, p));
    }
}
