//! From-scratch property-testing kit (proptest is unavailable offline).
//!
//! Deterministic xorshift PRNG + a tiny runner that executes a property over
//! many generated cases and reports the failing seed, so failures are
//! reproducible with `Rng::from_seed(seed)`.

/// xorshift64* — deterministic, fast, good enough for test-case generation.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn from_seed(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Rng { state: seed.wrapping_mul(2685821657736338717).max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) (n > 0).
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias is negligible for test generation
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// One of the elements of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Vector of uniformly random u64 limbs.
    pub fn limbs(&mut self, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.next_u64()).collect()
    }
}

/// Random normalized [`crate::softfloat::ApFloat`] with exponent uniform
/// in [-exp_range, exp_range] — the operand generator shared by the
/// softfloat tests, the allocation-free test and the hot-path benches.
pub fn rand_ap(rng: &mut Rng, prec: u32, exp_range: i64) -> crate::softfloat::ApFloat {
    let n = (prec / 64) as usize;
    let mut mant = rng.limbs(n);
    mant[n - 1] |= 1 << 63; // normalize: MSB set
    crate::softfloat::ApFloat::from_parts(
        rng.bool(),
        rng.range_i64(-exp_range, exp_range),
        mant,
        prec,
    )
}

/// Run `prop` over `cases` generated cases; panic with the case seed on
/// failure, so the failure reproduces with `Rng::from_seed(seed)`.
pub fn check<F: FnMut(&mut Rng)>(cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(case + 1);
        let mut rng = Rng::from_seed(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("testkit: property failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::from_seed(7);
        let mut b = Rng::from_seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng::from_seed(1);
        for _ in 0..1000 {
            assert!(rng.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut rng = Rng::from_seed(2);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = rng.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(25, |_| n += 1);
        assert_eq!(n, 25);
    }
}
