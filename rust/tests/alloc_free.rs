//! Counting-allocator proof of the ISSUE 1/2 acceptance criteria: the
//! whole softfloat MAC pipeline — `mul`, `add`, `mac` and the GEMM inner
//! loop built on them — performs zero heap allocations in steady state,
//! both through the explicit-arena `*_into` paths and through the plain
//! operators when results are recycled.
//!
//! This file intentionally holds a single `#[test]` so no sibling test
//! thread allocates while a measurement window is open.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use apfp::baseline::{gemm_fixed, gemm_into, pack_b_fixed, GemmScratch};
use apfp::bigint::Scratch;
use apfp::config::ApfpConfig;
use apfp::coordinator::{Device, Matrix};
use apfp::pack::PlaneBatch;
use apfp::runtime::{manifest, ArtifactKind, Backend, BackendKind, NativeBackend, TileShape};
use apfp::softfloat;
use apfp::softfloat::{ApFloat, ApFloatN};
use apfp::testkit::{rand_ap, Rng};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// Smallest allocation count observed over `rounds` runs of `body` — the
/// steady-state cost, immune to one-off warmup effects.
fn min_alloc_delta(rounds: usize, mut body: impl FnMut()) -> u64 {
    let mut best = u64::MAX;
    for _ in 0..rounds {
        let before = allocs();
        body();
        best = best.min(allocs() - before);
    }
    best
}

#[test]
fn mac_pipeline_is_allocation_free() {
    for prec in [448u32, 960] {
        let mut rng = Rng::from_seed(0xA110C);
        let a = rand_ap(&mut rng, prec, 40);
        let b = rand_ap(&mut rng, prec, 40);

        // --- mul_into against an explicit arena ----------------------------
        let mut scratch = Scratch::new();
        let mut out = a.mul_with(&b, &mut scratch); // warm arena + output
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                a.mul_into(&b, &mut out, &mut scratch);
            }
        });
        assert_eq!(delta, 0, "mul_into allocated in steady state at prec {prec}");
        assert_eq!(out, a.mul(&b), "arena path must stay correct");

        // --- mul_with + recycle_into on the same explicit arena ------------
        let warm = a.mul_with(&b, &mut scratch);
        softfloat::recycle_into(warm, &mut scratch); // warm pool
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                let r = a.mul_with(&b, &mut scratch);
                softfloat::recycle_into(r, &mut scratch);
            }
        });
        assert_eq!(delta, 0, "mul_with + recycle_into allocated at prec {prec}");

        // --- plain `mul` with recycling (thread-local arena) ---------------
        for _ in 0..4 {
            softfloat::recycle(a.mul(&b)); // warm pool, scratch, and TLS
        }
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                let r = a.mul(&b);
                softfloat::recycle(r);
            }
        });
        assert_eq!(delta, 0, "recycled mul allocated in steady state at prec {prec}");

        // --- add_into / sub_into against the explicit arena ----------------
        a.add_into(&b, &mut out, &mut scratch); // warm output
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                a.add_into(&b, &mut out, &mut scratch);
                a.sub_into(&b, &mut out, &mut scratch);
            }
        });
        assert_eq!(delta, 0, "add_into/sub_into allocated at prec {prec}");
        assert_eq!(out, a.sub(&b), "arena adder must stay correct");

        // --- add_with / sub_with on the warm recycle pool ------------------
        softfloat::recycle_into(a.add_with(&b, &mut scratch), &mut scratch);
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                softfloat::recycle_into(a.add_with(&b, &mut scratch), &mut scratch);
                softfloat::recycle_into(a.sub_with(&b, &mut scratch), &mut scratch);
            }
        });
        assert_eq!(delta, 0, "add_with/sub_with allocated at prec {prec}");

        // --- plain `add` with recycling (thread-local arena) ---------------
        for _ in 0..4 {
            softfloat::recycle(a.add(&b));
        }
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                let r = a.add(&b);
                softfloat::recycle(r);
            }
        });
        assert_eq!(delta, 0, "recycled add allocated in steady state at prec {prec}");

        // --- mac_into accumulation chain (the GEMM inner-loop primitive) ---
        let mut acc = rand_ap(&mut rng, prec, 40);
        acc.mac_into(&a, &b, &mut scratch); // warm the product/sum buffers
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                acc.mac_into(&a, &b, &mut scratch);
                if acc.exp() > 1 << 40 {
                    acc.assign(&a); // bounded exponents, allocation-free
                }
            }
        });
        assert_eq!(delta, 0, "mac_into allocated in steady state at prec {prec}");
    }

    // --- steady-state GEMM tile: out += A*B over a warm workspace ---------
    // One warm GemmScratch + a live output tile: repeated accumulation over
    // the packed panel must not touch the allocator at all.
    for prec in [448u32, 960] {
        let a = Matrix::random(6, 8, prec, 11, 20);
        let b = Matrix::random(8, 5, prec, 12, 20);
        let c = Matrix::random(6, 5, prec, 13, 20);
        let mut ws = GemmScratch::new();
        let mut out = c.clone();
        gemm_into(&a, &b, &mut out, &mut ws); // warm panel, arena, output
        let delta = min_alloc_delta(3, || {
            gemm_into(&a, &b, &mut out, &mut ws);
        });
        assert_eq!(delta, 0, "steady-state gemm_into tile allocated at prec {prec}");
        // and the result of the warm path stays bit-exact: replay the same
        // number of accumulations through the reference path
        let rounds = 1 + 3; // warmup + measured rounds
        let mut want = c.clone();
        for _ in 0..rounds {
            want = apfp::baseline::gemm_serial(&a, &b, &want);
        }
        assert_eq!(out, want, "warm tile accumulation must stay correct");
    }

    // --- steady-state fixed-width GEMM tile: gemm_fixed on stack scalars --
    // The const-generic fast path: a warm gemm_fixed tile — operands and
    // output held as `[u64; LIMBS]` stack values in plain Vecs — must be
    // zero-alloc.  There is no arena and no per-value buffer to warm; the
    // only allocations are the operand Vecs built up front.
    {
        fn fixed_tile<const L: usize>(prec: u32) {
            let (n, k, m) = (6usize, 8usize, 5usize);
            let a = Matrix::random(n, k, prec, 11, 20);
            let b = Matrix::random(k, m, prec, 12, 20);
            let c = Matrix::random(n, m, prec, 13, 20);
            let mut af: Vec<ApFloatN<L>> = Vec::new();
            for i in 0..n {
                for kk in 0..k {
                    af.push(ApFloatN::from_ap(a.get(i, kk)));
                }
            }
            let mut bt = Vec::new();
            pack_b_fixed::<L>(&b, &mut bt);
            let mut cf: Vec<ApFloatN<L>> = Vec::new();
            for i in 0..n {
                for j in 0..m {
                    cf.push(ApFloatN::from_ap(c.get(i, j)));
                }
            }
            gemm_fixed(&af, &bt, &mut cf, n, k, m); // matches the warmup round below
            let delta = min_alloc_delta(3, || {
                gemm_fixed(&af, &bt, &mut cf, n, k, m);
            });
            assert_eq!(delta, 0, "warm gemm_fixed tile allocated at prec {prec}");
            // bit-exact vs the dynamic reference over the same replay count,
            // decoded through the write_to shim (itself allocation-free once
            // the output width matches)
            let rounds = 1 + 3;
            let mut want = c.clone();
            for _ in 0..rounds {
                want = apfp::baseline::gemm_serial(&a, &b, &want);
            }
            let mut out = ApFloat::zero(prec);
            let before = allocs();
            for i in 0..n {
                for j in 0..m {
                    cf[i * m + j].write_to(&mut out);
                    assert_eq!(&out, want.get(i, j), "warm fixed tile ({i},{j}) prec {prec}");
                }
            }
            assert!(
                allocs() - before <= 1,
                "write_to decode loop allocated more than the one width fixup at prec {prec}"
            );
        }
        fixed_tile::<7>(448);
        fixed_tile::<15>(960);
    }

    // --- steady-state NativeBackend GEMM tile: the device datapath --------
    // Both lanes must meet the zero-alloc bar.  The fixed lane
    // (exec_gemm_tile_fixed behind `with_fixed_path(true)`) decodes planes
    // into reused `[u64; LIMBS]` slot Vecs and accumulates on the stack;
    // the dynamic lane (`with_fixed_path(false)`) decodes into reused
    // ApFloat slots and accumulates through the arena.  A warm
    // exec_gemm_tile loop — the compute-unit worker's K-step — must not
    // touch the allocator on either lane.
    for bits in [512u32, 1024] {
        let meta = manifest::builtin(bits, TileShape { n: 8, m: 8, k: 8 })
            .unwrap()
            .into_iter()
            .find(|m| m.kind == ArtifactKind::Gemm)
            .expect("builtin gemm artifact");
        let prec = meta.prec();
        let (tn, tm, kt) = (meta.t_n, meta.t_m, meta.k_tile);
        let mut rng = Rng::from_seed(0xD00D);
        let batch = |n: usize, rng: &mut Rng| -> (Vec<ApFloat>, PlaneBatch) {
            let vals: Vec<ApFloat> = (0..n).map(|_| rand_ap(rng, prec, 30)).collect();
            let planes = PlaneBatch::from_slice(&vals, prec);
            (vals, planes)
        };
        let (av, a) = batch(tn * kt, &mut rng);
        let (bv, b) = batch(kt * tm, &mut rng);
        let (cv, cp) = batch(tn * tm, &mut rng);
        for (lane, fixed) in [("fixed", true), ("dynamic", false)] {
            let mut c = cp.clone();
            let backend = NativeBackend::with_fixed_path(fixed);
            backend.exec_gemm_tile(&meta, &a, &b, &mut c).unwrap(); // warm slots + arena
            let delta = min_alloc_delta(3, || {
                backend.exec_gemm_tile(&meta, &a, &b, &mut c).unwrap();
            });
            assert_eq!(
                delta, 0,
                "native {lane}-lane exec_gemm_tile allocated in steady state at {bits} bits"
            );
            // the warm path stays bit-exact: replay warmup + measured rounds
            // through the softfloat mac chain
            let rounds = 1 + 3;
            for i in 0..tn {
                for j in 0..tm {
                    let mut acc = cv[i * tm + j].clone();
                    for _ in 0..rounds {
                        for k in 0..kt {
                            acc = acc.mac(&av[i * kt + k], &bv[k * tm + j]);
                        }
                    }
                    assert_eq!(
                        c.get(i * tm + j),
                        acc,
                        "warm native {lane}-lane tile ({i},{j}) at {bits} bits"
                    );
                }
            }
        }
    }

    // --- steady-state DeviceStream: warm pipelined enqueues + wait --------
    // The batched-launch acceptance criterion: on a warm stream (B tile
    // grids cached, staging pool filled, reply channels pooled, worker
    // buffers shaped) a full round of TWO independent enqueues — which the
    // hazard tracker keeps in flight simultaneously — plus the drain
    // touches the allocator exactly zero times: leader-side submission,
    // per-launch bookkeeping AND the worker thread's tile execution
    // (run_tile: PlanePanel::extract_tile_into / PlaneBatch::reset staging
    // the A and C tiles, exec_gemm_tile per K step, and the retirement
    // writeback through PlanePanel::write_tile), since the counting
    // allocator is global.
    if BackendKind::from_env() == BackendKind::Native {
        let cfg = ApfpConfig {
            compute_units: 1,
            tile_n: 4,
            tile_m: 4,
            tile_k: 4,
            ..Default::default()
        };
        let dir = std::env::temp_dir().join("apfp_alloc_stream_no_artifacts/none");
        let dev = Device::new(cfg, &dir).expect("native device on a clean checkout");
        let a = Matrix::random(8, 8, 448, 70, 20);
        let b = Matrix::random(8, 8, 448, 71, 20);
        let c = Matrix::random(8, 8, 448, 72, 20);
        let d = Matrix::random(8, 8, 448, 73, 20);
        let e = Matrix::random(8, 8, 448, 74, 20);
        let f = Matrix::random(8, 8, 448, 75, 20);
        let mut s = dev.stream().unwrap();
        let (ha, hb, hc) = (s.upload(&a), s.upload(&b), s.upload(&c));
        let (hd, he, hf) = (s.upload(&d), s.upload(&e), s.upload(&f));
        let warm_rounds = 2;
        for _ in 0..warm_rounds {
            s.enqueue_gemm(ha, hb, hc).unwrap();
            s.enqueue_gemm(hd, he, hf).unwrap(); // disjoint: stays in flight
            s.wait().unwrap();
        }
        // the warm rounds really pipelined: both launches were in flight
        assert!(
            dev.metrics().inflight_max >= 2,
            "disjoint warm launches must overlap, got {}",
            dev.metrics().inflight_max
        );
        let measured_rounds = 3;
        let delta = min_alloc_delta(measured_rounds, || {
            s.enqueue_gemm(ha, hb, hc).unwrap();
            s.enqueue_gemm(hd, he, hf).unwrap();
            s.wait().unwrap();
        });
        assert_eq!(delta, 0, "warm pipelined enqueue+wait allocated in steady state");
        // the warm path stays bit-exact: every round accumulated A@B onto
        // the resident C and D@E onto the resident F; replay both chains
        // through the baseline
        let rounds = warm_rounds + measured_rounds;
        let (mut want_c, mut want_f) = (c.clone(), f.clone());
        for _ in 0..rounds {
            want_c = apfp::baseline::gemm_serial(&a, &b, &want_c);
            want_f = apfp::baseline::gemm_serial(&d, &e, &want_f);
        }
        assert_eq!(s.download(hc).unwrap(), want_c, "warm stream accumulation stays correct");
        assert_eq!(s.download(hf).unwrap(), want_f, "pipelined launch accumulation stays correct");
    } else {
        eprintln!("skipped: stream alloc proof needs the native backend");
    }
}
