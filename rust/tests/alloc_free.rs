//! Counting-allocator proof of the ISSUE 1 acceptance criterion: the
//! softfloat multiply hot path performs zero heap allocations in steady
//! state, both through the explicit-arena `mul_into` path and through
//! plain `ApFloat::mul` when results are recycled.
//!
//! This file intentionally holds a single `#[test]` so no sibling test
//! thread allocates while a measurement window is open.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use apfp::bigint::MulScratch;
use apfp::softfloat;
use apfp::testkit::{rand_ap, Rng};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// Smallest allocation count observed over `rounds` runs of `body` — the
/// steady-state cost, immune to one-off warmup effects.
fn min_alloc_delta(rounds: usize, mut body: impl FnMut()) -> u64 {
    let mut best = u64::MAX;
    for _ in 0..rounds {
        let before = allocs();
        body();
        best = best.min(allocs() - before);
    }
    best
}

#[test]
fn mul_hot_path_is_allocation_free() {
    for prec in [448u32, 960] {
        let mut rng = Rng::from_seed(0xA110C);
        let a = rand_ap(&mut rng, prec, 40);
        let b = rand_ap(&mut rng, prec, 40);

        // --- mul_into against an explicit arena ----------------------------
        let mut scratch = MulScratch::new();
        let mut out = a.mul_with(&b, &mut scratch); // warm arena + output
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                a.mul_into(&b, &mut out, &mut scratch);
            }
        });
        assert_eq!(delta, 0, "mul_into allocated in steady state at prec {prec}");
        assert_eq!(out, a.mul(&b), "arena path must stay correct");

        // --- mul_with + recycle_into on the same explicit arena ------------
        let warm = a.mul_with(&b, &mut scratch);
        softfloat::recycle_into(warm, &mut scratch); // warm pool
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                let r = a.mul_with(&b, &mut scratch);
                softfloat::recycle_into(r, &mut scratch);
            }
        });
        assert_eq!(delta, 0, "mul_with + recycle_into allocated at prec {prec}");

        // --- plain `mul` with recycling (thread-local arena) ---------------
        for _ in 0..4 {
            softfloat::recycle(a.mul(&b)); // warm pool, scratch, and TLS
        }
        let delta = min_alloc_delta(3, || {
            for _ in 0..1000 {
                let r = a.mul(&b);
                softfloat::recycle(r);
            }
        });
        assert_eq!(delta, 0, "recycled mul allocated in steady state at prec {prec}");
    }
}
